//! Multi-backend demo: the same high-level kernel call runs unchanged on
//! the PJRT device (AOT JAX/Pallas artifact) and on the VTX emulator
//! (generated virtual-ISA kernel) — the paper's §5 claim that the same
//! driver API serves real hardware and the GPU Ocelot emulator, so
//! "developers can use the GPU support without any physical hardware".
//!
//! Run with: `cargo run --release --example multi_backend`

use hlgpu::coordinator::{arg, Launcher};
use hlgpu::tensor::Tensor;
use hlgpu::tracetransform::{impls, orientations, shepp_logan};

fn run_on(label: &str, mut launcher: Launcher) -> hlgpu::Result<Vec<f32>> {
    // 32x32 with 90 orientations — a signature the AOT manifest carries
    let size = 32;
    let img = shepp_logan(size).to_tensor();
    let thetas = orientations(90);
    let angles_t = Tensor::from_f32(&thetas, &[thetas.len()]);
    let mut sinos = Tensor::zeros_f32(&[4, thetas.len(), size]);

    // identical call on every backend:
    launcher.launch(
        "sinogram_all",
        hlgpu::driver::LaunchConfig::new(thetas.len() as u32, size as u32),
        &mut [arg::cu_in(&img), arg::cu_in(&angles_t), arg::cu_out(&mut sinos)],
    )?;

    println!(
        "  {label:<12} backend={:<14} sino[0][0][..4] = {:?}",
        launcher.context().backend_name(),
        &sinos.as_f32()[..4]
    );
    Ok(sinos.to_vec_f32())
}

fn main() -> hlgpu::Result<()> {
    println!("running `sinogram_all` through the identical API on both devices:");

    // device 0: PJRT, kernels resolved from the AOT artifact manifest
    let pjrt = Launcher::with_default_context()?;
    let a = run_on("pjrt", pjrt)?;

    // device 1: VTX emulator, kernels generated at first use by providers
    let mut emu = Launcher::emulator()?;
    impls::register_trace_providers(emu.registry_mut());
    let b = run_on("emulator", emu)?;

    // the two backends agree numerically
    let mut max_abs = 0.0f32;
    for (x, y) in a.iter().zip(&b) {
        max_abs = max_abs.max((x - y).abs());
    }
    println!("max |pjrt - emulator| = {max_abs:.2e}");
    assert!(max_abs < 1e-2, "backends diverge");
    println!("multi_backend OK");
    Ok(())
}
