//! Quickstart: the paper's Listing 3, line for line.
//!
//! ```julia
//! @target ptx function vadd(a, b, c) ... end     # L1 Pallas kernel (AOT)
//! dims = (3, 4)
//! a = round(rand(Float32, dims) * 100)
//! b = round(rand(Float32, dims) * 100)
//! c = Array(Float32, dims)
//! len = prod(dims)
//! @cuda (len, 1) vadd(CuIn(a), CuIn(b), CuOut(c))
//! @assert a+b == c
//! ```
//!
//! Run with: `cargo run --release --example quickstart`

use hlgpu::coordinator::{arg, Launcher};
use hlgpu::cuda;
use hlgpu::tensor::Tensor;
use hlgpu::util::Prng;

fn main() -> hlgpu::Result<()> {
    // the kernel itself lives in python/compile/kernels/vadd.py (Pallas),
    // AOT-lowered by `make artifacts`; the framework finds it by call
    // signature, compiles it on first use, and caches the specialization.
    let mut launcher = Launcher::with_default_context()?;

    // create some data — dims = (3, 4), like the paper
    let dims = [3usize, 4usize];
    let len = dims[0] * dims[1];
    let mut rng = Prng::new(7);
    let a = Tensor::from_f32(
        &rng.f32_vec(len, 0.0, 100.0).iter().map(|v| v.round()).collect::<Vec<_>>(),
        &[len],
    );
    let b = Tensor::from_f32(
        &rng.f32_vec(len, 0.0, 100.0).iter().map(|v| v.round()).collect::<Vec<_>>(),
        &[len],
    );
    let mut c = Tensor::zeros_f32(&[len]);

    // execute!  @cuda (len, 1) vadd(CuIn(a), CuIn(b), CuOut(c))
    cuda!(launcher, (len, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))?;

    // verify  @assert a+b == c
    for i in 0..len {
        assert_eq!(c.as_f32()[i], a.as_f32()[i] + b.as_f32()[i]);
    }

    // call again: the specialization cache makes this launch warm
    cuda!(launcher, (len, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))?;
    let stats = launcher.cache_stats();
    assert_eq!(stats.misses, 1, "first call specializes");
    assert_eq!(stats.hits, 1, "second call hits the method cache");

    println!("quickstart OK: c[0..4] = {:?}", &c.as_f32()[..4]);
    println!(
        "specializations: {} cold ({} ms), cache: {} hit / {} miss",
        launcher.metrics().cold_specializations,
        launcher.metrics().specialize_ns / 1_000_000,
        stats.hits,
        stats.misses,
    );
    Ok(())
}
