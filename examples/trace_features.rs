//! End-to-end driver: trace-transform feature extraction over a corpus of
//! synthetic phantom images, through the full framework path (automation +
//! specialization cache + transfer planning + PJRT execution of the
//! JAX/Pallas AOT artifacts), cross-checked against the native CPU
//! implementation and reported with throughput numbers.
//!
//! This is the repository's E2E validation workload (see EXPERIMENTS.md).
//!
//! Run with: `cargo run --release --example trace_features -- [size] [n_images]`

use std::time::Instant;

use hlgpu::stats::lognormal_fit;
use hlgpu::tracetransform::{
    orientations, random_phantom, CpuNative, DeviceChoice, GpuAuto, TraceImpl, FEATURE_COUNT,
};

fn main() -> hlgpu::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(128);
    let n_images: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let angles = 90;

    println!("corpus: {n_images} random phantoms, {size}x{size}, {angles} orientations");
    let corpus: Vec<_> = (0..n_images as u64).map(|i| random_phantom(size, i)).collect();
    let thetas = orientations(angles);

    // full framework path
    let mut auto = GpuAuto::on_device(DeviceChoice::Pjrt)?;
    // native reference for cross-checking
    let mut reference = CpuNative::new();

    // warmup (specialization happens here, once for the whole corpus —
    // every image has the same signature)
    let _ = auto.features(&corpus[0], &thetas)?;

    let mut per_image = Vec::with_capacity(n_images);
    let mut feature_matrix = Vec::with_capacity(n_images);
    let t_total = Instant::now();
    for img in &corpus {
        let t0 = Instant::now();
        let feats = auto.features(img, &thetas)?;
        per_image.push(t0.elapsed().as_secs_f64());
        assert_eq!(feats.len(), FEATURE_COUNT);
        feature_matrix.push(feats);
    }
    let total = t_total.elapsed().as_secs_f64();

    // cross-check a sample of the corpus against the native implementation
    let mut max_dev = 0.0f32;
    for (i, img) in corpus.iter().enumerate().step_by(n_images.div_ceil(5).max(1)) {
        let want = reference.features(img, &thetas)?;
        for (a, b) in feature_matrix[i].iter().zip(&want) {
            max_dev = max_dev.max((a - b).abs() / b.abs().max(1.0));
        }
    }
    assert!(max_dev < 5e-3, "framework deviates from native: {max_dev}");

    let summary = lognormal_fit(&per_image);
    println!(
        "per-image latency: {:.3} ms (log-normal mean, ±{:.2}%)",
        summary.mean * 1e3,
        summary.rel_uncertainty_pct()
    );
    println!(
        "throughput: {:.1} images/s  ({} images in {:.2} s)",
        n_images as f64 / total,
        n_images,
        total
    );
    println!(
        "feature matrix: {n_images} x {FEATURE_COUNT}; max deviation vs cpu-native: {max_dev:.2e}"
    );
    let m = auto.launcher().metrics();
    println!(
        "launcher: {} launches, {} cold specializations ({} ms specialize time)",
        m.launches,
        m.cold_specializations,
        m.specialize_ns / 1_000_000
    );
    // the whole corpus reused ONE specialization — the paper's central claim
    assert_eq!(m.cold_specializations, 1);
    println!("trace_features OK");
    Ok(())
}
