//! Streams & events demo: overlapping independent work on two streams and
//! timing with events — the driver-API surface (paper §5) that the
//! CUDA.jl wrapper exposes beyond plain kernel launches.
//!
//! A two-stage pipeline runs on the VTX emulator device: stream A rotates
//! phantom images while stream B reduces the previous image's sinogram,
//! with events fencing the handoff.
//!
//! Run with: `cargo run --release --example pipeline_stream`

use hlgpu::driver::{Context, Event, KernelArg, LaunchConfig, ModuleSource};
use hlgpu::emulator::kernels;
use hlgpu::tracetransform::{orientations, random_phantom};

fn main() -> hlgpu::Result<()> {
    // emulator device: everything local, no artifacts needed
    let dev = hlgpu::driver::emulator_device()?;
    let ctx = Context::create(&dev)?;

    let size = 48usize;
    let angles = 24usize;
    let n_images = 6;
    let thetas = orientations(angles);

    let module = ctx.load_module(&ModuleSource::Vtx {
        kernels: vec![kernels::sinogram_all()?, kernels::vadd()?],
    })?;
    let sino_fn = module.function("sinogram_all")?;

    // device buffers
    let img_buf = ctx.alloc(size * size * 4)?;
    let ang_buf = ctx.alloc(angles * 4)?;
    let sino_buf = ctx.alloc(4 * angles * size * 4)?;
    let angle_bytes: Vec<u8> = thetas.iter().flat_map(|v| v.to_le_bytes()).collect();
    ctx.upload(ang_buf, &angle_bytes)?;

    let compute = ctx.create_stream()?;
    let reduce = ctx.create_stream()?;

    let start = Event::new();
    start.record_now();

    let mem = ctx.memory_arc()?;
    let mut totals: Vec<f64> = Vec::new();
    for i in 0..n_images {
        // host prepares and uploads the next image (sync, cheap)
        let img = random_phantom(size, i as u64);
        let bytes: Vec<u8> = img.pixels().iter().flat_map(|v| v.to_le_bytes()).collect();
        ctx.upload(img_buf, &bytes)?;

        // stage 1 on the compute stream: fused sinogram
        let f = sino_fn.clone();
        let mem1 = mem.clone();
        let cfg = LaunchConfig::new(angles as u32, size as u32);
        compute.enqueue(move || {
            f.launch(
                &cfg,
                &[
                    KernelArg::Ptr(img_buf),
                    KernelArg::Ptr(ang_buf),
                    KernelArg::Ptr(sino_buf),
                    KernelArg::I32(size as i32),
                ],
                &mem1,
            )
        })?;
        // event fences the sinogram for the reducer stream
        let done = Event::new();
        compute.record_event(&done)?;

        // stage 2 on the reduce stream: downstream reduction (host-side
        // math, ordered after the event)
        let mem2 = mem.clone();
        let (tx, rx) = std::sync::mpsc::channel::<f64>();
        reduce.enqueue(move || {
            done.synchronize();
            let raw = mem2.read_raw(sino_buf)?;
            let total: f64 = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64)
                .sum();
            let _ = tx.send(total);
            Ok(())
        })?;
        // compute must finish this image before we overwrite img_buf
        compute.synchronize()?;
        totals.push(rx.recv().map_err(|e| hlgpu::Error::Stream(e.to_string()))?);
    }
    reduce.synchronize()?;

    let end = Event::new();
    end.record_now();
    let ms = Event::elapsed_ms(&start, &end)?;

    println!("pipeline processed {n_images} images in {ms:.1} ms on 2 streams");
    for (i, t) in totals.iter().enumerate() {
        println!("  image {i}: sinogram mass {t:.1}");
    }
    assert!(totals.iter().all(|t| t.is_finite() && *t != 0.0));
    let stats = ctx.mem_stats()?;
    println!(
        "transfers: {} H2D ({} KiB), device reads by reducer bypass D2H accounting",
        stats.h2d_count,
        stats.h2d_bytes / 1024
    );
    println!("pipeline_stream OK");
    Ok(())
}
