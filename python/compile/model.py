"""L2: the trace-transform compute graph in JAX, calling the L1 kernels.

The paper's application (§7.1) splits the trace transform into five or more
kernels: per-orientation rotation+projection (the hot, shared-memory one),
the simpler per-stage functionals, and host glue. We expose the same
decomposition as individually-lowerable stage functions (the *manual* launch
path launches each stage separately, like the paper's CUDA C kernels) plus
one fused full-pipeline graph (the L2 composition the automation path can
launch as a single module).

Everything here is build-time Python: ``aot.py`` lowers these functions to
HLO text once; the rust coordinator executes the artifacts via PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref
from .kernels.ref import F_FUNCTIONALS, P_FUNCTIONALS
from .kernels.tfunctionals import T_FUNCTIONALS

__all__ = [
    "vadd_graph",
    "rotate_graph",
    "tfunc_graph",
    "sinogram_graph",
    "pfunc_graph",
    "trace_full_graph",
    "FEATURE_ORDER",
]

#: (t, p, f) tuples in the exact order trace_full_graph emits features.
FEATURE_ORDER = [
    (t, p, f) for t in T_FUNCTIONALS for p in P_FUNCTIONALS for f in F_FUNCTIONALS
]


def vadd_graph(a, b):
    """Stage: the paper's running example."""
    return (kernels.vadd(a, b),)


def rotate_graph(img, theta):
    """Stage: bilinear rotation (Pallas kernel)."""
    return (kernels.rotate(img, theta),)


def tfunc_graph(img, name: str):
    """Stage: one T-functional over a (rotated) image's columns."""
    return (kernels.tfunctional(img, name),)


def sinogram_graph(img, thetas, name: str):
    """Stage: fused rotate+T-functional sinogram (the hot kernel)."""
    return (kernels.sinogram(img, thetas, name),)


def sinogram_all_graph(img, thetas):
    """Stage: multi-functional sinogram — one resampling pass feeds all
    |T| functionals (the optimized GPU-path kernel, see §Perf)."""
    return (kernels.sinogram_all(img, thetas),)


def pfunc_graph(sino, name: str):
    """Stage: P-functional, sinogram rows -> circus function (A,)."""
    return (ref.apply_p(sino, name),)


def trace_full_graph(img, thetas):
    """Fused full pipeline: image -> |T|x|P|x|F| feature vector.

    The sinogram for each T-functional is computed once (Pallas kernel) and
    shared by every (P, F) combination — no recomputation of the rotation
    grid across functionals (see DESIGN.md §Perf, L2 target).
    """
    feats = []
    for t in T_FUNCTIONALS:
        sino = kernels.sinogram(img, thetas, t)
        for p in P_FUNCTIONALS:
            circus = ref.apply_p(sino, p)
            for f in F_FUNCTIONALS:
                feats.append(ref.apply_f(circus, f))
    return (jnp.stack(feats),)
