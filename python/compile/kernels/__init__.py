"""L1: Pallas kernels for the paper's compute hot-spots.

Every kernel is lowered with ``interpret=True`` (CPU-PJRT compatible) and
has a pure-jnp oracle in :mod:`ref`.
"""

from .rotate import rotate
from .sinogram import sinogram, sinogram_all
from .tfunctionals import T_FUNCTIONALS, tfunctional
from .vadd import vadd

__all__ = ["vadd", "rotate", "tfunctional", "sinogram", "sinogram_all", "T_FUNCTIONALS"]
