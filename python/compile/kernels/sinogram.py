"""L1 Pallas kernel: fused rotate + T-functional sinogram step.

The hot path of the trace transform: for each orientation theta, resample
the image along the rotated grid and immediately reduce each line with the
T-functional — one sinogram row per orientation. The CUDA reference keeps
the rotated column in shared memory between the sampling threads and the
reduction; the Pallas analog keeps the rotated tile in VMEM and applies the
reduction before writing anything back to HBM, so the rotated image never
materializes in main memory.

Grid = one program per orientation (the paper's coarse-grained parallelism
across orientations, §7.1); within a program the resampling and reduction
are vectorized (the fine-grained parallelism).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tfunctionals as _tf
from .rotate import _bilinear_sample


def _sino_kernel(name: str, img_ref, thetas_ref, o_ref):
    s = img_ref.shape[0]
    img = img_ref[...]
    theta = thetas_ref[0]  # this program's orientation (blocked to 1)
    c = (s - 1) / 2.0
    rows = jax.lax.iota(jnp.int32, s)
    cols = jax.lax.iota(jnp.int32, s)
    dy = rows.astype(jnp.float32)[:, None] - c
    dx = cols.astype(jnp.float32)[None, :] - c
    ct = jnp.cos(theta)
    st = jnp.sin(theta)
    sx = ct * dx + st * dy + c
    sy = -st * dx + ct * dy + c
    rotated = _bilinear_sample(img, sy, sx)
    # Fused T-functional: reduce the VMEM-resident rotated tile per column.
    o_ref[0, :] = _tf.apply_t(rotated, name, axis=0).astype(img.dtype)


def sinogram_all(img: jax.Array, thetas: jax.Array) -> jax.Array:
    """Fused multi-functional sinogram: ONE pass over the rotated samples
    feeds every T-functional (the paper's CUDA code also amortizes the
    resampling across functionals via shared memory). Output shape
    (|T|, A, S), T-axis ordered as ``T_FUNCTIONALS``.

    This is the hot kernel of the optimized GPU path: resampling dominates
    (4 bilinear gathers per sample), so sharing it across the 4
    functionals is a ~4x saving over per-functional kernels — measured in
    EXPERIMENTS.md §Perf.
    """

    def kernel(img_ref, thetas_ref, o_ref):
        s = img_ref.shape[0]
        img = img_ref[...]
        theta = thetas_ref[0]
        c = (s - 1) / 2.0
        rows = jax.lax.iota(jnp.int32, s)
        cols = jax.lax.iota(jnp.int32, s)
        dy = rows.astype(jnp.float32)[:, None] - c
        dx = cols.astype(jnp.float32)[None, :] - c
        ct = jnp.cos(theta)
        st = jnp.sin(theta)
        sx = ct * dx + st * dy + c
        sy = -st * dx + ct * dy + c
        rotated = _bilinear_sample(img, sy, sx)
        # all four functionals on the same VMEM-resident tile
        for ti, name in enumerate(_tf.T_FUNCTIONALS):
            o_ref[ti, 0, :] = _tf.apply_t(rotated, name, axis=0).astype(img.dtype)

    s = img.shape[0]
    a = thetas.shape[0]
    nt = len(_tf.T_FUNCTIONALS)
    return pl.pallas_call(
        kernel,
        grid=(a,),
        in_specs=[
            pl.BlockSpec((s, s), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((nt, 1, s), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, a, s), img.dtype),
        interpret=True,
    )(img, thetas)


def sinogram(img: jax.Array, thetas: jax.Array, name: str) -> jax.Array:
    """Sinogram of ``img``: row a = T-functional of image rotated by
    ``thetas[a]``, applied down each column. Returns (A, S)."""
    if name not in _tf.T_FUNCTIONALS:
        raise ValueError(f"unknown T-functional: {name}")
    s = img.shape[0]
    a = thetas.shape[0]
    return pl.pallas_call(
        functools.partial(_sino_kernel, name),
        grid=(a,),
        in_specs=[
            pl.BlockSpec((s, s), lambda i: (0, 0)),  # full source resident
            pl.BlockSpec((1,), lambda i: (i,)),  # this program's angle
        ],
        out_specs=pl.BlockSpec((1, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a, s), img.dtype),
        interpret=True,
    )(img, thetas)
