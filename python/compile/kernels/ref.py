"""Pure-jnp oracle for every Pallas kernel in this package.

No Pallas here — plain jax.numpy, used by pytest/hypothesis to validate the
kernels and by the rust test-suite (via AOT'd reference artifacts) to
cross-check the native implementations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .tfunctionals import T_FUNCTIONALS, apply_t

P_FUNCTIONALS = ("psum", "pmax", "pl1")
F_FUNCTIONALS = ("fmean", "fmax")


def vadd(a: jax.Array, b: jax.Array) -> jax.Array:
    return a + b


def rotate(img: jax.Array, theta: jax.Array) -> jax.Array:
    """Bilinear rotation, zero fill — same convention as kernels.rotate."""
    s = img.shape[0]
    c = (s - 1) / 2.0
    theta = jnp.asarray(theta, jnp.float32).reshape(())
    rows = jnp.arange(s, dtype=jnp.float32)
    dy = rows[:, None] - c
    dx = rows[None, :] - c
    ct = jnp.cos(theta)
    st = jnp.sin(theta)
    sx = ct * dx + st * dy + c
    sy = -st * dx + ct * dy + c
    y0 = jnp.floor(sy)
    x0 = jnp.floor(sx)
    fy = sy - y0
    fx = sx - x0
    y0i = y0.astype(jnp.int32)
    x0i = x0.astype(jnp.int32)

    def gather(yi, xi):
        yc = jnp.clip(yi, 0, s - 1)
        xc = jnp.clip(xi, 0, s - 1)
        v = img[yc, xc]
        ok = (yi >= 0) & (yi < s) & (xi >= 0) & (xi < s)
        return jnp.where(ok, v, 0.0)

    out = (
        gather(y0i, x0i) * (1.0 - fy) * (1.0 - fx)
        + gather(y0i, x0i + 1) * (1.0 - fy) * fx
        + gather(y0i + 1, x0i) * fy * (1.0 - fx)
        + gather(y0i + 1, x0i + 1) * fy * fx
    )
    return out.astype(img.dtype)


def tfunctional(img: jax.Array, name: str) -> jax.Array:
    return apply_t(img, name, axis=0)


def sinogram(img: jax.Array, thetas: jax.Array, name: str) -> jax.Array:
    def row(theta):
        return tfunctional(rotate(img, theta), name)

    return jax.vmap(row)(thetas)


def sinogram_all(img: jax.Array, thetas: jax.Array) -> jax.Array:
    """Stack of all T-functional sinograms, shape (|T|, A, S)."""
    return jnp.stack([sinogram(img, thetas, t) for t in T_FUNCTIONALS])


def apply_p(sino: jax.Array, name: str) -> jax.Array:
    """Diametric (P-) functional: reduce each sinogram row (over offsets)
    to a scalar -> circus function of the orientation, shape (A,)."""
    if name == "psum":
        return jnp.sum(sino, axis=1)
    if name == "pmax":
        return jnp.max(sino, axis=1)
    if name == "pl1":
        return jnp.sum(jnp.abs(sino), axis=1)
    raise ValueError(f"unknown P-functional: {name}")


def apply_f(circus: jax.Array, name: str) -> jax.Array:
    """Circus (F-) functional: reduce the circus function to one scalar."""
    if name == "fmean":
        return jnp.mean(circus)
    if name == "fmax":
        return jnp.max(circus)
    raise ValueError(f"unknown F-functional: {name}")


def trace_features(img: jax.Array, thetas: jax.Array) -> jax.Array:
    """Full trace-transform feature vector: |T| x |P| x |F| scalars, in
    (t, p, f) lexicographic order over the tuples above."""
    feats = []
    for t in T_FUNCTIONALS:
        sino = sinogram(img, thetas, t)
        for p in P_FUNCTIONALS:
            circus = apply_p(sino, p)
            for f in F_FUNCTIONALS:
                feats.append(apply_f(circus, f))
    return jnp.stack(feats)
