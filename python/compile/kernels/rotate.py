"""L1 Pallas kernel: bilinear image rotation.

The trace transform's inner loop (paper §7.1) resamples the input image
along rotated lines. The CUDA reference assigns one thread per output
pixel; the Pallas port instead tiles the *output* image into row-blocks
(``BlockSpec`` over axis 0), keeps the full source image resident (it is
the randomly-gathered operand, so it must be addressable in full), and
performs the bilinear interpolation as vectorized gathers + weighted adds
on the VPU.

Rotation convention (shared exactly with the rust native implementation in
``rust/src/tracetransform/rotate.rs`` so cross-implementation checks agree
to float tolerance):

    centre c = (S - 1) / 2
    for output pixel (row y, col x):
        dx = x - c; dy = y - c
        sx =  cos(t) * dx + sin(t) * dy + c
        sy = -sin(t) * dx + cos(t) * dy + c
    out[y, x] = bilinear(img, sy, sx), 0 outside the source image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of output computed per grid step. Source image stays fully resident;
# a 512x512 f32 source is 1 MiB — comfortably within a TPU core's VMEM.
ROW_BLOCK = 64


def _bilinear_sample(img, sy, sx):
    """Bilinear sample of ``img`` at float coords (sy, sx), 0 out of range."""
    s = img.shape[0]
    y0 = jnp.floor(sy)
    x0 = jnp.floor(sx)
    fy = sy - y0
    fx = sx - x0
    y0i = y0.astype(jnp.int32)
    x0i = x0.astype(jnp.int32)

    def gather(yi, xi):
        yc = jnp.clip(yi, 0, s - 1)
        xc = jnp.clip(xi, 0, s - 1)
        v = img[yc, xc]
        ok = (yi >= 0) & (yi < s) & (xi >= 0) & (xi < s)
        return jnp.where(ok, v, 0.0)

    v00 = gather(y0i, x0i)
    v01 = gather(y0i, x0i + 1)
    v10 = gather(y0i + 1, x0i)
    v11 = gather(y0i + 1, x0i + 1)
    return (
        v00 * (1.0 - fy) * (1.0 - fx)
        + v01 * (1.0 - fy) * fx
        + v10 * fy * (1.0 - fx)
        + v11 * fy * fx
    )


def _rotate_kernel(row_block: int, img_ref, theta_ref, o_ref):
    s = img_ref.shape[0]
    img = img_ref[...]
    theta = theta_ref[0]
    c = (s - 1) / 2.0
    block = pl.program_id(0)
    rows = block * row_block + jax.lax.iota(jnp.int32, row_block)
    cols = jax.lax.iota(jnp.int32, s)
    dy = rows.astype(jnp.float32)[:, None] - c
    dx = cols.astype(jnp.float32)[None, :] - c
    ct = jnp.cos(theta)
    st = jnp.sin(theta)
    sx = ct * dx + st * dy + c
    sy = -st * dx + ct * dy + c
    o_ref[...] = _bilinear_sample(img, sy, sx).astype(img.dtype)


def rotate(img: jax.Array, theta: jax.Array) -> jax.Array:
    """Rotate a square f32 image by ``theta`` radians (bilinear, zero fill)."""
    s = img.shape[0]
    assert img.shape == (s, s), "rotate expects a square image"
    row_block = ROW_BLOCK if s % ROW_BLOCK == 0 and s > ROW_BLOCK else s
    grid = (s // row_block,)
    theta = jnp.asarray(theta, jnp.float32).reshape((1,))
    return pl.pallas_call(
        functools.partial(_rotate_kernel, row_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, s), lambda i: (0, 0)),  # full source resident
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((row_block, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, s), img.dtype),
        interpret=True,
    )(img, theta)
