"""L1 Pallas kernel: element-wise vector addition.

This is the paper's running example (Listing 1 / Listing 3): the CUDA C
``vadd`` kernel, re-thought for the Pallas/TPU model. Instead of one CUDA
thread per element, the vector is tiled into VMEM-sized blocks via
``BlockSpec``; each grid step processes one block on the VPU.

All kernels in this package are lowered with ``interpret=True``: the CPU
PJRT plugin cannot execute Mosaic custom-calls, and interpret mode lowers
to plain HLO ops that any backend (including the rust ``xla`` crate's CPU
client) can run. See DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size used when the vector is long enough to tile. 8192 f32 lanes
# (32 KiB) stays well within a VMEM tile budget while keeping the grid
# short; §Perf iteration I2 measured 1024 -> 8192 as a 3.4x warm-launch win
# at n=65536 on the CPU interpret path (fewer sequential grid steps, each
# with a dynamic-slice/update round trip).
BLOCK = 8192


def _vadd_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def vadd(a: jax.Array, b: jax.Array) -> jax.Array:
    """Element-wise ``a + b`` as a Pallas call.

    The grid tiles the vector into ``BLOCK``-wide chunks when the length is
    a multiple of ``BLOCK``; otherwise a single program handles the whole
    vector (small sizes — the paper's 3x4 demo shape).
    """
    n = a.shape[0]
    if n % BLOCK == 0 and n > BLOCK:
        grid = (n // BLOCK,)
        spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
        return pl.pallas_call(
            _vadd_kernel,
            grid=grid,
            in_specs=[spec, spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
            interpret=True,
        )(a, b)
    return pl.pallas_call(
        _vadd_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, b)
