"""L1 Pallas kernels: trace (T-) functionals as column reductions.

A T-functional maps every *line* of the rotated image (after rotation the
lines are the image columns) to a scalar — one sinogram sample per column.
The CUDA reference implements these as one threadblock per column with a
shared-memory tree reduction; the Pallas port instead tiles the image into
column-blocks (``BlockSpec`` over axis 1), keeps the tile in VMEM and lets
the reduction happen over the row axis of the resident tile. Weighted
functionals build their weight vector with an ``iota`` once per tile rather
than per-thread index arithmetic.

Supported functionals (names shared verbatim with
``rust/src/tracetransform/functionals.rs``):

    radon : sum_r f(r)                — the Radon transform
    t1    : sum_r |r - c| * f(r)      — first absolute moment
    t2    : sum_r (r - c)^2 * f(r)    — second moment
    tmax  : max_r f(r)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

T_FUNCTIONALS = ("radon", "t1", "t2", "tmax")

# Columns per grid step when the width divides evenly.
COL_BLOCK = 64


def apply_t(col: jax.Array, name: str, axis: int = 0):
    """Reference formula for a T-functional along ``axis`` (shared by the
    Pallas kernel body and the pure-jnp oracle so both stay in sync)."""
    n = col.shape[axis]
    c = (n - 1) / 2.0
    r = jax.lax.iota(jnp.float32, n) - c
    shape = [1] * col.ndim
    shape[axis] = n
    r = r.reshape(shape)
    if name == "radon":
        return jnp.sum(col, axis=axis)
    if name == "t1":
        return jnp.sum(jnp.abs(r) * col, axis=axis)
    if name == "t2":
        return jnp.sum(r * r * col, axis=axis)
    if name == "tmax":
        return jnp.max(col, axis=axis)
    raise ValueError(f"unknown T-functional: {name}")


def _tfunc_kernel(name: str, img_ref, o_ref):
    tile = img_ref[...]  # (S, COL_BLOCK) column tile, rows fully resident
    o_ref[...] = apply_t(tile, name, axis=0).astype(tile.dtype)


def tfunctional(img: jax.Array, name: str) -> jax.Array:
    """Apply T-functional ``name`` down the columns of ``img`` -> (W,)."""
    if name not in T_FUNCTIONALS:
        raise ValueError(f"unknown T-functional: {name}")
    h, w = img.shape
    col_block = COL_BLOCK if w % COL_BLOCK == 0 and w > COL_BLOCK else w
    grid = (w // col_block,)
    return pl.pallas_call(
        functools.partial(_tfunc_kernel, name),
        grid=grid,
        in_specs=[pl.BlockSpec((h, col_block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((col_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((w,), img.dtype),
        interpret=True,
    )(img)
