"""AOT entry point: lower every L2 graph to HLO *text* + a manifest.

This is the analog of the paper's Julia->PTX code generator, run once at
build time (`make artifacts`). The rust coordinator plays the part of the
CUDA driver: it loads the HLO text modules, compiles them on the PJRT CPU
client and launches them — Python is never on the request path.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (what
the published ``xla`` 0.1.6 rust crate links against) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--full]
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import P_FUNCTIONALS
from .kernels.tfunctionals import T_FUNCTIONALS

MANIFEST_VERSION = 1

#: Image sizes lowered by default; ``--full`` appends the paper-scale 512.
#: The small sizes exist to expose the constant launch overhead that makes
#: GPU implementations scale superlinearly at small inputs (Figure 3).
DEFAULT_SIZES = (16, 32, 64, 128, 256)
#: Orientation count for sinogram/full artifacts.
DEFAULT_ANGLES = 90
#: vadd vector lengths: the paper's 3x4 demo (12) plus tiled sizes.
VADD_SIZES = (12, 1024, 4096, 65536)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side can uniformly unwrap with ``to_tuple``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io(shape):
    return {"dtype": "f32", "shape": list(shape)}


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, kernel, fn, in_specs, outputs, meta):
        path = f"{name}.hlo.txt"
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        self.entries.append(
            {
                "name": name,
                "kernel": kernel,
                "path": path,
                "inputs": [_io(s.shape) for s in in_specs],
                "outputs": [_io(s) for s in outputs],
                "meta": meta,
            }
        )
        print(f"  {name}: {len(text) / 1024:.1f} KiB")

    def write_manifest(self):
        manifest = {
            "version": MANIFEST_VERSION,
            "generated_by": "compile.aot",
            "artifacts": self.entries,
        }
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"manifest: {len(self.entries)} artifacts -> {self.out_dir}/manifest.json")


def build_all(out_dir: str, full: bool) -> None:
    sizes = DEFAULT_SIZES + ((512,) if full else ())
    a = DEFAULT_ANGLES
    em = Emitter(out_dir)

    # --- running example -------------------------------------------------
    for n in VADD_SIZES:
        em.emit(
            f"vadd_f32_{n}",
            "vadd",
            model.vadd_graph,
            [_spec((n,)), _spec((n,))],
            [(n,)],
            {"n": n},
        )

    # --- staged kernels (the paper's separate CUDA kernels) --------------
    for s in sizes:
        em.emit(
            f"rotate_f32_{s}",
            "rotate",
            model.rotate_graph,
            [_spec((s, s)), _spec((), jnp.float32)],
            [(s, s)],
            {"size": s},
        )
    # NOTE: logical kernel names are per-functional (`sinogram_radon`, not
    # `sinogram`): all T-variants share the same input signature, and the
    # specialization lookup is (kernel, signature) -> artifact.
    for t in T_FUNCTIONALS:
        for s in (64, 128):
            em.emit(
                f"tfunc_{t}_f32_{s}",
                f"tfunc_{t}",
                functools.partial(model.tfunc_graph, name=t),
                [_spec((s, s))],
                [(s,)],
                {"tfunc": t, "size": s},
            )
    for p in P_FUNCTIONALS:
        em.emit(
            f"pfunc_{p}_f32_{a}x64",
            f"pfunc_{p}",
            functools.partial(model.pfunc_graph, name=p),
            [_spec((a, 64))],
            [(a,)],
            {"pfunc": p, "angles": a, "size": 64},
        )

    # --- the hot fused kernel (one per T-functional and size) ------------
    for t in T_FUNCTIONALS:
        for s in sizes:
            em.emit(
                f"sinogram_{t}_f32_{s}x{a}",
                f"sinogram_{t}",
                functools.partial(model.sinogram_graph, name=t),
                [_spec((s, s)), _spec((a,))],
                [(a, s)],
                {"tfunc": t, "size": s, "angles": a},
            )

    # --- the optimized multi-functional hot kernel (one resampling pass
    #     feeds all |T| functionals; the GPU implementations' default) ----
    nt = len(T_FUNCTIONALS)
    for s in sizes:
        em.emit(
            f"sinogram_all_f32_{s}x{a}",
            "sinogram_all",
            model.sinogram_all_graph,
            [_spec((s, s)), _spec((a,))],
            [(nt, a, s)],
            {"size": s, "angles": a, "tfuncs": nt},
        )

    # --- fused full pipeline (L2 composition, single launch) -------------
    n_feats = len(model.FEATURE_ORDER)
    for s in sizes:
        em.emit(
            f"trace_full_f32_{s}x{a}",
            "trace_full",
            model.trace_full_graph,
            [_spec((s, s)), _spec((a,))],
            [(n_feats,)],
            {"size": s, "angles": a, "features": n_feats},
        )

    em.write_manifest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--full", action="store_true", help="also lower 512x512")
    args = ap.parse_args()
    build_all(args.out_dir, args.full)


if __name__ == "__main__":
    main()
