"""AOT pipeline tests: lowering produces parseable HLO text and a
well-formed manifest (the contract the rust runtime consumes)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.aot import Emitter, to_hlo_text, VADD_SIZES

jax.config.update("jax_platform_name", "cpu")


class TestToHloText:
    def test_vadd_lowering_contains_entry_and_shapes(self):
        lowered = jax.jit(model.vadd_graph).lower(
            jax.ShapeDtypeStruct((32,), jnp.float32),
            jax.ShapeDtypeStruct((32,), jnp.float32),
        )
        text = to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "f32[32]" in text
        # return_tuple=True: output is a tuple type
        assert "(f32[32]" in text

    def test_rotate_lowering_has_scalar_param(self):
        lowered = jax.jit(model.rotate_graph).lower(
            jax.ShapeDtypeStruct((8, 8), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )
        text = to_hlo_text(lowered)
        assert "f32[8,8]" in text


class TestEmitter:
    def test_emit_writes_artifact_and_manifest(self, tmp_path):
        em = Emitter(str(tmp_path))
        em.emit(
            "vadd_f32_8",
            "vadd",
            model.vadd_graph,
            [jax.ShapeDtypeStruct((8,), jnp.float32)] * 2,
            [(8,)],
            {"n": 8},
        )
        em.write_manifest()
        assert (tmp_path / "vadd_f32_8.hlo.txt").exists()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["version"] == 1
        (entry,) = manifest["artifacts"]
        assert entry["kernel"] == "vadd"
        assert entry["inputs"] == [{"dtype": "f32", "shape": [8]}] * 2
        assert entry["outputs"] == [{"dtype": "f32", "shape": [8]}]
        assert entry["meta"] == {"n": 8}

    def test_paper_demo_size_in_default_set(self):
        # Listing 3 uses dims (3, 4) -> 12 elements
        assert 12 in VADD_SIZES


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
class TestBuiltManifest:
    def test_manifest_is_complete_and_consistent(self):
        path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        manifest = json.loads(open(path).read())
        arts = manifest["artifacts"]
        names = [a["name"] for a in arts]
        assert len(names) == len(set(names)), "artifact names must be unique"
        # every referenced file exists
        base = os.path.dirname(path)
        for a in arts:
            assert os.path.exists(os.path.join(base, a["path"])), a["path"]
        # the kernels the rust implementations rely on are present
        kernels = {a["kernel"] for a in arts}
        for required in ["vadd", "sinogram_all", "trace_full", "rotate"]:
            assert required in kernels, f"missing kernel family {required}"
        # (kernel, input signature) is unique — the specialization key
        sigs = [
            (a["kernel"], json.dumps(a["inputs"]))
            for a in arts
        ]
        assert len(sigs) == len(set(sigs)), "ambiguous specialization keys"
