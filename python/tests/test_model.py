"""L2 graph tests: shapes, staged-vs-fused consistency, feature ordering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from compile.kernels.ref import F_FUNCTIONALS, P_FUNCTIONALS
from compile.kernels.tfunctionals import T_FUNCTIONALS

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape, jnp.float32)


def thetas(a):
    return jnp.linspace(0.0, np.pi, a, endpoint=False)


class TestGraphShapes:
    def test_vadd(self):
        (out,) = model.vadd_graph(rand((100,)), rand((100,), 1))
        assert out.shape == (100,)

    def test_rotate(self):
        (out,) = model.rotate_graph(rand((32, 32)), jnp.float32(0.4))
        assert out.shape == (32, 32)

    def test_sinogram(self):
        (out,) = model.sinogram_graph(rand((32, 32)), thetas(7), name="radon")
        assert out.shape == (7, 32)

    def test_pfunc(self):
        (out,) = model.pfunc_graph(rand((9, 32)), name="pmax")
        assert out.shape == (9,)

    def test_trace_full(self):
        (out,) = model.trace_full_graph(rand((16, 16)), thetas(5))
        assert out.shape == (len(model.FEATURE_ORDER),)


class TestFeatureOrder:
    def test_order_is_t_p_f_lexicographic(self):
        assert model.FEATURE_ORDER[0] == (T_FUNCTIONALS[0], P_FUNCTIONALS[0], F_FUNCTIONALS[0])
        assert len(model.FEATURE_ORDER) == len(T_FUNCTIONALS) * len(P_FUNCTIONALS) * len(F_FUNCTIONALS)
        # last entry
        assert model.FEATURE_ORDER[-1] == (T_FUNCTIONALS[-1], P_FUNCTIONALS[-1], F_FUNCTIONALS[-1])

    def test_fused_matches_ref_pipeline(self):
        img = rand((16, 16))
        th = thetas(6)
        (fused,) = model.trace_full_graph(img, th)
        want = ref.trace_features(img, th)
        np.testing.assert_allclose(fused, want, rtol=1e-4, atol=1e-4)

    def test_fused_matches_staged_composition(self):
        img = rand((16, 16), 3)
        th = thetas(4)
        (fused,) = model.trace_full_graph(img, th)
        idx = 0
        for t in T_FUNCTIONALS:
            (sino,) = model.sinogram_graph(img, th, name=t)
            for p in P_FUNCTIONALS:
                (circus,) = model.pfunc_graph(sino, name=p)
                for f in F_FUNCTIONALS:
                    want = ref.apply_f(circus, f)
                    np.testing.assert_allclose(fused[idx], want, rtol=1e-4, atol=1e-4)
                    idx += 1


class TestLowering:
    """The graphs must lower to HLO text acceptable to xla_extension 0.5.1
    (the version the rust `xla` crate links)."""

    def test_vadd_lowers_to_hlo_text(self):
        from compile.aot import to_hlo_text

        lowered = jax.jit(model.vadd_graph).lower(
            jax.ShapeDtypeStruct((64,), jnp.float32),
            jax.ShapeDtypeStruct((64,), jnp.float32),
        )
        text = to_hlo_text(lowered)
        assert "ENTRY" in text and "f32[64]" in text

    def test_sinogram_lowers_to_hlo_text(self):
        import functools

        from compile.aot import to_hlo_text

        fn = functools.partial(model.sinogram_graph, name="radon")
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32),
            jax.ShapeDtypeStruct((5,), jnp.float32),
        )
        text = to_hlo_text(lowered)
        assert "ENTRY" in text
