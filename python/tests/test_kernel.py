"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes; every comparison is assert_allclose
against compile.kernels.ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.tfunctionals import T_FUNCTIONALS

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    return jax.random.uniform(k, shape, dtype, minval=-2.0, maxval=2.0)


# ---------------------------------------------------------------- vadd --
class TestVadd:
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=5000),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_any_length(self, n, seed):
        a, b = rand((n,), seed), rand((n,), seed + 1)
        np.testing.assert_allclose(kernels.vadd(a, b), ref.vadd(a, b), rtol=1e-6)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64, jnp.int32])
    def test_dtypes(self, dtype):
        a = jnp.arange(2048, dtype=dtype)
        b = jnp.arange(2048, dtype=dtype)[::-1].copy()
        np.testing.assert_allclose(kernels.vadd(a, b), ref.vadd(a, b))

    def test_tiled_path_used_for_multiples_of_block(self):
        n = 4096  # exercises the BLOCK-tiled grid
        a, b = rand((n,)), rand((n,), 1)
        np.testing.assert_allclose(kernels.vadd(a, b), a + b, rtol=1e-6)

    def test_paper_demo_shape(self):
        # Listing 3: dims = (3, 4) flattened -> 12 elements.
        a, b = rand((12,)), rand((12,), 1)
        np.testing.assert_allclose(kernels.vadd(a, b), a + b, rtol=1e-6)


# -------------------------------------------------------------- rotate --
class TestRotate:
    @settings(max_examples=15, deadline=None)
    @given(
        s=st.integers(min_value=4, max_value=48),
        theta=st.floats(min_value=-7.0, max_value=7.0, allow_nan=False),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref(self, s, theta, seed):
        img = rand((s, s), seed)
        got = kernels.rotate(img, theta)
        want = ref.rotate(img, theta)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_angle_is_identity(self):
        img = rand((32, 32))
        np.testing.assert_allclose(kernels.rotate(img, 0.0), img, atol=1e-6)

    def test_blocked_grid_path(self):
        # 128 % ROW_BLOCK == 0 -> multi-program grid.
        img = rand((128, 128))
        np.testing.assert_allclose(
            kernels.rotate(img, 0.3), ref.rotate(img, 0.3), rtol=1e-5, atol=1e-5
        )

    def test_full_turn_close_to_identity(self):
        img = rand((24, 24))
        got = kernels.rotate(img, 2.0 * np.pi)
        np.testing.assert_allclose(got, img, atol=1e-3)

    def test_rotation_preserves_mass_approximately(self):
        # Content concentrated at the centre stays inside the frame.
        s = 33
        img = jnp.zeros((s, s)).at[12:21, 12:21].set(1.0)
        got = kernels.rotate(img, 0.7)
        assert abs(float(jnp.sum(got)) - float(jnp.sum(img))) < 1.0


# --------------------------------------------------------------- tfunc --
class TestTFunctionals:
    @settings(max_examples=15, deadline=None)
    @given(
        name=st.sampled_from(T_FUNCTIONALS),
        h=st.integers(min_value=2, max_value=40),
        w=st.integers(min_value=1, max_value=40),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref(self, name, h, w, seed):
        img = rand((h, w), seed)
        np.testing.assert_allclose(
            kernels.tfunctional(img, name),
            ref.tfunctional(img, name),
            rtol=1e-4,
            atol=1e-4,
        )

    @pytest.mark.parametrize("name", T_FUNCTIONALS)
    def test_blocked_grid_path(self, name):
        img = rand((128, 128))
        np.testing.assert_allclose(
            kernels.tfunctional(img, name),
            ref.tfunctional(img, name),
            rtol=1e-5,
            atol=1e-4,
        )

    def test_radon_is_column_sum(self):
        img = rand((16, 16))
        np.testing.assert_allclose(
            kernels.tfunctional(img, "radon"), jnp.sum(img, axis=0), rtol=1e-5
        )

    def test_unknown_name_raises(self):
        with pytest.raises(ValueError):
            kernels.tfunctional(rand((4, 4)), "nope")


# ------------------------------------------------------------ sinogram --
class TestSinogram:
    @settings(max_examples=10, deadline=None)
    @given(
        name=st.sampled_from(T_FUNCTIONALS),
        s=st.integers(min_value=4, max_value=32),
        a=st.integers(min_value=1, max_value=12),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref(self, name, s, a, seed):
        img = rand((s, s), seed)
        thetas = jnp.linspace(0.0, np.pi, a, endpoint=False)
        got = kernels.sinogram(img, thetas, name)
        want = ref.sinogram(img, thetas, name)
        assert got.shape == (a, s)
        # f32 accumulation noise: t2 weights grow as (s/2)^2, so scale atol.
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3 * s)

    def test_row_zero_is_unrotated_tfunc(self):
        img = rand((24, 24))
        got = kernels.sinogram(img, jnp.zeros((1,)), "radon")
        np.testing.assert_allclose(got[0], jnp.sum(img, axis=0), rtol=1e-4, atol=1e-4)

    def test_fused_equals_staged(self):
        # sinogram == tfunc(rotate(img, theta)) row by row.
        img = rand((16, 16))
        thetas = jnp.array([0.1, 1.2, 2.9], jnp.float32)
        fused = kernels.sinogram(img, thetas, "t1")
        for i, th in enumerate(thetas):
            staged = kernels.tfunctional(kernels.rotate(img, th), "t1")
            np.testing.assert_allclose(fused[i], staged, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------- sinogram_all --
class TestSinogramAll:
    @settings(max_examples=8, deadline=None)
    @given(
        s=st.integers(min_value=4, max_value=24),
        a=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_stack(self, s, a, seed):
        img = rand((s, s), seed)
        thetas = jnp.linspace(0.0, np.pi, a, endpoint=False)
        got = kernels.sinogram_all(img, thetas)
        want = ref.sinogram_all(img, thetas)
        assert got.shape == (len(T_FUNCTIONALS), a, s)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3 * s)

    def test_planes_match_per_functional_kernels(self):
        img = rand((20, 20), 5)
        thetas = jnp.array([0.3, 1.7], jnp.float32)
        fused = kernels.sinogram_all(img, thetas)
        for ti, name in enumerate(T_FUNCTIONALS):
            single = kernels.sinogram(img, thetas, name)
            np.testing.assert_allclose(fused[ti], single, rtol=1e-4, atol=1e-3)
