//! Allocator-policy and batching benchmarks.
//!
//! Section 1: **cached vs uncached** device-memory allocation on warm,
//! repeated same-size alloc/free traffic — the §6.2 rationale for
//! pre-allocating launch buffers (raw `cuMemAlloc`/`cuMemFree`
//! round-trips dominate small-kernel launches) made measurable. The
//! cached policy serves warm requests from power-of-two bins and should
//! beat the uncached policy by >= 5x on the larger sizes, where the
//! uncached path pays the host allocator's mmap/zero-fill round trip.
//!
//! Section 2: **batch vs loop** through the automated trace-transform
//! path on the emulator — `features_batch(N images)` does one
//! `batched_sinogram` launch with one angle-table upload, against N
//! sequential `features` calls. Reports wall time and `MemStats`
//! transfer counts.
//!
//! Run: `cargo bench --bench alloc_throughput`
//! (env: AT_ITERS, AT_WARMUP, AT_ROUNDS, AT_SIZE, AT_BATCH, AT_ANGLES).

use hlgpu::bench_support::{fmt_speedup, fmt_summary, measure, Settings, Table};
use hlgpu::driver::{MemoryPool, PoolPolicy, DEFAULT_CAPACITY};
use hlgpu::tracetransform::image::random_phantom;
use hlgpu::tracetransform::{orientations, DeviceChoice, GpuAuto, Image, TraceImpl};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Section 1: warm repeated same-size alloc/free under both policies.
fn alloc_policy_section(settings: Settings) {
    let rounds = env_usize("AT_ROUNDS", 512);
    let sizes: [usize; 4] = [4 << 10, 64 << 10, 256 << 10, 1 << 20];

    let mut table = Table::new(&["size", "uncached", "cached", "hit rate", "speedup"]);
    for &sz in &sizes {
        let uncached = MemoryPool::with_policy(DEFAULT_CAPACITY, PoolPolicy::Uncached);
        let u = measure(settings, || {
            for _ in 0..rounds {
                let p = uncached.alloc(sz).unwrap();
                uncached.free(p).unwrap();
            }
        });

        let cached = MemoryPool::with_policy(DEFAULT_CAPACITY, PoolPolicy::Cached);
        // one cold round parks a block in the bin; everything after is warm
        let warm = cached.alloc(sz).unwrap();
        cached.free(warm).unwrap();
        let c = measure(settings, || {
            for _ in 0..rounds {
                let p = cached.alloc(sz).unwrap();
                cached.free(p).unwrap();
            }
        });

        let st = cached.stats();
        table.row(&[
            format!("{} KiB", sz >> 10),
            fmt_summary(&u),
            fmt_summary(&c),
            format!("{:.1}%", st.pool_hit_rate() * 100.0),
            fmt_speedup(u.mean, c.mean),
        ]);
    }

    println!(
        "\nAllocation policy — warm same-size alloc/free x{rounds} per iteration \
         (the HLGPU_POOL=cached|none A/B)"
    );
    println!("{}", table.render());
    println!("target: cached >= 5x uncached on warm repeated same-size allocs");
}

/// Section 2: batched vs sequential trace features on the emulator.
fn batch_section(settings: Settings) {
    let size = env_usize("AT_SIZE", 32);
    let nimg = env_usize("AT_BATCH", 8);
    let thetas = orientations(env_usize("AT_ANGLES", 16));
    let imgs: Vec<Image> = (0..nimg).map(|i| random_phantom(size, i as u64)).collect();

    let mut auto = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
    // warm both specializations
    auto.features(&imgs[0], &thetas).unwrap();
    auto.features_batch(&imgs, &thetas).unwrap();

    // transfer counts, one warm pass each
    let mem = auto.launcher().context().memory_arc().unwrap();
    mem.reset_stats();
    for img in &imgs {
        auto.features(img, &thetas).unwrap();
    }
    let seq_stats = mem.stats();
    mem.reset_stats();
    auto.features_batch(&imgs, &thetas).unwrap();
    let bat_stats = mem.stats();

    // wall time
    let seq = measure(settings, || {
        for img in &imgs {
            auto.features(img, &thetas).unwrap();
        }
    });
    let bat = measure(settings, || {
        auto.features_batch(&imgs, &thetas).unwrap();
    });

    let mut table = Table::new(&["path", "time", "h2d", "d2h", "h2d bytes"]);
    table.row(&[
        format!("{nimg} x features"),
        fmt_summary(&seq),
        seq_stats.h2d_count.to_string(),
        seq_stats.d2h_count.to_string(),
        seq_stats.h2d_bytes.to_string(),
    ]);
    table.row(&[
        format!("features_batch({nimg})"),
        fmt_summary(&bat),
        bat_stats.h2d_count.to_string(),
        bat_stats.d2h_count.to_string(),
        bat_stats.h2d_bytes.to_string(),
    ]);

    println!(
        "\nBatched trace pipeline — {nimg} images of {size}x{size}, {} angles (emulator)",
        thetas.len()
    );
    println!("{}", table.render());
    println!(
        "batch speedup: {} wall, {}x fewer H2D transfers",
        fmt_speedup(seq.mean, bat.mean),
        if bat_stats.h2d_count > 0 { seq_stats.h2d_count / bat_stats.h2d_count } else { 0 }
    );
    println!(
        "pool: hit rate {:.1}%, {} bytes cached, {} trims",
        mem.stats().pool_hit_rate() * 100.0,
        mem.stats().cached_bytes,
        mem.stats().trim_count
    );
}

fn main() {
    let settings = Settings {
        warmup_iters: env_usize("AT_WARMUP", 3),
        sample_iters: env_usize("AT_ITERS", 15),
    };
    alloc_policy_section(settings);
    batch_section(settings);
}
