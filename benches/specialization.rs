//! §6.2 ablation: the specialization (method) cache.
//!
//! "Each invocation ... [is] only executed once for every set of argument
//! types. The resulting code is saved in a method cache, and reused in
//! each subsequent invocation."
//!
//! Measures cold (first-call) vs warm (cached) launch cost per signature,
//! sweeps the number of distinct signatures, and reports cache hit rates.
//!
//! Run: `cargo bench --bench specialization` (env: SP_ITERS).

use hlgpu::bench_support::{fmt_time, measure, measure_once, Settings, Table};
use hlgpu::coordinator::{arg, Launcher};
use hlgpu::driver::LaunchConfig;
use hlgpu::tensor::Tensor;
use hlgpu::util::Prng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let settings = Settings {
        warmup_iters: 2,
        sample_iters: env_usize("SP_ITERS", 15),
    };
    // every vadd length that was AOT-lowered = one signature
    let lengths = [12usize, 1024, 4096, 65536];

    let mut launcher = Launcher::with_default_context().unwrap();
    let mut rng = Prng::new(11);

    let mut table = Table::new(&["signature", "cold (first call)", "warm (cached)", "speedup"]);
    for &n in &lengths {
        let a = Tensor::from_f32(&rng.f32_vec(n, 0.0, 1.0), &[n]);
        let b = Tensor::from_f32(&rng.f32_vec(n, 0.0, 1.0), &[n]);
        let mut c = Tensor::zeros_f32(&[n]);
        let cfg = LaunchConfig::new(n as u32, 1u32);

        let (cold, _) = measure_once(|| {
            launcher
                .launch("vadd", cfg, &mut [arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)])
                .unwrap();
        });
        let warm = measure(settings, || {
            launcher
                .launch("vadd", cfg, &mut [arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)])
                .unwrap();
        });
        table.row(&[
            format!("f32[{n}]"),
            fmt_time(cold),
            fmt_time(warm.mean),
            format!("{:.0}x", cold / warm.mean),
        ]);
    }

    let stats = launcher.cache_stats();
    let m = launcher.metrics();
    println!("Specialization cache — cold vs warm per signature (§6.2 method cache)");
    println!("{}", table.render());
    println!(
        "cache: {} entries, {} hits / {} misses; total specialize time {} ms over {} cold calls",
        stats.entries,
        stats.hits,
        stats.misses,
        m.specialize_ns / 1_000_000,
        m.cold_specializations
    );
    assert_eq!(stats.entries, lengths.len());
    assert_eq!(m.cold_specializations as usize, lengths.len());
    println!("expected: cold pays module compile once per signature; warm launches are");
    println!("orders of magnitude cheaper and allocation-free (the paper's zero-overhead claim).");
}
