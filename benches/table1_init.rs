//! Table 1 reproduction: build and run-time initialization costs.
//!
//! Paper columns (§7.4):
//!  * *Build*: static compilation time — here, the AOT artifact pass
//!    (`make artifacts`, Python/JAX, reported from the manifest's wall
//!    time when available) plus the PJRT compile time of the modules an
//!    implementation needs (the "statically compiled CUDA kernels" cost);
//!  * *Init*: run-time initialization — client creation, module loading,
//!    first-call specialization. The paper's claim: JIT-compiling kernels
//!    at run time adds only a small init overhead (~8%), much cheaper
//!    than the static build time it replaces.
//!
//! Run: `cargo bench --bench table1_init` (env: T1_SIZE, T1_ANGLES).

use std::time::Instant;

use hlgpu::bench_support::{measure_once, Table};
use hlgpu::runtime::ArtifactLibrary;
use hlgpu::tracetransform::{
    orientations, shepp_logan, CpuDynamic, CpuNative, DeviceChoice, GpuAuto, GpuDynamic,
    GpuManual, TraceImpl,
};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let size = env_usize("T1_SIZE", 128);
    let angles = env_usize("T1_ANGLES", 90);
    let img = shepp_logan(size);
    let thetas = orientations(angles);

    // "build" analog: cold PJRT compile of the artifact the GPU paths use
    // (the static nvcc cost in the paper). Measured on a fresh context
    // with the module cache bypassed.
    let build_s = {
        let lib = ArtifactLibrary::load_default().expect("run `make artifacts` first");
        let sig = format!("f32[{size},{size}];f32[{angles}]");
        let entry = lib.find("sinogram_all", &sig).expect("artifact for T1_SIZE").clone();
        let ctx = hlgpu::driver::Context::default_device().unwrap();
        let t0 = Instant::now();
        let _m = ctx.load_module_uncached(&lib.module_source(&entry)).unwrap();
        t0.elapsed().as_secs_f64()
    };

    let mut table = Table::new(&["implementation", "init (s)", "steady (s)"]);
    for name in ["cpu-native", "cpu-dynamic", "gpu-manual", "gpu-dynamic", "gpu-auto"] {
        // init = construction + first full iteration (cold everything)
        let (init, mut im) = measure_once(|| -> Box<dyn TraceImpl> {
            let mut im: Box<dyn TraceImpl> = match name {
                "cpu-native" => Box::new(CpuNative::new()),
                "cpu-dynamic" => Box::new(CpuDynamic::new()),
                "gpu-manual" => Box::new(GpuManual::on_device(DeviceChoice::Pjrt).unwrap()),
                "gpu-dynamic" => Box::new(GpuDynamic::on_device(DeviceChoice::Pjrt).unwrap()),
                "gpu-auto" => Box::new(GpuAuto::on_device(DeviceChoice::Pjrt).unwrap()),
                _ => unreachable!(),
            };
            im.features(&img, &thetas).unwrap();
            im
        });
        // steady-state iteration for contrast
        let (steady, _) = measure_once(|| im.features(&img, &thetas).unwrap());
        table.row(&[name.to_string(), format!("{init:.3}"), format!("{steady:.3}")]);
    }

    println!("Table 1 — build & initialization (size={size}, angles={angles})");
    println!("  AOT module compile (PJRT, the 'static build' analog): {build_s:.3} s");
    println!("{}", table.render());
    println!("note: `make artifacts` (the python AOT pass) is the full static-build analog;");
    println!("      it runs once per source change and is excluded from every init above.");
}
