//! Figure 3 reproduction: steady-state execution time of the five
//! trace-transform implementations across image sizes.
//!
//! Paper shapes this bench must reproduce (§7.3):
//!  * CPU implementations scale ~linearly in pixel count; GPU
//!    implementations scale *superlinearly at small sizes* because of the
//!    constant configure+launch overhead;
//!  * the dynamic-host CPU implementation trails the native CPU one by a
//!    factor that grows with size (boxing + bounds checks);
//!  * dynamic-host + manual GPU trails native + manual GPU by a margin
//!    that shrinks as the image grows (13% small → 2% large in the paper);
//!  * the fully automated implementation matches manual driver calls
//!    (±few %): automation adds no steady-state overhead.
//!
//! Run: `cargo bench --bench fig3_tracetransform` (env: FIG3_SIZES,
//! FIG3_ITERS, FIG3_ANGLES, FIG3_DEVICE=pjrt|emu).

use hlgpu::bench_support::{fmt_time, measure, Settings, Table};
use hlgpu::tracetransform::{
    orientations, shepp_logan, CpuDynamic, CpuNative, DeviceChoice, GpuAuto, GpuDynamic,
    GpuManual, TraceImpl,
};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_sizes() -> Vec<usize> {
    std::env::var("FIG3_SIZES")
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|_| vec![16, 32, 64, 128, 256])
}

fn main() {
    let sizes = env_sizes();
    let angles = env_usize("FIG3_ANGLES", 90);
    let settings = Settings {
        warmup_iters: env_usize("FIG3_WARMUP", 2),
        sample_iters: env_usize("FIG3_ITERS", 7),
    };
    let device = match std::env::var("FIG3_DEVICE").as_deref() {
        Ok("emu") | Ok("emulator") => DeviceChoice::Emulator,
        _ => DeviceChoice::Pjrt,
    };

    println!("fig3: device={device:?} angles={angles} sizes={sizes:?} iters={}", settings.sample_iters);
    let names = ["cpu-native", "cpu-dynamic", "gpu-manual", "gpu-dynamic", "gpu-auto"];
    let mut table = Table::new(&["size", names[0], names[1], names[2], names[3], names[4]]);
    let mut means: Vec<Vec<f64>> = Vec::new();
    let mut max_unc: f64 = 0.0;

    for &size in &sizes {
        let img = shepp_logan(size);
        let thetas = orientations(angles);
        let mut row = vec![size.to_string()];
        let mut mean_row = Vec::new();
        for name in names {
            let mut im: Box<dyn TraceImpl> = match name {
                "cpu-native" => Box::new(CpuNative::new()),
                "cpu-dynamic" => Box::new(CpuDynamic::new()),
                "gpu-manual" => Box::new(GpuManual::on_device(device).unwrap()),
                "gpu-dynamic" => Box::new(GpuDynamic::on_device(device).unwrap()),
                "gpu-auto" => Box::new(GpuAuto::on_device(device).unwrap()),
                _ => unreachable!(),
            };
            let summary = measure(settings, || im.features(&img, &thetas).unwrap());
            max_unc = max_unc.max(summary.rel_uncertainty_pct());
            mean_row.push(summary.mean);
            row.push(fmt_time(summary.mean));
        }
        means.push(mean_row);
        table.row(&row);
    }

    println!("\nFigure 3 — steady-state execution time per iteration");
    println!("(relative uncertainty ≤ {max_unc:.2}%)");
    println!("{}", table.render());

    // On the emulator device, report what the parallel block scheduler
    // did for the automated path at the largest size.
    if device == DeviceChoice::Emulator {
        let size = *sizes.last().unwrap();
        let img = shepp_logan(size);
        let thetas = orientations(angles);
        let mut auto = GpuAuto::on_device(device).unwrap();
        auto.features(&img, &thetas).unwrap();
        let m = auto.launcher().metrics();
        println!(
            "VTX scheduler (gpu-auto, size {size}): {} blocks over {} workers, {:.0}% worker utilization",
            m.blocks_executed,
            m.peak_workers,
            m.worker_utilization() * 100.0
        );
    }

    // shape assertions (soft: printed, not panicking, so partial artifact
    // sets still produce the table)
    let last = means.len() - 1;
    let dyn_vs_native_small = means[0][1] / means[0][0];
    let dyn_vs_native_large = means[last][1] / means[last][0];
    println!("shape checks:");
    println!(
        "  cpu-dynamic / cpu-native: {dyn_vs_native_small:.2}x (small) -> {dyn_vs_native_large:.2}x (large)  [paper: gap grows with size]"
    );
    let gd_vs_gm_small = means[0][3] / means[0][2];
    let gd_vs_gm_large = means[last][3] / means[last][2];
    println!(
        "  gpu-dynamic / gpu-manual: {gd_vs_gm_small:.2}x (small) -> {gd_vs_gm_large:.2}x (large)  [paper: 1.13x -> 1.02x]"
    );
    let auto_vs_manual = means[last][4] / means[last][2];
    println!(
        "  gpu-auto / gpu-manual (largest size): {auto_vs_manual:.3}x  [paper: ~1.015x]"
    );
    // GPU's constant overhead: time ratio across the size sweep is far
    // below the pixel-count ratio at the small end (superlinear scaling)
    if means.len() >= 2 {
        let px_ratio = (sizes[1] * sizes[1]) as f64 / (sizes[0] * sizes[0]) as f64;
        let gpu_ratio = means[1][4] / means[0][4];
        let cpu_ratio = means[1][0] / means[0][0];
        println!(
            "  {}->{}: pixel x{px_ratio:.1}, cpu-native x{cpu_ratio:.2}, gpu-auto x{gpu_ratio:.2}  [paper: gpu sublinear at small sizes = constant overhead]",
            sizes[0], sizes[1]
        );
    }
}
