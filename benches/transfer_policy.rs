//! §6.3 ablation: `CuIn`/`CuOut`/`CuInOut` transfer minimization vs naive
//! upload-and-download-everything.
//!
//! The paper: "By optionally wrapping arguments with CuIn, CuOut or
//! CuInOut, the developer can force the compiler to generate only the
//! absolutely necessary memory transfers." This bench counts transfers
//! and bytes, and times both policies on the trace pipeline's hot launch.
//!
//! Run: `cargo bench --bench transfer_policy` (env: TP_SIZE, TP_ITERS).

use hlgpu::bench_support::{fmt_summary, measure, Settings, Table};
use hlgpu::coordinator::{arg, Launcher, TransferPolicy};
use hlgpu::driver::LaunchConfig;
use hlgpu::tensor::Tensor;
use hlgpu::tracetransform::{orientations, shepp_logan};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let size = env_usize("TP_SIZE", 128);
    let angles = 90;
    let settings = Settings {
        warmup_iters: env_usize("TP_WARMUP", 2),
        sample_iters: env_usize("TP_ITERS", 10),
    };

    let img = shepp_logan(size).to_tensor();
    let thetas = orientations(angles);
    let ang = Tensor::from_f32(&thetas, &[angles]);
    let mut sinos = Tensor::zeros_f32(&[4, angles, size]);
    let cfg = LaunchConfig::new(angles as u32, size as u32);

    let mut table = Table::new(&["policy", "time/iter", "H2D count", "D2H count", "bytes moved/iter"]);
    for (label, policy) in [
        ("minimal (CuIn/CuOut)", TransferPolicy::Minimal),
        ("naive (all InOut)", TransferPolicy::Naive),
    ] {
        let mut launcher = Launcher::with_default_context().unwrap();
        launcher.set_policy(policy);
        // warm the cache, then reset counters so we count steady state only
        launcher
            .launch(
                "sinogram_all",
                cfg,
                &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
            )
            .unwrap();
        launcher.context().memory().unwrap().reset_stats();
        let iters = settings.sample_iters + settings.warmup_iters;
        let summary = measure(settings, || {
            launcher
                .launch(
                    "sinogram_all",
                    cfg,
                    &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
                )
                .unwrap();
        });
        let stats = launcher.context().mem_stats().unwrap();
        let per_iter_bytes = (stats.h2d_bytes + stats.d2h_bytes) / iters as u64;
        table.row(&[
            label.to_string(),
            fmt_summary(&summary),
            format!("{}", stats.h2d_count / iters as u64),
            format!("{}", stats.d2h_count / iters as u64),
            format!("{} KiB", per_iter_bytes / 1024),
        ]);
    }

    println!("Transfer policy ablation — sinogram_all {size}x{size}, {angles} orientations");
    println!("{}", table.render());
    println!("expected: minimal policy moves strictly fewer transfers (2 H2D + 1 D2H vs 3 + 3)");
    println!("and fewer bytes; the gap grows with the image size (§6.3).");
}
