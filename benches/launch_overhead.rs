//! The §6 claim: the automation layer adds **no run-time overhead** over
//! manual driver calls once the specialization cache is warm.
//!
//! Measures, for `vadd` and `sinogram_all`:
//!  * manual path — hand-written alloc/upload/launch/download against the
//!    driver API (the Listing 2 flow, buffers reused);
//!  * auto path — `launcher.launch` with `CuIn`/`CuOut` wrappers (the
//!    Listing 3 flow), warm cache;
//!  * auto cold — first-call cost, for contrast (specialize + compile).
//!
//! Run: `cargo bench --bench launch_overhead` (env: LO_ITERS, LO_N, LO_SIZE).

use hlgpu::bench_support::{fmt_summary, measure, Settings, Table};
use hlgpu::coordinator::{arg, Launcher};
use hlgpu::driver::{Context, KernelArg, LaunchConfig};
use hlgpu::runtime::ArtifactLibrary;
use hlgpu::tensor::Tensor;
use hlgpu::tracetransform::{orientations, shepp_logan};
use hlgpu::util::Prng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let settings = Settings {
        warmup_iters: env_usize("LO_WARMUP", 3),
        sample_iters: env_usize("LO_ITERS", 15),
    };
    let n = env_usize("LO_N", 4096);
    let size = env_usize("LO_SIZE", 64);
    let angles = 90;

    let lib = ArtifactLibrary::load_default().expect("run `make artifacts` first");
    let ctx = Context::default_device().unwrap();

    let mut table = Table::new(&["workload", "manual", "auto (warm)", "overhead"]);

    // ---------------- vadd ------------------------------------------------
    {
        let mut rng = Prng::new(3);
        let a = Tensor::from_f32(&rng.f32_vec(n, 0.0, 1.0), &[n]);
        let b = Tensor::from_f32(&rng.f32_vec(n, 0.0, 1.0), &[n]);
        let mut c = Tensor::zeros_f32(&[n]);

        // manual: persistent buffers, hand-written transfers
        let entry = lib.find("vadd", &format!("f32[{n}];f32[{n}]")).unwrap().clone();
        let module = ctx.load_module(&lib.module_source(&entry)).unwrap();
        let f = module.function("main").unwrap();
        let ga = ctx.alloc(n * 4).unwrap();
        let gb = ctx.alloc(n * 4).unwrap();
        let gc = ctx.alloc(n * 4).unwrap();
        let cfg = LaunchConfig::new(n as u32, 1u32);
        let manual = measure(settings, || {
            ctx.upload(ga, a.bytes()).unwrap();
            ctx.upload(gb, b.bytes()).unwrap();
            f.launch(
                &cfg,
                &[KernelArg::Ptr(ga), KernelArg::Ptr(gb), KernelArg::Ptr(gc)],
                ctx.memory().unwrap(),
            )
            .unwrap();
            ctx.download(gc, c.bytes_mut()).unwrap();
        });

        // auto: warm cache
        let mut launcher = Launcher::with_default_context().unwrap();
        let auto = measure(settings, || {
            launcher
                .launch(
                    "vadd",
                    cfg,
                    &mut [arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)],
                )
                .unwrap();
        });
        table.row(&[
            format!("vadd n={n}"),
            fmt_summary(&manual),
            fmt_summary(&auto),
            format!("{:+.1}%", (auto.mean / manual.mean - 1.0) * 100.0),
        ]);
    }

    // ---------------- sinogram_all ---------------------------------------
    {
        let img = shepp_logan(size).to_tensor();
        let thetas = orientations(angles);
        let ang = Tensor::from_f32(&thetas, &[angles]);
        let mut sinos = Tensor::zeros_f32(&[4, angles, size]);

        let entry = lib
            .find("sinogram_all", &format!("f32[{size},{size}];f32[{angles}]"))
            .expect("artifact for LO_SIZE")
            .clone();
        let module = ctx.load_module(&lib.module_source(&entry)).unwrap();
        let f = module.function("main").unwrap();
        let ga = ctx.alloc(img.byte_len()).unwrap();
        let gb = ctx.alloc(ang.byte_len()).unwrap();
        let gc = ctx.alloc(sinos.byte_len()).unwrap();
        let cfg = LaunchConfig::new(angles as u32, size as u32);
        let manual = measure(settings, || {
            ctx.upload(ga, img.bytes()).unwrap();
            ctx.upload(gb, ang.bytes()).unwrap();
            f.launch(
                &cfg,
                &[KernelArg::Ptr(ga), KernelArg::Ptr(gb), KernelArg::Ptr(gc)],
                ctx.memory().unwrap(),
            )
            .unwrap();
            ctx.download(gc, sinos.bytes_mut()).unwrap();
        });

        let mut launcher = Launcher::with_default_context().unwrap();
        // cold first call, for the record
        let (cold, _) = hlgpu::bench_support::measure_once(|| {
            launcher
                .launch(
                    "sinogram_all",
                    cfg,
                    &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
                )
                .unwrap();
        });
        let auto = measure(settings, || {
            launcher
                .launch(
                    "sinogram_all",
                    cfg,
                    &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
                )
                .unwrap();
        });
        table.row(&[
            format!("sinogram_all {size}x{size}"),
            fmt_summary(&manual),
            fmt_summary(&auto),
            format!("{:+.1}%", (auto.mean / manual.mean - 1.0) * 100.0),
        ]);
        println!(
            "cold first call (specialize + compile): {:.1} ms  vs warm {:.3} ms",
            cold * 1e3,
            auto.mean * 1e3
        );
    }

    println!("\nLaunch overhead — automation vs manual driver calls (§6 'no run-time overhead')");
    println!("{}", table.render());
    println!("paper expectation: overhead within measurement noise (±few %) once warm.");
}
