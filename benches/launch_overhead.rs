//! Launch-path benchmarks.
//!
//! Part 1 (always runs, VTX emulator): the **parallel block scheduler**
//! vs the sequential reference schedule on multi-block grids — the
//! emulator must exploit block independence ("runs as fast as the
//! hardware allows"), not simulate it at 1/N speed. Reports wall-clock
//! speedup, blocks executed and worker utilization; with >= 4 workers on
//! adequate hardware the fused sinogram workload shows >= 2x.
//!
//! Part 2 (always runs, VTX emulator): the **execution tiers** — the
//! warp-vectorized interpreter (basic-block lowering + superinstruction
//! fusion, `HLGPU_EXEC=vector`) and the closure-JIT compiled tier
//! (`HLGPU_EXEC=compiled`, hot blocks compiled into straight-line
//! closure chains) vs the scalar reference tier, on the straight-line
//! sinogram workload. Reports instructions/s, fused and compiled
//! instruction shares, vector lane utilization, tier-ups and deopts;
//! targets: vector >= 3x instructions/s over scalar, compiled >=
//! vector with a compiled share > 0.9 at steady state.
//!
//! Part 3 (always runs, VTX emulator): **launch API v2** — (a) a warm
//! bound `KernelHandle` with all-device-resident arguments vs the v1
//! host-round-trip `cuda!` path (bytes moved per launch must drop to
//! zero), (b) the v1 per-image sync loop vs the v2 two-stream
//! double-buffered batched pipeline in `gpu_auto` (bytes per image and
//! wall clock both drop — the device-resident angle table uploads once),
//! and (c) the host vs device P/F reduction stage (`HLGPU_REDUCE`):
//! bytes downloaded per image collapse from `|T|·a·s` floats to the
//! `FEATURE_COUNT`-float block, (d) the single-device pipeline vs
//! the same batch **sharded across a 2-/4-member `DeviceSet`**
//! (`HLGPU_SHARD=auto`): images/s, scaling efficiency, per-member
//! placement and the shard imbalance ratio (results stay bitwise
//! identical to the 1-device baseline), and (e) the **execution tiers
//! on the warm `features_batch` path**: scalar vs vector vs compiled,
//! with instructions/s, compiled share, tier-up count and the number
//! of batches until the JIT's compile time pays for itself.
//!
//! Part 4 (needs `make artifacts`): the §6 claim that the automation
//! layer adds **no run-time overhead** over manual driver calls once the
//! specialization cache is warm, on the PJRT backend.
//!
//! Run: `cargo bench --bench launch_overhead`
//! (env: LO_ITERS, LO_N, LO_SIZE, LO_ANGLES, LO_BATCH, HLGPU_WORKERS,
//! HLGPU_EXEC, HLGPU_TIER_UP, HLGPU_ARENAS).

use hlgpu::bench_support::{fmt_speedup, fmt_summary, measure, Settings, Table};
use hlgpu::coordinator::{arg, DeviceArray, Launcher};
use hlgpu::driver::{Context, KernelArg, LaunchConfig};
use hlgpu::emulator::{
    default_workers, set_default_exec, set_default_tier_up, set_default_workers, ExecTier,
};
use hlgpu::runtime::ArtifactLibrary;
use hlgpu::tensor::{Dtype, Tensor};
use hlgpu::tracetransform::{orientations, random_phantom, shepp_logan};
use hlgpu::util::Prng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Emulator section: sequential vs parallel block schedule, end to end
/// through the `cuda!` automation layer (warm cache, same transfer plan —
/// only the schedule differs).
fn emulator_scheduler_section(settings: Settings) {
    let size = env_usize("LO_SIZE", 96);
    let angles = env_usize("LO_ANGLES", 64);
    let img = shepp_logan(size).to_tensor();
    let thetas = orientations(angles);
    let ang = Tensor::from_f32(&thetas, &[angles]);
    let mut sinos = Tensor::zeros_f32(&[4, angles, size]);
    let cfg = LaunchConfig::new(angles as u32, size as u32);

    let mut launcher = Launcher::emulator().unwrap();
    hlgpu::tracetransform::impls::register_trace_providers(launcher.registry_mut());

    let machine_workers = {
        set_default_workers(None);
        default_workers()
    };
    let widths: Vec<usize> = {
        let mut w = vec![1usize, 2, 4, machine_workers];
        w.sort_unstable();
        w.dedup();
        w.retain(|&x| x >= 1);
        w
    };

    let mut table = Table::new(&["schedule", "time/iter", "blocks", "utilization", "speedup"]);
    let mut seq_mean = 0.0f64;
    let mut best_par = f64::INFINITY;
    let mut best_width = 1usize;
    for &w in &widths {
        set_default_workers(Some(w));
        // warm the specialization cache under this schedule
        launcher
            .launch(
                "sinogram_all",
                cfg,
                &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
            )
            .unwrap();
        let before = launcher.metrics();
        let summary = measure(settings, || {
            launcher
                .launch(
                    "sinogram_all",
                    cfg,
                    &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
                )
                .unwrap();
        });
        let after = launcher.metrics();
        let blocks = after.blocks_executed - before.blocks_executed;
        let busy = (after.worker_busy_ns - before.worker_busy_ns) as f64;
        let wall = (after.exec_wall_ns - before.exec_wall_ns) as f64;
        let util = if wall > 0.0 { busy / (wall * w as f64) } else { 0.0 };
        if w == 1 {
            seq_mean = summary.mean;
        } else if summary.mean < best_par {
            best_par = summary.mean;
            best_width = w;
        }
        table.row(&[
            if w == 1 { "sequential (1 worker)".into() } else { format!("parallel ({w} workers)") },
            fmt_summary(&summary),
            blocks.to_string(),
            format!("{:.0}%", util * 100.0),
            if w == 1 { "1.00x".into() } else { fmt_speedup(seq_mean, summary.mean) },
        ]);
    }
    set_default_workers(None);

    println!(
        "\nVTX block scheduler — sinogram_all {size}x{size}, {angles} blocks of {size} threads"
    );
    println!("(machine parallelism: {machine_workers}; HLGPU_WORKERS overrides the default)");
    println!("{}", table.render());
    if best_par.is_finite() && seq_mean > 0.0 {
        println!(
            "best parallel schedule: {} workers, {} over sequential (target: >= 2x with >= 4 workers)",
            best_width,
            fmt_speedup(seq_mean, best_par)
        );
    }
}

/// Execution-tier section: scalar reference interpreter vs the
/// warp-vectorized tier vs the closure-JIT compiled tier (forced
/// compilation, `HLGPU_TIER_UP=0` semantics — the steady-state shape)
/// on the straight-line sinogram workload, A/B'd through
/// `set_default_exec` (mirroring the scheduler section's
/// `set_default_workers` precedent). All tiers produce bitwise-equal
/// results; only dispatch amortization differs.
fn exec_tier_section(settings: Settings) {
    let size = env_usize("LO_SIZE", 96);
    let angles = env_usize("LO_ANGLES", 64);
    let img = shepp_logan(size).to_tensor();
    let thetas = orientations(angles);
    let ang = Tensor::from_f32(&thetas, &[angles]);
    let mut sinos = Tensor::zeros_f32(&[4, angles, size]);
    let cfg = LaunchConfig::new(angles as u32, size as u32);

    let mut launcher = Launcher::emulator().unwrap();
    hlgpu::tracetransform::impls::register_trace_providers(launcher.registry_mut());

    let mut table = Table::new(&[
        "tier",
        "time/iter",
        "Minstr/s",
        "fused share",
        "compiled share",
        "lane util",
        "speedup",
    ]);
    let iters = (settings.warmup_iters + settings.sample_iters) as f64;
    let mut scalar_mean = 0.0f64;
    let mut vector_mean = f64::INFINITY;
    let mut compiled_mean = f64::INFINITY;
    let mut compiled_share = 0.0f64;
    let mut tier_ups = 0u64;
    let mut deopts = 0u64;
    for tier in [ExecTier::Scalar, ExecTier::Vector, ExecTier::Compiled] {
        set_default_exec(Some(tier));
        // steady-state shape for the compiled tier: every block
        // compiles on first entry during the warm launch below
        set_default_tier_up(if tier == ExecTier::Compiled { Some(0) } else { None });
        // warm the specialization cache under this tier
        launcher
            .launch(
                "sinogram_all",
                cfg,
                &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
            )
            .unwrap();
        let before = launcher.metrics();
        let summary = measure(settings, || {
            launcher
                .launch(
                    "sinogram_all",
                    cfg,
                    &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
                )
                .unwrap();
        });
        let after = launcher.metrics();
        let instrs = (after.instrs_retired - before.instrs_retired) as f64 / iters;
        let mips = instrs / summary.mean / 1e6;
        let retired = after.instrs_retired - before.instrs_retired;
        let fused_share = if retired > 0 {
            (after.fused_instrs - before.fused_instrs) as f64 / retired as f64
        } else {
            0.0
        };
        let cshare = if retired > 0 {
            (after.compiled_instrs - before.compiled_instrs) as f64 / retired as f64
        } else {
            0.0
        };
        let lane_slots = after.vector_lane_slots - before.vector_lane_slots;
        let lane_util = if lane_slots > 0 {
            (after.vector_lane_ops - before.vector_lane_ops) as f64 / lane_slots as f64
        } else {
            0.0
        };
        let (name, speedup) = match tier {
            ExecTier::Scalar => {
                scalar_mean = summary.mean;
                ("scalar (reference)".to_string(), "1.00x".to_string())
            }
            ExecTier::Vector => {
                vector_mean = summary.mean;
                (
                    "vector (fused superinstructions)".to_string(),
                    fmt_speedup(scalar_mean, summary.mean),
                )
            }
            ExecTier::Compiled => {
                compiled_mean = summary.mean;
                compiled_share = cshare;
                tier_ups = after.tier_ups;
                deopts = after.deopts - before.deopts;
                (
                    "compiled (closure-JIT blocks)".to_string(),
                    fmt_speedup(scalar_mean, summary.mean),
                )
            }
        };
        table.row(&[
            name,
            fmt_summary(&summary),
            format!("{mips:.1}"),
            format!("{:.0}%", fused_share * 100.0),
            format!("{:.0}%", cshare * 100.0),
            format!("{:.0}%", lane_util * 100.0),
            speedup,
        ]);
    }
    set_default_exec(None);
    set_default_tier_up(None);

    println!(
        "\nVTX execution tiers — sinogram_all {size}x{size}, {angles} blocks of {size} threads"
    );
    println!("(HLGPU_EXEC=scalar|vector|compiled and HLGPU_TIER_UP=N override the defaults)");
    println!("{}", table.render());
    if scalar_mean > 0.0 && vector_mean.is_finite() {
        println!(
            "vector tier: {} instructions/s over scalar (target: >= 3x on straight-line kernels)",
            fmt_speedup(scalar_mean, vector_mean)
        );
    }
    if vector_mean.is_finite() && compiled_mean.is_finite() {
        println!(
            "compiled tier: {} over vector, compiled share {:.0}% (target: >= 1x with share > 90%), {} tier-ups, {} steady-state deopts",
            fmt_speedup(vector_mean, compiled_mean),
            compiled_share * 100.0,
            tier_ups,
            deopts,
        );
    }
}

/// Launch API v2 section A: a warm bound handle with device-resident
/// arguments vs the v1 host round-trip, same `sinogram_all` workload.
fn device_resident_section(settings: Settings) {
    let size = env_usize("LO_SIZE", 96);
    let angles = env_usize("LO_ANGLES", 64);
    let img = shepp_logan(size).to_tensor();
    let thetas = orientations(angles);
    let ang = Tensor::from_f32(&thetas, &[angles]);
    let mut sinos = Tensor::zeros_f32(&[4, angles, size]);
    let cfg = LaunchConfig::new(angles as u32, size as u32);
    let iters = (settings.warmup_iters + settings.sample_iters) as f64;

    let mut launcher = Launcher::emulator().unwrap();
    hlgpu::tracetransform::impls::register_trace_providers(launcher.registry_mut());
    let ctx = launcher.context().clone();

    let mut table = Table::new(&["path", "time/iter", "KiB moved/iter", "speedup"]);

    // v1: cuda!-style warm launch, every argument round-trips the host
    launcher
        .launch(
            "sinogram_all",
            cfg,
            &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
        )
        .unwrap();
    ctx.memory().unwrap().reset_stats();
    let v1 = measure(settings, || {
        launcher
            .launch(
                "sinogram_all",
                cfg,
                &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
            )
            .unwrap();
    });
    let st = ctx.mem_stats().unwrap();
    let v1_kib = (st.h2d_bytes + st.d2h_bytes) as f64 / iters / 1024.0;
    table.row(&[
        "v1 round-trip (cuda!)".into(),
        fmt_summary(&v1),
        format!("{v1_kib:.1}"),
        "1.00x".into(),
    ]);

    // v2: bound handle, all arguments device-resident
    let d_img = DeviceArray::from_tensor(&ctx, &img).unwrap();
    let d_ang = DeviceArray::from_tensor(&ctx, &ang).unwrap();
    let mut d_sinos = DeviceArray::alloc(&ctx, Dtype::F32, &[4, angles, size]).unwrap();
    let handle = launcher
        .bind(
            "sinogram_all",
            &[arg::cu_dev(&d_img), arg::cu_dev(&d_ang), arg::cu_dev_mut(&mut d_sinos)],
        )
        .unwrap();
    handle
        .launch(cfg, &mut [arg::cu_dev(&d_img), arg::cu_dev(&d_ang), arg::cu_dev_mut(&mut d_sinos)])
        .unwrap();
    ctx.memory().unwrap().reset_stats();
    let v2 = measure(settings, || {
        handle
            .launch(
                cfg,
                &mut [arg::cu_dev(&d_img), arg::cu_dev(&d_ang), arg::cu_dev_mut(&mut d_sinos)],
            )
            .unwrap();
    });
    let st = ctx.mem_stats().unwrap();
    let v2_kib = (st.h2d_bytes + st.d2h_bytes) as f64 / iters / 1024.0;
    table.row(&[
        "v2 device-resident handle".into(),
        fmt_summary(&v2),
        format!("{v2_kib:.1}"),
        fmt_speedup(v1.mean, v2.mean),
    ]);

    println!("\nLaunch API v2 — device-resident handle vs host round-trip ({size}x{size}, {angles} angles)");
    println!("{}", table.render());
    println!("v2 target: 0.0 KiB moved per warm launch (was {v1_kib:.1})");
}

/// Launch API v2 section B: the v1 per-image sync loop vs the v2
/// two-stream double-buffered batched pipeline (device-resident angles,
/// bound handles, uploads overlapping compute).
fn two_stream_pipeline_section(settings: Settings) {
    use hlgpu::tracetransform::{DeviceChoice, GpuAuto, TraceImpl};
    let size = env_usize("LO_SIZE", 96);
    let angles = env_usize("LO_ANGLES", 64);
    let batch = env_usize("LO_BATCH", 8);
    let thetas = orientations(angles);
    let imgs: Vec<_> = (0..batch).map(|i| random_phantom(size, 7 + i as u64)).collect();
    let iters = (settings.warmup_iters + settings.sample_iters) as f64;
    let per_img = |bytes: u64| bytes as f64 / iters / batch as f64 / 1024.0;

    let mut auto = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
    let mut table = Table::new(&["pipeline", "time/batch", "KiB h2d/image", "speedup"]);

    // v1: synchronous per-image loop (round-trip launches)
    auto.features(&imgs[0], &thetas).unwrap(); // warm the specialization
    auto.launcher().context().memory().unwrap().reset_stats();
    let sync = measure(settings, || {
        for img in &imgs {
            auto.features(img, &thetas).unwrap();
        }
    });
    let st = auto.launcher().context().mem_stats().unwrap();
    let sync_kib = per_img(st.h2d_bytes);
    table.row(&[
        "v1 sync per-image".into(),
        fmt_summary(&sync),
        format!("{sync_kib:.1}"),
        "1.00x".into(),
    ]);

    // v2: two-stream double-buffered batch, device-resident angles
    auto.features_batch(&imgs, &thetas).unwrap(); // warm pipes + handles
    auto.launcher().context().memory().unwrap().reset_stats();
    let piped = measure(settings, || {
        auto.features_batch(&imgs, &thetas).unwrap();
    });
    let st = auto.launcher().context().mem_stats().unwrap();
    let piped_kib = per_img(st.h2d_bytes);
    table.row(&[
        "v2 two-stream batched".into(),
        fmt_summary(&piped),
        format!("{piped_kib:.1}"),
        fmt_speedup(sync.mean, piped.mean),
    ]);

    println!(
        "\nLaunch API v2 — sync loop vs two-stream pipeline ({batch} images of {size}x{size}, {angles} angles)"
    );
    println!("{}", table.render());
    println!(
        "v2 moves {piped_kib:.1} KiB h2d per image vs v1's {sync_kib:.1} (angle table device-resident, uploads double-buffered)"
    );
}

/// Launch API v2 section C: the P/F reduction stage on the host (every
/// sinogram downloaded, `reduce_sinogram` on the CPU) vs on the device
/// (`circus_all`/`features_all` kernels + async `PendingDownload` of the
/// FEATURE_COUNT-float block). Reports bytes downloaded per image and
/// end-to-end features/s.
fn reduce_stage_section(settings: Settings) {
    use hlgpu::tracetransform::{
        set_default_reduce, DeviceChoice, GpuAuto, ReduceMode, TraceImpl, FEATURE_COUNT,
    };
    let size = env_usize("LO_SIZE", 96);
    let angles = env_usize("LO_ANGLES", 64);
    let batch = env_usize("LO_BATCH", 8);
    let thetas = orientations(angles);
    let imgs: Vec<_> = (0..batch).map(|i| random_phantom(size, 90 + i as u64)).collect();
    let iters = (settings.warmup_iters + settings.sample_iters) as f64;

    let mut table = Table::new(&["reduce stage", "time/batch", "KiB d2h/image", "features/s", "speedup"]);
    let mut host_mean = 0.0f64;
    for mode in [ReduceMode::Host, ReduceMode::Device] {
        set_default_reduce(Some(mode));
        let mut auto = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        auto.features_batch(&imgs, &thetas).unwrap(); // warm pipes + handles
        auto.launcher().context().memory().unwrap().reset_stats();
        let summary = measure(settings, || {
            auto.features_batch(&imgs, &thetas).unwrap();
        });
        let st = auto.launcher().context().mem_stats().unwrap();
        let d2h_kib = st.d2h_bytes as f64 / iters / batch as f64 / 1024.0;
        let feats_per_s = (batch * FEATURE_COUNT) as f64 / summary.mean;
        let (name, speedup) = match mode {
            ReduceMode::Host => {
                host_mean = summary.mean;
                ("host (download sinograms)".to_string(), "1.00x".to_string())
            }
            ReduceMode::Device => (
                "device (circus_all + features_all)".to_string(),
                fmt_speedup(host_mean, summary.mean),
            ),
        };
        table.row(&[
            name,
            fmt_summary(&summary),
            format!("{d2h_kib:.2}"),
            format!("{feats_per_s:.0}"),
            speedup,
        ]);
    }
    set_default_reduce(None);

    println!(
        "\nLaunch API v2 — host vs device P/F reduction ({batch} images of {size}x{size}, {angles} angles)"
    );
    println!("(HLGPU_REDUCE=host|device overrides the default placement)");
    println!("{}", table.render());
    println!(
        "device target: {:.3} KiB d2h per image (FEATURE_COUNT * 4 bytes) vs the host path's full sinogram download",
        FEATURE_COUNT as f64 * 4.0 / 1024.0
    );
}

/// Launch API v2 section D: the single-device two-stream pipeline vs the
/// same batch sharded across 2- and 4-member `DeviceSet`s (the
/// `HLGPU_SHARD=auto` path in `gpu_auto::features_batch`). Every run is
/// checked bitwise against the 1-device baseline; the table reports
/// images/s, the speedup over one device, scaling efficiency
/// (speedup / device count) and the shard imbalance ratio, plus the
/// per-member image placement underneath.
fn multi_device_section(settings: Settings) {
    use hlgpu::driver::DeviceSet;
    use hlgpu::tracetransform::{DeviceChoice, GpuAuto, ShardMode, TraceImpl};
    let size = env_usize("LO_SIZE", 96);
    let angles = env_usize("LO_ANGLES", 64);
    let batch = env_usize("LO_BATCH", 8);
    let thetas = orientations(angles);
    let imgs: Vec<_> = (0..batch).map(|i| random_phantom(size, 170 + i as u64)).collect();

    let mut table = Table::new(&[
        "devices",
        "time/batch",
        "images/s",
        "speedup",
        "efficiency",
        "imbalance",
    ]);
    let mut placements: Vec<String> = Vec::new();
    let mut base_mean = 0.0f64;
    let mut want: Vec<Vec<f32>> = Vec::new();
    for k in [1usize, 2, 4] {
        let (mut auto, set) = if k == 1 {
            let a = GpuAuto::on_device(DeviceChoice::Emulator)
                .unwrap()
                .with_shard(Some(ShardMode::Off));
            (a, None)
        } else {
            let set = DeviceSet::emulator(k).unwrap();
            let a = GpuAuto::on_set(set.clone()).unwrap().with_shard(Some(ShardMode::Auto));
            (a, Some(set))
        };
        // warm every lane's pipes + handles, and hold sharding to the
        // acceptance bar: bitwise identity with the 1-device result
        let got = auto.features_batch(&imgs, &thetas).unwrap();
        if k == 1 {
            want = got;
        } else {
            assert_eq!(got, want, "{k}-device shard diverged from single-device");
        }
        let summary = measure(settings, || {
            auto.features_batch(&imgs, &thetas).unwrap();
        });
        let images_per_s = batch as f64 / summary.mean;
        let (speedup, eff) = if k == 1 {
            base_mean = summary.mean;
            ("1.00x".to_string(), "100%".to_string())
        } else {
            (
                fmt_speedup(base_mean, summary.mean),
                format!("{:.0}%", base_mean / summary.mean / k as f64 * 100.0),
            )
        };
        let imbalance = match &set {
            Some(s) => format!("{:.2}", s.imbalance()),
            None => "-".into(),
        };
        table.row(&[
            if k == 1 { "1 (two-stream baseline)".into() } else { format!("{k} (sharded)") },
            fmt_summary(&summary),
            format!("{images_per_s:.1}"),
            speedup,
            eff,
            imbalance,
        ]);
        if let Some(s) = set {
            let per: Vec<String> = s
                .stats()
                .iter()
                .map(|m| format!("dev{} {} imgs", m.ordinal, m.images))
                .collect();
            placements.push(format!("{k} devices: {}", per.join(", ")));
        }
    }

    println!(
        "\nLaunch API v2 — 1 vs N-device sharded features_batch ({batch} images of {size}x{size}, {angles} angles)"
    );
    println!("(HLGPU_DEVICES=N sizes the registry, HLGPU_SHARD=auto|off gates the sharded path)");
    println!("{}", table.render());
    for p in &placements {
        println!("  {p}");
    }
    println!("efficiency = speedup / devices; lanes share this machine's cores, so treat it as an upper-bound trend, not a hardware claim");
}

/// Launch API v2 section E: the execution tiers on the warm
/// `features_batch` path — scalar vs vector vs compiled A/B with the
/// default tier-up threshold, so the compiled run shows the real
/// profile-driven lifecycle: the cold first batch pays hotness
/// counting + JIT compilation, warm batches ride the cached closure
/// chains. Reports instructions/s, compiled share, tier-up count,
/// deopts, and how many batches it takes the JIT to pay for itself
/// (cold-batch overhead vs per-batch saving over the vector tier).
fn compiled_features_section(settings: Settings) {
    use hlgpu::tracetransform::{DeviceChoice, GpuAuto, TraceImpl};
    let size = env_usize("LO_SIZE", 96);
    let angles = env_usize("LO_ANGLES", 64);
    let batch = env_usize("LO_BATCH", 8);
    let thetas = orientations(angles);
    let imgs: Vec<_> = (0..batch).map(|i| random_phantom(size, 260 + i as u64)).collect();
    let iters = (settings.warmup_iters + settings.sample_iters) as f64;

    let mut table = Table::new(&[
        "tier",
        "cold batch",
        "warm time/batch",
        "Minstr/s",
        "compiled share",
        "tier-ups",
        "deopts",
        "speedup",
    ]);
    let mut scalar_mean = 0.0f64;
    let mut vector_mean = f64::INFINITY;
    let mut vector_cold = 0.0f64;
    let mut compiled_mean = f64::INFINITY;
    let mut compiled_cold = 0.0f64;
    for tier in [ExecTier::Scalar, ExecTier::Vector, ExecTier::Compiled] {
        set_default_exec(Some(tier));
        let mut auto = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        // Cold first batch: specialization + pipe setup everywhere; on
        // the compiled tier also hotness counting and JIT compilation
        // (the default HLGPU_TIER_UP threshold, the real lifecycle).
        let (cold, _) = hlgpu::bench_support::measure_once(|| {
            auto.features_batch(&imgs, &thetas).unwrap();
        });
        let before = auto.launcher().metrics();
        let summary = measure(settings, || {
            auto.features_batch(&imgs, &thetas).unwrap();
        });
        let after = auto.launcher().metrics();
        let retired = after.instrs_retired - before.instrs_retired;
        let mips = retired as f64 / iters / summary.mean / 1e6;
        let cshare = if retired > 0 {
            (after.compiled_instrs - before.compiled_instrs) as f64 / retired as f64
        } else {
            0.0
        };
        let deopts = after.deopts - before.deopts;
        let (name, speedup) = match tier {
            ExecTier::Scalar => {
                scalar_mean = summary.mean;
                ("scalar".to_string(), "1.00x".to_string())
            }
            ExecTier::Vector => {
                vector_mean = summary.mean;
                vector_cold = cold;
                ("vector".to_string(), fmt_speedup(scalar_mean, summary.mean))
            }
            ExecTier::Compiled => {
                compiled_mean = summary.mean;
                compiled_cold = cold;
                ("compiled".to_string(), fmt_speedup(scalar_mean, summary.mean))
            }
        };
        table.row(&[
            name,
            format!("{:.1} ms", cold * 1e3),
            fmt_summary(&summary),
            format!("{mips:.1}"),
            format!("{:.0}%", cshare * 100.0),
            after.tier_ups.to_string(),
            deopts.to_string(),
            speedup,
        ]);
    }
    set_default_exec(None);

    println!(
        "\nLaunch API v2 — execution tiers on warm features_batch ({batch} images of {size}x{size}, {angles} angles)"
    );
    println!("(HLGPU_EXEC selects the tier; HLGPU_TIER_UP sets the block-hotness threshold)");
    println!("{}", table.render());
    if vector_mean.is_finite() && compiled_mean.is_finite() {
        let overhead = compiled_cold - vector_cold;
        let saving = vector_mean - compiled_mean;
        if saving > 0.0 && overhead > 0.0 {
            println!(
                "JIT amortization: {:.1} ms compile-time overhead on the cold batch, {:.3} ms saved per warm batch -> pays for itself after ~{} batches",
                overhead * 1e3,
                saving * 1e3,
                (overhead / saving).ceil() as u64
            );
        } else if saving > 0.0 {
            println!(
                "JIT amortization: no measurable cold-batch overhead, {:.3} ms saved per warm batch -> pays for itself immediately",
                saving * 1e3
            );
        } else {
            println!(
                "JIT amortization: compiled tier saved no time over vector on this workload ({} vs {})",
                hlgpu::bench_support::fmt_time(compiled_mean),
                hlgpu::bench_support::fmt_time(vector_mean),
            );
        }
    }
}

/// PJRT section: the original §6 manual-vs-automation comparison.
fn pjrt_overhead_section(settings: Settings, lib: &ArtifactLibrary) {
    let n = env_usize("LO_N", 4096);
    let size = env_usize("LO_SIZE", 64);
    let angles = 90;
    let ctx = Context::default_device().unwrap();

    let mut table = Table::new(&["workload", "manual", "auto (warm)", "overhead"]);

    // ---------------- vadd ------------------------------------------------
    {
        let mut rng = Prng::new(3);
        let a = Tensor::from_f32(&rng.f32_vec(n, 0.0, 1.0), &[n]);
        let b = Tensor::from_f32(&rng.f32_vec(n, 0.0, 1.0), &[n]);
        let mut c = Tensor::zeros_f32(&[n]);

        // manual: persistent buffers, hand-written transfers
        let entry = lib.find("vadd", &format!("f32[{n}];f32[{n}]")).unwrap().clone();
        let module = ctx.load_module(&lib.module_source(&entry)).unwrap();
        let f = module.function("main").unwrap();
        let ga = ctx.alloc(n * 4).unwrap();
        let gb = ctx.alloc(n * 4).unwrap();
        let gc = ctx.alloc(n * 4).unwrap();
        let cfg = LaunchConfig::new(n as u32, 1u32);
        let manual = measure(settings, || {
            ctx.upload(ga, a.bytes()).unwrap();
            ctx.upload(gb, b.bytes()).unwrap();
            f.launch(
                &cfg,
                &[KernelArg::Ptr(ga), KernelArg::Ptr(gb), KernelArg::Ptr(gc)],
                ctx.memory().unwrap(),
            )
            .unwrap();
            ctx.download(gc, c.bytes_mut()).unwrap();
        });

        // auto: warm cache
        let mut launcher = Launcher::with_default_context().unwrap();
        let auto = measure(settings, || {
            launcher
                .launch(
                    "vadd",
                    cfg,
                    &mut [arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)],
                )
                .unwrap();
        });
        table.row(&[
            format!("vadd n={n}"),
            fmt_summary(&manual),
            fmt_summary(&auto),
            format!("{:+.1}%", (auto.mean / manual.mean - 1.0) * 100.0),
        ]);
    }

    // ---------------- sinogram_all ---------------------------------------
    {
        let img = shepp_logan(size).to_tensor();
        let thetas = orientations(angles);
        let ang = Tensor::from_f32(&thetas, &[angles]);
        let mut sinos = Tensor::zeros_f32(&[4, angles, size]);

        let entry = lib
            .find("sinogram_all", &format!("f32[{size},{size}];f32[{angles}]"))
            .expect("artifact for LO_SIZE")
            .clone();
        let module = ctx.load_module(&lib.module_source(&entry)).unwrap();
        let f = module.function("main").unwrap();
        let ga = ctx.alloc(img.byte_len()).unwrap();
        let gb = ctx.alloc(ang.byte_len()).unwrap();
        let gc = ctx.alloc(sinos.byte_len()).unwrap();
        let cfg = LaunchConfig::new(angles as u32, size as u32);
        let manual = measure(settings, || {
            ctx.upload(ga, img.bytes()).unwrap();
            ctx.upload(gb, ang.bytes()).unwrap();
            f.launch(
                &cfg,
                &[KernelArg::Ptr(ga), KernelArg::Ptr(gb), KernelArg::Ptr(gc)],
                ctx.memory().unwrap(),
            )
            .unwrap();
            ctx.download(gc, sinos.bytes_mut()).unwrap();
        });

        let mut launcher = Launcher::with_default_context().unwrap();
        // cold first call, for the record
        let (cold, _) = hlgpu::bench_support::measure_once(|| {
            launcher
                .launch(
                    "sinogram_all",
                    cfg,
                    &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
                )
                .unwrap();
        });
        let auto = measure(settings, || {
            launcher
                .launch(
                    "sinogram_all",
                    cfg,
                    &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut sinos)],
                )
                .unwrap();
        });
        table.row(&[
            format!("sinogram_all {size}x{size}"),
            fmt_summary(&manual),
            fmt_summary(&auto),
            format!("{:+.1}%", (auto.mean / manual.mean - 1.0) * 100.0),
        ]);
        println!(
            "cold first call (specialize + compile): {:.1} ms  vs warm {:.3} ms",
            cold * 1e3,
            auto.mean * 1e3
        );
    }

    println!("\nLaunch overhead — automation vs manual driver calls (§6 'no run-time overhead')");
    println!("{}", table.render());
    println!("paper expectation: overhead within measurement noise (±few %) once warm.");
}

fn main() {
    let settings = Settings {
        warmup_iters: env_usize("LO_WARMUP", 3),
        sample_iters: env_usize("LO_ITERS", 15),
    };

    emulator_scheduler_section(settings);
    exec_tier_section(settings);
    device_resident_section(settings);
    two_stream_pipeline_section(settings);
    reduce_stage_section(settings);
    multi_device_section(settings);
    compiled_features_section(settings);

    match ArtifactLibrary::load_default() {
        Ok(lib) => pjrt_overhead_section(settings, &lib),
        Err(e) => {
            println!("\nPJRT overhead section skipped: {e}");
            println!("(run `make artifacts` to enable the manual-vs-automation comparison)");
        }
    }
}
