//! Open-loop load harness for the feature-serving engine (`hlgpu::serve`,
//! see `docs/serving.md`).
//!
//! Arrivals are Poisson (exponential interarrival times from the crate
//! PRNG) and **open-loop**: the submitter never waits for completions,
//! so queue growth, shedding and deadline expiry behave as they would
//! under real load rather than being self-throttled by the client. Each
//! request draws one of three image sizes, exercising the per-size batch
//! former under a mixed stream.
//!
//! Per offered rate the report shows admitted/served/shed/expired
//! counts, completion-time latency percentiles (p50/p99/p999 — tickets
//! timestamp resolution at the worker, so joining after the window does
//! not distort them), served images/s, and the maximum admission-queue
//! depth observed (must stay bounded by the configured capacity). A
//! batch-size histogram at the end shows what the dynamic former
//! actually built.
//!
//! Every rate then runs a second pass on a 2-member `DeviceSet`
//! (`Service::on_set`, workers pinned round-robin onto members — see
//! `docs/devices.md`): the report adds per-member image counts, busy
//! time and the shard imbalance ratio, plus the 2-device / 1-device
//! served-throughput ratio (report-only; lanes share this machine's
//! cores, so the >1.5x multi-device target needs independent hardware).
//!
//! With `SL_REMOTE=1` the same offered load is driven **over loopback
//! TCP** through `hlgpu::net` (`docs/wire.md`): a `NetServer` fronts the
//! service, the submitter pipelines framed requests on one socket half
//! while a collector thread joins responses on the other, and the report
//! keeps the same columns — so the network tax is directly comparable.
//! Latency is then measured at response receipt (it includes the wire),
//! every submitted request must come back (zero ticket loss; a 30 s
//! receive timeout trips instead of hanging), and the client-side error
//! breakdown must agree with the server's own books.
//!
//! Run: `cargo bench --bench serve_load`
//! Env: SL_RATES (req/s list, default "200,1000,4000"), SL_MS (window
//! per rate, default 400), SL_DEADLINE_US (per-request budget, default
//! 100000), SL_SEED, SL_DEVICES (second-pass set size, default 2),
//! SL_REMOTE=1 (drive over loopback TCP), SL_SMOKE=1 (CI smoke: one
//! small rate, short window, both passes).

use std::time::{Duration, Instant};

use hlgpu::bench_support::{fmt_time, Table};
use hlgpu::net::{NetClient, NetConfig, NetServer, Received};
use hlgpu::serve::{BatchHistogram, ServeConfig, Service};
use hlgpu::tracetransform::{orientations, random_phantom, DeviceChoice, Image};
use hlgpu::util::Prng;
use hlgpu::Error;

const SIZES: [usize; 3] = [10, 12, 16];

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn fmt_pct(sorted: &[f64], p: f64) -> String {
    if sorted.is_empty() {
        "-".into()
    } else {
        fmt_time(pct(sorted, p))
    }
}

struct RateOutcome {
    served: u64,
    throughput: f64,
    max_depth: usize,
    capacity: usize,
    histogram: String,
    errors: String,
    device_line: Option<String>,
}

fn build_service(deadline_us: u64, devices: usize, thetas: &[f32]) -> Service {
    let config = ServeConfig {
        max_batch: 8,
        max_delay_us: 300,
        queue_capacity: 64,
        default_deadline_us: deadline_us,
        workers: devices.max(2),
    };
    if devices <= 1 {
        Service::new(DeviceChoice::Emulator, thetas, config).unwrap()
    } else {
        Service::on_set(hlgpu::driver::DeviceSet::emulator(devices).unwrap(), thetas, config)
            .unwrap()
    }
}

/// Pre-built image pools so the submit loop measures serving, not
/// phantom generation.
fn build_pools(seed: u64) -> Vec<Vec<Image>> {
    SIZES
        .iter()
        .map(|&s| (0..16).map(|i| random_phantom(s, seed ^ ((s as u64) << 8) ^ i)).collect())
        .collect()
}

/// Cross-check the client-side tallies against the service's own books,
/// and render the per-rate report lines shared by both flavors.
fn report_tail(
    svc: &Service,
    served: u64,
    shed: u64,
    expired: u64,
    failed: u64,
) -> (String, String, Option<String>) {
    let st = svc.stats_total();
    assert_eq!(st.served, served, "ticket joins and stats agree on served");
    assert_eq!(st.rejected, shed, "admission sheds and stats agree");
    // Per-member utilization, for the DeviceSet passes.
    let device_line = svc.device_set().map(|s| {
        let per: Vec<String> = s
            .stats()
            .iter()
            .map(|m| {
                format!("dev{} {} imgs {:.0} ms busy", m.ordinal, m.images, m.busy_ns as f64 / 1e6)
            })
            .collect();
        format!("{} — imbalance {:.2}", per.join(", "), s.imbalance())
    });
    // Error breakdown: terminal outcomes plus the non-terminal recovery
    // counters (requests re-admitted after a failed batch, and those
    // re-admitted behind a worker that failed over to another member —
    // see docs/faults.md).
    let errors = format!(
        "shed {shed} / expired {expired} / failed-over {} / failed {failed} (retried {})",
        st.failed_over, st.retried
    );
    (histogram_line(&st.batches), errors, device_line)
}

fn run_rate(
    rate: f64,
    window: Duration,
    deadline_us: u64,
    seed: u64,
    devices: usize,
    table: &mut Table,
) -> RateOutcome {
    let thetas = orientations(6);
    let svc = build_service(deadline_us, devices, &thetas);
    let capacity = svc.config().queue_capacity;
    let pools = build_pools(seed);

    let mut prng = Prng::new(seed);
    let mut pending: Vec<(Instant, hlgpu::serve::Ticket)> = Vec::new();
    let mut shed = 0u64;
    let mut max_depth = 0usize;
    let start = Instant::now();
    let mut next_arrival = start;
    let mut n = 0usize;
    while start.elapsed() < window {
        let now = Instant::now();
        if now < next_arrival {
            std::thread::sleep(next_arrival - now);
        }
        let which = prng.usize_in(0, SIZES.len() - 1);
        let img = pools[which][n % pools[which].len()].clone();
        n += 1;
        match svc.submit("load", img) {
            Ok(t) => pending.push((Instant::now(), t)),
            Err(Error::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("unexpected admission error: {e}"),
        }
        max_depth = max_depth.max(svc.queue_depth());
        // Poisson arrivals: exponential interarrival gap.
        let u = prng.next_f64().min(1.0 - 1e-12);
        next_arrival += Duration::from_secs_f64(-(1.0 - u).ln() / rate);
    }
    let offered = pending.len() as u64 + shed;

    // Join every ticket; resolution instants were stamped by the workers.
    let mut lats: Vec<f64> = Vec::with_capacity(pending.len());
    let mut expired = 0u64;
    let mut failed = 0u64;
    for (t0, ticket) in pending {
        match ticket.wait_timed() {
            (at, Ok(_)) => lats.push(at.saturating_duration_since(t0).as_secs_f64()),
            (_, Err(Error::DeadlineExceeded { .. })) => expired += 1,
            (_, Err(_)) => failed += 1,
        }
    }
    let total = start.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let served = lats.len() as u64;

    table.row(&[
        format!("{rate:.0}/s x{devices}d"),
        offered.to_string(),
        served.to_string(),
        shed.to_string(),
        expired.to_string(),
        failed.to_string(),
        fmt_pct(&lats, 50.0),
        fmt_pct(&lats, 99.0),
        fmt_pct(&lats, 99.9),
        format!("{:.0}", served as f64 / total),
        format!("{max_depth}/{capacity}"),
    ]);

    let (histogram, errors, device_line) = report_tail(&svc, served, shed, expired, failed);
    RateOutcome {
        served,
        throughput: served as f64 / total,
        max_depth,
        capacity,
        histogram,
        errors,
        device_line,
    }
}

/// What the collector thread tallies from the response stream.
struct Collected {
    lats: Vec<f64>,
    shed: u64,
    expired: u64,
    failed: u64,
    received: u64,
}

/// The remote flavor: the same open-loop Poisson stream, driven through
/// a loopback `NetServer` + split `NetClient` instead of direct submits.
fn run_rate_remote(
    rate: f64,
    window: Duration,
    deadline_us: u64,
    seed: u64,
    devices: usize,
    table: &mut Table,
) -> RateOutcome {
    let thetas = orientations(6);
    let svc = build_service(deadline_us, devices, &thetas);
    let capacity = svc.config().queue_capacity;
    let server = NetServer::bind("127.0.0.1:0", svc, NetConfig::default()).unwrap();
    let client = NetClient::connect(&server.addr().to_string(), "load").unwrap();
    let (mut net_tx, mut net_rx) = client.split();
    // A lost ticket must trip this timeout and fail the run, not hang it.
    net_rx.set_timeout(Some(Duration::from_secs(30))).unwrap();

    // The submitter streams each request's (id, t0) to the collector;
    // responses arrive in submission order, so the collector joins them
    // one-for-one and measures receipt-time latency (wire included).
    let (t0_tx, t0_rx) = std::sync::mpsc::channel::<(u64, Instant)>();
    let collector = std::thread::spawn(move || {
        let mut c = Collected { lats: Vec::new(), shed: 0, expired: 0, failed: 0, received: 0 };
        loop {
            match net_rx.recv() {
                Ok(Some(Received::Response(id, outcome))) => {
                    let (want, t0) = t0_rx.recv().expect("a submit record for every response");
                    assert_eq!(id, want, "responses arrive in submission order");
                    c.received += 1;
                    match outcome {
                        Ok(_) => c.lats.push(t0.elapsed().as_secs_f64()),
                        Err(Error::Overloaded { .. }) => c.shed += 1,
                        Err(Error::DeadlineExceeded { .. }) => c.expired += 1,
                        Err(_) => c.failed += 1,
                    }
                }
                Ok(Some(Received::Stats(..))) => panic!("unsolicited stats reply"),
                Ok(None) => return c,
                Err(e) => panic!("receive failed — a ticket was lost over the wire: {e}"),
            }
        }
    });

    let pools = build_pools(seed);
    let mut prng = Prng::new(seed);
    let mut submitted = 0u64;
    let mut max_depth = 0usize;
    let start = Instant::now();
    let mut next_arrival = start;
    let mut n = 0usize;
    while start.elapsed() < window {
        let now = Instant::now();
        if now < next_arrival {
            std::thread::sleep(next_arrival - now);
        }
        let which = prng.usize_in(0, SIZES.len() - 1);
        let img = &pools[which][n % pools[which].len()];
        n += 1;
        let t0 = Instant::now();
        let id = net_tx.submit(img, deadline_us).unwrap();
        t0_tx.send((id, t0)).unwrap();
        submitted += 1;
        // Same-process introspection: the queue bound must hold with the
        // wire in front of it too.
        max_depth = max_depth.max(server.service().queue_depth());
        let u = prng.next_f64().min(1.0 - 1e-12);
        next_arrival += Duration::from_secs_f64(-(1.0 - u).ln() / rate);
    }
    drop(t0_tx);
    // GOODBYE: the server drains every in-flight response, then closes —
    // the collector sees them all, then a clean EOF.
    net_tx.goodbye().unwrap();
    let mut c = collector.join().unwrap();
    let total = start.elapsed().as_secs_f64();
    assert_eq!(c.received, submitted, "zero ticket loss: every request came back");
    c.lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let served = c.lats.len() as u64;

    table.row(&[
        format!("{rate:.0}/s x{devices}d net"),
        submitted.to_string(),
        served.to_string(),
        c.shed.to_string(),
        c.expired.to_string(),
        c.failed.to_string(),
        fmt_pct(&c.lats, 50.0),
        fmt_pct(&c.lats, 99.0),
        fmt_pct(&c.lats, 99.9),
        format!("{:.0}", served as f64 / total),
        format!("{max_depth}/{capacity}"),
    ]);

    let (histogram, errors, device_line) =
        report_tail(server.service(), served, c.shed, c.expired, c.failed);
    let outcome = RateOutcome {
        served,
        throughput: served as f64 / total,
        max_depth,
        capacity,
        histogram,
        errors,
        device_line,
    };
    server.shutdown();
    outcome
}

fn histogram_line(h: &BatchHistogram) -> String {
    let parts: Vec<String> = BatchHistogram::LABELS
        .iter()
        .zip(h.counts())
        .filter(|&(_, c)| c > 0)
        .map(|(l, c)| format!("{l}:{c}"))
        .collect();
    format!("[{}]", parts.join(" "))
}

fn main() {
    let smoke = std::env::var("SL_SMOKE").is_ok();
    let remote = std::env::var("SL_REMOTE").is_ok();
    let rates: Vec<f64> = if smoke {
        vec![300.0]
    } else {
        std::env::var("SL_RATES")
            .unwrap_or_else(|_| "200,1000,4000".into())
            .split(',')
            .filter_map(|r| r.trim().parse().ok())
            .collect()
    };
    let window = Duration::from_millis(if smoke { 120 } else { env_u64("SL_MS", 400) });
    let deadline_us = env_u64("SL_DEADLINE_US", 100_000);
    let seed = env_u64("SL_SEED", 42);

    println!(
        "serve_load: open-loop Poisson arrivals{}, sizes {SIZES:?}, \
         {} ms window, {deadline_us} µs deadline\n",
        if remote { " over loopback TCP" } else { "" },
        window.as_millis()
    );
    let mut table = Table::new(&[
        "offered", "reqs", "served", "shed", "expired", "failed", "p50", "p99", "p999",
        "imgs/s", "maxq",
    ]);
    let run = if remote { run_rate_remote } else { run_rate };
    let set_size = env_u64("SL_DEVICES", 2).max(2) as usize;
    let mut outcomes = Vec::new();
    let mut multi = Vec::new();
    for &rate in &rates {
        outcomes.push(run(rate, window, deadline_us, seed, 1, &mut table));
    }
    // Second pass: same offered load against a DeviceSet-backed service,
    // workers pinned round-robin onto the members.
    for &rate in &rates {
        multi.push(run(rate, window, deadline_us, seed, set_size, &mut table));
    }
    println!("\n{}", table.render());

    for (rate, o) in rates.iter().cycle().zip(outcomes.iter().chain(&multi)) {
        println!("{rate:>6.0}/s batch sizes: {}", o.histogram);
        println!("        errors: {}", o.errors);
        if let Some(line) = &o.device_line {
            println!("        members: {line}");
        }
        assert!(
            o.max_depth <= o.capacity,
            "queue depth {} exceeded capacity {} at {rate}/s",
            o.max_depth,
            o.capacity
        );
    }
    for ((rate, one), many) in rates.iter().zip(&outcomes).zip(&multi) {
        if one.throughput > 0.0 {
            println!(
                "{rate:>6.0}/s: {set_size}-device serve throughput {:.2}x the 1-device pass \
                 (report-only; target > 1.5x on 2 independent devices)",
                many.throughput / one.throughput
            );
        }
    }
    let total_served: u64 = outcomes.iter().chain(&multi).map(|o| o.served).sum();
    assert!(total_served > 0, "no request was ever served");
    println!("queue depth stayed bounded at every rate and set size; zero panics.");
}
