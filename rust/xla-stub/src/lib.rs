//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps the native XLA/PJRT C++ runtime, which cannot be
//! built in the offline image. This stub is **API-compatible** with the
//! subset the `hlgpu::runtime::pjrt` backend uses:
//!
//! * pure-host operations ([`Literal`] construction and extraction) work
//!   for real — they are plain byte-buffer bookkeeping;
//! * anything that needs the native runtime ([`PjRtClient::cpu`],
//!   compilation, execution) returns an "unavailable" [`Error`], which the
//!   backend surfaces as `Error::Xla` and the PJRT-gated tests treat as a
//!   skip condition.
//!
//! Swapping in the real bindings is a Cargo.toml change only.

use std::fmt;

/// Stub error: carries a message; Display-compatible with the real crate.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what} is unavailable: built against the offline xla stub (no native XLA/PJRT runtime)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types of the artifact tensors the backend handles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::F64 => 8,
        }
    }
}

/// Element trait for typed extraction from a [`Literal`].
pub trait ArrayElement: Sized + Copy {
    const ELEMENT_TYPE: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
}

impl ArrayElement for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn from_le(b: &[u8]) -> f32 {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl ArrayElement for f64 {
    const ELEMENT_TYPE: ElementType = ElementType::F64;
    fn from_le(b: &[u8]) -> f64 {
        f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    }
}

impl ArrayElement for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn from_le(b: &[u8]) -> i32 {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

/// A host-side literal: dtype + shape + raw little-endian bytes. Fully
/// functional in the stub (no native runtime involved).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = shape.iter().product();
        if data.len() != numel * ty.byte_size() {
            return Err(Error(format!(
                "literal data has {} bytes, shape {shape:?} of {ty:?} needs {}",
                data.len(),
                numel * ty.byte_size()
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), data: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        if self.ty != T::ELEMENT_TYPE {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        Ok(self
            .data
            .chunks_exact(self.ty.byte_size())
            .map(T::from_le)
            .collect())
    }

    /// Tuple unpacking — real executables return tuples; the stub never
    /// produces one, so this only errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple on a stub literal"))
    }
}

/// Parsed HLO module (opaque; parsing is deferred to the native runtime,
/// which the stub does not have — compile fails later instead).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn parse_and_return_unverified_module(_text: &[u8]) -> Result<HloModuleProto> {
        Ok(HloModuleProto)
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client. `cpu()` reports the runtime as unavailable in the stub.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data: Vec<u8> = [1.0f32, -2.5, 3.25]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &data).unwrap();
        assert_eq!(lit.element_count(), 3);
        let back: Vec<f32> = lit.to_vec().unwrap();
        assert_eq!(back, vec![1.0, -2.5, 3.25]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_length_checked() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[4], &[0u8; 8])
                .is_err()
        );
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("unavailable"), "{err}");
    }
}
