//! The in-process feature-serving engine.
//!
//! [`Service`] fronts the resident batched pipeline
//! ([`GpuAuto::features_batch`]) with an admission queue and a small
//! worker pool:
//!
//! * **admission** — [`Service::submit`] either enqueues the request and
//!   returns a [`Ticket`], or sheds it: [`Error::Overloaded`] when the
//!   bounded queue is full, [`Error::DeadlineExceeded`] when the
//!   request's deadline budget is already zero.
//! * **dynamic batching** — a worker forms a batch from queued requests
//!   of one image size and flushes it when either `max_batch` requests
//!   are available or the oldest has waited `max_delay_us`, whichever
//!   comes first. Requests of *other* sizes never gate a ready batch:
//!   the former picks whichever size group is ready first, so a slow
//!   size class cannot head-of-line-block a fast one.
//! * **deadlines** — a request whose budget ran out while it waited is
//!   dropped from the formed batch before launch; its ticket resolves to
//!   [`Error::DeadlineExceeded`] carrying the actual wait.
//! * **execution** — each worker owns a [`GpuAuto`] pipeline whose
//!   batched path leases its two streams per batch from a
//!   [`crate::driver::StreamPool`]; a failed batch's sticky stream error
//!   is quarantined and reclaimed at lease return, so the next batch
//!   starts clean (see `docs/serving.md`).
//! * **failover** — a failed batch marks the worker's `DeviceSet`
//!   member via `DeviceSet::observe_error`; on a device loss
//!   ([`Error::is_device_loss`]) the worker re-pins onto a healthy
//!   member with a fresh pipeline. The batch's requests are re-admitted
//!   once at the queue front (FIFO preserved, queue bound respected);
//!   a request whose retry also fails resolves with the typed error —
//!   every ticket resolves, nothing is silently dropped (see
//!   `docs/faults.md`).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::driver::DeviceSet;
use crate::error::{Error, Result};
use crate::tracetransform::{DeviceChoice, GpuAuto, Image, TraceImpl};

use super::stats::ServeStats;

/// Tuning knobs for a [`Service`]. `Default` is sized for the emulator
/// device: small batches, sub-millisecond flush, a queue deep enough to
/// absorb bursts without unbounded growth.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Flush a size group once this many requests are queued.
    pub max_batch: usize,
    /// Flush a size group once its oldest request has waited this long.
    pub max_delay_us: u64,
    /// Admission-queue bound; submissions past it get
    /// [`Error::Overloaded`].
    pub queue_capacity: usize,
    /// Deadline budget for [`Service::submit`] (µs);
    /// [`Service::submit_with_deadline`] overrides per request.
    pub default_deadline_us: u64,
    /// Worker threads, each owning its own pipeline. Two or more keep a
    /// flushing size group from serializing behind another's execution.
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_delay_us: 500,
            queue_capacity: 64,
            default_deadline_us: 1_000_000,
            workers: 2,
        }
    }
}

/// What a worker sends back through a ticket: the resolution instant
/// (taken at send, for load harnesses measuring true completion-time
/// latency) and the outcome.
type Resolution = (Instant, Result<Vec<f32>>);

/// One queued request.
struct PendingReq {
    tenant: String,
    image: Image,
    enqueued: Instant,
    budget: Duration,
    /// Already re-admitted once after a failed batch; a second failure
    /// resolves the ticket with the error instead of retrying again.
    retried: bool,
    tx: Sender<Resolution>,
}

/// State shared between submitters and workers.
struct Shared {
    queue: Mutex<VecDeque<PendingReq>>,
    work: Condvar,
    shutdown: AtomicBool,
    stats: Mutex<HashMap<String, ServeStats>>,
    config: ServeConfig,
}

impl Shared {
    fn stat<'a>(
        map: &'a mut HashMap<String, ServeStats>,
        tenant: &str,
    ) -> &'a mut ServeStats {
        map.entry(tenant.to_string()).or_default()
    }
}

/// Handle to one submitted request; resolves to the feature vector or
/// the error that ended it.
pub struct Ticket {
    rx: Receiver<Resolution>,
}

impl Ticket {
    /// Block until the request resolves.
    pub fn wait(self) -> Result<Vec<f32>> {
        self.wait_timed().1
    }

    /// Block until the request resolves; also return the instant the
    /// worker resolved it, so a load harness joining tickets after the
    /// fact still measures true completion-time latency.
    pub fn wait_timed(self) -> (Instant, Result<Vec<f32>>) {
        self.rx.recv().unwrap_or_else(|_| {
            (
                Instant::now(),
                Err(Error::Other("serving worker dropped the request".into())),
            )
        })
    }

    /// Non-blocking poll; `None` while the request is still in flight.
    pub fn try_wait(&self) -> Option<Result<Vec<f32>>> {
        self.rx.try_recv().ok().map(|(_, r)| r)
    }
}

/// Where a worker's pipeline comes from: a device choice (each worker
/// builds its own context) or a shared [`DeviceSet`] (worker `i` is
/// pinned to member `i % len`, round-robin).
#[derive(Clone)]
enum EngineSource {
    Device(DeviceChoice),
    Set(DeviceSet),
}

/// The in-process feature-serving engine. See the module docs for the
/// request lifecycle; construction spins up the worker pool, [`Drop`]
/// (or [`Service::shutdown`]) drains the queue and joins it.
pub struct Service {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    set: Option<DeviceSet>,
}

impl Service {
    /// Build a service on `device` answering every request against the
    /// fixed angle set `thetas` (the angle table uploads once per worker
    /// pipeline and stays device-resident).
    pub fn new(device: DeviceChoice, thetas: &[f32], config: ServeConfig) -> Result<Service> {
        Self::build(EngineSource::Device(device), thetas, config)
    }

    /// Build a service over a [`DeviceSet`]: worker `i` is pinned
    /// round-robin to member `i % set.len()`, each with a single-lane
    /// pipeline on that member's context. Per-member images and busy
    /// time are recorded into the set — read them back through
    /// [`Service::device_set`] for utilization reporting.
    pub fn on_set(set: DeviceSet, thetas: &[f32], config: ServeConfig) -> Result<Service> {
        Self::build(EngineSource::Set(set), thetas, config)
    }

    fn build(source: EngineSource, thetas: &[f32], config: ServeConfig) -> Result<Service> {
        if thetas.is_empty() {
            return Err(Error::Other("serving needs a non-empty angle set".into()));
        }
        let config = ServeConfig {
            max_batch: config.max_batch.max(1),
            queue_capacity: config.queue_capacity.max(1),
            workers: config.workers.max(1),
            ..config
        };
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
            stats: Mutex::new(HashMap::new()),
            config: config.clone(),
        });
        let set = match &source {
            EngineSource::Set(s) => Some(s.clone()),
            EngineSource::Device(_) => None,
        };
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut workers = Vec::with_capacity(config.workers);
        for index in 0..config.workers {
            let shared = shared.clone();
            let thetas = thetas.to_vec();
            let ready = ready_tx.clone();
            let source = source.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(shared, source, index, thetas, ready)
            }));
        }
        drop(ready_tx);
        let worker_count = workers.len();
        let service = Service { shared, workers: Mutex::new(workers), set };
        for _ in 0..worker_count {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    service.drain();
                    return Err(e);
                }
                Err(_) => {
                    service.drain();
                    return Err(Error::Other("serving worker died during startup".into()));
                }
            }
        }
        Ok(service)
    }

    /// The device set behind [`Service::on_set`] (per-member utilization
    /// counters); `None` for per-device construction.
    pub fn device_set(&self) -> Option<&DeviceSet> {
        self.set.as_ref()
    }

    /// Submit with the config's default deadline budget.
    pub fn submit(&self, tenant: &str, image: Image) -> Result<Ticket> {
        self.submit_with_deadline(tenant, image, self.shared.config.default_deadline_us)
    }

    /// Submit with an explicit deadline budget (µs from now). Sheds the
    /// request instead of queueing when the budget is already zero or
    /// the admission queue is full.
    pub fn submit_with_deadline(
        &self,
        tenant: &str,
        image: Image,
        budget_us: u64,
    ) -> Result<Ticket> {
        if budget_us == 0 {
            let mut stats = self.shared.stats.lock().unwrap();
            Shared::stat(&mut stats, tenant).rejected += 1;
            return Err(Error::DeadlineExceeded { waited_us: 0, budget_us: 0 });
        }
        let mut q = self.shared.queue.lock().unwrap();
        // Checked under the queue lock: `drain` raises the flag while
        // holding this lock, so a submission either lands before the
        // drain snapshot (workers flush it — shutdown makes every size
        // group ready) or observes the flag and is refused. Checking
        // before the lock left a window where a racing submit could
        // enqueue after the workers had already seen empty-queue +
        // shutdown and exited, stranding that ticket forever.
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(Error::Other("service is shut down".into()));
        }
        let capacity = self.shared.config.queue_capacity;
        if q.len() >= capacity {
            let depth = q.len();
            drop(q);
            let mut stats = self.shared.stats.lock().unwrap();
            Shared::stat(&mut stats, tenant).rejected += 1;
            return Err(Error::Overloaded { depth, capacity });
        }
        let (tx, rx) = mpsc::channel();
        q.push_back(PendingReq {
            tenant: tenant.to_string(),
            image,
            enqueued: Instant::now(),
            budget: Duration::from_micros(budget_us),
            retried: false,
            tx,
        });
        drop(q);
        let mut stats = self.shared.stats.lock().unwrap();
        Shared::stat(&mut stats, tenant).admitted += 1;
        drop(stats);
        self.shared.work.notify_one();
        Ok(Ticket { rx })
    }

    /// Current admission-queue depth.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// One tenant's counters (zeroes for an unknown tenant).
    pub fn stats(&self, tenant: &str) -> ServeStats {
        self.shared
            .stats
            .lock()
            .unwrap()
            .get(tenant)
            .copied()
            .unwrap_or_default()
    }

    /// Every tenant's counters.
    pub fn all_stats(&self) -> HashMap<String, ServeStats> {
        self.shared.stats.lock().unwrap().clone()
    }

    /// Counters summed across tenants.
    pub fn stats_total(&self) -> ServeStats {
        let mut total = ServeStats::default();
        for s in self.shared.stats.lock().unwrap().values() {
            total.merge(s);
        }
        total
    }

    /// The (normalized) configuration this service runs with.
    pub fn config(&self) -> &ServeConfig {
        &self.shared.config
    }

    /// Stop admitting, drain the queue (queued requests still get
    /// batched and served), and join the workers.
    pub fn shutdown(self) {
        self.drain();
    }

    /// [`Service::shutdown`] through a shared handle (`&self`, so it
    /// works behind an `Arc` — the TCP front door in `crate::net` drains
    /// this way). Raises the shutdown flag *under the queue lock*: every
    /// ticket admitted before the flag is flushed by the workers (the
    /// flag makes all size groups ready), every submit after it is
    /// refused — no ticket is ever stranded by the race. Idempotent;
    /// concurrent callers all return once the workers have exited.
    pub fn drain(&self) {
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.work.notify_all();
        let mut workers = self.workers.lock().unwrap();
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.drain();
    }
}

fn worker_loop(
    shared: Arc<Shared>,
    source: EngineSource,
    index: usize,
    thetas: Vec<f32>,
    ready: Sender<Result<()>>,
) {
    let built = match source {
        EngineSource::Device(device) => GpuAuto::on_device(device).map(|e| (e, None)),
        EngineSource::Set(set) => {
            let member = index % set.len();
            GpuAuto::on_context(set.context(member).clone()).map(|e| (e, Some((set, member))))
        }
    };
    let (mut engine, mut pin) = match built {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Some(batch) = next_batch(&shared) {
        run_batch(&shared, &mut engine, &mut pin, &thetas, batch);
    }
}

/// Block until a size group is ready to flush, then extract it from the
/// queue (FIFO within the group, other sizes left in place). `None` only
/// on shutdown with an empty queue — shutdown flushes every group, so
/// queued work drains before the workers exit.
fn next_batch(shared: &Shared) -> Option<Vec<PendingReq>> {
    let max_batch = shared.config.max_batch;
    let delay = Duration::from_micros(shared.config.max_delay_us);
    let mut q = shared.queue.lock().unwrap();
    loop {
        let down = shared.shutdown.load(Ordering::SeqCst);
        if q.is_empty() {
            if down {
                return None;
            }
            // Bounded wait so a shutdown raced against this sleep is
            // noticed without a wakeup.
            let (guard, _) = shared
                .work
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap();
            q = guard;
            continue;
        }
        // Group the queue by image size: per group the oldest entry (the
        // first seen — the queue is FIFO) decides the flush deadline.
        let now = Instant::now();
        let mut groups: Vec<(usize, Instant, usize)> = Vec::new(); // (size, oldest, count)
        for p in q.iter() {
            let size = p.image.size();
            match groups.iter_mut().find(|g| g.0 == size) {
                Some(g) => g.2 += 1,
                None => groups.push((size, p.enqueued, 1)),
            }
        }
        let ready = groups
            .iter()
            .filter(|&&(_, oldest, count)| down || count >= max_batch || oldest + delay <= now)
            .min_by_key(|&&(_, oldest, _)| oldest);
        if let Some(&(size, _, _)) = ready {
            let mut batch = Vec::with_capacity(max_batch);
            let mut i = 0;
            while i < q.len() && batch.len() < max_batch {
                if q[i].image.size() == size {
                    batch.push(q.remove(i).expect("index in bounds"));
                } else {
                    i += 1;
                }
            }
            if !q.is_empty() {
                // Another group may already be ready — hand it to a peer.
                shared.work.notify_one();
            }
            return Some(batch);
        }
        // Nothing ready: sleep until the earliest group flushes by age,
        // or a submission/shutdown wakes us.
        let next_flush = groups
            .iter()
            .map(|&(_, oldest, _)| oldest + delay)
            .min()
            .expect("non-empty queue has groups");
        let (guard, _) = shared
            .work
            .wait_timeout(q, next_flush.saturating_duration_since(now))
            .unwrap();
        q = guard;
    }
}

/// Drop expired requests, run the survivors through the pipeline, and
/// resolve every ticket. A worker pinned to a [`DeviceSet`] member
/// records its images and busy time into the set; a failed batch marks
/// the member, re-pins the worker onto a healthy one after a device
/// loss, and re-admits the batch's requests once at the queue front.
fn run_batch(
    shared: &Shared,
    engine: &mut GpuAuto,
    pin: &mut Option<(DeviceSet, usize)>,
    thetas: &[f32],
    batch: Vec<PendingReq>,
) {
    let now = Instant::now();
    let mut live = Vec::with_capacity(batch.len());
    for p in batch {
        let waited = now.saturating_duration_since(p.enqueued);
        if waited > p.budget {
            let mut stats = shared.stats.lock().unwrap();
            Shared::stat(&mut stats, &p.tenant).expired += 1;
            drop(stats);
            let _ = p.tx.send((
                Instant::now(),
                Err(Error::DeadlineExceeded {
                    waited_us: waited.as_micros() as u64,
                    budget_us: p.budget.as_micros() as u64,
                }),
            ));
        } else {
            live.push(p);
        }
    }
    if live.is_empty() {
        return;
    }
    let images: Vec<Image> = live.iter().map(|p| p.image.clone()).collect();
    let started = Instant::now();
    let outcome = engine.features_batch(&images, thetas);
    if let Some((set, member)) = pin.as_ref() {
        set.record_busy(*member, started.elapsed().as_nanos() as u64);
        if outcome.is_ok() {
            set.record_images(*member, images.len() as u64);
        }
    }
    match outcome {
        Ok(results) => {
            let n = live.len();
            let done = Instant::now();
            let mut stats = shared.stats.lock().unwrap();
            for (p, feats) in live.into_iter().zip(results) {
                let s = Shared::stat(&mut stats, &p.tenant);
                s.served += 1;
                s.batches.record(n);
                let _ = p.tx.send((done, Ok(feats)));
            }
        }
        Err(e) => {
            // Classify the failure and, after a device loss, re-pin this
            // worker onto a healthy member with a fresh pipeline before
            // deciding each rider's fate.
            let mut failed_over = false;
            if let Some((set, member)) = pin.as_mut() {
                set.observe_error(*member, &e);
                if e.is_device_loss() {
                    if let Some(next) = set.pick_healthy() {
                        if next != *member {
                            if let Ok(fresh) = GpuAuto::on_context(set.context(next).clone()) {
                                *engine = fresh;
                                *member = next;
                                failed_over = true;
                            }
                        }
                    }
                }
            }
            // Re-admit each rider once at the queue front (reverse
            // iteration + push_front preserves FIFO order) while the
            // queue bound allows; everyone else resolves with the typed
            // error. Re-admitted requests keep their original deadline.
            let retryable = e.is_device_loss() || e.is_transient();
            let capacity = shared.config.queue_capacity;
            // `Error` is not `Clone`; every rider gets the failure text.
            let msg = format!("serving batch failed: {e}");
            let mut requeued: Vec<String> = Vec::new();
            let mut dead = Vec::new();
            {
                let mut q = shared.queue.lock().unwrap();
                for mut p in live.into_iter().rev() {
                    if retryable && !p.retried && q.len() < capacity {
                        p.retried = true;
                        requeued.push(p.tenant.clone());
                        q.push_front(p);
                    } else {
                        dead.push(p);
                    }
                }
            }
            if !requeued.is_empty() {
                shared.work.notify_all();
            }
            let done = Instant::now();
            let mut stats = shared.stats.lock().unwrap();
            for tenant in &requeued {
                let s = Shared::stat(&mut stats, tenant);
                s.retried += 1;
                if failed_over {
                    s.failed_over += 1;
                }
            }
            for p in dead {
                Shared::stat(&mut stats, &p.tenant).failed += 1;
                let _ = p.tx.send((done, Err(Error::Stream(msg.clone()))));
            }
        }
    }
}
