//! Per-tenant serving counters and the batch-size histogram.
//!
//! Every counter is updated under the service's stats lock and read back
//! by value ([`ServeStats`] is `Clone`), so callers never hold a lock
//! into the serving hot path.

/// Histogram of formed-batch sizes, power-of-two buckets. A request's
/// bucket is the size of the batch it was *served in*, recorded once per
/// request — so per-tenant totals line up with the `served` counter.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchHistogram {
    buckets: [u64; 7],
}

impl BatchHistogram {
    /// Bucket labels, index-aligned with [`BatchHistogram::counts`].
    pub const LABELS: [&'static str; 7] = ["1", "2-3", "4-7", "8-15", "16-31", "32-63", "64+"];

    fn bucket(n: usize) -> usize {
        let n = n.max(1);
        ((usize::BITS - 1 - n.leading_zeros()) as usize).min(6)
    }

    /// Record one request served in a batch of `n` images.
    pub fn record(&mut self, n: usize) {
        self.buckets[Self::bucket(n)] += 1;
    }

    /// Per-bucket request counts (see [`BatchHistogram::LABELS`]).
    pub fn counts(&self) -> [u64; 7] {
        self.buckets
    }

    /// Total requests recorded.
    pub fn total(&self) -> u64 {
        self.buckets.iter().sum()
    }
}

/// One tenant's admission/serving counters.
///
/// The lifecycle is: every request ends in exactly one of `served`,
/// `rejected` (shed at admission: [`crate::Error::Overloaded`], or a
/// zero budget at admission), `expired` (deadline ran out while queued)
/// or `failed` (the batch it rode in errored) — and `admitted` counts
/// the ones that made it past admission into the queue.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests accepted into the admission queue.
    pub admitted: u64,
    /// Requests completed with features delivered.
    pub served: u64,
    /// Requests shed at admission (queue full, or zero deadline budget).
    pub rejected: u64,
    /// Admitted requests dropped before launch: the deadline budget ran
    /// out while they waited in the queue.
    pub expired: u64,
    /// Admitted requests whose batch failed in the pipeline.
    pub failed: u64,
    /// Requests re-admitted after their batch failed with a transient or
    /// device-loss error. Not a terminal state: each retried request
    /// still ends in `served`, `expired` or `failed`.
    pub retried: u64,
    /// Retried requests whose worker re-pinned onto another `DeviceSet`
    /// member (device loss) before the retry. Not a terminal state.
    pub failed_over: u64,
    /// Sizes of the batches this tenant's served requests rode in.
    pub batches: BatchHistogram,
}

impl ServeStats {
    /// Accumulate another tenant's counters into this one (used for the
    /// service-wide totals).
    pub fn merge(&mut self, other: &ServeStats) {
        self.admitted += other.admitted;
        self.served += other.served;
        self.rejected += other.rejected;
        self.expired += other.expired;
        self.failed += other.failed;
        self.retried += other.retried;
        self.failed_over += other.failed_over;
        for (b, o) in self.batches.buckets.iter_mut().zip(other.batches.buckets) {
            *b += o;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_power_of_two_ranges() {
        let mut h = BatchHistogram::default();
        for n in [1, 2, 3, 4, 7, 8, 15, 16, 31, 32, 63, 64, 1000] {
            h.record(n);
        }
        assert_eq!(h.counts(), [1, 2, 2, 2, 2, 2, 2]);
        assert_eq!(h.total(), 13);
        assert_eq!(BatchHistogram::LABELS.len(), h.counts().len());
    }

    #[test]
    fn stats_default_to_zero() {
        let s = ServeStats::default();
        assert_eq!(s.admitted + s.served + s.rejected + s.expired + s.failed, 0);
        assert_eq!(s.retried + s.failed_over, 0);
        assert_eq!(s.batches.total(), 0);
    }
}
