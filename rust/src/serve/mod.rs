//! Feature-serving engine: an in-process service front for the resident
//! batched pipeline (see `docs/serving.md`).
//!
//! The layers below make one *batch* fast — device-resident angle table
//! and buffers, bound kernel handles, a leased two-stream double-buffered
//! pipeline, optional device-side P/F reduction. This layer makes a
//! *stream of requests* fast and well-behaved:
//!
//! * [`Service`] — admission queue + worker pool over
//!   [`crate::tracetransform::GpuAuto`];
//! * dynamic batch formation — flush after `max_delay_us` or `max_batch`
//!   requests, whichever first, per image-size group (no head-of-line
//!   blocking across sizes);
//! * per-request deadlines — [`crate::Error::DeadlineExceeded`] at
//!   admission for a zero budget, expiry-drop before launch for requests
//!   that aged out in the queue;
//! * bounded-queue backpressure — [`crate::Error::Overloaded`] instead
//!   of unbounded growth;
//! * per-tenant [`ServeStats`] — admitted/served/rejected/expired/failed
//!   counters and a [`BatchHistogram`] of formed batch sizes;
//! * multi-device serving — [`Service::on_set`] pins workers round-robin
//!   onto [`crate::driver::DeviceSet`] members with per-member
//!   utilization accounting (see `docs/devices.md`);
//! * fault tolerance — batch failures are classified with
//!   [`crate::Error::is_device_loss`] / [`crate::Error::is_transient`];
//!   a worker whose member is lost re-pins onto a healthy one, the
//!   failed batch's requests are re-admitted once at the queue front,
//!   and every ticket still resolves with a result or a typed error.
//!   The `retried` / `failed_over` counters in [`ServeStats`] track
//!   this path (see `docs/faults.md`).
//!
//! The open-loop load harness lives in `benches/serve_load.rs`; the
//! correctness suite in `rust/tests/serve.rs`.
//!
//! The process boundary is `crate::net`: a framed TCP front door that
//! maps each connection onto a tenant here and submits into this same
//! admission queue (`docs/wire.md`). [`Service::drain`] is the shared
//! graceful-shutdown path — safe to call through an `Arc<Service>`, and
//! every admitted ticket resolves before the workers exit.

pub mod service;
pub mod stats;

pub use service::{ServeConfig, Service, Ticket};
pub use stats::{BatchHistogram, ServeStats};
