//! Tiny CLI argument parser (no clap offline): positional subcommand +
//! `--flag`, `--key value` / `--key=value` options.

use std::collections::BTreeMap;

/// Parsed command line: `prog SUBCOMMAND [positional...] [--opts]`.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--sizes 64,128,256`.
    pub fn opt_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.opt(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["fig3", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig3"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse(&["run", "--sizes=64,128", "--iters", "10", "--verbose"]);
        assert_eq!(a.opt("sizes"), Some("64,128"));
        assert_eq!(a.opt_usize("iters", 1), 10);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn usize_list_parsing() {
        let a = parse(&["x", "--sizes", "64, 128 ,256"]);
        assert_eq!(a.opt_usize_list("sizes", &[1]), vec![64, 128, 256]);
        assert_eq!(a.opt_usize_list("other", &[32]), vec![32]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--fast", "--sizes", "8"]);
        assert!(a.flag("fast"));
        assert_eq!(a.opt_usize("sizes", 0), 8);
    }
}
