//! Deterministic PRNG (xoshiro256**) for workload generation and the
//! in-repo property-test harness. No external `rand` crate offline; this
//! is small, fast and reproducible across runs — what the benchmarks need.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via splitmix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform usize in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as usize
    }

    /// Vector of uniform f32 values in [lo, hi).
    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick a random element from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut p = Prng::new(42);
            (0..8).map(|_| p.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut p = Prng::new(42);
            (0..8).map(|_| p.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut p = Prng::new(43);
            (0..8).map(|_| p.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..1000 {
            let v = p.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn usize_in_bounds_inclusive() {
        let mut p = Prng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = p.usize_in(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi, "range endpoints should be reachable");
    }

    #[test]
    fn rough_uniformity() {
        let mut p = Prng::new(1234);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| p.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }
}
