//! Utility substrates built in-repo because the image builds offline
//! (no serde/clap/rand available): a JSON parser for the artifact
//! manifest, a deterministic PRNG for workload generation and property
//! tests, and a tiny CLI argument parser.

pub mod cli;
pub mod json;
pub mod prng;

pub use json::Json;
pub use prng::Prng;
