//! Minimal recursive-descent JSON parser (RFC 8259 subset sufficient for
//! the AOT manifest): objects, arrays, strings (with escapes), numbers,
//! booleans, null. No serde available offline — this is the in-repo
//! substrate. [`Json`] also implements [`std::fmt::Display`] as a
//! compact serializer (object keys sorted — `BTreeMap` — so output is
//! deterministic), used by the wire protocol's STATS snapshot
//! (`crate::net`, see `docs/wire.md`).

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{Error, Result};

/// Parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // -- typed accessors --------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.field` access that produces a descriptive manifest error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing required key `{key}`")))
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

/// Compact serializer; `Json::parse(v.to_string())` round-trips every
/// value this crate builds (non-finite numbers render as `null`, which
/// JSON cannot carry).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    f.write_str("null")
                } else if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(map) => {
                f.write_str("{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        // report 1-based line/column for debuggability
        let mut line = 1usize;
        let mut col = 1usize;
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Error::Json(format!("{msg} at line {line}, column {col}"))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("invalid \\u escape"))?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("invalid escape sequence")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Collect the full UTF-8 sequence.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"version": 1, "artifacts": [
            {"name": "vadd_f32_12", "inputs": [{"dtype": "f32", "shape": [12]}],
             "meta": {"n": 12}}]}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("vadd_f32_12"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(12));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"Aé"));
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
        assert!(Json::parse("01x").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("truex").is_err());
    }

    #[test]
    fn error_reports_position() {
        let err = Json::parse("{\n  \"a\": @\n}").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn nested_structures_roundtrip() {
        let j = Json::parse(r#"[[1,2],[3,[4,null,true,false]]]"#).unwrap();
        let outer = j.as_arr().unwrap();
        assert_eq!(outer.len(), 2);
        let inner = outer[1].as_arr().unwrap()[1].as_arr().unwrap();
        assert_eq!(inner[1], Json::Null);
        assert_eq!(inner[2], Json::Bool(true));
    }

    #[test]
    fn display_serializes_and_roundtrips() {
        let doc = r#"{"b":[1,2.5,null,true],"a":"x\n\"y\"","z":{"k":-3}}"#;
        let j = Json::parse(doc).unwrap();
        // Keys come back sorted (BTreeMap), values compact.
        assert_eq!(j.to_string(), r#"{"a":"x\n\"y\"","b":[1,2.5,null,true],"z":{"k":-3}}"#);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        // Integral floats render without a trailing `.0`.
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        // Control characters escape to \u sequences.
        assert_eq!(Json::Str("\u{0007}".into()).to_string(), r#""\u0007""#);
        assert_eq!(Json::parse(r#""\u0007""#).unwrap(), Json::Str("\u{0007}".into()));
    }

    #[test]
    fn req_reports_missing_key() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(j.req("a").is_ok());
        assert!(j.req("b").unwrap_err().to_string().contains("`b`"));
    }
}
