//! # hlgpu — high-level accelerator programming, the Besard-2016 way
//!
//! Reproduction of *"High-level GPU programming in Julia"* (Besard,
//! Verstraete, De Sutter, 2016) as a rust + JAX + Pallas three-layer
//! stack. The paper's contribution — writing kernels in the high-level
//! language and having the framework specialize, compile, and launch them
//! with zero steady-state overhead — lives here in the host layer:
//!
//! * [`driver`] — a simulated accelerator **driver API** (the CUDA driver
//!   API analog): devices, contexts, modules, functions, handle-based
//!   disjoint device memory, streams and events. The memory pool is a
//!   **caching allocator** (power-of-two bins, CUDA.jl style): freed
//!   blocks recycle instead of round-tripping the host allocator;
//!   `HLGPU_POOL=none` restores the uncached policy for A/B runs (see
//!   `docs/memory.md`).
//! * [`runtime`] — the **PJRT backend**: loads AOT HLO-text artifacts
//!   produced by `python/compile/aot.py` (JAX + Pallas) and executes them
//!   on the `xla` crate's CPU client.
//! * [`emulator`] — the **VTX backend** (GPU Ocelot analog): a tiny
//!   PTX-like virtual ISA with a grid/block/thread model, shared memory
//!   and barriers, interpreted on the host so the whole stack runs with no
//!   PJRT dependency at all. Kernels are pre-decoded once per scalar
//!   binding and their thread blocks dispatched across a fixed
//!   worker-thread pool (`emulator::sched`), so grid parallelism is real:
//!   `HLGPU_WORKERS` overrides the schedule width (`1` = the sequential
//!   reference schedule), race-free kernels get identical results and
//!   trap coordinates at every width, and `LaunchMetrics` reports blocks
//!   executed and worker utilization per launch.
//! * [`coordinator`] — the **`@cuda` automation layer**: kernel registry,
//!   per-signature specialization cache (the paper's method cache),
//!   `In`/`Out`/`InOut` argument wrappers driving a minimal transfer plan,
//!   and the [`cuda!`] launch macro. The v2 surface (`docs/api.md`) adds
//!   bound `KernelHandle`s (warm launches with zero cache traffic),
//!   device-resident `arg::cu_dev`/`cu_dev_mut` arguments (the transfer
//!   plan skips h2d/d2h for data the device already holds), and
//!   stream-ordered async launches (`launch_on` → `PendingLaunch`,
//!   joinable via `Event`s) over per-stream pool arenas.
//! * [`hostlang`] — a dynamic, boxed, bounds-checked array layer playing
//!   the role of the high-level host language in the evaluation.
//! * [`tracetransform`] — the paper's case study (§7): the trace transform
//!   with T/P/F functional stacks and the five benchmark implementations.
//! * [`serve`] — the **feature-serving engine** over the batched pipeline:
//!   admission queue with dynamic batch formation, per-request deadlines,
//!   bounded-queue backpressure and per-tenant stats (`docs/serving.md`).
//! * [`net`] — the **networked serving layer**: a versioned length-prefixed
//!   wire format, a std-only TCP front door mapping connections onto
//!   serving tenants, and the matching client (`docs/wire.md`).
//! * [`stats`], [`bench_support`], [`sloc`], [`util`] — measurement
//!   methodology (log-normal fits, §7.2), bench harness, LoC counting for
//!   Table 2, and offline-built utility substrates (JSON, PRNG, CLI).
//!
//! ## Quickstart
//!
//! ```no_run
//! use hlgpu::coordinator::{Launcher, arg};
//! use hlgpu::cuda;
//! use hlgpu::tensor::Tensor;
//!
//! let mut launcher = Launcher::with_default_context().unwrap();
//! let a = Tensor::from_f32(&[1., 2., 3.], &[3]);
//! let b = Tensor::from_f32(&[4., 5., 6.], &[3]);
//! let mut c = Tensor::zeros_f32(&[3]);
//! // the paper's Listing 3: @cuda (len, 1) vadd(CuIn(a), CuIn(b), CuOut(c))
//! cuda!(launcher, (3, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
//! assert_eq!(c.as_f32(), &[5., 7., 9.]);
//! ```

pub mod bench_support;
pub mod coordinator;
pub mod driver;
pub mod emulator;
pub mod error;
pub mod hostlang;
pub mod net;
pub mod runtime;
pub mod serve;
pub mod sloc;
pub mod stats;
pub mod tensor;
pub mod tracetransform;
pub mod util;

pub use error::{Error, Result};

/// Repository root discovery: walks up from the current exe / cwd until a
/// directory containing `artifacts/manifest.json` (or `Cargo.toml`) is found.
pub fn repo_root() -> std::path::PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        if dir.join("artifacts").join("manifest.json").exists()
            || dir.join("Cargo.toml").exists()
        {
            return dir;
        }
        if !dir.pop() {
            return ".".into();
        }
    }
}

/// Path to the AOT artifact directory (`$HLGPU_ARTIFACTS` overrides).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("HLGPU_ARTIFACTS") {
        return p.into();
    }
    repo_root().join("artifacts")
}
