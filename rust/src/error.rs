//! Unified error type across driver, runtime, emulator and coordinator.
//!
//! Mirrors the paper's layering: driver-level failures (the `CUresult`
//! analog), backend compilation failures (PTX/HLO), and automation-level
//! failures (signature mismatch, unsupported argument types — the analog of
//! Julia's "would box" compilation abort, §4.1).

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[derive(Error, Debug)]
pub enum Error {
    // ---- driver-level (CUresult analog) --------------------------------
    #[error("invalid device ordinal {0}")]
    InvalidDevice(usize),
    #[error("context was destroyed")]
    ContextDestroyed,
    #[error("invalid device pointer {0:#x}")]
    InvalidDevicePtr(u64),
    #[error("device memory access out of bounds: {off}+{len} > {size} (buffer {ptr:#x})")]
    OutOfBounds { ptr: u64, off: usize, len: usize, size: usize },
    #[error("device out of memory: requested {requested} bytes, {available} available")]
    OutOfMemory { requested: usize, available: usize },
    #[error("double free of device pointer {0:#x}")]
    DoubleFree(u64),
    #[error("module not found: {0}")]
    ModuleNotFound(String),
    #[error("function not found in module: {0}")]
    FunctionNotFound(String),
    #[error("invalid launch configuration: {0}")]
    InvalidLaunch(String),
    #[error("stream error: {0}")]
    Stream(String),
    #[error("event not recorded")]
    EventNotRecorded,

    // ---- backend / compilation (nvcc / LLVM-PTX analog) ----------------
    #[error("artifact not found for kernel `{kernel}` with signature {signature}")]
    NoArtifact { kernel: String, signature: String },
    #[error("artifact manifest error: {0}")]
    Manifest(String),
    #[error("backend `{backend}` failed to load module: {reason}")]
    ModuleLoad { backend: String, reason: String },
    #[error("XLA/PJRT error: {0}")]
    Xla(String),
    #[error("VTX validation error in kernel `{kernel}`: {reason}")]
    VtxValidation { kernel: String, reason: String },
    #[error("VTX trap in kernel `{kernel}` (block {block:?}, thread {thread:?}): {reason}")]
    VtxTrap { kernel: String, block: (u32, u32, u32), thread: (u32, u32, u32), reason: String },

    // ---- automation-level (the "@cuda would box" analog) ---------------
    #[error("cannot specialize `{kernel}`: {reason}")]
    Specialize { kernel: String, reason: String },
    #[error("argument {index} of `{kernel}`: {reason}")]
    BadArgument { kernel: String, index: usize, reason: String },
    #[error("type error: {0}")]
    Type(String),

    // ---- host-language layer -------------------------------------------
    #[error("hostlang: {0}")]
    HostLang(String),

    // ---- misc ------------------------------------------------------------
    #[error("I/O error: {0}")]
    Io(#[from] std::io::Error),
    #[error("JSON parse error: {0}")]
    Json(String),
    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Other(s)
    }
}

impl Error {
    /// Driver-style status name (the `CUresult` enum analog), used by the
    /// CLI and tests to assert on error categories without string matching.
    pub fn status(&self) -> &'static str {
        use Error::*;
        match self {
            InvalidDevice(_) => "ERROR_INVALID_DEVICE",
            ContextDestroyed => "ERROR_CONTEXT_DESTROYED",
            InvalidDevicePtr(_) => "ERROR_INVALID_VALUE",
            OutOfBounds { .. } => "ERROR_ILLEGAL_ADDRESS",
            OutOfMemory { .. } => "ERROR_OUT_OF_MEMORY",
            DoubleFree(_) => "ERROR_INVALID_VALUE",
            ModuleNotFound(_) => "ERROR_INVALID_IMAGE",
            FunctionNotFound(_) => "ERROR_NOT_FOUND",
            InvalidLaunch(_) => "ERROR_INVALID_VALUE",
            Stream(_) => "ERROR_LAUNCH_FAILED",
            EventNotRecorded => "ERROR_NOT_READY",
            NoArtifact { .. } => "ERROR_NO_BINARY_FOR_GPU",
            Manifest(_) => "ERROR_INVALID_IMAGE",
            ModuleLoad { .. } => "ERROR_INVALID_IMAGE",
            Xla(_) => "ERROR_LAUNCH_FAILED",
            VtxValidation { .. } => "ERROR_INVALID_IMAGE",
            VtxTrap { .. } => "ERROR_LAUNCH_FAILED",
            Specialize { .. } => "ERROR_INVALID_IMAGE",
            BadArgument { .. } => "ERROR_INVALID_VALUE",
            Type(_) => "ERROR_INVALID_VALUE",
            HostLang(_) => "ERROR_UNKNOWN",
            Io(_) => "ERROR_FILE_NOT_FOUND",
            Json(_) => "ERROR_INVALID_IMAGE",
            Other(_) => "ERROR_UNKNOWN",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_names_are_cuda_like() {
        assert_eq!(Error::InvalidDevice(3).status(), "ERROR_INVALID_DEVICE");
        assert_eq!(
            Error::OutOfMemory { requested: 10, available: 5 }.status(),
            "ERROR_OUT_OF_MEMORY"
        );
    }

    #[test]
    fn xla_errors_convert() {
        let e: Error = Error::Xla("boom".into());
        assert_eq!(e.status(), "ERROR_LAUNCH_FAILED");
    }

    #[test]
    fn display_includes_details() {
        let e = Error::OutOfBounds { ptr: 0x10, off: 4, len: 8, size: 8 };
        let s = e.to_string();
        assert!(s.contains("4+8 > 8"));
    }
}
