//! Unified error type across driver, runtime, emulator and coordinator.
//!
//! Mirrors the paper's layering: driver-level failures (the `CUresult`
//! analog), backend compilation failures (PTX/HLO), and automation-level
//! failures (signature mismatch, unsupported argument types — the analog of
//! Julia's "would box" compilation abort, §4.1).
//!
//! Display and `std::error::Error` are implemented by hand — the offline
//! build has no `thiserror`.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    // ---- driver-level (CUresult analog) --------------------------------
    InvalidDevice(usize),
    ContextDestroyed,
    InvalidDevicePtr(u64),
    OutOfBounds { ptr: u64, off: usize, len: usize, size: usize },
    OutOfMemory { requested: usize, available: usize },
    DoubleFree(u64),
    ModuleNotFound(String),
    FunctionNotFound(String),
    InvalidLaunch(String),
    Stream(String),
    EventNotRecorded,
    /// The device was lost — a hung kernel hit the watchdog, a launch
    /// failed fatally, or the fault plane injected a loss. Sticky per
    /// ordinal: every subsequent operation on any context over that
    /// device fails fast with this error until `Device::reset` clears
    /// the mark (see `docs/faults.md`).
    DeviceLost(usize),

    // ---- backend / compilation (nvcc / LLVM-PTX analog) ----------------
    NoArtifact { kernel: String, signature: String },
    Manifest(String),
    ModuleLoad { backend: String, reason: String },
    Xla(String),
    VtxValidation { kernel: String, reason: String },
    VtxTrap { kernel: String, block: (u32, u32, u32), thread: (u32, u32, u32), reason: String },

    // ---- automation-level (the "@cuda would box" analog) ---------------
    Specialize { kernel: String, reason: String },
    BadArgument { kernel: String, index: usize, reason: String },
    Type(String),

    // ---- host-language layer -------------------------------------------
    HostLang(String),

    // ---- serving layer (rust/src/serve) ---------------------------------
    /// A request's deadline budget ran out — rejected at admission when
    /// the budget is already zero, or dropped from a formed batch before
    /// launch when it expired while queued.
    DeadlineExceeded { waited_us: u64, budget_us: u64 },
    /// The admission queue is at capacity; the service sheds the request
    /// instead of growing the queue without bound.
    Overloaded { depth: usize, capacity: usize },

    // ---- misc ------------------------------------------------------------
    Io(std::io::Error),
    Json(String),
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use Error::*;
        match self {
            InvalidDevice(n) => write!(f, "invalid device ordinal {n}"),
            ContextDestroyed => write!(f, "context was destroyed"),
            InvalidDevicePtr(p) => write!(f, "invalid device pointer {p:#x}"),
            OutOfBounds { ptr, off, len, size } => write!(
                f,
                "device memory access out of bounds: {off}+{len} > {size} (buffer {ptr:#x})"
            ),
            OutOfMemory { requested, available } => write!(
                f,
                "device out of memory: requested {requested} bytes, {available} available"
            ),
            DoubleFree(p) => write!(f, "double free of device pointer {p:#x}"),
            ModuleNotFound(m) => write!(f, "module not found: {m}"),
            FunctionNotFound(n) => write!(f, "function not found in module: {n}"),
            InvalidLaunch(r) => write!(f, "invalid launch configuration: {r}"),
            Stream(r) => write!(f, "stream error: {r}"),
            EventNotRecorded => write!(f, "event not recorded"),
            DeviceLost(n) => write!(f, "device {n} was lost (reset required)"),
            NoArtifact { kernel, signature } => write!(
                f,
                "artifact not found for kernel `{kernel}` with signature {signature}"
            ),
            Manifest(r) => write!(f, "artifact manifest error: {r}"),
            ModuleLoad { backend, reason } => {
                write!(f, "backend `{backend}` failed to load module: {reason}")
            }
            Xla(r) => write!(f, "XLA/PJRT error: {r}"),
            VtxValidation { kernel, reason } => {
                write!(f, "VTX validation error in kernel `{kernel}`: {reason}")
            }
            VtxTrap { kernel, block, thread, reason } => write!(
                f,
                "VTX trap in kernel `{kernel}` (block {block:?}, thread {thread:?}): {reason}"
            ),
            Specialize { kernel, reason } => {
                write!(f, "cannot specialize `{kernel}`: {reason}")
            }
            BadArgument { kernel, index, reason } => {
                write!(f, "argument {index} of `{kernel}`: {reason}")
            }
            Type(r) => write!(f, "type error: {r}"),
            HostLang(r) => write!(f, "hostlang: {r}"),
            DeadlineExceeded { waited_us, budget_us } => write!(
                f,
                "request deadline exceeded: waited {waited_us} µs of a {budget_us} µs budget"
            ),
            Overloaded { depth, capacity } => write!(
                f,
                "service overloaded: admission queue at {depth}/{capacity}"
            ),
            Io(e) => write!(f, "I/O error: {e}"),
            Json(r) => write!(f, "JSON parse error: {r}"),
            Other(r) => write!(f, "{r}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Other(s)
    }
}

impl Error {
    /// Driver-style status name (the `CUresult` enum analog), used by the
    /// CLI and tests to assert on error categories without string matching.
    pub fn status(&self) -> &'static str {
        use Error::*;
        match self {
            InvalidDevice(_) => "ERROR_INVALID_DEVICE",
            ContextDestroyed => "ERROR_CONTEXT_DESTROYED",
            InvalidDevicePtr(_) => "ERROR_INVALID_VALUE",
            OutOfBounds { .. } => "ERROR_ILLEGAL_ADDRESS",
            OutOfMemory { .. } => "ERROR_OUT_OF_MEMORY",
            DoubleFree(_) => "ERROR_INVALID_VALUE",
            ModuleNotFound(_) => "ERROR_INVALID_IMAGE",
            FunctionNotFound(_) => "ERROR_NOT_FOUND",
            InvalidLaunch(_) => "ERROR_INVALID_VALUE",
            Stream(_) => "ERROR_LAUNCH_FAILED",
            EventNotRecorded => "ERROR_NOT_READY",
            DeviceLost(_) => "ERROR_DEVICE_LOST",
            NoArtifact { .. } => "ERROR_NO_BINARY_FOR_GPU",
            Manifest(_) => "ERROR_INVALID_IMAGE",
            ModuleLoad { .. } => "ERROR_INVALID_IMAGE",
            Xla(_) => "ERROR_LAUNCH_FAILED",
            VtxValidation { .. } => "ERROR_INVALID_IMAGE",
            VtxTrap { .. } => "ERROR_LAUNCH_FAILED",
            Specialize { .. } => "ERROR_INVALID_IMAGE",
            BadArgument { .. } => "ERROR_INVALID_VALUE",
            Type(_) => "ERROR_INVALID_VALUE",
            HostLang(_) => "ERROR_UNKNOWN",
            DeadlineExceeded { .. } => "ERROR_TIMEOUT",
            Overloaded { .. } => "ERROR_OUT_OF_RESOURCES",
            Io(_) => "ERROR_FILE_NOT_FOUND",
            Json(_) => "ERROR_INVALID_IMAGE",
            Other(_) => "ERROR_UNKNOWN",
        }
    }

    /// Does this error classify as a *device loss*? True for the typed
    /// [`Error::DeviceLost`], and for stringly carriers — sticky stream
    /// errors and serve-layer batch-failure wrappers stringify their
    /// cause — whose message contains the canonical loss phrase.
    pub fn is_device_loss(&self) -> bool {
        match self {
            Error::DeviceLost(_) => true,
            Error::Stream(m) | Error::Other(m) => m.contains(DEVICE_LOST_PHRASE),
            _ => false,
        }
    }

    /// Is this error worth retrying on the same device set? Transient
    /// failures are tied to one batch — a poisoned stream, a failed
    /// allocation, a desynced warm cache, an overloaded queue — rather
    /// than to broken input or a lost device.
    pub fn is_transient(&self) -> bool {
        if self.is_device_loss() {
            return false;
        }
        matches!(
            self,
            Error::OutOfMemory { .. }
                | Error::Stream(_)
                | Error::InvalidLaunch(_)
                | Error::Overloaded { .. }
        )
    }
}

/// Canonical device-loss phrase from `Error::DeviceLost`'s Display, the
/// marker [`Error::is_device_loss`] matches in stringly-typed carriers.
const DEVICE_LOST_PHRASE: &str = "was lost (reset required)";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_names_are_cuda_like() {
        assert_eq!(Error::InvalidDevice(3).status(), "ERROR_INVALID_DEVICE");
        assert_eq!(
            Error::OutOfMemory { requested: 10, available: 5 }.status(),
            "ERROR_OUT_OF_MEMORY"
        );
    }

    #[test]
    fn serving_errors_have_statuses_and_details() {
        let e = Error::DeadlineExceeded { waited_us: 1500, budget_us: 1000 };
        assert_eq!(e.status(), "ERROR_TIMEOUT");
        assert!(e.to_string().contains("1500"));
        let e = Error::Overloaded { depth: 64, capacity: 64 };
        assert_eq!(e.status(), "ERROR_OUT_OF_RESOURCES");
        assert!(e.to_string().contains("64/64"));
    }

    #[test]
    fn xla_errors_convert() {
        let e: Error = Error::Xla("boom".into());
        assert_eq!(e.status(), "ERROR_LAUNCH_FAILED");
    }

    #[test]
    fn device_loss_is_sticky_and_classified() {
        let e = Error::DeviceLost(2);
        assert_eq!(e.status(), "ERROR_DEVICE_LOST");
        assert!(e.to_string().contains("device 2 was lost"));
        assert!(e.is_device_loss());
        assert!(!e.is_transient());
        // Stringly carriers keep the classification: a sticky stream
        // error stores the original Display text.
        let carried = Error::Stream(Error::DeviceLost(2).to_string());
        assert!(carried.is_device_loss());
        assert!(!carried.is_transient());
        let wrapped = Error::Other(format!("serving batch failed: {e}"));
        assert!(wrapped.is_device_loss());
    }

    #[test]
    fn transient_classification() {
        assert!(Error::OutOfMemory { requested: 1, available: 0 }.is_transient());
        assert!(Error::Stream("injected h2d fault on device 1".into()).is_transient());
        assert!(Error::InvalidLaunch("desynced pipe".into()).is_transient());
        assert!(Error::Overloaded { depth: 1, capacity: 1 }.is_transient());
        assert!(!Error::Type("bad dtype".into()).is_transient());
        assert!(!Error::Type("bad dtype".into()).is_device_loss());
    }

    #[test]
    fn display_includes_details() {
        let e = Error::OutOfBounds { ptr: 0x10, off: 4, len: 8, size: 8 };
        let s = e.to_string();
        assert!(s.contains("4+8 > 8"));
    }
}
