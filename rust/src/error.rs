//! Unified error type across driver, runtime, emulator and coordinator.
//!
//! Mirrors the paper's layering: driver-level failures (the `CUresult`
//! analog), backend compilation failures (PTX/HLO), and automation-level
//! failures (signature mismatch, unsupported argument types — the analog of
//! Julia's "would box" compilation abort, §4.1).
//!
//! Display and `std::error::Error` are implemented by hand — the offline
//! build has no `thiserror`.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    // ---- driver-level (CUresult analog) --------------------------------
    InvalidDevice(usize),
    ContextDestroyed,
    InvalidDevicePtr(u64),
    OutOfBounds { ptr: u64, off: usize, len: usize, size: usize },
    OutOfMemory { requested: usize, available: usize },
    DoubleFree(u64),
    ModuleNotFound(String),
    FunctionNotFound(String),
    InvalidLaunch(String),
    Stream(String),
    EventNotRecorded,
    /// The device was lost — a hung kernel hit the watchdog, a launch
    /// failed fatally, or the fault plane injected a loss. Sticky per
    /// ordinal: every subsequent operation on any context over that
    /// device fails fast with this error until `Device::reset` clears
    /// the mark (see `docs/faults.md`).
    DeviceLost(usize),

    // ---- backend / compilation (nvcc / LLVM-PTX analog) ----------------
    NoArtifact { kernel: String, signature: String },
    Manifest(String),
    ModuleLoad { backend: String, reason: String },
    Xla(String),
    VtxValidation { kernel: String, reason: String },
    VtxTrap { kernel: String, block: (u32, u32, u32), thread: (u32, u32, u32), reason: String },

    // ---- automation-level (the "@cuda would box" analog) ---------------
    Specialize { kernel: String, reason: String },
    BadArgument { kernel: String, index: usize, reason: String },
    Type(String),

    // ---- host-language layer -------------------------------------------
    HostLang(String),

    // ---- serving layer (rust/src/serve) ---------------------------------
    /// A request's deadline budget ran out — rejected at admission when
    /// the budget is already zero, or dropped from a formed batch before
    /// launch when it expired while queued.
    DeadlineExceeded { waited_us: u64, budget_us: u64 },
    /// The admission queue is at capacity; the service sheds the request
    /// instead of growing the queue without bound.
    Overloaded { depth: usize, capacity: usize },
    /// A wire-protocol violation on the framed TCP transport
    /// (`rust/src/net`): bad magic, truncated or oversized frame,
    /// unknown frame type, malformed payload (see `docs/wire.md`).
    Protocol(String),

    // ---- misc ------------------------------------------------------------
    Io(std::io::Error),
    Json(String),
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use Error::*;
        match self {
            InvalidDevice(n) => write!(f, "invalid device ordinal {n}"),
            ContextDestroyed => write!(f, "context was destroyed"),
            InvalidDevicePtr(p) => write!(f, "invalid device pointer {p:#x}"),
            OutOfBounds { ptr, off, len, size } => write!(
                f,
                "device memory access out of bounds: {off}+{len} > {size} (buffer {ptr:#x})"
            ),
            OutOfMemory { requested, available } => write!(
                f,
                "device out of memory: requested {requested} bytes, {available} available"
            ),
            DoubleFree(p) => write!(f, "double free of device pointer {p:#x}"),
            ModuleNotFound(m) => write!(f, "module not found: {m}"),
            FunctionNotFound(n) => write!(f, "function not found in module: {n}"),
            InvalidLaunch(r) => write!(f, "invalid launch configuration: {r}"),
            Stream(r) => write!(f, "stream error: {r}"),
            EventNotRecorded => write!(f, "event not recorded"),
            DeviceLost(n) => write!(f, "device {n} was lost (reset required)"),
            NoArtifact { kernel, signature } => write!(
                f,
                "artifact not found for kernel `{kernel}` with signature {signature}"
            ),
            Manifest(r) => write!(f, "artifact manifest error: {r}"),
            ModuleLoad { backend, reason } => {
                write!(f, "backend `{backend}` failed to load module: {reason}")
            }
            Xla(r) => write!(f, "XLA/PJRT error: {r}"),
            VtxValidation { kernel, reason } => {
                write!(f, "VTX validation error in kernel `{kernel}`: {reason}")
            }
            VtxTrap { kernel, block, thread, reason } => write!(
                f,
                "VTX trap in kernel `{kernel}` (block {block:?}, thread {thread:?}): {reason}"
            ),
            Specialize { kernel, reason } => {
                write!(f, "cannot specialize `{kernel}`: {reason}")
            }
            BadArgument { kernel, index, reason } => {
                write!(f, "argument {index} of `{kernel}`: {reason}")
            }
            Type(r) => write!(f, "type error: {r}"),
            HostLang(r) => write!(f, "hostlang: {r}"),
            DeadlineExceeded { waited_us, budget_us } => write!(
                f,
                "request deadline exceeded: waited {waited_us} µs of a {budget_us} µs budget"
            ),
            Overloaded { depth, capacity } => write!(
                f,
                "service overloaded: admission queue at {depth}/{capacity}"
            ),
            Protocol(r) => write!(f, "wire protocol error: {r}"),
            Io(e) => write!(f, "I/O error: {e}"),
            Json(r) => write!(f, "JSON parse error: {r}"),
            Other(r) => write!(f, "{r}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::Other(s)
    }
}

impl Error {
    /// Driver-style status name (the `CUresult` enum analog), used by the
    /// CLI and tests to assert on error categories without string matching.
    pub fn status(&self) -> &'static str {
        use Error::*;
        match self {
            InvalidDevice(_) => "ERROR_INVALID_DEVICE",
            ContextDestroyed => "ERROR_CONTEXT_DESTROYED",
            InvalidDevicePtr(_) => "ERROR_INVALID_VALUE",
            OutOfBounds { .. } => "ERROR_ILLEGAL_ADDRESS",
            OutOfMemory { .. } => "ERROR_OUT_OF_MEMORY",
            DoubleFree(_) => "ERROR_INVALID_VALUE",
            ModuleNotFound(_) => "ERROR_INVALID_IMAGE",
            FunctionNotFound(_) => "ERROR_NOT_FOUND",
            InvalidLaunch(_) => "ERROR_INVALID_VALUE",
            Stream(_) => "ERROR_LAUNCH_FAILED",
            EventNotRecorded => "ERROR_NOT_READY",
            DeviceLost(_) => "ERROR_DEVICE_LOST",
            NoArtifact { .. } => "ERROR_NO_BINARY_FOR_GPU",
            Manifest(_) => "ERROR_INVALID_IMAGE",
            ModuleLoad { .. } => "ERROR_INVALID_IMAGE",
            Xla(_) => "ERROR_LAUNCH_FAILED",
            VtxValidation { .. } => "ERROR_INVALID_IMAGE",
            VtxTrap { .. } => "ERROR_LAUNCH_FAILED",
            Specialize { .. } => "ERROR_INVALID_IMAGE",
            BadArgument { .. } => "ERROR_INVALID_VALUE",
            Type(_) => "ERROR_INVALID_VALUE",
            HostLang(_) => "ERROR_UNKNOWN",
            DeadlineExceeded { .. } => "ERROR_TIMEOUT",
            Overloaded { .. } => "ERROR_OUT_OF_RESOURCES",
            Protocol(_) => "ERROR_PROTOCOL",
            Io(_) => "ERROR_FILE_NOT_FOUND",
            Json(_) => "ERROR_INVALID_IMAGE",
            Other(_) => "ERROR_UNKNOWN",
        }
    }

    /// Stable numeric status code for the framed wire protocol
    /// (`rust/src/net`, see `docs/wire.md`). `0` is reserved for OK, so
    /// every variant maps to a non-zero code. Codes are grouped by layer
    /// (driver 1–19, backend 20–29, automation 30–39, host-language 40s,
    /// serving 50s, misc/transport 60s) and **never reused**: the match
    /// below is exhaustive, so adding an `Error` variant without
    /// assigning it a code fails to compile, and the
    /// `wire_codes_are_stable_and_unique` test pins the published
    /// values.
    pub fn wire_code(&self) -> u16 {
        use Error::*;
        match self {
            // driver-level
            InvalidDevice(_) => 1,
            ContextDestroyed => 2,
            InvalidDevicePtr(_) => 3,
            OutOfBounds { .. } => 4,
            OutOfMemory { .. } => 5,
            DoubleFree(_) => 6,
            ModuleNotFound(_) => 7,
            FunctionNotFound(_) => 8,
            InvalidLaunch(_) => 9,
            Stream(_) => 10,
            EventNotRecorded => 11,
            DeviceLost(_) => 12,
            // backend / compilation
            NoArtifact { .. } => 20,
            Manifest(_) => 21,
            ModuleLoad { .. } => 22,
            Xla(_) => 23,
            VtxValidation { .. } => 24,
            VtxTrap { .. } => 25,
            // automation-level
            Specialize { .. } => 30,
            BadArgument { .. } => 31,
            Type(_) => 32,
            // host-language layer
            HostLang(_) => 40,
            // serving layer
            DeadlineExceeded { .. } => 50,
            Overloaded { .. } => 51,
            // misc / transport
            Io(_) => 60,
            Json(_) => 61,
            Other(_) => 62,
            Protocol(_) => 63,
        }
    }

    /// Project this error onto the wire: `(code, a, b, message)`, where
    /// `a`/`b` carry the variant's numeric payload (so the well-known
    /// variants reconstruct losslessly through [`Error::from_wire`]) and
    /// `message` is the Display text.
    pub fn to_wire(&self) -> (u16, u64, u64, String) {
        use Error::*;
        let (a, b) = match self {
            InvalidDevice(n) | DeviceLost(n) => (*n as u64, 0),
            InvalidDevicePtr(p) | DoubleFree(p) => (*p, 0),
            OutOfMemory { requested, available } => (*requested as u64, *available as u64),
            DeadlineExceeded { waited_us, budget_us } => (*waited_us, *budget_us),
            Overloaded { depth, capacity } => (*depth as u64, *capacity as u64),
            _ => (0, 0),
        };
        (self.wire_code(), a, b, self.to_string())
    }

    /// Reconstruct an error from its wire projection. Well-known codes
    /// come back as their structured variants (deadlines, overload,
    /// device loss, OOM keep their numbers; stringly variants keep the
    /// message); anything else lands in [`Error::Other`] carrying the
    /// remote Display text, so no information is silently dropped.
    pub fn from_wire(code: u16, a: u64, b: u64, msg: String) -> Error {
        // The message travels as Display text; peel the variant's own
        // prefix back off so reconstruction doesn't double-wrap it.
        fn strip(msg: String, prefix: &str) -> String {
            match msg.strip_prefix(prefix) {
                Some(inner) => inner.to_string(),
                None => msg,
            }
        }
        match code {
            5 => Error::OutOfMemory { requested: a as usize, available: b as usize },
            9 => Error::InvalidLaunch(strip(msg, "invalid launch configuration: ")),
            10 => Error::Stream(strip(msg, "stream error: ")),
            12 => Error::DeviceLost(a as usize),
            32 => Error::Type(strip(msg, "type error: ")),
            50 => Error::DeadlineExceeded { waited_us: a, budget_us: b },
            51 => Error::Overloaded { depth: a as usize, capacity: b as usize },
            61 => Error::Json(strip(msg, "JSON parse error: ")),
            63 => Error::Protocol(strip(msg, "wire protocol error: ")),
            _ => Error::Other(msg),
        }
    }

    /// Does this error classify as a *device loss*? True for the typed
    /// [`Error::DeviceLost`], and for stringly carriers — sticky stream
    /// errors and serve-layer batch-failure wrappers stringify their
    /// cause — whose message contains the canonical loss phrase.
    pub fn is_device_loss(&self) -> bool {
        match self {
            Error::DeviceLost(_) => true,
            Error::Stream(m) | Error::Other(m) => m.contains(DEVICE_LOST_PHRASE),
            _ => false,
        }
    }

    /// Is this error worth retrying on the same device set? Transient
    /// failures are tied to one batch — a poisoned stream, a failed
    /// allocation, a desynced warm cache, an overloaded queue — rather
    /// than to broken input or a lost device.
    pub fn is_transient(&self) -> bool {
        if self.is_device_loss() {
            return false;
        }
        matches!(
            self,
            Error::OutOfMemory { .. }
                | Error::Stream(_)
                | Error::InvalidLaunch(_)
                | Error::Overloaded { .. }
        )
    }
}

/// Canonical device-loss phrase from `Error::DeviceLost`'s Display, the
/// marker [`Error::is_device_loss`] matches in stringly-typed carriers.
const DEVICE_LOST_PHRASE: &str = "was lost (reset required)";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_names_are_cuda_like() {
        assert_eq!(Error::InvalidDevice(3).status(), "ERROR_INVALID_DEVICE");
        assert_eq!(
            Error::OutOfMemory { requested: 10, available: 5 }.status(),
            "ERROR_OUT_OF_MEMORY"
        );
    }

    #[test]
    fn serving_errors_have_statuses_and_details() {
        let e = Error::DeadlineExceeded { waited_us: 1500, budget_us: 1000 };
        assert_eq!(e.status(), "ERROR_TIMEOUT");
        assert!(e.to_string().contains("1500"));
        let e = Error::Overloaded { depth: 64, capacity: 64 };
        assert_eq!(e.status(), "ERROR_OUT_OF_RESOURCES");
        assert!(e.to_string().contains("64/64"));
    }

    #[test]
    fn xla_errors_convert() {
        let e: Error = Error::Xla("boom".into());
        assert_eq!(e.status(), "ERROR_LAUNCH_FAILED");
    }

    #[test]
    fn device_loss_is_sticky_and_classified() {
        let e = Error::DeviceLost(2);
        assert_eq!(e.status(), "ERROR_DEVICE_LOST");
        assert!(e.to_string().contains("device 2 was lost"));
        assert!(e.is_device_loss());
        assert!(!e.is_transient());
        // Stringly carriers keep the classification: a sticky stream
        // error stores the original Display text.
        let carried = Error::Stream(Error::DeviceLost(2).to_string());
        assert!(carried.is_device_loss());
        assert!(!carried.is_transient());
        let wrapped = Error::Other(format!("serving batch failed: {e}"));
        assert!(wrapped.is_device_loss());
    }

    #[test]
    fn transient_classification() {
        assert!(Error::OutOfMemory { requested: 1, available: 0 }.is_transient());
        assert!(Error::Stream("injected h2d fault on device 1".into()).is_transient());
        assert!(Error::InvalidLaunch("desynced pipe".into()).is_transient());
        assert!(Error::Overloaded { depth: 1, capacity: 1 }.is_transient());
        assert!(!Error::Type("bad dtype".into()).is_transient());
        assert!(!Error::Type("bad dtype".into()).is_device_loss());
    }

    /// One representative instance of every variant. `wire_code`'s match
    /// is exhaustive, so a new variant without a code fails to compile;
    /// this test additionally pins the *published* values (the wire
    /// contract) and checks no two variants share a code.
    fn every_variant() -> Vec<Error> {
        vec![
            Error::InvalidDevice(3),
            Error::ContextDestroyed,
            Error::InvalidDevicePtr(0x10),
            Error::OutOfBounds { ptr: 0x10, off: 4, len: 8, size: 8 },
            Error::OutOfMemory { requested: 10, available: 5 },
            Error::DoubleFree(0x20),
            Error::ModuleNotFound("m".into()),
            Error::FunctionNotFound("f".into()),
            Error::InvalidLaunch("r".into()),
            Error::Stream("r".into()),
            Error::EventNotRecorded,
            Error::DeviceLost(2),
            Error::NoArtifact { kernel: "k".into(), signature: "s".into() },
            Error::Manifest("r".into()),
            Error::ModuleLoad { backend: "b".into(), reason: "r".into() },
            Error::Xla("r".into()),
            Error::VtxValidation { kernel: "k".into(), reason: "r".into() },
            Error::VtxTrap {
                kernel: "k".into(),
                block: (0, 0, 0),
                thread: (0, 0, 0),
                reason: "r".into(),
            },
            Error::Specialize { kernel: "k".into(), reason: "r".into() },
            Error::BadArgument { kernel: "k".into(), index: 0, reason: "r".into() },
            Error::Type("r".into()),
            Error::HostLang("r".into()),
            Error::DeadlineExceeded { waited_us: 1, budget_us: 2 },
            Error::Overloaded { depth: 1, capacity: 2 },
            Error::Io(std::io::Error::other("r")),
            Error::Json("r".into()),
            Error::Other("r".into()),
            Error::Protocol("r".into()),
        ]
    }

    #[test]
    fn wire_codes_are_stable_and_unique() {
        let want: &[u16] = &[
            1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, // driver
            20, 21, 22, 23, 24, 25, // backend
            30, 31, 32, // automation
            40, // hostlang
            50, 51, // serving
            60, 61, 62, 63, // misc / transport
        ];
        let all = every_variant();
        assert_eq!(all.len(), want.len(), "every_variant drifted from the code table");
        let mut seen = std::collections::HashSet::new();
        for (e, &code) in all.iter().zip(want) {
            assert_eq!(e.wire_code(), code, "code for {e:?} moved — wire codes are append-only");
            assert_ne!(e.wire_code(), 0, "0 is reserved for OK");
            assert!(seen.insert(e.wire_code()), "duplicate wire code {code}");
        }
    }

    #[test]
    fn wire_roundtrip_preserves_typed_variants() {
        for e in [
            Error::DeadlineExceeded { waited_us: 1500, budget_us: 1000 },
            Error::Overloaded { depth: 64, capacity: 64 },
            Error::DeviceLost(2),
            Error::OutOfMemory { requested: 10, available: 5 },
            Error::Stream("sticky".into()),
            Error::Protocol("bad magic".into()),
        ] {
            let status = e.status();
            let classified = (e.is_device_loss(), e.is_transient());
            let (code, a, b, msg) = e.to_wire();
            let back = Error::from_wire(code, a, b, msg.clone());
            assert_eq!(back.wire_code(), code, "{back:?}");
            assert_eq!(back.status(), status, "{back:?}");
            assert_eq!((back.is_device_loss(), back.is_transient()), classified, "{back:?}");
            assert_eq!(back.to_string(), e.to_string());
        }
        // Unknown / unmapped codes keep the remote message readable.
        let back = Error::from_wire(25, 0, 0, "VTX trap in kernel `k`".into());
        assert!(matches!(back, Error::Other(_)));
        assert!(back.to_string().contains("VTX trap"));
    }

    #[test]
    fn display_includes_details() {
        let e = Error::OutOfBounds { ptr: 0x10, off: 4, len: 8, size: 8 };
        let s = e.to_string();
        assert!(s.contains("4+8 > 8"));
    }
}
