//! Host-side tensors: the values the automation layer inspects to build a
//! call signature (the analog of Julia argument types driving `gen_launch`
//! specialization, paper §6.2).

use crate::error::{Error, Result};

/// Element type of a [`Tensor`]. The artifact signature format uses the
/// same short names as the manifest (`f32`, `f64`, `i32`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    F64,
    I32,
}

impl Dtype {
    pub fn size_of(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::F64 => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F64 => "f64",
            Dtype::I32 => "i32",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "f64" => Ok(Dtype::F64),
            "i32" => Ok(Dtype::I32),
            other => Err(Error::Type(format!("unknown dtype `{other}`"))),
        }
    }
}

/// A dense host tensor (row-major). Storage is raw bytes so buffers can be
/// copied to device memory without reinterpretation.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    dtype: Dtype,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Tensor {
    pub fn new(dtype: Dtype, shape: &[usize], data: Vec<u8>) -> Result<Self> {
        // overflow-checked: a wrapped byte length would let a crafted
        // shape validate against a tiny buffer
        let want = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .and_then(|numel| numel.checked_mul(dtype.size_of()));
        if want != Some(data.len()) {
            return Err(Error::Type(format!(
                "tensor data length {} does not match {:?} x {}",
                data.len(),
                shape,
                dtype.name()
            )));
        }
        Ok(Tensor { dtype, shape: shape.to_vec(), data })
    }

    pub fn from_f32(values: &[f32], shape: &[usize]) -> Self {
        let numel: usize = shape.iter().product();
        assert_eq!(values.len(), numel, "from_f32: shape/product mismatch");
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: Dtype::F32, shape: shape.to_vec(), data }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        Self::zeros(Dtype::F32, shape)
    }

    /// Zero-filled tensor of any supported dtype (the `download` path
    /// dispatches on the device array's dtype). Panics on byte-length
    /// overflow — a programming error, like the dtype asserts below.
    pub fn zeros(dtype: Dtype, shape: &[usize]) -> Self {
        let bytes = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .and_then(|numel| numel.checked_mul(dtype.size_of()))
            .expect("tensor byte length overflows usize");
        Tensor { dtype, shape: shape.to_vec(), data: vec![0u8; bytes] }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::from_f32(&[v], &[])
    }

    /// Lossy conversion from boxed f64 values (the hostlang layer) —
    /// models the paper's "copying and converting Julia datatypes before
    /// upload" overhead (§7.3).
    pub fn from_f64_as_f32(values: &[f64], shape: &[usize]) -> Self {
        let v32: Vec<f32> = values.iter().map(|&v| v as f32).collect();
        Tensor::from_f32(&v32, shape)
    }

    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    pub fn bytes_mut(&mut self) -> &mut [u8] {
        &mut self.data
    }

    /// View as f32 slice; panics if dtype is not F32 (programming error).
    pub fn as_f32(&self) -> &[f32] {
        assert_eq!(self.dtype, Dtype::F32);
        // Safety: data is always allocated in dtype.size_of() multiples and
        // Vec<u8> from to_le_bytes of f32 is layout-compatible on LE hosts.
        unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const f32, self.numel())
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        assert_eq!(self.dtype, Dtype::F32);
        let n = self.numel();
        unsafe { std::slice::from_raw_parts_mut(self.data.as_mut_ptr() as *mut f32, n) }
    }

    pub fn to_vec_f32(&self) -> Vec<f32> {
        self.as_f32().to_vec()
    }

    /// Signature fragment, e.g. `f32[90,128]` — the unit of specialization.
    pub fn signature(&self) -> String {
        let dims: Vec<String> = self.shape.iter().map(|d| d.to_string()).collect();
        format!("{}[{}]", self.dtype.name(), dims.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::from_f32(&[1.0, -2.5, 3.25], &[3]);
        assert_eq!(t.as_f32(), &[1.0, -2.5, 3.25]);
        assert_eq!(t.byte_len(), 12);
        assert_eq!(t.signature(), "f32[3]");
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::new(Dtype::F32, &[4], vec![0u8; 12]).is_err());
    }

    #[test]
    fn overflowing_shape_never_validates() {
        // the wrapped byte length must not coincidentally match the data
        assert!(Tensor::new(Dtype::F32, &[usize::MAX, 2], vec![0u8; 8]).is_err());
    }

    #[test]
    fn scalar_signature() {
        assert_eq!(Tensor::scalar_f32(2.0).signature(), "f32[]");
        assert_eq!(Tensor::scalar_f32(2.0).numel(), 1);
    }

    #[test]
    fn zeros_of_any_dtype() {
        let t = Tensor::zeros(Dtype::F64, &[3]);
        assert_eq!(t.byte_len(), 24);
        assert_eq!(t.signature(), "f64[3]");
        let t = Tensor::zeros(Dtype::I32, &[2, 2]);
        assert_eq!(t.byte_len(), 16);
        assert!(t.bytes().iter().all(|&b| b == 0));
    }

    #[test]
    fn f64_conversion_narrows() {
        let t = Tensor::from_f64_as_f32(&[1.5, 2.5], &[2]);
        assert_eq!(t.dtype(), Dtype::F32);
        assert_eq!(t.as_f32(), &[1.5f32, 2.5f32]);
    }

    #[test]
    fn mutation_through_view() {
        let mut t = Tensor::zeros_f32(&[2, 2]);
        t.as_f32_mut()[3] = 7.0;
        assert_eq!(t.as_f32(), &[0.0, 0.0, 0.0, 7.0]);
        assert_eq!(t.signature(), "f32[2,2]");
    }
}
