//! Blocking client for the framed serving protocol (`super::wire`).
//!
//! [`NetClient`] holds one TCP connection: connect performs the
//! HELLO/WELCOME handshake, then requests pipeline — [`NetClient::submit`]
//! writes a REQUEST without waiting, [`NetClient::recv`] reads the next
//! RESPONSE (the server answers in submission order). For the common
//! one-at-a-time case [`NetClient::features`] does both. A load
//! generator that wants the submit and receive sides on different
//! threads calls [`NetClient::split`].
//!
//! Typed failures cross the wire: a shed request comes back as
//! [`Error::Overloaded`], an expired one as [`Error::DeadlineExceeded`],
//! a device loss the service could not absorb as [`Error::DeviceLost`] —
//! the same variants in-process callers match on (`Error::from_wire`).

use std::io::{self, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::tracetransform::Image;
use crate::util::json::Json;

use super::wire::{self, Frame, Pixels, DEFAULT_MAX_FRAME, VERSION};

fn handshake(
    addr: &str,
    tenant: &str,
) -> Result<(io::BufReader<TcpStream>, io::BufWriter<TcpStream>, u32, u32)> {
    let stream = TcpStream::connect(addr)?;
    let _ = stream.set_nodelay(true);
    let read_half = stream.try_clone()?;
    let mut rd = io::BufReader::new(read_half);
    let mut wr = io::BufWriter::new(stream);
    wire::write_frame(&mut wr, &Frame::Hello { version: VERSION, tenant: tenant.to_string() })?;
    wr.flush()?;
    match wire::read_frame(&mut rd, DEFAULT_MAX_FRAME)? {
        Some(Frame::Welcome { version, max_frame, window }) => {
            if version != VERSION {
                return Err(Error::Protocol(format!(
                    "server speaks protocol version {version}, client speaks {VERSION}"
                )));
            }
            Ok((rd, wr, max_frame, window))
        }
        // A handshake refusal arrives as a typed error response on id 0.
        Some(Frame::Response { outcome: Err(failure), .. }) => Err(failure.into_error()),
        Some(_) => Err(Error::Protocol("expected WELCOME to answer HELLO".into())),
        None => Err(Error::Protocol("server closed during handshake".into())),
    }
}

/// The submit half after a [`NetClient::split`]: owns the write side.
pub struct NetSender {
    wr: io::BufWriter<TcpStream>,
    next_id: u64,
}

impl NetSender {
    fn send_request(&mut self, size: usize, pixels: Pixels, deadline_us: u64) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = Frame::Request { id, deadline_us, size: size as u32, pixels };
        wire::write_frame(&mut self.wr, &frame)?;
        self.wr.flush()?;
        Ok(id)
    }

    /// Write one f32 REQUEST and return its id without waiting.
    pub fn submit(&mut self, image: &Image, deadline_us: u64) -> Result<u64> {
        self.send_request(image.size(), Pixels::F32(image.pixels().to_vec()), deadline_us)
    }

    /// Write one quantized-u8 REQUEST (1 byte/pixel on the wire; the
    /// server reconstructs `v / 255` — not bitwise-faithful, for
    /// bandwidth-constrained clients).
    pub fn submit_u8(&mut self, size: usize, pixels: Vec<u8>, deadline_us: u64) -> Result<u64> {
        if pixels.len() != size * size {
            return Err(Error::Type(format!(
                "u8 image data length {} != {size}x{size}",
                pixels.len()
            )));
        }
        self.send_request(size, Pixels::U8(pixels), deadline_us)
    }

    /// Write a STATS probe and return its id; the snapshot arrives on
    /// the receive side as a [`Json`] in response order.
    pub fn submit_stats(&mut self) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        wire::write_frame(&mut self.wr, &Frame::Stats { id })?;
        self.wr.flush()?;
        Ok(id)
    }

    /// Announce a clean end of conversation (the server drains in-flight
    /// responses, the receive side then sees EOF after the last one).
    pub fn goodbye(mut self) -> Result<()> {
        wire::write_frame(&mut self.wr, &Frame::Goodbye)?;
        self.wr.flush()?;
        Ok(())
    }
}

/// What the receive side yields per frame: a resolved request or a
/// stats snapshot.
pub enum Received {
    /// `Response { id }`: the feature vector or the typed failure.
    Response(u64, Result<Vec<f32>>),
    /// `StatsReply { id }`: the parsed JSON snapshot.
    Stats(u64, Json),
}

/// The receive half after a [`NetClient::split`]: owns the read side.
pub struct NetReceiver {
    rd: io::BufReader<TcpStream>,
    max_frame: u32,
}

impl NetReceiver {
    /// Bound how long [`NetReceiver::recv`] blocks (`None` restores
    /// blocking reads). With a timeout set, a quiet socket surfaces as
    /// `Error::Io` with kind `WouldBlock`/`TimedOut` — load harnesses
    /// use this to detect lost tickets instead of hanging.
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.rd.get_ref().set_read_timeout(timeout)?;
        Ok(())
    }

    /// Read the next RESPONSE or STATS_REPLY. `Ok(None)` means the
    /// server closed cleanly at a frame boundary.
    pub fn recv(&mut self) -> Result<Option<Received>> {
        match wire::read_frame(&mut self.rd, self.max_frame)? {
            Some(Frame::Response { id, outcome }) => {
                Ok(Some(Received::Response(id, outcome.map_err(|f| f.into_error()))))
            }
            Some(Frame::StatsReply { id, json }) => {
                Ok(Some(Received::Stats(id, Json::parse(&json)?)))
            }
            Some(_) => Err(Error::Protocol("unexpected client-side frame from server".into())),
            None => Ok(None),
        }
    }
}

/// One connection to a [`NetServer`](super::NetServer); see the module
/// docs.
pub struct NetClient {
    tx: NetSender,
    rx: NetReceiver,
    window: u32,
}

impl NetClient {
    /// Connect and perform the HELLO/WELCOME handshake. Requests on this
    /// connection are accounted to `tenant` in the server's per-tenant
    /// serving stats.
    pub fn connect(addr: &str, tenant: &str) -> Result<NetClient> {
        let (rd, wr, max_frame, window) = handshake(addr, tenant)?;
        Ok(NetClient {
            tx: NetSender { wr, next_id: 1 },
            rx: NetReceiver { rd, max_frame },
            window,
        })
    }

    /// The in-flight window the server granted: responses it buffers
    /// before its reader stops pulling new requests off the socket.
    pub fn window(&self) -> u32 {
        self.window
    }

    /// Pipeline one request without waiting; returns its id.
    pub fn submit(&mut self, image: &Image, deadline_us: u64) -> Result<u64> {
        self.tx.submit(image, deadline_us)
    }

    /// Pipeline one quantized-u8 request without waiting; returns its id.
    pub fn submit_u8(&mut self, size: usize, pixels: Vec<u8>, deadline_us: u64) -> Result<u64> {
        self.tx.submit_u8(size, pixels, deadline_us)
    }

    /// Read the next response in submission order: `(id, outcome)`.
    pub fn recv(&mut self) -> Result<(u64, Result<Vec<f32>>)> {
        match self.rx.recv()? {
            Some(Received::Response(id, outcome)) => Ok((id, outcome)),
            Some(Received::Stats(..)) => {
                Err(Error::Protocol("stats reply arrived where a response was expected".into()))
            }
            None => Err(Error::Protocol("server closed with a response outstanding".into())),
        }
    }

    /// Submit one request and wait for its response — the remote
    /// equivalent of an in-process submit + `Ticket::wait`.
    pub fn features(&mut self, image: &Image, deadline_us: u64) -> Result<Vec<f32>> {
        let want = self.submit(image, deadline_us)?;
        let (id, outcome) = self.recv()?;
        if id != want {
            return Err(Error::Protocol(format!(
                "response id {id} does not match request id {want}"
            )));
        }
        outcome
    }

    /// Fetch the server's stats snapshot (per-tenant serving books,
    /// queue depth, device health — see `docs/wire.md`). Responses come
    /// in submission order, so call this with no requests in flight (or
    /// use [`NetClient::split`] and match ids).
    pub fn stats(&mut self) -> Result<Json> {
        let want = self.tx.submit_stats()?;
        match self.rx.recv()? {
            Some(Received::Stats(id, json)) if id == want => Ok(json),
            Some(Received::Stats(id, _)) => Err(Error::Protocol(format!(
                "stats reply id {id} does not match probe id {want}"
            ))),
            Some(Received::Response(..)) => {
                Err(Error::Protocol("response arrived where a stats reply was expected".into()))
            }
            None => Err(Error::Protocol("server closed with a stats probe outstanding".into())),
        }
    }

    /// Split into independent submit and receive halves (two socket
    /// handles over the one connection), for open-loop load generation.
    pub fn split(self) -> (NetSender, NetReceiver) {
        (self.tx, self.rx)
    }

    /// End the conversation cleanly.
    pub fn goodbye(self) -> Result<()> {
        self.tx.goodbye()
    }
}
