//! The versioned, length-prefixed binary wire format for networked
//! serving (see `docs/wire.md` for the layout diagrams).
//!
//! Every frame on the stream is
//!
//! ```text
//! [len: u32 LE] [type: u8] [payload: len-1 bytes]
//! ```
//!
//! where `len` counts the type byte plus the payload, so a frame costs
//! 4 bytes of framing. A connection opens with a client [`Frame::Hello`]
//! (magic + version + tenant) answered by a server [`Frame::Welcome`]
//! (negotiated limits); after that the client pipelines
//! [`Frame::Request`]s (and [`Frame::Stats`] probes) and the server
//! answers each with exactly one [`Frame::Response`] (or
//! [`Frame::StatsReply`]), in submission order. [`Frame::Goodbye`] ends
//! the conversation cleanly.
//!
//! All integers are little-endian. Malformed input — bad magic, a frame
//! longer than the negotiated cap, a payload that doesn't parse or has
//! trailing bytes, an unknown type — decodes to [`Error::Protocol`], so
//! transports can answer with a typed status (code 63) and close instead
//! of guessing. Failures travel as [`WireFailure`]: the stable
//! [`Error::wire_code`] plus two variant-specific numbers and the
//! Display text, reconstructed client-side by [`Error::from_wire`].

use std::io::{Read, Write};

use crate::error::{Error, Result};

/// First bytes of every conversation ("HLGW": hlgpu wire).
pub const MAGIC: [u8; 4] = *b"HLGW";
/// Protocol version carried in HELLO/WELCOME; bumped on layout changes.
pub const VERSION: u16 = 1;
/// Default cap on a single frame (header excluded). A 2048² f32 image is
/// 16 MiB, so this serves every benchmark size with headroom.
pub const DEFAULT_MAX_FRAME: u32 = 20 << 20;

const T_HELLO: u8 = 1;
const T_WELCOME: u8 = 2;
const T_REQUEST: u8 = 3;
const T_RESPONSE: u8 = 4;
const T_STATS: u8 = 5;
const T_STATS_REPLY: u8 = 6;
const T_GOODBYE: u8 = 7;

/// Request pixel payload: f32 (bitwise-exact, 4 bytes/px) or u8
/// (quantized, 1 byte/px — the client trades fidelity for bandwidth;
/// the server maps `v / 255` into the f32 pipeline).
#[derive(Clone, Debug, PartialEq)]
pub enum Pixels {
    F32(Vec<f32>),
    U8(Vec<u8>),
}

impl Pixels {
    /// Number of pixels carried.
    pub fn len(&self) -> usize {
        match self {
            Pixels::F32(v) => v.len(),
            Pixels::U8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The f32 pixels the pipeline runs on (u8 maps to `v / 255`).
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            Pixels::F32(v) => v.clone(),
            Pixels::U8(v) => v.iter().map(|&b| b as f32 / 255.0).collect(),
        }
    }
}

/// A failure crossing the wire: the stable numeric status (see
/// [`Error::wire_code`]), two variant-specific numbers, and the remote
/// Display text.
#[derive(Clone, Debug, PartialEq)]
pub struct WireFailure {
    pub code: u16,
    pub a: u64,
    pub b: u64,
    pub msg: String,
}

impl WireFailure {
    pub fn from_error(e: &Error) -> WireFailure {
        let (code, a, b, msg) = e.to_wire();
        WireFailure { code, a, b, msg }
    }

    pub fn into_error(self) -> Error {
        Error::from_wire(self.code, self.a, self.b, self.msg)
    }
}

/// One frame of the conversation.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Client opener: magic + version + the tenant this connection's
    /// requests are accounted to.
    Hello { version: u16, tenant: String },
    /// Server answer: the version it speaks, the frame-size cap it
    /// enforces, and the per-connection in-flight window it grants.
    Welcome { version: u16, max_frame: u32, window: u32 },
    /// One inference request: client-chosen id (echoed in the
    /// response), deadline budget in µs, square image dims and pixels.
    Request { id: u64, deadline_us: u64, size: u32, pixels: Pixels },
    /// The answer to `Request { id }`: the feature vector, or a typed
    /// failure.
    Response { id: u64, outcome: std::result::Result<Vec<f32>, WireFailure> },
    /// Control probe: ask for a JSON snapshot of serving + device stats.
    Stats { id: u64 },
    /// The answer to `Stats { id }`: a JSON document (see `docs/wire.md`).
    StatsReply { id: u64, json: String },
    /// Clean end of conversation; the server drains in-flight responses
    /// and closes.
    Goodbye,
}

// ------------------------------------------------------------ encoding --

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str16(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    let n = bytes.len().min(u16::MAX as usize);
    put_u16(out, n as u16);
    out.extend_from_slice(&bytes[..n]);
}

fn put_str32(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    let n = bytes.len().min(u32::MAX as usize);
    put_u32(out, n as u32);
    out.extend_from_slice(&bytes[..n]);
}

/// Encode a frame to its full wire bytes (length header included).
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut body = Vec::with_capacity(64);
    match frame {
        Frame::Hello { version, tenant } => {
            body.push(T_HELLO);
            body.extend_from_slice(&MAGIC);
            put_u16(&mut body, *version);
            put_str16(&mut body, tenant);
        }
        Frame::Welcome { version, max_frame, window } => {
            body.push(T_WELCOME);
            put_u16(&mut body, *version);
            put_u32(&mut body, *max_frame);
            put_u32(&mut body, *window);
        }
        Frame::Request { id, deadline_us, size, pixels } => {
            body.reserve(21 + pixels.len() * 4);
            body.push(T_REQUEST);
            put_u64(&mut body, *id);
            put_u64(&mut body, *deadline_us);
            put_u32(&mut body, *size);
            match pixels {
                Pixels::F32(v) => {
                    body.push(0);
                    for x in v {
                        body.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Pixels::U8(v) => {
                    body.push(1);
                    body.extend_from_slice(v);
                }
            }
        }
        Frame::Response { id, outcome } => {
            body.push(T_RESPONSE);
            put_u64(&mut body, *id);
            match outcome {
                Ok(feats) => {
                    body.reserve(6 + feats.len() * 4);
                    put_u16(&mut body, 0);
                    put_u32(&mut body, feats.len() as u32);
                    for x in feats {
                        body.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Err(f) => {
                    put_u16(&mut body, f.code.max(1));
                    put_u64(&mut body, f.a);
                    put_u64(&mut body, f.b);
                    put_str32(&mut body, &f.msg);
                }
            }
        }
        Frame::Stats { id } => {
            body.push(T_STATS);
            put_u64(&mut body, *id);
        }
        Frame::StatsReply { id, json } => {
            body.push(T_STATS_REPLY);
            put_u64(&mut body, *id);
            put_str32(&mut body, json);
        }
        Frame::Goodbye => body.push(T_GOODBYE),
    }
    let mut out = Vec::with_capacity(4 + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

/// Encode + write a frame. I/O failures surface as [`Error::Io`].
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    w.write_all(&encode(frame))?;
    Ok(())
}

// ------------------------------------------------------------ decoding --

/// Byte-cursor over a frame body; every getter fails with a typed
/// [`Error::Protocol`] on truncation.
struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Rd<'a> {
        Rd { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(Error::Protocol(format!(
                "truncated frame: wanted {n} bytes for {what}, {} left",
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32s(&mut self, count: usize, what: &str) -> Result<Vec<f32>> {
        let b = self.take(count * 4, what)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn str16(&mut self, what: &str) -> Result<String> {
        let n = self.u16(what)? as usize;
        let b = self.take(n, what)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| Error::Protocol(format!("{what} is not valid UTF-8")))
    }

    fn str32(&mut self, what: &str) -> Result<String> {
        let n = self.u32(what)? as usize;
        let b = self.take(n, what)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| Error::Protocol(format!("{what} is not valid UTF-8")))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn done(&self, what: &str) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::Protocol(format!(
                "{} trailing bytes after {what}",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Decode one frame body (the type byte plus payload — everything the
/// length header counted).
pub fn decode_body(body: &[u8]) -> Result<Frame> {
    let mut rd = Rd::new(body);
    let frame = match rd.u8("frame type")? {
        T_HELLO => {
            let magic = rd.take(4, "magic")?;
            if magic != MAGIC {
                return Err(Error::Protocol(format!(
                    "bad magic {magic:02x?} (expected {MAGIC:02x?} — not an hlgpu client?)"
                )));
            }
            let version = rd.u16("version")?;
            let tenant = rd.str16("tenant")?;
            Frame::Hello { version, tenant }
        }
        T_WELCOME => {
            let version = rd.u16("version")?;
            let max_frame = rd.u32("max_frame")?;
            let window = rd.u32("window")?;
            Frame::Welcome { version, max_frame, window }
        }
        T_REQUEST => {
            let id = rd.u64("request id")?;
            let deadline_us = rd.u64("deadline")?;
            let size = rd.u32("image size")?;
            let dtype = rd.u8("pixel dtype")?;
            if size == 0 {
                return Err(Error::Protocol("zero image size".into()));
            }
            let npix = size as u64 * size as u64;
            let expect = match dtype {
                0 => npix * 4,
                1 => npix,
                other => {
                    return Err(Error::Protocol(format!("unknown pixel dtype {other}")));
                }
            };
            if rd.remaining() as u64 != expect {
                return Err(Error::Protocol(format!(
                    "pixel payload is {} bytes, {size}x{size} dtype {dtype} needs {expect}",
                    rd.remaining()
                )));
            }
            let pixels = match dtype {
                0 => Pixels::F32(rd.f32s(npix as usize, "pixels")?),
                _ => Pixels::U8(rd.take(npix as usize, "pixels")?.to_vec()),
            };
            Frame::Request { id, deadline_us, size, pixels }
        }
        T_RESPONSE => {
            let id = rd.u64("response id")?;
            let code = rd.u16("status code")?;
            let outcome = if code == 0 {
                let count = rd.u32("feature count")? as usize;
                if rd.remaining() != count * 4 {
                    return Err(Error::Protocol(format!(
                        "feature payload is {} bytes, count {count} needs {}",
                        rd.remaining(),
                        count * 4
                    )));
                }
                Ok(rd.f32s(count, "features")?)
            } else {
                let a = rd.u64("status detail a")?;
                let b = rd.u64("status detail b")?;
                let msg = rd.str32("status message")?;
                Err(WireFailure { code, a, b, msg })
            };
            Frame::Response { id, outcome }
        }
        T_STATS => Frame::Stats { id: rd.u64("stats id")? },
        T_STATS_REPLY => {
            let id = rd.u64("stats id")?;
            let json = rd.str32("stats json")?;
            Frame::StatsReply { id, json }
        }
        T_GOODBYE => Frame::Goodbye,
        other => {
            return Err(Error::Protocol(format!("unknown frame type {other}")));
        }
    };
    rd.done("frame")?;
    Ok(frame)
}

/// Read one frame off the stream. Returns `Ok(None)` on a clean EOF at
/// a frame boundary (the peer closed); mid-frame EOF, an oversized or
/// empty length header, and every malformed body decode to
/// [`Error::Protocol`]; transport trouble surfaces as [`Error::Io`].
pub fn read_frame(r: &mut impl Read, max_frame: u32) -> Result<Option<Frame>> {
    let mut header = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(Error::Protocol(format!(
                    "connection closed mid-header ({got}/4 bytes)"
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::Io(e)),
        }
    }
    let len = u32::from_le_bytes(header);
    if len == 0 {
        return Err(Error::Protocol("empty frame (length 0)".into()));
    }
    if len > max_frame {
        return Err(Error::Protocol(format!(
            "oversized frame: {len} bytes exceeds the {max_frame}-byte cap"
        )));
    }
    let mut body = vec![0u8; len as usize];
    if let Err(e) = r.read_exact(&mut body) {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            return Err(Error::Protocol(format!("connection closed mid-frame ({len} bytes)")));
        }
        return Err(Error::Io(e));
    }
    decode_body(&body).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(frame: Frame) {
        let bytes = encode(&frame);
        let back = read_frame(&mut Cursor::new(&bytes), DEFAULT_MAX_FRAME)
            .unwrap()
            .expect("one frame");
        assert_eq!(back, frame);
    }

    #[test]
    fn every_frame_roundtrips() {
        roundtrip(Frame::Hello { version: VERSION, tenant: "tenant-α".into() });
        roundtrip(Frame::Welcome { version: VERSION, max_frame: 1 << 20, window: 32 });
        roundtrip(Frame::Request {
            id: 7,
            deadline_us: 100_000,
            size: 2,
            pixels: Pixels::F32(vec![0.0, 0.25, 0.5, 1.0]),
        });
        roundtrip(Frame::Request {
            id: 8,
            deadline_us: 0,
            size: 2,
            pixels: Pixels::U8(vec![0, 64, 128, 255]),
        });
        roundtrip(Frame::Response { id: 7, outcome: Ok(vec![1.5, -2.5]) });
        roundtrip(Frame::Response {
            id: 9,
            outcome: Err(WireFailure { code: 51, a: 64, b: 64, msg: "overloaded".into() }),
        });
        roundtrip(Frame::Stats { id: 1 });
        roundtrip(Frame::StatsReply { id: 1, json: "{\"queue_depth\":0}".into() });
        roundtrip(Frame::Goodbye);
    }

    #[test]
    fn back_to_back_frames_parse_in_order() {
        let mut bytes = encode(&Frame::Stats { id: 1 });
        bytes.extend(encode(&Frame::Goodbye));
        let mut cur = Cursor::new(&bytes);
        assert_eq!(read_frame(&mut cur, 1024).unwrap(), Some(Frame::Stats { id: 1 }));
        assert_eq!(read_frame(&mut cur, 1024).unwrap(), Some(Frame::Goodbye));
        assert_eq!(read_frame(&mut cur, 1024).unwrap(), None, "clean EOF at the boundary");
    }

    #[test]
    fn u8_pixels_map_to_unit_range() {
        let p = Pixels::U8(vec![0, 255]);
        assert_eq!(p.to_f32(), vec![0.0, 1.0]);
        assert_eq!(p.len(), 2);
        assert!(!p.is_empty());
    }

    fn expect_protocol(bytes: &[u8]) -> Error {
        let err = read_frame(&mut Cursor::new(bytes), 1024).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "wanted Protocol, got {err:?}");
        err
    }

    #[test]
    fn malformed_streams_get_typed_protocol_errors() {
        // Truncated header.
        expect_protocol(&[1, 0]);
        // Empty frame.
        expect_protocol(&0u32.to_le_bytes());
        // Oversized: ASCII text reads as a huge little-endian length.
        let err = expect_protocol(b"GET / HTTP/1.1\r\n");
        assert!(err.to_string().contains("oversized"), "{err}");
        // Truncated body.
        let mut bytes = encode(&Frame::Stats { id: 1 });
        bytes.truncate(bytes.len() - 2);
        expect_protocol(&bytes);
        // Unknown frame type.
        expect_protocol(&[2, 0, 0, 0, 0xEE, 0]);
        // Bad magic in HELLO.
        let mut hello = encode(&Frame::Hello { version: VERSION, tenant: "t".into() });
        hello[5] = b'X'; // first magic byte (after len header + type)
        let err = expect_protocol(&hello);
        assert!(err.to_string().contains("magic"), "{err}");
        // Trailing junk after a valid payload.
        let mut stats = encode(&Frame::Stats { id: 1 });
        stats.extend_from_slice(&[0, 0]);
        let fixed = (stats.len() - 4) as u32;
        stats[..4].copy_from_slice(&fixed.to_le_bytes());
        let err = expect_protocol(&stats);
        assert!(err.to_string().contains("trailing"), "{err}");
        // Pixel payload / dims mismatch.
        let mut req = encode(&Frame::Request {
            id: 1,
            deadline_us: 1,
            size: 2,
            pixels: Pixels::U8(vec![1, 2, 3, 4]),
        });
        let short = (req.len() - 4 - 1) as u32;
        req.truncate(req.len() - 1);
        req[..4].copy_from_slice(&short.to_le_bytes());
        let err = expect_protocol(&req);
        assert!(err.to_string().contains("pixel payload"), "{err}");
        // All of these carry the Protocol wire code.
        assert_eq!(err.wire_code(), 63);
        assert_eq!(err.status(), "ERROR_PROTOCOL");
    }

    #[test]
    fn failures_cross_the_wire_typed() {
        let e = Error::Overloaded { depth: 64, capacity: 64 };
        let f = WireFailure::from_error(&e);
        assert_eq!(f.code, e.wire_code());
        let back = f.into_error();
        assert!(matches!(back, Error::Overloaded { depth: 64, capacity: 64 }), "{back:?}");
    }
}
