//! The std-only TCP front door for [`Service`](crate::serve::Service).
//!
//! [`NetServer::bind`] wraps an existing service and listens on a TCP
//! address. The accept loop is a blocking thread; each connection gets a
//! **reader/writer thread pair** joined by a bounded channel of
//! [`NetConfig::window`] slots:
//!
//! * the **reader** decodes frames (`super::wire`), maps each REQUEST
//!   into the service's admission queue under the connection's tenant
//!   (the HELLO tenant feeds the per-tenant
//!   [`ServeStats`](crate::serve::ServeStats) books and deadline
//!   machinery unchanged), and pushes the resulting ticket into the
//!   channel. When `window` responses are in flight the push **blocks**,
//!   which stops reading, which fills the kernel socket buffers, which
//!   stalls the client's writes — backpressure for free, no frame is
//!   ever dropped.
//! * the **writer** pops tickets in FIFO order, waits each one, and
//!   writes the RESPONSE (or STATS_REPLY). A client that disconnects
//!   mid-batch breaks the socket but not the drain: the writer keeps
//!   popping and waiting tickets so every server-side ticket resolves
//!   and the stats books stay exact.
//!
//! Shutdown is a graceful drain: stop accepting, let every reader stop
//! at its next frame boundary (mid-frame reads get
//! [`NetConfig::drain_grace_ms`] to finish), let the writers flush every
//! in-flight response *while the service workers are still running*,
//! then [`Service::drain`] the service itself. Tickets admitted over the
//! network are never stranded.

use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::serve::Service;
use crate::tracetransform::Image;
use crate::util::json::Json;

use super::wire::{self, Frame, WireFailure, DEFAULT_MAX_FRAME, VERSION};

/// Tuning knobs for a [`NetServer`].
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Largest frame accepted from a client (bytes, header excluded);
    /// advertised in WELCOME. Oversized frames get a typed protocol
    /// error and the connection closes.
    pub max_frame: u32,
    /// Per-connection in-flight window: responses admitted but not yet
    /// written. The reader blocks once the window is full (see the
    /// module docs); advertised in WELCOME.
    pub window: usize,
    /// Reader poll interval (ms): how often a blocked read re-checks
    /// the shutdown flag. Bounds shutdown latency, not throughput.
    pub poll_ms: u64,
    /// On shutdown, how long a reader mid-frame may keep reading before
    /// the connection is cut (ms).
    pub drain_grace_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { max_frame: DEFAULT_MAX_FRAME, window: 32, poll_ms: 20, drain_grace_ms: 2000 }
    }
}

/// What the reader hands the writer, in FIFO order.
enum Outgoing {
    /// An admitted request: the client's id and the service ticket.
    Pending(u64, crate::serve::Ticket),
    /// A request that never reached the queue (shed, bad image, shut
    /// down) or a protocol failure to report before closing.
    Done(u64, Error),
    /// A STATS probe to answer with the JSON snapshot.
    Stats(u64),
}

/// A running TCP front door; dropping (or [`NetServer::shutdown`])
/// drains connections and the service.
pub struct NetServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    service: Arc<Service>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `service` over it.
    pub fn bind(addr: &str, service: Service, config: NetConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let service = Arc::new(service);
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let service = service.clone();
            let shutdown = shutdown.clone();
            let conns = conns.clone();
            std::thread::spawn(move || {
                accept_loop(listener, service, config, shutdown, conns)
            })
        };
        Ok(NetServer { addr, shutdown, accept: Some(accept), conns, service })
    }

    /// The bound address (with the resolved port when bound to `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The service behind the front door, for in-process inspection
    /// (stats, queue depth) while remote clients drive it.
    pub fn service(&self) -> &Arc<Service> {
        &self.service
    }

    /// Graceful drain: stop accepting, finish every in-flight response,
    /// then drain the service (see the module docs for the ordering).
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // The accept loop blocks in `accept`; a throwaway connection
        // wakes it to observe the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        // Only now — with every network ticket resolved and written —
        // drain the workers.
        self.service.drain();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: TcpListener,
    service: Arc<Service>,
    config: NetConfig,
    shutdown: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if shutdown.load(Ordering::SeqCst) {
            // The wake-up connection from `stop`, or a client racing the
            // shutdown: either way, refuse by closing.
            return;
        }
        let service = service.clone();
        let config = config.clone();
        let flag = shutdown.clone();
        let handle = std::thread::spawn(move || {
            handle_conn(stream, service, config, flag);
        });
        let mut guard = conns.lock().unwrap();
        // Reap finished connections so a long-lived server does not
        // accumulate handles.
        guard.retain(|h| !h.is_finished());
        guard.push(handle);
    }
}

/// `Read` over a `TcpStream` with a poll timeout, so a blocked reader
/// notices the shutdown flag. Returns EOF (`Ok(0)`) when shutdown
/// arrives **at a frame boundary** (no bytes consumed since
/// [`PollReader::begin_frame`]) — `wire::read_frame` turns that into a
/// clean `Ok(None)`. Mid-frame, the peer gets `drain_grace_ms` to finish
/// the frame before the read times out for real.
struct PollReader<'a> {
    stream: &'a TcpStream,
    shutdown: &'a AtomicBool,
    grace: Duration,
    mid_frame: bool,
    cut_at: Option<Instant>,
}

impl<'a> PollReader<'a> {
    fn new(stream: &'a TcpStream, shutdown: &'a AtomicBool, grace: Duration) -> PollReader<'a> {
        PollReader { stream, shutdown, grace, mid_frame: false, cut_at: None }
    }

    /// Mark a frame boundary: a shutdown observed from here until the
    /// first byte of the next frame reads as clean EOF.
    fn begin_frame(&mut self) {
        self.mid_frame = false;
    }
}

impl Read for PollReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        loop {
            match self.stream.read(buf) {
                Ok(n) => {
                    if n > 0 {
                        self.mid_frame = true;
                    }
                    return Ok(n);
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                    ) =>
                {
                    if !self.shutdown.load(Ordering::SeqCst) {
                        continue;
                    }
                    if !self.mid_frame {
                        return Ok(0);
                    }
                    let cut = *self.cut_at.get_or_insert_with(|| Instant::now() + self.grace);
                    if Instant::now() >= cut {
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "drain grace expired mid-frame",
                        ));
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    service: Arc<Service>,
    config: NetConfig,
    shutdown: Arc<AtomicBool>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(config.poll_ms.max(1))));
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let grace = Duration::from_millis(config.drain_grace_ms);
    let mut reader = PollReader::new(&stream, &shutdown, grace);

    // Handshake: exactly one HELLO, answered by WELCOME (or a typed
    // error response and close).
    let mut hello_err = None;
    let tenant = match wire::read_frame(&mut reader, config.max_frame) {
        Ok(Some(Frame::Hello { version, tenant })) if version == VERSION => {
            if tenant.is_empty() {
                "anonymous".to_string()
            } else {
                tenant
            }
        }
        Ok(Some(Frame::Hello { version, .. })) => {
            hello_err = Some(Error::Protocol(format!(
                "unsupported protocol version {version} (server speaks {VERSION})"
            )));
            String::new()
        }
        Ok(Some(_)) => {
            hello_err = Some(Error::Protocol("expected HELLO as the first frame".into()));
            String::new()
        }
        Ok(None) => return,
        Err(e @ Error::Protocol(_)) => {
            hello_err = Some(e);
            String::new()
        }
        Err(_) => return,
    };
    let mut writer = io::BufWriter::new(write_half);
    if let Some(e) = hello_err {
        let frame =
            Frame::Response { id: 0, outcome: Err(WireFailure::from_error(&e)) };
        let _ = wire::write_frame(&mut writer, &frame);
        let _ = writer.flush();
        return;
    }
    let welcome = Frame::Welcome {
        version: VERSION,
        max_frame: config.max_frame,
        window: config.window as u32,
    };
    let welcomed = wire::write_frame(&mut writer, &welcome)
        .and_then(|()| writer.flush().map_err(Error::Io));
    if welcomed.is_err() {
        return;
    }

    // Reader/writer pair joined by the bounded in-flight window.
    let (tx, rx) = mpsc::sync_channel::<Outgoing>(config.window.max(1));
    let writer_service = service.clone();
    let writer_handle =
        std::thread::spawn(move || writer_loop(writer, rx, writer_service));
    reader_loop(&mut reader, tx, &service, &tenant, config.max_frame);
    // Dropping our sender ends the writer's queue; it drains every
    // in-flight ticket before exiting.
    let _ = writer_handle.join();
}

fn reader_loop(
    reader: &mut PollReader<'_>,
    tx: SyncSender<Outgoing>,
    service: &Service,
    tenant: &str,
    max_frame: u32,
) {
    loop {
        reader.begin_frame();
        let out = match wire::read_frame(reader, max_frame) {
            Ok(Some(Frame::Request { id, deadline_us, size, pixels })) => {
                match Image::new(size as usize, pixels.to_f32()) {
                    Ok(image) => match service.submit_with_deadline(tenant, image, deadline_us) {
                        Ok(ticket) => Outgoing::Pending(id, ticket),
                        Err(e) => Outgoing::Done(id, e),
                    },
                    Err(e) => Outgoing::Done(id, e),
                }
            }
            Ok(Some(Frame::Stats { id })) => Outgoing::Stats(id),
            Ok(Some(Frame::Goodbye)) | Ok(None) => return,
            Ok(Some(_)) => {
                // A server-to-client frame arriving here is a protocol
                // violation: report on id 0 and close.
                let e = Error::Protocol("unexpected server-side frame from client".into());
                let _ = tx.send(Outgoing::Done(0, e));
                return;
            }
            Err(e @ Error::Protocol(_)) => {
                let _ = tx.send(Outgoing::Done(0, e));
                return;
            }
            Err(_) => return,
        };
        // Blocking send: this is the in-flight window's backpressure.
        if tx.send(out).is_err() {
            return;
        }
    }
}

fn writer_loop(
    mut writer: io::BufWriter<TcpStream>,
    rx: Receiver<Outgoing>,
    service: Arc<Service>,
) {
    // After a socket write fails (client gone) we stop writing but keep
    // draining: every ticket still resolves, so the service's books
    // (served/expired/failed) stay exact — nothing leaks.
    let mut broken = false;
    for out in rx {
        let frame = match out {
            Outgoing::Pending(id, ticket) => {
                let (_, res) = ticket.wait_timed();
                Frame::Response { id, outcome: res.map_err(|e| WireFailure::from_error(&e)) }
            }
            Outgoing::Done(id, e) => {
                Frame::Response { id, outcome: Err(WireFailure::from_error(&e)) }
            }
            Outgoing::Stats(id) => {
                Frame::StatsReply { id, json: stats_snapshot(&service).to_string() }
            }
        };
        if !broken {
            let sent = wire::write_frame(&mut writer, &frame)
                .and_then(|()| writer.flush().map_err(Error::Io));
            broken = sent.is_err();
        }
    }
}

/// The STATS snapshot: the service's per-tenant books, queue depth and
/// config, plus per-member `DeviceSet` health when the service runs on
/// a set. Schema documented in `docs/wire.md`.
fn stats_snapshot(service: &Service) -> Json {
    fn num(n: u64) -> Json {
        Json::Num(n as f64)
    }
    let mut root = BTreeMap::new();
    root.insert("queue_depth".to_string(), num(service.queue_depth() as u64));
    let mut tenants = BTreeMap::new();
    for (name, s) in service.all_stats() {
        let mut t = BTreeMap::new();
        t.insert("admitted".to_string(), num(s.admitted));
        t.insert("served".to_string(), num(s.served));
        t.insert("rejected".to_string(), num(s.rejected));
        t.insert("expired".to_string(), num(s.expired));
        t.insert("failed".to_string(), num(s.failed));
        t.insert("retried".to_string(), num(s.retried));
        t.insert("failed_over".to_string(), num(s.failed_over));
        let mut batches = BTreeMap::new();
        for (label, count) in
            crate::serve::BatchHistogram::LABELS.iter().zip(s.batches.counts())
        {
            if count > 0 {
                batches.insert(label.to_string(), num(count));
            }
        }
        t.insert("batches".to_string(), Json::Obj(batches));
        tenants.insert(name, Json::Obj(t));
    }
    root.insert("tenants".to_string(), Json::Obj(tenants));
    if let Some(set) = service.device_set() {
        let members = set
            .stats()
            .iter()
            .map(|m| {
                let mut d = BTreeMap::new();
                d.insert("ordinal".to_string(), num(m.ordinal as u64));
                d.insert("shards".to_string(), num(m.shards));
                d.insert("images".to_string(), num(m.images));
                d.insert("outstanding".to_string(), num(m.outstanding));
                d.insert("busy_ms".to_string(), Json::Num(m.busy_ns as f64 / 1e6));
                d.insert(
                    "health".to_string(),
                    Json::Str(format!("{:?}", m.health).to_lowercase()),
                );
                Json::Obj(d)
            })
            .collect();
        root.insert("devices".to_string(), Json::Arr(members));
    }
    let c = service.config();
    let mut config = BTreeMap::new();
    config.insert("max_batch".to_string(), num(c.max_batch as u64));
    config.insert("max_delay_us".to_string(), num(c.max_delay_us));
    config.insert("queue_capacity".to_string(), num(c.queue_capacity as u64));
    config.insert("default_deadline_us".to_string(), num(c.default_deadline_us));
    config.insert("workers".to_string(), num(c.workers as u64));
    root.insert("config".to_string(), Json::Obj(config));
    Json::Obj(root)
}
