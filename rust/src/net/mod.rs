//! Networked serving: the process boundary for `hlgpu::serve`.
//!
//! Three layers (see `docs/wire.md` for the protocol reference):
//!
//! * [`wire`] — the versioned, length-prefixed binary frame format:
//!   HELLO/WELCOME handshake, pipelined REQUEST/RESPONSE, the STATS
//!   control probe, typed failures carrying the stable
//!   [`Error::wire_code`](crate::error::Error::wire_code) status table.
//! * [`server`] — [`NetServer`], a std-only blocking TCP front door: an
//!   accept loop, one reader/writer thread pair per connection, a
//!   bounded in-flight window for backpressure, graceful drain on
//!   shutdown. Each connection maps onto a tenant in the service's
//!   per-tenant stats and deadline machinery; the admission queue and
//!   batching below it are unchanged.
//! * [`client`] — [`NetClient`], the matching blocking client; splits
//!   into submit/receive halves for open-loop load generation
//!   (`benches/serve_load.rs` with `SL_REMOTE=1` drives a loopback
//!   server and reports the same table as the in-process run, so the
//!   network tax is directly measurable).
//!
//! Everything rides the standard library: no async runtime, no serde,
//! no protocol dependencies — the same offline-first constraint as the
//! rest of the crate.

pub mod client;
pub mod server;
pub mod wire;

pub use client::{NetClient, NetReceiver, NetSender, Received};
pub use server::{NetConfig, NetServer};
pub use wire::{Frame, Pixels, WireFailure, DEFAULT_MAX_FRAME, MAGIC, VERSION};
