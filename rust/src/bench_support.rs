//! Shared benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations, log-normal summaries per the paper's §7.2
//! methodology, and table rendering helpers used by all `benches/` mains.

use std::time::Instant;

use crate::stats::{lognormal_fit, LogNormalSummary};

/// Measurement settings.
#[derive(Clone, Copy, Debug)]
pub struct Settings {
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

impl Default for Settings {
    fn default() -> Self {
        Settings { warmup_iters: 3, sample_iters: 10 }
    }
}

impl Settings {
    /// Scale the iteration counts from CLI overrides.
    pub fn from_cli(args: &crate::util::cli::Args) -> Self {
        Settings {
            warmup_iters: args.opt_usize("warmup", 3),
            sample_iters: args.opt_usize("iters", 10),
        }
    }
}

/// Time `f` repeatedly: warmup discarded (the paper discards initial
/// warm-up iterations), then `sample_iters` timed runs fitted log-normal.
pub fn measure<R>(settings: Settings, mut f: impl FnMut() -> R) -> LogNormalSummary {
    for _ in 0..settings.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(settings.sample_iters);
    for _ in 0..settings.sample_iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    lognormal_fit(&samples)
}

/// Time one single execution (init-time measurements, Table 1).
pub fn measure_once<R>(f: impl FnOnce() -> R) -> (f64, R) {
    let t0 = Instant::now();
    let r = f();
    (t0.elapsed().as_secs_f64(), r)
}

/// Render a results table with fixed-width columns.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.3} s")
    } else if seconds >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else {
        format!("{:.1} µs", seconds * 1e6)
    }
}

/// Format a summary as `mean ±unc%`.
pub fn fmt_summary(s: &LogNormalSummary) -> String {
    format!("{} ±{:.2}%", fmt_time(s.mean), s.rel_uncertainty_pct())
}

/// Format a speedup of `base` over `other` as `N.NNx` (used by the
/// scheduler benches: sequential time / parallel time).
pub fn fmt_speedup(base_seconds: f64, other_seconds: f64) -> String {
    if other_seconds <= 0.0 {
        return "inf".into();
    }
    format!("{:.2}x", base_seconds / other_seconds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_positive_mean() {
        let s = measure(Settings { warmup_iters: 1, sample_iters: 5 }, || {
            std::hint::black_box((0..1000).sum::<u64>())
        });
        assert!(s.mean > 0.0);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["impl", "time"]);
        t.row(&["cpu".into(), "1.0 s".into()]);
        t.row(&["gpu-auto".into(), "0.5 s".into()]);
        let s = t.render();
        assert!(s.contains("impl"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn time_formatting_units() {
        assert!(fmt_time(2.5).ends_with(" s"));
        assert!(fmt_time(0.0025).ends_with(" ms"));
        assert!(fmt_time(0.0000025).ends_with(" µs"));
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(fmt_speedup(4.0, 2.0), "2.00x");
        assert_eq!(fmt_speedup(1.0, 0.0), "inf");
    }
}
