//! Argument wrappers: `CuIn` / `CuOut` / `CuInOut` (paper §6.3), plus
//! the v2 **device-resident** modes (`cu_dev` / `cu_dev_mut`).
//!
//! By wrapping arguments, the developer tells the framework which
//! transfers are actually needed; the specialization step turns this into
//! a fixed transfer plan so the steady-state launch does no analysis work
//! and moves no unnecessary bytes. Device-resident arguments go one step
//! further: the data already lives on the device (a
//! [`DeviceArray`]), so the plan skips the host↔device copies entirely —
//! chained kernels hand buffers to each other without touching the host
//! (`LaunchMetrics::skipped_h2d` / `skipped_d2h` count the avoided
//! transfers).

use crate::coordinator::devarray::DeviceArray;
use crate::driver::DevicePtr;
use crate::tensor::{Dtype, Tensor};

/// Transfer direction of one kernel argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgMode {
    /// Uploaded before launch; never downloaded (`CuIn`).
    In,
    /// Allocated on device; downloaded after launch (`CuOut`).
    Out,
    /// Uploaded and downloaded (`CuInOut`).
    InOut,
    /// Direction unknown at the call site — the framework infers it at
    /// specialization time (from the VTX kernel body's load/store
    /// dataflow, or from the artifact's input/output split). This is the
    /// paper's §9 future-work item, implemented.
    Auto,
}

impl ArgMode {
    pub fn uploads(self) -> bool {
        matches!(self, ArgMode::In | ArgMode::InOut)
    }

    pub fn downloads(self) -> bool {
        matches!(self, ArgMode::Out | ArgMode::InOut)
    }

    pub fn is_auto(self) -> bool {
        matches!(self, ArgMode::Auto)
    }
}

enum Payload<'a> {
    Shared(&'a Tensor),
    Mut(&'a mut Tensor),
    /// Read-only device-resident array: no transfers either way.
    Dev(&'a DeviceArray),
    /// Read-write device-resident array: the kernel mutates it in place,
    /// results stay on device.
    DevMut(&'a mut DeviceArray),
}

/// One wrapped kernel argument.
pub struct Arg<'a> {
    mode: ArgMode,
    payload: Payload<'a>,
}

impl<'a> Arg<'a> {
    pub fn mode(&self) -> ArgMode {
        self.mode
    }

    /// True for `cu_dev` / `cu_dev_mut` arguments: the data lives on the
    /// device and the transfer plan must not copy it.
    pub fn is_device(&self) -> bool {
        matches!(self.payload, Payload::Dev(_) | Payload::DevMut(_))
    }

    /// The device pointer of a device-resident argument.
    pub fn device_ptr(&self) -> Option<DevicePtr> {
        match &self.payload {
            Payload::Dev(d) => Some(d.ptr()),
            Payload::DevMut(d) => Some(d.ptr()),
            _ => None,
        }
    }

    /// The owning context of a device-resident argument (used to reject
    /// arrays from a different context at specialization time).
    pub(crate) fn device_context(&self) -> Option<&crate::driver::Context> {
        match &self.payload {
            Payload::Dev(d) => Some(d.context()),
            Payload::DevMut(d) => Some(d.context()),
            _ => None,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match &self.payload {
            Payload::Shared(t) => t.dtype(),
            Payload::Mut(t) => t.dtype(),
            Payload::Dev(d) => d.dtype(),
            Payload::DevMut(d) => d.dtype(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match &self.payload {
            Payload::Shared(t) => t.shape(),
            Payload::Mut(t) => t.shape(),
            Payload::Dev(d) => d.shape(),
            Payload::DevMut(d) => d.shape(),
        }
    }

    pub fn byte_len(&self) -> usize {
        match &self.payload {
            Payload::Shared(t) => t.byte_len(),
            Payload::Mut(t) => t.byte_len(),
            Payload::Dev(d) => d.byte_len(),
            Payload::DevMut(d) => d.byte_len(),
        }
    }

    /// Host-side view of the argument, when there is one (`None` for
    /// device-resident arguments).
    pub(crate) fn host_tensor(&self) -> Option<&Tensor> {
        match &self.payload {
            Payload::Shared(t) => Some(t),
            Payload::Mut(t) => Some(t),
            _ => None,
        }
    }

    pub(crate) fn host_tensor_mut(&mut self) -> Option<&mut Tensor> {
        match &mut self.payload {
            Payload::Mut(t) => Some(t),
            _ => None,
        }
    }

    /// Signature fragment of this argument (`f32[128,128]`), residency
    /// excluded — artifact manifests key on the plain type shape.
    pub fn signature(&self) -> String {
        use std::fmt::Write;
        let mut out = String::with_capacity(16);
        out.push_str(self.dtype().name());
        out.push('[');
        for (d, dim) in self.shape().iter().enumerate() {
            if d > 0 {
                out.push(',');
            }
            let _ = write!(out, "{dim}");
        }
        out.push(']');
        out
    }
}

/// `CuIn(x)`: read-only input.
pub fn cu_in(t: &Tensor) -> Arg<'_> {
    Arg { mode: ArgMode::In, payload: Payload::Shared(t) }
}

/// `CuOut(x)`: output container; contents before launch are ignored.
pub fn cu_out(t: &mut Tensor) -> Arg<'_> {
    Arg { mode: ArgMode::Out, payload: Payload::Mut(t) }
}

/// `CuInOut(x)`: read-write.
pub fn cu_inout(t: &mut Tensor) -> Arg<'_> {
    Arg { mode: ArgMode::InOut, payload: Payload::Mut(t) }
}

/// Unwrapped argument: direction inferred by the framework at
/// specialization time (§9 future work, implemented). Requires `&mut`
/// because the inference may classify it as an output.
pub fn cu_auto(t: &mut Tensor) -> Arg<'_> {
    Arg { mode: ArgMode::Auto, payload: Payload::Mut(t) }
}

/// Device-resident read-only argument (launch API v2): the kernel reads
/// the [`DeviceArray`] in place — no upload, no download.
pub fn cu_dev(d: &DeviceArray) -> Arg<'_> {
    Arg { mode: ArgMode::In, payload: Payload::Dev(d) }
}

/// Device-resident read-write argument (launch API v2): the kernel
/// mutates the [`DeviceArray`] in place and the result stays on device —
/// download it explicitly when (and if) the host needs it.
pub fn cu_dev_mut(d: &mut DeviceArray) -> Arg<'_> {
    Arg { mode: ArgMode::InOut, payload: Payload::DevMut(d) }
}

/// Call-site signature over all arguments — the specialization cache key
/// (the analog of the Julia method-cache key: the tuple of argument
/// types, §6.2). Includes modes and residency:
/// `in:f32[12];dev.in:f32[12];out:f32[12]` — a device-resident call
/// specializes separately from its host-round-trip twin because the
/// transfer plans differ.
pub fn call_signature(args: &[Arg<'_>]) -> String {
    let mut out = String::with_capacity(24 * args.len());
    write_call_signature(&mut out, args);
    out
}

/// Allocation-lean signature writer used on the warm launch path (§Perf
/// iteration I3): one pre-sized String, no intermediate Vec/format calls.
pub fn write_call_signature(out: &mut String, args: &[Arg<'_>]) {
    use std::fmt::Write;
    for (i, a) in args.iter().enumerate() {
        if i > 0 {
            out.push(';');
        }
        if a.is_device() {
            out.push_str("dev.");
        }
        out.push_str(match a.mode() {
            ArgMode::In => "in:",
            ArgMode::Out => "out:",
            ArgMode::InOut => "inout:",
            ArgMode::Auto => "auto:",
        });
        out.push_str(a.dtype().name());
        out.push('[');
        for (d, dim) in a.shape().iter().enumerate() {
            if d > 0 {
                out.push(',');
            }
            let _ = write!(out, "{dim}");
        }
        out.push(']');
    }
}

/// Input-only signature (what the artifact manifest keys on).
pub fn input_signature(args: &[Arg<'_>]) -> String {
    args.iter()
        .filter(|a| a.mode().uploads())
        .map(|a| a.signature())
        .collect::<Vec<_>>()
        .join(";")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modes_transfer_flags() {
        assert!(ArgMode::In.uploads() && !ArgMode::In.downloads());
        assert!(!ArgMode::Out.uploads() && ArgMode::Out.downloads());
        assert!(ArgMode::InOut.uploads() && ArgMode::InOut.downloads());
    }

    #[test]
    fn signatures() {
        let a = Tensor::from_f32(&[1.0; 12], &[12]);
        let b = Tensor::from_f32(&[2.0; 12], &[12]);
        let mut c = Tensor::zeros_f32(&[12]);
        let args = [cu_in(&a), cu_in(&b), cu_out(&mut c)];
        assert_eq!(call_signature(&args), "in:f32[12];in:f32[12];out:f32[12]");
        assert_eq!(input_signature(&args), "f32[12];f32[12]");
    }

    #[test]
    fn out_args_expose_mut_tensor() {
        let mut c = Tensor::zeros_f32(&[2]);
        let mut arg = cu_out(&mut c);
        assert!(arg.host_tensor_mut().is_some());
        let a = Tensor::zeros_f32(&[2]);
        let mut arg = cu_in(&a);
        assert!(arg.host_tensor_mut().is_none());
    }

    #[test]
    fn device_args_carry_residency_in_the_cache_key() {
        use crate::driver::{emulator_device, Context};
        let ctx = Context::create(&emulator_device().unwrap()).unwrap();
        let t = Tensor::from_f32(&[1.0; 8], &[8]);
        let mut d = DeviceArray::from_tensor(&ctx, &t).unwrap();
        let host_sig = call_signature(&[cu_in(&t)]);
        let dev_sig = call_signature(&[cu_dev(&d)]);
        assert_eq!(host_sig, "in:f32[8]");
        assert_eq!(dev_sig, "dev.in:f32[8]");
        assert_ne!(host_sig, dev_sig, "plans differ, keys must differ");
        assert_eq!(call_signature(&[cu_dev_mut(&mut d)]), "dev.inout:f32[8]");
        // residency is excluded from the artifact-facing signature
        let arg = cu_dev(&d);
        assert_eq!(arg.signature(), "f32[8]");
        assert!(arg.is_device());
        assert_eq!(arg.device_ptr(), Some(d.ptr()));
        assert_eq!(arg.byte_len(), 32);
    }
}
