//! Argument wrappers: `CuIn` / `CuOut` / `CuInOut` (paper §6.3).
//!
//! By wrapping arguments, the developer tells the framework which
//! transfers are actually needed; the specialization step turns this into
//! a fixed transfer plan so the steady-state launch does no analysis work
//! and moves no unnecessary bytes.

use crate::tensor::Tensor;

/// Transfer direction of one kernel argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgMode {
    /// Uploaded before launch; never downloaded (`CuIn`).
    In,
    /// Allocated on device; downloaded after launch (`CuOut`).
    Out,
    /// Uploaded and downloaded (`CuInOut`).
    InOut,
    /// Direction unknown at the call site — the framework infers it at
    /// specialization time (from the VTX kernel body's load/store
    /// dataflow, or from the artifact's input/output split). This is the
    /// paper's §9 future-work item, implemented.
    Auto,
}

impl ArgMode {
    pub fn uploads(self) -> bool {
        matches!(self, ArgMode::In | ArgMode::InOut)
    }

    pub fn downloads(self) -> bool {
        matches!(self, ArgMode::Out | ArgMode::InOut)
    }

    pub fn is_auto(self) -> bool {
        matches!(self, ArgMode::Auto)
    }
}

enum TensorRef<'a> {
    Shared(&'a Tensor),
    Mut(&'a mut Tensor),
}

/// One wrapped kernel argument.
pub struct Arg<'a> {
    mode: ArgMode,
    tensor: TensorRef<'a>,
}

impl<'a> Arg<'a> {
    pub fn mode(&self) -> ArgMode {
        self.mode
    }

    pub fn tensor(&self) -> &Tensor {
        match &self.tensor {
            TensorRef::Shared(t) => t,
            TensorRef::Mut(t) => t,
        }
    }

    pub(crate) fn tensor_mut(&mut self) -> Option<&mut Tensor> {
        match &mut self.tensor {
            TensorRef::Shared(_) => None,
            TensorRef::Mut(t) => Some(t),
        }
    }

    /// Signature fragment of this argument (`f32[128,128]`).
    pub fn signature(&self) -> String {
        self.tensor().signature()
    }
}

/// `CuIn(x)`: read-only input.
pub fn cu_in(t: &Tensor) -> Arg<'_> {
    Arg { mode: ArgMode::In, tensor: TensorRef::Shared(t) }
}

/// `CuOut(x)`: output container; contents before launch are ignored.
pub fn cu_out(t: &mut Tensor) -> Arg<'_> {
    Arg { mode: ArgMode::Out, tensor: TensorRef::Mut(t) }
}

/// `CuInOut(x)`: read-write.
pub fn cu_inout(t: &mut Tensor) -> Arg<'_> {
    Arg { mode: ArgMode::InOut, tensor: TensorRef::Mut(t) }
}

/// Unwrapped argument: direction inferred by the framework at
/// specialization time (§9 future work, implemented). Requires `&mut`
/// because the inference may classify it as an output.
pub fn cu_auto(t: &mut Tensor) -> Arg<'_> {
    Arg { mode: ArgMode::Auto, tensor: TensorRef::Mut(t) }
}

/// Call-site signature over all arguments — the specialization cache key
/// (the analog of the Julia method-cache key: the tuple of argument
/// types, §6.2). Includes modes: `in:f32[12];in:f32[12];out:f32[12]`.
pub fn call_signature(args: &[Arg<'_>]) -> String {
    let mut out = String::with_capacity(24 * args.len());
    write_call_signature(&mut out, args);
    out
}

/// Allocation-lean signature writer used on the warm launch path (§Perf
/// iteration I3): one pre-sized String, no intermediate Vec/format calls.
pub fn write_call_signature(out: &mut String, args: &[Arg<'_>]) {
    use std::fmt::Write;
    for (i, a) in args.iter().enumerate() {
        if i > 0 {
            out.push(';');
        }
        out.push_str(match a.mode() {
            ArgMode::In => "in:",
            ArgMode::Out => "out:",
            ArgMode::InOut => "inout:",
            ArgMode::Auto => "auto:",
        });
        let t = a.tensor();
        out.push_str(t.dtype().name());
        out.push('[');
        for (d, dim) in t.shape().iter().enumerate() {
            if d > 0 {
                out.push(',');
            }
            let _ = write!(out, "{dim}");
        }
        out.push(']');
    }
}

/// Input-only signature (what the artifact manifest keys on).
pub fn input_signature(args: &[Arg<'_>]) -> String {
    args.iter()
        .filter(|a| a.mode().uploads())
        .map(|a| a.signature())
        .collect::<Vec<_>>()
        .join(";")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modes_transfer_flags() {
        assert!(ArgMode::In.uploads() && !ArgMode::In.downloads());
        assert!(!ArgMode::Out.uploads() && ArgMode::Out.downloads());
        assert!(ArgMode::InOut.uploads() && ArgMode::InOut.downloads());
    }

    #[test]
    fn signatures() {
        let a = Tensor::from_f32(&[1.0; 12], &[12]);
        let b = Tensor::from_f32(&[2.0; 12], &[12]);
        let mut c = Tensor::zeros_f32(&[12]);
        let args = [cu_in(&a), cu_in(&b), cu_out(&mut c)];
        assert_eq!(call_signature(&args), "in:f32[12];in:f32[12];out:f32[12]");
        assert_eq!(input_signature(&args), "f32[12];f32[12]");
    }

    #[test]
    fn out_args_expose_mut_tensor() {
        let mut c = Tensor::zeros_f32(&[2]);
        let mut arg = cu_out(&mut c);
        assert!(arg.tensor_mut().is_some());
        let a = Tensor::zeros_f32(&[2]);
        let mut arg = cu_in(&a);
        assert!(arg.tensor_mut().is_none());
    }
}
