//! The launch automation layer — the `@cuda` macro + `gen_launch`
//! generated function of the paper (§6), as a rust API.
//!
//! Cold path (first call per signature): resolve the kernel for the
//! call's argument-type signature, load/compile the module, validate
//! shapes, precompute the transfer plan, pre-allocate device buffers.
//! Warm path (every subsequent call): copy `In`/`InOut` tensors into the
//! pre-allocated buffers, launch, copy `Out`/`InOut` back. Nothing else —
//! no lookups beyond one cache read, no allocation, no signature string
//! rebuilt beyond the key (measured by `benches/launch_overhead.rs`).

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::args::{input_signature, Arg, ArgMode};
use crate::coordinator::cache::{CacheStats, SpecializationCache};
use crate::coordinator::registry::{KernelRegistry, VtxSpec};
use crate::driver::backend::TensorSpec;
use crate::driver::{
    BackendKind, Context, DevicePtr, KernelArg, LaunchConfig, MemoryPool,
};
use crate::error::{Error, Result};

/// Per-argument entry in the precomputed transfer plan.
#[derive(Clone, Copy, Debug)]
struct PlanEntry {
    mode: ArgMode,
    byte_len: usize,
    ptr: DevicePtr,
}

/// A cached specialization: everything the warm path needs.
struct Specialized {
    function: crate::driver::Function,
    plan: Vec<PlanEntry>,
    /// Launch-time argument vector template (pointers + trailing scalars).
    kernel_args: Vec<KernelArg>,
    /// Launch configuration override chosen at specialization time (VTX
    /// providers pick their own grid; artifacts run whole-module).
    config: Option<LaunchConfig>,
    /// Pool the plan's buffers live in (freed on drop).
    pool: Arc<MemoryPool>,
}

impl Drop for Specialized {
    fn drop(&mut self) {
        for e in &self.plan {
            let _ = self.pool.free(e.ptr);
        }
    }
}

/// Aggregate metrics of a launcher (inspected by benches and tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct LaunchMetrics {
    pub launches: u64,
    pub cold_specializations: u64,
    /// Total nanoseconds spent in cold specialization work.
    pub specialize_ns: u64,
    /// Thread blocks executed by the VTX emulator's block scheduler
    /// (PJRT launches execute whole modules and report zero).
    pub blocks_executed: u64,
    /// Launches whose grid was dispatched across more than one worker.
    pub parallel_launches: u64,
    /// Sum of per-worker busy time inside the execution engine, ns.
    pub worker_busy_ns: u64,
    /// Wall-clock time inside the execution engine, ns.
    pub exec_wall_ns: u64,
    /// Widest worker schedule observed.
    pub peak_workers: usize,
    /// Virtual-ISA instructions retired by the VTX execution engine
    /// (PJRT launches report zero).
    pub instrs_retired: u64,
    /// Instructions retired inside fused superinstructions (vector
    /// tier only).
    pub fused_instrs: u64,
    /// Interpreter dispatch events (scalar tier: == instructions).
    pub dispatches: u64,
    /// Σ active lanes over vector-tier dispatches.
    pub vector_lane_ops: u64,
    /// Σ block width over vector-tier dispatches.
    pub vector_lane_slots: u64,
}

impl LaunchMetrics {
    /// Fraction of the worker pool's capacity spent executing blocks,
    /// aggregated over every launch (1.0 = perfectly parallel).
    pub fn worker_utilization(&self) -> f64 {
        if self.exec_wall_ns == 0 || self.peak_workers == 0 {
            return 0.0;
        }
        self.worker_busy_ns as f64 / (self.exec_wall_ns as f64 * self.peak_workers as f64)
    }

    /// Fraction of retired instructions covered by fused
    /// superinstructions, aggregated over every launch.
    pub fn fused_share(&self) -> f64 {
        if self.instrs_retired == 0 {
            return 0.0;
        }
        self.fused_instrs as f64 / self.instrs_retired as f64
    }

    /// Mean fraction of a block's lanes active per vector dispatch,
    /// aggregated over every launch (0.0 when only the scalar tier ran).
    pub fn vector_lane_utilization(&self) -> f64 {
        if self.vector_lane_slots == 0 {
            return 0.0;
        }
        self.vector_lane_ops as f64 / self.vector_lane_slots as f64
    }
}

/// Transfer policy ablation switch (benches/transfer_policy.rs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransferPolicy {
    /// Respect `In`/`Out`/`InOut` wrappers (the paper's design).
    Minimal,
    /// Ignore wrappers: upload *and* download every argument (what naive
    /// host code does without the wrappers, §6.3).
    Naive,
}

/// The automation front-end: owns a context, a registry and the
/// specialization cache.
pub struct Launcher {
    ctx: Context,
    registry: KernelRegistry,
    cache: SpecializationCache<Specialized>,
    policy: TransferPolicy,
    metrics: LaunchMetrics,
}

impl Launcher {
    pub fn new(ctx: Context, registry: KernelRegistry) -> Self {
        Launcher {
            ctx,
            registry,
            cache: SpecializationCache::new(),
            policy: TransferPolicy::Minimal,
            metrics: LaunchMetrics::default(),
        }
    }

    /// Launcher on device 0 (PJRT) with the default artifact library.
    pub fn with_default_context() -> Result<Self> {
        Ok(Launcher::new(
            Context::default_device()?,
            KernelRegistry::with_default_library()?,
        ))
    }

    /// Launcher on the VTX emulator device with an empty registry —
    /// register providers with [`Launcher::registry_mut`].
    pub fn emulator() -> Result<Self> {
        let dev = crate::driver::device(1)?;
        Ok(Launcher::new(Context::create(&dev)?, KernelRegistry::new(None)))
    }

    pub fn context(&self) -> &Context {
        &self.ctx
    }

    pub fn registry_mut(&mut self) -> &mut KernelRegistry {
        &mut self.registry
    }

    pub fn set_policy(&mut self, policy: TransferPolicy) {
        self.policy = policy;
        // Plans are policy-dependent; drop them.
        self.cache.clear();
    }

    pub fn metrics(&self) -> LaunchMetrics {
        self.metrics
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// The `@cuda (grid, block) kernel(args...)` entry point. `cfg` is the
    /// dimension pair from the call site; backends that fix their own
    /// parallelism at specialization time (PJRT artifacts, VTX providers)
    /// may override it.
    pub fn launch(
        &mut self,
        kernel: &str,
        cfg: LaunchConfig,
        args: &mut [Arg<'_>],
    ) -> Result<()> {
        let effective_mode = |m: ArgMode| -> ArgMode {
            match self.policy {
                TransferPolicy::Minimal => m,
                TransferPolicy::Naive => ArgMode::InOut,
            }
        };

        // ---- phase 1+2, cached: macro expansion + generated function ----
        // (key built with one pre-sized String — §Perf I3)
        let mut key = String::with_capacity(kernel.len() + 1 + 24 * args.len());
        key.push_str(kernel);
        key.push('\u{1}');
        crate::coordinator::args::write_call_signature(&mut key, args);
        let spec = match self.cache.get(&key) {
            Some(s) => s,
            None => {
                let t0 = Instant::now();
                let s = self.specialize(kernel, args)?;
                self.metrics.cold_specializations += 1;
                self.metrics.specialize_ns += t0.elapsed().as_nanos() as u64;
                self.cache.insert(key, s)
            }
        };

        // ---- warm path: the code fragment ⟨c⟩ of Figure 2 ---------------
        let mem = &spec.pool;
        for (arg, entry) in args.iter().zip(&spec.plan) {
            if effective_mode(entry.mode).uploads() {
                mem.copy_h2d(entry.ptr, arg.tensor().bytes())?;
            }
        }
        let launch_cfg = spec.config.unwrap_or(cfg);
        let report = spec
            .function
            .launch_report(&launch_cfg, &spec.kernel_args, mem)?;
        self.metrics.blocks_executed += report.blocks;
        self.metrics.worker_busy_ns += report.busy_ns;
        self.metrics.exec_wall_ns += report.wall_ns;
        self.metrics.peak_workers = self.metrics.peak_workers.max(report.workers);
        self.metrics.instrs_retired += report.instrs;
        self.metrics.fused_instrs += report.fused_instrs;
        self.metrics.dispatches += report.dispatches;
        self.metrics.vector_lane_ops += report.lane_ops;
        self.metrics.vector_lane_slots += report.lane_slots;
        if report.workers > 1 {
            self.metrics.parallel_launches += 1;
        }
        for (index, (arg, entry)) in args.iter_mut().zip(&spec.plan).enumerate() {
            if effective_mode(entry.mode).downloads() {
                match arg.tensor_mut() {
                    Some(t) => mem.copy_d2h(entry.ptr, t.bytes_mut())?,
                    None if self.policy == TransferPolicy::Naive => {
                        // Naive mode downloads read-only arguments too —
                        // into a discarded host buffer, modeling the wasted
                        // transfer the In/Out wrappers avoid (§6.3).
                        let mut scratch = vec![0u8; entry.byte_len];
                        mem.copy_d2h(entry.ptr, &mut scratch)?;
                    }
                    None => {
                        return Err(Error::BadArgument {
                            kernel: kernel.to_string(),
                            index,
                            reason: "Out/InOut argument is not mutable".into(),
                        })
                    }
                }
            }
        }
        self.metrics.launches += 1;
        Ok(())
    }

    /// Cold path: the `gen_launch` generated function (§6.2). Runs once
    /// per (kernel, argument signature).
    fn specialize(&self, kernel: &str, args: &[Arg<'_>]) -> Result<Specialized> {
        enum Resolved {
            Hlo(crate::driver::ModuleSource),
            Vtx(VtxSpec),
        }
        let has_auto = args.iter().any(|a| a.mode().is_auto());
        // Resolved transfer direction per argument; starts from the
        // wrapper modes, overwritten for `Auto` arguments below.
        let mut modes: Vec<ArgMode> = args.iter().map(|a| a.mode()).collect();
        let source = match self.ctx.device().kind {
            BackendKind::Pjrt if has_auto => {
                // §9 automatic usage detection, artifact flavor: match the
                // call positionally against inputs ++ outputs.
                let sigs: Vec<String> = args.iter().map(|a| a.signature()).collect();
                let (lib, entry, is_output) =
                    self.registry.resolve_artifact_positional(kernel, &sigs)?;
                for (m, out) in modes.iter_mut().zip(is_output) {
                    if m.is_auto() {
                        *m = if out { ArgMode::Out } else { ArgMode::In };
                    }
                }
                Resolved::Hlo(lib.module_source(&entry))
            }
            BackendKind::Pjrt => {
                let in_sig = input_signature(args);
                let (lib, entry) = self.registry.resolve_artifact(kernel, &in_sig)?;
                // Shape validation: outputs of the artifact must match the
                // Out/InOut tensors of the call, in order.
                let out_specs: Vec<TensorSpec> = args
                    .iter()
                    .filter(|a| a.mode().downloads())
                    .map(|a| TensorSpec {
                        dtype: a.tensor().dtype().name().to_string(),
                        shape: a.tensor().shape().to_vec(),
                    })
                    .collect();
                if out_specs.len() != entry.outputs.len() {
                    return Err(Error::Specialize {
                        kernel: kernel.to_string(),
                        reason: format!(
                            "call has {} output arguments, artifact `{}` produces {}",
                            out_specs.len(),
                            entry.name,
                            entry.outputs.len()
                        ),
                    });
                }
                for (i, (got, want)) in out_specs.iter().zip(&entry.outputs).enumerate() {
                    if got != want {
                        return Err(Error::Specialize {
                            kernel: kernel.to_string(),
                            reason: format!(
                                "output {i} is {}, artifact `{}` produces {}",
                                got.signature(),
                                entry.name,
                                want.signature()
                            ),
                        });
                    }
                }
                Resolved::Hlo(lib.module_source(&entry))
            }
            BackendKind::VtxEmulator => {
                let specs: Vec<TensorSpec> = args
                    .iter()
                    .map(|a| TensorSpec {
                        dtype: a.tensor().dtype().name().to_string(),
                        shape: a.tensor().shape().to_vec(),
                    })
                    .collect();
                let spec = self.registry.resolve_vtx(kernel, &specs)?;
                if has_auto {
                    // §9 automatic usage detection, emulator flavor: infer
                    // from the generated kernel's load/store dataflow.
                    use crate::emulator::isa::ParamUsage;
                    let usage = spec.kernel.infer_param_usage();
                    if usage.len() != modes.len() {
                        return Err(Error::Specialize {
                            kernel: kernel.to_string(),
                            reason: format!(
                                "kernel has {} pointer params, call has {} tensor args",
                                usage.len(),
                                modes.len()
                            ),
                        });
                    }
                    for (m, u) in modes.iter_mut().zip(usage) {
                        if m.is_auto() {
                            *m = match u {
                                ParamUsage::ReadOnly => ArgMode::In,
                                ParamUsage::WriteOnly => ArgMode::Out,
                                ParamUsage::ReadWrite => ArgMode::InOut,
                                // dead param: no transfers either way
                                ParamUsage::Unused => ArgMode::In,
                            };
                        }
                    }
                }
                Resolved::Vtx(spec)
            }
        };
        if modes.iter().any(|m| m.is_auto()) {
            return Err(Error::Specialize {
                kernel: kernel.to_string(),
                reason: "could not infer direction for all Auto arguments".into(),
            });
        }

        let pool = self.ctx.memory_arc()?;
        // Pre-allocate one device buffer per tensor argument; the plan
        // carries the *resolved* modes (wrapper or inferred).
        let mut plan = Vec::with_capacity(args.len());
        for (arg, &mode) in args.iter().zip(&modes) {
            let byte_len = arg.tensor().byte_len();
            let ptr = pool.alloc(byte_len)?;
            plan.push(PlanEntry { mode, byte_len, ptr });
        }
        // free plan buffers on any later error via Specialized::drop

        match source {
            Resolved::Hlo(src) => {
                let module = self.ctx.load_module(&src)?;
                let function = module.function("main")?;
                // PJRT argument order: uploads (inputs) then downloads
                // (outputs); InOut pointers appear in both lists.
                let mut kernel_args = Vec::new();
                for e in plan.iter().filter(|e| e.mode.uploads()) {
                    kernel_args.push(KernelArg::Ptr(e.ptr));
                }
                for e in plan.iter().filter(|e| e.mode.downloads()) {
                    kernel_args.push(KernelArg::Ptr(e.ptr));
                }
                Ok(Specialized { function, plan, kernel_args, config: None, pool })
            }
            Resolved::Vtx(VtxSpec { kernel: vk, scalars, config }) => {
                let module = self
                    .ctx
                    .load_module_uncached(&crate::driver::ModuleSource::Vtx {
                        kernels: vec![vk.clone()],
                    })?;
                let function = module.function(&vk.name)?;
                // VTX argument order: one pointer per tensor argument (in
                // call order), then the provider's scalars.
                let mut kernel_args: Vec<KernelArg> =
                    plan.iter().map(|e| KernelArg::Ptr(e.ptr)).collect();
                kernel_args.extend(scalars);
                Ok(Specialized {
                    function,
                    plan,
                    kernel_args,
                    config: Some(config),
                    pool,
                })
            }
        }
    }
}

/// The `@cuda` macro analog: `cuda!(launcher, (grid, block), kernel(args...))`.
///
/// Mirrors the paper's Listing 3 call syntax:
/// `@cuda (len, 1) vadd(CuIn(a), CuIn(b), CuOut(c))`.
#[macro_export]
macro_rules! cuda {
    ($launcher:expr, ($grid:expr, $block:expr), $kernel:ident ( $($arg:expr),* $(,)? )) => {
        $launcher.launch(
            stringify!($kernel),
            $crate::driver::LaunchConfig::new($grid as u32, $block as u32),
            &mut [$($arg),*],
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::arg;
    use crate::emulator::kernels;
    use crate::tensor::Tensor;

    fn emulator_launcher_with_vadd() -> Launcher {
        let mut l = Launcher::emulator().unwrap();
        l.registry_mut().register_vtx("vadd", |specs| {
            let n = specs[0].numel();
            Ok(VtxSpec {
                kernel: kernels::vadd()?,
                scalars: vec![KernelArg::I32(n as i32)],
                config: LaunchConfig::new(((n as u32) + 255) / 256, 256u32),
            })
        });
        l
    }

    #[test]
    fn automation_roundtrip_on_emulator() {
        let mut l = emulator_launcher_with_vadd();
        let a = Tensor::from_f32(&[1., 2., 3., 4.], &[4]);
        let b = Tensor::from_f32(&[10., 20., 30., 40.], &[4]);
        let mut c = Tensor::zeros_f32(&[4]);
        cuda!(l, (1, 4), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        assert_eq!(c.as_f32(), &[11., 22., 33., 44.]);
    }

    #[test]
    fn cache_hit_on_second_call_miss_on_new_signature() {
        let mut l = emulator_launcher_with_vadd();
        let a = Tensor::from_f32(&[1.0; 8], &[8]);
        let b = Tensor::from_f32(&[2.0; 8], &[8]);
        let mut c = Tensor::zeros_f32(&[8]);
        cuda!(l, (1, 8), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        cuda!(l, (1, 8), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        let st = l.cache_stats();
        assert_eq!(st.misses, 1);
        assert_eq!(st.hits, 1);
        assert_eq!(l.metrics().cold_specializations, 1);

        // new length -> new specialization
        let a2 = Tensor::from_f32(&[1.0; 16], &[16]);
        let b2 = Tensor::from_f32(&[2.0; 16], &[16]);
        let mut c2 = Tensor::zeros_f32(&[16]);
        cuda!(l, (1, 16), vadd(arg::cu_in(&a2), arg::cu_in(&b2), arg::cu_out(&mut c2))).unwrap();
        assert_eq!(l.metrics().cold_specializations, 2);
        assert_eq!(c2.as_f32()[0], 3.0);
    }

    #[test]
    fn minimal_policy_moves_fewer_bytes_than_naive() {
        let mut l = emulator_launcher_with_vadd();
        let a = Tensor::from_f32(&[1.0; 256], &[256]);
        let b = Tensor::from_f32(&[2.0; 256], &[256]);
        let mut c = Tensor::zeros_f32(&[256]);

        cuda!(l, (1, 256), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        let minimal = l.context().mem_stats().unwrap();

        l.set_policy(TransferPolicy::Naive);
        l.context().memory().unwrap().reset_stats();
        cuda!(l, (1, 256), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        let naive = l.context().mem_stats().unwrap();

        // minimal: 2 uploads + 1 download; naive: 3 + 3
        assert_eq!(minimal.h2d_count, 2);
        assert_eq!(minimal.d2h_count, 1);
        assert_eq!(naive.h2d_count, 3);
        assert_eq!(naive.d2h_count, 3);
        assert!(naive.h2d_bytes + naive.d2h_bytes > minimal.h2d_bytes + minimal.d2h_bytes);
    }

    #[test]
    fn auto_arguments_inferred_from_vtx_dataflow() {
        // §9 future work: no CuIn/CuOut wrappers at all — the framework
        // derives the transfer plan from the kernel body.
        let mut l = emulator_launcher_with_vadd();
        let mut a = Tensor::from_f32(&[1.0; 64], &[64]);
        let mut b = Tensor::from_f32(&[2.0; 64], &[64]);
        let mut c = Tensor::zeros_f32(&[64]);
        l.launch(
            "vadd",
            LaunchConfig::new(1u32, 64u32),
            &mut [arg::cu_auto(&mut a), arg::cu_auto(&mut b), arg::cu_auto(&mut c)],
        )
        .unwrap();
        assert!(c.as_f32().iter().all(|&v| v == 3.0));
        // inference produced the minimal plan: 2 uploads, 1 download
        let st = l.context().mem_stats().unwrap();
        assert_eq!(st.h2d_count, 2, "a and b are read-only -> CuIn");
        assert_eq!(st.d2h_count, 1, "c is write-only -> CuOut");
        // inputs were not clobbered by a download
        assert!(a.as_f32().iter().all(|&v| v == 1.0));
    }

    #[test]
    fn infer_param_usage_detects_all_classes() {
        use crate::emulator::isa::ParamUsage;
        let k = kernels::vadd().unwrap();
        assert_eq!(
            k.infer_param_usage(),
            vec![ParamUsage::ReadOnly, ParamUsage::ReadOnly, ParamUsage::WriteOnly]
        );
        let s = kernels::sinogram_all().unwrap();
        assert_eq!(
            s.infer_param_usage(),
            vec![ParamUsage::ReadOnly, ParamUsage::ReadOnly, ParamUsage::WriteOnly]
        );
    }

    #[test]
    fn metrics_track_emulator_block_scheduler() {
        let mut l = emulator_launcher_with_vadd();
        let n = 1024usize;
        let a = Tensor::from_f32(&vec![1.0; n], &[n]);
        let b = Tensor::from_f32(&vec![2.0; n], &[n]);
        let mut c = Tensor::zeros_f32(&[n]);
        cuda!(l, (1, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        // provider picks grid = ceil(n/256) = 4 blocks
        let m = l.metrics();
        assert_eq!(m.blocks_executed, 4);
        assert!(m.peak_workers >= 1);
        assert!(m.exec_wall_ns > 0);
        // execution-engine counters flow through, whatever the tier
        assert!(m.instrs_retired > 0);
        assert!(m.dispatches > 0);
        assert!(m.dispatches <= m.instrs_retired);
        assert!(m.fused_share() >= 0.0 && m.fused_share() <= 1.0);
        assert!(m.vector_lane_utilization() >= 0.0 && m.vector_lane_utilization() <= 1.0);
        cuda!(l, (1, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        assert_eq!(l.metrics().blocks_executed, 8, "blocks accumulate per launch");
    }

    #[test]
    fn metrics_track_execution_tiers() {
        use crate::emulator::{set_default_exec, ExecTier};
        let _g = crate::emulator::sched::exec_override_test_lock();
        // Vector tier: fused superinstructions retire and lanes occupy
        // slots; scalar tier: one dispatch per instruction, no fusion.
        // Both retire the same instruction count.
        let mk = || {
            let mut l = emulator_launcher_with_vadd();
            let a = Tensor::from_f32(&[1.0; 256], &[256]);
            let b = Tensor::from_f32(&[2.0; 256], &[256]);
            let mut c = Tensor::zeros_f32(&[256]);
            cuda!(l, (1, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))
                .unwrap();
            assert!(c.as_f32().iter().all(|&v| v == 3.0));
            l.metrics()
        };
        set_default_exec(Some(ExecTier::Vector));
        let vector = mk();
        set_default_exec(Some(ExecTier::Scalar));
        let scalar = mk();
        set_default_exec(None);
        assert_eq!(vector.instrs_retired, scalar.instrs_retired);
        assert!(vector.fused_instrs > 0, "vadd's index prologue must fuse");
        assert_eq!(scalar.fused_instrs, 0);
        assert!(vector.dispatches < scalar.dispatches, "dispatch amortization");
        assert!(vector.vector_lane_utilization() > 0.0);
        assert_eq!(scalar.vector_lane_slots, 0);
    }

    #[test]
    fn unregistered_kernel_fails_to_specialize() {
        let mut l = Launcher::emulator().unwrap();
        let a = Tensor::zeros_f32(&[4]);
        let err = l
            .launch(
                "ghost",
                LaunchConfig::new(1u32, 4u32),
                &mut [arg::cu_in(&a)],
            )
            .unwrap_err();
        assert!(matches!(err, Error::Specialize { .. }), "{err}");
    }
}
