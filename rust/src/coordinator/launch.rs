//! The launch automation layer — the `@cuda` macro + `gen_launch`
//! generated function of the paper (§6), as a rust API.
//!
//! Cold path (first call per signature): resolve the kernel for the
//! call's argument-type signature, load/compile the module, validate
//! shapes, precompute the transfer plan, pre-allocate device buffers.
//! Warm path (every subsequent call): copy `In`/`InOut` tensors into the
//! pre-allocated buffers, launch, copy `Out`/`InOut` back. Nothing else —
//! no lookups beyond one cache read, no allocation, no signature string
//! rebuilt beyond the key (measured by `benches/launch_overhead.rs`).
//!
//! # Launch API v2 (see `docs/api.md`)
//!
//! * [`Launcher::bind`] resolves + specializes **once** and returns a
//!   [`KernelHandle`] whose warm path does zero key-building and zero
//!   cache hashing — [`Launcher::launch`] (and the [`crate::cuda!`]
//!   macro) stays source-compatible as a thin front-end that adds the
//!   one cache read.
//! * Device-resident arguments (`arg::cu_dev` / `arg::cu_dev_mut` over a
//!   [`crate::coordinator::DeviceArray`]) make the transfer plan skip
//!   h2d/d2h for data the device already holds; the skips are counted in
//!   [`LaunchMetrics::skipped_h2d`] / [`LaunchMetrics::skipped_d2h`].
//! * [`KernelHandle::launch_on`] enqueues the kernel on a
//!   [`Stream`] and returns a [`PendingLaunch`] joinable via its
//!   [`Event`] or [`PendingLaunch::wait`] — the stream-ordered async
//!   path the double-buffered pipelines build on.
//! * [`KernelHandle::download_on`] /
//!   [`crate::coordinator::DeviceArray::download_on`] enqueue an
//!   **async d2h readback** and return a [`PendingDownload`] that
//!   resolves to a [`Tensor`](crate::tensor::Tensor) on
//!   [`PendingDownload::wait`] — the result-fetch half of a fully
//!   device-resident pipeline (the trace pipeline's feature block is
//!   `FEATURE_COUNT` floats instead of whole sinograms; see
//!   `docs/api.md`).

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::args::{input_signature, write_call_signature, Arg, ArgMode};
use crate::coordinator::cache::{CacheStats, SpecializationCache};
use crate::coordinator::registry::{KernelRegistry, VtxSpec};
use crate::driver::backend::TensorSpec;
use crate::driver::{
    BackendKind, Context, DevicePtr, Event, KernelArg, LaunchConfig, LaunchReport, MemoryPool,
    Stream,
};
use crate::error::{Error, Result};

/// Per-argument entry in the precomputed transfer plan.
#[derive(Clone, Debug)]
struct PlanEntry {
    mode: ArgMode,
    dtype: crate::tensor::Dtype,
    shape: Vec<usize>,
    byte_len: usize,
    /// Pre-allocated staging buffer for host arguments; null for
    /// device-resident entries (their pointer is patched in per launch).
    ptr: DevicePtr,
    /// True when the argument is device-resident (`cu_dev`/`cu_dev_mut`):
    /// the plan skips both transfer directions for it.
    device: bool,
}

/// A cached specialization: everything the warm path needs.
struct Specialized {
    function: crate::driver::Function,
    plan: Vec<PlanEntry>,
    /// True when any plan entry stages through a shared host buffer.
    /// Launches through such a plan serialize on [`Specialized::stage`]
    /// — cloned handles (and cache-shared specializations) would
    /// otherwise interleave upload/launch/download on one staging
    /// buffer and corrupt each other's arguments. All-device-resident
    /// plans skip the lock entirely.
    has_host: bool,
    /// Serializes staged (host-argument) launches; see `has_host`.
    stage: Mutex<()>,
    /// Launch-time argument vector template (pointers + trailing scalars).
    kernel_args: Vec<KernelArg>,
    /// `(arg index, kernel_args index)` pairs for device-resident
    /// arguments: their concrete pointer is patched into a copy of the
    /// template at launch time, so one specialization serves every
    /// same-shaped `DeviceArray`.
    patches: Vec<(usize, usize)>,
    /// Launch configuration override chosen at specialization time (VTX
    /// providers pick their own grid; artifacts run whole-module).
    config: Option<LaunchConfig>,
    /// Pool the plan's buffers live in (freed on drop).
    pool: Arc<MemoryPool>,
    /// Ordinal of the device this specialization targets (for residency
    /// diagnostics and handle migration).
    ordinal: usize,
}

impl Drop for Specialized {
    fn drop(&mut self) {
        for e in &self.plan {
            if !e.device {
                let _ = self.pool.free(e.ptr);
            }
        }
    }
}

/// Aggregate metrics of a launcher (inspected by benches and tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct LaunchMetrics {
    pub launches: u64,
    pub cold_specializations: u64,
    /// Total nanoseconds spent in cold specialization work.
    pub specialize_ns: u64,
    /// Host→device copies the transfer planner *skipped* because the
    /// argument was device-resident (`arg::cu_dev`/`cu_dev_mut`).
    pub skipped_h2d: u64,
    /// Device→host copies skipped for device-resident arguments.
    pub skipped_d2h: u64,
    /// Thread blocks executed by the VTX emulator's block scheduler
    /// (PJRT launches execute whole modules and report zero).
    pub blocks_executed: u64,
    /// Launches whose grid was dispatched across more than one worker.
    pub parallel_launches: u64,
    /// Sum of per-worker busy time inside the execution engine, ns.
    pub worker_busy_ns: u64,
    /// Wall-clock time inside the execution engine, ns.
    pub exec_wall_ns: u64,
    /// Widest worker schedule observed.
    pub peak_workers: usize,
    /// Virtual-ISA instructions retired by the VTX execution engine
    /// (PJRT launches report zero).
    pub instrs_retired: u64,
    /// Instructions retired inside fused superinstructions (vector
    /// tier only).
    pub fused_instrs: u64,
    /// Interpreter dispatch events (scalar tier: == instructions).
    pub dispatches: u64,
    /// Σ active lanes over vector-tier dispatches.
    pub vector_lane_ops: u64,
    /// Σ block width over vector-tier dispatches.
    pub vector_lane_slots: u64,
    /// Instructions retired inside JIT-compiled block bodies
    /// (compiled tier only).
    pub compiled_instrs: u64,
    /// Compiled-block body executions on the compiled tier.
    pub compiled_blocks: u64,
    /// Basic blocks promoted to compiled form (at most once per block
    /// per decoded kernel — warm launches add zero).
    pub tier_ups: u64,
    /// Compiled-body guard failures that deopted back to the vector
    /// tier mid-block.
    pub deopts: u64,
    /// Async d2h readbacks enqueued through [`KernelHandle::download_on`]
    /// (each resolves to a `Tensor` on `PendingDownload::wait`).
    pub d2h_deferred: u64,
    /// Bytes moved by those deferred readbacks — for the trace pipeline's
    /// device-reduce path this is `FEATURE_COUNT * 4` per image, the
    /// measurable A/B against downloading whole sinograms.
    pub features_bytes: u64,
}

impl LaunchMetrics {
    /// Fraction of the worker pool's capacity spent executing blocks,
    /// aggregated over every launch (1.0 = perfectly parallel).
    pub fn worker_utilization(&self) -> f64 {
        if self.exec_wall_ns == 0 || self.peak_workers == 0 {
            return 0.0;
        }
        self.worker_busy_ns as f64 / (self.exec_wall_ns as f64 * self.peak_workers as f64)
    }

    /// Fraction of retired instructions covered by fused
    /// superinstructions, aggregated over every launch.
    pub fn fused_share(&self) -> f64 {
        if self.instrs_retired == 0 {
            return 0.0;
        }
        self.fused_instrs as f64 / self.instrs_retired as f64
    }

    /// Fraction of retired instructions executed by JIT-compiled block
    /// bodies, aggregated over every launch (0.0 unless the compiled
    /// tier ran). Mirrors [`fused_share`](Self::fused_share).
    pub fn compiled_share(&self) -> f64 {
        if self.instrs_retired == 0 {
            return 0.0;
        }
        self.compiled_instrs as f64 / self.instrs_retired as f64
    }

    /// Mean fraction of a block's lanes active per vector dispatch,
    /// aggregated over every launch (0.0 when only the scalar tier ran).
    pub fn vector_lane_utilization(&self) -> f64 {
        if self.vector_lane_slots == 0 {
            return 0.0;
        }
        self.vector_lane_ops as f64 / self.vector_lane_slots as f64
    }
}

/// Transfer policy ablation switch (benches/transfer_policy.rs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransferPolicy {
    /// Respect `In`/`Out`/`InOut` wrappers (the paper's design).
    Minimal,
    /// Ignore wrappers: upload *and* download every argument (what naive
    /// host code does without the wrappers, §6.3). Device-resident
    /// arguments still skip — their storage never round-trips.
    Naive,
}

fn effective_mode(policy: TransferPolicy, m: ArgMode) -> ArgMode {
    match policy {
        TransferPolicy::Minimal => m,
        TransferPolicy::Naive => ArgMode::InOut,
    }
}

fn absorb_report(m: &mut LaunchMetrics, r: &LaunchReport) {
    m.launches += 1;
    m.blocks_executed += r.blocks;
    m.worker_busy_ns += r.busy_ns;
    m.exec_wall_ns += r.wall_ns;
    m.peak_workers = m.peak_workers.max(r.workers);
    m.instrs_retired += r.instrs;
    m.fused_instrs += r.fused_instrs;
    m.dispatches += r.dispatches;
    m.vector_lane_ops += r.lane_ops;
    m.vector_lane_slots += r.lane_slots;
    m.compiled_instrs += r.compiled_instrs;
    m.compiled_blocks += r.compiled_blocks;
    m.tier_ups += r.tier_ups;
    m.deopts += r.deopts;
    if r.workers > 1 {
        m.parallel_launches += 1;
    }
}

/// Checked grid/block conversion used by the [`crate::cuda!`] macro:
/// dimensions that do not fit in `u32` become [`Error::BadArgument`]
/// instead of the silent `as u32` truncation of the v1 macro.
pub fn checked_cfg<G, B>(kernel: &str, grid: G, block: B) -> Result<LaunchConfig>
where
    G: TryInto<u32> + Copy + std::fmt::Display,
    B: TryInto<u32> + Copy + std::fmt::Display,
{
    let g: u32 = grid.try_into().map_err(|_| Error::BadArgument {
        kernel: kernel.to_string(),
        index: 0,
        reason: format!("grid dimension {grid} does not fit in u32"),
    })?;
    let b: u32 = block.try_into().map_err(|_| Error::BadArgument {
        kernel: kernel.to_string(),
        index: 0,
        reason: format!("block dimension {block} does not fit in u32"),
    })?;
    Ok(LaunchConfig::new(g, b))
}

/// [`checked_cfg`] for 2-D grids (`LaunchConfig::new((gx, gy), block)`):
/// the batched pipelines' `(angles, batch)` grids route through this so
/// a batch or angle count past `u32::MAX` is [`Error::BadArgument`], not
/// a silently truncated grid.
pub fn checked_cfg2<X, Y, B>(kernel: &str, grid: (X, Y), block: B) -> Result<LaunchConfig>
where
    X: TryInto<u32> + Copy + std::fmt::Display,
    Y: TryInto<u32> + Copy + std::fmt::Display,
    B: TryInto<u32> + Copy + std::fmt::Display,
{
    let gx: u32 = grid.0.try_into().map_err(|_| Error::BadArgument {
        kernel: kernel.to_string(),
        index: 0,
        reason: format!("grid x dimension {} does not fit in u32", grid.0),
    })?;
    let gy: u32 = grid.1.try_into().map_err(|_| Error::BadArgument {
        kernel: kernel.to_string(),
        index: 0,
        reason: format!("grid y dimension {} does not fit in u32", grid.1),
    })?;
    let b: u32 = block.try_into().map_err(|_| Error::BadArgument {
        kernel: kernel.to_string(),
        index: 0,
        reason: format!("block dimension {block} does not fit in u32"),
    })?;
    Ok(LaunchConfig::new((gx, gy), b))
}

/// The foreign-context rejection: names both device ordinals and the
/// offending argument index (a generic `BadArgument` hid which array on
/// which device broke the launch once multiple devices existed). Two
/// contexts on the *same* ordinal are still foreign — each owns its own
/// address space, like two CUDA contexts on one GPU.
fn foreign_context_error(kernel: &str, index: usize, ours: usize, theirs: usize) -> Error {
    Error::BadArgument {
        kernel: kernel.to_string(),
        index,
        reason: format!(
            "device-resident argument {index} belongs to a different context: the array \
             lives on device {theirs}, this specialization targets device {ours} (two \
             contexts on one ordinal are still distinct address spaces) — copy it with \
             DeviceArray::migrate_to or rebind on the owning context"
        ),
    }
}

/// Check a call's arguments against a specialization's transfer plan.
/// The v1 warm path `zip`ped the two and silently truncated on length
/// mismatch; the v2 path errors with the shape of the disagreement.
fn validate_args(kernel: &str, spec: &Specialized, args: &[Arg<'_>]) -> Result<()> {
    if args.len() != spec.plan.len() {
        return Err(Error::BadArgument {
            kernel: kernel.to_string(),
            index: args.len().min(spec.plan.len()),
            reason: format!(
                "call passes {} arguments but this specialization's transfer plan has {} \
                 entries — the handle was bound for a different call shape",
                args.len(),
                spec.plan.len()
            ),
        });
    }
    for (index, (arg, entry)) in args.iter().zip(&spec.plan).enumerate() {
        if arg.is_device() != entry.device {
            return Err(Error::BadArgument {
                kernel: kernel.to_string(),
                index,
                reason: if entry.device {
                    "plan expects a device-resident argument (arg::cu_dev / cu_dev_mut)".into()
                } else {
                    "plan expects a host argument, got a device-resident one".into()
                },
            });
        }
        // Residency check at launch time: a handle migrated to another
        // device (or a call mixing arrays from several contexts) must
        // fail with the ordinals named, not with an InvalidDevicePtr
        // from the wrong pool deep inside the launch.
        if entry.device {
            if let Some(actx) = arg.device_context() {
                let theirs = actx.memory_arc()?;
                if !Arc::ptr_eq(&spec.pool, &theirs) {
                    return Err(foreign_context_error(
                        kernel,
                        index,
                        spec.ordinal,
                        actx.device().ordinal,
                    ));
                }
            }
        }
        // Transfer-direction check: the handle path has no cache key to
        // separate an `In` plan from an `InOut` call — a mismatch would
        // silently run the *bound* plan's transfers (e.g. never
        // downloading what the caller wrapped as an output). `Auto` call
        // modes are exempt: the plan's resolved mode IS their meaning.
        if !arg.mode().is_auto() && arg.mode() != entry.mode {
            return Err(Error::BadArgument {
                kernel: kernel.to_string(),
                index,
                reason: format!(
                    "argument is wrapped {:?}, the plan was specialized for {:?} — transfer \
                     directions are part of the bound call shape",
                    arg.mode(),
                    entry.mode
                ),
            });
        }
        // Full type-shape check, not just byte length: the handle path
        // has no cache key to catch an i32[64] passed where the
        // specialization was built for f32[64].
        if arg.dtype() != entry.dtype || arg.shape() != entry.shape.as_slice() {
            return Err(Error::BadArgument {
                kernel: kernel.to_string(),
                index,
                reason: format!(
                    "argument is {}, the plan was specialized for {}[{}]",
                    arg.signature(),
                    entry.dtype.name(),
                    entry
                        .shape
                        .iter()
                        .map(|d| d.to_string())
                        .collect::<Vec<_>>()
                        .join(",")
                ),
            });
        }
    }
    Ok(())
}

/// The shared warm path: transfers in, (patched) launch, transfers out,
/// metrics. [`Launcher::launch`] reaches it through one cache read;
/// [`KernelHandle::launch`] reaches it directly.
fn run_launch(
    kernel: &str,
    spec: &Specialized,
    policy: TransferPolicy,
    metrics: &Mutex<LaunchMetrics>,
    cfg: LaunchConfig,
    args: &mut [Arg<'_>],
) -> Result<()> {
    validate_args(kernel, spec, args)?;
    // Fault plane: one `launch` operation per warm-path call; an
    // injected failure here loses the device (sticky, see docs/faults.md).
    crate::driver::faults::on_launch(spec.ordinal)?;
    let mem = &spec.pool;
    // Plans with host staging buffers serialize: concurrent launches
    // through cloned handles (or the shared cache entry) must not
    // interleave upload/launch/download on one buffer. All-device plans
    // have nothing shared to protect and skip the lock.
    let _stage_guard = if spec.has_host { Some(spec.stage.lock().unwrap()) } else { None };
    let mut skipped_h2d = 0u64;
    let mut skipped_d2h = 0u64;

    // ---- uploads (the code fragment ⟨c⟩ of Figure 2) --------------------
    for (arg, entry) in args.iter().zip(&spec.plan) {
        if effective_mode(policy, entry.mode).uploads() {
            if entry.device {
                skipped_h2d += 1;
            } else {
                mem.copy_h2d(entry.ptr, arg.host_tensor().expect("host plan entry").bytes())?;
            }
        }
    }

    // ---- launch, patching device-resident pointers in -------------------
    let patched: Vec<KernelArg>;
    let kargs: &[KernelArg] = if spec.patches.is_empty() {
        &spec.kernel_args
    } else {
        let mut v = spec.kernel_args.clone();
        for &(ai, ki) in &spec.patches {
            v[ki] = KernelArg::Ptr(args[ai].device_ptr().expect("validated device entry"));
        }
        patched = v;
        &patched
    };
    let launch_cfg = spec.config.unwrap_or(cfg);
    let report = spec.function.launch_report(&launch_cfg, kargs, mem)?;

    // ---- downloads ------------------------------------------------------
    for (index, (arg, entry)) in args.iter_mut().zip(&spec.plan).enumerate() {
        if effective_mode(policy, entry.mode).downloads() {
            if entry.device {
                skipped_d2h += 1;
                continue;
            }
            match arg.host_tensor_mut() {
                Some(t) => mem.copy_d2h(entry.ptr, t.bytes_mut())?,
                None if policy == TransferPolicy::Naive => {
                    // Naive mode downloads read-only arguments too —
                    // into a discarded host buffer, modeling the wasted
                    // transfer the In/Out wrappers avoid (§6.3).
                    let mut scratch = vec![0u8; entry.byte_len];
                    mem.copy_d2h(entry.ptr, &mut scratch)?;
                }
                None => {
                    return Err(Error::BadArgument {
                        kernel: kernel.to_string(),
                        index,
                        reason: "Out/InOut argument is not mutable".into(),
                    })
                }
            }
        }
    }
    let mut m = metrics.lock().unwrap();
    m.skipped_h2d += skipped_h2d;
    m.skipped_d2h += skipped_d2h;
    absorb_report(&mut m, &report);
    Ok(())
}

/// The automation front-end: owns a context, a registry and the
/// specialization cache.
pub struct Launcher {
    ctx: Context,
    registry: KernelRegistry,
    cache: SpecializationCache<Specialized>,
    policy: TransferPolicy,
    metrics: Arc<Mutex<LaunchMetrics>>,
}

impl Launcher {
    pub fn new(ctx: Context, registry: KernelRegistry) -> Self {
        Launcher {
            ctx,
            registry,
            cache: SpecializationCache::new(),
            policy: TransferPolicy::Minimal,
            metrics: Arc::new(Mutex::new(LaunchMetrics::default())),
        }
    }

    /// Launcher on the default PJRT device with the default artifact
    /// library.
    pub fn with_default_context() -> Result<Self> {
        Ok(Launcher::new(
            Context::default_device()?,
            KernelRegistry::with_default_library()?,
        ))
    }

    /// Launcher on the VTX emulator device with an empty registry —
    /// register providers with [`Launcher::registry_mut`].
    pub fn emulator() -> Result<Self> {
        let dev = crate::driver::emulator_device()?;
        Ok(Launcher::new(Context::create(&dev)?, KernelRegistry::new(None)))
    }

    pub fn context(&self) -> &Context {
        &self.ctx
    }

    pub fn registry_mut(&mut self) -> &mut KernelRegistry {
        &mut self.registry
    }

    pub fn set_policy(&mut self, policy: TransferPolicy) {
        self.policy = policy;
        // Plans are policy-dependent; drop them. Handles bound earlier
        // keep the policy they were bound under.
        self.cache.clear();
    }

    pub fn metrics(&self) -> LaunchMetrics {
        *self.metrics.lock().unwrap()
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// One cache read (key built with one pre-sized String — §Perf I3),
    /// specializing on miss.
    fn lookup_or_specialize(&mut self, kernel: &str, args: &[Arg<'_>]) -> Result<Arc<Specialized>> {
        let mut key = String::with_capacity(kernel.len() + 1 + 24 * args.len());
        key.push_str(kernel);
        key.push('\u{1}');
        write_call_signature(&mut key, args);
        match self.cache.get(&key) {
            Some(s) => Ok(s),
            None => {
                let t0 = Instant::now();
                let s = self.specialize(kernel, args)?;
                {
                    let mut m = self.metrics.lock().unwrap();
                    m.cold_specializations += 1;
                    m.specialize_ns += t0.elapsed().as_nanos() as u64;
                }
                Ok(self.cache.insert(key, s))
            }
        }
    }

    /// The `@cuda (grid, block) kernel(args...)` entry point. `cfg` is the
    /// dimension pair from the call site; backends that fix their own
    /// parallelism at specialization time (PJRT artifacts, VTX providers)
    /// may override it.
    pub fn launch(
        &mut self,
        kernel: &str,
        cfg: LaunchConfig,
        args: &mut [Arg<'_>],
    ) -> Result<()> {
        let spec = self.lookup_or_specialize(kernel, args)?;
        run_launch(kernel, &spec, self.policy, &self.metrics, cfg, args)
    }

    /// Launch API v2: resolve + specialize **once** and hand back a
    /// [`KernelHandle`] bound to this call shape. The handle's warm path
    /// builds no cache key and takes no cache read — repeated launches
    /// through it are pure transfer + dispatch work. The handle shares
    /// this launcher's [`LaunchMetrics`] and stays valid after the
    /// launcher moves or specializes other kernels.
    pub fn bind(&mut self, kernel: &str, args: &[Arg<'_>]) -> Result<KernelHandle> {
        let spec = self.lookup_or_specialize(kernel, args)?;
        Ok(KernelHandle {
            kernel: kernel.to_string(),
            spec,
            policy: self.policy,
            metrics: self.metrics.clone(),
        })
    }

    /// Cold path: the `gen_launch` generated function (§6.2). Runs once
    /// per (kernel, argument signature).
    fn specialize(&self, kernel: &str, args: &[Arg<'_>]) -> Result<Specialized> {
        enum Resolved {
            Hlo(crate::driver::ModuleSource),
            Vtx(VtxSpec),
        }
        let has_auto = args.iter().any(|a| a.mode().is_auto());
        // Resolved transfer direction per argument; starts from the
        // wrapper modes, overwritten for `Auto` arguments below.
        let mut modes: Vec<ArgMode> = args.iter().map(|a| a.mode()).collect();
        let source = match self.ctx.device().kind {
            BackendKind::Pjrt if has_auto => {
                // §9 automatic usage detection, artifact flavor: match the
                // call positionally against inputs ++ outputs.
                let sigs: Vec<String> = args.iter().map(|a| a.signature()).collect();
                let (lib, entry, is_output) =
                    self.registry.resolve_artifact_positional(kernel, &sigs)?;
                for (m, out) in modes.iter_mut().zip(is_output) {
                    if m.is_auto() {
                        *m = if out { ArgMode::Out } else { ArgMode::In };
                    }
                }
                Resolved::Hlo(lib.module_source(&entry))
            }
            BackendKind::Pjrt => {
                let in_sig = input_signature(args);
                let (lib, entry) = self.registry.resolve_artifact(kernel, &in_sig)?;
                // Shape validation: outputs of the artifact must match the
                // Out/InOut arguments of the call, in order.
                let out_specs: Vec<TensorSpec> = args
                    .iter()
                    .filter(|a| a.mode().downloads())
                    .map(|a| TensorSpec {
                        dtype: a.dtype().name().to_string(),
                        shape: a.shape().to_vec(),
                    })
                    .collect();
                if out_specs.len() != entry.outputs.len() {
                    return Err(Error::Specialize {
                        kernel: kernel.to_string(),
                        reason: format!(
                            "call has {} output arguments, artifact `{}` produces {}",
                            out_specs.len(),
                            entry.name,
                            entry.outputs.len()
                        ),
                    });
                }
                for (i, (got, want)) in out_specs.iter().zip(&entry.outputs).enumerate() {
                    if got != want {
                        return Err(Error::Specialize {
                            kernel: kernel.to_string(),
                            reason: format!(
                                "output {i} is {}, artifact `{}` produces {}",
                                got.signature(),
                                entry.name,
                                want.signature()
                            ),
                        });
                    }
                }
                Resolved::Hlo(lib.module_source(&entry))
            }
            BackendKind::VtxEmulator => {
                let specs: Vec<TensorSpec> = args
                    .iter()
                    .map(|a| TensorSpec {
                        dtype: a.dtype().name().to_string(),
                        shape: a.shape().to_vec(),
                    })
                    .collect();
                let spec = self.registry.resolve_vtx(kernel, &specs)?;
                if has_auto {
                    // §9 automatic usage detection, emulator flavor: infer
                    // from the generated kernel's load/store dataflow.
                    use crate::emulator::isa::ParamUsage;
                    let usage = spec.kernel.infer_param_usage();
                    if usage.len() != modes.len() {
                        return Err(Error::Specialize {
                            kernel: kernel.to_string(),
                            reason: format!(
                                "kernel has {} pointer params, call has {} tensor args",
                                usage.len(),
                                modes.len()
                            ),
                        });
                    }
                    for (m, u) in modes.iter_mut().zip(usage) {
                        if m.is_auto() {
                            *m = match u {
                                ParamUsage::ReadOnly => ArgMode::In,
                                ParamUsage::WriteOnly => ArgMode::Out,
                                ParamUsage::ReadWrite => ArgMode::InOut,
                                // dead param: no transfers either way
                                ParamUsage::Unused => ArgMode::In,
                            };
                        }
                    }
                }
                Resolved::Vtx(spec)
            }
        };
        if modes.iter().any(|m| m.is_auto()) {
            return Err(Error::Specialize {
                kernel: kernel.to_string(),
                reason: "could not infer direction for all Auto arguments".into(),
            });
        }

        let pool = self.ctx.memory_arc()?;
        // Pre-allocate one device staging buffer per *host* tensor
        // argument; device-resident arguments bring their own storage
        // (their pointer is patched in per launch) and the plan carries
        // the *resolved* modes (wrapper or inferred).
        let mut plan = Vec::with_capacity(args.len());
        for (index, (arg, &mode)) in args.iter().zip(&modes).enumerate() {
            let byte_len = arg.byte_len();
            if arg.is_device() {
                // the array must live in this launcher's device memory
                if let Some(actx) = arg.device_context() {
                    let theirs = actx.memory_arc()?;
                    if !Arc::ptr_eq(&pool, &theirs) {
                        return Err(foreign_context_error(
                            kernel,
                            index,
                            self.ctx.device().ordinal,
                            actx.device().ordinal,
                        ));
                    }
                }
                plan.push(PlanEntry {
                    mode,
                    dtype: arg.dtype(),
                    shape: arg.shape().to_vec(),
                    byte_len,
                    ptr: DevicePtr::null(),
                    device: true,
                });
            } else {
                let ptr = pool.alloc(byte_len)?;
                plan.push(PlanEntry {
                    mode,
                    dtype: arg.dtype(),
                    shape: arg.shape().to_vec(),
                    byte_len,
                    ptr,
                    device: false,
                });
            }
        }
        let has_host = plan.iter().any(|e| !e.device);
        // free plan buffers on any later error via Specialized::drop

        match source {
            Resolved::Hlo(src) => {
                let module = self.ctx.load_module(&src)?;
                let function = module.function("main")?;
                // PJRT argument order: uploads (inputs) then downloads
                // (outputs); InOut pointers appear in both lists.
                let mut kernel_args = Vec::new();
                let mut patches = Vec::new();
                for (i, e) in plan.iter().enumerate() {
                    if e.mode.uploads() {
                        if e.device {
                            patches.push((i, kernel_args.len()));
                        }
                        kernel_args.push(KernelArg::Ptr(e.ptr));
                    }
                }
                for (i, e) in plan.iter().enumerate() {
                    if e.mode.downloads() {
                        if e.device {
                            patches.push((i, kernel_args.len()));
                        }
                        kernel_args.push(KernelArg::Ptr(e.ptr));
                    }
                }
                Ok(Specialized {
                    function,
                    plan,
                    has_host,
                    stage: Mutex::new(()),
                    kernel_args,
                    patches,
                    config: None,
                    pool,
                    ordinal: self.ctx.device().ordinal,
                })
            }
            Resolved::Vtx(VtxSpec { kernel: vk, scalars, config }) => {
                let module = self
                    .ctx
                    .load_module_uncached(&crate::driver::ModuleSource::Vtx {
                        kernels: vec![vk.clone()],
                    })?;
                let function = module.function(&vk.name)?;
                // VTX argument order: one pointer per tensor argument (in
                // call order), then the provider's scalars.
                let mut kernel_args: Vec<KernelArg> =
                    plan.iter().map(|e| KernelArg::Ptr(e.ptr)).collect();
                let patches: Vec<(usize, usize)> = plan
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| e.device)
                    .map(|(i, _)| (i, i))
                    .collect();
                kernel_args.extend(scalars);
                Ok(Specialized {
                    function,
                    plan,
                    has_host,
                    stage: Mutex::new(()),
                    kernel_args,
                    patches,
                    config: Some(config),
                    pool,
                    ordinal: self.ctx.device().ordinal,
                })
            }
        }
    }
}

/// A kernel bound to one call shape (launch API v2): the product of
/// [`Launcher::bind`]. Holds the specialization directly — launching
/// through a handle does **zero** cache-key building and **zero** cache
/// lookups, only the transfer plan and the dispatch. Cheap to clone;
/// clones share the specialization and the launcher's metrics.
#[derive(Clone)]
pub struct KernelHandle {
    kernel: String,
    spec: Arc<Specialized>,
    policy: TransferPolicy,
    metrics: Arc<Mutex<LaunchMetrics>>,
}

impl KernelHandle {
    /// The logical kernel name this handle was bound to.
    pub fn kernel(&self) -> &str {
        &self.kernel
    }

    /// Synchronous launch through the bound specialization. Arguments
    /// must match the bound call shape (same count, residency and byte
    /// lengths) — a different same-shaped `DeviceArray` is fine, its
    /// pointer is patched into the launch.
    pub fn launch(&self, cfg: LaunchConfig, args: &mut [Arg<'_>]) -> Result<()> {
        run_launch(&self.kernel, &self.spec, self.policy, &self.metrics, cfg, args)
    }

    /// Stream-ordered asynchronous launch (launch API v2): enqueue the
    /// kernel on `stream` and return immediately with a
    /// [`PendingLaunch`] joinable via [`PendingLaunch::wait`] or fence-
    /// able via its [`Event`].
    ///
    /// Host `In` arguments are copied into owned buffers and their
    /// uploads **enqueued on the stream ahead of the kernel**, so
    /// back-to-back `launch_on` calls through one handle stay correctly
    /// ordered (call N+1's upload cannot overwrite staging before call
    /// N's kernel has run). A handle with host-staged arguments must not
    /// be launched concurrently on *different* streams — the staging
    /// buffers are shared; use device-resident arguments (or separate
    /// handles) for cross-stream pipelines. For the same reason, do not
    /// interleave a synchronous [`KernelHandle::launch`] with an
    /// in-flight `launch_on` on a host-staged handle: the sync path
    /// copies into the shared staging buffers immediately, not in
    /// stream order — join the pending launch first (all-device plans
    /// have no staging and mix freely). Every `Out`/`InOut` argument
    /// must be **device-resident** (`arg::cu_dev_mut`): an async launch
    /// cannot write back into borrowed host memory; download the result
    /// after joining.
    pub fn launch_on<'s>(
        &self,
        stream: &'s Stream,
        cfg: LaunchConfig,
        args: &mut [Arg<'_>],
    ) -> Result<PendingLaunch<'s>> {
        let spec = &*self.spec;
        validate_args(&self.kernel, spec, args)?;
        // Fault plane: the `launch` operation is counted at enqueue time
        // (deterministic for a deterministic enqueue order); an injected
        // failure surfaces here, before anything lands on the stream. A
        // scheduled `hang` turns the enqueued kernel into one that never
        // completes until the watchdog (or the hang cap) loses the device.
        crate::driver::faults::on_launch(spec.ordinal)?;
        let hang = crate::driver::faults::hang_requested(spec.ordinal);
        let mut skipped_h2d = 0u64;
        let mut skipped_d2h = 0u64;
        for (index, entry) in spec.plan.iter().enumerate() {
            let mode = effective_mode(self.policy, entry.mode);
            if mode.downloads() && !entry.device {
                return Err(Error::BadArgument {
                    kernel: self.kernel.clone(),
                    index,
                    reason: "async launch_on requires Out/InOut arguments to be \
                             device-resident (arg::cu_dev_mut); download explicitly after \
                             wait()"
                        .into(),
                });
            }
            if mode.uploads() && entry.device {
                skipped_h2d += 1;
            }
            if mode.downloads() && entry.device {
                skipped_d2h += 1;
            }
        }
        // Serialize the enqueue sequence for host-staged plans so two
        // threads sharing a handle cannot interleave their upload/kernel
        // ops on the stream.
        let _stage_guard = if spec.has_host { Some(spec.stage.lock().unwrap()) } else { None };
        for (arg, entry) in args.iter().zip(&spec.plan) {
            if effective_mode(self.policy, entry.mode).uploads() && !entry.device {
                let bytes = arg.host_tensor().expect("host plan entry").bytes().to_vec();
                stream.copy_h2d(spec.pool.clone(), entry.ptr, bytes)?;
            }
        }
        // Owned, patched argument vector: moves into the stream closure.
        let mut kargs = spec.kernel_args.clone();
        for &(ai, ki) in &spec.patches {
            kargs[ki] = KernelArg::Ptr(args[ai].device_ptr().expect("validated device entry"));
        }
        let launch_cfg = spec.config.unwrap_or(cfg);
        let function = spec.function.clone();
        let pool = spec.pool.clone();
        let metrics = self.metrics.clone();
        let error: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let slot = error.clone();
        let ordinal = spec.ordinal;
        stream.enqueue(move || {
            if hang {
                let e = crate::driver::faults::hang_until_lost(ordinal);
                *slot.lock().unwrap() = Some(e.to_string());
                return Err(e);
            }
            match function.launch_report(&launch_cfg, &kargs, &pool) {
                Ok(report) => {
                    let mut m = metrics.lock().unwrap();
                    m.skipped_h2d += skipped_h2d;
                    m.skipped_d2h += skipped_d2h;
                    absorb_report(&mut m, &report);
                    Ok(())
                }
                Err(e) => {
                    *slot.lock().unwrap() = Some(e.to_string());
                    Err(e)
                }
            }
        })?;
        let event = Event::new();
        stream.record_event(&event)?;
        Ok(PendingLaunch { stream, event, error, ordinal })
    }

    /// Enqueue an asynchronous readback of `array` on `stream` and
    /// return a [`PendingDownload`] resolving to the array's contents —
    /// the natural tail of a `launch_on` chain (enqueue it on the same
    /// stream after the producing kernel, or fence another stream with
    /// the launch's [`Event`] first). Identical to
    /// [`crate::coordinator::DeviceArray::download_on`] except the
    /// readback is counted in this handle's [`LaunchMetrics`]
    /// (`d2h_deferred` / `features_bytes`), so pipelines can assert how
    /// many bytes their result path actually moves.
    pub fn download_on<'s>(
        &self,
        stream: &'s Stream,
        array: &crate::coordinator::DeviceArray,
    ) -> Result<PendingDownload<'s>> {
        let pd = array.download_on(stream)?;
        let mut m = self.metrics.lock().unwrap();
        m.d2h_deferred += 1;
        m.features_bytes += array.byte_len() as u64;
        Ok(pd)
    }

    /// Migrate this bound handle to another device: re-resolve and
    /// re-specialize the bound call shape against `target`'s registry
    /// and module cache, and return a new handle whose plan (staging
    /// buffers, patched pointers, launch config) lives entirely in the
    /// target context. Repeated migrations of same-shaped handles hit
    /// the target's specialization cache. The handle's *data* does not
    /// move with it — migrate the backing `DeviceArray`s with
    /// [`crate::coordinator::DeviceArray::migrate_to`]; launching the
    /// migrated handle with un-migrated arrays fails with the
    /// foreign-context error naming both ordinals. Migrating to the
    /// handle's own context returns a plain clone.
    pub fn migrate_to(&self, target: &mut Launcher) -> Result<KernelHandle> {
        use crate::coordinator::arg;

        let spec = &*self.spec;
        let tpool = target.ctx.memory_arc()?;
        if Arc::ptr_eq(&spec.pool, &tpool) {
            return Ok(self.clone());
        }
        // Rebuild the bound call shape. Specialization depends only on
        // dtype/shape/mode/residency, so host entries stage through
        // zeroed tensors and device entries through temporary arrays on
        // the target (freed again once the plan is built — device plan
        // entries hold no storage, their pointer is patched per launch).
        enum Backing {
            Host(crate::tensor::Tensor),
            Dev(crate::coordinator::DeviceArray),
        }
        let mut backing = Vec::with_capacity(spec.plan.len());
        for e in &spec.plan {
            backing.push(if e.device {
                Backing::Dev(crate::coordinator::DeviceArray::alloc(
                    target.context(),
                    e.dtype,
                    &e.shape,
                )?)
            } else {
                Backing::Host(crate::tensor::Tensor::new(
                    e.dtype,
                    &e.shape,
                    vec![0u8; e.byte_len],
                )?)
            });
        }
        let mut args: Vec<Arg<'_>> = Vec::with_capacity(spec.plan.len());
        for (e, b) in spec.plan.iter().zip(backing.iter_mut()) {
            args.push(match b {
                Backing::Host(t) => match e.mode {
                    ArgMode::In => arg::cu_in(t),
                    ArgMode::Out => arg::cu_out(t),
                    ArgMode::InOut => arg::cu_inout(t),
                    // plans never carry Auto (resolved at specialization)
                    ArgMode::Auto => arg::cu_inout(t),
                },
                Backing::Dev(d) => match e.mode {
                    ArgMode::In => arg::cu_dev(d),
                    _ => arg::cu_dev_mut(d),
                },
            });
        }
        target.bind(&self.kernel, &args)
    }
}

/// An in-flight stream-ordered launch: join it with
/// [`PendingLaunch::wait`], or fence another stream on its
/// [`PendingLaunch::event`] without blocking the host.
pub struct PendingLaunch<'s> {
    stream: &'s Stream,
    event: Event,
    error: Arc<Mutex<Option<String>>>,
    ordinal: usize,
}

impl PendingLaunch<'_> {
    /// Event recorded immediately after the kernel on the stream — pass
    /// it to [`Stream::wait_event`] to order another stream's work after
    /// this launch.
    pub fn event(&self) -> &Event {
        &self.event
    }

    /// `cuEventQuery` semantics: has the launch (and everything before it
    /// on the stream) completed?
    pub fn is_done(&self) -> bool {
        self.event.query()
    }

    /// Block until the launch has completed and surface its error, or —
    /// CUDA's sticky-error model — any earlier failure on the stream.
    /// With `HLGPU_WATCHDOG_MS` set the join is bounded: a launch that
    /// has not completed within the budget loses its device
    /// ([`crate::Error::DeviceLost`]) instead of wedging the caller.
    pub fn wait(self) -> Result<()> {
        match crate::driver::faults::watchdog_ms() {
            Some(ms) => self.wait_timeout(Duration::from_millis(ms)),
            None => self.join(),
        }
    }

    /// Bounded join — the launch watchdog. Waits up to `timeout` for
    /// the launch to complete; on timeout the device is marked lost and
    /// the join fails with [`crate::Error::DeviceLost`]. A hung op
    /// observes the loss and unwedges itself, so the stream worker
    /// recovers rather than carrying the hang forever.
    pub fn wait_timeout(self, timeout: Duration) -> Result<()> {
        if !self.event.wait_timeout(timeout) {
            crate::driver::faults::mark_lost(self.ordinal);
            return Err(Error::DeviceLost(self.ordinal));
        }
        self.join()
    }

    fn join(self) -> Result<()> {
        self.event.synchronize();
        // One `sync` fault-site operation per join (shared with
        // `PendingDownload::wait`); a lost ordinal fails fast here.
        crate::driver::faults::on_sync(self.ordinal)?;
        if let Some(msg) = self.error.lock().unwrap().take() {
            return Err(Error::Stream(msg));
        }
        if let Some(msg) = self.stream.peek_error() {
            return Err(Error::Stream(msg));
        }
        Ok(())
    }
}

/// An in-flight stream-ordered **device→host readback** (launch API v2):
/// the async counterpart of `DeviceArray::download`. Obtained from
/// [`KernelHandle::download_on`] or
/// [`crate::coordinator::DeviceArray::download_on`]; the copy runs in
/// stream order (after every kernel enqueued before it), and
/// [`PendingDownload::wait`] blocks until it lands, surfaces any sticky
/// stream error, and hands back the bytes as a typed
/// [`Tensor`](crate::tensor::Tensor). Fence another stream's consumer on
/// [`PendingDownload::event`] via [`Stream::wait_event`] to chain
/// without blocking the host.
pub struct PendingDownload<'s> {
    pub(crate) stream: &'s Stream,
    pub(crate) event: Event,
    pub(crate) bytes: Arc<Mutex<Vec<u8>>>,
    pub(crate) dtype: crate::tensor::Dtype,
    pub(crate) shape: Vec<usize>,
    pub(crate) ordinal: usize,
}

impl PendingDownload<'_> {
    /// Event recorded immediately after the copy on the stream.
    pub fn event(&self) -> &Event {
        &self.event
    }

    /// `cuEventQuery` semantics: has the copy (and everything before it
    /// on the stream) completed?
    pub fn is_done(&self) -> bool {
        self.event.query()
    }

    /// Block until the copy has landed and return the tensor — or, per
    /// the sticky-error model, the first failure of anything enqueued on
    /// the stream so far (a trapped kernel upstream poisons the
    /// readback; the bytes would be garbage).
    /// Respects the `HLGPU_WATCHDOG_MS` launch watchdog the same way
    /// [`PendingLaunch::wait`] does: a readback stuck behind a hung
    /// kernel becomes [`crate::Error::DeviceLost`] instead of a wedge.
    pub fn wait(self) -> Result<crate::tensor::Tensor> {
        if let Some(ms) = crate::driver::faults::watchdog_ms() {
            if !self.event.wait_timeout(Duration::from_millis(ms)) {
                crate::driver::faults::mark_lost(self.ordinal);
                return Err(Error::DeviceLost(self.ordinal));
            }
        }
        self.event.synchronize();
        // Same `sync` fault site as `PendingLaunch`: one operation per join.
        crate::driver::faults::on_sync(self.ordinal)?;
        if let Some(msg) = self.stream.peek_error() {
            return Err(Error::Stream(msg));
        }
        let data = std::mem::take(&mut *self.bytes.lock().unwrap());
        crate::tensor::Tensor::new(self.dtype, &self.shape, data)
    }
}

/// The `@cuda` macro analog: `cuda!(launcher, (grid, block), kernel(args...))`.
///
/// Mirrors the paper's Listing 3 call syntax:
/// `@cuda (len, 1) vadd(CuIn(a), CuIn(b), CuOut(c))`.
///
/// The kernel may be a bare identifier or a string literal
/// (`cuda!(l, (1, n), "sinogram_all"(...))` — useful for names that are
/// not rust identifiers). Grid/block dimensions are converted with
/// checked arithmetic: values exceeding `u32::MAX` return
/// [`Error::BadArgument`](crate::Error::BadArgument) instead of silently
/// truncating.
#[macro_export]
macro_rules! cuda {
    ($launcher:expr, ($grid:expr, $block:expr), $kernel:ident ( $($arg:expr),* $(,)? )) => {
        $crate::coordinator::launch::checked_cfg(stringify!($kernel), $grid, $block)
            .and_then(|cfg| $launcher.launch(stringify!($kernel), cfg, &mut [$($arg),*]))
    };
    ($launcher:expr, ($grid:expr, $block:expr), $kernel:literal ( $($arg:expr),* $(,)? )) => {
        $crate::coordinator::launch::checked_cfg($kernel, $grid, $block)
            .and_then(|cfg| $launcher.launch($kernel, cfg, &mut [$($arg),*]))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{arg, DeviceArray};
    use crate::emulator::kernels;
    use crate::tensor::Tensor;

    fn emulator_launcher_with_vadd() -> Launcher {
        let mut l = Launcher::emulator().unwrap();
        l.registry_mut().register_vtx("vadd", |specs| {
            let n = specs[0].numel();
            Ok(VtxSpec {
                kernel: kernels::vadd()?,
                scalars: vec![KernelArg::I32(n as i32)],
                config: LaunchConfig::new(((n as u32) + 255) / 256, 256u32),
            })
        });
        l
    }

    /// The watchdog path in isolation: an event that never records (a
    /// hung kernel) times out, marks the ordinal lost, and returns the
    /// typed loss. Uses a synthesized high ordinal so the sticky mark
    /// cannot perturb tests running in parallel on real ordinals.
    #[test]
    fn watchdog_timeout_marks_device_lost() {
        let ord = 9_070usize;
        let stream = Stream::new();
        let pending = PendingLaunch {
            stream: &stream,
            event: Event::new(),
            error: Arc::new(Mutex::new(None)),
            ordinal: ord,
        };
        let err = pending.wait_timeout(Duration::from_millis(25)).unwrap_err();
        assert!(matches!(err, Error::DeviceLost(o) if o == ord), "{err}");
        assert!(crate::driver::faults::is_lost(ord));
        crate::driver::faults::reset_device(ord);
        // A recorded event joins normally within the budget.
        let ev = Event::new();
        ev.record_now();
        let pending = PendingLaunch {
            stream: &stream,
            event: ev,
            error: Arc::new(Mutex::new(None)),
            ordinal: ord,
        };
        pending.wait_timeout(Duration::from_millis(25)).unwrap();
        assert!(!crate::driver::faults::is_lost(ord));
    }

    #[test]
    fn automation_roundtrip_on_emulator() {
        let mut l = emulator_launcher_with_vadd();
        let a = Tensor::from_f32(&[1., 2., 3., 4.], &[4]);
        let b = Tensor::from_f32(&[10., 20., 30., 40.], &[4]);
        let mut c = Tensor::zeros_f32(&[4]);
        cuda!(l, (1, 4), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        assert_eq!(c.as_f32(), &[11., 22., 33., 44.]);
    }

    #[test]
    fn string_literal_kernel_names_work() {
        let mut l = emulator_launcher_with_vadd();
        let a = Tensor::from_f32(&[1.0; 4], &[4]);
        let b = Tensor::from_f32(&[2.0; 4], &[4]);
        let mut c = Tensor::zeros_f32(&[4]);
        cuda!(l, (1, 4), "vadd"(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        assert!(c.as_f32().iter().all(|&v| v == 3.0));
    }

    #[test]
    fn oversized_dims_error_instead_of_truncating() {
        let mut l = emulator_launcher_with_vadd();
        let a = Tensor::from_f32(&[1.0; 4], &[4]);
        let b = Tensor::from_f32(&[2.0; 4], &[4]);
        let mut c = Tensor::zeros_f32(&[4]);
        // u32::MAX + 1 used to truncate to grid 0 via `as u32`
        let big: u64 = u64::from(u32::MAX) + 1;
        let err = cuda!(l, (big, 4), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))
            .unwrap_err();
        assert!(matches!(err, Error::BadArgument { .. }), "{err}");
        assert!(err.to_string().contains("grid dimension"), "{err}");
        let err = cuda!(l, (1, big), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))
            .unwrap_err();
        assert!(err.to_string().contains("block dimension"), "{err}");
        // in-range values still go through unscathed
        cuda!(l, (1u64, 4usize), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))
            .unwrap();
        assert!(c.as_f32().iter().all(|&v| v == 3.0));
    }

    #[test]
    fn checked_cfg2_rejects_overflow_in_either_grid_dim() {
        // the batched pipelines' 2-D grids ((angles, batch)) route
        // through this; a dimension past u32::MAX must error, not wrap
        let big: u64 = u64::from(u32::MAX) + 1;
        let err = checked_cfg2("batched_sinogram", (big, 4u64), 8u64).unwrap_err();
        assert!(matches!(err, Error::BadArgument { .. }), "{err}");
        assert!(err.to_string().contains("grid x dimension"), "{err}");
        let err = checked_cfg2("batched_sinogram", (4u64, big), 8u64).unwrap_err();
        assert!(err.to_string().contains("grid y dimension"), "{err}");
        let err = checked_cfg2("batched_sinogram", (4u64, 4u64), big).unwrap_err();
        assert!(err.to_string().contains("block dimension"), "{err}");
        // in-range usize dims pass through to the 2-D grid unchanged
        let cfg = checked_cfg2("batched_sinogram", (6usize, 3usize), 12usize).unwrap();
        assert_eq!((cfg.grid.x, cfg.grid.y, cfg.block.x), (6, 3, 12));
    }

    #[test]
    fn cache_hit_on_second_call_miss_on_new_signature() {
        let mut l = emulator_launcher_with_vadd();
        let a = Tensor::from_f32(&[1.0; 8], &[8]);
        let b = Tensor::from_f32(&[2.0; 8], &[8]);
        let mut c = Tensor::zeros_f32(&[8]);
        cuda!(l, (1, 8), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        cuda!(l, (1, 8), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        let st = l.cache_stats();
        assert_eq!(st.misses, 1);
        assert_eq!(st.hits, 1);
        assert_eq!(l.metrics().cold_specializations, 1);

        // new length -> new specialization
        let a2 = Tensor::from_f32(&[1.0; 16], &[16]);
        let b2 = Tensor::from_f32(&[2.0; 16], &[16]);
        let mut c2 = Tensor::zeros_f32(&[16]);
        cuda!(l, (1, 16), vadd(arg::cu_in(&a2), arg::cu_in(&b2), arg::cu_out(&mut c2))).unwrap();
        assert_eq!(l.metrics().cold_specializations, 2);
        assert_eq!(c2.as_f32()[0], 3.0);
    }

    #[test]
    fn bound_handle_launches_without_cache_traffic() {
        let mut l = emulator_launcher_with_vadd();
        let a = Tensor::from_f32(&[1.0; 32], &[32]);
        let b = Tensor::from_f32(&[2.0; 32], &[32]);
        let mut c = Tensor::zeros_f32(&[32]);
        let handle = l
            .bind("vadd", &[arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)])
            .unwrap();
        let before = l.cache_stats();
        let cfg = LaunchConfig::new(1u32, 32u32);
        for _ in 0..10 {
            handle
                .launch(cfg, &mut [arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)])
                .unwrap();
        }
        assert!(c.as_f32().iter().all(|&v| v == 3.0));
        let after = l.cache_stats();
        assert_eq!(before.hits, after.hits, "handle launches read no cache");
        assert_eq!(before.misses, after.misses);
        assert_eq!(l.metrics().launches, 10);
    }

    #[test]
    fn handle_rejects_mismatched_call_shapes() {
        let mut l = emulator_launcher_with_vadd();
        let a = Tensor::from_f32(&[1.0; 8], &[8]);
        let b = Tensor::from_f32(&[2.0; 8], &[8]);
        let mut c = Tensor::zeros_f32(&[8]);
        let handle = l
            .bind("vadd", &[arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)])
            .unwrap();
        let cfg = LaunchConfig::new(1u32, 8u32);
        // wrong arity: the v1 warm path zip-truncated this silently
        let err = handle.launch(cfg, &mut [arg::cu_in(&a)]).unwrap_err();
        assert!(matches!(err, Error::BadArgument { .. }), "{err}");
        assert!(err.to_string().contains("transfer plan"), "{err}");
        // wrong byte length
        let short = Tensor::from_f32(&[1.0; 4], &[4]);
        let err = handle
            .launch(cfg, &mut [arg::cu_in(&short), arg::cu_in(&b), arg::cu_out(&mut c)])
            .unwrap_err();
        assert!(matches!(err, Error::BadArgument { .. }), "{err}");
        // wrong residency
        let ctx = l.context().clone();
        let dev_a = DeviceArray::from_tensor(&ctx, &a).unwrap();
        let err = handle
            .launch(cfg, &mut [arg::cu_dev(&dev_a), arg::cu_in(&b), arg::cu_out(&mut c)])
            .unwrap_err();
        assert!(err.to_string().contains("host argument"), "{err}");
    }

    #[test]
    fn handle_rejects_mismatched_transfer_modes() {
        // Regression: the handle path has no cache key, so a call whose
        // wrapper direction differs from the bound plan must error
        // instead of silently running the plan's transfers.
        let mut l = emulator_launcher_with_vadd();
        let a = Tensor::from_f32(&[1.0; 8], &[8]);
        let b = Tensor::from_f32(&[2.0; 8], &[8]);
        let mut c = Tensor::zeros_f32(&[8]);
        let handle = l
            .bind("vadd", &[arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)])
            .unwrap();
        let cfg = LaunchConfig::new(1u32, 8u32);
        let mut a2 = Tensor::from_f32(&[1.0; 8], &[8]);
        let err = handle
            .launch(cfg, &mut [arg::cu_inout(&mut a2), arg::cu_in(&b), arg::cu_out(&mut c)])
            .unwrap_err();
        assert!(matches!(err, Error::BadArgument { .. }), "{err}");
        assert!(err.to_string().contains("transfer"), "{err}");
        // Auto call modes stay accepted — the plan's resolved mode is
        // exactly what Auto defers to.
        handle
            .launch(cfg, &mut [arg::cu_auto(&mut a2), arg::cu_in(&b), arg::cu_out(&mut c)])
            .unwrap();
        assert!(c.as_f32().iter().all(|&v| v == 3.0));
    }

    #[test]
    fn device_resident_args_skip_all_transfers() {
        let mut l = emulator_launcher_with_vadd();
        let ctx = l.context().clone();
        let a = Tensor::from_f32(&[1.0; 16], &[16]);
        let b = Tensor::from_f32(&[2.0; 16], &[16]);
        let da = DeviceArray::from_tensor(&ctx, &a).unwrap();
        let db = DeviceArray::from_tensor(&ctx, &b).unwrap();
        let mut dc = DeviceArray::alloc(&ctx, crate::tensor::Dtype::F32, &[16]).unwrap();
        ctx.memory().unwrap().reset_stats();
        let cfg = LaunchConfig::new(1u32, 16u32);
        l.launch(
            "vadd",
            cfg,
            &mut [arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)],
        )
        .unwrap();
        let st = ctx.mem_stats().unwrap();
        assert_eq!(st.h2d_count, 0, "device-resident args upload nothing");
        assert_eq!(st.d2h_count, 0, "results stay on device");
        let m = l.metrics();
        assert_eq!(m.skipped_h2d, 3, "a, b (In) + c (InOut) skipped uploads");
        assert_eq!(m.skipped_d2h, 1, "c (InOut) skipped download");
        // the result is there when the host finally asks
        let out = dc.download().unwrap();
        assert!(out.as_f32().iter().all(|&v| v == 3.0));
    }

    #[test]
    fn device_args_from_foreign_context_rejected() {
        let mut l = emulator_launcher_with_vadd();
        let other = Context::create(&crate::driver::emulator_device().unwrap()).unwrap();
        let t = Tensor::from_f32(&[1.0; 8], &[8]);
        let foreign = DeviceArray::from_tensor(&other, &t).unwrap();
        let b = Tensor::from_f32(&[2.0; 8], &[8]);
        let mut c = Tensor::zeros_f32(&[8]);
        let err = l
            .launch(
                "vadd",
                LaunchConfig::new(1u32, 8u32),
                &mut [arg::cu_dev(&foreign), arg::cu_in(&b), arg::cu_out(&mut c)],
            )
            .unwrap_err();
        assert!(err.to_string().contains("different context"), "{err}");
    }

    #[test]
    fn minimal_policy_moves_fewer_bytes_than_naive() {
        let mut l = emulator_launcher_with_vadd();
        let a = Tensor::from_f32(&[1.0; 256], &[256]);
        let b = Tensor::from_f32(&[2.0; 256], &[256]);
        let mut c = Tensor::zeros_f32(&[256]);

        cuda!(l, (1, 256), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        let minimal = l.context().mem_stats().unwrap();

        l.set_policy(TransferPolicy::Naive);
        l.context().memory().unwrap().reset_stats();
        cuda!(l, (1, 256), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        let naive = l.context().mem_stats().unwrap();

        // minimal: 2 uploads + 1 download; naive: 3 + 3
        assert_eq!(minimal.h2d_count, 2);
        assert_eq!(minimal.d2h_count, 1);
        assert_eq!(naive.h2d_count, 3);
        assert_eq!(naive.d2h_count, 3);
        assert!(naive.h2d_bytes + naive.d2h_bytes > minimal.h2d_bytes + minimal.d2h_bytes);
    }

    #[test]
    fn auto_arguments_inferred_from_vtx_dataflow() {
        // §9 future work: no CuIn/CuOut wrappers at all — the framework
        // derives the transfer plan from the kernel body.
        let mut l = emulator_launcher_with_vadd();
        let mut a = Tensor::from_f32(&[1.0; 64], &[64]);
        let mut b = Tensor::from_f32(&[2.0; 64], &[64]);
        let mut c = Tensor::zeros_f32(&[64]);
        l.launch(
            "vadd",
            LaunchConfig::new(1u32, 64u32),
            &mut [arg::cu_auto(&mut a), arg::cu_auto(&mut b), arg::cu_auto(&mut c)],
        )
        .unwrap();
        assert!(c.as_f32().iter().all(|&v| v == 3.0));
        // inference produced the minimal plan: 2 uploads, 1 download
        let st = l.context().mem_stats().unwrap();
        assert_eq!(st.h2d_count, 2, "a and b are read-only -> CuIn");
        assert_eq!(st.d2h_count, 1, "c is write-only -> CuOut");
        // inputs were not clobbered by a download
        assert!(a.as_f32().iter().all(|&v| v == 1.0));
    }

    #[test]
    fn infer_param_usage_detects_all_classes() {
        use crate::emulator::isa::ParamUsage;
        let k = kernels::vadd().unwrap();
        assert_eq!(
            k.infer_param_usage(),
            vec![ParamUsage::ReadOnly, ParamUsage::ReadOnly, ParamUsage::WriteOnly]
        );
        let s = kernels::sinogram_all().unwrap();
        assert_eq!(
            s.infer_param_usage(),
            vec![ParamUsage::ReadOnly, ParamUsage::ReadOnly, ParamUsage::WriteOnly]
        );
    }

    #[test]
    fn metrics_track_emulator_block_scheduler() {
        let mut l = emulator_launcher_with_vadd();
        let n = 1024usize;
        let a = Tensor::from_f32(&vec![1.0; n], &[n]);
        let b = Tensor::from_f32(&vec![2.0; n], &[n]);
        let mut c = Tensor::zeros_f32(&[n]);
        cuda!(l, (1, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        // provider picks grid = ceil(n/256) = 4 blocks
        let m = l.metrics();
        assert_eq!(m.blocks_executed, 4);
        assert!(m.peak_workers >= 1);
        assert!(m.exec_wall_ns > 0);
        // execution-engine counters flow through, whatever the tier
        assert!(m.instrs_retired > 0);
        assert!(m.dispatches > 0);
        assert!(m.dispatches <= m.instrs_retired);
        assert!(m.fused_share() >= 0.0 && m.fused_share() <= 1.0);
        assert!(m.vector_lane_utilization() >= 0.0 && m.vector_lane_utilization() <= 1.0);
        cuda!(l, (1, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c))).unwrap();
        assert_eq!(l.metrics().blocks_executed, 8, "blocks accumulate per launch");
    }

    #[test]
    fn metrics_track_execution_tiers() {
        use crate::emulator::{set_default_exec, ExecTier};
        let _g = crate::emulator::sched::exec_override_test_lock();
        // Vector tier: fused superinstructions retire and lanes occupy
        // slots; scalar tier: one dispatch per instruction, no fusion.
        // Both retire the same instruction count.
        let mk = || {
            let mut l = emulator_launcher_with_vadd();
            let a = Tensor::from_f32(&[1.0; 256], &[256]);
            let b = Tensor::from_f32(&[2.0; 256], &[256]);
            let mut c = Tensor::zeros_f32(&[256]);
            cuda!(l, (1, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))
                .unwrap();
            assert!(c.as_f32().iter().all(|&v| v == 3.0));
            l.metrics()
        };
        set_default_exec(Some(ExecTier::Vector));
        let vector = mk();
        set_default_exec(Some(ExecTier::Scalar));
        let scalar = mk();
        set_default_exec(None);
        assert_eq!(vector.instrs_retired, scalar.instrs_retired);
        assert!(vector.fused_instrs > 0, "vadd's index prologue must fuse");
        assert_eq!(scalar.fused_instrs, 0);
        assert!(vector.dispatches < scalar.dispatches, "dispatch amortization");
        assert!(vector.vector_lane_utilization() > 0.0);
        assert_eq!(scalar.vector_lane_slots, 0);
    }

    #[test]
    fn unregistered_kernel_fails_to_specialize() {
        let mut l = Launcher::emulator().unwrap();
        let a = Tensor::zeros_f32(&[4]);
        let err = l
            .launch(
                "ghost",
                LaunchConfig::new(1u32, 4u32),
                &mut [arg::cu_in(&a)],
            )
            .unwrap_err();
        assert!(matches!(err, Error::Specialize { .. }), "{err}");
    }
}
