//! The coordinator: the paper's **high-level automation** layer (§6).
//!
//! * [`args`] — `CuIn`/`CuOut`/`CuInOut` wrappers (§6.3);
//! * [`cache`] — the per-signature specialization cache (the Julia method
//!   cache, §6.2);
//! * [`registry`] — logical-kernel resolution: AOT artifacts for the PJRT
//!   device, generated VTX kernels for the emulator device;
//! * [`launch`] — [`Launcher`] + the [`crate::cuda!`] macro, the
//!   `@cuda (grid, block) kernel(args...)` front-end, plus the v2
//!   surface: [`Launcher::bind`] → [`KernelHandle`] (zero cache traffic
//!   on the warm path) and [`KernelHandle::launch_on`] →
//!   [`PendingLaunch`] (stream-ordered async launches) — see
//!   `docs/api.md`;
//! * [`devarray`] — `CuArray`-style device-resident arrays; first-class
//!   launch arguments via [`arg::cu_dev`] / [`arg::cu_dev_mut`];
//! * [`replicated`] — read-only inputs replicated lazily across the
//!   members of a [`DeviceSet`](crate::driver::DeviceSet) (see
//!   `docs/devices.md`).

pub mod args;
pub mod cache;
pub mod devarray;
pub mod launch;
pub mod registry;
pub mod replicated;

pub use args::{call_signature, input_signature, Arg, ArgMode};
pub use cache::{CacheStats, SpecializationCache};
pub use devarray::DeviceArray;
pub use replicated::ReplicatedArray;
pub use launch::{
    checked_cfg, checked_cfg2, KernelHandle, LaunchMetrics, Launcher, PendingDownload,
    PendingLaunch, TransferPolicy,
};
pub use registry::{KernelRegistry, KernelSource, VtxSpec};

/// Argument constructors, idiomatically imported as `coordinator::arg`.
pub mod arg {
    pub use super::args::{cu_auto, cu_dev, cu_dev_mut, cu_in, cu_inout, cu_out};
}
