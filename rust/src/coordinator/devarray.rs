//! `DeviceArray` — the `CuArray` analog of the *manual* API path
//! (paper Listing 2): an RAII device buffer with typed upload/download.
//! Used by the "+ CUDA" benchmark implementations that drive the driver
//! API by hand, without the automation layer.

use crate::driver::{Context, DevicePtr};
use crate::error::{Error, Result};
use crate::tensor::{Dtype, Tensor};

/// A device-resident array tied to a context.
pub struct DeviceArray {
    ctx: Context,
    ptr: DevicePtr,
    dtype: Dtype,
    shape: Vec<usize>,
    freed: bool,
}

impl DeviceArray {
    /// `CuArray(Float32, dims)`: allocate uninitialized.
    pub fn alloc(ctx: &Context, dtype: Dtype, shape: &[usize]) -> Result<DeviceArray> {
        let numel: usize = shape.iter().product();
        let ptr = ctx.alloc(numel * dtype.size_of())?;
        Ok(DeviceArray {
            ctx: ctx.clone(),
            ptr,
            dtype,
            shape: shape.to_vec(),
            freed: false,
        })
    }

    /// `CuArray(host)`: allocate + upload.
    pub fn from_tensor(ctx: &Context, t: &Tensor) -> Result<DeviceArray> {
        let arr = Self::alloc(ctx, t.dtype(), t.shape())?;
        arr.upload(t)?;
        Ok(arr)
    }

    pub fn ptr(&self) -> DevicePtr {
        self.ptr
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    pub fn byte_len(&self) -> usize {
        self.shape.iter().product::<usize>() * self.dtype.size_of()
    }

    pub fn upload(&self, t: &Tensor) -> Result<()> {
        if t.shape() != self.shape.as_slice() || t.dtype() != self.dtype {
            return Err(Error::Type(format!(
                "upload shape mismatch: host {} vs device {:?}",
                t.signature(),
                self.shape
            )));
        }
        self.ctx.upload(self.ptr, t.bytes())
    }

    /// `to_host(gpu_array)`.
    pub fn download(&self) -> Result<Tensor> {
        let mut t = match self.dtype {
            Dtype::F32 => Tensor::zeros_f32(&self.shape),
            other => {
                return Err(Error::Type(format!(
                    "download of {other:?} arrays not supported"
                )))
            }
        };
        self.ctx.download(self.ptr, t.bytes_mut())?;
        Ok(t)
    }

    pub fn download_into(&self, t: &mut Tensor) -> Result<()> {
        if t.shape() != self.shape.as_slice() || t.dtype() != self.dtype {
            return Err(Error::Type("download shape mismatch".into()));
        }
        self.ctx.download(self.ptr, t.bytes_mut())
    }

    /// Explicit `free` (Listing 2 line 30). Otherwise freed on drop.
    pub fn free(mut self) -> Result<()> {
        self.freed = true;
        self.ctx.free(self.ptr)
    }
}

impl Drop for DeviceArray {
    fn drop(&mut self) {
        if !self.freed && self.ctx.is_alive() {
            let _ = self.ctx.free(self.ptr);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::device;

    fn ctx() -> Context {
        Context::create(&device::device(1).unwrap()).unwrap()
    }

    #[test]
    fn upload_download_roundtrip() {
        let ctx = ctx();
        let t = Tensor::from_f32(&[1.5, -2.5, 3.0], &[3]);
        let d = DeviceArray::from_tensor(&ctx, &t).unwrap();
        let back = d.download().unwrap();
        assert_eq!(back.as_f32(), t.as_f32());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let ctx = ctx();
        let d = DeviceArray::alloc(&ctx, Dtype::F32, &[4]).unwrap();
        let wrong = Tensor::zeros_f32(&[5]);
        assert!(d.upload(&wrong).is_err());
    }

    #[test]
    fn raii_frees_on_drop() {
        let ctx = ctx();
        {
            let _d = DeviceArray::alloc(&ctx, Dtype::F32, &[64]).unwrap();
            assert_eq!(ctx.memory().unwrap().live_buffers(), 1);
        }
        assert_eq!(ctx.memory().unwrap().live_buffers(), 0);
    }

    #[test]
    fn explicit_free_prevents_double_free_on_drop() {
        let ctx = ctx();
        let d = DeviceArray::alloc(&ctx, Dtype::F32, &[8]).unwrap();
        d.free().unwrap();
        assert_eq!(ctx.memory().unwrap().live_buffers(), 0);
        assert_eq!(ctx.mem_stats().unwrap().free_count, 1);
    }
}
