//! `DeviceArray` — the `CuArray` analog of the *manual* API path
//! (paper Listing 2): an RAII device buffer with typed upload/download.
//! Used by the "+ CUDA" benchmark implementations that drive the driver
//! API by hand, without the automation layer.

use crate::driver::{Context, DevicePtr};
use crate::error::{Error, Result};
use crate::tensor::{Dtype, Tensor};

/// A device-resident array tied to a context.
pub struct DeviceArray {
    ctx: Context,
    ptr: DevicePtr,
    dtype: Dtype,
    shape: Vec<usize>,
    freed: bool,
}

impl DeviceArray {
    /// `CuArray(Float32, dims)`: allocate uninitialized. The byte length
    /// is computed with checked arithmetic: a crafted shape like
    /// `[usize::MAX, 2]` would otherwise wrap and allocate a tiny buffer
    /// that later transfers would overrun.
    pub fn alloc(ctx: &Context, dtype: Dtype, shape: &[usize]) -> Result<DeviceArray> {
        Self::alloc_in(ctx, 0, dtype, shape)
    }

    /// `CuArray(Float32, dims)` in a specific pool arena — pass a
    /// [`Stream::arena_id`](crate::driver::Stream::arena_id) so a
    /// stream-ordered pipeline's buffers live in their own allocator
    /// shard (see `docs/memory.md`).
    pub fn alloc_in(
        ctx: &Context,
        arena: usize,
        dtype: Dtype,
        shape: &[usize],
    ) -> Result<DeviceArray> {
        let numel = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| {
                Error::Type(format!("element count of shape {shape:?} overflows usize"))
            })?;
        let bytes = numel.checked_mul(dtype.size_of()).ok_or_else(|| {
            Error::Type(format!(
                "byte length of {} x {shape:?} overflows usize",
                dtype.name()
            ))
        })?;
        let ptr = ctx.alloc_in(arena, bytes)?;
        Ok(DeviceArray {
            ctx: ctx.clone(),
            ptr,
            dtype,
            shape: shape.to_vec(),
            freed: false,
        })
    }

    /// `CuArray(host)`: allocate + upload.
    pub fn from_tensor(ctx: &Context, t: &Tensor) -> Result<DeviceArray> {
        let arr = Self::alloc(ctx, t.dtype(), t.shape())?;
        arr.upload(t)?;
        Ok(arr)
    }

    /// Allocate + upload in a specific pool arena (the stream-pipeline
    /// variant of [`DeviceArray::from_tensor`]).
    pub fn from_tensor_in(ctx: &Context, arena: usize, t: &Tensor) -> Result<DeviceArray> {
        let arr = Self::alloc_in(ctx, arena, t.dtype(), t.shape())?;
        arr.upload(t)?;
        Ok(arr)
    }

    /// The context this array's storage belongs to.
    pub fn context(&self) -> &Context {
        &self.ctx
    }

    pub fn ptr(&self) -> DevicePtr {
        self.ptr
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    pub fn byte_len(&self) -> usize {
        self.shape.iter().product::<usize>() * self.dtype.size_of()
    }

    pub fn upload(&self, t: &Tensor) -> Result<()> {
        if t.shape() != self.shape.as_slice() || t.dtype() != self.dtype {
            return Err(Error::Type(format!(
                "upload shape mismatch: host {} vs device {:?}",
                t.signature(),
                self.shape
            )));
        }
        self.ctx.upload(self.ptr, t.bytes())
    }

    /// `to_host(gpu_array)`. Dispatches on the array's dtype, like
    /// `alloc` does — device buffers are raw bytes, so every supported
    /// element type round-trips.
    pub fn download(&self) -> Result<Tensor> {
        let mut t = Tensor::zeros(self.dtype, &self.shape);
        self.ctx.download(self.ptr, t.bytes_mut())?;
        Ok(t)
    }

    /// Asynchronous `to_host`: enqueue the download on `stream` (after
    /// everything already enqueued there — a kernel that produces this
    /// array on the same stream is observed) and return a
    /// [`PendingDownload`](crate::coordinator::PendingDownload) that
    /// resolves to the tensor on `wait()`. Sticky stream errors surface
    /// at the join, and the download's
    /// [`Event`](crate::driver::Event) composes with
    /// [`Stream::wait_event`](crate::driver::Stream::wait_event) for
    /// cross-stream chains. See `docs/api.md` (launch API v2).
    pub fn download_on<'s>(
        &self,
        stream: &'s crate::driver::Stream,
    ) -> Result<crate::coordinator::PendingDownload<'s>> {
        let pool = self.ctx.memory_arc()?;
        let bytes = std::sync::Arc::new(std::sync::Mutex::new(vec![0u8; self.byte_len()]));
        stream.copy_d2h(pool, self.ptr, bytes.clone())?;
        let event = crate::driver::Event::new();
        stream.record_event(&event)?;
        Ok(crate::coordinator::PendingDownload {
            stream,
            event,
            bytes,
            dtype: self.dtype,
            shape: self.shape.clone(),
            ordinal: self.ctx.device().ordinal,
        })
    }

    pub fn download_into(&self, t: &mut Tensor) -> Result<()> {
        if t.shape() != self.shape.as_slice() || t.dtype() != self.dtype {
            return Err(Error::Type("download shape mismatch".into()));
        }
        self.ctx.download(self.ptr, t.bytes_mut())
    }

    /// Migrate this array's contents to another context (`cuMemcpyPeer`
    /// analog, staged through the host: d2h from the home device, h2d
    /// into a fresh allocation on `target`). The source array is left
    /// intact — free it when the old replica is no longer needed.
    /// Migrating within one context produces an independent copy.
    pub fn migrate_to(&self, target: &Context) -> Result<DeviceArray> {
        let host = self.download()?;
        DeviceArray::from_tensor(target, &host)
    }

    /// Explicit `free` (Listing 2 line 30). Otherwise freed on drop.
    /// The array is only marked freed when the driver call succeeds — a
    /// failed free keeps the drop-time retry instead of silently leaking.
    pub fn free(mut self) -> Result<()> {
        self.free_inner()
    }

    fn free_inner(&mut self) -> Result<()> {
        self.ctx.free(self.ptr)?;
        self.freed = true;
        Ok(())
    }
}

impl Drop for DeviceArray {
    fn drop(&mut self) {
        if !self.freed && self.ctx.is_alive() {
            let _ = self.free_inner();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::emulator_device;

    fn ctx() -> Context {
        Context::create(&emulator_device().unwrap()).unwrap()
    }

    #[test]
    fn upload_download_roundtrip() {
        let ctx = ctx();
        let t = Tensor::from_f32(&[1.5, -2.5, 3.0], &[3]);
        let d = DeviceArray::from_tensor(&ctx, &t).unwrap();
        let back = d.download().unwrap();
        assert_eq!(back.as_f32(), t.as_f32());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let ctx = ctx();
        let d = DeviceArray::alloc(&ctx, Dtype::F32, &[4]).unwrap();
        let wrong = Tensor::zeros_f32(&[5]);
        assert!(d.upload(&wrong).is_err());
    }

    #[test]
    fn raii_frees_on_drop() {
        let ctx = ctx();
        {
            let _d = DeviceArray::alloc(&ctx, Dtype::F32, &[64]).unwrap();
            assert_eq!(ctx.memory().unwrap().live_buffers(), 1);
        }
        assert_eq!(ctx.memory().unwrap().live_buffers(), 0);
    }

    #[test]
    fn explicit_free_prevents_double_free_on_drop() {
        let ctx = ctx();
        let d = DeviceArray::alloc(&ctx, Dtype::F32, &[8]).unwrap();
        d.free().unwrap();
        assert_eq!(ctx.memory().unwrap().live_buffers(), 0);
        assert_eq!(ctx.mem_stats().unwrap().free_count, 1);
    }

    #[test]
    fn overflowing_shape_rejected() {
        // regression: numel/byte-length arithmetic used to wrap, turning
        // a crafted shape into a tiny allocation
        let ctx = ctx();
        let err = DeviceArray::alloc(&ctx, Dtype::F32, &[usize::MAX, 2]).unwrap_err();
        assert!(matches!(err, Error::Type(_)), "{err}");
        // numel fits but the byte length overflows
        let err = DeviceArray::alloc(&ctx, Dtype::F64, &[usize::MAX / 4]).unwrap_err();
        assert!(matches!(err, Error::Type(_)), "{err}");
        assert_eq!(ctx.memory().unwrap().live_buffers(), 0);
    }

    #[test]
    fn failed_free_does_not_mark_freed() {
        // regression: `free` used to set the freed flag before the driver
        // call, so a failed free was silently dropped with no retry
        let ctx = ctx();
        let mut d = DeviceArray::alloc(&ctx, Dtype::F32, &[4]).unwrap();
        // free the buffer behind the array's back: the array's own free
        // now fails (double free at the driver level)
        ctx.free(d.ptr).unwrap();
        assert!(d.free_inner().is_err());
        assert!(!d.freed, "failed free must keep the drop-time retry armed");
        // silence this intentionally-broken handle's drop retry
        d.freed = true;
    }

    #[test]
    fn download_on_resolves_to_the_uploaded_tensor() {
        let ctx = ctx();
        let t = Tensor::from_f32(&[4.0, 5.0, 6.0, 7.0], &[4]);
        let d = DeviceArray::from_tensor(&ctx, &t).unwrap();
        let s = ctx.create_stream().unwrap();
        let pd = d.download_on(&s).unwrap();
        let back = pd.wait().unwrap();
        assert_eq!(back.dtype(), Dtype::F32);
        assert_eq!(back.shape(), &[4]);
        assert_eq!(back.as_f32(), t.as_f32());
    }

    #[test]
    fn migrate_to_copies_across_contexts() {
        let a = ctx();
        let b = ctx();
        let t = Tensor::from_f32(&[9.0, 8.0, 7.0], &[3]);
        let d = DeviceArray::from_tensor(&a, &t).unwrap();
        let moved = d.migrate_to(&b).unwrap();
        assert_eq!(moved.download().unwrap().as_f32(), t.as_f32());
        // the source is untouched and both contexts hold one buffer
        assert_eq!(d.download().unwrap().as_f32(), t.as_f32());
        assert_eq!(a.memory().unwrap().live_buffers(), 1);
        assert_eq!(b.memory().unwrap().live_buffers(), 1);
    }

    #[test]
    fn download_dispatches_on_dtype() {
        // regression: download rejected everything but F32 even though
        // Dtype/Tensor support more
        let ctx = ctx();
        for dtype in [Dtype::F64, Dtype::I32] {
            let n = 6usize;
            let data: Vec<u8> = (0..n * dtype.size_of()).map(|i| i as u8).collect();
            let t = Tensor::new(dtype, &[n], data.clone()).unwrap();
            let d = DeviceArray::from_tensor(&ctx, &t).unwrap();
            let back = d.download().unwrap();
            assert_eq!(back.dtype(), dtype);
            assert_eq!(back.shape(), &[n]);
            assert_eq!(back.bytes(), data.as_slice());
        }
    }
}
