//! `ReplicatedArray` — a read-only input replicated across devices.
//!
//! Multi-device pipelines share read-only inputs (the trace pipeline's
//! angle table) across every member of a
//! [`DeviceSet`](crate::driver::DeviceSet). A `ReplicatedArray` holds
//! the host master copy and uploads **lazily, once per context**: the
//! first shard placed on a device pays the h2d, every later shard on
//! that device reuses the resident copy. Replicas are keyed by the
//! context's memory pool identity (two contexts on one ordinal are
//! distinct address spaces and each get their own copy).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::devarray::DeviceArray;
use crate::driver::Context;
use crate::error::Result;
use crate::tensor::Tensor;

/// A host tensor with lazily-uploaded per-context device replicas.
pub struct ReplicatedArray {
    master: Tensor,
    copies: Mutex<HashMap<usize, Arc<DeviceArray>>>,
}

impl ReplicatedArray {
    pub fn new(master: Tensor) -> ReplicatedArray {
        ReplicatedArray { master, copies: Mutex::new(HashMap::new()) }
    }

    /// The device replica for `ctx`, uploading on first use. Replicas
    /// are shared (`Arc`) — a shard borrows its local copy for the
    /// duration of a launch while the table stays cached here.
    pub fn on(&self, ctx: &Context) -> Result<Arc<DeviceArray>> {
        let key = Arc::as_ptr(&ctx.memory_arc()?) as usize;
        let mut copies = self.copies.lock().unwrap();
        if let Some(a) = copies.get(&key) {
            return Ok(a.clone());
        }
        let a = Arc::new(DeviceArray::from_tensor(ctx, &self.master)?);
        copies.insert(key, a.clone());
        Ok(a)
    }

    /// Number of device replicas uploaded so far.
    pub fn uploads(&self) -> usize {
        self.copies.lock().unwrap().len()
    }

    /// The host master copy.
    pub fn master(&self) -> &Tensor {
        &self.master
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::{emulator_device, Context};

    #[test]
    fn uploads_once_per_context() {
        let a = Context::create(&emulator_device().unwrap()).unwrap();
        let b = Context::create(&emulator_device().unwrap()).unwrap();
        let rep = ReplicatedArray::new(Tensor::from_f32(&[1.0, 2.0, 3.0], &[3]));
        assert_eq!(rep.uploads(), 0);

        let ra1 = rep.on(&a).unwrap();
        let ra2 = rep.on(&a).unwrap();
        assert_eq!(rep.uploads(), 1, "same context reuses the replica");
        assert_eq!(ra1.ptr(), ra2.ptr());

        let rb = rep.on(&b).unwrap();
        assert_eq!(rep.uploads(), 2, "second context pays its own upload");
        assert_eq!(rb.download().unwrap().as_f32(), rep.master().as_f32());
        assert_eq!(ra1.download().unwrap().as_f32(), rep.master().as_f32());
    }
}
