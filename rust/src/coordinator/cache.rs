//! The specialization cache — the paper's **method cache** (§6.1–6.2).
//!
//! "Each invocation of the @cuda macro and ensuing call to gen_launch are
//! only executed once for every set of argument types. The resulting code
//! is saved in a method cache, and reused in each subsequent invocation."
//!
//! Keys are `(kernel, call signature)`; values hold everything the warm
//! path needs: the compiled function handle, the precomputed transfer
//! plan, pre-allocated device scratch buffers and the launch
//! configuration. On the emulator backend the function handle also
//! caches the pre-decoded, basic-block-lowered and fused instruction
//! stream (see `crate::emulator::backend_impl::VtxFunction`), so a warm
//! launch skips decode, lowering and fusion. Read-mostly: `RwLock` +
//! `Arc` values so warm launches take only a shared lock.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Cache statistics (validated by the `specialization` bench).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

pub struct SpecializationCache<V> {
    map: RwLock<HashMap<String, Arc<V>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<V> Default for SpecializationCache<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> SpecializationCache<V> {
    pub fn new() -> Self {
        SpecializationCache {
            map: RwLock::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Cache key format: `kernel⎮call_signature`.
    pub fn key(kernel: &str, signature: &str) -> String {
        format!("{kernel}\u{1}{signature}")
    }

    pub fn get(&self, key: &str) -> Option<Arc<V>> {
        let found = self.map.read().unwrap().get(key).cloned();
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Insert (first writer wins under races — the paper's semantics:
    /// recompilation of the same signature yields identical code).
    pub fn insert(&self, key: String, value: V) -> Arc<V> {
        let mut map = self.map.write().unwrap();
        map.entry(key).or_insert_with(|| Arc::new(value)).clone()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.read().unwrap().len(),
        }
    }

    pub fn clear(&self) {
        self.map.write().unwrap().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit() {
        let c: SpecializationCache<u32> = SpecializationCache::new();
        let k = SpecializationCache::<u32>::key("vadd", "in:f32[12]");
        assert!(c.get(&k).is_none());
        c.insert(k.clone(), 7);
        assert_eq!(*c.get(&k).unwrap(), 7);
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
    }

    #[test]
    fn distinct_signatures_distinct_entries() {
        let c: SpecializationCache<u32> = SpecializationCache::new();
        c.insert(SpecializationCache::<u32>::key("k", "f32[1]"), 1);
        c.insert(SpecializationCache::<u32>::key("k", "f32[2]"), 2);
        c.insert(SpecializationCache::<u32>::key("j", "f32[1]"), 3);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn first_writer_wins() {
        let c: SpecializationCache<u32> = SpecializationCache::new();
        let k = "x".to_string();
        let a = c.insert(k.clone(), 1);
        let b = c.insert(k, 2);
        assert_eq!(*a, 1);
        assert_eq!(*b, 1, "racing insert must not replace");
    }

    #[test]
    fn clear_resets() {
        let c: SpecializationCache<u32> = SpecializationCache::new();
        c.insert("x".into(), 1);
        let _ = c.get("x");
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats().hits, 0);
    }
}
