//! Kernel registry: resolves a logical kernel name + call signature to
//! loadable code for the active backend.
//!
//! PJRT devices resolve against the AOT artifact manifest (JAX played the
//! role of the Julia→PTX code generator at build time); emulator devices
//! resolve against registered VTX *providers* — closures that, given the
//! concrete input shapes, author a specialized VTX kernel with the
//! builder DSL (generation at first use, exactly the paper's
//! generated-function flow).

use std::collections::HashMap;

use crate::driver::backend::TensorSpec;
use crate::driver::launch::{KernelArg, LaunchConfig};
use crate::emulator::isa::Kernel as VtxKernel;
use crate::error::{Error, Result};
use crate::runtime::{ArtifactEntry, ArtifactLibrary};

/// A fully specialized VTX kernel ready to load & launch.
pub struct VtxSpec {
    pub kernel: VtxKernel,
    /// Trailing scalar arguments appended after the tensor pointers.
    pub scalars: Vec<KernelArg>,
    /// Launch configuration chosen by the provider for these shapes.
    pub config: LaunchConfig,
}

/// Resolved kernel code.
pub enum KernelSource {
    /// AOT HLO artifact (PJRT path).
    Artifact(ArtifactEntry),
    /// Generated VTX kernel (emulator path).
    Vtx(VtxSpec),
}

type VtxProvider = Box<dyn Fn(&[TensorSpec]) -> Result<VtxSpec> + Send + Sync>;

/// Registry of kernels available to the automation layer.
pub struct KernelRegistry {
    library: Option<ArtifactLibrary>,
    vtx: HashMap<String, VtxProvider>,
}

impl KernelRegistry {
    pub fn new(library: Option<ArtifactLibrary>) -> Self {
        KernelRegistry { library, vtx: HashMap::new() }
    }

    /// Load the default artifact library from `artifacts/`.
    pub fn with_default_library() -> Result<Self> {
        Ok(Self::new(Some(ArtifactLibrary::load_default()?)))
    }

    pub fn library(&self) -> Option<&ArtifactLibrary> {
        self.library.as_ref()
    }

    /// Register a VTX provider for `kernel` (emulator path).
    pub fn register_vtx(
        &mut self,
        kernel: &str,
        provider: impl Fn(&[TensorSpec]) -> Result<VtxSpec> + Send + Sync + 'static,
    ) {
        self.vtx.insert(kernel.to_string(), Box::new(provider));
    }

    pub fn has_vtx(&self, kernel: &str) -> bool {
        self.vtx.contains_key(kernel)
    }

    /// Resolve for the PJRT path: match the manifest on the *input*
    /// signature (uploads only, `;`-joined `dtype[dims]`).
    pub fn resolve_artifact(
        &self,
        kernel: &str,
        input_signature: &str,
    ) -> Result<(&ArtifactLibrary, ArtifactEntry)> {
        let lib = self.library.as_ref().ok_or_else(|| Error::Specialize {
            kernel: kernel.to_string(),
            reason: "no artifact library loaded (run `make artifacts`)".into(),
        })?;
        let entry = lib.find(kernel, input_signature)?.clone();
        Ok((lib, entry))
    }

    /// Positional artifact resolution for calls with `Auto` arguments:
    /// find the artifact of `kernel` whose `inputs ++ outputs` signature
    /// sequence equals the call's argument signatures, and derive each
    /// argument's transfer mode from which side it fell on (§9 automatic
    /// argument-usage detection, PJRT flavor).
    pub fn resolve_artifact_positional(
        &self,
        kernel: &str,
        arg_sigs: &[String],
    ) -> Result<(&ArtifactLibrary, ArtifactEntry, Vec<bool>)> {
        let lib = self.library.as_ref().ok_or_else(|| Error::Specialize {
            kernel: kernel.to_string(),
            reason: "no artifact library loaded (run `make artifacts`)".into(),
        })?;
        'entry: for entry in lib.for_kernel(kernel) {
            if entry.inputs.len() + entry.outputs.len() != arg_sigs.len() {
                continue;
            }
            let mut is_output = Vec::with_capacity(arg_sigs.len());
            for (i, sig) in arg_sigs.iter().enumerate() {
                let (want, out) = if i < entry.inputs.len() {
                    (&entry.inputs[i], false)
                } else {
                    (&entry.outputs[i - entry.inputs.len()], true)
                };
                if &want.signature() != sig {
                    continue 'entry;
                }
                is_output.push(out);
            }
            return Ok((lib, entry.clone(), is_output));
        }
        Err(Error::NoArtifact {
            kernel: kernel.to_string(),
            signature: arg_sigs.join(";"),
        })
    }

    /// Resolve for the emulator path: run the provider against the
    /// concrete tensor shapes of the call.
    pub fn resolve_vtx(&self, kernel: &str, specs: &[TensorSpec]) -> Result<VtxSpec> {
        let provider = self.vtx.get(kernel).ok_or_else(|| Error::Specialize {
            kernel: kernel.to_string(),
            reason: "no VTX provider registered for the emulator backend".into(),
        })?;
        provider(specs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emulator::kernels;

    #[test]
    fn vtx_provider_resolution() {
        let mut reg = KernelRegistry::new(None);
        reg.register_vtx("vadd", |specs| {
            let n = specs[0].numel();
            Ok(VtxSpec {
                kernel: kernels::vadd()?,
                scalars: vec![KernelArg::I32(n as i32)],
                config: LaunchConfig::new(((n as u32) + 255) / 256, 256u32),
            })
        });
        assert!(reg.has_vtx("vadd"));
        let spec = reg
            .resolve_vtx("vadd", &[TensorSpec::f32(&[100]), TensorSpec::f32(&[100])])
            .unwrap();
        assert_eq!(spec.kernel.name, "vadd");
        assert_eq!(spec.scalars, vec![KernelArg::I32(100)]);
        assert!(reg.resolve_vtx("nope", &[]).is_err());
    }

    #[test]
    fn missing_library_reports_make_artifacts() {
        let reg = KernelRegistry::new(None);
        let err = reg.resolve_artifact("vadd", "f32[12];f32[12]").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
