//! VTX emulator: the GPU Ocelot analog (paper §5) — a PTX-like virtual
//! ISA, a rust kernel-builder DSL, and an interpreter with the full
//! grid/block/thread model, shared memory and barriers. Lets the entire
//! framework run with no PJRT/XLA dependency, e.g. on CI or for
//! cross-backend differential testing.

pub mod backend_impl;
pub mod builder;
pub mod interp;
pub mod isa;
pub mod kernels;

pub use backend_impl::VtxBackend;
pub use builder::KernelBuilder;
pub use interp::{execute, Launch, Limits, ScalarArg};
pub use isa::{Instr, Kernel, ParamKind};
