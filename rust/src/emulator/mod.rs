//! VTX emulator: the GPU Ocelot analog (paper §5) — a PTX-like virtual
//! ISA, a rust kernel-builder DSL, and an interpreter with the full
//! grid/block/thread model, shared memory and barriers. Lets the entire
//! framework run with no PJRT/XLA dependency, e.g. on CI or for
//! cross-backend differential testing.
//!
//! Execution engine: kernels are pre-decoded once per scalar binding
//! ([`decode`]) and their blocks dispatched across a fixed worker-thread
//! pool ([`sched`]) — grid-level parallelism is real, not simulated at
//! 1/N speed. `HLGPU_WORKERS=1` (or a single-block grid) selects the
//! sequential reference schedule; for race-free kernels both schedules
//! produce identical results and identical trap coordinates.

pub mod backend_impl;
pub mod builder;
pub mod decode;
pub mod interp;
pub mod isa;
pub mod kernels;
pub mod sched;

pub use backend_impl::VtxBackend;
pub use builder::KernelBuilder;
pub use decode::{decode, DecodedKernel};
pub use interp::{execute, execute_decoded, execute_with, Launch, Limits, ScalarArg};
pub use isa::{Instr, Kernel, ParamKind};
pub use sched::{default_workers, set_default_workers, WorkerPool};
