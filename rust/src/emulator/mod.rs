//! VTX emulator: the GPU Ocelot analog (paper §5) — a PTX-like virtual
//! ISA, a rust kernel-builder DSL, and an interpreter with the full
//! grid/block/thread model, shared memory and barriers. Lets the entire
//! framework run with no PJRT/XLA dependency, e.g. on CI or for
//! cross-backend differential testing.
//!
//! Execution engine — a tiered pipeline (`decode` → `lower`/fuse →
//! execute): kernels are pre-decoded once per scalar binding
//! ([`decode`]), lowered into a basic-block CFG with fused
//! superinstructions ([`lower`]), and their blocks dispatched across a
//! fixed worker-thread pool ([`sched`]) — grid-level parallelism is
//! real, not simulated at 1/N speed. Inside a block, `HLGPU_EXEC`
//! selects the tier: `vector` (the default, [`vector`]) executes one
//! operation across all threads of the block at a time over
//! structure-of-arrays register files; `scalar` ([`interp`]) is the
//! one-instruction-per-thread reference semantics; `compiled`
//! ([`compile`]) JIT-compiles hot basic blocks into straight-line
//! closure chains with profile-driven tier-up (`HLGPU_TIER_UP`) and
//! bitwise-faithful deopt back to the vector tier on any guard
//! failure. `HLGPU_WORKERS=1` (or a single-block grid) selects the
//! sequential block schedule; for race-free kernels every (schedule,
//! tier) combination produces identical results and identical trap
//! coordinates. See `docs/emulator.md`.

pub mod backend_impl;
pub mod builder;
pub(crate) mod compile;
pub mod decode;
pub mod interp;
pub mod isa;
pub mod kernels;
pub mod lower;
pub mod sched;
pub(crate) mod vector;

pub use backend_impl::VtxBackend;
pub use builder::KernelBuilder;
pub use decode::{decode, DecodedKernel};
pub use interp::{
    execute, execute_decoded, execute_decoded_on, execute_decoded_tier, execute_with,
    execute_with_tier, Launch, Limits, ScalarArg,
};
pub use isa::{Instr, Kernel, ParamKind};
pub use lower::LoweredKernel;
pub use sched::{
    default_exec, default_exec_checked, default_tier_up, default_tier_up_checked, default_workers,
    device_pool, set_default_exec, set_default_tier_up, set_default_workers, ExecTier, WorkerPool,
    DEFAULT_TIER_UP,
};
