//! The VTX interpreter: executes a kernel over a grid of thread blocks,
//! with shared memory, barriers and full trap checking — the GPU Ocelot
//! analog of this stack (paper §5: "developers can now use the GPU
//! support without having any physical NVIDIA hardware").
//!
//! Execution model: blocks are **independent** (the CUDA contract) and
//! are dispatched across the fixed worker-thread pool of
//! [`crate::emulator::sched`] — each worker claims the next unclaimed
//! block, interprets it to completion with its own private shared memory
//! and register files, and moves on. Global memory is shared across
//! blocks through per-element atomic cells, so cross-block races behave
//! like (relaxed) hardware races instead of undefined behavior. A
//! single-worker schedule (`HLGPU_WORKERS=1`, or
//! [`crate::emulator::sched::set_default_workers`]) degenerates to the
//! classic sequential block loop, which is also used automatically for
//! single-block grids. For **race-free kernels** (blocks that do not
//! communicate through global memory — the only kernels with defined
//! results on real hardware either) schedules of any width produce
//! identical results and identical trap coordinates: on a trap, the
//! scheduler stops claiming new blocks, drains the in-flight ones, and
//! reports the trap of the **lowest** block index — exactly what the
//! sequential schedule reports. Kernels that race across blocks get
//! hardware-like unordered behavior, under which observed values (and
//! therefore traps derived from them) may differ from the sequential
//! interleaving.
//!
//! Within a block, execution is **tiered** (`HLGPU_EXEC`, or
//! [`crate::emulator::sched::set_default_exec`]):
//!
//! * the **scalar** tier (this module) is the reference semantics: each
//!   thread executes until it hits a barrier or exits, then the next
//!   thread runs — one dispatch per instruction per thread;
//! * the **vector** tier ([`crate::emulator::vector`], the default)
//!   executes the lowered basic-block form one operation at a time
//!   across all active threads of the block with predication masks for
//!   divergence, amortizing dispatch over `blockDim` threads.
//!
//! Both tiers produce bitwise-identical results and identical trap
//! coordinates for race-free kernels (threads that communicate through
//! shared or global memory within a barrier segment are racy on real
//! hardware too, and get unordered behavior). A barrier releases when
//! every live thread has arrived; divergent barriers (some threads
//! exited while others wait) trap, as on real hardware, reporting the
//! coordinates of the lowest-indexed waiting thread.
//!
//! Before any block runs, the instruction stream is pre-decoded once per
//! (kernel, scalar binding) by [`crate::emulator::decode`]: scalar
//! parameters become immediates and pointer parameters become dense
//! buffer slots, so the interpreter hot loop performs no binding lookups.
//! The decode step also lowers the stream into its basic-block, fused
//! form ([`crate::emulator::lower`]) for the vector tier, cached with
//! the decoded kernel.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::driver::launch::LaunchReport;
use crate::emulator::decode::{decode, DecodedKernel};
use crate::emulator::isa::{CmpOp, FOp, IOp, Instr, Kernel, Special, UnFOp};
use crate::emulator::sched::{
    default_exec_checked, default_tier_up_checked, default_workers, ArriveGuard, ExecTier, Latch,
    WorkerPool,
};
use crate::error::{Error, Result};

/// Per-launch resource limits.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// Max instructions executed per thread (infinite-loop trap).
    pub steps_per_thread: u64,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { steps_per_thread: 64_000_000 }
    }
}

/// Scalar parameter values bound at launch (pointer params are bound via
/// `buffers` instead).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScalarArg {
    F32(f32),
    I32(i32),
}

/// A grid launch of one kernel.
pub struct Launch<'a> {
    pub kernel: &'a Kernel,
    pub grid: (u32, u32),
    pub block: (u32, u32),
    /// One f32 slice per `PtrF32` parameter, in parameter order.
    pub buffers: Vec<&'a mut [f32]>,
    /// One entry per scalar parameter, in parameter order.
    pub scalars: Vec<ScalarArg>,
    pub limits: Limits,
}

/// Execute a launch with the default schedule width
/// ([`crate::emulator::sched::default_workers`]).
pub fn execute(launch: Launch<'_>) -> Result<()> {
    execute_with(launch, default_workers()).map(|_| ())
}

/// Execute a launch with an explicit schedule width. `workers <= 1` (or a
/// single-block grid) runs the sequential schedule; larger widths
/// dispatch blocks across the global worker pool.
pub fn execute_with(launch: Launch<'_>, workers: usize) -> Result<LaunchReport> {
    execute_with_tier(launch, workers, default_exec_checked()?)
}

/// Execute a launch with an explicit schedule width and execution tier
/// (tests and benches A/B the tiers through this).
pub fn execute_with_tier(
    launch: Launch<'_>,
    workers: usize,
    tier: ExecTier,
) -> Result<LaunchReport> {
    let decoded = Arc::new(decode(launch.kernel, &launch.scalars)?);
    execute_decoded_tier(
        &decoded,
        launch.grid,
        launch.block,
        launch.buffers,
        &launch.limits,
        workers,
        tier,
    )
}

/// Execute a pre-decoded kernel (the cached warm path: the coordinator's
/// `Specialized` entry holds the decoded + lowered form and skips both
/// `decode` and `lower`), on the default execution tier.
pub fn execute_decoded(
    kernel: &Arc<DecodedKernel>,
    grid: (u32, u32),
    block: (u32, u32),
    buffers: Vec<&mut [f32]>,
    limits: &Limits,
    workers: usize,
) -> Result<LaunchReport> {
    execute_decoded_tier(kernel, grid, block, buffers, limits, workers, default_exec_checked()?)
}

/// Execute a pre-decoded kernel on an explicit worker pool — the
/// per-device dispatch path: each emulator device's backend passes its
/// own pool (see [`crate::emulator::sched::device_pool`]) so launches
/// on different devices never share a worker queue. Uses the default
/// execution tier.
#[allow(clippy::too_many_arguments)]
pub fn execute_decoded_on(
    kernel: &Arc<DecodedKernel>,
    grid: (u32, u32),
    block: (u32, u32),
    buffers: Vec<&mut [f32]>,
    limits: &Limits,
    workers: usize,
    pool: &'static WorkerPool,
) -> Result<LaunchReport> {
    execute_decoded_pool_tier(
        kernel,
        grid,
        block,
        buffers,
        limits,
        workers,
        default_exec_checked()?,
        pool,
    )
}

/// Execute a pre-decoded kernel on an explicit execution tier (the
/// global worker pool).
#[allow(clippy::too_many_arguments)]
pub fn execute_decoded_tier(
    kernel: &Arc<DecodedKernel>,
    grid: (u32, u32),
    block: (u32, u32),
    buffers: Vec<&mut [f32]>,
    limits: &Limits,
    workers: usize,
    tier: ExecTier,
) -> Result<LaunchReport> {
    execute_decoded_pool_tier(
        kernel,
        grid,
        block,
        buffers,
        limits,
        workers,
        tier,
        WorkerPool::global(),
    )
}

#[allow(clippy::too_many_arguments)]
fn execute_decoded_pool_tier(
    kernel: &Arc<DecodedKernel>,
    grid: (u32, u32),
    block: (u32, u32),
    buffers: Vec<&mut [f32]>,
    limits: &Limits,
    workers: usize,
    tier: ExecTier,
    pool: &'static WorkerPool,
) -> Result<LaunchReport> {
    if buffers.len() != kernel.nbufs {
        return Err(Error::InvalidLaunch(format!(
            "kernel `{}` takes {} buffers, got {}",
            kernel.name,
            kernel.nbufs,
            buffers.len()
        )));
    }
    // Resolve the tier-up threshold once per launch — also where a
    // malformed `HLGPU_TIER_UP` surfaces as a typed error at first use.
    let tier_up = if tier == ExecTier::Compiled { default_tier_up_checked()? } else { 0 };
    let nblocks = grid.0 as u64 * grid.1 as u64;
    if workers > 1 && nblocks > 1 {
        run_parallel(kernel, grid, block, buffers, limits, workers, tier, tier_up, pool)
    } else {
        run_sequential(kernel, grid, block, buffers, limits, tier, tier_up)
    }
}

// ------------------------------------------------------------------------
// Global memory views
// ------------------------------------------------------------------------

/// Global-memory access used by the block interpreters (scalar and
/// vector tiers). Monomorphized per schedule: plain slices for the
/// sequential path, shared atomic cells for the parallel path.
pub(crate) trait GlobalMem {
    fn len(&self, slot: usize) -> usize;
    fn load(&self, slot: usize, idx: usize) -> f32;
    fn store(&mut self, slot: usize, idx: usize, v: f32);
}

/// Sequential view: the launch's slices, accessed directly.
struct SliceMem<'a> {
    bufs: Vec<&'a mut [f32]>,
}

impl GlobalMem for SliceMem<'_> {
    #[inline]
    fn len(&self, slot: usize) -> usize {
        self.bufs[slot].len()
    }
    #[inline]
    fn load(&self, slot: usize, idx: usize) -> f32 {
        self.bufs[slot][idx]
    }
    #[inline]
    fn store(&mut self, slot: usize, idx: usize, v: f32) {
        self.bufs[slot][idx] = v;
    }
}

/// Parallel view: per-block scoped handle onto the launch's shared
/// buffers. f32 values live as `AtomicU32` bit patterns with relaxed
/// ordering — block independence means race-free kernels see exactly the
/// sequential results, and racy kernels get hardware-like (defined,
/// unordered) behavior rather than UB.
struct AtomicMem<'a> {
    bufs: &'a [Vec<AtomicU32>],
}

impl GlobalMem for AtomicMem<'_> {
    #[inline]
    fn len(&self, slot: usize) -> usize {
        self.bufs[slot].len()
    }
    #[inline]
    fn load(&self, slot: usize, idx: usize) -> f32 {
        f32::from_bits(self.bufs[slot][idx].load(Ordering::Relaxed))
    }
    #[inline]
    fn store(&mut self, slot: usize, idx: usize, v: f32) {
        self.bufs[slot][idx].store(v.to_bits(), Ordering::Relaxed);
    }
}

// ------------------------------------------------------------------------
// Block interpreter (shared by both schedules)
// ------------------------------------------------------------------------

/// Execution statistics of one block run, aggregated into the launch's
/// [`LaunchReport`]. On the scalar tier only `instrs`/`dispatches` are
/// populated (one dispatch per instruction); the vector tier also
/// reports fusion and lane-occupancy counters.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct BlockStats {
    /// ISA instructions retired across all threads of the block.
    pub instrs: u64,
    /// Instructions retired inside fused superinstructions.
    pub fused_instrs: u64,
    /// Interpreter dispatch events.
    pub dispatches: u64,
    /// Σ active lanes over vector dispatches.
    pub lane_ops: u64,
    /// Σ block width over vector dispatches (lane capacity).
    pub lane_slots: u64,
    /// Instructions retired inside compiled regions (closure-JIT tier).
    pub compiled_instrs: u64,
    /// Compiled-block executions (closure chain entered).
    pub compiled_blocks: u64,
    /// Blocks compiled during this run (tier-up events).
    pub tier_ups: u64,
    /// Guard failures that fell back to the vector op path.
    pub deopts: u64,
}

impl BlockStats {
    pub(crate) fn merge(&mut self, o: &BlockStats) {
        self.instrs += o.instrs;
        self.fused_instrs += o.fused_instrs;
        self.dispatches += o.dispatches;
        self.lane_ops += o.lane_ops;
        self.lane_slots += o.lane_slots;
        self.compiled_instrs += o.compiled_instrs;
        self.compiled_blocks += o.compiled_blocks;
        self.tier_ups += o.tier_ups;
        self.deopts += o.deopts;
    }
}

/// Apply a binary f32 op — shared between tiers so arithmetic semantics
/// are single-sourced (bitwise identity by construction).
#[inline]
pub(crate) fn binf_apply(op: FOp, x: f32, y: f32) -> f32 {
    match op {
        FOp::Add => x + y,
        FOp::Sub => x - y,
        FOp::Mul => x * y,
        FOp::Div => x / y,
        FOp::Min => x.min(y),
        FOp::Max => x.max(y),
    }
}

/// Apply a unary f32 op (shared between tiers).
#[inline]
pub(crate) fn unf_apply(op: UnFOp, x: f32) -> f32 {
    match op {
        UnFOp::Neg => -x,
        UnFOp::Abs => x.abs(),
        UnFOp::Sqrt => x.sqrt(),
        UnFOp::Sin => x.sin(),
        UnFOp::Cos => x.cos(),
        UnFOp::Floor => x.floor(),
    }
}

// Trap-reason constructors, shared between tiers: cross-tier trap
// parity asserts exact string equality, so the wording must be
// single-sourced.

pub(crate) const TRAP_DIV_ZERO: &str = "integer division by zero";
pub(crate) const TRAP_REM_ZERO: &str = "integer remainder by zero";

pub(crate) fn trap_budget(limit: u64) -> String {
    format!("step budget exhausted ({limit} instructions)")
}

pub(crate) fn trap_oob_global(kind: &str, i: i64, len: usize, slot: usize) -> String {
    format!("global {kind} OOB: index {i} in buffer of {len} elements (buffer {slot})")
}

pub(crate) fn trap_oob_shared(kind: &str, i: i64, len: usize) -> String {
    format!("shared {kind} OOB: index {i} of {len}")
}

/// Interpret one block on the selected tier. Both tiers are
/// observationally identical for race-free kernels, so traps surface
/// with identical coordinates and reasons under every (schedule, tier)
/// combination.
#[allow(clippy::too_many_arguments)]
fn run_block_tier<M: GlobalMem>(
    k: &DecodedKernel,
    tier: ExecTier,
    grid: (u32, u32),
    block: (u32, u32),
    block_id: (u32, u32),
    mem: &mut M,
    limits: &Limits,
    tier_up: u64,
) -> Result<BlockStats> {
    match tier {
        ExecTier::Scalar => run_block(k, grid, block, block_id, mem, limits),
        ExecTier::Vector => {
            crate::emulator::vector::run_block_tiered(k, grid, block, block_id, mem, limits, None)
        }
        ExecTier::Compiled => crate::emulator::vector::run_block_tiered(
            k,
            grid,
            block,
            block_id,
            mem,
            limits,
            Some((&k.jit, tier_up)),
        ),
    }
}

#[derive(Clone, Copy, PartialEq)]
enum ThreadState {
    Running,
    AtBarrier,
    Done,
}

struct Thread {
    pc: usize,
    f: Vec<f32>,
    i: Vec<i64>,
    state: ThreadState,
    steps: u64,
}

/// Interpret one thread block to completion (or trap) on the scalar
/// reference tier: one dispatch per instruction per thread. Identical
/// for the sequential and parallel schedules, so traps surface with
/// identical coordinates and reasons under both.
fn run_block<M: GlobalMem>(
    k: &DecodedKernel,
    grid: (u32, u32),
    block: (u32, u32),
    block_id: (u32, u32),
    mem: &mut M,
    limits: &Limits,
) -> Result<BlockStats> {
    let (gx, gy) = grid;
    let (bx, by) = block;
    let (bx_i, by_i) = block_id;
    let threads_per_block = (bx * by) as usize;

    let trap = |thread: (u32, u32), reason: String| Error::VtxTrap {
        kernel: k.name.clone(),
        block: (bx_i, by_i, 0),
        thread: (thread.0, thread.1, 0),
        reason,
    };

    let mut shared = vec![0f32; k.shared_f32];
    let mut threads: Vec<Thread> = (0..threads_per_block)
        .map(|_| Thread {
            pc: 0,
            f: vec![0f32; k.fregs as usize],
            i: vec![0i64; k.iregs as usize],
            state: ThreadState::Running,
            steps: 0,
        })
        .collect();

    loop {
        let mut progressed = false;
        for t_lin in 0..threads_per_block {
            if threads[t_lin].state != ThreadState::Running {
                continue;
            }
            progressed = true;
            let tx = (t_lin as u32) % bx;
            let ty = (t_lin as u32) / bx;
            let th = &mut threads[t_lin];
            // Run this thread until barrier/exit/trap.
            loop {
                if th.steps >= limits.steps_per_thread {
                    return Err(trap((tx, ty), trap_budget(limits.steps_per_thread)));
                }
                th.steps += 1;
                let ins = k.code[th.pc];
                th.pc += 1;
                match ins {
                    Instr::ConstF(d, v) => th.f[d as usize] = v,
                    Instr::ConstI(d, v) => th.i[d as usize] = v,
                    Instr::MovF(d, s) => th.f[d as usize] = th.f[s as usize],
                    Instr::MovI(d, s) => th.i[d as usize] = th.i[s as usize],
                    Instr::BinF(op, d, a, b) => {
                        let (x, y) = (th.f[a as usize], th.f[b as usize]);
                        th.f[d as usize] = binf_apply(op, x, y);
                    }
                    Instr::BinI(op, d, a, b) => {
                        let (x, y) = (th.i[a as usize], th.i[b as usize]);
                        th.i[d as usize] = match op {
                            IOp::Add => x.wrapping_add(y),
                            IOp::Sub => x.wrapping_sub(y),
                            IOp::Mul => x.wrapping_mul(y),
                            IOp::Div => {
                                if y == 0 {
                                    return Err(trap((tx, ty), TRAP_DIV_ZERO.to_string()));
                                }
                                // wrapping: i64::MIN / -1 must not panic
                                x.wrapping_div(y)
                            }
                            IOp::Rem => {
                                if y == 0 {
                                    return Err(trap((tx, ty), TRAP_REM_ZERO.to_string()));
                                }
                                x.wrapping_rem(y)
                            }
                        };
                    }
                    Instr::UnF(op, d, a) => {
                        th.f[d as usize] = unf_apply(op, th.f[a as usize]);
                    }
                    Instr::CmpF(op, d, a, b) => {
                        let (x, y) = (th.f[a as usize], th.f[b as usize]);
                        th.i[d as usize] = cmpf(op, x, y) as i64;
                    }
                    Instr::CmpI(op, d, a, b) => {
                        let (x, y) = (th.i[a as usize], th.i[b as usize]);
                        th.i[d as usize] = cmpi(op, x, y) as i64;
                    }
                    Instr::SelF(d, p, a, b) => {
                        th.f[d as usize] = if th.i[p as usize] != 0 {
                            th.f[a as usize]
                        } else {
                            th.f[b as usize]
                        };
                    }
                    Instr::CvtFI(d, s) => th.i[d as usize] = th.f[s as usize] as i64,
                    Instr::CvtIF(d, s) => th.f[d as usize] = th.i[s as usize] as f32,
                    Instr::Spec(d, s) => {
                        th.i[d as usize] = match s {
                            Special::ThreadIdX => tx as i64,
                            Special::ThreadIdY => ty as i64,
                            Special::BlockIdX => bx_i as i64,
                            Special::BlockIdY => by_i as i64,
                            Special::BlockDimX => bx as i64,
                            Special::BlockDimY => by as i64,
                            Special::GridDimX => gx as i64,
                            Special::GridDimY => gy as i64,
                        };
                    }
                    Instr::LdG { dst, param, idx } => {
                        // `param` is a buffer slot after pre-decoding.
                        let slot = param as usize;
                        let i = th.i[idx as usize];
                        let len = mem.len(slot);
                        if i < 0 || i as usize >= len {
                            return Err(trap((tx, ty), trap_oob_global("load", i, len, slot)));
                        }
                        th.f[dst as usize] = mem.load(slot, i as usize);
                    }
                    Instr::StG { param, idx, src } => {
                        let slot = param as usize;
                        let i = th.i[idx as usize];
                        let v = th.f[src as usize];
                        let len = mem.len(slot);
                        if i < 0 || i as usize >= len {
                            return Err(trap(
                                (tx, ty),
                                trap_oob_global("store", i, len, slot),
                            ));
                        }
                        mem.store(slot, i as usize, v);
                    }
                    Instr::LdS { dst, idx } => {
                        let i = th.i[idx as usize];
                        if i < 0 || i as usize >= shared.len() {
                            return Err(trap(
                                (tx, ty),
                                trap_oob_shared("load", i, shared.len()),
                            ));
                        }
                        th.f[dst as usize] = shared[i as usize];
                    }
                    Instr::StS { idx, src } => {
                        let i = th.i[idx as usize];
                        if i < 0 || i as usize >= shared.len() {
                            return Err(trap(
                                (tx, ty),
                                trap_oob_shared("store", i, shared.len()),
                            ));
                        }
                        shared[i as usize] = th.f[src as usize];
                    }
                    Instr::LdParamF(..) | Instr::LdParamI(..) => {
                        unreachable!("scalar params resolved by pre-decode")
                    }
                    Instr::Bar => {
                        th.state = ThreadState::AtBarrier;
                        break;
                    }
                    Instr::Bra(t) => th.pc = t as usize,
                    Instr::BraIf(p, t) => {
                        if th.i[p as usize] != 0 {
                            th.pc = t as usize;
                        }
                    }
                    Instr::BraIfZ(p, t) => {
                        if th.i[p as usize] == 0 {
                            th.pc = t as usize;
                        }
                    }
                    Instr::Ret => {
                        th.state = ThreadState::Done;
                        break;
                    }
                }
            }
        }

        // Barrier resolution.
        let any_running = threads.iter().any(|t| t.state == ThreadState::Running);
        if any_running {
            continue;
        }
        let at_barrier = threads
            .iter()
            .filter(|t| t.state == ThreadState::AtBarrier)
            .count();
        if at_barrier == 0 {
            // All done: one dispatch per retired instruction on this tier.
            let instrs: u64 = threads.iter().map(|t| t.steps).sum();
            return Ok(BlockStats { instrs, dispatches: instrs, ..BlockStats::default() });
        }
        let done = threads.iter().filter(|t| t.state == ThreadState::Done).count();
        if done > 0 {
            // Report the coordinates of an actual waiting thread (the
            // lowest-indexed one, matching the vector tier).
            let waiter = threads
                .iter()
                .position(|t| t.state == ThreadState::AtBarrier)
                .unwrap_or(0) as u32;
            return Err(trap(
                (waiter % bx, waiter / bx),
                format!("barrier divergence: {at_barrier} threads waiting, {done} exited"),
            ));
        }
        for t in &mut threads {
            t.state = ThreadState::Running;
        }
        if !progressed {
            return Err(trap((0, 0), "scheduler made no progress".into()));
        }
    }
}

// ------------------------------------------------------------------------
// Schedules
// ------------------------------------------------------------------------

/// Sequential schedule: blocks in linear order on the calling thread —
/// the reference schedule the parallel one must be indistinguishable
/// from (modulo wall time).
fn run_sequential(
    k: &DecodedKernel,
    grid: (u32, u32),
    block: (u32, u32),
    buffers: Vec<&mut [f32]>,
    limits: &Limits,
    tier: ExecTier,
    tier_up: u64,
) -> Result<LaunchReport> {
    let t0 = Instant::now();
    let (gx, gy) = grid;
    let mut mem = SliceMem { bufs: buffers };
    let mut agg = BlockStats::default();
    for by_i in 0..gy {
        for bx_i in 0..gx {
            let st =
                run_block_tier(k, tier, grid, block, (bx_i, by_i), &mut mem, limits, tier_up)?;
            agg.merge(&st);
        }
    }
    let wall = t0.elapsed().as_nanos() as u64;
    Ok(LaunchReport {
        blocks: gx as u64 * gy as u64,
        workers: 1,
        busy_ns: wall,
        wall_ns: wall,
        instrs: agg.instrs,
        fused_instrs: agg.fused_instrs,
        dispatches: agg.dispatches,
        lane_ops: agg.lane_ops,
        lane_slots: agg.lane_slots,
        compiled_instrs: agg.compiled_instrs,
        compiled_blocks: agg.compiled_blocks,
        tier_ups: agg.tier_ups,
        deopts: agg.deopts,
    })
}

/// Shared state of one parallel launch.
struct ParShared {
    kernel: Arc<DecodedKernel>,
    bufs: Vec<Vec<AtomicU32>>,
    grid: (u32, u32),
    block: (u32, u32),
    limits: Limits,
    tier: ExecTier,
    tier_up: u64,
    /// Next unclaimed linear block index. Claimed strictly in order, so
    /// when a trap cancels the launch every block below the trapping one
    /// has already been claimed — guaranteeing the minimum-index trap is
    /// the same one the sequential schedule would report.
    next: AtomicU64,
    cancel: AtomicBool,
    traps: Mutex<Vec<(u64, Error)>>,
    busy_ns: AtomicU64,
    instrs: AtomicU64,
    fused_instrs: AtomicU64,
    dispatches: AtomicU64,
    lane_ops: AtomicU64,
    lane_slots: AtomicU64,
    compiled_instrs: AtomicU64,
    compiled_blocks: AtomicU64,
    tier_ups: AtomicU64,
    deopts: AtomicU64,
    latch: Latch,
}

impl ParShared {
    fn worker(&self) {
        let _arrive = ArriveGuard(&self.latch);
        let t0 = Instant::now();
        let gx = self.grid.0 as u64;
        let nblocks = gx * self.grid.1 as u64;
        let mut mem = AtomicMem { bufs: &self.bufs };
        let mut agg = BlockStats::default();
        loop {
            if self.cancel.load(Ordering::Relaxed) {
                break;
            }
            let lin = self.next.fetch_add(1, Ordering::Relaxed);
            if lin >= nblocks {
                break;
            }
            let block_id = ((lin % gx) as u32, (lin / gx) as u32);
            match run_block_tier(
                &self.kernel,
                self.tier,
                self.grid,
                self.block,
                block_id,
                &mut mem,
                &self.limits,
                self.tier_up,
            ) {
                Ok(st) => agg.merge(&st),
                Err(e) => {
                    self.traps.lock().unwrap().push((lin, e));
                    self.cancel.store(true, Ordering::Relaxed);
                }
            }
        }
        self.instrs.fetch_add(agg.instrs, Ordering::Relaxed);
        self.fused_instrs.fetch_add(agg.fused_instrs, Ordering::Relaxed);
        self.dispatches.fetch_add(agg.dispatches, Ordering::Relaxed);
        self.lane_ops.fetch_add(agg.lane_ops, Ordering::Relaxed);
        self.lane_slots.fetch_add(agg.lane_slots, Ordering::Relaxed);
        self.compiled_instrs
            .fetch_add(agg.compiled_instrs, Ordering::Relaxed);
        self.compiled_blocks
            .fetch_add(agg.compiled_blocks, Ordering::Relaxed);
        self.tier_ups.fetch_add(agg.tier_ups, Ordering::Relaxed);
        self.deopts.fetch_add(agg.deopts, Ordering::Relaxed);
        self.busy_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Parallel schedule: blocks claimed in order off a shared counter by up
/// to `workers` jobs on the global pool. On success the atomic buffers
/// are written back to the launch's slices; on a trap the buffers are
/// left untouched (as the backend discards them — trap-visible state
/// matches the sequential schedule at the driver level).
///
/// The copy into owned `AtomicU32` cells (and back) keeps every job
/// `'static`-safe without unsafe lifetime erasure; it is O(buffer)
/// serial work, acceptable because the interpreter's per-element cost
/// dwarfs a memcpy for every kernel in the repo. Revisit with an
/// in-place atomic view if a memory-bound workload ever appears.
#[allow(clippy::too_many_arguments)]
fn run_parallel(
    kernel: &Arc<DecodedKernel>,
    grid: (u32, u32),
    block: (u32, u32),
    mut buffers: Vec<&mut [f32]>,
    limits: &Limits,
    workers: usize,
    tier: ExecTier,
    tier_up: u64,
    pool: &'static WorkerPool,
) -> Result<LaunchReport> {
    let nblocks = grid.0 as u64 * grid.1 as u64;
    // Clamp to the pool: submitting more jobs than threads cannot add
    // concurrency, and the report must state the width that actually ran.
    let njobs = workers.min(nblocks as usize).min(pool.size()).max(1);
    let t0 = Instant::now();

    let shared = Arc::new(ParShared {
        kernel: kernel.clone(),
        bufs: buffers
            .iter()
            .map(|b| b.iter().map(|v| AtomicU32::new(v.to_bits())).collect())
            .collect(),
        grid,
        block,
        limits: *limits,
        tier,
        tier_up,
        next: AtomicU64::new(0),
        cancel: AtomicBool::new(false),
        traps: Mutex::new(Vec::new()),
        busy_ns: AtomicU64::new(0),
        instrs: AtomicU64::new(0),
        fused_instrs: AtomicU64::new(0),
        dispatches: AtomicU64::new(0),
        lane_ops: AtomicU64::new(0),
        lane_slots: AtomicU64::new(0),
        compiled_instrs: AtomicU64::new(0),
        compiled_blocks: AtomicU64::new(0),
        tier_ups: AtomicU64::new(0),
        deopts: AtomicU64::new(0),
        latch: Latch::new(njobs),
    });

    for _ in 0..njobs {
        let st = shared.clone();
        pool.submit(Box::new(move || st.worker()));
    }
    let panicked = shared.latch.wait();
    if panicked {
        return Err(Error::Other(
            "VTX worker thread panicked during block execution".into(),
        ));
    }

    {
        let mut traps = shared.traps.lock().unwrap();
        if !traps.is_empty() {
            traps.sort_by_key(|(lin, _)| *lin);
            return Err(traps.remove(0).1);
        }
    }

    // Success: publish the device-visible state back into the launch's
    // buffers.
    for (dst, src) in buffers.iter_mut().zip(&shared.bufs) {
        for (d, s) in dst.iter_mut().zip(src) {
            *d = f32::from_bits(s.load(Ordering::Relaxed));
        }
    }

    Ok(LaunchReport {
        blocks: nblocks,
        workers: njobs,
        busy_ns: shared.busy_ns.load(Ordering::Relaxed),
        wall_ns: t0.elapsed().as_nanos() as u64,
        instrs: shared.instrs.load(Ordering::Relaxed),
        fused_instrs: shared.fused_instrs.load(Ordering::Relaxed),
        dispatches: shared.dispatches.load(Ordering::Relaxed),
        lane_ops: shared.lane_ops.load(Ordering::Relaxed),
        lane_slots: shared.lane_slots.load(Ordering::Relaxed),
        compiled_instrs: shared.compiled_instrs.load(Ordering::Relaxed),
        compiled_blocks: shared.compiled_blocks.load(Ordering::Relaxed),
        tier_ups: shared.tier_ups.load(Ordering::Relaxed),
        deopts: shared.deopts.load(Ordering::Relaxed),
    })
}

pub(crate) fn cmpf(op: CmpOp, x: f32, y: f32) -> bool {
    match op {
        CmpOp::Lt => x < y,
        CmpOp::Le => x <= y,
        CmpOp::Gt => x > y,
        CmpOp::Ge => x >= y,
        CmpOp::Eq => x == y,
        CmpOp::Ne => x != y,
    }
}

pub(crate) fn cmpi(op: CmpOp, x: i64, y: i64) -> bool {
    match op {
        CmpOp::Lt => x < y,
        CmpOp::Le => x <= y,
        CmpOp::Gt => x > y,
        CmpOp::Ge => x >= y,
        CmpOp::Eq => x == y,
        CmpOp::Ne => x != y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emulator::builder::KernelBuilder;
    use crate::emulator::isa::CmpOp;

    fn run(k: &Kernel, grid: (u32, u32), block: (u32, u32), bufs: Vec<&mut [f32]>) -> Result<()> {
        execute(Launch {
            kernel: k,
            grid,
            block,
            buffers: bufs,
            scalars: vec![],
            limits: Limits::default(),
        })
    }

    /// out[global_tid] = a[global_tid] + b[global_tid]
    fn vadd_kernel() -> Kernel {
        let mut b = KernelBuilder::new("vadd");
        let pa = b.ptr_param();
        let pb = b.ptr_param();
        let pc = b.ptr_param();
        let tid = b.tid_x();
        let bid = b.ctaid_x();
        let bdim = b.ntid_x();
        let base = b.imul(bid, bdim);
        let gid = b.iadd(base, tid);
        let x = b.ldg(pa, gid);
        let y = b.ldg(pb, gid);
        let s = b.fadd(x, y);
        b.stg(pc, gid, s);
        b.ret();
        b.build().unwrap()
    }

    #[test]
    fn vadd_runs() {
        let k = vadd_kernel();
        let mut a = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut bb = vec![10.0f32, 20.0, 30.0, 40.0];
        let mut c = vec![0.0f32; 4];
        run(&k, (2, 1), (2, 1), vec![&mut a, &mut bb, &mut c]).unwrap();
        assert_eq!(c, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn vadd_parallel_matches_sequential_bitwise() {
        let k = vadd_kernel();
        let n = 1024usize;
        let a: Vec<f32> = (0..n).map(|i| (i as f32) * 0.37).collect();
        let b: Vec<f32> = (0..n).map(|i| (i as f32) * -1.11).collect();
        let mut outs = Vec::new();
        for workers in [1usize, 2, 8] {
            let mut aa = a.clone();
            let mut bb = b.clone();
            let mut c = vec![0.0f32; n];
            let report = execute_with(
                Launch {
                    kernel: &k,
                    grid: ((n / 64) as u32, 1),
                    block: (64, 1),
                    buffers: vec![&mut aa, &mut bb, &mut c],
                    scalars: vec![],
                    limits: Limits::default(),
                },
                workers,
            )
            .unwrap();
            assert_eq!(report.blocks, (n / 64) as u64);
            if workers == 1 {
                assert_eq!(report.workers, 1);
            } else {
                assert!(report.workers >= 2 && report.workers <= workers);
            }
            outs.push(c);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[0], outs[2]);
    }

    #[test]
    fn oob_load_traps() {
        let k = vadd_kernel();
        let mut a = vec![1.0f32; 2]; // too small for 4 threads
        let mut bb = vec![1.0f32; 4];
        let mut c = vec![0.0f32; 4];
        let err = run(&k, (2, 1), (2, 1), vec![&mut a, &mut bb, &mut c]).unwrap_err();
        assert!(matches!(err, Error::VtxTrap { .. }), "{err}");
        assert!(err.to_string().contains("OOB"));
    }

    #[test]
    fn shared_memory_tree_reduction() {
        // Classic CUDA reduction: shared[tid] = in[tid]; stride halving
        // with barriers; out[block] = shared[0].
        let n = 8u32;
        let mut b = KernelBuilder::new("reduce");
        let pin = b.ptr_param();
        let pout = b.ptr_param();
        b.shared(n as usize);
        let tid = b.tid_x();
        let v = b.ldg(pin, tid);
        b.sts(tid, v);
        b.bar();
        // stride loop: s = n/2; while s >= 1 { if tid < s shared[tid]+=shared[tid+s]; bar; s/=2 }
        let s = b.consti((n / 2) as i64);
        let one = b.consti(1);
        let two = b.consti(2);
        let zero = b.consti(0);
        let top = b.label();
        let skip = b.label();
        let done = b.label();
        b.bind(top);
        let cont = b.cmpi(CmpOp::Ge, s, one);
        b.bra_ifz(cont, done);
        let active = b.cmpi(CmpOp::Lt, tid, s);
        b.bra_ifz(active, skip);
        let lhs = b.lds(tid);
        let oidx = b.iadd(tid, s);
        let rhs = b.lds(oidx);
        let sum = b.fadd(lhs, rhs);
        b.sts(tid, sum);
        b.bind(skip);
        b.bar();
        let half = b.idiv(s, two);
        b.movi(s, half);
        b.bra(top);
        b.bind(done);
        let is0 = b.cmpi(CmpOp::Eq, tid, zero);
        let out_end = b.label();
        b.bra_ifz(is0, out_end);
        let total = b.lds(tid);
        let bid = b.ctaid_x();
        b.stg(pout, bid, total);
        b.bind(out_end);
        b.ret();
        let k = b.build().unwrap();

        let mut input: Vec<f32> = (1..=n).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; 1];
        run(&k, (1, 1), (n, 1), vec![&mut input, &mut out]).unwrap();
        assert_eq!(out[0], 36.0); // 1+..+8
    }

    #[test]
    fn infinite_loop_hits_step_budget() {
        let mut b = KernelBuilder::new("spin");
        let top = b.label();
        b.bind(top);
        b.bra(top);
        let k = b.build().unwrap();
        let err = execute(Launch {
            kernel: &k,
            grid: (1, 1),
            block: (1, 1),
            buffers: vec![],
            scalars: vec![],
            limits: Limits { steps_per_thread: 1000 },
        })
        .unwrap_err();
        assert!(err.to_string().contains("step budget"), "{err}");
    }

    #[test]
    fn barrier_divergence_traps() {
        // threads with tid==0 exit before the barrier -> divergence
        let mut b = KernelBuilder::new("diverge");
        let tid = b.tid_x();
        let zero = b.consti(0);
        let is0 = b.cmpi(CmpOp::Eq, tid, zero);
        let out = b.label();
        b.bra_if(is0, out);
        b.bar();
        b.bind(out);
        b.ret();
        let k = b.build().unwrap();
        let err = run(&k, (1, 1), (2, 1), vec![]).unwrap_err();
        assert!(err.to_string().contains("barrier divergence"), "{err}");
    }

    #[test]
    fn scalar_params_bound() {
        // out[tid] = scale * tid + offset
        let mut b = KernelBuilder::new("affine");
        let pout = b.ptr_param();
        let pscale = b.f32_param();
        let poff = b.f32_param();
        let tid = b.tid_x();
        let tf = b.cvt_i2f(tid);
        let scale = b.ld_param_f(pscale);
        let off = b.ld_param_f(poff);
        let prod = b.fmul(scale, tf);
        let v = b.fadd(prod, off);
        b.stg(pout, tid, v);
        b.ret();
        let k = b.build().unwrap();
        let mut out = vec![0.0f32; 4];
        execute(Launch {
            kernel: &k,
            grid: (1, 1),
            block: (4, 1),
            buffers: vec![&mut out],
            scalars: vec![ScalarArg::F32(2.0), ScalarArg::F32(1.0)],
            limits: Limits::default(),
        })
        .unwrap();
        assert_eq!(out, vec![1.0, 3.0, 5.0, 7.0]);
    }

    #[test]
    fn buffer_count_mismatch_rejected() {
        let k = vadd_kernel();
        let mut a = vec![0.0f32; 4];
        let err = run(&k, (1, 1), (4, 1), vec![&mut a]).unwrap_err();
        assert!(err.to_string().contains("takes 3 buffers"), "{err}");
    }
}
