//! The VTX interpreter: executes a kernel over a grid of thread blocks,
//! with shared memory, barriers and full trap checking — the GPU Ocelot
//! analog of this stack (paper §5: "developers can now use the GPU
//! support without having any physical NVIDIA hardware").
//!
//! Execution model: blocks are independent (executed sequentially, which
//! is a legal CUDA schedule); within a block, threads run co-operatively —
//! each thread executes until it hits a barrier or exits, then the next
//! thread runs. A barrier releases when every live thread has arrived;
//! divergent barriers (some threads exited while others wait) trap, as on
//! real hardware.

use crate::emulator::isa::{CmpOp, FOp, IOp, Instr, Kernel, Special, UnFOp};
use crate::error::{Error, Result};

/// Per-launch resource limits.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// Max instructions executed per thread (infinite-loop trap).
    pub steps_per_thread: u64,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { steps_per_thread: 64_000_000 }
    }
}

/// Scalar parameter values bound at launch (pointer params are bound via
/// `buffers` instead).
#[derive(Clone, Copy, Debug)]
pub enum ScalarArg {
    F32(f32),
    I32(i32),
}

/// A grid launch of one kernel.
pub struct Launch<'a> {
    pub kernel: &'a Kernel,
    pub grid: (u32, u32),
    pub block: (u32, u32),
    /// One f32 slice per `PtrF32` parameter, in parameter order.
    pub buffers: Vec<&'a mut [f32]>,
    /// One entry per scalar parameter, in parameter order.
    pub scalars: Vec<ScalarArg>,
    pub limits: Limits,
}

#[derive(Clone, Copy, PartialEq)]
enum ThreadState {
    Running,
    AtBarrier,
    Done,
}

struct Thread {
    pc: usize,
    f: Vec<f32>,
    i: Vec<i64>,
    state: ThreadState,
    steps: u64,
}

/// Mapping from parameter index to its binding slot.
enum Binding {
    Ptr(usize),
    Scalar(ScalarArg),
}

pub fn execute(launch: Launch<'_>) -> Result<()> {
    let k = launch.kernel;
    // Bind parameters.
    let mut bindings = Vec::with_capacity(k.params.len());
    let mut nptr = 0usize;
    let mut nscalar = 0usize;
    for p in &k.params {
        match p {
            crate::emulator::isa::ParamKind::PtrF32 => {
                if nptr >= launch.buffers.len() {
                    return Err(Error::InvalidLaunch(format!(
                        "kernel `{}` needs {} buffers, got {}",
                        k.name,
                        k.ptr_param_count(),
                        launch.buffers.len()
                    )));
                }
                bindings.push(Binding::Ptr(nptr));
                nptr += 1;
            }
            _ => {
                let s = launch.scalars.get(nscalar).copied().ok_or_else(|| {
                    Error::InvalidLaunch(format!(
                        "kernel `{}` missing scalar argument {nscalar}",
                        k.name
                    ))
                })?;
                bindings.push(Binding::Scalar(s));
                nscalar += 1;
            }
        }
    }
    if nptr != launch.buffers.len() {
        return Err(Error::InvalidLaunch(format!(
            "kernel `{}` takes {nptr} buffers, got {}",
            k.name,
            launch.buffers.len()
        )));
    }

    let mut buffers = launch.buffers;
    let (gx, gy) = launch.grid;
    let (bx, by) = launch.block;
    let threads_per_block = (bx * by) as usize;

    let trap = |block: (u32, u32), thread: (u32, u32), reason: String| Error::VtxTrap {
        kernel: k.name.clone(),
        block: (block.0, block.1, 0),
        thread: (thread.0, thread.1, 0),
        reason,
    };

    for by_i in 0..gy {
        for bx_i in 0..gx {
            let block_id = (bx_i, by_i);
            let mut shared = vec![0f32; k.shared_f32];
            let mut threads: Vec<Thread> = (0..threads_per_block)
                .map(|_| Thread {
                    pc: 0,
                    f: vec![0f32; k.fregs as usize],
                    i: vec![0i64; k.iregs as usize],
                    state: ThreadState::Running,
                    steps: 0,
                })
                .collect();

            loop {
                let mut progressed = false;
                for t_lin in 0..threads_per_block {
                    if threads[t_lin].state != ThreadState::Running {
                        continue;
                    }
                    progressed = true;
                    let tx = (t_lin as u32) % bx;
                    let ty = (t_lin as u32) / bx;
                    let th = &mut threads[t_lin];
                    // Run this thread until barrier/exit/trap.
                    loop {
                        if th.steps >= launch.limits.steps_per_thread {
                            return Err(trap(
                                block_id,
                                (tx, ty),
                                format!(
                                    "step budget exhausted ({} instructions)",
                                    launch.limits.steps_per_thread
                                ),
                            ));
                        }
                        th.steps += 1;
                        let ins = k.code[th.pc];
                        th.pc += 1;
                        match ins {
                            Instr::ConstF(d, v) => th.f[d as usize] = v,
                            Instr::ConstI(d, v) => th.i[d as usize] = v,
                            Instr::MovF(d, s) => th.f[d as usize] = th.f[s as usize],
                            Instr::MovI(d, s) => th.i[d as usize] = th.i[s as usize],
                            Instr::BinF(op, d, a, b) => {
                                let (x, y) = (th.f[a as usize], th.f[b as usize]);
                                th.f[d as usize] = match op {
                                    FOp::Add => x + y,
                                    FOp::Sub => x - y,
                                    FOp::Mul => x * y,
                                    FOp::Div => x / y,
                                    FOp::Min => x.min(y),
                                    FOp::Max => x.max(y),
                                };
                            }
                            Instr::BinI(op, d, a, b) => {
                                let (x, y) = (th.i[a as usize], th.i[b as usize]);
                                th.i[d as usize] = match op {
                                    IOp::Add => x.wrapping_add(y),
                                    IOp::Sub => x.wrapping_sub(y),
                                    IOp::Mul => x.wrapping_mul(y),
                                    IOp::Div => {
                                        if y == 0 {
                                            return Err(trap(
                                                block_id,
                                                (tx, ty),
                                                "integer division by zero".into(),
                                            ));
                                        }
                                        x / y
                                    }
                                    IOp::Rem => {
                                        if y == 0 {
                                            return Err(trap(
                                                block_id,
                                                (tx, ty),
                                                "integer remainder by zero".into(),
                                            ));
                                        }
                                        x % y
                                    }
                                };
                            }
                            Instr::UnF(op, d, a) => {
                                let x = th.f[a as usize];
                                th.f[d as usize] = match op {
                                    UnFOp::Neg => -x,
                                    UnFOp::Abs => x.abs(),
                                    UnFOp::Sqrt => x.sqrt(),
                                    UnFOp::Sin => x.sin(),
                                    UnFOp::Cos => x.cos(),
                                    UnFOp::Floor => x.floor(),
                                };
                            }
                            Instr::CmpF(op, d, a, b) => {
                                let (x, y) = (th.f[a as usize], th.f[b as usize]);
                                th.i[d as usize] = cmpf(op, x, y) as i64;
                            }
                            Instr::CmpI(op, d, a, b) => {
                                let (x, y) = (th.i[a as usize], th.i[b as usize]);
                                th.i[d as usize] = cmpi(op, x, y) as i64;
                            }
                            Instr::SelF(d, p, a, b) => {
                                th.f[d as usize] = if th.i[p as usize] != 0 {
                                    th.f[a as usize]
                                } else {
                                    th.f[b as usize]
                                };
                            }
                            Instr::CvtFI(d, s) => th.i[d as usize] = th.f[s as usize] as i64,
                            Instr::CvtIF(d, s) => th.f[d as usize] = th.i[s as usize] as f32,
                            Instr::Spec(d, s) => {
                                th.i[d as usize] = match s {
                                    Special::ThreadIdX => tx as i64,
                                    Special::ThreadIdY => ty as i64,
                                    Special::BlockIdX => bx_i as i64,
                                    Special::BlockIdY => by_i as i64,
                                    Special::BlockDimX => bx as i64,
                                    Special::BlockDimY => by as i64,
                                    Special::GridDimX => gx as i64,
                                    Special::GridDimY => gy as i64,
                                };
                            }
                            Instr::LdG { dst, param, idx } => {
                                let slot = match &bindings[param as usize] {
                                    Binding::Ptr(s) => *s,
                                    _ => unreachable!("validated"),
                                };
                                let i = th.i[idx as usize];
                                let buf = &buffers[slot];
                                if i < 0 || i as usize >= buf.len() {
                                    return Err(trap(
                                        block_id,
                                        (tx, ty),
                                        format!(
                                            "global load OOB: index {i} in buffer of {} elements (param {param})",
                                            buf.len()
                                        ),
                                    ));
                                }
                                th.f[dst as usize] = buf[i as usize];
                            }
                            Instr::StG { param, idx, src } => {
                                let slot = match &bindings[param as usize] {
                                    Binding::Ptr(s) => *s,
                                    _ => unreachable!("validated"),
                                };
                                let i = th.i[idx as usize];
                                let v = th.f[src as usize];
                                let buf = &mut buffers[slot];
                                if i < 0 || i as usize >= buf.len() {
                                    return Err(trap(
                                        block_id,
                                        (tx, ty),
                                        format!(
                                            "global store OOB: index {i} in buffer of {} elements (param {param})",
                                            buf.len()
                                        ),
                                    ));
                                }
                                buf[i as usize] = v;
                            }
                            Instr::LdS { dst, idx } => {
                                let i = th.i[idx as usize];
                                if i < 0 || i as usize >= shared.len() {
                                    return Err(trap(
                                        block_id,
                                        (tx, ty),
                                        format!(
                                            "shared load OOB: index {i} of {}",
                                            shared.len()
                                        ),
                                    ));
                                }
                                th.f[dst as usize] = shared[i as usize];
                            }
                            Instr::StS { idx, src } => {
                                let i = th.i[idx as usize];
                                if i < 0 || i as usize >= shared.len() {
                                    return Err(trap(
                                        block_id,
                                        (tx, ty),
                                        format!(
                                            "shared store OOB: index {i} of {}",
                                            shared.len()
                                        ),
                                    ));
                                }
                                shared[i as usize] = th.f[src as usize];
                            }
                            Instr::LdParamF(d, p) => {
                                th.f[d as usize] = match &bindings[p as usize] {
                                    Binding::Scalar(ScalarArg::F32(v)) => *v,
                                    Binding::Scalar(ScalarArg::I32(v)) => *v as f32,
                                    _ => unreachable!("validated"),
                                };
                            }
                            Instr::LdParamI(d, p) => {
                                th.i[d as usize] = match &bindings[p as usize] {
                                    Binding::Scalar(ScalarArg::I32(v)) => *v as i64,
                                    Binding::Scalar(ScalarArg::F32(v)) => *v as i64,
                                    _ => unreachable!("validated"),
                                };
                            }
                            Instr::Bar => {
                                th.state = ThreadState::AtBarrier;
                                break;
                            }
                            Instr::Bra(t) => th.pc = t as usize,
                            Instr::BraIf(p, t) => {
                                if th.i[p as usize] != 0 {
                                    th.pc = t as usize;
                                }
                            }
                            Instr::BraIfZ(p, t) => {
                                if th.i[p as usize] == 0 {
                                    th.pc = t as usize;
                                }
                            }
                            Instr::Ret => {
                                th.state = ThreadState::Done;
                                break;
                            }
                        }
                    }
                }

                // Barrier resolution.
                let any_running = threads.iter().any(|t| t.state == ThreadState::Running);
                if any_running {
                    continue;
                }
                let at_barrier = threads
                    .iter()
                    .filter(|t| t.state == ThreadState::AtBarrier)
                    .count();
                if at_barrier == 0 {
                    break; // all done
                }
                let done = threads.iter().filter(|t| t.state == ThreadState::Done).count();
                if done > 0 {
                    return Err(trap(
                        block_id,
                        (0, 0),
                        format!(
                            "barrier divergence: {at_barrier} threads waiting, {done} exited"
                        ),
                    ));
                }
                for t in &mut threads {
                    t.state = ThreadState::Running;
                }
                if !progressed {
                    return Err(trap(block_id, (0, 0), "scheduler made no progress".into()));
                }
            }
        }
    }
    Ok(())
}

fn cmpf(op: CmpOp, x: f32, y: f32) -> bool {
    match op {
        CmpOp::Lt => x < y,
        CmpOp::Le => x <= y,
        CmpOp::Gt => x > y,
        CmpOp::Ge => x >= y,
        CmpOp::Eq => x == y,
        CmpOp::Ne => x != y,
    }
}

fn cmpi(op: CmpOp, x: i64, y: i64) -> bool {
    match op {
        CmpOp::Lt => x < y,
        CmpOp::Le => x <= y,
        CmpOp::Gt => x > y,
        CmpOp::Ge => x >= y,
        CmpOp::Eq => x == y,
        CmpOp::Ne => x != y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emulator::builder::KernelBuilder;
    use crate::emulator::isa::CmpOp;

    fn run(k: &Kernel, grid: (u32, u32), block: (u32, u32), bufs: Vec<&mut [f32]>) -> Result<()> {
        execute(Launch {
            kernel: k,
            grid,
            block,
            buffers: bufs,
            scalars: vec![],
            limits: Limits::default(),
        })
    }

    /// out[global_tid] = a[global_tid] + b[global_tid]
    fn vadd_kernel() -> Kernel {
        let mut b = KernelBuilder::new("vadd");
        let pa = b.ptr_param();
        let pb = b.ptr_param();
        let pc = b.ptr_param();
        let tid = b.tid_x();
        let bid = b.ctaid_x();
        let bdim = b.ntid_x();
        let base = b.imul(bid, bdim);
        let gid = b.iadd(base, tid);
        let x = b.ldg(pa, gid);
        let y = b.ldg(pb, gid);
        let s = b.fadd(x, y);
        b.stg(pc, gid, s);
        b.ret();
        b.build().unwrap()
    }

    #[test]
    fn vadd_runs() {
        let k = vadd_kernel();
        let mut a = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut bb = vec![10.0f32, 20.0, 30.0, 40.0];
        let mut c = vec![0.0f32; 4];
        run(&k, (2, 1), (2, 1), vec![&mut a, &mut bb, &mut c]).unwrap();
        assert_eq!(c, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn oob_load_traps() {
        let k = vadd_kernel();
        let mut a = vec![1.0f32; 2]; // too small for 4 threads
        let mut bb = vec![1.0f32; 4];
        let mut c = vec![0.0f32; 4];
        let err = run(&k, (2, 1), (2, 1), vec![&mut a, &mut bb, &mut c]).unwrap_err();
        assert!(matches!(err, Error::VtxTrap { .. }), "{err}");
        assert!(err.to_string().contains("OOB"));
    }

    #[test]
    fn shared_memory_tree_reduction() {
        // Classic CUDA reduction: shared[tid] = in[tid]; stride halving
        // with barriers; out[block] = shared[0].
        let n = 8u32;
        let mut b = KernelBuilder::new("reduce");
        let pin = b.ptr_param();
        let pout = b.ptr_param();
        b.shared(n as usize);
        let tid = b.tid_x();
        let v = b.ldg(pin, tid);
        b.sts(tid, v);
        b.bar();
        // stride loop: s = n/2; while s >= 1 { if tid < s shared[tid]+=shared[tid+s]; bar; s/=2 }
        let s = b.consti((n / 2) as i64);
        let one = b.consti(1);
        let two = b.consti(2);
        let zero = b.consti(0);
        let top = b.label();
        let skip = b.label();
        let done = b.label();
        b.bind(top);
        let cont = b.cmpi(CmpOp::Ge, s, one);
        b.bra_ifz(cont, done);
        let active = b.cmpi(CmpOp::Lt, tid, s);
        b.bra_ifz(active, skip);
        let lhs = b.lds(tid);
        let oidx = b.iadd(tid, s);
        let rhs = b.lds(oidx);
        let sum = b.fadd(lhs, rhs);
        b.sts(tid, sum);
        b.bind(skip);
        b.bar();
        let half = b.idiv(s, two);
        b.movi(s, half);
        b.bra(top);
        b.bind(done);
        let is0 = b.cmpi(CmpOp::Eq, tid, zero);
        let out_end = b.label();
        b.bra_ifz(is0, out_end);
        let total = b.lds(tid);
        let bid = b.ctaid_x();
        b.stg(pout, bid, total);
        b.bind(out_end);
        b.ret();
        let k = b.build().unwrap();

        let mut input: Vec<f32> = (1..=n).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; 1];
        run(&k, (1, 1), (n, 1), vec![&mut input, &mut out]).unwrap();
        assert_eq!(out[0], 36.0); // 1+..+8
    }

    #[test]
    fn infinite_loop_hits_step_budget() {
        let mut b = KernelBuilder::new("spin");
        let top = b.label();
        b.bind(top);
        b.bra(top);
        let k = b.build().unwrap();
        let err = execute(Launch {
            kernel: &k,
            grid: (1, 1),
            block: (1, 1),
            buffers: vec![],
            scalars: vec![],
            limits: Limits { steps_per_thread: 1000 },
        })
        .unwrap_err();
        assert!(err.to_string().contains("step budget"), "{err}");
    }

    #[test]
    fn barrier_divergence_traps() {
        // threads with tid==0 exit before the barrier -> divergence
        let mut b = KernelBuilder::new("diverge");
        let tid = b.tid_x();
        let zero = b.consti(0);
        let is0 = b.cmpi(CmpOp::Eq, tid, zero);
        let out = b.label();
        b.bra_if(is0, out);
        b.bar();
        b.bind(out);
        b.ret();
        let k = b.build().unwrap();
        let err = run(&k, (1, 1), (2, 1), vec![]).unwrap_err();
        assert!(err.to_string().contains("barrier divergence"), "{err}");
    }

    #[test]
    fn scalar_params_bound() {
        // out[tid] = scale * tid + offset
        let mut b = KernelBuilder::new("affine");
        let pout = b.ptr_param();
        let pscale = b.f32_param();
        let poff = b.f32_param();
        let tid = b.tid_x();
        let tf = b.cvt_i2f(tid);
        let scale = b.ld_param_f(pscale);
        let off = b.ld_param_f(poff);
        let prod = b.fmul(scale, tf);
        let v = b.fadd(prod, off);
        b.stg(pout, tid, v);
        b.ret();
        let k = b.build().unwrap();
        let mut out = vec![0.0f32; 4];
        execute(Launch {
            kernel: &k,
            grid: (1, 1),
            block: (4, 1),
            buffers: vec![&mut out],
            scalars: vec![ScalarArg::F32(2.0), ScalarArg::F32(1.0)],
            limits: Limits::default(),
        })
        .unwrap();
        assert_eq!(out, vec![1.0, 3.0, 5.0, 7.0]);
    }
}
