//! The VTX interpreter: executes a kernel over a grid of thread blocks,
//! with shared memory, barriers and full trap checking — the GPU Ocelot
//! analog of this stack (paper §5: "developers can now use the GPU
//! support without having any physical NVIDIA hardware").
//!
//! Execution model: blocks are **independent** (the CUDA contract) and
//! are dispatched across the fixed worker-thread pool of
//! [`crate::emulator::sched`] — each worker claims the next unclaimed
//! block, interprets it to completion with its own private shared memory
//! and register files, and moves on. Global memory is shared across
//! blocks through per-element atomic cells, so cross-block races behave
//! like (relaxed) hardware races instead of undefined behavior. A
//! single-worker schedule (`HLGPU_WORKERS=1`, or
//! [`crate::emulator::sched::set_default_workers`]) degenerates to the
//! classic sequential block loop, which is also used automatically for
//! single-block grids. For **race-free kernels** (blocks that do not
//! communicate through global memory — the only kernels with defined
//! results on real hardware either) schedules of any width produce
//! identical results and identical trap coordinates: on a trap, the
//! scheduler stops claiming new blocks, drains the in-flight ones, and
//! reports the trap of the **lowest** block index — exactly what the
//! sequential schedule reports. Kernels that race across blocks get
//! hardware-like unordered behavior, under which observed values (and
//! therefore traps derived from them) may differ from the sequential
//! interleaving.
//!
//! Within a block, threads run co-operatively — each thread executes
//! until it hits a barrier or exits, then the next thread runs. A barrier
//! releases when every live thread has arrived; divergent barriers (some
//! threads exited while others wait) trap, as on real hardware.
//!
//! Before any block runs, the instruction stream is pre-decoded once per
//! (kernel, scalar binding) by [`crate::emulator::decode`]: scalar
//! parameters become immediates and pointer parameters become dense
//! buffer slots, so the interpreter hot loop performs no binding lookups.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::driver::launch::LaunchReport;
use crate::emulator::decode::{decode, DecodedKernel};
use crate::emulator::isa::{CmpOp, FOp, IOp, Instr, Kernel, Special, UnFOp};
use crate::emulator::sched::{default_workers, ArriveGuard, Latch, WorkerPool};
use crate::error::{Error, Result};

/// Per-launch resource limits.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// Max instructions executed per thread (infinite-loop trap).
    pub steps_per_thread: u64,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { steps_per_thread: 64_000_000 }
    }
}

/// Scalar parameter values bound at launch (pointer params are bound via
/// `buffers` instead).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScalarArg {
    F32(f32),
    I32(i32),
}

/// A grid launch of one kernel.
pub struct Launch<'a> {
    pub kernel: &'a Kernel,
    pub grid: (u32, u32),
    pub block: (u32, u32),
    /// One f32 slice per `PtrF32` parameter, in parameter order.
    pub buffers: Vec<&'a mut [f32]>,
    /// One entry per scalar parameter, in parameter order.
    pub scalars: Vec<ScalarArg>,
    pub limits: Limits,
}

/// Execute a launch with the default schedule width
/// ([`crate::emulator::sched::default_workers`]).
pub fn execute(launch: Launch<'_>) -> Result<()> {
    execute_with(launch, default_workers()).map(|_| ())
}

/// Execute a launch with an explicit schedule width. `workers <= 1` (or a
/// single-block grid) runs the sequential schedule; larger widths
/// dispatch blocks across the global worker pool.
pub fn execute_with(launch: Launch<'_>, workers: usize) -> Result<LaunchReport> {
    let decoded = Arc::new(decode(launch.kernel, &launch.scalars)?);
    execute_decoded(
        &decoded,
        launch.grid,
        launch.block,
        launch.buffers,
        &launch.limits,
        workers,
    )
}

/// Execute a pre-decoded kernel (the cached warm path: the coordinator's
/// `Specialized` entry holds the decoded form and skips `decode`).
pub fn execute_decoded(
    kernel: &Arc<DecodedKernel>,
    grid: (u32, u32),
    block: (u32, u32),
    buffers: Vec<&mut [f32]>,
    limits: &Limits,
    workers: usize,
) -> Result<LaunchReport> {
    if buffers.len() != kernel.nbufs {
        return Err(Error::InvalidLaunch(format!(
            "kernel `{}` takes {} buffers, got {}",
            kernel.name,
            kernel.nbufs,
            buffers.len()
        )));
    }
    let nblocks = grid.0 as u64 * grid.1 as u64;
    if workers > 1 && nblocks > 1 {
        run_parallel(kernel, grid, block, buffers, limits, workers)
    } else {
        run_sequential(kernel, grid, block, buffers, limits)
    }
}

// ------------------------------------------------------------------------
// Global memory views
// ------------------------------------------------------------------------

/// Global-memory access used by the block interpreter. Monomorphized per
/// schedule: plain slices for the sequential path, shared atomic cells
/// for the parallel path.
trait GlobalMem {
    fn len(&self, slot: usize) -> usize;
    fn load(&self, slot: usize, idx: usize) -> f32;
    fn store(&mut self, slot: usize, idx: usize, v: f32);
}

/// Sequential view: the launch's slices, accessed directly.
struct SliceMem<'a> {
    bufs: Vec<&'a mut [f32]>,
}

impl GlobalMem for SliceMem<'_> {
    #[inline]
    fn len(&self, slot: usize) -> usize {
        self.bufs[slot].len()
    }
    #[inline]
    fn load(&self, slot: usize, idx: usize) -> f32 {
        self.bufs[slot][idx]
    }
    #[inline]
    fn store(&mut self, slot: usize, idx: usize, v: f32) {
        self.bufs[slot][idx] = v;
    }
}

/// Parallel view: per-block scoped handle onto the launch's shared
/// buffers. f32 values live as `AtomicU32` bit patterns with relaxed
/// ordering — block independence means race-free kernels see exactly the
/// sequential results, and racy kernels get hardware-like (defined,
/// unordered) behavior rather than UB.
struct AtomicMem<'a> {
    bufs: &'a [Vec<AtomicU32>],
}

impl GlobalMem for AtomicMem<'_> {
    #[inline]
    fn len(&self, slot: usize) -> usize {
        self.bufs[slot].len()
    }
    #[inline]
    fn load(&self, slot: usize, idx: usize) -> f32 {
        f32::from_bits(self.bufs[slot][idx].load(Ordering::Relaxed))
    }
    #[inline]
    fn store(&mut self, slot: usize, idx: usize, v: f32) {
        self.bufs[slot][idx].store(v.to_bits(), Ordering::Relaxed);
    }
}

// ------------------------------------------------------------------------
// Block interpreter (shared by both schedules)
// ------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum ThreadState {
    Running,
    AtBarrier,
    Done,
}

struct Thread {
    pc: usize,
    f: Vec<f32>,
    i: Vec<i64>,
    state: ThreadState,
    steps: u64,
}

/// Interpret one thread block to completion (or trap). Identical for the
/// sequential and parallel schedules, so traps surface with identical
/// coordinates and reasons under both.
fn run_block<M: GlobalMem>(
    k: &DecodedKernel,
    grid: (u32, u32),
    block: (u32, u32),
    block_id: (u32, u32),
    mem: &mut M,
    limits: &Limits,
) -> Result<()> {
    let (gx, gy) = grid;
    let (bx, by) = block;
    let (bx_i, by_i) = block_id;
    let threads_per_block = (bx * by) as usize;

    let trap = |thread: (u32, u32), reason: String| Error::VtxTrap {
        kernel: k.name.clone(),
        block: (bx_i, by_i, 0),
        thread: (thread.0, thread.1, 0),
        reason,
    };

    let mut shared = vec![0f32; k.shared_f32];
    let mut threads: Vec<Thread> = (0..threads_per_block)
        .map(|_| Thread {
            pc: 0,
            f: vec![0f32; k.fregs as usize],
            i: vec![0i64; k.iregs as usize],
            state: ThreadState::Running,
            steps: 0,
        })
        .collect();

    loop {
        let mut progressed = false;
        for t_lin in 0..threads_per_block {
            if threads[t_lin].state != ThreadState::Running {
                continue;
            }
            progressed = true;
            let tx = (t_lin as u32) % bx;
            let ty = (t_lin as u32) / bx;
            let th = &mut threads[t_lin];
            // Run this thread until barrier/exit/trap.
            loop {
                if th.steps >= limits.steps_per_thread {
                    return Err(trap(
                        (tx, ty),
                        format!(
                            "step budget exhausted ({} instructions)",
                            limits.steps_per_thread
                        ),
                    ));
                }
                th.steps += 1;
                let ins = k.code[th.pc];
                th.pc += 1;
                match ins {
                    Instr::ConstF(d, v) => th.f[d as usize] = v,
                    Instr::ConstI(d, v) => th.i[d as usize] = v,
                    Instr::MovF(d, s) => th.f[d as usize] = th.f[s as usize],
                    Instr::MovI(d, s) => th.i[d as usize] = th.i[s as usize],
                    Instr::BinF(op, d, a, b) => {
                        let (x, y) = (th.f[a as usize], th.f[b as usize]);
                        th.f[d as usize] = match op {
                            FOp::Add => x + y,
                            FOp::Sub => x - y,
                            FOp::Mul => x * y,
                            FOp::Div => x / y,
                            FOp::Min => x.min(y),
                            FOp::Max => x.max(y),
                        };
                    }
                    Instr::BinI(op, d, a, b) => {
                        let (x, y) = (th.i[a as usize], th.i[b as usize]);
                        th.i[d as usize] = match op {
                            IOp::Add => x.wrapping_add(y),
                            IOp::Sub => x.wrapping_sub(y),
                            IOp::Mul => x.wrapping_mul(y),
                            IOp::Div => {
                                if y == 0 {
                                    return Err(trap(
                                        (tx, ty),
                                        "integer division by zero".into(),
                                    ));
                                }
                                x / y
                            }
                            IOp::Rem => {
                                if y == 0 {
                                    return Err(trap(
                                        (tx, ty),
                                        "integer remainder by zero".into(),
                                    ));
                                }
                                x % y
                            }
                        };
                    }
                    Instr::UnF(op, d, a) => {
                        let x = th.f[a as usize];
                        th.f[d as usize] = match op {
                            UnFOp::Neg => -x,
                            UnFOp::Abs => x.abs(),
                            UnFOp::Sqrt => x.sqrt(),
                            UnFOp::Sin => x.sin(),
                            UnFOp::Cos => x.cos(),
                            UnFOp::Floor => x.floor(),
                        };
                    }
                    Instr::CmpF(op, d, a, b) => {
                        let (x, y) = (th.f[a as usize], th.f[b as usize]);
                        th.i[d as usize] = cmpf(op, x, y) as i64;
                    }
                    Instr::CmpI(op, d, a, b) => {
                        let (x, y) = (th.i[a as usize], th.i[b as usize]);
                        th.i[d as usize] = cmpi(op, x, y) as i64;
                    }
                    Instr::SelF(d, p, a, b) => {
                        th.f[d as usize] = if th.i[p as usize] != 0 {
                            th.f[a as usize]
                        } else {
                            th.f[b as usize]
                        };
                    }
                    Instr::CvtFI(d, s) => th.i[d as usize] = th.f[s as usize] as i64,
                    Instr::CvtIF(d, s) => th.f[d as usize] = th.i[s as usize] as f32,
                    Instr::Spec(d, s) => {
                        th.i[d as usize] = match s {
                            Special::ThreadIdX => tx as i64,
                            Special::ThreadIdY => ty as i64,
                            Special::BlockIdX => bx_i as i64,
                            Special::BlockIdY => by_i as i64,
                            Special::BlockDimX => bx as i64,
                            Special::BlockDimY => by as i64,
                            Special::GridDimX => gx as i64,
                            Special::GridDimY => gy as i64,
                        };
                    }
                    Instr::LdG { dst, param, idx } => {
                        // `param` is a buffer slot after pre-decoding.
                        let slot = param as usize;
                        let i = th.i[idx as usize];
                        let len = mem.len(slot);
                        if i < 0 || i as usize >= len {
                            return Err(trap(
                                (tx, ty),
                                format!(
                                    "global load OOB: index {i} in buffer of {len} elements (buffer {slot})"
                                ),
                            ));
                        }
                        th.f[dst as usize] = mem.load(slot, i as usize);
                    }
                    Instr::StG { param, idx, src } => {
                        let slot = param as usize;
                        let i = th.i[idx as usize];
                        let v = th.f[src as usize];
                        let len = mem.len(slot);
                        if i < 0 || i as usize >= len {
                            return Err(trap(
                                (tx, ty),
                                format!(
                                    "global store OOB: index {i} in buffer of {len} elements (buffer {slot})"
                                ),
                            ));
                        }
                        mem.store(slot, i as usize, v);
                    }
                    Instr::LdS { dst, idx } => {
                        let i = th.i[idx as usize];
                        if i < 0 || i as usize >= shared.len() {
                            return Err(trap(
                                (tx, ty),
                                format!("shared load OOB: index {i} of {}", shared.len()),
                            ));
                        }
                        th.f[dst as usize] = shared[i as usize];
                    }
                    Instr::StS { idx, src } => {
                        let i = th.i[idx as usize];
                        if i < 0 || i as usize >= shared.len() {
                            return Err(trap(
                                (tx, ty),
                                format!("shared store OOB: index {i} of {}", shared.len()),
                            ));
                        }
                        shared[i as usize] = th.f[src as usize];
                    }
                    Instr::LdParamF(..) | Instr::LdParamI(..) => {
                        unreachable!("scalar params resolved by pre-decode")
                    }
                    Instr::Bar => {
                        th.state = ThreadState::AtBarrier;
                        break;
                    }
                    Instr::Bra(t) => th.pc = t as usize,
                    Instr::BraIf(p, t) => {
                        if th.i[p as usize] != 0 {
                            th.pc = t as usize;
                        }
                    }
                    Instr::BraIfZ(p, t) => {
                        if th.i[p as usize] == 0 {
                            th.pc = t as usize;
                        }
                    }
                    Instr::Ret => {
                        th.state = ThreadState::Done;
                        break;
                    }
                }
            }
        }

        // Barrier resolution.
        let any_running = threads.iter().any(|t| t.state == ThreadState::Running);
        if any_running {
            continue;
        }
        let at_barrier = threads
            .iter()
            .filter(|t| t.state == ThreadState::AtBarrier)
            .count();
        if at_barrier == 0 {
            return Ok(()); // all done
        }
        let done = threads.iter().filter(|t| t.state == ThreadState::Done).count();
        if done > 0 {
            return Err(trap(
                (0, 0),
                format!("barrier divergence: {at_barrier} threads waiting, {done} exited"),
            ));
        }
        for t in &mut threads {
            t.state = ThreadState::Running;
        }
        if !progressed {
            return Err(trap((0, 0), "scheduler made no progress".into()));
        }
    }
}

// ------------------------------------------------------------------------
// Schedules
// ------------------------------------------------------------------------

/// Sequential schedule: blocks in linear order on the calling thread —
/// the reference schedule the parallel one must be indistinguishable
/// from (modulo wall time).
fn run_sequential(
    k: &DecodedKernel,
    grid: (u32, u32),
    block: (u32, u32),
    buffers: Vec<&mut [f32]>,
    limits: &Limits,
) -> Result<LaunchReport> {
    let t0 = Instant::now();
    let (gx, gy) = grid;
    let mut mem = SliceMem { bufs: buffers };
    for by_i in 0..gy {
        for bx_i in 0..gx {
            run_block(k, grid, block, (bx_i, by_i), &mut mem, limits)?;
        }
    }
    let wall = t0.elapsed().as_nanos() as u64;
    Ok(LaunchReport {
        blocks: gx as u64 * gy as u64,
        workers: 1,
        busy_ns: wall,
        wall_ns: wall,
    })
}

/// Shared state of one parallel launch.
struct ParShared {
    kernel: Arc<DecodedKernel>,
    bufs: Vec<Vec<AtomicU32>>,
    grid: (u32, u32),
    block: (u32, u32),
    limits: Limits,
    /// Next unclaimed linear block index. Claimed strictly in order, so
    /// when a trap cancels the launch every block below the trapping one
    /// has already been claimed — guaranteeing the minimum-index trap is
    /// the same one the sequential schedule would report.
    next: AtomicU64,
    cancel: AtomicBool,
    traps: Mutex<Vec<(u64, Error)>>,
    busy_ns: AtomicU64,
    latch: Latch,
}

impl ParShared {
    fn worker(&self) {
        let _arrive = ArriveGuard(&self.latch);
        let t0 = Instant::now();
        let gx = self.grid.0 as u64;
        let nblocks = gx * self.grid.1 as u64;
        let mut mem = AtomicMem { bufs: &self.bufs };
        loop {
            if self.cancel.load(Ordering::Relaxed) {
                break;
            }
            let lin = self.next.fetch_add(1, Ordering::Relaxed);
            if lin >= nblocks {
                break;
            }
            let block_id = ((lin % gx) as u32, (lin / gx) as u32);
            if let Err(e) = run_block(
                &self.kernel,
                self.grid,
                self.block,
                block_id,
                &mut mem,
                &self.limits,
            ) {
                self.traps.lock().unwrap().push((lin, e));
                self.cancel.store(true, Ordering::Relaxed);
            }
        }
        self.busy_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Parallel schedule: blocks claimed in order off a shared counter by up
/// to `workers` jobs on the global pool. On success the atomic buffers
/// are written back to the launch's slices; on a trap the buffers are
/// left untouched (as the backend discards them — trap-visible state
/// matches the sequential schedule at the driver level).
///
/// The copy into owned `AtomicU32` cells (and back) keeps every job
/// `'static`-safe without unsafe lifetime erasure; it is O(buffer)
/// serial work, acceptable because the interpreter's per-element cost
/// dwarfs a memcpy for every kernel in the repo. Revisit with an
/// in-place atomic view if a memory-bound workload ever appears.
fn run_parallel(
    kernel: &Arc<DecodedKernel>,
    grid: (u32, u32),
    block: (u32, u32),
    mut buffers: Vec<&mut [f32]>,
    limits: &Limits,
    workers: usize,
) -> Result<LaunchReport> {
    let nblocks = grid.0 as u64 * grid.1 as u64;
    let pool = WorkerPool::global();
    // Clamp to the pool: submitting more jobs than threads cannot add
    // concurrency, and the report must state the width that actually ran.
    let njobs = workers.min(nblocks as usize).min(pool.size()).max(1);
    let t0 = Instant::now();

    let shared = Arc::new(ParShared {
        kernel: kernel.clone(),
        bufs: buffers
            .iter()
            .map(|b| b.iter().map(|v| AtomicU32::new(v.to_bits())).collect())
            .collect(),
        grid,
        block,
        limits: *limits,
        next: AtomicU64::new(0),
        cancel: AtomicBool::new(false),
        traps: Mutex::new(Vec::new()),
        busy_ns: AtomicU64::new(0),
        latch: Latch::new(njobs),
    });

    for _ in 0..njobs {
        let st = shared.clone();
        pool.submit(Box::new(move || st.worker()));
    }
    let panicked = shared.latch.wait();
    if panicked {
        return Err(Error::Other(
            "VTX worker thread panicked during block execution".into(),
        ));
    }

    {
        let mut traps = shared.traps.lock().unwrap();
        if !traps.is_empty() {
            traps.sort_by_key(|(lin, _)| *lin);
            return Err(traps.remove(0).1);
        }
    }

    // Success: publish the device-visible state back into the launch's
    // buffers.
    for (dst, src) in buffers.iter_mut().zip(&shared.bufs) {
        for (d, s) in dst.iter_mut().zip(src) {
            *d = f32::from_bits(s.load(Ordering::Relaxed));
        }
    }

    Ok(LaunchReport {
        blocks: nblocks,
        workers: njobs,
        busy_ns: shared.busy_ns.load(Ordering::Relaxed),
        wall_ns: t0.elapsed().as_nanos() as u64,
    })
}

fn cmpf(op: CmpOp, x: f32, y: f32) -> bool {
    match op {
        CmpOp::Lt => x < y,
        CmpOp::Le => x <= y,
        CmpOp::Gt => x > y,
        CmpOp::Ge => x >= y,
        CmpOp::Eq => x == y,
        CmpOp::Ne => x != y,
    }
}

fn cmpi(op: CmpOp, x: i64, y: i64) -> bool {
    match op {
        CmpOp::Lt => x < y,
        CmpOp::Le => x <= y,
        CmpOp::Gt => x > y,
        CmpOp::Ge => x >= y,
        CmpOp::Eq => x == y,
        CmpOp::Ne => x != y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emulator::builder::KernelBuilder;
    use crate::emulator::isa::CmpOp;

    fn run(k: &Kernel, grid: (u32, u32), block: (u32, u32), bufs: Vec<&mut [f32]>) -> Result<()> {
        execute(Launch {
            kernel: k,
            grid,
            block,
            buffers: bufs,
            scalars: vec![],
            limits: Limits::default(),
        })
    }

    /// out[global_tid] = a[global_tid] + b[global_tid]
    fn vadd_kernel() -> Kernel {
        let mut b = KernelBuilder::new("vadd");
        let pa = b.ptr_param();
        let pb = b.ptr_param();
        let pc = b.ptr_param();
        let tid = b.tid_x();
        let bid = b.ctaid_x();
        let bdim = b.ntid_x();
        let base = b.imul(bid, bdim);
        let gid = b.iadd(base, tid);
        let x = b.ldg(pa, gid);
        let y = b.ldg(pb, gid);
        let s = b.fadd(x, y);
        b.stg(pc, gid, s);
        b.ret();
        b.build().unwrap()
    }

    #[test]
    fn vadd_runs() {
        let k = vadd_kernel();
        let mut a = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut bb = vec![10.0f32, 20.0, 30.0, 40.0];
        let mut c = vec![0.0f32; 4];
        run(&k, (2, 1), (2, 1), vec![&mut a, &mut bb, &mut c]).unwrap();
        assert_eq!(c, vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn vadd_parallel_matches_sequential_bitwise() {
        let k = vadd_kernel();
        let n = 1024usize;
        let a: Vec<f32> = (0..n).map(|i| (i as f32) * 0.37).collect();
        let b: Vec<f32> = (0..n).map(|i| (i as f32) * -1.11).collect();
        let mut outs = Vec::new();
        for workers in [1usize, 2, 8] {
            let mut aa = a.clone();
            let mut bb = b.clone();
            let mut c = vec![0.0f32; n];
            let report = execute_with(
                Launch {
                    kernel: &k,
                    grid: ((n / 64) as u32, 1),
                    block: (64, 1),
                    buffers: vec![&mut aa, &mut bb, &mut c],
                    scalars: vec![],
                    limits: Limits::default(),
                },
                workers,
            )
            .unwrap();
            assert_eq!(report.blocks, (n / 64) as u64);
            if workers == 1 {
                assert_eq!(report.workers, 1);
            } else {
                assert!(report.workers >= 2 && report.workers <= workers);
            }
            outs.push(c);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[0], outs[2]);
    }

    #[test]
    fn oob_load_traps() {
        let k = vadd_kernel();
        let mut a = vec![1.0f32; 2]; // too small for 4 threads
        let mut bb = vec![1.0f32; 4];
        let mut c = vec![0.0f32; 4];
        let err = run(&k, (2, 1), (2, 1), vec![&mut a, &mut bb, &mut c]).unwrap_err();
        assert!(matches!(err, Error::VtxTrap { .. }), "{err}");
        assert!(err.to_string().contains("OOB"));
    }

    #[test]
    fn shared_memory_tree_reduction() {
        // Classic CUDA reduction: shared[tid] = in[tid]; stride halving
        // with barriers; out[block] = shared[0].
        let n = 8u32;
        let mut b = KernelBuilder::new("reduce");
        let pin = b.ptr_param();
        let pout = b.ptr_param();
        b.shared(n as usize);
        let tid = b.tid_x();
        let v = b.ldg(pin, tid);
        b.sts(tid, v);
        b.bar();
        // stride loop: s = n/2; while s >= 1 { if tid < s shared[tid]+=shared[tid+s]; bar; s/=2 }
        let s = b.consti((n / 2) as i64);
        let one = b.consti(1);
        let two = b.consti(2);
        let zero = b.consti(0);
        let top = b.label();
        let skip = b.label();
        let done = b.label();
        b.bind(top);
        let cont = b.cmpi(CmpOp::Ge, s, one);
        b.bra_ifz(cont, done);
        let active = b.cmpi(CmpOp::Lt, tid, s);
        b.bra_ifz(active, skip);
        let lhs = b.lds(tid);
        let oidx = b.iadd(tid, s);
        let rhs = b.lds(oidx);
        let sum = b.fadd(lhs, rhs);
        b.sts(tid, sum);
        b.bind(skip);
        b.bar();
        let half = b.idiv(s, two);
        b.movi(s, half);
        b.bra(top);
        b.bind(done);
        let is0 = b.cmpi(CmpOp::Eq, tid, zero);
        let out_end = b.label();
        b.bra_ifz(is0, out_end);
        let total = b.lds(tid);
        let bid = b.ctaid_x();
        b.stg(pout, bid, total);
        b.bind(out_end);
        b.ret();
        let k = b.build().unwrap();

        let mut input: Vec<f32> = (1..=n).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; 1];
        run(&k, (1, 1), (n, 1), vec![&mut input, &mut out]).unwrap();
        assert_eq!(out[0], 36.0); // 1+..+8
    }

    #[test]
    fn infinite_loop_hits_step_budget() {
        let mut b = KernelBuilder::new("spin");
        let top = b.label();
        b.bind(top);
        b.bra(top);
        let k = b.build().unwrap();
        let err = execute(Launch {
            kernel: &k,
            grid: (1, 1),
            block: (1, 1),
            buffers: vec![],
            scalars: vec![],
            limits: Limits { steps_per_thread: 1000 },
        })
        .unwrap_err();
        assert!(err.to_string().contains("step budget"), "{err}");
    }

    #[test]
    fn barrier_divergence_traps() {
        // threads with tid==0 exit before the barrier -> divergence
        let mut b = KernelBuilder::new("diverge");
        let tid = b.tid_x();
        let zero = b.consti(0);
        let is0 = b.cmpi(CmpOp::Eq, tid, zero);
        let out = b.label();
        b.bra_if(is0, out);
        b.bar();
        b.bind(out);
        b.ret();
        let k = b.build().unwrap();
        let err = run(&k, (1, 1), (2, 1), vec![]).unwrap_err();
        assert!(err.to_string().contains("barrier divergence"), "{err}");
    }

    #[test]
    fn scalar_params_bound() {
        // out[tid] = scale * tid + offset
        let mut b = KernelBuilder::new("affine");
        let pout = b.ptr_param();
        let pscale = b.f32_param();
        let poff = b.f32_param();
        let tid = b.tid_x();
        let tf = b.cvt_i2f(tid);
        let scale = b.ld_param_f(pscale);
        let off = b.ld_param_f(poff);
        let prod = b.fmul(scale, tf);
        let v = b.fadd(prod, off);
        b.stg(pout, tid, v);
        b.ret();
        let k = b.build().unwrap();
        let mut out = vec![0.0f32; 4];
        execute(Launch {
            kernel: &k,
            grid: (1, 1),
            block: (4, 1),
            buffers: vec![&mut out],
            scalars: vec![ScalarArg::F32(2.0), ScalarArg::F32(1.0)],
            limits: Limits::default(),
        })
        .unwrap();
        assert_eq!(out, vec![1.0, 3.0, 5.0, 7.0]);
    }

    #[test]
    fn buffer_count_mismatch_rejected() {
        let k = vadd_kernel();
        let mut a = vec![0.0f32; 4];
        let err = run(&k, (1, 1), (4, 1), vec![&mut a]).unwrap_err();
        assert!(err.to_string().contains("takes 3 buffers"), "{err}");
    }
}
