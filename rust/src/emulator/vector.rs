//! The **warp-vectorized execution tier**: executes the lowered
//! basic-block form ([`crate::emulator::lower`]) one operation at a
//! time across *all active threads of a block*, instead of one
//! instruction per thread like the scalar reference tier
//! ([`crate::emulator::interp`]).
//!
//! Register files are structure-of-arrays (`reg`-major, one lane per
//! thread), so each dispatch decodes an operation **once** and then runs
//! a tight per-lane loop — amortizing the per-instruction match,
//! program-counter bookkeeping and step accounting over `blockDim`
//! threads. Per-buffer lengths are hoisted out of the per-thread loop
//! (loaded once per block), and fused superinstructions retire several
//! ISA instructions per dispatch.
//!
//! # Divergence
//!
//! Threads only diverge at block terminators, so each lane's program
//! counter is just a basic-block id. The scheduler repeatedly picks the
//! **lowest** block id among running lanes (block ids are ordered by
//! original pc, so this is the minimum-pc reconvergence heuristic) and
//! executes that whole block for the set of lanes parked on it — the
//! predication mask. Lanes on a divergent branch split into two masks
//! and re-merge as soon as they reach a common block.
//!
//! # Observational identity with the scalar tier
//!
//! For race-free kernels (no intra-block communication through shared or
//! global memory within a barrier segment — the only programs with
//! defined results on real hardware) this tier is bitwise-identical to
//! the scalar tier, including trap coordinates and reasons:
//!
//! * every lane executes exactly its scalar trajectory (fused ops replay
//!   the original instruction sequence, operand order preserved);
//! * step budgets are charged per lane with the fused weights (trap-free
//!   superinstructions charge their whole weight at once — the budget
//!   trap reason and coordinates are position-independent — while
//!   `RmwG`, whose bounds check can trap internally, interleaves
//!   per-instruction budget checks exactly like the scalar tier);
//! * when a lane traps, all lanes with an index **greater or equal**
//!   are halted (their side effects cannot be observed — the launch
//!   errors and device memory is discarded at the driver level) while
//!   lower lanes run to quiescence. The surviving lowest-indexed trap is
//!   reported — exactly the trap the scalar tier meets first, because it
//!   runs threads in index order and lower threads completed cleanly;
//! * barrier divergence reports the lowest-indexed waiting thread, as
//!   the scalar tier does.

use crate::emulator::compile::{CompiledRun, JitState};
use crate::emulator::decode::DecodedKernel;
use crate::emulator::interp::{
    binf_apply, cmpf, cmpi, trap_budget, trap_oob_global, trap_oob_shared, unf_apply,
    BlockStats, GlobalMem, Limits, TRAP_DIV_ZERO, TRAP_REM_ZERO,
};
use crate::emulator::isa::{IOp, Instr, Special};
use crate::emulator::lower::{LoweredKernel, Term, VOp};
use crate::error::{Error, Result};

/// Per-lane scheduling state.
#[derive(Clone, Copy, PartialEq)]
enum St {
    Running,
    AtBarrier,
    Done,
    /// Stopped because this or a lower-indexed lane trapped; its
    /// remaining side effects are unobservable (the launch errors).
    Halted,
}

/// Everything needed to construct a trap error for a lane.
struct TrapCtx<'a> {
    name: &'a str,
    bx: u32,
    block: (u32, u32, u32),
}

impl TrapCtx<'_> {
    fn trap(&self, lane: usize, reason: String) -> Error {
        Error::VtxTrap {
            kernel: self.name.to_string(),
            block: self.block,
            thread: ((lane as u32) % self.bx, (lane as u32) / self.bx, 0),
            reason,
        }
    }
}

/// Record a lane trap: halt the trapping lane and everything above it
/// (their work cannot influence the reported trap — the scalar tier
/// never runs them past this point), and keep the lowest-lane error.
fn record_trap(
    status: &mut [St],
    pending: &mut Option<(usize, Error)>,
    lane: usize,
    e: Error,
) {
    for s in status[lane..].iter_mut() {
        if *s != St::Done {
            *s = St::Halted;
        }
    }
    match pending {
        Some((p, _)) if *p <= lane => {}
        _ => *pending = Some((lane, e)),
    }
}

/// Charge `w` steps to every lane of the mask. On budget exhaustion the
/// exhausted lane traps (same boundary as the scalar tier: a fused op of
/// weight `w` traps iff any of its replayed instructions would), the
/// mask is truncated to the lanes that were charged, and higher lanes
/// halt.
#[allow(clippy::too_many_arguments)]
fn charge(
    mask: &mut Vec<usize>,
    steps: &mut [u64],
    status: &mut [St],
    pending: &mut Option<(usize, Error)>,
    limit: u64,
    w: u64,
    ctx: &TrapCtx<'_>,
) {
    let mut trap_at: Option<usize> = None;
    for (pos, &lane) in mask.iter().enumerate() {
        if steps[lane] + w > limit {
            trap_at = Some(pos);
            break;
        }
        steps[lane] += w;
    }
    if let Some(pos) = trap_at {
        let lane = mask[pos];
        let e = ctx.trap(lane, trap_budget(limit));
        record_trap(status, pending, lane, e);
        mask.truncate(pos);
    }
}

/// Interpret one thread block on the vector tier, or — when `jit` is
/// `Some((state, tier_up))` — on the **compiled tier**: blocks whose
/// hotness crossed the threshold execute their pre-compiled closure
/// chain ([`crate::emulator::compile`]) and fall back (deopt) onto this
/// loop's op path at the exact op index on any guard failure. The
/// scheduler (reconvergence, barriers, trap bookkeeping) and every
/// terminator are shared between the two tiers, so trap parity with the
/// vector tier is by construction.
pub(crate) fn run_block_tiered<M: GlobalMem>(
    k: &DecodedKernel,
    grid: (u32, u32),
    block: (u32, u32),
    block_id: (u32, u32),
    mem: &mut M,
    limits: &Limits,
    jit: Option<(&JitState, u64)>,
) -> Result<BlockStats> {
    let lowered: &LoweredKernel = &k.lowered;
    let (gx, gy) = grid;
    let (bx, by) = block;
    let (bx_i, by_i) = block_id;
    let nl = (bx * by) as usize;
    let limit = limits.steps_per_thread;

    let ctx = TrapCtx { name: &k.name, bx, block: (bx_i, by_i, 0) };

    let mut stats = BlockStats::default();

    // Structure-of-arrays register files: register-major, one lane per
    // thread, so per-op lane loops are contiguous.
    let mut fr = vec![0f32; k.fregs as usize * nl];
    let mut ir = vec![0i64; k.iregs as usize * nl];
    let mut shared = vec![0f32; k.shared_f32];

    let mut status = vec![St::Running; nl];
    let mut cur_blk = vec![0u32; nl];
    let mut steps = vec![0u64; nl];
    let mut pending: Option<(usize, Error)> = None;
    let mut mask: Vec<usize> = Vec::with_capacity(nl);

    // Hoisted per-buffer lengths: loaded once per block instead of once
    // per access per thread. Launch-constant, so bounds semantics are
    // unchanged.
    let lens: Vec<usize> = (0..k.nbufs).map(|s| mem.len(s)).collect();

    'sched: loop {
        // Reconvergence: the lowest block id among running lanes.
        let mut next: Option<u32> = None;
        for l in 0..nl {
            if status[l] == St::Running {
                let b = cur_blk[l];
                match next {
                    Some(n) if n <= b => {}
                    _ => next = Some(b),
                }
            }
        }
        let bid = match next {
            Some(b) => b,
            None => {
                if let Some((_, e)) = pending.take() {
                    return Err(e);
                }
                // Barrier resolution (identical to the scalar tier).
                let waiting = status.iter().filter(|s| **s == St::AtBarrier).count();
                if waiting == 0 {
                    return Ok(stats); // all done
                }
                let done = status.iter().filter(|s| **s == St::Done).count();
                if done > 0 {
                    let lane = status
                        .iter()
                        .position(|s| *s == St::AtBarrier)
                        .unwrap_or(0);
                    return Err(ctx.trap(
                        lane,
                        format!(
                            "barrier divergence: {waiting} threads waiting, {done} exited"
                        ),
                    ));
                }
                for s in status.iter_mut() {
                    *s = St::Running;
                }
                continue;
            }
        };

        mask.clear();
        for l in 0..nl {
            if status[l] == St::Running && cur_blk[l] == bid {
                mask.push(l);
            }
        }

        let blk = &lowered.blocks[bid as usize];

        // Compiled tier: count this block execution toward tier-up and,
        // once compiled, run the closure chain. `Done` skips the op loop
        // entirely; `Deopt(i)` resumes the vector op path at op `i` with
        // exactly the register/step state the vector tier would have had
        // there (compiled ops are all-or-nothing), so the replay reports
        // the precise trap.
        let mut start_op = 0usize;
        let mut body_compiled = false;
        if let Some((state, tier_up)) = jit {
            if let Some(cb) =
                state.compiled(bid as usize, blk, k.fregs, k.iregs, tier_up, &mut stats.tier_ups)
            {
                match cb.run(
                    &mut fr,
                    &mut ir,
                    nl,
                    &mask,
                    &mut shared,
                    &mut *mem,
                    &lens,
                    &mut steps,
                    limit,
                    grid,
                    block,
                    block_id,
                    &mut stats,
                ) {
                    CompiledRun::Done => {
                        start_op = blk.ops.len();
                        body_compiled = true;
                    }
                    CompiledRun::Deopt(i) => {
                        start_op = i;
                        stats.deopts += 1;
                    }
                }
            }
        }

        for op in &blk.ops[start_op..] {
            let w = op.weight();
            // RmwG is the only superinstruction with an internal trap
            // (the bounds check), so its budget checks must interleave
            // with the replayed sub-instructions — the arm below does
            // its own per-sub-instruction accounting. All other ops are
            // trap-free, so a coarse whole-weight charge reports the
            // same budget-trap reason and coordinates as the scalar
            // tier would at whichever sub-instruction.
            if !matches!(op, VOp::RmwG { .. }) {
                charge(&mut mask, &mut steps, &mut status, &mut pending, limit, w, &ctx);
                if mask.is_empty() {
                    continue 'sched;
                }
            }
            stats.dispatches += 1;
            stats.instrs += w * mask.len() as u64;
            if op.is_fused() {
                stats.fused_instrs += w * mask.len() as u64;
            }
            stats.lane_ops += mask.len() as u64;
            stats.lane_slots += nl as u64;

            // A memory/arithmetic trap inside the op: (position in mask,
            // reason). Lanes before the position executed normally.
            let mut trapped: Option<(usize, String)> = None;

            match *op {
                VOp::Base(ins) => match ins {
                    Instr::ConstF(d, v) => {
                        let db = d as usize * nl;
                        for &l in &mask {
                            fr[db + l] = v;
                        }
                    }
                    Instr::ConstI(d, v) => {
                        let db = d as usize * nl;
                        for &l in &mask {
                            ir[db + l] = v;
                        }
                    }
                    Instr::MovF(d, s) => {
                        let (db, sb) = (d as usize * nl, s as usize * nl);
                        for &l in &mask {
                            fr[db + l] = fr[sb + l];
                        }
                    }
                    Instr::MovI(d, s) => {
                        let (db, sb) = (d as usize * nl, s as usize * nl);
                        for &l in &mask {
                            ir[db + l] = ir[sb + l];
                        }
                    }
                    Instr::BinF(op, d, a, b) => {
                        let (db, ab, bb) =
                            (d as usize * nl, a as usize * nl, b as usize * nl);
                        for &l in &mask {
                            fr[db + l] = binf_apply(op, fr[ab + l], fr[bb + l]);
                        }
                    }
                    Instr::BinI(op, d, a, b) => {
                        let (db, ab, bb) =
                            (d as usize * nl, a as usize * nl, b as usize * nl);
                        match op {
                            IOp::Add => {
                                for &l in &mask {
                                    ir[db + l] = ir[ab + l].wrapping_add(ir[bb + l]);
                                }
                            }
                            IOp::Sub => {
                                for &l in &mask {
                                    ir[db + l] = ir[ab + l].wrapping_sub(ir[bb + l]);
                                }
                            }
                            IOp::Mul => {
                                for &l in &mask {
                                    ir[db + l] = ir[ab + l].wrapping_mul(ir[bb + l]);
                                }
                            }
                            IOp::Div => {
                                for (pos, &l) in mask.iter().enumerate() {
                                    let y = ir[bb + l];
                                    if y == 0 {
                                        trapped = Some((pos, TRAP_DIV_ZERO.to_string()));
                                        break;
                                    }
                                    // wrapping: i64::MIN / -1 must not panic
                                    ir[db + l] = ir[ab + l].wrapping_div(y);
                                }
                            }
                            IOp::Rem => {
                                for (pos, &l) in mask.iter().enumerate() {
                                    let y = ir[bb + l];
                                    if y == 0 {
                                        trapped = Some((pos, TRAP_REM_ZERO.to_string()));
                                        break;
                                    }
                                    ir[db + l] = ir[ab + l].wrapping_rem(y);
                                }
                            }
                        }
                    }
                    Instr::UnF(op, d, a) => {
                        let (db, ab) = (d as usize * nl, a as usize * nl);
                        for &l in &mask {
                            fr[db + l] = unf_apply(op, fr[ab + l]);
                        }
                    }
                    Instr::CmpF(op, d, a, b) => {
                        let (db, ab, bb) =
                            (d as usize * nl, a as usize * nl, b as usize * nl);
                        for &l in &mask {
                            ir[db + l] = cmpf(op, fr[ab + l], fr[bb + l]) as i64;
                        }
                    }
                    Instr::CmpI(op, d, a, b) => {
                        let (db, ab, bb) =
                            (d as usize * nl, a as usize * nl, b as usize * nl);
                        for &l in &mask {
                            ir[db + l] = cmpi(op, ir[ab + l], ir[bb + l]) as i64;
                        }
                    }
                    Instr::SelF(d, p, a, b) => {
                        let (db, pb, ab, bb) = (
                            d as usize * nl,
                            p as usize * nl,
                            a as usize * nl,
                            b as usize * nl,
                        );
                        for &l in &mask {
                            fr[db + l] = if ir[pb + l] != 0 {
                                fr[ab + l]
                            } else {
                                fr[bb + l]
                            };
                        }
                    }
                    Instr::CvtFI(d, s) => {
                        let (db, sb) = (d as usize * nl, s as usize * nl);
                        for &l in &mask {
                            ir[db + l] = fr[sb + l] as i64;
                        }
                    }
                    Instr::CvtIF(d, s) => {
                        let (db, sb) = (d as usize * nl, s as usize * nl);
                        for &l in &mask {
                            fr[db + l] = ir[sb + l] as f32;
                        }
                    }
                    Instr::Spec(d, s) => {
                        let db = d as usize * nl;
                        match s {
                            Special::ThreadIdX => {
                                for &l in &mask {
                                    ir[db + l] = ((l as u32) % bx) as i64;
                                }
                            }
                            Special::ThreadIdY => {
                                for &l in &mask {
                                    ir[db + l] = ((l as u32) / bx) as i64;
                                }
                            }
                            other => {
                                // Uniform across the block: computed once.
                                let v = match other {
                                    Special::BlockIdX => bx_i as i64,
                                    Special::BlockIdY => by_i as i64,
                                    Special::BlockDimX => bx as i64,
                                    Special::BlockDimY => by as i64,
                                    Special::GridDimX => gx as i64,
                                    Special::GridDimY => gy as i64,
                                    Special::ThreadIdX | Special::ThreadIdY => {
                                        unreachable!()
                                    }
                                };
                                for &l in &mask {
                                    ir[db + l] = v;
                                }
                            }
                        }
                    }
                    Instr::LdG { dst, param, idx } => {
                        let slot = param as usize;
                        let len = lens[slot];
                        let (db, ib) = (dst as usize * nl, idx as usize * nl);
                        for (pos, &l) in mask.iter().enumerate() {
                            let i = ir[ib + l];
                            if i < 0 || i as usize >= len {
                                trapped = Some((pos, trap_oob_global("load", i, len, slot)));
                                break;
                            }
                            fr[db + l] = mem.load(slot, i as usize);
                        }
                    }
                    Instr::StG { param, idx, src } => {
                        let slot = param as usize;
                        let len = lens[slot];
                        let (sb, ib) = (src as usize * nl, idx as usize * nl);
                        for (pos, &l) in mask.iter().enumerate() {
                            let i = ir[ib + l];
                            if i < 0 || i as usize >= len {
                                trapped = Some((pos, trap_oob_global("store", i, len, slot)));
                                break;
                            }
                            mem.store(slot, i as usize, fr[sb + l]);
                        }
                    }
                    Instr::LdS { dst, idx } => {
                        let slen = shared.len();
                        let (db, ib) = (dst as usize * nl, idx as usize * nl);
                        for (pos, &l) in mask.iter().enumerate() {
                            let i = ir[ib + l];
                            if i < 0 || i as usize >= slen {
                                trapped = Some((pos, trap_oob_shared("load", i, slen)));
                                break;
                            }
                            fr[db + l] = shared[i as usize];
                        }
                    }
                    Instr::StS { idx, src } => {
                        let slen = shared.len();
                        let (sb, ib) = (src as usize * nl, idx as usize * nl);
                        for (pos, &l) in mask.iter().enumerate() {
                            let i = ir[ib + l];
                            if i < 0 || i as usize >= slen {
                                trapped = Some((pos, trap_oob_shared("store", i, slen)));
                                break;
                            }
                            shared[i as usize] = fr[sb + l];
                        }
                    }
                    Instr::LdParamF(..) | Instr::LdParamI(..) => {
                        unreachable!("scalar params resolved by pre-decode")
                    }
                    Instr::Bar
                    | Instr::Bra(_)
                    | Instr::BraIf(..)
                    | Instr::BraIfZ(..)
                    | Instr::Ret => {
                        unreachable!("control flow is lowered to block terminators")
                    }
                },
                VOp::MulAddF { dm, ma, mb, dd, aa, ab } => {
                    let (dmb, mab, mbb) =
                        (dm as usize * nl, ma as usize * nl, mb as usize * nl);
                    let (ddb, aab, abb) =
                        (dd as usize * nl, aa as usize * nl, ab as usize * nl);
                    for &l in &mask {
                        fr[dmb + l] = fr[mab + l] * fr[mbb + l];
                        fr[ddb + l] = fr[aab + l] + fr[abb + l];
                    }
                }
                VOp::MulAddI { dm, ma, mb, dd, aa, ab } => {
                    let (dmb, mab, mbb) =
                        (dm as usize * nl, ma as usize * nl, mb as usize * nl);
                    let (ddb, aab, abb) =
                        (dd as usize * nl, aa as usize * nl, ab as usize * nl);
                    for &l in &mask {
                        ir[dmb + l] = ir[mab + l].wrapping_mul(ir[mbb + l]);
                        ir[ddb + l] = ir[aab + l].wrapping_add(ir[abb + l]);
                    }
                }
                VOp::CvtMulAddF { df, si, dm, ma, mb, dd, aa, ab } => {
                    let (dfb, sib) = (df as usize * nl, si as usize * nl);
                    let (dmb, mab, mbb) =
                        (dm as usize * nl, ma as usize * nl, mb as usize * nl);
                    let (ddb, aab, abb) =
                        (dd as usize * nl, aa as usize * nl, ab as usize * nl);
                    for &l in &mask {
                        fr[dfb + l] = ir[sib + l] as f32;
                        fr[dmb + l] = fr[mab + l] * fr[mbb + l];
                        fr[ddb + l] = fr[aab + l] + fr[abb + l];
                    }
                }
                VOp::GlobalIdX { tid, bid, bdim, mul, add } => {
                    let (tb, bb, db) =
                        (tid as usize * nl, bid as usize * nl, bdim as usize * nl);
                    let (md, ma, mb) = mul;
                    let (ad, aa, ab) = add;
                    let (mdb, mab, mbb) =
                        (md as usize * nl, ma as usize * nl, mb as usize * nl);
                    let (adb, aab, abb) =
                        (ad as usize * nl, aa as usize * nl, ab as usize * nl);
                    let bidv = bx_i as i64;
                    let bdimv = bx as i64;
                    for &l in &mask {
                        ir[tb + l] = ((l as u32) % bx) as i64;
                        ir[bb + l] = bidv;
                        ir[db + l] = bdimv;
                        ir[mdb + l] = ir[mab + l].wrapping_mul(ir[mbb + l]);
                        ir[adb + l] = ir[aab + l].wrapping_add(ir[abb + l]);
                    }
                }
                VOp::RmwG { slot, idx, ld, op, sa, sb, st } => {
                    let slot = slot as usize;
                    let len = lens[slot];
                    let (ib, ldb) = (idx as usize * nl, ld as usize * nl);
                    let (sab, sbb, stb) =
                        (sa as usize * nl, sb as usize * nl, st as usize * nl);
                    'lanes: for (pos, &l) in mask.iter().enumerate() {
                        // Replay LdG; BinF; StG with the scalar tier's
                        // per-instruction budget checks interleaved: a
                        // lane whose budget expires mid-superinstruction
                        // must report the same trap (reason included)
                        // the scalar tier would.
                        // -- LdG: budget, then the single bounds check
                        //    for the load+store pair (same slot, same
                        //    index register, so if the load fits the
                        //    store does too).
                        if steps[l] >= limit {
                            trapped = Some((pos, trap_budget(limit)));
                            break 'lanes;
                        }
                        steps[l] += 1;
                        let i = ir[ib + l];
                        if i < 0 || i as usize >= len {
                            trapped = Some((pos, trap_oob_global("load", i, len, slot)));
                            break 'lanes;
                        }
                        let iu = i as usize;
                        fr[ldb + l] = mem.load(slot, iu);
                        // -- BinF
                        if steps[l] >= limit {
                            trapped = Some((pos, trap_budget(limit)));
                            break 'lanes;
                        }
                        steps[l] += 1;
                        fr[stb + l] = binf_apply(op, fr[sab + l], fr[sbb + l]);
                        // -- StG (bounds already proven by the load)
                        if steps[l] >= limit {
                            trapped = Some((pos, trap_budget(limit)));
                            break 'lanes;
                        }
                        steps[l] += 1;
                        mem.store(slot, iu, fr[stb + l]);
                    }
                }
            }

            if let Some((pos, reason)) = trapped {
                let lane = mask[pos];
                let e = ctx.trap(lane, reason);
                record_trap(&mut status, &mut pending, lane, e);
                mask.truncate(pos);
                if mask.is_empty() {
                    continue 'sched;
                }
            }
        }

        // Terminator: one step for explicit control flow, zero for a
        // synthetic fallthrough edge, three for the fused loop-counter
        // back-edge (it replays IAdd + CmpI + BraIf; none can trap, so
        // the whole-weight budget charge keeps scalar trap parity).
        let w = match blk.term {
            Term::Jump { steps, .. } => steps as u64,
            Term::Branch { .. } | Term::Bar { .. } | Term::Ret => 1,
            Term::LoopBack { .. } => 3,
        };
        if w > 0 {
            charge(&mut mask, &mut steps, &mut status, &mut pending, limit, w, &ctx);
            if mask.is_empty() {
                continue 'sched;
            }
            stats.dispatches += 1;
            stats.instrs += w * mask.len() as u64;
            if matches!(blk.term, Term::LoopBack { .. }) {
                stats.fused_instrs += w * mask.len() as u64;
            }
            if body_compiled {
                // The terminator retires as part of the compiled region
                // (its registers were produced by the chain); instrs
                // retired under compiled execution include it.
                stats.compiled_instrs += w * mask.len() as u64;
            }
            stats.lane_ops += mask.len() as u64;
            stats.lane_slots += nl as u64;
        }
        match blk.term {
            Term::Jump { target, .. } => {
                for &l in &mask {
                    cur_blk[l] = target;
                }
            }
            Term::Branch { pred, nz, z } => {
                let pb = pred as usize * nl;
                for &l in &mask {
                    cur_blk[l] = if ir[pb + l] != 0 { nz } else { z };
                }
            }
            Term::LoopBack { add: (ad, aa, ab), cmp_op, pred, cmp: (ca, cb), nz, z } => {
                // Replay counter add + compare, then branch — exactly
                // the original sequence, one dispatch per iteration.
                let (adb, aab, abb) =
                    (ad as usize * nl, aa as usize * nl, ab as usize * nl);
                let (pdb, cab, cbb) =
                    (pred as usize * nl, ca as usize * nl, cb as usize * nl);
                for &l in &mask {
                    ir[adb + l] = ir[aab + l].wrapping_add(ir[abb + l]);
                    let p = cmpi(cmp_op, ir[cab + l], ir[cbb + l]) as i64;
                    ir[pdb + l] = p;
                    cur_blk[l] = if p != 0 { nz } else { z };
                }
            }
            Term::Bar { next } => {
                for &l in &mask {
                    cur_blk[l] = next;
                    status[l] = St::AtBarrier;
                }
            }
            Term::Ret => {
                for &l in &mask {
                    status[l] = St::Done;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::emulator::interp::{execute_with_tier, Launch, Limits, ScalarArg};
    use crate::emulator::kernels;
    use crate::emulator::sched::ExecTier;

    fn run_tier(
        k: &crate::emulator::isa::Kernel,
        tier: ExecTier,
        grid: (u32, u32),
        block: (u32, u32),
        bufs: &mut [Vec<f32>],
        scalars: Vec<ScalarArg>,
    ) -> crate::error::Result<crate::driver::launch::LaunchReport> {
        let views: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
        execute_with_tier(
            Launch { kernel: k, grid, block, buffers: views, scalars, limits: Limits::default() },
            1,
            tier,
        )
    }

    #[test]
    fn vadd_matches_scalar_bitwise() {
        let k = kernels::vadd().unwrap();
        let n = 100usize; // 4 blocks of 32, tail-guarded
        let a: Vec<f32> = (0..n).map(|i| (i as f32) * 0.37).collect();
        let b: Vec<f32> = (0..n).map(|i| (i as f32) * -1.11).collect();
        let mut outs = Vec::new();
        for tier in [ExecTier::Scalar, ExecTier::Vector] {
            let mut bufs = vec![a.clone(), b.clone(), vec![0.0f32; n]];
            run_tier(&k, tier, (4, 1), (32, 1), &mut bufs, vec![ScalarArg::I32(n as i32)])
                .unwrap();
            outs.push(bufs[2].clone());
        }
        assert_eq!(outs[0], outs[1]);
    }

    #[test]
    fn shared_memory_reduction_matches_scalar() {
        let (h, w) = (13usize, 5usize);
        let block_h = h.next_power_of_two();
        let k = kernels::tfunc_column("t2", block_h).unwrap();
        let img: Vec<f32> = (0..h * w).map(|i| ((i * 11) % 19) as f32 * 0.25).collect();
        let mut outs = Vec::new();
        for tier in [ExecTier::Scalar, ExecTier::Vector] {
            let mut bufs = vec![img.clone(), vec![0.0f32; w]];
            run_tier(
                &k,
                tier,
                (w as u32, 1),
                (block_h as u32, 1),
                &mut bufs,
                vec![ScalarArg::I32(h as i32), ScalarArg::I32(w as i32)],
            )
            .unwrap();
            outs.push(bufs[1].clone());
        }
        assert_eq!(outs[0], outs[1]);
    }

    #[test]
    fn two_dimensional_blocks_use_thread_id_y() {
        // out[ty*bdimx + tx] = ty*10 + tx via tid_x/tid_y — exercises the
        // 2-D lane-to-thread mapping of the SoA files.
        use crate::emulator::builder::KernelBuilder;
        let mut b = KernelBuilder::new("tid2d");
        let pout = b.ptr_param();
        let tx = b.tid_x();
        let ty = b.tid_y();
        let bdx = b.ntid_x();
        let row = b.imul(ty, bdx);
        let idx = b.iadd(row, tx);
        let ten = b.consti(10);
        let v0 = b.imul(ty, ten);
        let v = b.iadd(v0, tx);
        let vf = b.cvt_i2f(v);
        b.stg(pout, idx, vf);
        b.ret();
        let k = b.build().unwrap();
        let (bx, by) = (4u32, 3u32);
        let mut outs = Vec::new();
        for tier in [ExecTier::Scalar, ExecTier::Vector] {
            let mut bufs = vec![vec![0.0f32; (bx * by) as usize]];
            run_tier(&k, tier, (1, 1), (bx, by), &mut bufs, vec![]).unwrap();
            outs.push(bufs[0].clone());
        }
        assert_eq!(outs[0], outs[1]);
        for ty in 0..by {
            for tx in 0..bx {
                assert_eq!(outs[1][(ty * bx + tx) as usize], (ty * 10 + tx) as f32);
            }
        }
    }

    #[test]
    fn loop_back_edge_fuses_and_matches_scalar() {
        use crate::emulator::builder::KernelBuilder;
        use crate::emulator::isa::CmpOp;
        // out[tid] = n iterations of acc += 1.0 — the loop epilogue must
        // retire as a fused terminator with identical results and step
        // accounting across tiers.
        let n = 37i64;
        let mut b = KernelBuilder::new("loopn");
        let pout = b.ptr_param();
        let acc = b.constf(0.0);
        let one_f = b.constf(1.0);
        let i = b.consti(0);
        let lim = b.consti(n);
        let one = b.consti(1);
        let top = b.label();
        b.bind(top);
        b.fadd_to(acc, one_f);
        b.iadd_to(i, one);
        let more = b.cmpi(CmpOp::Lt, i, lim);
        b.bra_if(more, top);
        let tid = b.tid_x();
        b.stg(pout, tid, acc);
        b.ret();
        let k = b.build().unwrap();

        let mut reports = Vec::new();
        let mut outs = Vec::new();
        for tier in [ExecTier::Scalar, ExecTier::Vector] {
            let mut bufs = vec![vec![0.0f32; 8]];
            let r = run_tier(&k, tier, (1, 1), (8, 1), &mut bufs, vec![]).unwrap();
            reports.push(r);
            outs.push(bufs[0].clone());
        }
        assert_eq!(outs[0], outs[1]);
        assert!(outs[1].iter().all(|&v| v == n as f32));
        assert_eq!(reports[0].instrs, reports[1].instrs, "step accounting preserved");
        // fused_share regression: the back-edge dominates this loop
        // (3 of 4 per-iteration instructions), so the fused share must
        // clear 0.5 — it was 0.0 for this kernel before the LoopBack
        // catalog entry.
        let share = reports[1].fused_instrs as f64 / reports[1].instrs as f64;
        assert!(share > 0.5, "fused share {share} too low: {:?}", reports[1]);
        assert_eq!(reports[0].fused_instrs, 0, "scalar tier reports no fusion");
    }

    #[test]
    fn loop_budget_trap_parity_across_tiers() {
        use crate::emulator::builder::KernelBuilder;
        use crate::emulator::isa::CmpOp;
        // A long counter loop against a small step budget: both tiers
        // must trap with the same reason and coordinates even though the
        // vector tier charges the fused back-edge as one unit.
        let mut b = KernelBuilder::new("loop_budget");
        let pout = b.ptr_param();
        let acc = b.constf(0.0);
        let one_f = b.constf(1.0);
        let i = b.consti(0);
        let lim = b.consti(1_000_000);
        let one = b.consti(1);
        let top = b.label();
        b.bind(top);
        b.fadd_to(acc, one_f);
        b.iadd_to(i, one);
        let more = b.cmpi(CmpOp::Lt, i, lim);
        b.bra_if(more, top);
        let tid = b.tid_x();
        b.stg(pout, tid, acc);
        b.ret();
        let k = b.build().unwrap();
        assert!(crate::emulator::decode::decode(&k, &[])
            .unwrap()
            .lowered
            .blocks
            .iter()
            .any(|blk| matches!(blk.term, crate::emulator::lower::Term::LoopBack { .. })));

        let mut errs = Vec::new();
        for tier in [ExecTier::Scalar, ExecTier::Vector] {
            let mut bufs = vec![vec![0.0f32; 4]];
            let views: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
            let e = execute_with_tier(
                Launch {
                    kernel: &k,
                    grid: (1, 1),
                    block: (4, 1),
                    buffers: views,
                    scalars: vec![],
                    limits: Limits { steps_per_thread: 100 },
                },
                1,
                tier,
            )
            .unwrap_err();
            errs.push(e.to_string());
        }
        assert_eq!(errs[0], errs[1], "trap reason and coordinates must match");
        assert!(errs[0].contains("step budget"), "{}", errs[0]);
    }

    #[test]
    fn instrs_retired_match_scalar_exactly() {
        // The step-accounting invariant: both tiers retire the same
        // instruction count (fused weights are exact).
        let k = kernels::sinogram_all().unwrap();
        let s = 12usize;
        let a = 5usize;
        let img: Vec<f32> = (0..s * s).map(|i| ((i * 13) % 17) as f32).collect();
        let angles: Vec<f32> = (0..a).map(|i| i as f32 * 0.7).collect();
        let mut reports = Vec::new();
        for tier in [ExecTier::Scalar, ExecTier::Vector] {
            let mut bufs =
                vec![img.clone(), angles.clone(), vec![0.0f32; 4 * a * s]];
            let r = run_tier(
                &k,
                tier,
                (a as u32, 1),
                (s as u32, 1),
                &mut bufs,
                vec![ScalarArg::I32(s as i32)],
            )
            .unwrap();
            reports.push(r);
        }
        assert_eq!(reports[0].instrs, reports[1].instrs);
        assert!(reports[1].fused_instrs > 0);
        assert!(reports[1].dispatches < reports[0].dispatches);
    }
}
