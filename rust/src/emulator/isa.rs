//! VTX: a tiny PTX-like **virtual ISA** with a grid/block/thread execution
//! model — the code format the emulator backend interprets.
//!
//! The paper's framework emits PTX, a virtual ISA JIT-translated by the
//! driver; its emulator path (GPU Ocelot) interprets PTX on the host.
//! VTX plays the same role here: kernels are authored with the
//! [`crate::emulator::builder::KernelBuilder`] DSL, validated at module
//! load (the "JIT" step), and interpreted with full bounds/trap checking.
//!
//! Design points (deliberately PTX-like):
//! * register machine with separate float (f32) and integer (i64) files;
//! * special registers for thread/block indices and dimensions;
//! * global memory accessed **only** through pointer parameters + element
//!   index (the disjoint-address-space restriction made structural);
//! * static shared memory per block, with `Bar` barriers;
//! * structured traps: OOB access, barrier divergence, step-budget
//!   exhaustion.

/// Register index into the float or integer file (instruction decides).
pub type Reg = u16;

/// Branch target: index into the kernel's instruction vector (resolved by
/// the builder from symbolic labels).
pub type Pc = u32;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FOp {
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnFOp {
    Neg,
    Abs,
    Sqrt,
    Sin,
    Cos,
    Floor,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

/// Special (read-only) registers, the `%tid`/`%ctaid`/`%ntid` analogs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Special {
    ThreadIdX,
    ThreadIdY,
    BlockIdX,
    BlockIdY,
    BlockDimX,
    BlockDimY,
    GridDimX,
    GridDimY,
}

/// Kernel parameter kinds. Pointers are *opaque*: device buffers bound at
/// launch, addressed by f32 element index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    /// Device buffer of f32 elements.
    PtrF32,
    /// Scalar f32.
    F32,
    /// Scalar i32 (widened to i64 in the register file).
    I32,
}

/// One VTX instruction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Instr {
    /// fdst = imm
    ConstF(Reg, f32),
    /// idst = imm
    ConstI(Reg, i64),
    MovF(Reg, Reg),
    MovI(Reg, Reg),
    /// fdst = op(fa, fb)
    BinF(FOp, Reg, Reg, Reg),
    /// idst = op(ia, ib)
    BinI(IOp, Reg, Reg, Reg),
    /// fdst = op(fa)
    UnF(UnFOp, Reg, Reg),
    /// idst = cmp(fa, fb) as 0/1
    CmpF(CmpOp, Reg, Reg, Reg),
    /// idst = cmp(ia, ib) as 0/1
    CmpI(CmpOp, Reg, Reg, Reg),
    /// fdst = ipred != 0 ? fa : fb
    SelF(Reg, Reg, Reg, Reg),
    /// idst = trunc(fa)
    CvtFI(Reg, Reg),
    /// fdst = (f32) ia
    CvtIF(Reg, Reg),
    /// idst = special register
    Spec(Reg, Special),
    /// fdst = param[p][iidx]   (global load, bounds-checked)
    LdG { dst: Reg, param: u8, idx: Reg },
    /// param[p][iidx] = fsrc   (global store, bounds-checked)
    StG { param: u8, idx: Reg, src: Reg },
    /// fdst = shared[iidx]
    LdS { dst: Reg, idx: Reg },
    /// shared[iidx] = fsrc
    StS { idx: Reg, src: Reg },
    /// fdst = scalar param p (must be ParamKind::F32)
    LdParamF(Reg, u8),
    /// idst = scalar param p (must be ParamKind::I32)
    LdParamI(Reg, u8),
    /// Block-wide barrier.
    Bar,
    /// Unconditional branch.
    Bra(Pc),
    /// Branch if ipred != 0.
    BraIf(Reg, Pc),
    /// Branch if ipred == 0.
    BraIfZ(Reg, Pc),
    /// Thread exit.
    Ret,
}

/// A VTX kernel: code + static resource declaration.
#[derive(Clone, Debug)]
pub struct Kernel {
    pub name: String,
    pub params: Vec<ParamKind>,
    /// Number of float registers per thread.
    pub fregs: u16,
    /// Number of integer registers per thread.
    pub iregs: u16,
    /// Static shared memory, in f32 elements per block.
    pub shared_f32: usize,
    pub code: Vec<Instr>,
}

impl Kernel {
    /// Static validation — the module-load-time "JIT" check. Every
    /// register index, param index, and branch target must be in range,
    /// and the kernel must end in `Ret` on all paths (approximated: last
    /// instruction must be `Ret` and every branch target valid).
    pub fn validate(&self) -> Result<(), String> {
        let nf = self.fregs as u32;
        let ni = self.iregs as u32;
        let np = self.params.len();
        let len = self.code.len() as u32;
        if self.code.is_empty() {
            return Err("empty kernel body".into());
        }
        if !matches!(self.code.last(), Some(Instr::Ret) | Some(Instr::Bra(_))) {
            return Err("kernel must end with Ret or Bra".into());
        }
        let chk_f = |r: Reg| -> Result<(), String> {
            if (r as u32) < nf {
                Ok(())
            } else {
                Err(format!("float register {r} out of range (fregs={nf})"))
            }
        };
        let chk_i = |r: Reg| -> Result<(), String> {
            if (r as u32) < ni {
                Ok(())
            } else {
                Err(format!("int register {r} out of range (iregs={ni})"))
            }
        };
        let chk_p = |p: u8, want: ParamKind| -> Result<(), String> {
            match self.params.get(p as usize) {
                None => Err(format!("param {p} out of range ({np} params)")),
                Some(k) if *k == want => Ok(()),
                Some(k) => Err(format!("param {p} is {k:?}, instruction needs {want:?}")),
            }
        };
        let chk_pc = |t: Pc| -> Result<(), String> {
            if t < len {
                Ok(())
            } else {
                Err(format!("branch target {t} out of range ({len} instructions)"))
            }
        };
        for (pc, ins) in self.code.iter().enumerate() {
            let r: Result<(), String> = (|| {
                match *ins {
                    Instr::ConstF(d, _) => chk_f(d),
                    Instr::ConstI(d, _) => chk_i(d),
                    Instr::MovF(d, s) => chk_f(d).and(chk_f(s)),
                    Instr::MovI(d, s) => chk_i(d).and(chk_i(s)),
                    Instr::BinF(_, d, a, b) => chk_f(d).and(chk_f(a)).and(chk_f(b)),
                    Instr::BinI(_, d, a, b) => chk_i(d).and(chk_i(a)).and(chk_i(b)),
                    Instr::UnF(_, d, a) => chk_f(d).and(chk_f(a)),
                    Instr::CmpF(_, d, a, b) => chk_i(d).and(chk_f(a)).and(chk_f(b)),
                    Instr::CmpI(_, d, a, b) => chk_i(d).and(chk_i(a)).and(chk_i(b)),
                    Instr::SelF(d, p, a, b) => chk_f(d).and(chk_i(p)).and(chk_f(a)).and(chk_f(b)),
                    Instr::CvtFI(d, s) => chk_i(d).and(chk_f(s)),
                    Instr::CvtIF(d, s) => chk_f(d).and(chk_i(s)),
                    Instr::Spec(d, _) => chk_i(d),
                    Instr::LdG { dst, param, idx } => {
                        chk_f(dst).and(chk_p(param, ParamKind::PtrF32)).and(chk_i(idx))
                    }
                    Instr::StG { param, idx, src } => {
                        chk_p(param, ParamKind::PtrF32).and(chk_i(idx)).and(chk_f(src))
                    }
                    Instr::LdS { dst, idx } => chk_f(dst).and(chk_i(idx)),
                    Instr::StS { idx, src } => chk_i(idx).and(chk_f(src)),
                    Instr::LdParamF(d, p) => chk_f(d).and(chk_p(p, ParamKind::F32)),
                    Instr::LdParamI(d, p) => chk_i(d).and(chk_p(p, ParamKind::I32)),
                    Instr::Bar | Instr::Ret => Ok(()),
                    Instr::Bra(t) => chk_pc(t),
                    Instr::BraIf(p, t) | Instr::BraIfZ(p, t) => chk_i(p).and(chk_pc(t)),
                }
            })();
            r.map_err(|e| format!("instruction {pc}: {e}"))?;
        }
        Ok(())
    }

    /// Number of pointer parameters (device buffers bound at launch).
    pub fn ptr_param_count(&self) -> usize {
        self.params
            .iter()
            .filter(|k| matches!(k, ParamKind::PtrF32))
            .count()
    }

    /// Dataflow analysis of pointer-parameter usage: which buffers the
    /// kernel only reads, only writes, or both. This powers the automatic
    /// argument-usage detection the paper lists as future work (§9) —
    /// the `CuIn`/`CuOut` wrappers become optional on the emulator path
    /// because the transfer plan can be derived from the kernel body.
    ///
    /// Returns one entry per *pointer* parameter, in declaration order.
    pub fn infer_param_usage(&self) -> Vec<ParamUsage> {
        let mut usage = vec![(false, false); self.params.len()]; // (read, written)
        for ins in &self.code {
            match *ins {
                Instr::LdG { param, .. } => usage[param as usize].0 = true,
                Instr::StG { param, .. } => usage[param as usize].1 = true,
                _ => {}
            }
        }
        self.params
            .iter()
            .enumerate()
            .filter(|(_, k)| matches!(k, ParamKind::PtrF32))
            .map(|(i, _)| match usage[i] {
                (true, true) => ParamUsage::ReadWrite,
                (true, false) => ParamUsage::ReadOnly,
                (false, true) => ParamUsage::WriteOnly,
                (false, false) => ParamUsage::Unused,
            })
            .collect()
    }
}

/// Result of [`Kernel::infer_param_usage`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamUsage {
    /// Only `LdG` — an input; upload, never download (`CuIn`).
    ReadOnly,
    /// Only `StG` — an output container (`CuOut`).
    WriteOnly,
    /// Both — `CuInOut`.
    ReadWrite,
    /// Never touched (dead parameter).
    Unused,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal() -> Kernel {
        Kernel {
            name: "nop".into(),
            params: vec![],
            fregs: 1,
            iregs: 1,
            shared_f32: 0,
            code: vec![Instr::Ret],
        }
    }

    #[test]
    fn minimal_valid() {
        assert!(minimal().validate().is_ok());
    }

    #[test]
    fn empty_rejected() {
        let mut k = minimal();
        k.code.clear();
        assert!(k.validate().is_err());
    }

    #[test]
    fn register_bounds_checked() {
        let mut k = minimal();
        k.code = vec![Instr::ConstF(5, 1.0), Instr::Ret];
        let err = k.validate().unwrap_err();
        assert!(err.contains("float register 5"), "{err}");
    }

    #[test]
    fn param_kind_checked() {
        let mut k = minimal();
        k.params = vec![ParamKind::F32];
        k.code = vec![
            Instr::ConstI(0, 0),
            Instr::LdG { dst: 0, param: 0, idx: 0 },
            Instr::Ret,
        ];
        let err = k.validate().unwrap_err();
        assert!(err.contains("PtrF32"), "{err}");
    }

    #[test]
    fn branch_targets_checked() {
        let mut k = minimal();
        k.code = vec![Instr::Bra(7)];
        assert!(k.validate().unwrap_err().contains("branch target"));
    }

    #[test]
    fn must_end_in_ret_or_bra() {
        let mut k = minimal();
        k.code = vec![Instr::ConstF(0, 1.0)];
        assert!(k.validate().unwrap_err().contains("end with Ret"));
    }
}
