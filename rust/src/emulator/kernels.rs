//! VTX kernel library: the trace-transform kernels authored in the
//! builder DSL — the emulator-path counterparts of the Pallas kernels in
//! `python/compile/kernels/`.
//!
//! These mirror the CUDA reference structure: one thread per output
//! element, one block per orientation/column, shared-memory tree
//! reductions with barriers where the CUDA version used them.

use crate::emulator::builder::{KernelBuilder, F, I};
use crate::emulator::isa::{CmpOp, FOp, Kernel};
use crate::error::Result;

/// Supported T-functionals, mirroring `python/compile/kernels/tfunctionals.py`.
pub const T_FUNCTIONALS: [&str; 4] = ["radon", "t1", "t2", "tmax"];

/// `vadd(a, b, c, n)`: c[i] = a[i] + b[i] with a tail guard — the paper's
/// Listing 1 translated to VTX.
pub fn vadd() -> Result<Kernel> {
    let mut b = KernelBuilder::new("vadd");
    let pa = b.ptr_param();
    let pb = b.ptr_param();
    let pc = b.ptr_param();
    let pn = b.i32_param();
    let tid = b.tid_x();
    let bid = b.ctaid_x();
    let bdim = b.ntid_x();
    let base = b.imul(bid, bdim);
    let gid = b.iadd(base, tid);
    let n = b.ld_param_i(pn);
    let in_range = b.cmpi(CmpOp::Lt, gid, n);
    let out = b.label();
    b.bra_ifz(in_range, out);
    let x = b.ldg(pa, gid);
    let y = b.ldg(pb, gid);
    let s = b.fadd(x, y);
    b.stg(pc, gid, s);
    b.bind(out);
    b.ret();
    b.build()
}

/// Emit a zero-filled bilinear sample of `img` (size `s_i` x `s_i`, row
/// major, starting `base` elements into the buffer — the per-image offset
/// of the batched kernels). `None` skips the offset add entirely, so the
/// unbatched kernels pay nothing for it on the interpreter's hot loop.
/// Returns the sample register.
fn emit_bilinear(
    b: &mut KernelBuilder,
    pimg: u8,
    base: Option<I>,
    s_i: I,
    sy: F,
    sx: F,
) -> F {
    let zero_i = b.consti(0);
    let one_i = b.consti(1);

    let y0f = b.ffloor(sy);
    let x0f = b.ffloor(sx);
    let fy = b.fsub(sy, y0f);
    let fx = b.fsub(sx, x0f);
    let y0 = b.cvt_f2i(y0f);
    let x0 = b.cvt_f2i(x0f);
    let y1 = b.iadd(y0, one_i);
    let x1 = b.iadd(x0, one_i);

    // gather(yi, xi): returns 0 outside [0, s)².
    let gather = |b: &mut KernelBuilder, yi: I, xi: I| -> F {
        let out = b.constf(0.0);
        let oky0 = b.cmpi(CmpOp::Ge, yi, zero_i);
        let oky1 = b.cmpi(CmpOp::Lt, yi, s_i);
        let okx0 = b.cmpi(CmpOp::Ge, xi, zero_i);
        let okx1 = b.cmpi(CmpOp::Lt, xi, s_i);
        let a = b.imul(oky0, oky1);
        let c = b.imul(okx0, okx1);
        let ok = b.imul(a, c);
        let skip = b.label();
        b.bra_ifz(ok, skip);
        let row = b.imul(yi, s_i);
        let mut idx = b.iadd(row, xi);
        if let Some(base) = base {
            idx = b.iadd(base, idx);
        }
        let v = b.ldg(pimg, idx);
        b.movf(out, v);
        b.bind(skip);
        out
    };

    let v00 = gather(b, y0, x0);
    let v01 = gather(b, y0, x1);
    let v10 = gather(b, y1, x0);
    let v11 = gather(b, y1, x1);

    let one_f = b.constf(1.0);
    let ify = b.fsub(one_f, fy);
    let ifx = b.fsub(one_f, fx);
    let w00 = b.fmul(ify, ifx);
    let w01 = b.fmul(ify, fx);
    let w10 = b.fmul(fy, ifx);
    let w11 = b.fmul(fy, fx);
    let t00 = b.fmul(v00, w00);
    let t01 = b.fmul(v01, w01);
    let t10 = b.fmul(v10, w10);
    let t11 = b.fmul(v11, w11);
    let s0 = b.fadd(t00, t01);
    let s1 = b.fadd(t10, t11);
    b.fadd(s0, s1)
}

/// `rotate(img, out, theta, s)`: bilinear rotation, zero fill. Grid:
/// one block per output row, one thread per output column (the CUDA
/// one-thread-per-pixel scheme). Shares the rotation convention with
/// `python/compile/kernels/rotate.py` and the native rust implementation.
pub fn rotate_bilinear() -> Result<Kernel> {
    let mut b = KernelBuilder::new("rotate");
    let pimg = b.ptr_param();
    let pout = b.ptr_param();
    let ptheta = b.f32_param();
    let ps = b.i32_param();

    let s_i = b.ld_param_i(ps);
    let col = b.tid_x();
    let row = b.ctaid_x();
    // guards for launches rounded up to block multiples
    let col_ok = b.cmpi(CmpOp::Lt, col, s_i);
    let row_ok = b.cmpi(CmpOp::Lt, row, s_i);
    let both = b.imul(col_ok, row_ok);
    let end = b.label();
    b.bra_ifz(both, end);

    let theta = b.ld_param_f(ptheta);
    let ct = b.fcos(theta);
    let st = b.fsin(theta);
    let s_f = b.cvt_i2f(s_i);
    let one_f = b.constf(1.0);
    let half = b.constf(0.5);
    let sm1 = b.fsub(s_f, one_f);
    let c = b.fmul(sm1, half);

    let colf = b.cvt_i2f(col);
    let rowf = b.cvt_i2f(row);
    let dx = b.fsub(colf, c);
    let dy = b.fsub(rowf, c);
    // sx = ct*dx + st*dy + c ; sy = -st*dx + ct*dy + c
    let a0 = b.fmul(ct, dx);
    let a1 = b.fmul(st, dy);
    let a01 = b.fadd(a0, a1);
    let sx = b.fadd(a01, c);
    let b0 = b.fmul(st, dx);
    let b0n = b.fneg(b0);
    let b1 = b.fmul(ct, dy);
    let b01 = b.fadd(b0n, b1);
    let sy = b.fadd(b01, c);

    let v = emit_bilinear(&mut b, pimg, None, s_i, sy, sx);
    let rowbase = b.imul(row, s_i);
    let oidx = b.iadd(rowbase, col);
    b.stg(pout, oidx, v);
    b.bind(end);
    b.ret();
    b.build()
}

/// `sinogram_<tf>(img, angles, out, s)`: the fused hot kernel. Grid: one
/// block per orientation; threads: one per column. Each thread marches
/// down the rows of the (virtually) rotated image, bilinearly sampling
/// and reducing with the T-functional — the rotated image never
/// materializes (the CUDA version's shared-memory trick, done in
/// registers here).
pub fn sinogram(tfunc: &str) -> Result<Kernel> {
    assert!(T_FUNCTIONALS.contains(&tfunc), "unknown tfunc {tfunc}");
    let mut b = KernelBuilder::new(&format!("sinogram_{tfunc}"));
    let pimg = b.ptr_param();
    let pangles = b.ptr_param();
    let pout = b.ptr_param();
    let ps = b.i32_param();

    let s_i = b.ld_param_i(ps);
    let col = b.tid_x();
    let aidx = b.ctaid_x();
    let col_ok = b.cmpi(CmpOp::Lt, col, s_i);
    let end = b.label();
    b.bra_ifz(col_ok, end);

    let theta = b.ldg(pangles, aidx);
    let ct = b.fcos(theta);
    let st = b.fsin(theta);
    let s_f = b.cvt_i2f(s_i);
    let one_f = b.constf(1.0);
    let half = b.constf(0.5);
    let sm1 = b.fsub(s_f, one_f);
    let c = b.fmul(sm1, half);
    let colf = b.cvt_i2f(col);
    let dx = b.fsub(colf, c);
    // Row-independent terms: sx = (ct*dx + c) + st*dy ; sy = (c - st*dx) + ct*dy
    let sx_base0 = b.fmul(ct, dx);
    let sx_base = b.fadd(sx_base0, c);
    let sy_sub = b.fmul(st, dx);
    let sy_base = b.fsub(c, sy_sub);

    // accumulator: 0 for sums, first sample handled via -inf for max
    let acc = b.constf(if tfunc == "tmax" { f32::NEG_INFINITY } else { 0.0 });
    let r = b.consti(0);
    let one_i = b.consti(1);
    let top = b.label();
    b.bind(top);
    let rf = b.cvt_i2f(r);
    let dy = b.fsub(rf, c);
    let sx_t = b.fmul(st, dy);
    let sx = b.fadd(sx_base, sx_t);
    let sy_t = b.fmul(ct, dy);
    let sy = b.fadd(sy_base, sy_t);
    let v = emit_bilinear(&mut b, pimg, None, s_i, sy, sx);
    match tfunc {
        "radon" => b.fadd_to(acc, v),
        "t1" => {
            let w = b.fabs(dy);
            let wv = b.fmul(w, v);
            b.fadd_to(acc, wv);
        }
        "t2" => {
            let w = b.fmul(dy, dy);
            let wv = b.fmul(w, v);
            b.fadd_to(acc, wv);
        }
        "tmax" => b.fmax_to(acc, v),
        _ => unreachable!(),
    }
    b.iadd_to(r, one_i);
    let more = b.cmpi(CmpOp::Lt, r, s_i);
    b.bra_if(more, top);

    let out_row = b.imul(aidx, s_i);
    let oidx = b.iadd(out_row, col);
    b.stg(pout, oidx, acc);
    b.bind(end);
    b.ret();
    b.build()
}

/// `sinogram_all(img, angles, out, s)`: the optimized multi-functional
/// variant — ONE marching pass over the rotated samples accumulates all
/// four T-functionals (resampling dominates, so this is ~4x cheaper than
/// four per-functional launches). Output layout: `out[t][angle][col]`,
/// t ordered as [`T_FUNCTIONALS`]. Grid: one block per orientation;
/// threads: one per column.
pub fn sinogram_all() -> Result<Kernel> {
    let mut b = KernelBuilder::new("sinogram_all");
    let pimg = b.ptr_param();
    let pangles = b.ptr_param();
    let pout = b.ptr_param();
    let ps = b.i32_param();

    let s_i = b.ld_param_i(ps);
    let col = b.tid_x();
    let aidx = b.ctaid_x();
    let n_angles = b.nctaid_x();
    let col_ok = b.cmpi(CmpOp::Lt, col, s_i);
    let end = b.label();
    b.bra_ifz(col_ok, end);

    let theta = b.ldg(pangles, aidx);
    let ct = b.fcos(theta);
    let st = b.fsin(theta);
    let s_f = b.cvt_i2f(s_i);
    let one_f = b.constf(1.0);
    let half = b.constf(0.5);
    let sm1 = b.fsub(s_f, one_f);
    let c = b.fmul(sm1, half);
    let colf = b.cvt_i2f(col);
    let dx = b.fsub(colf, c);
    let sx_base0 = b.fmul(ct, dx);
    let sx_base = b.fadd(sx_base0, c);
    let sy_sub = b.fmul(st, dx);
    let sy_base = b.fsub(c, sy_sub);

    let acc_radon = b.constf(0.0);
    let acc_t1 = b.constf(0.0);
    let acc_t2 = b.constf(0.0);
    let acc_max = b.constf(f32::NEG_INFINITY);
    let r = b.consti(0);
    let one_i = b.consti(1);
    let top = b.label();
    b.bind(top);
    let rf = b.cvt_i2f(r);
    let dy = b.fsub(rf, c);
    let sx_t = b.fmul(st, dy);
    let sx = b.fadd(sx_base, sx_t);
    let sy_t = b.fmul(ct, dy);
    let sy = b.fadd(sy_base, sy_t);
    let v = emit_bilinear(&mut b, pimg, None, s_i, sy, sx);
    b.fadd_to(acc_radon, v);
    let w1 = b.fabs(dy);
    let wv1 = b.fmul(w1, v);
    b.fadd_to(acc_t1, wv1);
    let w2 = b.fmul(dy, dy);
    let wv2 = b.fmul(w2, v);
    b.fadd_to(acc_t2, wv2);
    b.fmax_to(acc_max, v);
    b.iadd_to(r, one_i);
    let more = b.cmpi(CmpOp::Lt, r, s_i);
    b.bra_if(more, top);

    // out[t*a*s + aidx*s + col], t in declaration order
    let row_base = b.imul(aidx, s_i);
    let base0 = b.iadd(row_base, col);
    let plane = b.imul(n_angles, s_i);
    let mut idx = base0;
    for acc in [acc_radon, acc_t1, acc_t2, acc_max] {
        b.stg(pout, idx, acc);
        idx = b.iadd(idx, plane);
    }
    b.bind(end);
    b.ret();
    b.build()
}

/// `batched_sinogram(imgs, angles, out, s)`: the batched launch shape —
/// N stacked images through ONE launch of one specialization, so a whole
/// batch pays a single angle-table upload and a single image upload
/// (§6.2's pre-allocated buffers amortized across the batch). Input
/// layout `imgs[n][s][s]`, output `out[n][t][angle][col]` with t ordered
/// as [`T_FUNCTIONALS`]. Grid: (orientations, images); threads: one per
/// column. Per-element arithmetic is identical to [`sinogram_all`], so
/// batched and per-image results agree bitwise.
pub fn batched_sinogram() -> Result<Kernel> {
    let mut b = KernelBuilder::new("batched_sinogram");
    let pimg = b.ptr_param();
    let pangles = b.ptr_param();
    let pout = b.ptr_param();
    let ps = b.i32_param();

    let s_i = b.ld_param_i(ps);
    let col = b.tid_x();
    let aidx = b.ctaid_x();
    let bimg = b.ctaid_y();
    let n_angles = b.nctaid_x();
    let col_ok = b.cmpi(CmpOp::Lt, col, s_i);
    let end = b.label();
    b.bra_ifz(col_ok, end);

    // per-image base offsets: images are s*s apart, outputs 4*a*s apart
    let ss = b.imul(s_i, s_i);
    let img_base = b.imul(bimg, ss);
    let plane = b.imul(n_angles, s_i);
    let four = b.consti(4);
    let out_stride = b.imul(four, plane);
    let out_base = b.imul(bimg, out_stride);

    let theta = b.ldg(pangles, aidx);
    let ct = b.fcos(theta);
    let st = b.fsin(theta);
    let s_f = b.cvt_i2f(s_i);
    let one_f = b.constf(1.0);
    let half = b.constf(0.5);
    let sm1 = b.fsub(s_f, one_f);
    let c = b.fmul(sm1, half);
    let colf = b.cvt_i2f(col);
    let dx = b.fsub(colf, c);
    let sx_base0 = b.fmul(ct, dx);
    let sx_base = b.fadd(sx_base0, c);
    let sy_sub = b.fmul(st, dx);
    let sy_base = b.fsub(c, sy_sub);

    let acc_radon = b.constf(0.0);
    let acc_t1 = b.constf(0.0);
    let acc_t2 = b.constf(0.0);
    let acc_max = b.constf(f32::NEG_INFINITY);
    let r = b.consti(0);
    let one_i = b.consti(1);
    let top = b.label();
    b.bind(top);
    let rf = b.cvt_i2f(r);
    let dy = b.fsub(rf, c);
    let sx_t = b.fmul(st, dy);
    let sx = b.fadd(sx_base, sx_t);
    let sy_t = b.fmul(ct, dy);
    let sy = b.fadd(sy_base, sy_t);
    let v = emit_bilinear(&mut b, pimg, Some(img_base), s_i, sy, sx);
    b.fadd_to(acc_radon, v);
    let w1 = b.fabs(dy);
    let wv1 = b.fmul(w1, v);
    b.fadd_to(acc_t1, wv1);
    let w2 = b.fmul(dy, dy);
    let wv2 = b.fmul(w2, v);
    b.fadd_to(acc_t2, wv2);
    b.fmax_to(acc_max, v);
    b.iadd_to(r, one_i);
    let more = b.cmpi(CmpOp::Lt, r, s_i);
    b.bra_if(more, top);

    // out[img*4*a*s + t*a*s + aidx*s + col], t in declaration order
    let row_base = b.imul(aidx, s_i);
    let base0 = b.iadd(row_base, col);
    let mut idx = b.iadd(out_base, base0);
    for acc in [acc_radon, acc_t1, acc_t2, acc_max] {
        b.stg(pout, idx, acc);
        idx = b.iadd(idx, plane);
    }
    b.bind(end);
    b.ret();
    b.build()
}

/// `circus_all(sinos, circus, s)`: the device-side **P stage** — one
/// block per sinogram row, a shared-memory tree reduction computing all
/// `|P|` circus values (Σ, max, Σ|·|) for the row in ONE pass over the
/// data (`P_SET` order). Input rows are `s` wide; grid `(a, T)` where
/// `T` is however many sinogram planes are stacked (4 for one image's
/// `sinogram_all` output, `n·4` for a `batched_sinogram` batch — the
/// kernel only sees rows). Output layout `circus[(t*3 + p)*a + angle]`,
/// so the F stage reads each (t, p) circus function contiguously.
/// `block_h` threads per block (power of two >= s; extra threads
/// contribute the identity). The tree width is baked into the kernel
/// (shared size, loop bound), so the name carries it — the driver's
/// module cache keys on kernel names, and two widths must not collide.
pub fn circus_all(block_h: usize) -> Result<Kernel> {
    assert!(block_h.is_power_of_two(), "block_h must be a power of two");
    let mut b = KernelBuilder::new(&format!("circus_all_b{block_h}"));
    let psin = b.ptr_param();
    let pcir = b.ptr_param();
    let ps = b.i32_param();
    b.shared(3 * block_h);

    let s_i = b.ld_param_i(ps);
    let tid = b.tid_x();
    let aidx = b.ctaid_x();
    let t = b.ctaid_y();
    let na = b.nctaid_x();

    // identities: 0 for the sums, -inf for the max
    let sumv = b.constf(0.0);
    let maxv = b.constf(f32::NEG_INFINITY);
    let l1v = b.constf(0.0);
    let in_s = b.cmpi(CmpOp::Lt, tid, s_i);
    let skip_load = b.label();
    b.bra_ifz(in_s, skip_load);
    let row0 = b.imul(t, na);
    let row = b.iadd(row0, aidx);
    let rbase = b.imul(row, s_i);
    let idx = b.iadd(rbase, tid);
    let g = b.ldg(psin, idx);
    b.movf(sumv, g);
    b.movf(maxv, g);
    let ab = b.fabs(g);
    b.movf(l1v, ab);
    b.bind(skip_load);

    // three shared regions [0, bh, 2bh), folded by one reduce1d loop
    let bh_i = b.consti(block_h as i64);
    let bh2_i = b.consti(2 * block_h as i64);
    b.sts(tid, sumv);
    let i1 = b.iadd(tid, bh_i);
    b.sts(i1, maxv);
    let i2 = b.iadd(tid, bh2_i);
    b.sts(i2, l1v);
    b.bar();
    b.reduce1d(
        tid,
        block_h,
        &[(0, FOp::Add), (block_h, FOp::Max), (2 * block_h, FOp::Add)],
    );

    // thread 0 writes circus[(t*3 + p)*a + aidx] for p in P_SET order
    let zero = b.consti(0);
    let is0 = b.cmpi(CmpOp::Eq, tid, zero);
    let end = b.label();
    b.bra_ifz(is0, end);
    let three = b.consti(3);
    let one = b.consti(1);
    let t3 = b.imul(t, three);
    let mut prow = t3;
    for sh_base in [zero, bh_i, bh2_i] {
        let obase = b.imul(prow, na);
        let oi = b.iadd(obase, aidx);
        let v = b.lds(sh_base);
        b.stg(pcir, oi, v);
        prow = b.iadd(prow, one);
    }
    b.bind(end);
    b.ret();
    b.build()
}

/// `features_all(circus, out, a)`: the device-side **F stage** — one
/// block per (t, p) circus function, reducing its `a` angle samples
/// with all `|F|` functionals (mean, max — `F_SET` order) in one
/// shared-memory tree pass. Grid `(|P|, T)`; input layout is
/// [`circus_all`]'s output; output `out[(t*|P| + p)*2 + f]` — exactly
/// the (T, P, F)-lexicographic feature block of
/// `functionals::feature_order`, `FEATURE_COUNT` floats for a full
/// stack. `block_h` threads per block (power of two >= a); like
/// [`circus_all`], the baked tree width rides in the kernel name so
/// module caches never serve the wrong width.
pub fn features_all(block_h: usize) -> Result<Kernel> {
    assert!(block_h.is_power_of_two(), "block_h must be a power of two");
    let mut b = KernelBuilder::new(&format!("features_all_b{block_h}"));
    let pcir = b.ptr_param();
    let pout = b.ptr_param();
    let pa = b.i32_param();
    b.shared(2 * block_h);

    let a_i = b.ld_param_i(pa);
    let tid = b.tid_x();
    let p = b.ctaid_x();
    let t = b.ctaid_y();
    let np = b.nctaid_x();
    let row0 = b.imul(t, np);
    let row = b.iadd(row0, p);

    let sumv = b.constf(0.0);
    let maxv = b.constf(f32::NEG_INFINITY);
    let in_a = b.cmpi(CmpOp::Lt, tid, a_i);
    let skip_load = b.label();
    b.bra_ifz(in_a, skip_load);
    let rbase = b.imul(row, a_i);
    let idx = b.iadd(rbase, tid);
    let h = b.ldg(pcir, idx);
    b.movf(sumv, h);
    b.movf(maxv, h);
    b.bind(skip_load);

    let bh_i = b.consti(block_h as i64);
    b.sts(tid, sumv);
    let i1 = b.iadd(tid, bh_i);
    b.sts(i1, maxv);
    b.bar();
    b.reduce1d(tid, block_h, &[(0, FOp::Add), (block_h, FOp::Max)]);

    let zero = b.consti(0);
    let is0 = b.cmpi(CmpOp::Eq, tid, zero);
    let end = b.label();
    b.bra_ifz(is0, end);
    let total = b.lds(zero);
    let a_f = b.cvt_i2f(a_i);
    let mean = b.fdiv(total, a_f);
    let two = b.consti(2);
    let oi0 = b.imul(row, two);
    b.stg(pout, oi0, mean);
    let one = b.consti(1);
    let oi1 = b.iadd(oi0, one);
    let mx = b.lds(bh_i);
    b.stg(pout, oi1, mx);
    b.bind(end);
    b.ret();
    b.build()
}

/// `tfunc_<tf>(img, out, h, w)`: standalone column T-functional with a
/// shared-memory tree reduction — one block per column, `block_h` threads
/// per block (must be a power of two >= h; extra threads contribute the
/// identity). Exercises the barrier path like the CUDA original.
pub fn tfunc_column(tfunc: &str, block_h: usize) -> Result<Kernel> {
    assert!(T_FUNCTIONALS.contains(&tfunc), "unknown tfunc {tfunc}");
    assert!(block_h.is_power_of_two(), "block_h must be a power of two");
    let mut b = KernelBuilder::new(&format!("tfunc_{tfunc}"));
    let pimg = b.ptr_param();
    let pout = b.ptr_param();
    let ph = b.i32_param();
    let pw = b.i32_param();
    b.shared(block_h);

    let h = b.ld_param_i(ph);
    let w = b.ld_param_i(pw);
    let tid = b.tid_x();
    let colb = b.ctaid_x();

    // identity: 0 for sums, -inf for max
    let ident = b.constf(if tfunc == "tmax" { f32::NEG_INFINITY } else { 0.0 });
    let val = b.f();
    b.movf(val, ident);

    // threads with tid < h load img[tid*w + col] and weight it
    let in_h = b.cmpi(CmpOp::Lt, tid, h);
    let col_ok = b.cmpi(CmpOp::Lt, colb, w);
    let live = b.imul(in_h, col_ok);
    let skip_load = b.label();
    b.bra_ifz(live, skip_load);
    let rowbase = b.imul(tid, w);
    let idx = b.iadd(rowbase, colb);
    let x = b.ldg(pimg, idx);
    match tfunc {
        "radon" | "tmax" => b.movf(val, x),
        "t1" | "t2" => {
            let hf = b.cvt_i2f(h);
            let one_f = b.constf(1.0);
            let half = b.constf(0.5);
            let hm1 = b.fsub(hf, one_f);
            let c = b.fmul(hm1, half);
            let tf = b.cvt_i2f(tid);
            let dy = b.fsub(tf, c);
            let wgt = if tfunc == "t1" { b.fabs(dy) } else { b.fmul(dy, dy) };
            let wx = b.fmul(wgt, x);
            b.movf(val, wx);
        }
        _ => unreachable!(),
    }
    b.bind(skip_load);
    b.sts(tid, val);
    b.bar();

    // tree reduction over block_h (power of two)
    let op = if tfunc == "tmax" { FOp::Max } else { FOp::Add };
    b.reduce1d(tid, block_h, &[(0, op)]);
    let zero_i = b.consti(0);

    let is0 = b.cmpi(CmpOp::Eq, tid, zero_i);
    let write_ok = b.imul(is0, col_ok);
    let out_end = b.label();
    b.bra_ifz(write_ok, out_end);
    let total = b.lds(tid);
    b.stg(pout, colb, total);
    b.bind(out_end);
    b.ret();
    b.build()
}

/// All kernels needed by the emulator trace-transform path for image size
/// `s` (rounded block height for the column reduction).
pub fn trace_module(s: usize) -> Result<Vec<Kernel>> {
    let block_h = s.next_power_of_two();
    let mut kernels = vec![
        vadd()?,
        rotate_bilinear()?,
        sinogram_all()?,
        batched_sinogram()?,
        // the device-resident P/F stage (the F stage's block covers the
        // angle count, which is bounded by the row width in practice)
        circus_all(block_h)?,
        features_all(block_h)?,
    ];
    for t in T_FUNCTIONALS {
        kernels.push(sinogram(t)?);
        kernels.push(tfunc_column(t, block_h)?);
    }
    Ok(kernels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emulator::interp::{execute, Launch, Limits, ScalarArg};

    fn run(
        k: &Kernel,
        grid: u32,
        block: u32,
        bufs: Vec<&mut [f32]>,
        scalars: Vec<ScalarArg>,
    ) {
        execute(Launch {
            kernel: k,
            grid: (grid, 1),
            block: (block, 1),
            buffers: bufs,
            scalars,
            limits: Limits::default(),
        })
        .unwrap()
    }

    #[test]
    fn vadd_with_tail_guard() {
        let k = vadd().unwrap();
        let n = 5usize;
        let mut a = vec![1.0f32; 8];
        let mut b = vec![2.0f32; 8];
        let mut c = vec![0.0f32; 8];
        // 2 blocks x 4 threads = 8 threads, only 5 write
        run(&k, 2, 4, vec![&mut a[..n], &mut b[..n], &mut c], vec![ScalarArg::I32(n as i32)]);
        assert_eq!(&c[..5], &[3.0; 5]);
        assert_eq!(&c[5..], &[0.0; 3]);
    }

    /// Native reference rotation, same convention as the kernels.
    fn rotate_ref(img: &[f32], s: usize, theta: f32) -> Vec<f32> {
        let c = (s as f32 - 1.0) / 2.0;
        let (st, ct) = theta.sin_cos();
        let mut out = vec![0.0f32; s * s];
        for y in 0..s {
            for x in 0..s {
                let dx = x as f32 - c;
                let dy = y as f32 - c;
                let sx = ct * dx + st * dy + c;
                let sy = -st * dx + ct * dy + c;
                let y0 = sy.floor();
                let x0 = sx.floor();
                let (fy, fx) = (sy - y0, sx - x0);
                let gather = |yi: i64, xi: i64| -> f32 {
                    if yi >= 0 && (yi as usize) < s && xi >= 0 && (xi as usize) < s {
                        img[yi as usize * s + xi as usize]
                    } else {
                        0.0
                    }
                };
                let (y0, x0) = (y0 as i64, x0 as i64);
                out[y * s + x] = gather(y0, x0) * (1.0 - fy) * (1.0 - fx)
                    + gather(y0, x0 + 1) * (1.0 - fy) * fx
                    + gather(y0 + 1, x0) * fy * (1.0 - fx)
                    + gather(y0 + 1, x0 + 1) * fy * fx;
            }
        }
        out
    }

    #[test]
    fn rotate_matches_native_reference() {
        let s = 12usize;
        let k = rotate_bilinear().unwrap();
        let mut img: Vec<f32> = (0..s * s).map(|i| ((i * 37) % 101) as f32 * 0.1).collect();
        let want = rotate_ref(&img, s, 0.6);
        let mut out = vec![0.0f32; s * s];
        run(
            &k,
            s as u32,
            s as u32,
            vec![&mut img, &mut out],
            vec![ScalarArg::F32(0.6), ScalarArg::I32(s as i32)],
        );
        for (g, w) in out.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn rotate_zero_angle_is_identity() {
        let s = 8usize;
        let k = rotate_bilinear().unwrap();
        let mut img: Vec<f32> = (0..s * s).map(|i| i as f32).collect();
        let orig = img.clone();
        let mut out = vec![0.0f32; s * s];
        run(
            &k,
            s as u32,
            s as u32,
            vec![&mut img, &mut out],
            vec![ScalarArg::F32(0.0), ScalarArg::I32(s as i32)],
        );
        assert_eq!(out, orig);
    }

    #[test]
    fn sinogram_zero_angle_matches_column_functional() {
        let s = 10usize;
        let mut img: Vec<f32> = (0..s * s).map(|i| ((i * 13) % 17) as f32).collect();
        for tf in T_FUNCTIONALS {
            let k = sinogram(tf).unwrap();
            let mut angles = vec![0.0f32];
            let mut out = vec![0.0f32; s];
            run(
                &k,
                1,
                s as u32,
                vec![&mut img, &mut angles, &mut out],
                vec![ScalarArg::I32(s as i32)],
            );
            // expected: T-functional straight down the columns
            let c = (s as f32 - 1.0) / 2.0;
            for col in 0..s {
                let expected = match tf {
                    "radon" => (0..s).map(|r| img[r * s + col]).sum::<f32>(),
                    "t1" => (0..s).map(|r| (r as f32 - c).abs() * img[r * s + col]).sum(),
                    "t2" => (0..s)
                        .map(|r| (r as f32 - c) * (r as f32 - c) * img[r * s + col])
                        .sum(),
                    "tmax" => (0..s).map(|r| img[r * s + col]).fold(f32::MIN, f32::max),
                    _ => unreachable!(),
                };
                assert!(
                    (out[col] - expected).abs() < 1e-3,
                    "{tf} col {col}: {} vs {expected}",
                    out[col]
                );
            }
        }
    }

    #[test]
    fn tfunc_column_tree_reduction_matches() {
        let (h, w) = (10usize, 6usize);
        let block_h = h.next_power_of_two();
        let mut img: Vec<f32> = (0..h * w).map(|i| ((i * 7) % 23) as f32 * 0.5).collect();
        let c = (h as f32 - 1.0) / 2.0;
        for tf in T_FUNCTIONALS {
            let k = tfunc_column(tf, block_h).unwrap();
            let mut out = vec![0.0f32; w];
            run(
                &k,
                w as u32,
                block_h as u32,
                vec![&mut img, &mut out],
                vec![ScalarArg::I32(h as i32), ScalarArg::I32(w as i32)],
            );
            for col in 0..w {
                let expected = match tf {
                    "radon" => (0..h).map(|r| img[r * w + col]).sum::<f32>(),
                    "t1" => (0..h).map(|r| (r as f32 - c).abs() * img[r * w + col]).sum(),
                    "t2" => (0..h)
                        .map(|r| (r as f32 - c) * (r as f32 - c) * img[r * w + col])
                        .sum(),
                    "tmax" => (0..h).map(|r| img[r * w + col]).fold(f32::MIN, f32::max),
                    _ => unreachable!(),
                };
                assert!(
                    (out[col] - expected).abs() < 1e-3,
                    "{tf} col {col}: {} vs {expected}",
                    out[col]
                );
            }
        }
    }

    #[test]
    fn two_dimensional_grid_transpose() {
        // out[x*h + y] = in[y*w + x], one thread per element, 2-D blocks
        // over a 2-D grid — exercises the y-dimension special registers.
        use crate::emulator::builder::KernelBuilder;
        let (h, w) = (10usize, 6usize);
        let mut b = KernelBuilder::new("transpose");
        let pin = b.ptr_param();
        let pout = b.ptr_param();
        let ph = b.i32_param();
        let pw = b.i32_param();
        let hh = b.ld_param_i(ph);
        let ww = b.ld_param_i(pw);
        let tx = b.tid_x();
        let ty = b.tid_y();
        let bx = b.ctaid_x();
        let by = b.ctaid_y();
        let bdx = b.ntid_x();
        let bdy = b.ntid_y();
        let gx0 = b.imul(bx, bdx);
        let gx = b.iadd(gx0, tx); // column
        let gy0 = b.imul(by, bdy);
        let gy = b.iadd(gy0, ty); // row
        let okx = b.cmpi(CmpOp::Lt, gx, ww);
        let oky = b.cmpi(CmpOp::Lt, gy, hh);
        let ok = b.imul(okx, oky);
        let end = b.label();
        b.bra_ifz(ok, end);
        let in_row = b.imul(gy, ww);
        let in_idx = b.iadd(in_row, gx);
        let v = b.ldg(pin, in_idx);
        let out_row = b.imul(gx, hh);
        let out_idx = b.iadd(out_row, gy);
        b.stg(pout, out_idx, v);
        b.bind(end);
        b.ret();
        let k = b.build().unwrap();

        let mut input: Vec<f32> = (0..h * w).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; h * w];
        execute(Launch {
            kernel: &k,
            grid: (2, 3), // 2x3 blocks of 4x4 threads covers 6x10
            block: (4, 4),
            buffers: vec![&mut input, &mut out],
            scalars: vec![ScalarArg::I32(h as i32), ScalarArg::I32(w as i32)],
            limits: Limits::default(),
        })
        .unwrap();
        for y in 0..h {
            for x in 0..w {
                assert_eq!(out[x * h + y], input[y * w + x], "({y},{x})");
            }
        }
    }

    #[test]
    fn trace_module_builds_all() {
        let ks = trace_module(64).unwrap();
        assert_eq!(ks.len(), 6 + 2 * T_FUNCTIONALS.len());
        for k in &ks {
            assert!(k.validate().is_ok(), "{} invalid", k.name);
        }
    }

    #[test]
    fn circus_all_matches_host_p_functionals() {
        use crate::tracetransform::functionals::P_SET;
        let (nt, a, s) = (4usize, 5usize, 10usize);
        let mut sinos: Vec<f32> =
            (0..nt * a * s).map(|i| ((i * 31) % 29) as f32 * 0.4 - 5.0).collect();
        let block_h = s.next_power_of_two();
        let k = circus_all(block_h).unwrap();
        let mut circus = vec![0.0f32; nt * 3 * a];
        execute(Launch {
            kernel: &k,
            grid: (a as u32, nt as u32),
            block: (block_h as u32, 1),
            buffers: vec![&mut sinos, &mut circus],
            scalars: vec![ScalarArg::I32(s as i32)],
            limits: Limits::default(),
        })
        .unwrap();
        for t in 0..nt {
            for ai in 0..a {
                let row = &sinos[(t * a + ai) * s..(t * a + ai + 1) * s];
                for (p, pf) in P_SET.iter().enumerate() {
                    let want = pf.apply(row);
                    let got = circus[(t * 3 + p) * a + ai];
                    assert!(
                        (got - want).abs() < 1e-4 * want.abs().max(1.0),
                        "t={t} p={p} a={ai}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn features_all_matches_host_f_functionals() {
        use crate::tracetransform::functionals::F_SET;
        let (rows, a) = (12usize, 7usize); // rows = nt * |P|
        let mut circus: Vec<f32> =
            (0..rows * a).map(|i| ((i * 17) % 13) as f32 * 0.7 - 2.0).collect();
        let block_h = a.next_power_of_two();
        let k = features_all(block_h).unwrap();
        let mut out = vec![0.0f32; rows * 2];
        execute(Launch {
            kernel: &k,
            grid: (3, (rows / 3) as u32), // grid (|P|, T)
            block: (block_h as u32, 1),
            buffers: vec![&mut circus, &mut out],
            scalars: vec![ScalarArg::I32(a as i32)],
            limits: Limits::default(),
        })
        .unwrap();
        for r in 0..rows {
            let h = &circus[r * a..(r + 1) * a];
            for (f, ff) in F_SET.iter().enumerate() {
                let want = ff.apply(h);
                let got = out[r * 2 + f];
                assert!(
                    (got - want).abs() < 1e-4 * want.abs().max(1.0),
                    "row {r} f {f}: {got} vs {want}"
                );
            }
        }
    }

    /// The full device chain `sinogram_all -> circus_all -> features_all`
    /// agrees with the host `reduce_sinogram` reference on the fused
    /// sinograms — the kernel-level version of the pipeline acceptance.
    #[test]
    fn device_reduction_chain_matches_reduce_sinogram() {
        use crate::tracetransform::functionals::reduce_sinogram;
        let (s, a) = (12usize, 6usize);
        let mut img: Vec<f32> = (0..s * s).map(|i| ((i * 13) % 19) as f32 * 0.3).collect();
        let mut angles: Vec<f32> = (0..a).map(|i| 0.2 + i as f32 * 0.5).collect();
        let mut sinos = vec![0.0f32; 4 * a * s];
        let k_sino = sinogram_all().unwrap();
        run(
            &k_sino,
            a as u32,
            s as u32,
            vec![&mut img, &mut angles, &mut sinos],
            vec![ScalarArg::I32(s as i32)],
        );
        let mut want = Vec::new();
        for t in 0..4 {
            want.extend(reduce_sinogram(&sinos[t * a * s..(t + 1) * a * s], a, s));
        }

        let bh_s = s.next_power_of_two();
        let k_cir = circus_all(bh_s).unwrap();
        let mut circus = vec![0.0f32; 4 * 3 * a];
        execute(Launch {
            kernel: &k_cir,
            grid: (a as u32, 4),
            block: (bh_s as u32, 1),
            buffers: vec![&mut sinos, &mut circus],
            scalars: vec![ScalarArg::I32(s as i32)],
            limits: Limits::default(),
        })
        .unwrap();
        let bh_a = a.next_power_of_two();
        let k_feat = features_all(bh_a).unwrap();
        let mut feats = vec![0.0f32; 24];
        execute(Launch {
            kernel: &k_feat,
            grid: (3, 4),
            block: (bh_a as u32, 1),
            buffers: vec![&mut circus, &mut feats],
            scalars: vec![ScalarArg::I32(a as i32)],
            limits: Limits::default(),
        })
        .unwrap();
        for (i, (g, w)) in feats.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4 * w.abs().max(1.0), "feature {i}: {g} vs {w}");
        }
    }

    #[test]
    fn batched_sinogram_matches_per_image_fused() {
        let (s, a, n) = (8usize, 3usize, 3usize);
        let mut imgs: Vec<f32> =
            (0..n * s * s).map(|i| ((i * 29) % 31) as f32 * 0.25).collect();
        let mut angles: Vec<f32> = (0..a).map(|i| 0.3 + i as f32 * 0.9).collect();
        let k = batched_sinogram().unwrap();
        let mut out = vec![0.0f32; n * 4 * a * s];
        execute(Launch {
            kernel: &k,
            grid: (a as u32, n as u32),
            block: (s as u32, 1),
            buffers: vec![&mut imgs, &mut angles, &mut out],
            scalars: vec![ScalarArg::I32(s as i32)],
            limits: Limits::default(),
        })
        .unwrap();
        let fused = sinogram_all().unwrap();
        for i in 0..n {
            let mut img: Vec<f32> = imgs[i * s * s..(i + 1) * s * s].to_vec();
            let mut ang = angles.clone();
            let mut single = vec![0.0f32; 4 * a * s];
            run(
                &fused,
                a as u32,
                s as u32,
                vec![&mut img, &mut ang, &mut single],
                vec![ScalarArg::I32(s as i32)],
            );
            let plane = &out[i * 4 * a * s..(i + 1) * 4 * a * s];
            // identical per-element arithmetic -> bitwise equality
            assert_eq!(plane, single.as_slice(), "image {i}");
        }
    }

    #[test]
    fn sinogram_all_matches_per_functional_kernels() {
        let s = 10usize;
        let a = 4usize;
        let mut img: Vec<f32> = (0..s * s).map(|i| ((i * 13) % 17) as f32 * 0.3).collect();
        let mut angles: Vec<f32> = (0..a).map(|i| i as f32 * 0.7).collect();
        let k_all = sinogram_all().unwrap();
        let mut fused = vec![0.0f32; T_FUNCTIONALS.len() * a * s];
        run(
            &k_all,
            a as u32,
            s as u32,
            vec![&mut img, &mut angles, &mut fused],
            vec![ScalarArg::I32(s as i32)],
        );
        for (ti, tf) in T_FUNCTIONALS.iter().enumerate() {
            let k = sinogram(tf).unwrap();
            let mut single = vec![0.0f32; a * s];
            run(
                &k,
                a as u32,
                s as u32,
                vec![&mut img, &mut angles, &mut single],
                vec![ScalarArg::I32(s as i32)],
            );
            let plane = &fused[ti * a * s..(ti + 1) * a * s];
            for (i, (f, g)) in plane.iter().zip(&single).enumerate() {
                assert!((f - g).abs() < 1e-4, "{tf} elem {i}: {f} vs {g}");
            }
        }
    }
}
