//! The **compiled execution tier**: a closure JIT (threaded code) over
//! the lowered basic-block form of [`crate::emulator::lower`].
//!
//! Hot basic blocks compile into straight-line chains of pre-resolved
//! `Box<dyn Fn>` closures — one per (possibly fused) operation, with
//! operand register slots, opcode selection and bounds-check shapes
//! resolved **at compile time** — so steady-state execution runs the
//! chain without the per-op `match` dispatch of the vector tier, and
//! charges the whole block's step weight in one entry guard instead of
//! one `charge()` pass per op.
//!
//! # Tier-up
//!
//! Each [`crate::emulator::decode::DecodedKernel`] carries a [`JitState`]
//! with a per-block execution counter and a per-block `OnceLock`
//! compilation slot, so the compiled form rides the existing
//! `DecodedKernel`/`Specialized` caches: warm launches inherit both the
//! hotness profile and the compiled blocks for free. A block compiles
//! once its execution count exceeds the tier-up threshold
//! (`HLGPU_TIER_UP` / [`crate::emulator::sched::set_default_tier_up`];
//! `0` = always-compile). Compilation is racy-but-idempotent: concurrent
//! workers may both cross the threshold, the `OnceLock` keeps the first
//! result, and only the winning run counts a `tier_up`.
//!
//! # Deopt: guard-then-execute, zero side effects
//!
//! Bitwise parity with the scalar reference comes from one invariant:
//! **a compiled op either completes for every masked lane or executes
//! nothing at all.** Every trap source is hoisted into a guard that runs
//! before any side effect:
//!
//! * **budget** — the entry guard checks `steps + body_weight <= limit`
//!   for every masked lane; any failure deopts at op 0 with nothing
//!   charged and nothing executed. (Charging is monotone, so if the
//!   whole-block charge fits, no per-op charge of the vector tier could
//!   have trapped — including `RmwG`'s interleaved checks.)
//! * **memory / division** — ops that can trap (`LdG`/`StG`/`LdS`/`StS`,
//!   integer `Div`/`Rem`, `RmwG`) run a read-only guard pass over all
//!   masked lanes first; a failure deopts at that op index after
//!   un-charging the remaining suffix weight (`rest_w`), with zero side
//!   effects from the failing op.
//!
//! On deopt the caller (the shared scheduler loop in
//! [`crate::emulator::vector`]) replays the block's ops **from the deopt
//! index** on the ordinary vector path, which re-charges op by op and
//! reports the exact trap — same lane, same coordinates, same reason
//! string — because the compiled prefix left precisely the state the
//! vector tier would have had. Terminators (including barriers and the
//! fused `LoopBack`) always run on the shared vector path, so
//! reconvergence, barrier-divergence and trap bookkeeping are never
//! duplicated.
//!
//! # Lane re-packing
//!
//! When the active mask is sparse (≤ half the block's lanes), the
//! referenced registers of the block are gathered into dense
//! lane-packed buffers (stride = mask length) before the chain runs and
//! scattered back after — on success *and* on deopt — so divergent
//! kernels execute contiguous lanes instead of striding across
//! masked-out ones. The gather/scatter set is every register the block
//! reads **or** writes, so scatter is always safe.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::emulator::interp::{binf_apply, cmpf, cmpi, BlockStats, GlobalMem};
use crate::emulator::isa::{FOp, IOp, Instr, Special, UnFOp};
use crate::emulator::lower::{Block, VOp};

/// Execution context handed to compiled-op closures. Register files may
/// be the block's full SoA buffers (stride = block lanes) or the packed
/// gather buffers (stride = mask length); closures address registers as
/// `reg * stride + row`, so the same chain serves both.
pub(crate) struct OpCtx<'a> {
    pub fr: &'a mut [f32],
    pub ir: &'a mut [i64],
    /// Lane stride of `fr`/`ir` (block lanes, or mask length when packed).
    pub stride: usize,
    /// Row of each active lane in `fr`/`ir` (the mask itself, or `0..m`
    /// when packed).
    pub rows: &'a [usize],
    /// Original lane id of each active lane (thread-id computations).
    pub lanes: &'a [usize],
    pub shared: &'a mut [f32],
    pub mem: &'a mut dyn GlobalMem,
    /// Hoisted per-buffer lengths (launch-constant).
    pub lens: &'a [usize],
    pub bx: u32,
    pub by: u32,
    pub gx: u32,
    pub gy: u32,
    pub bx_i: u32,
    pub by_i: u32,
}

/// Side-effecting body of a compiled op: runs every lane, cannot trap
/// (all trap sources were proven absent by the guard / entry guard).
type Exec = Box<dyn Fn(&mut OpCtx) + Send + Sync>;

/// Read-only trap guard: `true` = safe to execute for every masked lane.
type Guard = Box<dyn Fn(&OpCtx) -> bool + Send + Sync>;

struct COp {
    guard: Option<Guard>,
    exec: Exec,
    weight: u64,
    fused: bool,
}

/// Outcome of running a compiled block body.
pub(crate) enum CompiledRun {
    /// All ops retired; the caller runs the terminator on the shared
    /// vector path.
    Done,
    /// Guard failure before op *i* executed: the caller replays the ops
    /// from index *i* on the vector path, which reports the exact trap.
    Deopt(usize),
}

/// One basic block compiled to a closure chain.
pub(crate) struct CompiledBlock {
    ops: Vec<COp>,
    /// Suffix weights: `rest_w[i]` = Σ weights of ops `i..` — the amount
    /// to un-charge when deopting before op `i`.
    rest_w: Vec<u64>,
    /// Whole-body weight (== `rest_w[0]`), charged by the entry guard.
    body_w: u64,
    /// Registers the block reads or writes, per file — the lane
    /// re-packing gather/scatter set.
    fregs: Vec<usize>,
    iregs: Vec<usize>,
    /// Register-file sizes (packed-buffer allocation).
    nf: usize,
    ni: usize,
}

impl CompiledBlock {
    /// Run the block body for the masked lanes. `fr`/`ir` are the
    /// block's full SoA register files (stride `nl`); `steps` is
    /// lane-indexed. Statistics are accounted per retired op exactly as
    /// the vector tier would, plus the compiled-tier counters.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run(
        &self,
        fr: &mut [f32],
        ir: &mut [i64],
        nl: usize,
        mask: &[usize],
        shared: &mut [f32],
        mem: &mut dyn GlobalMem,
        lens: &[usize],
        steps: &mut [u64],
        limit: u64,
        grid: (u32, u32),
        block: (u32, u32),
        block_id: (u32, u32),
        stats: &mut BlockStats,
    ) -> CompiledRun {
        let m = mask.len();
        // Entry guard: the whole body's weight must fit every lane's
        // remaining budget, else deopt with nothing charged — the vector
        // replay re-charges op by op and traps exactly where the scalar
        // tier would.
        for &l in mask {
            if steps[l] + self.body_w > limit {
                return CompiledRun::Deopt(0);
            }
        }
        for &l in mask {
            steps[l] += self.body_w;
        }

        stats.dispatches += 1;
        stats.compiled_blocks += 1;

        // Lane re-packing: gather the referenced registers of a sparse
        // mask into dense buffers so the chain runs contiguous rows.
        let packed = m * 2 <= nl && m < nl;
        let mut pfr: Vec<f32> = Vec::new();
        let mut pir: Vec<i64> = Vec::new();
        let ident: Vec<usize> = if packed { (0..m).collect() } else { Vec::new() };
        if packed {
            pfr = vec![0f32; self.nf * m];
            pir = vec![0i64; self.ni * m];
            for &reg in &self.fregs {
                let (src, dst) = (reg * nl, reg * m);
                for (p, &l) in mask.iter().enumerate() {
                    pfr[dst + p] = fr[src + l];
                }
            }
            for &reg in &self.iregs {
                let (src, dst) = (reg * nl, reg * m);
                for (p, &l) in mask.iter().enumerate() {
                    pir[dst + p] = ir[src + l];
                }
            }
        }

        let mut outcome = CompiledRun::Done;
        {
            let (frs, irs, stride): (&mut [f32], &mut [i64], usize) = if packed {
                (pfr.as_mut_slice(), pir.as_mut_slice(), m)
            } else {
                (&mut *fr, &mut *ir, nl)
            };
            let rows: &[usize] = if packed { &ident } else { mask };
            let mut ctx = OpCtx {
                fr: frs,
                ir: irs,
                stride,
                rows,
                lanes: mask,
                shared,
                mem,
                lens,
                bx: block.0,
                by: block.1,
                gx: grid.0,
                gy: grid.1,
                bx_i: block_id.0,
                by_i: block_id.1,
            };
            for (i, cop) in self.ops.iter().enumerate() {
                if let Some(g) = &cop.guard {
                    if !g(&ctx) {
                        // Un-charge the unexecuted suffix so the vector
                        // replay's per-op charging resumes at the exact
                        // boundary.
                        for &l in mask {
                            steps[l] -= self.rest_w[i];
                        }
                        outcome = CompiledRun::Deopt(i);
                        break;
                    }
                }
                let wm = cop.weight * m as u64;
                stats.instrs += wm;
                stats.compiled_instrs += wm;
                if cop.fused {
                    stats.fused_instrs += wm;
                }
                stats.lane_ops += m as u64;
                stats.lane_slots += nl as u64;
                (cop.exec)(&mut ctx);
            }
        }

        if packed {
            // Scatter back — on success and on deopt alike: the vector
            // replay (or the terminator) must see the compiled prefix's
            // register state.
            for &reg in &self.fregs {
                let (src, dst) = (reg * m, reg * nl);
                for (p, &l) in mask.iter().enumerate() {
                    fr[dst + l] = pfr[src + p];
                }
            }
            for &reg in &self.iregs {
                let (src, dst) = (reg * m, reg * nl);
                for (p, &l) in mask.iter().enumerate() {
                    ir[dst + l] = pir[src + p];
                }
            }
        }
        outcome
    }
}

/// Per-kernel JIT state: hotness counters and compiled blocks, cached on
/// the [`crate::emulator::decode::DecodedKernel`] so warm launches share
/// both across schedules, devices and repeated launches.
pub(crate) struct JitState {
    hot: Vec<AtomicU64>,
    blocks: Vec<OnceLock<CompiledBlock>>,
}

impl JitState {
    pub(crate) fn new(nblocks: usize) -> Self {
        JitState {
            hot: (0..nblocks).map(|_| AtomicU64::new(0)).collect(),
            blocks: (0..nblocks).map(|_| OnceLock::new()).collect(),
        }
    }

    /// The compiled form of block `bid`, counting this execution toward
    /// tier-up and compiling (once, process-wide) when the count exceeds
    /// `tier_up`. `tier_ups` increments only in the run that actually
    /// won the compilation. Empty-bodied blocks (bare terminators) never
    /// compile — there is nothing to chain.
    pub(crate) fn compiled(
        &self,
        bid: usize,
        blk: &Block,
        fregs: u16,
        iregs: u16,
        tier_up: u64,
        tier_ups: &mut u64,
    ) -> Option<&CompiledBlock> {
        if blk.ops.is_empty() {
            return None;
        }
        let count = self.hot[bid].fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(cb) = self.blocks[bid].get() {
            return Some(cb);
        }
        if count > tier_up {
            let mut won = false;
            let cb = self.blocks[bid].get_or_init(|| {
                won = true;
                compile_block(blk, fregs, iregs)
            });
            if won {
                *tier_ups += 1;
            }
            return Some(cb);
        }
        None
    }

    /// Number of blocks currently holding a compiled form.
    pub(crate) fn compiled_count(&self) -> usize {
        self.blocks.iter().filter(|s| s.get().is_some()).count()
    }
}

impl fmt::Debug for JitState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("JitState")
            .field("blocks", &self.blocks.len())
            .field("compiled", &self.compiled_count())
            .finish()
    }
}

/// Compile one basic block into its closure chain.
fn compile_block(blk: &Block, fregs: u16, iregs: u16) -> CompiledBlock {
    let (nf, ni) = (fregs as usize, iregs as usize);
    let mut fused_regs = vec![false; nf];
    let mut iused_regs = vec![false; ni];
    let mut ops = Vec::with_capacity(blk.ops.len());
    for op in &blk.ops {
        collect_regs(op, &mut fused_regs, &mut iused_regs);
        ops.push(compile_op(op));
    }
    let mut rest_w = vec![0u64; ops.len()];
    let mut acc = 0u64;
    for i in (0..ops.len()).rev() {
        acc += ops[i].weight;
        rest_w[i] = acc;
    }
    CompiledBlock {
        ops,
        rest_w,
        body_w: acc,
        fregs: (0..nf).filter(|&r| fused_regs[r]).collect(),
        iregs: (0..ni).filter(|&r| iused_regs[r]).collect(),
        nf,
        ni,
    }
}

/// Mark every register the op reads or writes — the full set, so the
/// packed gather/scatter round trip is lossless no matter which
/// registers the op only writes.
fn collect_regs(op: &VOp, f: &mut [bool], i: &mut [bool]) {
    let mut fr = |r: u16| f[r as usize] = true;
    let mut ir = |r: u16| i[r as usize] = true;
    match *op {
        VOp::Base(ins) => match ins {
            Instr::ConstF(d, _) => fr(d),
            Instr::ConstI(d, _) => ir(d),
            Instr::MovF(d, s) => {
                fr(d);
                fr(s);
            }
            Instr::MovI(d, s) => {
                ir(d);
                ir(s);
            }
            Instr::BinF(_, d, a, b) => {
                fr(d);
                fr(a);
                fr(b);
            }
            Instr::BinI(_, d, a, b) => {
                ir(d);
                ir(a);
                ir(b);
            }
            Instr::UnF(_, d, a) => {
                fr(d);
                fr(a);
            }
            Instr::CmpF(_, d, a, b) => {
                ir(d);
                fr(a);
                fr(b);
            }
            Instr::CmpI(_, d, a, b) => {
                ir(d);
                ir(a);
                ir(b);
            }
            Instr::SelF(d, p, a, b) => {
                fr(d);
                ir(p);
                fr(a);
                fr(b);
            }
            Instr::CvtFI(d, s) => {
                ir(d);
                fr(s);
            }
            Instr::CvtIF(d, s) => {
                fr(d);
                ir(s);
            }
            Instr::Spec(d, _) => ir(d),
            Instr::LdG { dst, idx, .. } => {
                fr(dst);
                ir(idx);
            }
            Instr::StG { idx, src, .. } => {
                ir(idx);
                fr(src);
            }
            Instr::LdS { dst, idx } => {
                fr(dst);
                ir(idx);
            }
            Instr::StS { idx, src } => {
                ir(idx);
                fr(src);
            }
            Instr::LdParamF(..) | Instr::LdParamI(..) => {
                unreachable!("scalar params resolved by pre-decode")
            }
            Instr::Bar | Instr::Bra(_) | Instr::BraIf(..) | Instr::BraIfZ(..) | Instr::Ret => {
                unreachable!("control flow is lowered to block terminators")
            }
        },
        VOp::MulAddF { dm, ma, mb, dd, aa, ab } => {
            for r in [dm, ma, mb, dd, aa, ab] {
                fr(r);
            }
        }
        VOp::MulAddI { dm, ma, mb, dd, aa, ab } => {
            for r in [dm, ma, mb, dd, aa, ab] {
                ir(r);
            }
        }
        VOp::CvtMulAddF { df, si, dm, ma, mb, dd, aa, ab } => {
            ir(si);
            for r in [df, dm, ma, mb, dd, aa, ab] {
                fr(r);
            }
        }
        VOp::GlobalIdX { tid, bid, bdim, mul, add } => {
            for r in [tid, bid, bdim, mul.0, mul.1, mul.2, add.0, add.1, add.2] {
                ir(r);
            }
        }
        VOp::RmwG { idx, ld, sa, sb, st, .. } => {
            ir(idx);
            for r in [ld, sa, sb, st] {
                fr(r);
            }
        }
    }
}

/// Compile one lowered op into its guard + pre-resolved exec closure.
/// Opcode dispatch happens **here**, once per compilation — the returned
/// closures contain only the selected arithmetic and fixed register
/// slots.
fn compile_op(op: &VOp) -> COp {
    let weight = op.weight();
    let fused = op.is_fused();

    // Dense f32 binary: d = f(a, b), one specialized closure per opcode.
    macro_rules! binf_exec {
        ($d:expr, $a:expr, $b:expr, $f:expr) => {{
            let (d, a, b) = ($d as usize, $a as usize, $b as usize);
            Box::new(move |c: &mut OpCtx| {
                let s = c.stride;
                let rows = c.rows;
                let (db, ab, bb) = (d * s, a * s, b * s);
                for &r in rows {
                    c.fr[db + r] = $f(c.fr[ab + r], c.fr[bb + r]);
                }
            }) as Exec
        }};
    }
    // Dense i64 binary (non-trapping flavors).
    macro_rules! bini_exec {
        ($d:expr, $a:expr, $b:expr, $f:expr) => {{
            let (d, a, b) = ($d as usize, $a as usize, $b as usize);
            Box::new(move |c: &mut OpCtx| {
                let s = c.stride;
                let rows = c.rows;
                let (db, ab, bb) = (d * s, a * s, b * s);
                for &r in rows {
                    c.ir[db + r] = $f(c.ir[ab + r], c.ir[bb + r]);
                }
            }) as Exec
        }};
    }
    // Guard: no masked lane divides by zero.
    macro_rules! nonzero_guard {
        ($b:expr) => {{
            let b = $b as usize;
            Some(Box::new(move |c: &OpCtx| {
                let bb = b * c.stride;
                c.rows.iter().all(|&r| c.ir[bb + r] != 0)
            }) as Guard)
        }};
    }
    // Guard: every masked lane's index register is within `len` bounds.
    macro_rules! global_bounds_guard {
        ($slot:expr, $idx:expr) => {{
            let (slot, idx) = ($slot as usize, $idx as usize);
            Some(Box::new(move |c: &OpCtx| {
                let len = c.lens[slot];
                let ib = idx * c.stride;
                c.rows.iter().all(|&r| {
                    let i = c.ir[ib + r];
                    i >= 0 && (i as usize) < len
                })
            }) as Guard)
        }};
    }
    macro_rules! shared_bounds_guard {
        ($idx:expr) => {{
            let idx = $idx as usize;
            Some(Box::new(move |c: &OpCtx| {
                let len = c.shared.len();
                let ib = idx * c.stride;
                c.rows.iter().all(|&r| {
                    let i = c.ir[ib + r];
                    i >= 0 && (i as usize) < len
                })
            }) as Guard)
        }};
    }

    let (guard, exec): (Option<Guard>, Exec) = match *op {
        VOp::Base(ins) => match ins {
            Instr::ConstF(d, v) => {
                let d = d as usize;
                (
                    None,
                    Box::new(move |c: &mut OpCtx| {
                        let db = d * c.stride;
                        for &r in c.rows {
                            c.fr[db + r] = v;
                        }
                    }),
                )
            }
            Instr::ConstI(d, v) => {
                let d = d as usize;
                (
                    None,
                    Box::new(move |c: &mut OpCtx| {
                        let db = d * c.stride;
                        for &r in c.rows {
                            c.ir[db + r] = v;
                        }
                    }),
                )
            }
            Instr::MovF(d, s) => {
                let (d, s) = (d as usize, s as usize);
                (
                    None,
                    Box::new(move |c: &mut OpCtx| {
                        let (db, sb) = (d * c.stride, s * c.stride);
                        for &r in c.rows {
                            c.fr[db + r] = c.fr[sb + r];
                        }
                    }),
                )
            }
            Instr::MovI(d, s) => {
                let (d, s) = (d as usize, s as usize);
                (
                    None,
                    Box::new(move |c: &mut OpCtx| {
                        let (db, sb) = (d * c.stride, s * c.stride);
                        for &r in c.rows {
                            c.ir[db + r] = c.ir[sb + r];
                        }
                    }),
                )
            }
            Instr::BinF(fop, d, a, b) => (
                None,
                match fop {
                    FOp::Add => binf_exec!(d, a, b, |x: f32, y: f32| x + y),
                    FOp::Sub => binf_exec!(d, a, b, |x: f32, y: f32| x - y),
                    FOp::Mul => binf_exec!(d, a, b, |x: f32, y: f32| x * y),
                    FOp::Div => binf_exec!(d, a, b, |x: f32, y: f32| x / y),
                    FOp::Min => binf_exec!(d, a, b, |x: f32, y: f32| x.min(y)),
                    FOp::Max => binf_exec!(d, a, b, |x: f32, y: f32| x.max(y)),
                },
            ),
            Instr::BinI(iop, d, a, b) => match iop {
                IOp::Add => (None, bini_exec!(d, a, b, |x: i64, y: i64| x.wrapping_add(y))),
                IOp::Sub => (None, bini_exec!(d, a, b, |x: i64, y: i64| x.wrapping_sub(y))),
                IOp::Mul => (None, bini_exec!(d, a, b, |x: i64, y: i64| x.wrapping_mul(y))),
                // wrapping: i64::MIN / -1 must not panic (scalar parity)
                IOp::Div => (
                    nonzero_guard!(b),
                    bini_exec!(d, a, b, |x: i64, y: i64| x.wrapping_div(y)),
                ),
                IOp::Rem => (
                    nonzero_guard!(b),
                    bini_exec!(d, a, b, |x: i64, y: i64| x.wrapping_rem(y)),
                ),
            },
            Instr::UnF(uop, d, a) => {
                let (d, a) = (d as usize, a as usize);
                macro_rules! unf_exec {
                    ($f:expr) => {
                        Box::new(move |c: &mut OpCtx| {
                            let (db, ab) = (d * c.stride, a * c.stride);
                            for &r in c.rows {
                                c.fr[db + r] = $f(c.fr[ab + r]);
                            }
                        }) as Exec
                    };
                }
                (
                    None,
                    match uop {
                        UnFOp::Neg => unf_exec!(|x: f32| -x),
                        UnFOp::Abs => unf_exec!(|x: f32| x.abs()),
                        UnFOp::Sqrt => unf_exec!(|x: f32| x.sqrt()),
                        UnFOp::Sin => unf_exec!(|x: f32| x.sin()),
                        UnFOp::Cos => unf_exec!(|x: f32| x.cos()),
                        UnFOp::Floor => unf_exec!(|x: f32| x.floor()),
                    },
                )
            }
            Instr::CmpF(cop, d, a, b) => {
                let (d, a, b) = (d as usize, a as usize, b as usize);
                (
                    None,
                    Box::new(move |c: &mut OpCtx| {
                        let s = c.stride;
                        let (db, ab, bb) = (d * s, a * s, b * s);
                        for &r in c.rows {
                            c.ir[db + r] = cmpf(cop, c.fr[ab + r], c.fr[bb + r]) as i64;
                        }
                    }),
                )
            }
            Instr::CmpI(cop, d, a, b) => {
                let (d, a, b) = (d as usize, a as usize, b as usize);
                (
                    None,
                    Box::new(move |c: &mut OpCtx| {
                        let s = c.stride;
                        let (db, ab, bb) = (d * s, a * s, b * s);
                        for &r in c.rows {
                            c.ir[db + r] = cmpi(cop, c.ir[ab + r], c.ir[bb + r]) as i64;
                        }
                    }),
                )
            }
            Instr::SelF(d, p, a, b) => {
                let (d, p, a, b) = (d as usize, p as usize, a as usize, b as usize);
                (
                    None,
                    Box::new(move |c: &mut OpCtx| {
                        let s = c.stride;
                        let (db, pb, ab, bb) = (d * s, p * s, a * s, b * s);
                        for &r in c.rows {
                            c.fr[db + r] =
                                if c.ir[pb + r] != 0 { c.fr[ab + r] } else { c.fr[bb + r] };
                        }
                    }),
                )
            }
            Instr::CvtFI(d, s) => {
                let (d, s) = (d as usize, s as usize);
                (
                    None,
                    Box::new(move |c: &mut OpCtx| {
                        let (db, sb) = (d * c.stride, s * c.stride);
                        for &r in c.rows {
                            c.ir[db + r] = c.fr[sb + r] as i64;
                        }
                    }),
                )
            }
            Instr::CvtIF(d, s) => {
                let (d, s) = (d as usize, s as usize);
                (
                    None,
                    Box::new(move |c: &mut OpCtx| {
                        let (db, sb) = (d * c.stride, s * c.stride);
                        for &r in c.rows {
                            c.fr[db + r] = c.ir[sb + r] as f32;
                        }
                    }),
                )
            }
            Instr::Spec(d, sp) => {
                let d = d as usize;
                macro_rules! uniform_exec {
                    ($v:expr) => {
                        Box::new(move |c: &mut OpCtx| {
                            let db = d * c.stride;
                            let v = $v(c) as i64;
                            for &r in c.rows {
                                c.ir[db + r] = v;
                            }
                        }) as Exec
                    };
                }
                (
                    None,
                    match sp {
                        Special::ThreadIdX => Box::new(move |c: &mut OpCtx| {
                            let db = d * c.stride;
                            let bx = c.bx;
                            let lanes = c.lanes;
                            for (p, &r) in c.rows.iter().enumerate() {
                                c.ir[db + r] = ((lanes[p] as u32) % bx) as i64;
                            }
                        }),
                        Special::ThreadIdY => Box::new(move |c: &mut OpCtx| {
                            let db = d * c.stride;
                            let bx = c.bx;
                            let lanes = c.lanes;
                            for (p, &r) in c.rows.iter().enumerate() {
                                c.ir[db + r] = ((lanes[p] as u32) / bx) as i64;
                            }
                        }),
                        Special::BlockIdX => uniform_exec!(|c: &OpCtx| c.bx_i),
                        Special::BlockIdY => uniform_exec!(|c: &OpCtx| c.by_i),
                        Special::BlockDimX => uniform_exec!(|c: &OpCtx| c.bx),
                        Special::BlockDimY => uniform_exec!(|c: &OpCtx| c.by),
                        Special::GridDimX => uniform_exec!(|c: &OpCtx| c.gx),
                        Special::GridDimY => uniform_exec!(|c: &OpCtx| c.gy),
                    },
                )
            }
            Instr::LdG { dst, param, idx } => {
                let (d, slot, i) = (dst as usize, param as usize, idx as usize);
                (
                    global_bounds_guard!(slot, i),
                    Box::new(move |c: &mut OpCtx| {
                        let (db, ib) = (d * c.stride, i * c.stride);
                        for &r in c.rows {
                            c.fr[db + r] = c.mem.load(slot, c.ir[ib + r] as usize);
                        }
                    }),
                )
            }
            Instr::StG { param, idx, src } => {
                let (slot, i, s) = (param as usize, idx as usize, src as usize);
                (
                    global_bounds_guard!(slot, i),
                    Box::new(move |c: &mut OpCtx| {
                        let (ib, sb) = (i * c.stride, s * c.stride);
                        for &r in c.rows {
                            let v = c.fr[sb + r];
                            c.mem.store(slot, c.ir[ib + r] as usize, v);
                        }
                    }),
                )
            }
            Instr::LdS { dst, idx } => {
                let (d, i) = (dst as usize, idx as usize);
                (
                    shared_bounds_guard!(i),
                    Box::new(move |c: &mut OpCtx| {
                        let (db, ib) = (d * c.stride, i * c.stride);
                        for &r in c.rows {
                            c.fr[db + r] = c.shared[c.ir[ib + r] as usize];
                        }
                    }),
                )
            }
            Instr::StS { idx, src } => {
                let (i, s) = (idx as usize, src as usize);
                (
                    shared_bounds_guard!(i),
                    Box::new(move |c: &mut OpCtx| {
                        let (ib, sb) = (i * c.stride, s * c.stride);
                        for &r in c.rows {
                            c.shared[c.ir[ib + r] as usize] = c.fr[sb + r];
                        }
                    }),
                )
            }
            Instr::LdParamF(..) | Instr::LdParamI(..) => {
                unreachable!("scalar params resolved by pre-decode")
            }
            Instr::Bar | Instr::Bra(_) | Instr::BraIf(..) | Instr::BraIfZ(..) | Instr::Ret => {
                unreachable!("control flow is lowered to block terminators")
            }
        },
        VOp::MulAddF { dm, ma, mb, dd, aa, ab } => {
            let (dm, ma, mb) = (dm as usize, ma as usize, mb as usize);
            let (dd, aa, ab) = (dd as usize, aa as usize, ab as usize);
            (
                None,
                Box::new(move |c: &mut OpCtx| {
                    let s = c.stride;
                    let (dmb, mab, mbb) = (dm * s, ma * s, mb * s);
                    let (ddb, aab, abb) = (dd * s, aa * s, ab * s);
                    for &r in c.rows {
                        c.fr[dmb + r] = c.fr[mab + r] * c.fr[mbb + r];
                        c.fr[ddb + r] = c.fr[aab + r] + c.fr[abb + r];
                    }
                }),
            )
        }
        VOp::MulAddI { dm, ma, mb, dd, aa, ab } => {
            let (dm, ma, mb) = (dm as usize, ma as usize, mb as usize);
            let (dd, aa, ab) = (dd as usize, aa as usize, ab as usize);
            (
                None,
                Box::new(move |c: &mut OpCtx| {
                    let s = c.stride;
                    let (dmb, mab, mbb) = (dm * s, ma * s, mb * s);
                    let (ddb, aab, abb) = (dd * s, aa * s, ab * s);
                    for &r in c.rows {
                        c.ir[dmb + r] = c.ir[mab + r].wrapping_mul(c.ir[mbb + r]);
                        c.ir[ddb + r] = c.ir[aab + r].wrapping_add(c.ir[abb + r]);
                    }
                }),
            )
        }
        VOp::CvtMulAddF { df, si, dm, ma, mb, dd, aa, ab } => {
            let (df, si) = (df as usize, si as usize);
            let (dm, ma, mb) = (dm as usize, ma as usize, mb as usize);
            let (dd, aa, ab) = (dd as usize, aa as usize, ab as usize);
            (
                None,
                Box::new(move |c: &mut OpCtx| {
                    let s = c.stride;
                    let (dfb, sib) = (df * s, si * s);
                    let (dmb, mab, mbb) = (dm * s, ma * s, mb * s);
                    let (ddb, aab, abb) = (dd * s, aa * s, ab * s);
                    for &r in c.rows {
                        c.fr[dfb + r] = c.ir[sib + r] as f32;
                        c.fr[dmb + r] = c.fr[mab + r] * c.fr[mbb + r];
                        c.fr[ddb + r] = c.fr[aab + r] + c.fr[abb + r];
                    }
                }),
            )
        }
        VOp::GlobalIdX { tid, bid, bdim, mul, add } => {
            let (tb, bb, db) = (tid as usize, bid as usize, bdim as usize);
            let (md, ma, mb) = (mul.0 as usize, mul.1 as usize, mul.2 as usize);
            let (ad, aa, ab) = (add.0 as usize, add.1 as usize, add.2 as usize);
            (
                None,
                Box::new(move |c: &mut OpCtx| {
                    let s = c.stride;
                    let (tbb, bbb, dbb) = (tb * s, bb * s, db * s);
                    let (mdb, mab, mbb) = (md * s, ma * s, mb * s);
                    let (adb, aab, abb) = (ad * s, aa * s, ab * s);
                    let bx = c.bx;
                    let bidv = c.bx_i as i64;
                    let bdimv = c.bx as i64;
                    let lanes = c.lanes;
                    for (p, &r) in c.rows.iter().enumerate() {
                        c.ir[tbb + r] = ((lanes[p] as u32) % bx) as i64;
                        c.ir[bbb + r] = bidv;
                        c.ir[dbb + r] = bdimv;
                        c.ir[mdb + r] = c.ir[mab + r].wrapping_mul(c.ir[mbb + r]);
                        c.ir[adb + r] = c.ir[aab + r].wrapping_add(c.ir[abb + r]);
                    }
                }),
            )
        }
        VOp::RmwG { slot, idx, ld, op: fop, sa, sb, st } => {
            let (slot, i) = (slot as usize, idx as usize);
            let (ldr, sar, sbr, str_) = (ld as usize, sa as usize, sb as usize, st as usize);
            (
                // Bounds only: the entry guard already proved the budget
                // for the whole body, so the vector tier's interleaved
                // per-sub-instruction checks could never fire here.
                global_bounds_guard!(slot, i),
                Box::new(move |c: &mut OpCtx| {
                    let s = c.stride;
                    let (ib, ldb) = (i * s, ldr * s);
                    let (sab, sbb, stb) = (sar * s, sbr * s, str_ * s);
                    // Per-lane load → combine → store, in lane order —
                    // the exact side-effect order of the replayed
                    // sequence on the vector and scalar tiers.
                    for &r in c.rows {
                        let iu = c.ir[ib + r] as usize;
                        c.fr[ldb + r] = c.mem.load(slot, iu);
                        c.fr[stb + r] = binf_apply(fop, c.fr[sab + r], c.fr[sbb + r]);
                        c.mem.store(slot, iu, c.fr[stb + r]);
                    }
                }),
            )
        }
    };

    COp { guard, exec, weight, fused }
}
