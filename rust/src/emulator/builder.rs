//! Kernel-builder DSL: author VTX kernels from rust with typed register
//! handles and symbolic labels — the "high-level kernel language" of the
//! emulator path, playing the role Julia source plays for the PTX path.

use crate::emulator::isa::{
    CmpOp, FOp, IOp, Instr, Kernel, ParamKind, Pc, Reg, Special, UnFOp,
};
use crate::error::{Error, Result};

/// Typed float-register handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct F(pub Reg);

/// Typed integer-register handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct I(pub Reg);

/// Symbolic label, resolved when the kernel is built.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Label(usize);

pub struct KernelBuilder {
    name: String,
    params: Vec<ParamKind>,
    nf: u16,
    ni: u16,
    shared_f32: usize,
    code: Vec<Instr>,
    /// label -> bound pc (None until bound)
    labels: Vec<Option<Pc>>,
    /// (instruction index, label) patch sites
    patches: Vec<(usize, Label)>,
}

impl KernelBuilder {
    pub fn new(name: &str) -> Self {
        KernelBuilder {
            name: name.to_string(),
            params: Vec::new(),
            nf: 0,
            ni: 0,
            shared_f32: 0,
            code: Vec::new(),
            labels: Vec::new(),
            patches: Vec::new(),
        }
    }

    // ---- declarations ---------------------------------------------------

    /// Declare a pointer parameter (device f32 buffer). Order matters.
    pub fn ptr_param(&mut self) -> u8 {
        self.params.push(ParamKind::PtrF32);
        (self.params.len() - 1) as u8
    }

    pub fn f32_param(&mut self) -> u8 {
        self.params.push(ParamKind::F32);
        (self.params.len() - 1) as u8
    }

    pub fn i32_param(&mut self) -> u8 {
        self.params.push(ParamKind::I32);
        (self.params.len() - 1) as u8
    }

    pub fn shared(&mut self, f32_elems: usize) {
        self.shared_f32 = f32_elems;
    }

    /// Allocate a fresh float register.
    pub fn f(&mut self) -> F {
        let r = self.nf;
        self.nf += 1;
        F(r)
    }

    /// Allocate a fresh integer register.
    pub fn i(&mut self) -> I {
        let r = self.ni;
        self.ni += 1;
        I(r)
    }

    // ---- immediates & moves ----------------------------------------------

    pub fn constf(&mut self, v: f32) -> F {
        let d = self.f();
        self.code.push(Instr::ConstF(d.0, v));
        d
    }

    pub fn consti(&mut self, v: i64) -> I {
        let d = self.i();
        self.code.push(Instr::ConstI(d.0, v));
        d
    }

    pub fn movf(&mut self, dst: F, src: F) {
        self.code.push(Instr::MovF(dst.0, src.0));
    }

    pub fn movi(&mut self, dst: I, src: I) {
        self.code.push(Instr::MovI(dst.0, src.0));
    }

    pub fn setf(&mut self, dst: F, v: f32) {
        self.code.push(Instr::ConstF(dst.0, v));
    }

    pub fn seti(&mut self, dst: I, v: i64) {
        self.code.push(Instr::ConstI(dst.0, v));
    }

    // ---- arithmetic -------------------------------------------------------

    fn binf(&mut self, op: FOp, a: F, b: F) -> F {
        let d = self.f();
        self.code.push(Instr::BinF(op, d.0, a.0, b.0));
        d
    }

    pub fn fadd(&mut self, a: F, b: F) -> F {
        self.binf(FOp::Add, a, b)
    }
    pub fn fsub(&mut self, a: F, b: F) -> F {
        self.binf(FOp::Sub, a, b)
    }
    pub fn fmul(&mut self, a: F, b: F) -> F {
        self.binf(FOp::Mul, a, b)
    }
    pub fn fdiv(&mut self, a: F, b: F) -> F {
        self.binf(FOp::Div, a, b)
    }
    pub fn fmin(&mut self, a: F, b: F) -> F {
        self.binf(FOp::Min, a, b)
    }
    pub fn fmax(&mut self, a: F, b: F) -> F {
        self.binf(FOp::Max, a, b)
    }

    /// In-place accumulate: dst = dst + a.
    pub fn fadd_to(&mut self, dst: F, a: F) {
        self.code.push(Instr::BinF(FOp::Add, dst.0, dst.0, a.0));
    }

    pub fn fmax_to(&mut self, dst: F, a: F) {
        self.code.push(Instr::BinF(FOp::Max, dst.0, dst.0, a.0));
    }

    fn bini(&mut self, op: IOp, a: I, b: I) -> I {
        let d = self.i();
        self.code.push(Instr::BinI(op, d.0, a.0, b.0));
        d
    }

    pub fn iadd(&mut self, a: I, b: I) -> I {
        self.bini(IOp::Add, a, b)
    }
    pub fn isub(&mut self, a: I, b: I) -> I {
        self.bini(IOp::Sub, a, b)
    }
    pub fn imul(&mut self, a: I, b: I) -> I {
        self.bini(IOp::Mul, a, b)
    }
    pub fn idiv(&mut self, a: I, b: I) -> I {
        self.bini(IOp::Div, a, b)
    }
    pub fn irem(&mut self, a: I, b: I) -> I {
        self.bini(IOp::Rem, a, b)
    }

    /// In-place integer add (loop counters).
    pub fn iadd_to(&mut self, dst: I, a: I) {
        self.code.push(Instr::BinI(IOp::Add, dst.0, dst.0, a.0));
    }

    fn unf(&mut self, op: UnFOp, a: F) -> F {
        let d = self.f();
        self.code.push(Instr::UnF(op, d.0, a.0));
        d
    }

    pub fn fneg(&mut self, a: F) -> F {
        self.unf(UnFOp::Neg, a)
    }
    pub fn fabs(&mut self, a: F) -> F {
        self.unf(UnFOp::Abs, a)
    }
    pub fn fsqrt(&mut self, a: F) -> F {
        self.unf(UnFOp::Sqrt, a)
    }
    pub fn fsin(&mut self, a: F) -> F {
        self.unf(UnFOp::Sin, a)
    }
    pub fn fcos(&mut self, a: F) -> F {
        self.unf(UnFOp::Cos, a)
    }
    pub fn ffloor(&mut self, a: F) -> F {
        self.unf(UnFOp::Floor, a)
    }

    // ---- compare / select / convert ---------------------------------------

    pub fn cmpf(&mut self, op: CmpOp, a: F, b: F) -> I {
        let d = self.i();
        self.code.push(Instr::CmpF(op, d.0, a.0, b.0));
        d
    }

    pub fn cmpi(&mut self, op: CmpOp, a: I, b: I) -> I {
        let d = self.i();
        self.code.push(Instr::CmpI(op, d.0, a.0, b.0));
        d
    }

    pub fn self_f(&mut self, pred: I, a: F, b: F) -> F {
        let d = self.f();
        self.code.push(Instr::SelF(d.0, pred.0, a.0, b.0));
        d
    }

    pub fn cvt_f2i(&mut self, a: F) -> I {
        let d = self.i();
        self.code.push(Instr::CvtFI(d.0, a.0));
        d
    }

    pub fn cvt_i2f(&mut self, a: I) -> F {
        let d = self.f();
        self.code.push(Instr::CvtIF(d.0, a.0));
        d
    }

    // ---- special registers --------------------------------------------------

    fn special(&mut self, s: Special) -> I {
        let d = self.i();
        self.code.push(Instr::Spec(d.0, s));
        d
    }

    pub fn tid_x(&mut self) -> I {
        self.special(Special::ThreadIdX)
    }
    pub fn tid_y(&mut self) -> I {
        self.special(Special::ThreadIdY)
    }
    pub fn ctaid_x(&mut self) -> I {
        self.special(Special::BlockIdX)
    }
    pub fn ctaid_y(&mut self) -> I {
        self.special(Special::BlockIdY)
    }
    pub fn ntid_x(&mut self) -> I {
        self.special(Special::BlockDimX)
    }
    pub fn ntid_y(&mut self) -> I {
        self.special(Special::BlockDimY)
    }
    pub fn nctaid_x(&mut self) -> I {
        self.special(Special::GridDimX)
    }
    pub fn nctaid_y(&mut self) -> I {
        self.special(Special::GridDimY)
    }

    // ---- memory ---------------------------------------------------------------

    pub fn ldg(&mut self, param: u8, idx: I) -> F {
        let d = self.f();
        self.code.push(Instr::LdG { dst: d.0, param, idx: idx.0 });
        d
    }

    pub fn stg(&mut self, param: u8, idx: I, src: F) {
        self.code.push(Instr::StG { param, idx: idx.0, src: src.0 });
    }

    pub fn lds(&mut self, idx: I) -> F {
        let d = self.f();
        self.code.push(Instr::LdS { dst: d.0, idx: idx.0 });
        d
    }

    pub fn sts(&mut self, idx: I, src: F) {
        self.code.push(Instr::StS { idx: idx.0, src: src.0 });
    }

    pub fn ld_param_f(&mut self, param: u8) -> F {
        let d = self.f();
        self.code.push(Instr::LdParamF(d.0, param));
        d
    }

    pub fn ld_param_i(&mut self, param: u8) -> I {
        let d = self.i();
        self.code.push(Instr::LdParamI(d.0, param));
        d
    }

    // ---- control flow ------------------------------------------------------------

    pub fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() - 1)
    }

    /// Bind a label to the *next* emitted instruction.
    pub fn bind(&mut self, l: Label) {
        self.labels[l.0] = Some(self.code.len() as Pc);
    }

    pub fn bar(&mut self) {
        self.code.push(Instr::Bar);
    }

    pub fn bra(&mut self, l: Label) {
        self.patches.push((self.code.len(), l));
        self.code.push(Instr::Bra(0));
    }

    pub fn bra_if(&mut self, pred: I, l: Label) {
        self.patches.push((self.code.len(), l));
        self.code.push(Instr::BraIf(pred.0, 0));
    }

    pub fn bra_ifz(&mut self, pred: I, l: Label) {
        self.patches.push((self.code.len(), l));
        self.code.push(Instr::BraIfZ(pred.0, 0));
    }

    pub fn ret(&mut self) {
        self.code.push(Instr::Ret);
    }

    // ---- reduction primitive ---------------------------------------------

    /// Emit a barrier-synchronized **shared-memory tree reduction** over
    /// one or more regions of the block's shared array — the generic
    /// building block behind every reduction kernel (`tfunc_<t>`,
    /// `circus_all`, `features_all`, and whatever future workloads need
    /// a block-wide fold).
    ///
    /// Each `(base, op)` region is `block_h` consecutive f32 slots
    /// starting at `base`; `block_h` must be a power of two and every
    /// thread of the block must reach this code (the loop barriers are
    /// unconditional). All regions reduce in one strided loop, so a
    /// multi-functional kernel pays one barrier per stride instead of
    /// one per functional. On return `shared[base]` holds each region's
    /// fold; callers seed the slots with their operator's identity
    /// (0 for `Add`, `-inf` for `Max`) before the preceding barrier.
    pub fn reduce1d(&mut self, tid: I, block_h: usize, regions: &[(usize, FOp)]) {
        assert!(block_h.is_power_of_two(), "block_h must be a power of two");
        assert!(!regions.is_empty(), "reduce1d needs at least one region");
        let stride = self.consti((block_h / 2) as i64);
        let one = self.consti(1);
        let two = self.consti(2);
        let bases: Vec<I> = regions.iter().map(|&(base, _)| self.consti(base as i64)).collect();
        let top = self.label();
        let skip = self.label();
        let done = self.label();
        self.bind(top);
        let cont = self.cmpi(CmpOp::Ge, stride, one);
        self.bra_ifz(cont, done);
        let active = self.cmpi(CmpOp::Lt, tid, stride);
        self.bra_ifz(active, skip);
        for (base, &(_, op)) in bases.iter().zip(regions) {
            let li = self.iadd(*base, tid);
            let ri = self.iadd(li, stride);
            let lhs = self.lds(li);
            let rhs = self.lds(ri);
            let red = self.binf(op, lhs, rhs);
            self.sts(li, red);
        }
        self.bind(skip);
        self.bar();
        let halved = self.idiv(stride, two);
        self.movi(stride, halved);
        self.bra(top);
        self.bind(done);
    }

    // ---- finish ----------------------------------------------------------------------

    /// Resolve labels and validate — errors mirror a PTX JIT rejection.
    pub fn build(mut self) -> Result<Kernel> {
        for (at, label) in self.patches.drain(..) {
            let target = self.labels[label.0].ok_or_else(|| Error::VtxValidation {
                kernel: self.name.clone(),
                reason: format!("label {label:?} used but never bound"),
            })?;
            match &mut self.code[at] {
                Instr::Bra(t) | Instr::BraIf(_, t) | Instr::BraIfZ(_, t) => *t = target,
                other => {
                    return Err(Error::VtxValidation {
                        kernel: self.name.clone(),
                        reason: format!("patch site {at} is not a branch: {other:?}"),
                    })
                }
            }
        }
        let kernel = Kernel {
            name: self.name.clone(),
            params: self.params,
            fregs: self.nf.max(1),
            iregs: self.ni.max(1),
            shared_f32: self.shared_f32,
            code: self.code,
        };
        kernel.validate().map_err(|reason| Error::VtxValidation {
            kernel: self.name,
            reason,
        })?;
        Ok(kernel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_a_simple_loop_kernel() {
        // for (i = 0; i < 4; i++) acc += 1.0; out[tid] = acc
        let mut b = KernelBuilder::new("loop4");
        let out = b.ptr_param();
        let acc = b.constf(0.0);
        let one = b.constf(1.0);
        let i = b.consti(0);
        let four = b.consti(4);
        let inc = b.consti(1);
        let top = b.label();
        b.bind(top);
        b.fadd_to(acc, one);
        b.iadd_to(i, inc);
        let more = b.cmpi(CmpOp::Lt, i, four);
        b.bra_if(more, top);
        let tid = b.tid_x();
        b.stg(out, tid, acc);
        b.ret();
        let k = b.build().unwrap();
        assert_eq!(k.params.len(), 1);
        assert!(k.fregs >= 2 && k.iregs >= 4);
    }

    #[test]
    fn reduce1d_folds_multiple_regions_in_one_strided_loop() {
        use crate::emulator::interp::{execute, Launch, Limits};
        use crate::emulator::isa::FOp;
        // shared[tid] = in[tid] (sum region); shared[bh+tid] = in[tid]
        // (max region); one reduce1d call folds both; thread 0 writes
        // out = [Σ in, max in].
        let bh = 8usize;
        let mut b = KernelBuilder::new("fold2");
        let pin = b.ptr_param();
        let pout = b.ptr_param();
        b.shared(2 * bh);
        let tid = b.tid_x();
        let v = b.ldg(pin, tid);
        b.sts(tid, v);
        let bh_i = b.consti(bh as i64);
        let hi = b.iadd(tid, bh_i);
        b.sts(hi, v);
        b.bar();
        b.reduce1d(tid, bh, &[(0, FOp::Add), (bh, FOp::Max)]);
        let zero = b.consti(0);
        let is0 = b.cmpi(CmpOp::Eq, tid, zero);
        let end = b.label();
        b.bra_ifz(is0, end);
        let sum = b.lds(zero);
        b.stg(pout, zero, sum);
        let one = b.consti(1);
        let mx = b.lds(bh_i);
        b.stg(pout, one, mx);
        b.bind(end);
        b.ret();
        let k = b.build().unwrap();

        let mut input: Vec<f32> = vec![3.0, -1.0, 4.0, 1.0, -5.0, 9.0, 2.0, 6.0];
        let mut out = vec![0.0f32; 2];
        execute(Launch {
            kernel: &k,
            grid: (1, 1),
            block: (bh as u32, 1),
            buffers: vec![&mut input, &mut out],
            scalars: vec![],
            limits: Limits::default(),
        })
        .unwrap();
        assert_eq!(out[0], 19.0);
        assert_eq!(out[1], 9.0);
    }

    #[test]
    fn unbound_label_rejected() {
        let mut b = KernelBuilder::new("bad");
        let l = b.label();
        b.bra(l);
        // never bound
        let err = b.build().unwrap_err();
        assert!(err.to_string().contains("never bound"), "{err}");
    }

    #[test]
    fn forward_and_backward_branches_resolve() {
        let mut b = KernelBuilder::new("fwd");
        let skip = b.label();
        let c = b.consti(1);
        b.bra_if(c, skip);
        b.constf(99.0); // skipped
        b.bind(skip);
        b.ret();
        let k = b.build().unwrap();
        match k.code[1] {
            Instr::BraIf(_, t) => assert_eq!(t, 3),
            ref other => panic!("expected BraIf, got {other:?}"),
        }
    }
}
