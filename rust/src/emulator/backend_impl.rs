//! [`crate::driver::Backend`] implementation for the VTX emulator:
//! plugs interpreted kernels into the same driver API the PJRT backend
//! serves, mirroring how GPU Ocelot slots in under the CUDA driver API.
//!
//! Each [`VtxFunction`] carries a one-entry **decoded-kernel cache**
//! keyed by the launch's scalar arguments: the coordinator loads one
//! function handle per `Specialized` entry (whose scalars are fixed per
//! signature), so warm `cuda!` launches reuse the pre-decoded,
//! register-resolved instruction stream — which also carries its
//! basic-block, superinstruction-fused lowering
//! ([`DecodedKernel::lowered`]) — and pay neither binding nor lowering
//! work at all.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::driver::backend::{Backend, DeviceFunction, LoadedModule, ModuleSource};
use crate::driver::launch::{KernelArg, LaunchConfig, LaunchReport};
use crate::driver::memory::MemoryPool;
use crate::emulator::decode::{decode, DecodedKernel};
use crate::emulator::interp::{execute_decoded_on, Limits, ScalarArg};
use crate::emulator::isa::{Kernel, ParamKind};
use crate::emulator::sched::{default_workers, device_pool, WorkerPool};
use crate::error::{Error, Result};

/// The emulator backend. Stateless apart from the worker pool it
/// dispatches onto: each module owns its kernels, and the pool is the
/// per-device-ordinal pool (see [`device_pool`]) so launches on
/// different emulator devices never contend for the same worker queue.
pub struct VtxBackend {
    pool: &'static WorkerPool,
}

impl Default for VtxBackend {
    fn default() -> Self {
        VtxBackend::new()
    }
}

impl VtxBackend {
    /// A backend on the first emulator device's (global) worker pool.
    pub fn new() -> Self {
        VtxBackend { pool: WorkerPool::global() }
    }

    /// A backend dispatching onto the worker pool of the given device
    /// ordinal.
    pub fn for_device(ordinal: usize) -> Self {
        VtxBackend { pool: device_pool(ordinal) }
    }
}

impl Backend for VtxBackend {
    fn name(&self) -> &'static str {
        "vtx-emulator"
    }

    fn load_module(&self, source: &ModuleSource) -> Result<Arc<dyn LoadedModule>> {
        match source {
            ModuleSource::Vtx { kernels } => {
                // Module-load-time validation = the JIT step.
                let mut map = HashMap::new();
                for k in kernels {
                    k.validate().map_err(|reason| Error::VtxValidation {
                        kernel: k.name.clone(),
                        reason,
                    })?;
                    map.insert(k.name.clone(), Arc::new(k.clone()));
                }
                Ok(Arc::new(VtxModule { kernels: map, pool: self.pool }))
            }
            other => Err(Error::ModuleLoad {
                backend: "vtx-emulator".into(),
                reason: format!(
                    "emulator can only load VTX modules, got `{}`",
                    other.name()
                ),
            }),
        }
    }
}

pub struct VtxModule {
    kernels: HashMap<String, Arc<Kernel>>,
    pool: &'static WorkerPool,
}

impl LoadedModule for VtxModule {
    fn function(&self, name: &str) -> Result<Arc<dyn DeviceFunction>> {
        self.kernels
            .get(name)
            .map(|k| {
                Arc::new(VtxFunction {
                    kernel: k.clone(),
                    decoded: Mutex::new(None),
                    pool: self.pool,
                }) as Arc<dyn DeviceFunction>
            })
            .ok_or_else(|| Error::FunctionNotFound(name.to_string()))
    }

    fn function_names(&self) -> Vec<String> {
        self.kernels.keys().cloned().collect()
    }
}

pub struct VtxFunction {
    kernel: Arc<Kernel>,
    /// One-entry decode cache: (scalar binding, decoded + lowered form).
    /// The coordinator's warm path always hits it (fixed scalars per
    /// specialization); manual driver users hit it as long as their
    /// scalar arguments are stable. Hitting it skips decode *and* the
    /// basic-block/fusion lowering of the vector execution tier.
    decoded: Mutex<Option<(Vec<ScalarArg>, Arc<DecodedKernel>)>>,
    pool: &'static WorkerPool,
}

/// Bitwise scalar-binding equality: the cache must distinguish -0.0
/// from 0.0 (different constants baked into the decoded stream) and
/// must hit on NaN == NaN (plain `==` would re-decode forever).
fn scalars_bitwise_eq(a: &[ScalarArg], b: &[ScalarArg]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| match (x, y) {
            (ScalarArg::F32(p), ScalarArg::F32(q)) => p.to_bits() == q.to_bits(),
            (ScalarArg::I32(p), ScalarArg::I32(q)) => p == q,
            _ => false,
        })
}

impl VtxFunction {
    fn decoded_for(&self, scalars: &[ScalarArg]) -> Result<Arc<DecodedKernel>> {
        let mut cache = self.decoded.lock().unwrap();
        if let Some((cached_scalars, d)) = cache.as_ref() {
            if scalars_bitwise_eq(cached_scalars, scalars) {
                return Ok(d.clone());
            }
        }
        let d = Arc::new(decode(&self.kernel, scalars)?);
        *cache = Some((scalars.to_vec(), d.clone()));
        Ok(d)
    }
}

impl DeviceFunction for VtxFunction {
    /// Argument order must match the kernel's parameter declaration order:
    /// `Ptr` args bind to `PtrF32` params, scalar args to scalar params.
    fn launch(&self, cfg: &LaunchConfig, args: &[KernelArg], mem: &MemoryPool) -> Result<()> {
        self.launch_report(cfg, args, mem).map(|_| ())
    }

    fn launch_report(
        &self,
        cfg: &LaunchConfig,
        args: &[KernelArg],
        mem: &MemoryPool,
    ) -> Result<LaunchReport> {
        let k = &self.kernel;
        if args.len() != k.params.len() {
            return Err(Error::InvalidLaunch(format!(
                "kernel `{}` takes {} arguments, got {}",
                k.name,
                k.params.len(),
                args.len()
            )));
        }
        let mut ptrs = Vec::new();
        let mut scalars = Vec::new();
        for (i, (param, arg)) in k.params.iter().zip(args).enumerate() {
            match param {
                ParamKind::PtrF32 => ptrs.push(arg.as_ptr().map_err(|_| {
                    Error::BadArgument {
                        kernel: k.name.clone(),
                        index: i,
                        reason: "expected device pointer".into(),
                    }
                })?),
                ParamKind::F32 => scalars.push(ScalarArg::F32(arg.as_f32()?)),
                ParamKind::I32 => scalars.push(ScalarArg::I32(arg.as_i64()? as i32)),
            }
        }
        let decoded = self.decoded_for(&scalars)?;
        // Pull buffers out of the pool, reinterpret bytes as f32, run, put
        // them back — the emulator's "device-side" view of global memory.
        // On a trap the write-back is skipped, so device memory is
        // unchanged regardless of the schedule.
        let report = mem.with_buffers(&ptrs, |bufs| -> Result<LaunchReport> {
            let mut f32bufs: Vec<Vec<f32>> = bufs
                .iter()
                .map(|b| {
                    b.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect()
                })
                .collect();
            let report = {
                let views: Vec<&mut [f32]> =
                    f32bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
                execute_decoded_on(
                    &decoded,
                    (cfg.grid.x, cfg.grid.y),
                    (cfg.block.x, cfg.block.y),
                    views,
                    &Limits::default(),
                    default_workers(),
                    self.pool,
                )?
            };
            for (b, f) in bufs.iter_mut().zip(&f32bufs) {
                for (chunk, v) in b.chunks_exact_mut(4).zip(f) {
                    chunk.copy_from_slice(&v.to_le_bytes());
                }
            }
            Ok(report)
        })??;
        Ok(report)
    }

    fn name(&self) -> String {
        self.kernel.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::launch::LaunchConfig;
    use crate::emulator::builder::KernelBuilder;

    fn vadd_kernel() -> Kernel {
        let mut b = KernelBuilder::new("vadd");
        let pa = b.ptr_param();
        let pb = b.ptr_param();
        let pc = b.ptr_param();
        let tid = b.tid_x();
        let bid = b.ctaid_x();
        let bdim = b.ntid_x();
        let base = b.imul(bid, bdim);
        let gid = b.iadd(base, tid);
        let x = b.ldg(pa, gid);
        let y = b.ldg(pb, gid);
        let s = b.fadd(x, y);
        b.stg(pc, gid, s);
        b.ret();
        b.build().unwrap()
    }

    #[test]
    fn module_lifecycle_through_driver_traits() {
        let backend = VtxBackend::new();
        let module = backend
            .load_module(&ModuleSource::Vtx { kernels: vec![vadd_kernel()] })
            .unwrap();
        assert_eq!(module.function_names(), vec!["vadd".to_string()]);
        assert!(module.function("nope").is_err());

        let f = module.function("vadd").unwrap();
        let mem = MemoryPool::default();
        let bytes = |v: &[f32]| -> Vec<u8> {
            v.iter().flat_map(|x| x.to_le_bytes()).collect()
        };
        let a = mem.alloc(16).unwrap();
        mem.copy_h2d(a, &bytes(&[1., 2., 3., 4.])).unwrap();
        let b = mem.alloc(16).unwrap();
        mem.copy_h2d(b, &bytes(&[5., 5., 5., 5.])).unwrap();
        let c = mem.alloc(16).unwrap();
        f.launch(
            &LaunchConfig::new(1u32, 4u32),
            &[KernelArg::Ptr(a), KernelArg::Ptr(b), KernelArg::Ptr(c)],
            &mem,
        )
        .unwrap();
        let mut out = vec![0u8; 16];
        mem.copy_d2h(c, &mut out).unwrap();
        let vals: Vec<f32> = out
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(vals, vec![6., 7., 8., 9.]);
    }

    #[test]
    fn launch_report_counts_blocks() {
        let backend = VtxBackend::new();
        let module = backend
            .load_module(&ModuleSource::Vtx { kernels: vec![vadd_kernel()] })
            .unwrap();
        let f = module.function("vadd").unwrap();
        let mem = MemoryPool::default();
        let n = 256usize;
        let bytes = vec![0u8; n * 4];
        let a = mem.alloc(n * 4).unwrap();
        mem.copy_h2d(a, &bytes).unwrap();
        let b = mem.alloc(n * 4).unwrap();
        mem.copy_h2d(b, &bytes).unwrap();
        let c = mem.alloc(n * 4).unwrap();
        let report = f
            .launch_report(
                &LaunchConfig::new((n / 32) as u32, 32u32),
                &[KernelArg::Ptr(a), KernelArg::Ptr(b), KernelArg::Ptr(c)],
                &mem,
            )
            .unwrap();
        assert_eq!(report.blocks, (n / 32) as u64);
        assert!(report.workers >= 1);
        assert!(report.wall_ns > 0);
    }

    #[test]
    fn wrong_arg_count_rejected() {
        let backend = VtxBackend::new();
        let module = backend
            .load_module(&ModuleSource::Vtx { kernels: vec![vadd_kernel()] })
            .unwrap();
        let f = module.function("vadd").unwrap();
        let mem = MemoryPool::default();
        let err = f
            .launch(&LaunchConfig::new(1u32, 1u32), &[], &mem)
            .unwrap_err();
        assert!(err.to_string().contains("takes 3 arguments"));
    }

    #[test]
    fn hlo_source_rejected() {
        let backend = VtxBackend::new();
        let err = backend
            .load_module(&ModuleSource::HloText {
                name: "x".into(),
                text: "".into(),
                inputs: vec![],
                outputs: vec![],
            })
            .err()
            .expect("HLO source must be rejected by the emulator");
        assert!(err.to_string().contains("VTX"));
    }
}
