//! The emulator's **fixed worker-thread pool** and scheduling
//! configuration.
//!
//! Thread blocks of a VTX grid are independent (the CUDA contract), so
//! the interpreter dispatches them across this pool — one detached,
//! process-global set of `hlgpu-vtx-N` threads that every launch reuses.
//! Launch submission is a latch-counted batch of jobs; the submitting
//! thread blocks until its batch drains, so a launch stays synchronous at
//! the driver level while streams provide host-side asynchrony above it.
//!
//! Configuration:
//! * `HLGPU_WORKERS` — environment override for the default schedule
//!   width (`1` forces the sequential schedule);
//! * [`set_default_workers`] — process-wide programmatic override, used
//!   by benches to A/B the schedules;
//! * otherwise the width is `std::thread::available_parallelism()`.
//!
//! The same precedent applies to the **execution tier** inside a block:
//! * `HLGPU_EXEC` — `scalar` (the reference interpreter, one dispatch
//!   per instruction per thread), `vector` (the warp-vectorized tier
//!   over the lowered basic-block form; the default) or `compiled`
//!   (the closure-JIT tier: hot blocks compile to straight-line closure
//!   chains and deopt to the vector tier on any guard failure);
//! * `HLGPU_TIER_UP` — how many times a basic block must execute before
//!   the compiled tier JITs it (`0` = always-compile);
//! * [`set_default_exec`] / [`set_default_tier_up`] — process-wide
//!   programmatic overrides, used by benches to A/B the tiers. All
//!   tiers are observationally identical for race-free kernels (see
//!   `docs/emulator.md`). Unknown `HLGPU_EXEC` / `HLGPU_TIER_UP` values
//!   are a typed [`Error::BadArgument`] at the first launch that reads
//!   them, never a silent fallback.
//!
//! The pool itself is provisioned with `max(width, 8)` threads so
//! explicit widths up to 8 (the determinism property tests exercise 1, 2
//! and 8) get real concurrency even when the default width is smaller.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::error::{Error, Result};

/// A unit of work: one scheduler job (a slice of a launch's blocks).
pub(crate) type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
}

/// Fixed pool of worker threads executing submitted jobs FIFO.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    size: usize,
}

impl WorkerPool {
    fn new(size: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
        });
        for i in 0..size {
            let sh = shared.clone();
            std::thread::Builder::new()
                .name(format!("hlgpu-vtx-{i}"))
                .spawn(move || worker_loop(sh))
                .expect("failed to spawn VTX worker thread");
            // handle intentionally detached: the pool lives for the whole
            // process, workers park on the queue condvar when idle.
        }
        WorkerPool { shared, size }
    }

    /// The process-global pool, created on first use.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| WorkerPool::new(pool_threads()))
    }

    /// The worker pool for an emulator device ordinal. The first
    /// emulator device (ordinal 1, and ordinal 0 for safety) maps to
    /// the process-global pool; every other ordinal gets its own pool,
    /// created on first use and leaked — device pools, like the global
    /// one, live for the whole process. This is what makes
    /// `HLGPU_DEVICES=N` emulator devices *independent*: blocks
    /// launched on different devices never contend for the same worker
    /// queue.
    pub fn device_pool(ordinal: usize) -> &'static WorkerPool {
        if ordinal <= 1 {
            return WorkerPool::global();
        }
        static POOLS: OnceLock<Mutex<std::collections::HashMap<usize, &'static WorkerPool>>> =
            OnceLock::new();
        let mut pools = POOLS.get_or_init(|| Mutex::new(Default::default())).lock().unwrap();
        *pools
            .entry(ordinal)
            .or_insert_with(|| Box::leak(Box::new(WorkerPool::new(pool_threads()))))
    }

    /// Number of threads in the pool.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Enqueue a job. Never blocks; jobs run FIFO as workers free up.
    pub(crate) fn submit(&self, job: Job) {
        self.shared.queue.lock().unwrap().push_back(job);
        self.shared.cv.notify_one();
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        // A panicking job must not take the worker down (the submitting
        // launch observes the panic through its latch guard).
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
    }
}

/// Completion latch for one launch's batch of jobs. Arrival happens in a
/// drop guard on the worker, so panicking jobs still release the
/// submitter (and are reported).
pub(crate) struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panicked: bool,
}

impl Latch {
    pub(crate) fn new(count: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining: count, panicked: false }),
            cv: Condvar::new(),
        }
    }

    fn arrive(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        st.panicked |= panicked;
        if st.remaining == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every job has arrived; returns true if any panicked.
    pub(crate) fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.panicked
    }
}

/// Arrival guard: counts down the latch when the job's scope ends, even
/// by unwinding.
pub(crate) struct ArriveGuard<'a>(pub(crate) &'a Latch);

impl Drop for ArriveGuard<'_> {
    fn drop(&mut self) {
        self.0.arrive(std::thread::panicking());
    }
}

// ---- schedule-width configuration ---------------------------------------

/// Programmatic override (0 = unset). Takes precedence over the
/// environment.
static WORKER_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the default schedule width for subsequent launches
/// (process-wide). Pass `None` to clear; `Some(1)` forces the sequential
/// schedule. Benches use this to A/B sequential vs parallel execution.
pub fn set_default_workers(workers: Option<usize>) {
    WORKER_OVERRIDE.store(workers.unwrap_or(0), Ordering::Relaxed);
}

fn hardware_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// The schedule width used by launches that do not specify one:
/// the [`set_default_workers`] override, else `HLGPU_WORKERS`, else the
/// machine's available parallelism.
pub fn default_workers() -> usize {
    let o = WORKER_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    if let Ok(v) = std::env::var("HLGPU_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    hardware_parallelism()
}

/// Threads to provision in the global pool: the machine's parallelism
/// and any `HLGPU_WORKERS` request, floored at 8 so explicit schedule
/// widths up to 8 get distinct threads on small machines. Deliberately
/// ignores [`set_default_workers`] — that override narrows a schedule
/// for A/B runs and must not freeze a small pool at first use. Launches
/// clamp their reported width to the pool size, so `LaunchReport` never
/// claims more concurrency than actually existed.
fn pool_threads() -> usize {
    let env = std::env::var("HLGPU_WORKERS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(0);
    hardware_parallelism().max(env).max(8)
}

// ---- execution-tier configuration ---------------------------------------

/// Execution tier of the per-block interpreter (see
/// [`crate::emulator::interp`] and [`crate::emulator::vector`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecTier {
    /// Reference semantics: one dispatch per instruction per thread over
    /// the pre-decoded instruction stream.
    Scalar,
    /// Warp-vectorized: one dispatch per instruction across all active
    /// threads of the block, over the lowered basic-block form with
    /// fused superinstructions. Observationally identical to `Scalar`
    /// for race-free kernels.
    Vector,
    /// Closure-JIT: basic blocks that execute more than the tier-up
    /// threshold compile into straight-line chains of pre-resolved
    /// closures (no per-op dispatch); any guard failure (bounds, budget,
    /// division by zero) deopts back to the vector tier at the exact
    /// instruction, so traps and results stay bitwise identical to
    /// `Scalar`.
    Compiled,
}

impl ExecTier {
    /// Parse an `HLGPU_EXEC` value; unknown values select no tier.
    pub fn parse(v: &str) -> Option<ExecTier> {
        match v.trim().to_ascii_lowercase().as_str() {
            "scalar" | "interp" | "reference" => Some(ExecTier::Scalar),
            "vector" | "warp" | "simd" => Some(ExecTier::Vector),
            "compiled" | "jit" | "regions" => Some(ExecTier::Compiled),
            _ => None,
        }
    }
}

/// Parse an `HLGPU_EXEC` value into a tier, or a typed rejection that
/// names the bad value and the accepted spellings.
fn parse_exec_checked(v: &str) -> Result<ExecTier> {
    ExecTier::parse(v).ok_or_else(|| Error::BadArgument {
        kernel: "HLGPU_EXEC".into(),
        index: 0,
        reason: format!(
            "unknown execution tier `{}` (expected `scalar`, `vector` or `compiled`)",
            v.trim()
        ),
    })
}

/// Programmatic tier override (0 = unset, 1 = scalar, 2 = vector,
/// 3 = compiled). Takes precedence over the environment, like
/// [`set_default_workers`].
static EXEC_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the execution tier for subsequent launches (process-wide).
/// Pass `None` to clear. Benches use this to A/B the tiers.
pub fn set_default_exec(tier: Option<ExecTier>) {
    EXEC_OVERRIDE.store(
        match tier {
            None => 0,
            Some(ExecTier::Scalar) => 1,
            Some(ExecTier::Vector) => 2,
            Some(ExecTier::Compiled) => 3,
        },
        Ordering::Relaxed,
    );
}

/// The tier used by launches that do not specify one: the
/// [`set_default_exec`] override, else `HLGPU_EXEC`, else the vector
/// tier (the fast path; `scalar` selects the reference interpreter and
/// `compiled` the closure-JIT). An unrecognized `HLGPU_EXEC` value is a
/// typed [`Error::BadArgument`]; launch entry points call this so the
/// rejection surfaces at first use instead of silently running the
/// default tier.
pub fn default_exec_checked() -> Result<ExecTier> {
    match EXEC_OVERRIDE.load(Ordering::Relaxed) {
        1 => return Ok(ExecTier::Scalar),
        2 => return Ok(ExecTier::Vector),
        3 => return Ok(ExecTier::Compiled),
        _ => {}
    }
    if let Ok(v) = std::env::var("HLGPU_EXEC") {
        return parse_exec_checked(&v);
    }
    Ok(ExecTier::Vector)
}

/// Infallible flavor of [`default_exec_checked`] for display paths
/// (benches, stats lines) that must not error: an invalid `HLGPU_EXEC`
/// reads as the vector default here but still fails the actual launch.
pub fn default_exec() -> ExecTier {
    default_exec_checked().unwrap_or(ExecTier::Vector)
}

// ---- compiled-tier tier-up threshold -------------------------------------

/// Blocks must execute this many times before the compiled tier JITs
/// them, absent an `HLGPU_TIER_UP` / [`set_default_tier_up`] request.
/// Small enough that steady-state loops tier up within the first launch,
/// large enough that one-shot cold blocks never pay compilation.
pub const DEFAULT_TIER_UP: u64 = 8;

/// Programmatic tier-up override. `u64::MAX` = unset (a threshold of 0
/// is meaningful: always-compile).
static TIER_UP_OVERRIDE: AtomicU64 = AtomicU64::new(u64::MAX);

/// Override the compiled tier's tier-up threshold for subsequent
/// launches (process-wide). Pass `None` to clear, `Some(0)` to force
/// compilation on first execution.
pub fn set_default_tier_up(threshold: Option<u64>) {
    TIER_UP_OVERRIDE.store(threshold.unwrap_or(u64::MAX), Ordering::Relaxed);
}

/// Parse an `HLGPU_TIER_UP` value, or a typed rejection naming the bad
/// value.
fn parse_tier_up_checked(v: &str) -> Result<u64> {
    v.trim().parse::<u64>().map_err(|_| Error::BadArgument {
        kernel: "HLGPU_TIER_UP".into(),
        index: 0,
        reason: format!(
            "invalid tier-up threshold `{}` (expected a non-negative integer; 0 = always-compile)",
            v.trim()
        ),
    })
}

/// The tier-up threshold used by compiled-tier launches: the
/// [`set_default_tier_up`] override, else `HLGPU_TIER_UP`, else
/// [`DEFAULT_TIER_UP`]. Like [`default_exec_checked`], a malformed
/// environment value is a typed [`Error::BadArgument`] at first use.
pub fn default_tier_up_checked() -> Result<u64> {
    let o = TIER_UP_OVERRIDE.load(Ordering::Relaxed);
    if o != u64::MAX {
        return Ok(o);
    }
    if let Ok(v) = std::env::var("HLGPU_TIER_UP") {
        return parse_tier_up_checked(&v);
    }
    Ok(DEFAULT_TIER_UP)
}

/// Infallible flavor of [`default_tier_up_checked`] for display paths.
pub fn default_tier_up() -> u64 {
    default_tier_up_checked().unwrap_or(DEFAULT_TIER_UP)
}

/// Serializes tests that flip the process-wide tier override (flipping
/// is observationally harmless for other launches — both tiers are
/// identical — but tests asserting on the override itself must not
/// interleave).
#[cfg(test)]
pub(crate) fn exec_override_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn pool_runs_jobs_and_latch_joins() {
        let pool = WorkerPool::global();
        assert!(pool.size() >= 8);
        let counter = Arc::new(AtomicU32::new(0));
        let latch = Arc::new(Latch::new(16));
        for _ in 0..16 {
            let c = counter.clone();
            let l = latch.clone();
            pool.submit(Box::new(move || {
                let _g = ArriveGuard(&l);
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        assert!(!latch.wait());
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn panicking_job_reported_not_fatal() {
        let pool = WorkerPool::global();
        let latch = Arc::new(Latch::new(1));
        {
            let l = latch.clone();
            pool.submit(Box::new(move || {
                let _g = ArriveGuard(&l);
                panic!("job panic (expected by test)");
            }));
        }
        assert!(latch.wait(), "panic must be observable");
        // the pool still works afterwards
        let latch2 = Arc::new(Latch::new(1));
        {
            let l = latch2.clone();
            pool.submit(Box::new(move || {
                let _g = ArriveGuard(&l);
            }));
        }
        assert!(!latch2.wait());
    }

    #[test]
    fn device_pools_are_distinct_and_stable() {
        // Ordinals 0 and 1 alias the global pool; higher ordinals get
        // their own pool, and repeated lookups return the same one.
        assert!(std::ptr::eq(device_pool(0), WorkerPool::global()));
        assert!(std::ptr::eq(device_pool(1), WorkerPool::global()));
        let p2 = device_pool(2);
        let p3 = device_pool(3);
        assert!(!std::ptr::eq(p2, WorkerPool::global()));
        assert!(!std::ptr::eq(p2, p3));
        assert!(std::ptr::eq(p2, device_pool(2)));
        // A per-device pool runs jobs like the global one.
        let counter = Arc::new(AtomicU32::new(0));
        let latch = Arc::new(Latch::new(4));
        for _ in 0..4 {
            let c = counter.clone();
            let l = latch.clone();
            p2.submit(Box::new(move || {
                let _g = ArriveGuard(&l);
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        assert!(!latch.wait());
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn override_beats_env_and_hardware() {
        set_default_workers(Some(3));
        assert_eq!(default_workers(), 3);
        set_default_workers(None);
        assert!(default_workers() >= 1);
    }

    #[test]
    fn exec_tier_parsing() {
        assert_eq!(ExecTier::parse("scalar"), Some(ExecTier::Scalar));
        assert_eq!(ExecTier::parse("SCALAR"), Some(ExecTier::Scalar));
        assert_eq!(ExecTier::parse("vector"), Some(ExecTier::Vector));
        assert_eq!(ExecTier::parse("warp"), Some(ExecTier::Vector));
        assert_eq!(ExecTier::parse("compiled"), Some(ExecTier::Compiled));
        assert_eq!(ExecTier::parse("JIT"), Some(ExecTier::Compiled));
        assert_eq!(ExecTier::parse("nonsense"), None);
    }

    #[test]
    fn unknown_exec_tier_is_typed_and_names_the_value() {
        let e = parse_exec_checked("turbo").unwrap_err();
        match &e {
            Error::BadArgument { kernel, reason, .. } => {
                assert_eq!(kernel, "HLGPU_EXEC");
                assert!(reason.contains("`turbo`"), "reason must name the bad value: {reason}");
                assert!(reason.contains("compiled"), "reason lists accepted tiers: {reason}");
            }
            other => panic!("expected BadArgument, got {other:?}"),
        }
        // The Display form (what users see) also names the knob and value.
        let msg = e.to_string();
        assert!(msg.contains("HLGPU_EXEC") && msg.contains("turbo"), "{msg}");
    }

    #[test]
    fn bad_tier_up_threshold_is_typed_and_names_the_value() {
        assert_eq!(parse_tier_up_checked("0").unwrap(), 0);
        assert_eq!(parse_tier_up_checked(" 12 ").unwrap(), 12);
        let e = parse_tier_up_checked("-3").unwrap_err();
        match &e {
            Error::BadArgument { kernel, reason, .. } => {
                assert_eq!(kernel, "HLGPU_TIER_UP");
                assert!(reason.contains("`-3`"), "reason must name the bad value: {reason}");
            }
            other => panic!("expected BadArgument, got {other:?}"),
        }
    }

    #[test]
    fn tier_up_override_beats_env_and_default() {
        let _g = exec_override_test_lock();
        set_default_tier_up(Some(0));
        assert_eq!(default_tier_up_checked().unwrap(), 0);
        set_default_tier_up(Some(100));
        assert_eq!(default_tier_up_checked().unwrap(), 100);
        set_default_tier_up(None);
        let _ = default_tier_up(); // env- or default-driven either way
    }

    #[test]
    fn exec_override_beats_env() {
        // Both tiers are observationally identical, so flipping the
        // process-wide default mid-test-run is harmless for concurrent
        // launches (mirrors override_beats_env_and_hardware).
        let _g = exec_override_test_lock();
        set_default_exec(Some(ExecTier::Scalar));
        assert_eq!(default_exec(), ExecTier::Scalar);
        set_default_exec(Some(ExecTier::Vector));
        assert_eq!(default_exec(), ExecTier::Vector);
        set_default_exec(Some(ExecTier::Compiled));
        assert_eq!(default_exec(), ExecTier::Compiled);
        set_default_exec(None);
        let _ = default_exec(); // env- or default-driven either way
    }
}
