//! Basic-block lowering and superinstruction fusion: the middle stage of
//! the emulator's tiered execution pipeline
//! (`decode` → **`lower` → fuse** → `interp`/`vector`).
//!
//! The paper's thesis is that code generation — not interpretation of
//! high-level abstractions — removes run-time overhead. The emulator
//! cannot JIT to native code, but it can do the next-best thing: rewrite
//! the decoded instruction stream **once** per specialization into a
//! form with radically cheaper steady-state dispatch:
//!
//! * the flat stream becomes a **basic-block CFG** — branch targets are
//!   resolved to block ids and every block body is a straight-line run,
//!   so the vector tier schedules whole blocks instead of single
//!   instructions and pays one reconvergence decision per block;
//! * a **fusion pass** collapses common dataflow chains into
//!   superinstructions (see [`VOp`]), so one dispatch retires several
//!   ISA instructions.
//!
//! # Fusion invariants
//!
//! Every superinstruction **replays the exact original instruction
//! sequence** per thread — same operand registers, same operand order,
//! same intermediate register writes. Nothing is dead-code-eliminated:
//! an intermediate register may be read by a later (even
//! cross-block) instruction, so it is always written. This makes fusion
//! bitwise-transparent by construction: float operand order is
//! preserved (f32 ops are not reassociated or commuted), integer ops
//! keep their wrapping semantics, and the only elided work is the
//! *dispatch* itself — plus one bounds check in [`VOp::RmwG`], which is
//! sound because the load and store use the same buffer slot and the
//! same index register, and the intervening float op cannot modify an
//! integer register or a buffer length.
//!
//! Step accounting is preserved through [`VOp::weight`]: a fused op
//! charges as many steps as the instructions it replays, so the
//! step-budget trap fires with the same coordinates and reason under
//! every tier.

use crate::emulator::isa::{CmpOp, FOp, IOp, Instr, Pc, Reg, Special};

/// One vector-tier operation: either a single non-control ISA
/// instruction, or a fused superinstruction replaying a short dataflow
/// chain. Control flow never appears here — it lives in [`Term`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum VOp {
    /// Unfused ISA instruction (never a branch, `Bar` or `Ret`).
    Base(Instr),
    /// `BinF(Mul, dm, ma, mb); BinF(Add, dd, aa, ab)` where the product
    /// feeds the add — the affine chains of the sinogram kernels.
    MulAddF { dm: Reg, ma: Reg, mb: Reg, dd: Reg, aa: Reg, ab: Reg },
    /// `BinI(Mul, dm, ma, mb); BinI(Add, dd, aa, ab)` where the product
    /// feeds the add — index arithmetic (`row*stride + col`).
    MulAddI { dm: Reg, ma: Reg, mb: Reg, dd: Reg, aa: Reg, ab: Reg },
    /// `CvtIF(df, si); BinF(Mul, dm, ma, mb); BinF(Add, dd, aa, ab)`
    /// where the converted value feeds the multiply and the product
    /// feeds the add — the fused affine of an integer index.
    CvtMulAddF {
        df: Reg,
        si: Reg,
        dm: Reg,
        ma: Reg,
        mb: Reg,
        dd: Reg,
        aa: Reg,
        ab: Reg,
    },
    /// The canonical global-thread-id prologue:
    /// `Spec(tid, ThreadIdX); Spec(bid, BlockIdX); Spec(bdim, BlockDimX);
    /// BinI(Mul, mul.0, mul.1, mul.2); BinI(Add, add.0, add.1, add.2)`
    /// where the multiply combines `bid`/`bdim` and the add combines the
    /// product with `tid`.
    GlobalIdX {
        tid: Reg,
        bid: Reg,
        bdim: Reg,
        mul: (Reg, Reg, Reg),
        add: (Reg, Reg, Reg),
    },
    /// `LdG {ld, slot, idx}; BinF(op, st, sa, sb); StG {slot, idx, st}`
    /// — a global read-modify-write on one element, executed with a
    /// single bounds check (sound: same slot, same index register, and
    /// the float op cannot change either).
    RmwG {
        slot: u8,
        idx: Reg,
        ld: Reg,
        op: FOp,
        sa: Reg,
        sb: Reg,
        st: Reg,
    },
}

impl VOp {
    /// ISA instructions this op retires (= steps it charges against the
    /// per-thread budget, preserving step-budget trap parity).
    pub fn weight(&self) -> u64 {
        match self {
            VOp::Base(_) => 1,
            VOp::MulAddF { .. } | VOp::MulAddI { .. } => 2,
            VOp::CvtMulAddF { .. } | VOp::RmwG { .. } => 3,
            VOp::GlobalIdX { .. } => 5,
        }
    }

    /// True for superinstructions (anything but `Base`).
    pub fn is_fused(&self) -> bool {
        !matches!(self, VOp::Base(_))
    }
}

/// Basic-block terminator. Step weights mirror the original stream: an
/// explicit `Bra`/`BraIf`/`Bar`/`Ret` costs one step; the synthetic jump
/// of a fallthrough into a branch target costs zero.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Term {
    /// Unconditional continuation. `steps` is 1 for an explicit `Bra`
    /// and 0 for a synthetic fallthrough edge.
    Jump { target: u32, steps: u8 },
    /// Conditional: `pred != 0` goes to `nz`, else to `z` (covers both
    /// `BraIf` and `BraIfZ`). Always one step.
    Branch { pred: Reg, nz: u32, z: u32 },
    /// Block-wide barrier; released threads continue at `next`.
    Bar { next: u32 },
    /// Thread exit.
    Ret,
    /// Fused loop-counter back-edge:
    /// `BinI(Add, ad, aa, ab); CmpI(op, pred, ca, cb); BraIf(pred, nz)`
    /// where the compare reads the add's destination and `nz` is a
    /// backward edge — the `i += step; if i < n goto top` epilogue of
    /// every marching kernel loop, retired in ONE dispatch per
    /// iteration. Replays the exact original sequence (the counter and
    /// predicate registers are written), weight 3, and none of the three
    /// replayed instructions can trap, so whole-weight budget charging
    /// keeps trap parity with the scalar tier.
    LoopBack {
        add: (Reg, Reg, Reg),
        cmp_op: CmpOp,
        pred: Reg,
        cmp: (Reg, Reg),
        nz: u32,
        z: u32,
    },
}

/// One basic block: a straight-line run of (possibly fused) operations
/// and a terminator. Blocks are numbered in original-pc order, so the
/// lowest block id among divergent threads is also the lowest original
/// pc — the vector tier's reconvergence heuristic relies on this.
#[derive(Clone, Debug)]
pub struct Block {
    /// Original pc of the block's first instruction (diagnostics).
    pub start_pc: Pc,
    pub ops: Vec<VOp>,
    pub term: Term,
}

/// A lowered kernel: the basic-block CFG with fused superinstructions.
/// Built once per (kernel, scalar binding) by [`lower`] and cached on
/// [`crate::emulator::decode::DecodedKernel`], so warm launches skip
/// lowering entirely.
#[derive(Clone, Debug)]
pub struct LoweredKernel {
    pub blocks: Vec<Block>,
    /// ISA instructions lowered (== the decoded stream's length).
    pub instr_count: usize,
    /// ISA instructions covered by superinstructions (static count).
    pub fused_instrs: usize,
    /// Superinstructions emitted (static count).
    pub fused_ops: usize,
}

/// Lower a decoded instruction stream into its basic-block form and run
/// the fusion pass. The stream must come from a validated kernel (every
/// branch target in range, control flow never in final position except
/// `Ret`/`Bra`) — crate-private because only
/// [`crate::emulator::decode::decode`], which works on validated
/// kernels, may call it.
pub(crate) fn lower(code: &[Instr]) -> LoweredKernel {
    let n = code.len();
    if n == 0 {
        // Validation rejects empty kernels; lowering one is still total.
        return LoweredKernel { blocks: Vec::new(), instr_count: 0, fused_instrs: 0, fused_ops: 0 };
    }

    // 1. Leaders: pc 0, every branch target, and every pc following a
    //    control instruction (branch, barrier, return).
    let mut is_leader = vec![false; n];
    is_leader[0] = true;
    for (pc, ins) in code.iter().enumerate() {
        match *ins {
            Instr::Bra(t) => {
                is_leader[t as usize] = true;
                if pc + 1 < n {
                    is_leader[pc + 1] = true;
                }
            }
            Instr::BraIf(_, t) | Instr::BraIfZ(_, t) => {
                is_leader[t as usize] = true;
                is_leader[pc + 1] = true;
            }
            Instr::Ret | Instr::Bar => {
                if pc + 1 < n {
                    is_leader[pc + 1] = true;
                }
            }
            _ => {}
        }
    }

    // 2. pc -> block id (blocks numbered in pc order).
    let mut block_of = vec![0u32; n];
    let mut nblocks = 0u32;
    for pc in 0..n {
        if is_leader[pc] {
            nblocks += 1;
        }
        block_of[pc] = nblocks - 1;
    }

    // 3. Split, resolve targets to block ids, fuse block bodies.
    let mut blocks = Vec::with_capacity(nblocks as usize);
    let mut fused_instrs = 0usize;
    let mut fused_ops = 0usize;
    let mut pc = 0usize;
    while pc < n {
        let start = pc;
        let mut body: Vec<Instr> = Vec::new();
        let term = loop {
            match code[pc] {
                Instr::Bra(t) => {
                    pc += 1;
                    break Term::Jump { target: block_of[t as usize], steps: 1 };
                }
                Instr::BraIf(p, t) => {
                    pc += 1;
                    break Term::Branch {
                        pred: p,
                        nz: block_of[t as usize],
                        z: block_of[pc],
                    };
                }
                Instr::BraIfZ(p, t) => {
                    pc += 1;
                    break Term::Branch {
                        pred: p,
                        nz: block_of[pc],
                        z: block_of[t as usize],
                    };
                }
                Instr::Ret => {
                    pc += 1;
                    break Term::Ret;
                }
                Instr::Bar => {
                    pc += 1;
                    break Term::Bar { next: block_of[pc] };
                }
                other => {
                    body.push(other);
                    pc += 1;
                    if pc >= n {
                        // Unreachable for validated kernels (they end in
                        // Ret/Bra); terminate defensively.
                        break Term::Ret;
                    }
                    if is_leader[pc] {
                        // Fallthrough into a branch target: synthetic
                        // zero-step edge.
                        break Term::Jump { target: block_of[pc], steps: 0 };
                    }
                }
            }
        };
        // Terminator fusion: the canonical loop-counter epilogue
        // (`IAdd; CmpI; BraIf` back-edge) collapses into one fused
        // terminator before the body's peephole pass runs, so the two
        // counter instructions are not considered for body patterns.
        let mut body = body;
        let mut term = term;
        if let Term::Branch { pred, nz, z } = term {
            let id = blocks.len() as u32;
            if nz <= id && body.len() >= 2 {
                if let (Instr::BinI(IOp::Add, ad, aa, ab), Instr::CmpI(op, cd, ca, cb)) =
                    (body[body.len() - 2], body[body.len() - 1])
                {
                    if cd == pred && (ca == ad || cb == ad) {
                        body.truncate(body.len() - 2);
                        term = Term::LoopBack {
                            add: (ad, aa, ab),
                            cmp_op: op,
                            pred,
                            cmp: (ca, cb),
                            nz,
                            z,
                        };
                        fused_instrs += 3;
                        fused_ops += 1;
                    }
                }
            }
        }
        blocks.push(Block {
            start_pc: start as Pc,
            ops: fuse(&body, &mut fused_instrs, &mut fused_ops),
            term,
        });
    }

    LoweredKernel { blocks, instr_count: n, fused_instrs, fused_ops }
}

/// Greedy peephole fusion over one straight-line block body: longest
/// pattern first at each position, falling back to the bare instruction.
fn fuse(body: &[Instr], fused_instrs: &mut usize, fused_ops: &mut usize) -> Vec<VOp> {
    let mut ops = Vec::with_capacity(body.len());
    let mut i = 0usize;
    while i < body.len() {
        let (op, len) = match_at(body, i);
        if len > 1 {
            *fused_instrs += len;
            *fused_ops += 1;
        }
        ops.push(op);
        i += len;
    }
    ops
}

/// Try every catalog pattern at position `i`; returns the op and how
/// many instructions it consumes.
fn match_at(body: &[Instr], i: usize) -> (VOp, usize) {
    let rest = &body[i..];

    // Spec + IOp index chain: the global-thread-id prologue (5 instrs).
    if rest.len() >= 5 {
        if let (
            Instr::Spec(tid, Special::ThreadIdX),
            Instr::Spec(bid, Special::BlockIdX),
            Instr::Spec(bdim, Special::BlockDimX),
            Instr::BinI(IOp::Mul, md, ma, mb),
            Instr::BinI(IOp::Add, ad, aa, ab),
        ) = (rest[0], rest[1], rest[2], rest[3], rest[4])
        {
            let mul_is_chain = (ma == bid && mb == bdim) || (ma == bdim && mb == bid);
            let add_is_chain = (aa == md && ab == tid) || (aa == tid && ab == md);
            if mul_is_chain && add_is_chain {
                return (
                    VOp::GlobalIdX {
                        tid,
                        bid,
                        bdim,
                        mul: (md, ma, mb),
                        add: (ad, aa, ab),
                    },
                    5,
                );
            }
        }
    }

    if rest.len() >= 3 {
        // CvtIF + FMul + FAdd: fused affine of an integer index.
        if let (
            Instr::CvtIF(df, si),
            Instr::BinF(FOp::Mul, dm, ma, mb),
            Instr::BinF(FOp::Add, dd, aa, ab),
        ) = (rest[0], rest[1], rest[2])
        {
            if (ma == df || mb == df) && (aa == dm || ab == dm) {
                return (VOp::CvtMulAddF { df, si, dm, ma, mb, dd, aa, ab }, 3);
            }
        }
        // LdG + FOp + StG on the same slot and index register: fused
        // read-modify-write with a single bounds check.
        if let (
            Instr::LdG { dst, param, idx },
            Instr::BinF(op, d, a, b),
            Instr::StG { param: p2, idx: i2, src },
        ) = (rest[0], rest[1], rest[2])
        {
            if p2 == param && i2 == idx && (a == dst || b == dst) && src == d {
                return (
                    VOp::RmwG { slot: param, idx, ld: dst, op, sa: a, sb: b, st: d },
                    3,
                );
            }
        }
    }

    if rest.len() >= 2 {
        if let (Instr::BinF(FOp::Mul, dm, ma, mb), Instr::BinF(FOp::Add, dd, aa, ab)) =
            (rest[0], rest[1])
        {
            if aa == dm || ab == dm {
                return (VOp::MulAddF { dm, ma, mb, dd, aa, ab }, 2);
            }
        }
        if let (Instr::BinI(IOp::Mul, dm, ma, mb), Instr::BinI(IOp::Add, dd, aa, ab)) =
            (rest[0], rest[1])
        {
            if aa == dm || ab == dm {
                return (VOp::MulAddI { dm, ma, mb, dd, aa, ab }, 2);
            }
        }
    }

    (VOp::Base(rest[0]), 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emulator::builder::KernelBuilder;
    use crate::emulator::decode::decode;
    use crate::emulator::isa::CmpOp;

    /// Σ weights over a lowered kernel (ops + terminator steps) must
    /// equal the instruction count — the step-accounting invariant.
    fn total_weight(l: &LoweredKernel) -> u64 {
        l.blocks
            .iter()
            .map(|b| {
                let ops: u64 = b.ops.iter().map(|o| o.weight()).sum();
                let term = match b.term {
                    Term::Jump { steps, .. } => steps as u64,
                    Term::Branch { .. } | Term::Bar { .. } | Term::Ret => 1,
                    Term::LoopBack { .. } => 3,
                };
                ops + term
            })
            .sum()
    }

    #[test]
    fn vadd_lowering_fuses_global_id_and_preserves_weight() {
        let k = crate::emulator::kernels::vadd().unwrap();
        let d = decode(&k, &[crate::emulator::interp::ScalarArg::I32(64)]).unwrap();
        let l = &d.lowered;
        assert_eq!(l.instr_count, d.code.len());
        assert_eq!(total_weight(l), l.instr_count as u64);
        // the tid/bid/bdim index prologue fuses into one superinstruction
        assert!(
            l.blocks
                .iter()
                .any(|b| b.ops.iter().any(|o| matches!(o, VOp::GlobalIdX { .. }))),
            "expected a GlobalIdX superinstruction: {l:?}"
        );
        assert!(l.fused_instrs >= 5);
        assert!(l.fused_ops >= 1);
    }

    #[test]
    fn blocks_split_at_branch_targets_and_after_branches() {
        // consti; bra_ifz -> skip; constf; bind(skip); ret
        let mut b = KernelBuilder::new("split");
        let c = b.consti(1);
        let skip = b.label();
        b.bra_ifz(c, skip);
        b.constf(0.5);
        b.bind(skip);
        b.ret();
        let k = b.build().unwrap();
        let d = decode(&k, &[]).unwrap();
        let l = &d.lowered;
        // three blocks: [consti | branch], [constf | fallthrough], [ret]
        assert_eq!(l.blocks.len(), 3, "{l:?}");
        assert!(matches!(l.blocks[0].term, Term::Branch { .. }));
        assert!(matches!(l.blocks[1].term, Term::Jump { target: 2, steps: 0 }));
        assert!(matches!(l.blocks[2].term, Term::Ret));
        assert_eq!(total_weight(l), l.instr_count as u64);
    }

    #[test]
    fn loop_kernel_backward_edge_resolves_to_block_id() {
        let mut b = KernelBuilder::new("loop");
        let acc = b.constf(0.0);
        let one = b.constf(1.0);
        let i = b.consti(0);
        let four = b.consti(4);
        let inc = b.consti(1);
        let top = b.label();
        b.bind(top);
        b.fadd_to(acc, one);
        b.iadd_to(i, inc);
        let more = b.cmpi(CmpOp::Lt, i, four);
        b.bra_if(more, top);
        b.ret();
        let k = b.build().unwrap();
        let d = decode(&k, &[]).unwrap();
        let l = &d.lowered;
        // blocks: [preamble | fallthrough], [loop body | LoopBack], [ret]
        // — the counter epilogue (iadd_to; cmpi; bra_if back-edge) fuses
        // into the terminator.
        assert_eq!(l.blocks.len(), 3, "{l:?}");
        match l.blocks[1].term {
            Term::LoopBack { nz, z, cmp_op, .. } => {
                assert_eq!(nz, 1, "backward edge goes to the loop head");
                assert_eq!(z, 2);
                assert_eq!(cmp_op, CmpOp::Lt);
            }
            ref other => panic!("expected LoopBack, got {other:?}"),
        }
        // the two counter instructions left the block body
        assert_eq!(l.blocks[1].ops.len(), 1, "only the fadd_to remains");
        assert_eq!(total_weight(l), l.instr_count as u64);
    }

    /// A forward conditional branch (no back-edge) must NOT fuse — the
    /// loop-counter superinstruction is strictly for backward edges.
    #[test]
    fn forward_branch_with_counter_shape_does_not_fuse() {
        let mut b = KernelBuilder::new("fwd_guard");
        let p = b.ptr_param();
        let i = b.consti(0);
        let one = b.consti(1);
        let four = b.consti(4);
        b.iadd_to(i, one);
        let more = b.cmpi(CmpOp::Lt, i, four);
        let skip = b.label();
        b.bra_if(more, skip); // forward edge
        let v = b.constf(9.0);
        let tid = b.tid_x();
        b.stg(p, tid, v);
        b.bind(skip);
        b.ret();
        let k = b.build().unwrap();
        let d = decode(&k, &[]).unwrap();
        assert!(
            !d.lowered
                .blocks
                .iter()
                .any(|blk| matches!(blk.term, Term::LoopBack { .. })),
            "{:?}",
            d.lowered
        );
        assert_eq!(total_weight(&d.lowered), d.lowered.instr_count as u64);
    }

    /// The sinogram marching loops end in the canonical counter epilogue:
    /// their back-edges must fuse, and the static fused share must stay
    /// above the pre-LoopBack catalog's (regression for the fusion
    /// catalog growth).
    #[test]
    fn sinogram_loops_fuse_their_back_edges() {
        for k in [
            crate::emulator::kernels::sinogram_all().unwrap(),
            crate::emulator::kernels::sinogram("t1").unwrap(),
            crate::emulator::kernels::vadd().unwrap(),
        ] {
            let d = decode(&k, &[crate::emulator::interp::ScalarArg::I32(16)]).unwrap();
            let has_loop = d
                .lowered
                .blocks
                .iter()
                .any(|blk| matches!(blk.term, Term::LoopBack { .. }));
            if k.name.starts_with("sinogram") {
                assert!(has_loop, "{}: marching loop must fuse its back-edge", k.name);
            } else {
                assert!(!has_loop, "{}: no loop, no LoopBack", k.name);
            }
            assert_eq!(total_weight(&d.lowered), d.lowered.instr_count as u64, "{}", k.name);
        }
    }

    #[test]
    fn rmw_pattern_fuses_with_single_bounds_check() {
        // out[tid] = out[tid] * s  (LdG; FMul; StG on the same slot+idx)
        let mut b = KernelBuilder::new("scale");
        let p = b.ptr_param();
        let s = b.constf(3.0);
        let tid = b.tid_x();
        let v = b.ldg(p, tid);
        let w = b.fmul(v, s);
        b.stg(p, tid, w);
        b.ret();
        let k = b.build().unwrap();
        let d = decode(&k, &[]).unwrap();
        let l = &d.lowered;
        assert!(
            l.blocks
                .iter()
                .any(|blk| blk.ops.iter().any(|o| matches!(o, VOp::RmwG { .. }))),
            "{l:?}"
        );
        assert_eq!(total_weight(l), l.instr_count as u64);
    }

    #[test]
    fn mul_add_chain_fuses_but_unrelated_pair_does_not() {
        // chained: dm feeds the add
        let mut b = KernelBuilder::new("chain");
        let p = b.ptr_param();
        let x = b.constf(2.0);
        let y = b.constf(3.0);
        let z = b.constf(4.0);
        let m = b.fmul(x, y);
        let a = b.fadd(m, z);
        let tid = b.tid_x();
        b.stg(p, tid, a);
        b.ret();
        let k = b.build().unwrap();
        let d = decode(&k, &[]).unwrap();
        assert!(d.lowered.blocks[0]
            .ops
            .iter()
            .any(|o| matches!(o, VOp::MulAddF { .. })));

        // unrelated: the add does not consume the product
        let mut b = KernelBuilder::new("nochain");
        let p = b.ptr_param();
        let x = b.constf(2.0);
        let y = b.constf(3.0);
        let _m = b.fmul(x, y);
        let a = b.fadd(x, y);
        let tid = b.tid_x();
        b.stg(p, tid, a);
        b.ret();
        let k = b.build().unwrap();
        let d = decode(&k, &[]).unwrap();
        assert!(!d.lowered.blocks[0]
            .ops
            .iter()
            .any(|o| matches!(o, VOp::MulAddF { .. })));
    }

    #[test]
    fn cvt_mul_add_fuses() {
        // f = (f32)i * s + c, emitted back-to-back
        let mut b = KernelBuilder::new("affine");
        let p = b.ptr_param();
        let s = b.constf(2.0);
        let c = b.constf(1.0);
        let tid = b.tid_x();
        let tf = b.cvt_i2f(tid);
        let m = b.fmul(tf, s);
        let a = b.fadd(m, c);
        b.stg(p, tid, a);
        b.ret();
        let k = b.build().unwrap();
        let d = decode(&k, &[]).unwrap();
        assert!(
            d.lowered.blocks[0]
                .ops
                .iter()
                .any(|o| matches!(o, VOp::CvtMulAddF { .. })),
            "{:?}",
            d.lowered
        );
    }

    #[test]
    fn sinogram_kernels_get_nonzero_fused_share() {
        for k in [
            crate::emulator::kernels::sinogram_all().unwrap(),
            crate::emulator::kernels::sinogram("radon").unwrap(),
        ] {
            let d = decode(&k, &[crate::emulator::interp::ScalarArg::I32(32)]).unwrap();
            let l = &d.lowered;
            assert!(l.fused_ops > 0, "{}: no fusion", k.name);
            assert!(l.fused_instrs > 0);
            assert_eq!(total_weight(l), l.instr_count as u64, "{}", k.name);
        }
    }
}
