//! Launch-time pre-decoding of VTX kernels: the instruction stream is
//! rewritten **once** per (kernel, scalar arguments) into a
//! register-resolved form the interpreter can execute with zero binding
//! lookups on the hot path:
//!
//! * `LdParamF` / `LdParamI` become `ConstF` / `ConstI` with the bound
//!   scalar value baked in;
//! * `LdG` / `StG` parameter indices are remapped to dense **buffer
//!   slots** (the position among the pointer parameters), so global
//!   accesses index the launch's buffer vector directly.
//!
//! The decoded form is cached by [`crate::emulator::VtxFunction`]
//! alongside the coordinator's `Specialized` entry (the coordinator's
//! scalars are fixed per signature), so warm `cuda!` launches skip the
//! decode entirely — the emulator-side analog of the paper's "no
//! steady-state overhead" claim.
//!
//! Decoding also runs the next pipeline stage eagerly: the stream is
//! [lowered](crate::emulator::lower) into its basic-block form with
//! fused superinstructions and the result is carried in
//! [`DecodedKernel::lowered`], so the vector execution tier pays no
//! lowering cost on warm launches either.

use std::sync::Arc;

use crate::emulator::compile::JitState;
use crate::emulator::interp::ScalarArg;
use crate::emulator::isa::{Instr, Kernel, ParamKind};
use crate::emulator::lower::{lower, LoweredKernel};
use crate::error::{Error, Result};

/// A kernel with all parameter references resolved for one scalar
/// binding. Safe to share across worker threads (plain data).
#[derive(Clone, Debug)]
pub struct DecodedKernel {
    pub name: String,
    /// Float registers per thread.
    pub fregs: u16,
    /// Integer registers per thread.
    pub iregs: u16,
    /// Static shared memory, in f32 elements per block.
    pub shared_f32: usize,
    /// Number of global buffers the launch must bind (one per `PtrF32`
    /// parameter, in declaration order).
    pub nbufs: usize,
    /// Rewritten instruction stream: `LdG`/`StG` carry buffer slots in
    /// their `param` field, `LdParam*` no longer occur. The scalar
    /// execution tier interprets this directly.
    pub code: Vec<Instr>,
    /// Basic-block lowering of `code` with fused superinstructions —
    /// the vector execution tier's program, built once here and cached
    /// with the decoded form.
    pub lowered: Arc<LoweredKernel>,
    /// Compiled-tier state: per-block hotness counters and lazily
    /// JIT-compiled block bodies. Shared via `Arc` so clones of the
    /// decoded kernel (and the `Specialized` cache that holds them)
    /// keep riding the same warm compiled blocks.
    pub(crate) jit: Arc<JitState>,
}

/// Resolve `kernel` against the launch's scalar arguments. The kernel must
/// already have passed [`Kernel::validate`] (module load does this); this
/// step only checks the scalar binding.
pub fn decode(kernel: &Kernel, scalars: &[ScalarArg]) -> Result<DecodedKernel> {
    // Map parameter index -> buffer slot or bound scalar.
    #[derive(Clone, Copy)]
    enum Bound {
        Slot(u8),
        Scalar(ScalarArg),
    }
    let mut bound = Vec::with_capacity(kernel.params.len());
    let mut nbufs = 0usize;
    let mut nscalar = 0usize;
    for p in &kernel.params {
        match p {
            ParamKind::PtrF32 => {
                bound.push(Bound::Slot(nbufs as u8));
                nbufs += 1;
            }
            _ => {
                let s = scalars.get(nscalar).copied().ok_or_else(|| {
                    Error::InvalidLaunch(format!(
                        "kernel `{}` missing scalar argument {nscalar}",
                        kernel.name
                    ))
                })?;
                bound.push(Bound::Scalar(s));
                nscalar += 1;
            }
        }
    }
    if nscalar != scalars.len() {
        return Err(Error::InvalidLaunch(format!(
            "kernel `{}` takes {nscalar} scalar arguments, got {}",
            kernel.name,
            scalars.len()
        )));
    }

    let code: Vec<Instr> = kernel
        .code
        .iter()
        .map(|ins| match *ins {
            Instr::LdG { dst, param, idx } => match bound[param as usize] {
                Bound::Slot(slot) => Instr::LdG { dst, param: slot, idx },
                Bound::Scalar(_) => unreachable!("validated: LdG param is PtrF32"),
            },
            Instr::StG { param, idx, src } => match bound[param as usize] {
                Bound::Slot(slot) => Instr::StG { param: slot, idx, src },
                Bound::Scalar(_) => unreachable!("validated: StG param is PtrF32"),
            },
            Instr::LdParamF(d, p) => {
                let v = match bound[p as usize] {
                    Bound::Scalar(ScalarArg::F32(v)) => v,
                    Bound::Scalar(ScalarArg::I32(v)) => v as f32,
                    Bound::Slot(_) => unreachable!("validated: LdParamF param is scalar"),
                };
                Instr::ConstF(d, v)
            }
            Instr::LdParamI(d, p) => {
                let v = match bound[p as usize] {
                    Bound::Scalar(ScalarArg::I32(v)) => v as i64,
                    Bound::Scalar(ScalarArg::F32(v)) => v as i64,
                    Bound::Slot(_) => unreachable!("validated: LdParamI param is scalar"),
                };
                Instr::ConstI(d, v)
            }
            other => other,
        })
        .collect();

    let lowered = Arc::new(lower(&code));
    let jit = Arc::new(JitState::new(lowered.blocks.len()));
    Ok(DecodedKernel {
        name: kernel.name.clone(),
        fregs: kernel.fregs,
        iregs: kernel.iregs,
        shared_f32: kernel.shared_f32,
        nbufs,
        code,
        lowered,
        jit,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emulator::builder::KernelBuilder;

    fn affine_kernel() -> Kernel {
        // out[tid] = scale * tid + offset; params: ptr, f32, i32
        let mut b = KernelBuilder::new("affine");
        let pout = b.ptr_param();
        let pscale = b.f32_param();
        let pn = b.i32_param();
        let tid = b.tid_x();
        let tf = b.cvt_i2f(tid);
        let scale = b.ld_param_f(pscale);
        let n = b.ld_param_i(pn);
        let nf = b.cvt_i2f(n);
        let prod = b.fmul(scale, tf);
        let v = b.fadd(prod, nf);
        b.stg(pout, tid, v);
        b.ret();
        b.build().unwrap()
    }

    #[test]
    fn scalars_baked_and_slots_remapped() {
        let k = affine_kernel();
        let d = decode(&k, &[ScalarArg::F32(2.5), ScalarArg::I32(7)]).unwrap();
        assert_eq!(d.nbufs, 1);
        assert!(d.code.iter().any(|i| matches!(i, Instr::ConstF(_, v) if *v == 2.5)));
        assert!(d.code.iter().any(|i| matches!(i, Instr::ConstI(_, 7))));
        assert!(!d
            .code
            .iter()
            .any(|i| matches!(i, Instr::LdParamF(..) | Instr::LdParamI(..))));
        // the single StG references buffer slot 0, not param index 0
        assert!(d
            .code
            .iter()
            .any(|i| matches!(i, Instr::StG { param: 0, .. })));
    }

    #[test]
    fn missing_scalar_rejected() {
        let k = affine_kernel();
        let err = decode(&k, &[ScalarArg::F32(1.0)]).unwrap_err();
        assert!(err.to_string().contains("missing scalar argument"), "{err}");
    }

    #[test]
    fn extra_scalars_rejected() {
        let k = affine_kernel();
        let err = decode(
            &k,
            &[ScalarArg::F32(1.0), ScalarArg::I32(1), ScalarArg::I32(2)],
        )
        .unwrap_err();
        assert!(err.to_string().contains("scalar arguments"), "{err}");
    }
}
