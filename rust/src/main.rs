//! `hlgpu` CLI — the launcher binary for the framework: device info,
//! smoke tests, the end-to-end trace-transform driver, and the paper's
//! experiments (Figure 3, Table 1, Table 2).
//!
//! ```text
//! hlgpu info                         # devices, artifacts, platform
//! hlgpu vadd [--n 4096] [--device pjrt|emu]
//! hlgpu trace --impl cpu-native --size 128 [--angles 90] [--iters 5]
//! hlgpu fig3  [--sizes 64,128,256] [--iters 5] [--emulator]
//! hlgpu table1 [--size 128]
//! hlgpu table2
//! hlgpu selftest                     # cross-check all implementations
//! ```

use hlgpu::bench_support::{fmt_summary, fmt_time, measure, Settings, Table};
use hlgpu::coordinator::arg;
use hlgpu::cuda;
use hlgpu::error::Result;
use hlgpu::tensor::Tensor;
use hlgpu::tracetransform::{
    impls, orientations, shepp_logan, CpuDynamic, CpuNative, DeviceChoice, GpuAuto, GpuDynamic,
    GpuManual, TraceImpl,
};
use hlgpu::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error [{}]: {e}", e.status());
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("info") => info(),
        Some("vadd") => vadd(args),
        Some("trace") => trace(args),
        Some("fig3") => fig3(args),
        Some("table1") => table1(args),
        Some("table2") => table2(),
        Some("selftest") => selftest(args),
        _ => {
            println!("{}", HELP.trim());
            Ok(())
        }
    }
}

const HELP: &str = r#"
hlgpu — high-level accelerator programming framework (Besard'16 reproduction)

subcommands:
  info       devices, artifact library, platform
  vadd       run the paper's Listing-3 vadd example through @cuda automation
  trace      run one trace-transform implementation
  fig3       Figure 3: steady-state time of 5 implementations vs image size
  table1     Table 1: build / initialization times
  table2     Table 2: lines of code per implementation
  selftest   cross-check features across implementations and backends
common options:
  --device pjrt|emu   --sizes 64,128   --angles 90   --iters N   --warmup N
"#;

fn device_choice(args: &Args) -> DeviceChoice {
    match args.opt("device") {
        Some("emu") | Some("emulator") | Some("vtx") => DeviceChoice::Emulator,
        _ => DeviceChoice::Pjrt,
    }
}

fn info() -> Result<()> {
    println!("devices:");
    for d in hlgpu::driver::devices() {
        println!(
            "  [{}] {} (max {} threads/block, {} KiB shared)",
            d.ordinal,
            d.name,
            d.attributes.max_threads_per_block,
            d.attributes.max_shared_mem_per_block >> 10
        );
    }
    match hlgpu::runtime::pjrt::platform_name() {
        Ok(p) => println!("PJRT platform: {p}"),
        Err(e) => println!("PJRT platform: unavailable ({e})"),
    }
    match hlgpu::runtime::ArtifactLibrary::load_default() {
        Ok(lib) => {
            println!("artifact library: {} entries at {}", lib.len(), lib.dir().display());
            let mut kernels: Vec<&str> =
                lib.entries().iter().map(|e| e.kernel.as_str()).collect();
            kernels.sort_unstable();
            kernels.dedup();
            println!("  kernels: {}", kernels.join(", "));
        }
        Err(e) => println!("artifact library: {e}"),
    }
    Ok(())
}

/// The paper's Listing 3, end to end.
fn vadd(args: &Args) -> Result<()> {
    let n = args.opt_usize("n", 4096);
    let device = device_choice(args);
    let mut launcher = match device {
        DeviceChoice::Pjrt => hlgpu::coordinator::Launcher::with_default_context()?,
        DeviceChoice::Emulator => {
            let mut l = hlgpu::coordinator::Launcher::emulator()?;
            impls::register_trace_providers(l.registry_mut());
            l
        }
    };
    let mut rng = hlgpu::util::Prng::new(42);
    let a = Tensor::from_f32(&rng.f32_vec(n, 0.0, 100.0), &[n]);
    let b = Tensor::from_f32(&rng.f32_vec(n, 0.0, 100.0), &[n]);
    let mut c = Tensor::zeros_f32(&[n]);
    // @cuda (len, 1) vadd(CuIn(a), CuIn(b), CuOut(c))
    cuda!(launcher, (n, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))?;
    // verify: @assert a+b == c
    for i in 0..n {
        let want = a.as_f32()[i] + b.as_f32()[i];
        assert!((c.as_f32()[i] - want).abs() < 1e-4, "mismatch at {i}");
    }
    println!(
        "vadd OK: n={n} device={device:?} cold_specializations={} (cache {:?})",
        launcher.metrics().cold_specializations,
        launcher.cache_stats()
    );
    Ok(())
}

fn make_impl(name: &str, device: DeviceChoice) -> Result<Box<dyn TraceImpl>> {
    Ok(match name {
        "cpu-native" => Box::new(CpuNative::new()),
        "cpu-dynamic" => Box::new(CpuDynamic::new()),
        "gpu-manual" => Box::new(GpuManual::on_device(device)?),
        "gpu-dynamic" => Box::new(GpuDynamic::on_device(device)?),
        "gpu-auto" => Box::new(GpuAuto::on_device(device)?),
        "gpu-auto-fused" => Box::new(GpuAuto::fused()?),
        other => {
            return Err(hlgpu::Error::Other(format!(
                "unknown implementation `{other}` (try cpu-native, cpu-dynamic, gpu-manual, gpu-dynamic, gpu-auto, gpu-auto-fused)"
            )))
        }
    })
}

fn trace(args: &Args) -> Result<()> {
    let name = args.opt("impl").unwrap_or("gpu-auto").to_string();
    let size = args.opt_usize("size", 128);
    let angles = args.opt_usize("angles", 90);
    let device = device_choice(args);
    let img = shepp_logan(size);
    let thetas = orientations(angles);
    let mut im = make_impl(&name, device)?;
    let settings = Settings::from_cli(args);
    let mut last = Vec::new();
    let summary = measure(settings, || {
        last = im.features(&img, &thetas).unwrap();
    });
    println!(
        "{name} size={size} angles={angles}: {} ({} features, first={:.4})",
        fmt_summary(&summary),
        last.len(),
        last.first().copied().unwrap_or(0.0)
    );
    Ok(())
}

/// Figure 3: steady-state execution times, 5 implementations x sizes.
fn fig3(args: &Args) -> Result<()> {
    let sizes = args.opt_usize_list("sizes", &[64, 128, 256]);
    let angles = args.opt_usize("angles", 90);
    let device =
        if args.flag("emulator") { DeviceChoice::Emulator } else { DeviceChoice::Pjrt };
    let settings = Settings::from_cli(args);
    let impl_names =
        ["cpu-native", "cpu-dynamic", "gpu-manual", "gpu-dynamic", "gpu-auto"];

    let mut table = Table::new(
        &["size", "cpu-native", "cpu-dynamic", "gpu-manual", "gpu-dynamic", "gpu-auto"],
    );
    let mut max_unc: f64 = 0.0;
    for &size in &sizes {
        let img = shepp_logan(size);
        let thetas = orientations(angles);
        let mut row = vec![size.to_string()];
        for name in impl_names {
            let mut im = make_impl(name, device)?;
            let summary = measure(settings, || im.features(&img, &thetas).unwrap());
            max_unc = max_unc.max(summary.rel_uncertainty_pct());
            row.push(fmt_time(summary.mean));
        }
        table.row(&row);
    }
    println!("Figure 3 — steady-state execution time per iteration");
    println!(
        "(device={device:?}, angles={angles}, {} samples, relative uncertainty ≤ {max_unc:.2}%)",
        settings.sample_iters
    );
    println!("{}", table.render());
    Ok(())
}

/// Table 1: "build" (artifact AOT cost, measured as PJRT compile of the
/// needed modules) and "init" (first-call cost: client + module load +
/// first specialization) per implementation.
fn table1(args: &Args) -> Result<()> {
    let size = args.opt_usize("size", 128);
    let angles = args.opt_usize("angles", 90);
    let img = shepp_logan(size);
    let thetas = orientations(angles);

    let mut table = Table::new(&["implementation", "init (s)"]);
    for name in ["cpu-native", "cpu-dynamic", "gpu-manual", "gpu-dynamic", "gpu-auto"] {
        let (init, feats) = hlgpu::bench_support::measure_once(|| -> Result<Vec<f32>> {
            let mut im = make_impl(name, DeviceChoice::Pjrt)?;
            im.features(&img, &thetas)
        });
        let feats = feats?;
        assert!(!feats.is_empty());
        table.row(&[name.to_string(), format!("{init:.3}")]);
    }
    println!("Table 1 — initialization time to first result (size={size}, angles={angles})");
    println!("{}", table.render());
    println!("note: the AOT 'build' column is `make artifacts` wall time (python, build-time only).");
    Ok(())
}

/// Table 2: lines of code per implementation (program + core algorithm).
fn table2() -> Result<()> {
    let root = hlgpu::repo_root();
    let rows: &[(&str, &[&str])] = &[
        ("cpu-native", &["rust/src/tracetransform/impls/cpu_native.rs"]),
        ("cpu-dynamic", &["rust/src/tracetransform/impls/cpu_dynamic.rs"]),
        ("gpu-manual", &["rust/src/tracetransform/impls/gpu_manual.rs"]),
        ("gpu-dynamic", &["rust/src/tracetransform/impls/gpu_dynamic.rs"]),
        ("gpu-auto", &["rust/src/tracetransform/impls/gpu_auto.rs"]),
        (
            "kernels (pallas L1)",
            &[
                "python/compile/kernels/rotate.py",
                "python/compile/kernels/tfunctionals.py",
                "python/compile/kernels/sinogram.py",
            ],
        ),
        ("kernels (VTX)", &["rust/src/emulator/kernels.rs"]),
    ];
    let mut table = Table::new(&["implementation", "program", "core algorithm"]);
    for (name, files) in rows {
        let paths: Vec<_> = files.iter().map(|f| root.join(f)).collect();
        let c = hlgpu::sloc::count_files(&paths)?;
        table.row(&[name.to_string(), c.total.to_string(), c.core.to_string()]);
    }
    println!("Table 2 — lines of code (non-blank, non-comment; core = SLOC:core regions)");
    println!("{}", table.render());
    Ok(())
}

/// Cross-check all implementations produce the same features.
fn selftest(args: &Args) -> Result<()> {
    let size = args.opt_usize("size", 32);
    let angles = args.opt_usize("angles", 16);
    let img = shepp_logan(size);
    let thetas = orientations(angles);
    let reference = CpuNative::new().features(&img, &thetas)?;
    println!("reference: cpu-native, {} features", reference.len());

    let mut checked = 0;
    let mut check = |name: &str, feats: Result<Vec<f32>>| match feats {
        Ok(f) => {
            let max_rel = f
                .iter()
                .zip(&reference)
                .map(|(a, b)| (a - b).abs() / b.abs().max(1.0))
                .fold(0.0f32, f32::max);
            println!("  {name:<24} max relative deviation {max_rel:.2e}");
            assert!(max_rel < 5e-3, "{name} deviates too much");
            checked += 1;
        }
        Err(e) => println!("  {name:<24} SKIPPED ({e})"),
    };

    check("cpu-dynamic", CpuDynamic::new().features(&img, &thetas));
    for device in [DeviceChoice::Pjrt, DeviceChoice::Emulator] {
        for name in ["gpu-manual", "gpu-dynamic", "gpu-auto"] {
            let tag = format!("{name}@{device:?}");
            match make_impl(name, device) {
                Ok(mut im) => check(&tag, im.features(&img, &thetas)),
                Err(e) => println!("  {tag:<24} SKIPPED ({e})"),
            }
        }
    }
    println!("selftest OK: {checked} implementations agree");
    Ok(())
}
