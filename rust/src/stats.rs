//! Measurement methodology from the paper (§7.2): performance numbers are
//! the mean of a fitted **log-normal** distribution, with precision
//! reported as the **relative uncertainty** derived from the standard
//! deviation of the log-samples. The paper cites Ciemiewicz'01 and
//! Mashey'04 for this; relative uncertainties below 2% are considered
//! careful measurements (Taylor'97).

/// Summary of a set of positive timing samples under a log-normal model.
#[derive(Clone, Copy, Debug)]
pub struct LogNormalSummary {
    /// Number of samples.
    pub n: usize,
    /// Mean of ln(samples).
    pub mu: f64,
    /// Standard deviation of ln(samples) (unbiased).
    pub sigma: f64,
    /// Mean of the fitted log-normal: exp(mu + sigma^2/2).
    pub mean: f64,
    /// Relative uncertainty of the mean (fraction, not %):
    /// sigma / sqrt(n) in log-space, which for small values equals the
    /// relative error of the fitted mean.
    pub rel_uncertainty: f64,
}

/// Fit a log-normal to positive samples. Panics on empty input; samples
/// that are zero or negative are clamped to the smallest positive sample
/// (timer resolution artifacts).
pub fn lognormal_fit(samples: &[f64]) -> LogNormalSummary {
    assert!(!samples.is_empty(), "lognormal_fit: empty sample set");
    let floor = samples
        .iter()
        .copied()
        .filter(|&x| x > 0.0)
        .fold(f64::INFINITY, f64::min);
    let floor = if floor.is_finite() { floor } else { 1e-12 };
    let logs: Vec<f64> = samples
        .iter()
        .map(|&x| if x > 0.0 { x } else { floor })
        .map(f64::ln)
        .collect();
    let n = logs.len();
    let mu = logs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        logs.iter().map(|l| (l - mu) * (l - mu)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let sigma = var.sqrt();
    LogNormalSummary {
        n,
        mu,
        sigma,
        mean: (mu + var / 2.0).exp(),
        rel_uncertainty: if n > 0 { sigma / (n as f64).sqrt() } else { 0.0 },
    }
}

impl LogNormalSummary {
    /// Relative uncertainty in percent (the unit the paper's captions use).
    pub fn rel_uncertainty_pct(&self) -> f64 {
        self.rel_uncertainty * 100.0
    }

    /// True when the measurement meets the paper's "careful measurement"
    /// bar of < 2% relative uncertainty.
    pub fn is_careful(&self) -> bool {
        self.rel_uncertainty_pct() < 2.0
    }
}

/// Simple arithmetic summary, used for cross-checking and for quantities
/// that are not timing-like.
#[derive(Clone, Copy, Debug)]
pub struct BasicSummary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

pub fn basic_summary(samples: &[f64]) -> BasicSummary {
    assert!(!samples.is_empty());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    };
    BasicSummary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        median,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn constant_samples_zero_uncertainty() {
        let s = lognormal_fit(&[2.0, 2.0, 2.0, 2.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.rel_uncertainty, 0.0);
        assert!(s.is_careful());
    }

    #[test]
    fn recovers_lognormal_parameters() {
        // Generate log-normal samples with mu=ln(10), sigma=0.05.
        let mut p = Prng::new(5);
        let mu = 10.0f64.ln();
        let sigma = 0.05;
        let samples: Vec<f64> = (0..4000)
            .map(|_| {
                // Box-Muller from two uniforms.
                let u1 = p.next_f64().max(1e-12);
                let u2 = p.next_f64();
                let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
                (mu + sigma * z).exp()
            })
            .collect();
        let s = lognormal_fit(&samples);
        assert!((s.mu - mu).abs() < 0.01, "mu {} vs {}", s.mu, mu);
        assert!((s.sigma - sigma).abs() < 0.01);
        // mean = exp(mu + sigma^2/2) ~ 10.0125
        assert!((s.mean - 10.0).abs() < 0.2);
        assert!(s.is_careful());
    }

    #[test]
    fn zero_samples_clamped_not_panicking() {
        let s = lognormal_fit(&[0.0, 1.0, 1.0]);
        assert!(s.mean > 0.0);
    }

    #[test]
    fn basic_summary_median() {
        let s = basic_summary(&[5.0, 1.0, 3.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        let s2 = basic_summary(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s2.median, 2.5);
    }

    #[test]
    fn uncertainty_shrinks_with_samples() {
        let mut p = Prng::new(17);
        let few: Vec<f64> = (0..10).map(|_| 1.0 + 0.1 * p.next_f64()).collect();
        let many: Vec<f64> = (0..1000).map(|_| 1.0 + 0.1 * p.next_f64()).collect();
        assert!(lognormal_fit(&many).rel_uncertainty < lognormal_fit(&few).rel_uncertainty);
    }
}
