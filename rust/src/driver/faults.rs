//! Deterministic fault-injection plane and the sticky device-loss
//! registry (see `docs/faults.md`).
//!
//! A [`FaultPlan`] names *sites* — the driver boundaries where real GPU
//! stacks fail — and schedules an injection at the `nth` operation a
//! given device ordinal performs at that site:
//!
//! | site     | boundary                                  | injected failure |
//! |----------|-------------------------------------------|------------------|
//! | `alloc`  | device memory allocation                  | [`Error::OutOfMemory`] |
//! | `h2d`    | host→device copy                          | [`Error::Stream`] |
//! | `d2h`    | device→host copy                          | [`Error::Stream`] |
//! | `launch` | kernel launch (sync or stream enqueue)    | sticky [`Error::DeviceLost`] |
//! | `sync`   | host-side join (`PendingLaunch::wait` / `PendingDownload::wait`) | sticky [`Error::DeviceLost`] |
//! | `hang`   | stream launch that never completes        | watchdog → [`Error::DeviceLost`] |
//!
//! Plans come from code ([`install`]) or from the environment
//! (`HLGPU_FAULTS=<site>@<ordinal>:<nth>[,…]`, parsed once at first
//! use). Operations are only counted at (site, ordinal) pairs the
//! active plan targets, and a rule fires on the exact `nth` count —
//! so schedules are deterministic for a deterministic workload, and
//! [`FaultPlan::seeded`] plans replay identically under the same seed.
//!
//! Loss is *sticky*, mirroring CUDA's model: once an ordinal is marked
//! lost every subsequent operation on any context over it fails fast
//! with [`Error::DeviceLost`], and `Device::reset` is the only way
//! back. The layers above lean on that contract — `DeviceSet` health,
//! the sharded-batch retry, and serve-worker re-pinning all key off
//! [`Error::is_device_loss`].

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::util::Prng;

/// A named injection site (one driver boundary).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FaultSite {
    /// Device memory allocation; fires as [`Error::OutOfMemory`].
    Alloc,
    /// Kernel launch (sync launch or stream enqueue); fires as a sticky
    /// [`Error::DeviceLost`].
    Launch,
    /// Host-side join of pending stream work; fires as a sticky
    /// [`Error::DeviceLost`].
    Sync,
    /// Host→device copy; fires as a (transient) [`Error::Stream`].
    H2d,
    /// Device→host copy; fires as a (transient) [`Error::Stream`].
    D2h,
    /// Stream launch that never completes — until a watchdog or the
    /// hang cap marks the device lost.
    Hang,
}

impl FaultSite {
    /// Every site, in grammar order.
    pub const ALL: [FaultSite; 6] = [
        FaultSite::Alloc,
        FaultSite::Launch,
        FaultSite::Sync,
        FaultSite::H2d,
        FaultSite::D2h,
        FaultSite::Hang,
    ];

    /// The site's name in the `HLGPU_FAULTS` grammar.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::Alloc => "alloc",
            FaultSite::Launch => "launch",
            FaultSite::Sync => "sync",
            FaultSite::H2d => "h2d",
            FaultSite::D2h => "d2h",
            FaultSite::Hang => "hang",
        }
    }

    /// Parse a grammar name (case-insensitive).
    pub fn parse(s: &str) -> Option<FaultSite> {
        let s = s.trim().to_ascii_lowercase();
        FaultSite::ALL.into_iter().find(|site| site.name() == s)
    }
}

/// One scheduled injection: fail the `nth` operation (1-based) that
/// device `ordinal` performs at `site`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultRule {
    pub site: FaultSite,
    pub ordinal: usize,
    /// 1-based operation count at which the rule fires, exactly once.
    pub nth: u64,
}

/// A deterministic fault schedule: a set of [`FaultRule`]s.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// An empty plan (no injections).
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Builder: fail the `nth` (1-based, clamped to ≥ 1) operation at
    /// `site` on device `ordinal`.
    pub fn fail(mut self, site: FaultSite, ordinal: usize, nth: u64) -> FaultPlan {
        self.rules.push(FaultRule { site, ordinal, nth: nth.max(1) });
        self
    }

    /// The scheduled rules.
    pub fn rules(&self) -> &[FaultRule] {
        &self.rules
    }

    /// Does the plan schedule nothing?
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Parse the `HLGPU_FAULTS` grammar: `<site>@<ordinal>:<nth>[,…]`,
    /// e.g. `launch@2:3,h2d@1:1`. Empty segments are skipped.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (site_s, rest) = part.split_once('@').ok_or_else(|| bad(part, "missing `@`"))?;
            let (ord_s, nth_s) = rest.split_once(':').ok_or_else(|| bad(part, "missing `:`"))?;
            let site = FaultSite::parse(site_s).ok_or_else(|| bad(part, "unknown site"))?;
            let ordinal: usize =
                ord_s.trim().parse().map_err(|_| bad(part, "bad device ordinal"))?;
            let nth: u64 = nth_s.trim().parse().map_err(|_| bad(part, "bad operation count"))?;
            if nth == 0 {
                return Err(bad(part, "operation counts are 1-based"));
            }
            plan.rules.push(FaultRule { site, ordinal, nth });
        }
        Ok(plan)
    }

    /// A seeded random plan: draw `count` rules over the given `sites`
    /// and `ordinals` with operation counts in `1..=max_nth`. The same
    /// seed always yields the same plan (the crate PRNG is pure).
    pub fn seeded(
        seed: u64,
        sites: &[FaultSite],
        ordinals: &[usize],
        max_nth: u64,
        count: usize,
    ) -> FaultPlan {
        let mut prng = Prng::new(seed);
        let mut plan = FaultPlan::new();
        if sites.is_empty() || ordinals.is_empty() {
            return plan;
        }
        for _ in 0..count {
            let site = *prng.choose(sites);
            let ordinal = *prng.choose(ordinals);
            let nth = 1 + prng.next_u64() % max_nth.max(1);
            plan.rules.push(FaultRule { site, ordinal, nth });
        }
        plan
    }

    fn targets(&self, site: FaultSite, ordinal: usize) -> bool {
        self.rules.iter().any(|r| r.site == site && r.ordinal == ordinal)
    }

    fn fires(&self, site: FaultSite, ordinal: usize, count: u64) -> bool {
        self.rules.iter().any(|r| r.site == site && r.ordinal == ordinal && r.nth == count)
    }
}

fn bad(part: &str, why: &str) -> Error {
    Error::Other(format!(
        "HLGPU_FAULTS: bad rule `{part}`: {why} \
         (grammar: <site>@<ordinal>:<nth>[,...]; sites: alloc launch sync h2d d2h hang)"
    ))
}

// ------------------------------------------------------ global state --

struct FaultState {
    /// The active schedule; `None` disarms every site hook.
    plan: Mutex<Option<FaultPlan>>,
    /// Operations seen per (site, ordinal) the active plan targets.
    seen: Mutex<HashMap<(FaultSite, usize), u64>>,
    /// Injections fired per (site, ordinal).
    fired: Mutex<HashMap<(FaultSite, usize), u64>>,
    /// Sticky lost ordinals.
    lost: Mutex<HashSet<usize>>,
    /// Fast-path gates: plan present / any ordinal lost.
    armed: AtomicBool,
    lost_count: AtomicUsize,
}

fn state() -> &'static FaultState {
    static STATE: OnceLock<FaultState> = OnceLock::new();
    STATE.get_or_init(|| {
        // The env plan is parsed once, lazily, at first use; a later
        // `install` overrides it for the rest of the process.
        let plan = std::env::var("HLGPU_FAULTS").ok().and_then(|v| match FaultPlan::parse(&v) {
            Ok(p) if !p.is_empty() => Some(p),
            Ok(_) => None,
            Err(e) => {
                eprintln!("hlgpu: ignoring HLGPU_FAULTS: {e}");
                None
            }
        });
        FaultState {
            armed: AtomicBool::new(plan.is_some()),
            plan: Mutex::new(plan),
            seen: Mutex::new(HashMap::new()),
            fired: Mutex::new(HashMap::new()),
            lost: Mutex::new(HashSet::new()),
            lost_count: AtomicUsize::new(0),
        }
    })
}

/// Install `plan` as the active schedule — replacing the env plan or a
/// previous install — and reset the operation and injection counters.
pub fn install(plan: FaultPlan) {
    let st = state();
    st.seen.lock().unwrap().clear();
    st.fired.lock().unwrap().clear();
    let armed = !plan.is_empty();
    *st.plan.lock().unwrap() = Some(plan);
    st.armed.store(armed, Ordering::SeqCst);
}

/// Remove the active plan (every site hook disarms) and reset the
/// counters. Sticky lost marks survive — see [`reset_all`] and
/// `Device::reset`.
pub fn clear() {
    let st = state();
    *st.plan.lock().unwrap() = None;
    st.armed.store(false, Ordering::SeqCst);
    st.seen.lock().unwrap().clear();
    st.fired.lock().unwrap().clear();
}

/// [`clear`] plus dropping every sticky lost mark — the chaos suite's
/// between-tests reset.
pub fn reset_all() {
    clear();
    let st = state();
    st.lost.lock().unwrap().clear();
    st.lost_count.store(0, Ordering::SeqCst);
}

/// Is a fault plan currently armed? Tests use this to relax exact
/// work-placement assertions under an ambient chaos schedule.
pub fn armed() -> bool {
    state().armed.load(Ordering::Relaxed)
}

/// A snapshot of the active plan, if any.
pub fn active_plan() -> Option<FaultPlan> {
    state().plan.lock().unwrap().clone()
}

/// Injections fired so far at `site` on `ordinal` under the active plan.
pub fn injections(site: FaultSite, ordinal: usize) -> u64 {
    state().fired.lock().unwrap().get(&(site, ordinal)).copied().unwrap_or(0)
}

/// Every (site, ordinal) pair that fired at least once, with its count,
/// in deterministic order — the chaos suite compares these across
/// same-seed runs.
pub fn injection_counts() -> Vec<(FaultSite, usize, u64)> {
    let mut v: Vec<(FaultSite, usize, u64)> =
        state().fired.lock().unwrap().iter().map(|(&(s, o), &c)| (s, o, c)).collect();
    v.sort_unstable_by_key(|&(s, o, _)| (s, o));
    v
}

// ------------------------------------------------------ loss registry --

/// Mark `ordinal` lost: every subsequent driver operation on a context
/// over it fails fast with [`Error::DeviceLost`] until `Device::reset`.
pub fn mark_lost(ordinal: usize) {
    let st = state();
    if st.lost.lock().unwrap().insert(ordinal) {
        st.lost_count.fetch_add(1, Ordering::SeqCst);
    }
}

/// Is `ordinal` sticky-lost? One relaxed atomic load on the (common)
/// nothing-lost path.
pub fn is_lost(ordinal: usize) -> bool {
    let st = state();
    if st.lost_count.load(Ordering::Relaxed) == 0 {
        return false;
    }
    st.lost.lock().unwrap().contains(&ordinal)
}

/// Clear the sticky lost mark on `ordinal` — the `cuDeviceReset`
/// analog, reached through `Device::reset`.
pub fn reset_device(ordinal: usize) {
    let st = state();
    if st.lost.lock().unwrap().remove(&ordinal) {
        st.lost_count.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Fail fast when `ordinal` is lost.
pub fn check_lost(ordinal: usize) -> Result<()> {
    if is_lost(ordinal) {
        Err(Error::DeviceLost(ordinal))
    } else {
        Ok(())
    }
}

/// Count one operation at (site, ordinal) against the active plan and
/// report whether a rule fires on it. Pairs the plan does not target
/// are not counted, so an unfaulted workload pays one atomic load.
fn decide(site: FaultSite, ordinal: usize) -> bool {
    let st = state();
    if !st.armed.load(Ordering::Relaxed) {
        return false;
    }
    let plan = st.plan.lock().unwrap();
    let Some(plan) = plan.as_ref() else {
        return false;
    };
    if !plan.targets(site, ordinal) {
        return false;
    }
    let count = {
        let mut seen = st.seen.lock().unwrap();
        let c = seen.entry((site, ordinal)).or_insert(0);
        *c += 1;
        *c
    };
    if plan.fires(site, ordinal, count) {
        *st.fired.lock().unwrap().entry((site, ordinal)).or_insert(0) += 1;
        true
    } else {
        false
    }
}

// --------------------------------------------------------- site hooks --

/// Allocation-site hook: fail fast on a lost device, else an injected
/// [`Error::OutOfMemory`] when the plan schedules one here.
pub(crate) fn on_alloc(ordinal: usize, requested: usize) -> Result<()> {
    check_lost(ordinal)?;
    if decide(FaultSite::Alloc, ordinal) {
        return Err(Error::OutOfMemory { requested, available: 0 });
    }
    Ok(())
}

/// Host→device copy hook: the injected failure is a transient stream
/// error, not a loss.
pub(crate) fn on_h2d(ordinal: usize) -> Result<()> {
    check_lost(ordinal)?;
    if decide(FaultSite::H2d, ordinal) {
        return Err(Error::Stream(format!("injected h2d fault on device {ordinal}")));
    }
    Ok(())
}

/// Device→host copy hook: transient stream error, as [`on_h2d`].
pub(crate) fn on_d2h(ordinal: usize) -> Result<()> {
    check_lost(ordinal)?;
    if decide(FaultSite::D2h, ordinal) {
        return Err(Error::Stream(format!("injected d2h fault on device {ordinal}")));
    }
    Ok(())
}

/// Launch-site hook (sync launch and stream enqueue): an injected
/// launch failure *loses* the device — sticky until reset.
pub(crate) fn on_launch(ordinal: usize) -> Result<()> {
    check_lost(ordinal)?;
    if decide(FaultSite::Launch, ordinal) {
        mark_lost(ordinal);
        return Err(Error::DeviceLost(ordinal));
    }
    Ok(())
}

/// Sync-site hook, shared by `PendingLaunch::wait` and
/// `PendingDownload::wait`: one `sync` operation is counted per join,
/// and an injected failure loses the device.
pub(crate) fn on_sync(ordinal: usize) -> Result<()> {
    check_lost(ordinal)?;
    if decide(FaultSite::Sync, ordinal) {
        mark_lost(ordinal);
        return Err(Error::DeviceLost(ordinal));
    }
    Ok(())
}

/// Should the next stream launch on `ordinal` hang instead of running?
/// Consulted at `launch_on` enqueue time (the sync launch path cannot
/// hang — it would wedge the caller with no watchdog in between).
pub(crate) fn hang_requested(ordinal: usize) -> bool {
    decide(FaultSite::Hang, ordinal)
}

/// Upper bound on an injected hang when no watchdog fires first: past
/// the cap the hung op loses the device *itself* and returns, so the
/// stream worker always unblocks and `Stream::drop` can always join.
/// `HLGPU_HANG_MS` overrides.
const DEFAULT_HANG_CAP_MS: u64 = 1_500;

fn env_ms(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.trim().parse().ok()).filter(|&ms| ms > 0)
}

/// The launch-watchdog budget from `HLGPU_WATCHDOG_MS`, if set (read
/// per call so tests can flip it).
pub fn watchdog_ms() -> Option<u64> {
    env_ms("HLGPU_WATCHDOG_MS")
}

/// Body of an injected hung kernel: nap in 1 ms steps until a watchdog
/// marks the ordinal lost, or the hang cap expires and the op marks it
/// lost itself. Either way the device ends lost and the returned error
/// goes into the stream's sticky slot.
pub(crate) fn hang_until_lost(ordinal: usize) -> Error {
    let cap = Duration::from_millis(env_ms("HLGPU_HANG_MS").unwrap_or(DEFAULT_HANG_CAP_MS));
    let start = Instant::now();
    while !is_lost(ordinal) && start.elapsed() < cap {
        std::thread::sleep(Duration::from_millis(1));
    }
    mark_lost(ordinal);
    Error::DeviceLost(ordinal)
}

/// Serializes lib-internal tests that mutate the process-global fault
/// plane (the integration chaos suite runs in its own process and has
/// its own lock).
#[cfg(test)]
pub(crate) static FAULT_TEST_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    // Synthesized ordinals far past any real device table, so marking
    // them lost cannot perturb tests running in parallel.
    const ORD: usize = 9_200;

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        FAULT_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn parse_grammar_roundtrip() {
        let plan = FaultPlan::parse("launch@2:3, h2d@1:1,,D2H@0:7").unwrap();
        assert_eq!(
            plan.rules(),
            &[
                FaultRule { site: FaultSite::Launch, ordinal: 2, nth: 3 },
                FaultRule { site: FaultSite::H2d, ordinal: 1, nth: 1 },
                FaultRule { site: FaultSite::D2h, ordinal: 0, nth: 7 },
            ]
        );
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_rules() {
        for bad in ["launch@2", "launch:2@1", "bogus@1:1", "launch@x:1", "launch@1:0", "@1:1"] {
            let err = FaultPlan::parse(bad).unwrap_err();
            assert!(err.to_string().contains("HLGPU_FAULTS"), "{bad}: {err}");
        }
    }

    #[test]
    fn seeded_plans_replay_under_the_same_seed() {
        let ords = [1usize, 2, 3, 4];
        let sites = FaultSite::ALL;
        let mut distinct = false;
        for seed in 0..8u64 {
            let a = FaultPlan::seeded(seed, &sites, &ords, 6, 5);
            let b = FaultPlan::seeded(seed, &sites, &ords, 6, 5);
            assert_eq!(a, b, "seed {seed} must replay");
            assert_eq!(a.rules().len(), 5);
            distinct |= a != FaultPlan::seeded(seed + 1, &sites, &ords, 6, 5);
        }
        assert!(distinct, "different seeds should produce different plans");
    }

    #[test]
    fn rules_fire_on_the_exact_nth_operation_only() {
        let _g = lock();
        install(FaultPlan::new().fail(FaultSite::Alloc, ORD, 2));
        assert!(armed());
        assert!(on_alloc(ORD, 64).is_ok(), "1st op passes");
        let err = on_alloc(ORD, 64).unwrap_err();
        assert!(matches!(err, Error::OutOfMemory { requested: 64, available: 0 }));
        assert!(on_alloc(ORD, 64).is_ok(), "3rd op passes again");
        // Untargeted ordinals are never counted or failed.
        assert!(on_alloc(ORD + 1, 64).is_ok());
        assert_eq!(injections(FaultSite::Alloc, ORD), 1);
        assert_eq!(injection_counts(), vec![(FaultSite::Alloc, ORD, 1)]);
        reset_all();
        assert!(!armed());
        assert!(active_plan().is_none());
    }

    #[test]
    fn launch_injection_is_sticky_until_reset() {
        let _g = lock();
        let ord = ORD + 10;
        install(FaultPlan::new().fail(FaultSite::Launch, ord, 1));
        let err = on_launch(ord).unwrap_err();
        assert!(err.is_device_loss());
        assert!(is_lost(ord));
        // Every later site on the ordinal fails fast, typed.
        assert!(matches!(on_alloc(ord, 8).unwrap_err(), Error::DeviceLost(o) if o == ord));
        assert!(matches!(on_h2d(ord).unwrap_err(), Error::DeviceLost(_)));
        assert!(matches!(on_sync(ord).unwrap_err(), Error::DeviceLost(_)));
        assert!(check_lost(ord).is_err());
        // Reset is the only way back.
        reset_device(ord);
        assert!(!is_lost(ord));
        assert!(on_sync(ord).is_ok());
        reset_all();
    }

    #[test]
    fn hang_cap_unwedges_and_loses_the_device() {
        let _g = lock();
        let ord = ORD + 20;
        install(FaultPlan::new().fail(FaultSite::Hang, ord, 1));
        assert!(hang_requested(ord), "1st stream launch hangs");
        assert!(!hang_requested(ord), "2nd does not");
        // A watchdog path: mark the device lost from "outside" and the
        // hung body returns promptly.
        mark_lost(ord);
        let t0 = Instant::now();
        let err = hang_until_lost(ord);
        assert!(err.is_device_loss());
        assert!(t0.elapsed() < Duration::from_millis(DEFAULT_HANG_CAP_MS));
        reset_all();
    }
}
