//! Contexts (`cuCtxCreate` / `cuCtxDestroy`): own device memory, loaded
//! modules and streams for one device.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::driver::backend::{Backend, ModuleSource};
use crate::driver::device::Device;
use crate::driver::memory::{DevicePtr, MemStats, MemoryPool, PoolPolicy};
use crate::driver::module::Module;
use crate::driver::stream::Stream;
use crate::error::{Error, Result};

struct ContextInner {
    device: Device,
    backend: Arc<dyn Backend>,
    mem: Arc<MemoryPool>,
    modules: Mutex<HashMap<String, Module>>,
    destroyed: AtomicBool,
}

/// A driver context. Cheap to clone (shared handle); `destroy` poisons all
/// clones, as with real driver contexts.
#[derive(Clone)]
pub struct Context {
    inner: Arc<ContextInner>,
}

impl Context {
    /// `cuCtxCreate` for a device ordinal. The memory pool's allocation
    /// policy follows the `HLGPU_POOL` environment knob.
    pub fn create(device: &Device) -> Result<Context> {
        Self::create_with_policy(device, PoolPolicy::from_env())
    }

    /// `cuCtxCreate` with an explicit pool policy (benches and tests A/B
    /// the cached vs uncached allocator without touching the process
    /// environment).
    pub fn create_with_policy(device: &Device, policy: PoolPolicy) -> Result<Context> {
        let backend = device.backend()?;
        Ok(Context {
            inner: Arc::new(ContextInner {
                device: device.clone(),
                backend,
                mem: Arc::new(
                    MemoryPool::with_policy(device.attributes.total_memory, policy)
                        .with_device_ordinal(device.ordinal),
                ),
                modules: Mutex::new(HashMap::new()),
                destroyed: AtomicBool::new(false),
            }),
        })
    }

    /// Context on device 0 (the PJRT-backed simulated accelerator).
    pub fn default_device() -> Result<Context> {
        Context::create(&crate::driver::device::device(0)?)
    }

    fn check_alive(&self) -> Result<()> {
        if self.inner.destroyed.load(Ordering::Acquire) {
            return Err(Error::ContextDestroyed);
        }
        // Sticky device loss: every context over a lost ordinal fails
        // fast until `Device::reset` (see docs/faults.md).
        crate::driver::faults::check_lost(self.inner.device.ordinal)
    }

    pub fn device(&self) -> &Device {
        &self.inner.device
    }

    pub fn backend_name(&self) -> &'static str {
        self.inner.backend.name()
    }

    /// The context's device memory pool.
    pub fn memory(&self) -> Result<&MemoryPool> {
        self.check_alive()?;
        Ok(&self.inner.mem)
    }

    /// Shared handle to the pool for streams / long-lived launchers.
    pub fn memory_arc(&self) -> Result<Arc<MemoryPool>> {
        self.check_alive()?;
        Ok(self.inner.mem.clone())
    }

    // ---- convenience memory API (the CUDA.jl-style wrappers) -----------

    pub fn alloc(&self, bytes: usize) -> Result<DevicePtr> {
        self.memory()?.alloc(bytes)
    }

    /// Allocate in a specific pool arena — pass a
    /// [`Stream::arena_id`](crate::driver::Stream::arena_id) so the
    /// stream's buffers live in their own allocator shard.
    pub fn alloc_in(&self, arena: usize, bytes: usize) -> Result<DevicePtr> {
        self.memory()?.alloc_in(arena, bytes)
    }

    pub fn free(&self, ptr: DevicePtr) -> Result<()> {
        self.memory()?.free(ptr)
    }

    pub fn upload(&self, ptr: DevicePtr, data: &[u8]) -> Result<()> {
        self.memory()?.copy_h2d(ptr, data)
    }

    pub fn download(&self, ptr: DevicePtr, out: &mut [u8]) -> Result<()> {
        self.memory()?.copy_d2h(ptr, out)
    }

    /// Allocate + upload in one call (`CuArray(host_array)` analog).
    pub fn alloc_upload(&self, data: &[u8]) -> Result<DevicePtr> {
        let ptr = self.alloc(data.len())?;
        self.upload(ptr, data)?;
        Ok(ptr)
    }

    pub fn mem_stats(&self) -> Result<MemStats> {
        Ok(self.memory()?.stats())
    }

    /// Release the pool's cached blocks back to the host allocator
    /// (`cuMemPoolTrimTo(0)` analog); returns the bytes released.
    pub fn trim_memory(&self) -> Result<usize> {
        Ok(self.memory()?.trim())
    }

    // ---- modules ---------------------------------------------------------

    /// `cuModuleLoad`: load (compile) a module, cached by module name so
    /// repeated loads of the same artifact are free.
    pub fn load_module(&self, source: &ModuleSource) -> Result<Module> {
        self.check_alive()?;
        let name = source.name();
        {
            let modules = self.inner.modules.lock().unwrap();
            if let Some(m) = modules.get(&name) {
                return Ok(m.clone());
            }
        }
        let loaded = self.inner.backend.load_module(source)?;
        let module = Module::new(name.clone(), loaded);
        self.inner
            .modules
            .lock()
            .unwrap()
            .insert(name, module.clone());
        Ok(module)
    }

    /// Load bypassing the cache (used by init-time benchmarks that must
    /// measure a cold compile).
    pub fn load_module_uncached(&self, source: &ModuleSource) -> Result<Module> {
        self.check_alive()?;
        let loaded = self.inner.backend.load_module(source)?;
        Ok(Module::new(source.name(), loaded))
    }

    /// `cuModuleUnload`.
    pub fn unload_module(&self, name: &str) -> Result<()> {
        self.check_alive()?;
        self.inner
            .modules
            .lock()
            .unwrap()
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| Error::ModuleNotFound(name.to_string()))
    }

    pub fn loaded_modules(&self) -> Vec<String> {
        self.inner
            .modules
            .lock()
            .unwrap()
            .keys()
            .cloned()
            .collect()
    }

    // ---- streams ----------------------------------------------------------

    /// `cuStreamCreate`.
    pub fn create_stream(&self) -> Result<Stream> {
        self.check_alive()?;
        Ok(Stream::new())
    }

    // ---- lifecycle ---------------------------------------------------------

    /// `cuCtxDestroy`: further API calls on any clone fail.
    pub fn destroy(&self) {
        self.inner.destroyed.store(true, Ordering::Release);
        self.inner.modules.lock().unwrap().clear();
    }

    pub fn is_alive(&self) -> bool {
        !self.inner.destroyed.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::driver::device;

    fn emulator_ctx() -> Context {
        // The VTX emulator device needs no PJRT client — fast for tests.
        Context::create(&device::emulator_device().unwrap()).unwrap()
    }

    #[test]
    fn memory_roundtrip_through_context() {
        let ctx = emulator_ctx();
        let ptr = ctx.alloc_upload(&[1, 2, 3, 4]).unwrap();
        let mut out = [0u8; 4];
        ctx.download(ptr, &mut out).unwrap();
        assert_eq!(out, [1, 2, 3, 4]);
        ctx.free(ptr).unwrap();
        assert_eq!(ctx.mem_stats().unwrap().free_count, 1);
    }

    #[test]
    fn destroy_poisons_all_clones() {
        let ctx = emulator_ctx();
        let clone = ctx.clone();
        ctx.destroy();
        assert!(!clone.is_alive());
        assert!(matches!(clone.alloc(4), Err(Error::ContextDestroyed)));
        assert!(matches!(
            clone.create_stream().err().map(|e| e.to_string()),
            Some(s) if s.contains("destroyed")
        ));
    }

    #[test]
    fn unload_unknown_module_errors() {
        let ctx = emulator_ctx();
        assert!(matches!(
            ctx.unload_module("ghost"),
            Err(Error::ModuleNotFound(_))
        ));
    }
}
