//! Multi-tenant stream pool: checkout/return leasing of [`Stream`]s with
//! sticky-error quarantine.
//!
//! The double-buffered pipelines (`gpu_auto::features_batch`) and the
//! serving layer (`rust/src/serve`) both need streams that outlive one
//! call but must not be poisoned by it: under CUDA's sticky-error model
//! a failed launch leaves an error on the stream that surfaces to the
//! *next* synchronize — which, once streams are shared across requests
//! and tenants, would hand one client another client's failure (or hide
//! its own). The pool closes that hole at the return boundary:
//!
//! * [`StreamPool::checkout`] leases a stream (creating up to `capacity`
//!   lazily, then blocking until one is returned). The lease derefs to
//!   `&Stream`, so `launch_on`/`copy_h2d`/`record_event` work unchanged.
//! * Dropping the [`StreamLease`] returns the stream. A stream returned
//!   with a sticky error is **quarantined**: the pool drains it and
//!   clears the error via [`Stream::reset_error`] before it re-enters
//!   the idle set, so the next lessee starts from a clean queue. An
//!   error that has not yet surfaced when the lease drops (the op is
//!   still in flight) is caught the same way on a later return, and in
//!   the meantime remains visible to the lessee through
//!   `peek_error`/`synchronize` — either way it is reported or
//!   reclaimed, never silently recycled.
//!
//! [`StreamPoolStats`] counts leases, lazily created streams, and
//! quarantine/reclaim events for the serve layer's observability.

use std::ops::Deref;
use std::sync::{Condvar, Mutex};

use crate::driver::stream::Stream;

/// Counters of pool activity since construction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StreamPoolStats {
    /// Checkouts served (from the idle set or freshly created).
    pub leases: u64,
    /// Streams created lazily to serve a checkout.
    pub created: u64,
    /// Streams returned with a sticky error and pulled aside.
    pub quarantined: u64,
    /// Quarantined streams whose error was drained and cleared.
    pub reclaimed: u64,
}

struct Inner {
    idle: Vec<Stream>,
    /// Streams alive (idle + leased); bounded by `capacity`.
    live: usize,
}

/// A bounded pool of [`Stream`]s leased to concurrent clients.
pub struct StreamPool {
    inner: Mutex<Inner>,
    available: Condvar,
    stats: Mutex<StreamPoolStats>,
    capacity: usize,
}

impl StreamPool {
    /// A pool that lazily creates up to `capacity` streams (at least 1).
    pub fn new(capacity: usize) -> StreamPool {
        StreamPool {
            inner: Mutex::new(Inner { idle: Vec::new(), live: 0 }),
            available: Condvar::new(),
            stats: Mutex::new(StreamPoolStats::default()),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Streams currently parked in the idle set.
    pub fn idle_count(&self) -> usize {
        self.inner.lock().unwrap().idle.len()
    }

    pub fn stats(&self) -> StreamPoolStats {
        *self.stats.lock().unwrap()
    }

    /// Lease a stream: pop an idle one, create one if the pool is under
    /// capacity, otherwise block until a lease is returned. The stream
    /// goes back to the pool when the returned lease drops.
    pub fn checkout(&self) -> StreamLease<'_> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(s) = inner.idle.pop() {
                drop(inner);
                self.stats.lock().unwrap().leases += 1;
                return StreamLease { pool: self, stream: Some(s) };
            }
            if inner.live < self.capacity {
                inner.live += 1;
                drop(inner);
                let mut st = self.stats.lock().unwrap();
                st.leases += 1;
                st.created += 1;
                drop(st);
                return StreamLease { pool: self, stream: Some(Stream::new()) };
            }
            inner = self.available.wait(inner).unwrap();
        }
    }

    /// Non-blocking [`StreamPool::checkout`]: `None` when every stream
    /// is leased out and the pool is at capacity.
    pub fn try_checkout(&self) -> Option<StreamLease<'_>> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(s) = inner.idle.pop() {
            drop(inner);
            self.stats.lock().unwrap().leases += 1;
            return Some(StreamLease { pool: self, stream: Some(s) });
        }
        if inner.live < self.capacity {
            inner.live += 1;
            drop(inner);
            let mut st = self.stats.lock().unwrap();
            st.leases += 1;
            st.created += 1;
            return Some(StreamLease { pool: self, stream: Some(Stream::new()) });
        }
        None
    }

    /// Return path: quarantine-then-reclaim. A stream coming back with a
    /// visible sticky error is drained and cleared before it can serve
    /// the next lessee; a clean stream goes straight to the idle set.
    fn give_back(&self, stream: Stream) {
        if stream.peek_error().is_some() {
            let mut st = self.stats.lock().unwrap();
            st.quarantined += 1;
            drop(st);
            if stream.reset_error().is_some() {
                self.stats.lock().unwrap().reclaimed += 1;
            }
        }
        self.inner.lock().unwrap().idle.push(stream);
        self.available.notify_one();
    }
}

/// An exclusive lease on one pooled [`Stream`]; derefs to `&Stream` and
/// returns the stream to the pool on drop.
pub struct StreamLease<'p> {
    pool: &'p StreamPool,
    stream: Option<Stream>,
}

impl Deref for StreamLease<'_> {
    type Target = Stream;

    fn deref(&self) -> &Stream {
        self.stream.as_ref().expect("lease holds its stream until drop")
    }
}

impl Drop for StreamLease<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.stream.take() {
            self.pool.give_back(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    #[test]
    fn leases_recycle_the_same_streams() {
        let pool = StreamPool::new(2);
        let (a1, a2) = {
            let a = pool.checkout();
            let b = pool.checkout();
            (a.arena_id(), b.arena_id())
        };
        assert_ne!(a1, a2);
        // both returned; the next pair reuses them (no new arenas)
        let c = pool.checkout();
        let d = pool.checkout();
        assert!(
            [a1, a2].contains(&c.arena_id()) && [a1, a2].contains(&d.arena_id()),
            "warm checkouts lease the pooled streams, not fresh ones"
        );
        let st = pool.stats();
        assert_eq!(st.created, 2);
        assert_eq!(st.leases, 4);
    }

    #[test]
    fn checkout_blocks_at_capacity_until_return() {
        let pool = Arc::new(StreamPool::new(1));
        let first = pool.checkout();
        let progressed = Arc::new(AtomicU32::new(0));
        let (p2, pr2) = (pool.clone(), progressed.clone());
        let waiter = std::thread::spawn(move || {
            let lease = p2.checkout(); // blocks until `first` drops
            pr2.store(1, Ordering::SeqCst);
            drop(lease);
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(progressed.load(Ordering::SeqCst), 0, "second checkout must wait");
        assert!(pool.try_checkout().is_none());
        drop(first);
        waiter.join().unwrap();
        assert_eq!(progressed.load(Ordering::SeqCst), 1);
        assert_eq!(pool.stats().created, 1, "capacity 1 never creates a second stream");
    }

    #[test]
    fn errored_stream_is_quarantined_then_reclaimed() {
        let pool = StreamPool::new(1);
        {
            let lease = pool.checkout();
            lease.enqueue(|| Err(Error::Stream("tenant A's failure".into()))).unwrap();
            // make the error visible before the lease returns
            while lease.peek_error().is_none() {
                std::thread::yield_now();
            }
        }
        let st = pool.stats();
        assert_eq!((st.quarantined, st.reclaimed), (1, 1));
        // the reclaimed stream serves the next tenant cleanly: no stale
        // sticky error, fresh work completes
        let lease = pool.checkout();
        assert!(lease.peek_error().is_none(), "tenant B must not see tenant A's error");
        lease.enqueue(|| Ok(())).unwrap();
        lease.synchronize().unwrap();
    }

    #[test]
    fn late_surfacing_error_is_reported_or_reclaimed_never_recycled() {
        let pool = StreamPool::new(1);
        {
            // the failing op is still queued behind a sleep when the
            // lease returns, so quarantine cannot trigger yet
            let lease = pool.checkout();
            lease
                .enqueue(|| {
                    std::thread::sleep(std::time::Duration::from_millis(15));
                    Err(Error::Stream("late failure".into()))
                })
                .unwrap();
        }
        let lease = pool.checkout();
        // the sticky model still reports it to whoever joins the stream…
        let err = lease.synchronize().unwrap_err();
        assert!(err.to_string().contains("late failure"));
        drop(lease);
        // …and once consumed (or caught at a later return) the stream is
        // clean again
        let lease = pool.checkout();
        lease.enqueue(|| Ok(())).unwrap();
        lease.synchronize().unwrap();
    }
}
