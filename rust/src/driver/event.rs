//! Events (`cuEventRecord` / `cuEventSynchronize` / `cuEventElapsedTime`).
//!
//! Events are recorded into streams; synchronizing blocks until the
//! stream's worker has reached the record point.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};

#[derive(Default)]
struct EventState {
    recorded: Option<Instant>,
}

/// A timing/synchronization event.
#[derive(Clone)]
pub struct Event {
    state: Arc<(Mutex<EventState>, Condvar)>,
}

impl Default for Event {
    fn default() -> Self {
        Self::new()
    }
}

impl Event {
    pub fn new() -> Self {
        Event { state: Arc::new((Mutex::new(EventState::default()), Condvar::new())) }
    }

    /// Mark the event as reached *now*. Streams call this from their worker
    /// thread; host code can call it directly for inline recording.
    pub fn record_now(&self) {
        let (lock, cv) = &*self.state;
        lock.lock().unwrap().recorded = Some(Instant::now());
        cv.notify_all();
    }

    /// `cuEventQuery`: has the event been reached?
    pub fn query(&self) -> bool {
        self.state.0.lock().unwrap().recorded.is_some()
    }

    /// `cuEventSynchronize`: block until recorded.
    pub fn synchronize(&self) {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        while st.recorded.is_none() {
            st = cv.wait(st).unwrap();
        }
    }

    /// Bounded [`Event::synchronize`]: block until recorded or until
    /// `timeout` elapses. Returns `true` when the event was recorded in
    /// time — the launch watchdog (`HLGPU_WATCHDOG_MS`) builds on this.
    pub fn wait_timeout(&self, timeout: std::time::Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        while st.recorded.is_none() {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        true
    }

    fn instant(&self) -> Result<Instant> {
        self.state
            .0
            .lock()
            .unwrap()
            .recorded
            .ok_or(Error::EventNotRecorded)
    }

    /// `cuEventElapsedTime`: milliseconds between two recorded events.
    pub fn elapsed_ms(start: &Event, end: &Event) -> Result<f64> {
        let s = start.instant()?;
        let e = end.instant()?;
        Ok(e.saturating_duration_since(s).as_secs_f64() * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unrecorded_event_errors() {
        let a = Event::new();
        let b = Event::new();
        assert!(!a.query());
        assert!(matches!(
            Event::elapsed_ms(&a, &b),
            Err(Error::EventNotRecorded)
        ));
    }

    #[test]
    fn elapsed_between_records() {
        let a = Event::new();
        let b = Event::new();
        a.record_now();
        std::thread::sleep(std::time::Duration::from_millis(5));
        b.record_now();
        let ms = Event::elapsed_ms(&a, &b).unwrap();
        assert!(ms >= 4.0, "elapsed {ms} ms");
        assert!(a.query() && b.query());
    }

    #[test]
    fn wait_timeout_times_out_then_succeeds() {
        let ev = Event::new();
        let t0 = Instant::now();
        assert!(!ev.wait_timeout(std::time::Duration::from_millis(20)));
        assert!(t0.elapsed() >= std::time::Duration::from_millis(20));
        ev.record_now();
        assert!(ev.wait_timeout(std::time::Duration::from_millis(1)));
    }

    #[test]
    fn synchronize_waits_for_cross_thread_record() {
        let ev = Event::new();
        let ev2 = ev.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            ev2.record_now();
        });
        ev.synchronize();
        assert!(ev.query());
        t.join().unwrap();
    }
}
