//! Backend abstraction: what a "device" must provide for the driver API.
//!
//! The paper ships two execution paths — real CUDA hardware and the GPU
//! Ocelot emulator — behind the same driver API (§5). We mirror that with
//! a [`Backend`] trait implemented by the PJRT runtime
//! ([`crate::runtime::PjrtBackend`]) and by the VTX emulator
//! ([`crate::emulator::VtxBackend`]).

use std::sync::Arc;

use crate::driver::launch::{KernelArg, LaunchConfig, LaunchReport};
use crate::driver::memory::MemoryPool;
use crate::error::Result;

/// Tensor I/O description used by module-level executables (the PJRT
/// backend needs shapes to build literals from raw device buffers).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn f32(shape: &[usize]) -> Self {
        TensorSpec { dtype: "f32".into(), shape: shape.to_vec() }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        let elem = match self.dtype.as_str() {
            "f64" => 8,
            _ => 4,
        };
        self.numel() * elem
    }

    pub fn signature(&self) -> String {
        let dims: Vec<String> = self.shape.iter().map(|d| d.to_string()).collect();
        format!("{}[{}]", self.dtype, dims.join(","))
    }
}

/// Source code of a module, the `cuModuleLoad` payload analog.
pub enum ModuleSource {
    /// AOT HLO text (the PTX analog of this stack) plus its I/O contract.
    HloText {
        name: String,
        text: String,
        inputs: Vec<TensorSpec>,
        outputs: Vec<TensorSpec>,
    },
    /// HLO text loaded lazily from a file path.
    HloFile {
        name: String,
        path: std::path::PathBuf,
        inputs: Vec<TensorSpec>,
        outputs: Vec<TensorSpec>,
    },
    /// VTX virtual-ISA kernels (the emulator path).
    Vtx { kernels: Vec<crate::emulator::isa::Kernel> },
}

impl ModuleSource {
    pub fn name(&self) -> String {
        match self {
            ModuleSource::HloText { name, .. } | ModuleSource::HloFile { name, .. } => {
                name.clone()
            }
            ModuleSource::Vtx { kernels } => kernels
                .first()
                .map(|k| k.name.clone())
                .unwrap_or_else(|| "<empty>".into()),
        }
    }
}

/// A device execution backend.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Load (JIT-compile) a module. The expensive step — the driver caches
    /// the result per context, and the coordinator per signature.
    fn load_module(&self, source: &ModuleSource) -> Result<Arc<dyn LoadedModule>>;
}

/// A loaded module: a set of named functions.
pub trait LoadedModule: Send + Sync {
    /// Resolve a kernel handle (`cuModuleGetFunction`).
    fn function(&self, name: &str) -> Result<Arc<dyn DeviceFunction>>;

    /// Names of the kernels in this module.
    fn function_names(&self) -> Vec<String>;
}

/// A launchable kernel (`CUfunction`).
pub trait DeviceFunction: Send + Sync {
    /// Execute with the given configuration. Device buffers are resolved
    /// through `mem`. Synchronous from the caller's point of view; streams
    /// provide asynchrony above this layer. Backends may execute the
    /// grid's blocks concurrently on an internal worker pool (the VTX
    /// emulator does) — block independence is part of the programming
    /// model, exactly as on real hardware.
    fn launch(&self, cfg: &LaunchConfig, args: &[KernelArg], mem: &MemoryPool) -> Result<()>;

    /// Like [`DeviceFunction::launch`], additionally reporting execution
    /// statistics. Backends without instrumentation fall back to a launch
    /// plus a zeroed report.
    fn launch_report(
        &self,
        cfg: &LaunchConfig,
        args: &[KernelArg],
        mem: &MemoryPool,
    ) -> Result<LaunchReport> {
        self.launch(cfg, args, mem)?;
        Ok(LaunchReport::default())
    }

    /// Human-readable name, for error messages and profiling.
    fn name(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_sizes() {
        let s = TensorSpec::f32(&[90, 128]);
        assert_eq!(s.numel(), 11520);
        assert_eq!(s.byte_len(), 46080);
        assert_eq!(s.signature(), "f32[90,128]");
        let d = TensorSpec { dtype: "f64".into(), shape: vec![4] };
        assert_eq!(d.byte_len(), 32);
    }

    #[test]
    fn scalar_spec_signature() {
        let s = TensorSpec::f32(&[]);
        assert_eq!(s.signature(), "f32[]");
        assert_eq!(s.numel(), 1);
        assert_eq!(s.byte_len(), 4);
    }
}
