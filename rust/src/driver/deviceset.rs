//! `DeviceSet`: a scheduling group of devices with per-member contexts
//! and least-outstanding-work placement.
//!
//! A set owns one [`Context`] per member device — each with its own
//! module cache, `MemoryPool` arenas, and streams — and tracks, per
//! member, the outstanding work weight (images currently placed there),
//! cumulative shard/image counts, and busy time. Placement is a simple
//! least-outstanding-work heuristic with lowest-ordinal tie-break:
//! callers place shards **serially in deterministic chunk order**, so
//! for a fixed set size the (chunk → member) assignment is a pure
//! function of the chunk weights — which is what lets sharded results
//! be reassembled bitwise-identically to single-device execution (see
//! `docs/devices.md`).
//!
//! The sharded `features_batch` path (`tracetransform::impls::gpu_auto`)
//! and the serve layer's worker pinning both build on this type.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;

use crate::driver::context::Context;
use crate::driver::device::{self, BackendKind, Device};
use crate::driver::faults;
use crate::error::{Error, Result};

/// Per-member health, driven by observed pipeline errors (see
/// `docs/faults.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Normal placement target.
    Healthy,
    /// A transient failure was observed here recently; placed only when
    /// no healthy member remains, reclaimed by [`DeviceSet::probe`].
    Quarantined,
    /// A device loss was observed here; excluded from placement until
    /// `Device::reset` clears the sticky mark *and* a probe succeeds.
    Lost,
}

const HEALTHY: u8 = 0;
const QUARANTINED: u8 = 1;
const LOST: u8 = 2;

fn health_from(v: u8) -> Health {
    match v {
        QUARANTINED => Health::Quarantined,
        LOST => Health::Lost,
        _ => Health::Healthy,
    }
}

struct Member {
    ctx: Context,
    /// Work weight currently placed on this member (e.g. images).
    outstanding: AtomicU64,
    /// Cumulative shards placed here.
    shards: AtomicU64,
    /// Cumulative images recorded here.
    images: AtomicU64,
    /// Cumulative busy time recorded here (worker-reported).
    busy_ns: AtomicU64,
    /// Health state (`HEALTHY`/`QUARANTINED`/`LOST`).
    health: AtomicU8,
}

/// Per-member scheduling counters, as reported by [`DeviceSet::stats`].
#[derive(Clone, Debug)]
pub struct DeviceSetStats {
    pub ordinal: usize,
    pub shards: u64,
    pub images: u64,
    pub outstanding: u64,
    pub busy_ns: u64,
    pub health: Health,
}

/// A scheduling group of devices. Cheap to clone (shared members).
#[derive(Clone)]
pub struct DeviceSet {
    members: Arc<Vec<Member>>,
}

impl DeviceSet {
    /// Build a set with one fresh context per device.
    pub fn new(devices: &[Device]) -> Result<DeviceSet> {
        if devices.is_empty() {
            return Err(Error::Other("a DeviceSet needs at least one device".into()));
        }
        let mut members = Vec::with_capacity(devices.len());
        for d in devices {
            members.push(Member {
                ctx: Context::create(d)?,
                outstanding: AtomicU64::new(0),
                shards: AtomicU64::new(0),
                images: AtomicU64::new(0),
                busy_ns: AtomicU64::new(0),
                // A set built over an already-lost ordinal starts that
                // member excluded rather than discovering it the hard way.
                health: AtomicU8::new(if faults::is_lost(d.ordinal) { LOST } else { HEALTHY }),
            });
        }
        Ok(DeviceSet { members: Arc::new(members) })
    }

    /// A set of `n` VTX emulator devices: the visible emulator devices
    /// first (in ordinal order), then synthesized devices at ordinals
    /// past the table for any shortfall — so `DeviceSet::emulator(4)`
    /// works regardless of `HLGPU_DEVICES`.
    pub fn emulator(n: usize) -> Result<DeviceSet> {
        if n == 0 {
            return Err(Error::Other("a DeviceSet needs at least one device".into()));
        }
        let mut devs = device::emulator_devices();
        devs.truncate(n);
        let have = devs.len();
        let next = device::device_count();
        for i in have..n {
            devs.push(Device::emulator_at(next + (i - have), None));
        }
        DeviceSet::new(&devs)
    }

    /// Number of member devices.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// The member context at index `i` (panics if out of range).
    pub fn context(&self, i: usize) -> &Context {
        &self.members[i].ctx
    }

    /// The member device at index `i`.
    pub fn device(&self, i: usize) -> &Device {
        self.members[i].ctx.device()
    }

    /// Place a shard of the given weight: picks the member with the
    /// least outstanding work (lowest index on ties), adds the weight,
    /// and returns the member index. Callers placing shards serially in
    /// a deterministic order get a deterministic assignment.
    ///
    /// Placement is health-aware: healthy members are preferred, then
    /// quarantined ones; lost members are only chosen when *every*
    /// member is lost (the caller will surface the failure). With all
    /// members healthy — the fault-free case — the assignment is
    /// identical to the original least-outstanding heuristic.
    pub fn place(&self, weight: u64) -> usize {
        let best = |eligible: fn(u8) -> bool| {
            self.members
                .iter()
                .enumerate()
                .filter(|(_, m)| eligible(m.health.load(Ordering::Relaxed)))
                .min_by_key(|(idx, m)| (m.outstanding.load(Ordering::Relaxed), *idx))
                .map(|(idx, _)| idx)
        };
        let i = best(|h| h == HEALTHY)
            .or_else(|| best(|h| h != LOST))
            .or_else(|| best(|_| true))
            .unwrap_or(0);
        self.members[i].outstanding.fetch_add(weight, Ordering::Relaxed);
        self.members[i].shards.fetch_add(1, Ordering::Relaxed);
        i
    }

    /// The least-loaded *healthy* member, if any — the serve layer's
    /// re-pinning target after a worker observes a device loss. Unlike
    /// [`DeviceSet::place`] this mutates no counters.
    pub fn pick_healthy(&self) -> Option<usize> {
        self.members
            .iter()
            .enumerate()
            .filter(|(_, m)| m.health.load(Ordering::Relaxed) == HEALTHY)
            .min_by_key(|(idx, m)| (m.outstanding.load(Ordering::Relaxed), *idx))
            .map(|(idx, _)| idx)
    }

    /// Member `i`'s current health.
    pub fn health(&self, i: usize) -> Health {
        health_from(self.members[i].health.load(Ordering::Relaxed))
    }

    /// Drive member `i`'s health from an error observed while running
    /// work placed there: a device loss marks it `Lost` (sticky at set
    /// level too — only [`DeviceSet::probe`] after `Device::reset`
    /// brings it back); a transient failure quarantines a healthy
    /// member (never downgrading `Lost`). Non-transient errors — bad
    /// arguments, type mismatches — say nothing about device health and
    /// leave it unchanged.
    pub fn observe_error(&self, i: usize, e: &Error) {
        let m = &self.members[i];
        if e.is_device_loss() {
            m.health.store(LOST, Ordering::Relaxed);
        } else if e.is_transient() {
            let _ = m.health.compare_exchange(
                HEALTHY,
                QUARANTINED,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
        }
    }

    /// Probe unhealthy members and reclaim the ones that answer: a
    /// member returns to `Healthy` once its ordinal is no longer
    /// sticky-lost (see `Device::reset`) and a trivial alloc/free on
    /// its context succeeds. Returns how many members were reclaimed.
    pub fn probe(&self) -> usize {
        let mut reclaimed = 0;
        for m in self.members.iter() {
            if m.health.load(Ordering::Relaxed) == HEALTHY {
                continue;
            }
            if faults::is_lost(m.ctx.device().ordinal) {
                continue;
            }
            let ok = m.ctx.alloc(64).and_then(|p| m.ctx.free(p)).is_ok();
            if ok {
                m.health.store(HEALTHY, Ordering::Relaxed);
                reclaimed += 1;
            }
        }
        reclaimed
    }

    /// Retire a previously placed shard's weight from member `i`.
    pub fn complete(&self, i: usize, weight: u64) {
        self.members[i].outstanding.fetch_sub(weight, Ordering::Relaxed);
    }

    /// Record `n` images processed on member `i` (for utilization
    /// reporting).
    pub fn record_images(&self, i: usize, n: u64) {
        self.members[i].images.fetch_add(n, Ordering::Relaxed);
    }

    /// Record busy time on member `i`.
    pub fn record_busy(&self, i: usize, ns: u64) {
        self.members[i].busy_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Per-member scheduling counters.
    pub fn stats(&self) -> Vec<DeviceSetStats> {
        self.members
            .iter()
            .map(|m| DeviceSetStats {
                ordinal: m.ctx.device().ordinal,
                shards: m.shards.load(Ordering::Relaxed),
                images: m.images.load(Ordering::Relaxed),
                outstanding: m.outstanding.load(Ordering::Relaxed),
                busy_ns: m.busy_ns.load(Ordering::Relaxed),
                health: health_from(m.health.load(Ordering::Relaxed)),
            })
            .collect()
    }

    /// Shard imbalance: max over mean of per-member image counts
    /// (1.0 = perfectly balanced; 0.0 when nothing ran).
    pub fn imbalance(&self) -> f64 {
        let counts: Vec<u64> =
            self.members.iter().map(|m| m.images.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let mean = total as f64 / counts.len() as f64;
        counts.iter().copied().max().unwrap_or(0) as f64 / mean
    }
}

impl std::fmt::Debug for DeviceSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let ords: Vec<usize> = self.members.iter().map(|m| m.ctx.device().ordinal).collect();
        write!(f, "DeviceSet({ords:?})")
    }
}

/// Convenience: is this set made of emulator devices only? (The sharded
/// batch path requires it — PJRT members share a process-global client.)
impl DeviceSet {
    pub fn all_emulator(&self) -> bool {
        self.members.iter().all(|m| m.ctx.device().kind == BackendKind::VtxEmulator)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emulator_set_has_distinct_contexts() {
        let set = DeviceSet::emulator(3).unwrap();
        assert_eq!(set.len(), 3);
        assert!(set.all_emulator());
        // Every member context owns its own pool.
        for i in 0..set.len() {
            for j in (i + 1)..set.len() {
                let a = set.context(i).memory_arc().unwrap();
                let b = set.context(j).memory_arc().unwrap();
                assert!(!Arc::ptr_eq(&a, &b), "members {i} and {j} share a pool");
            }
        }
        // Ordinals are distinct.
        let mut ords: Vec<usize> = (0..set.len()).map(|i| set.device(i).ordinal).collect();
        ords.dedup();
        assert_eq!(ords.len(), 3);
    }

    /// A set over synthesized ordinals far past the visible table, so
    /// an ambient chaos schedule (`HLGPU_FAULTS` targeting real
    /// ordinals) can never mark a member lost under these tests. Each
    /// test passes a distinct `slot` so tests that mark their own
    /// members lost cannot collide with tests running in parallel.
    fn quiet_set(slot: usize, n: usize) -> DeviceSet {
        let base = device::device_count() + 100 + slot * 16;
        let devs: Vec<Device> = (0..n).map(|i| Device::emulator_at(base + i, None)).collect();
        DeviceSet::new(&devs).unwrap()
    }

    #[test]
    fn placement_is_least_outstanding_deterministic() {
        let set = quiet_set(0, 2);
        // Equal load: ties break to the lowest index.
        assert_eq!(set.place(10), 0);
        assert_eq!(set.place(10), 1);
        // Member 1 finishes first; the next shards chase the lighter member.
        set.complete(1, 10);
        assert_eq!(set.place(4), 1); // 1 at 0 < 10
        assert_eq!(set.place(4), 1); // 1 at 4 < 10
        assert_eq!(set.place(1), 1); // 1 at 8 < 10
        let st = set.stats();
        assert_eq!(st[0].shards + st[1].shards, 5);
        assert_eq!(st[0].outstanding, 10);
        assert_eq!(st[1].outstanding, 9);
    }

    #[test]
    fn imbalance_and_image_accounting() {
        let set = quiet_set(1, 2);
        assert_eq!(set.imbalance(), 0.0);
        set.record_images(0, 6);
        set.record_images(1, 2);
        let st = set.stats();
        assert_eq!(st[0].images, 6);
        assert_eq!(st[1].images, 2);
        assert!((set.imbalance() - 1.5).abs() < 1e-12);
        set.record_images(1, 4);
        assert!((set.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn health_drives_placement_and_probe_reclaims() {
        let set = quiet_set(2, 3);
        for i in 0..3 {
            assert_eq!(set.health(i), Health::Healthy);
            assert_eq!(set.stats()[i].health, Health::Healthy);
        }
        // A transient failure quarantines; placement prefers the others.
        set.observe_error(0, &Error::Stream("injected h2d fault on device 0".into()));
        assert_eq!(set.health(0), Health::Quarantined);
        assert_eq!(set.place(1), 1);
        assert_eq!(set.place(1), 2);
        // Non-transient errors say nothing about health.
        set.observe_error(1, &Error::Type("bad dtype".into()));
        assert_eq!(set.health(1), Health::Healthy);
        // A probe reclaims the quarantined member (nothing sticky-lost).
        assert_eq!(set.probe(), 1);
        assert_eq!(set.health(0), Health::Healthy);
        set.complete(1, 1);
        set.complete(2, 1);
    }

    #[test]
    fn lost_member_is_excluded_until_reset_and_probe() {
        let set = quiet_set(3, 2);
        let ord0 = set.device(0).ordinal;
        faults::mark_lost(ord0);
        set.observe_error(0, &Error::DeviceLost(ord0));
        assert_eq!(set.health(0), Health::Lost);
        // Placement and re-pinning both skip the lost member.
        for _ in 0..3 {
            assert_eq!(set.place(1), 1);
        }
        assert_eq!(set.pick_healthy(), Some(1));
        // Probe alone cannot reclaim it while the sticky mark stands...
        assert_eq!(set.probe(), 0);
        assert_eq!(set.health(0), Health::Lost);
        // ...but reset + probe brings it back.
        set.device(0).reset();
        assert_eq!(set.probe(), 1);
        assert_eq!(set.health(0), Health::Healthy);
        // A set constructed over a lost ordinal starts excluded.
        faults::mark_lost(ord0);
        let set2 = DeviceSet::new(&[set.device(0).clone(), set.device(1).clone()]).unwrap();
        assert_eq!(set2.health(0), Health::Lost);
        assert_eq!(set2.place(1), 1);
        faults::reset_device(ord0);
    }

    #[test]
    fn all_lost_set_still_places() {
        let set = quiet_set(4, 2);
        for i in 0..2 {
            set.observe_error(i, &Error::DeviceLost(set.device(i).ordinal));
        }
        // Callers still get a member back (and will surface the typed
        // loss); least-outstanding order holds within the lost tier.
        assert_eq!(set.place(1), 0);
        assert_eq!(set.place(1), 1);
    }

    #[test]
    fn empty_set_rejected() {
        assert!(DeviceSet::new(&[]).is_err());
        assert!(DeviceSet::emulator(0).is_err());
    }

    /// Per-member capacities are independent (the `HLGPU_DEV_MEM` shape):
    /// a small member OOMs on a request its sibling absorbs.
    #[test]
    fn asymmetric_capacities_oom_independently() {
        let base = device::device_count();
        let set = DeviceSet::new(&[
            Device::emulator_at(base + 10, Some(1 << 20)),
            Device::emulator_at(base + 11, None),
        ])
        .unwrap();
        let err = set.context(0).alloc(2 << 20).unwrap_err();
        assert!(matches!(err, Error::OutOfMemory { .. }), "{err:?}");
        let p = set.context(1).alloc(2 << 20).unwrap();
        set.context(1).free(p).unwrap();
    }
}
