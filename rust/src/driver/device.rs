//! Device enumeration (`cuDeviceGet` / `cuDeviceGetAttribute` analog).
//!
//! The device table is a configurable registry: `HLGPU_DEVICES=N`
//! exposes N independent VTX emulator devices (ordinals 1..=N) next to
//! the PJRT device at ordinal 0. Each emulator device gets its own
//! worker pool (see `emulator::sched::device_pool`), and every
//! `Context` created on it gets its own module cache, `MemoryPool`
//! arenas, and streams — so the emulator devices are independent in the
//! same sense real GPUs are. `HLGPU_DEV_MEM` overrides per-ordinal
//! memory capacity (comma-separated sizes, `k`/`m`/`g` suffixes; empty
//! entries keep the default), making asymmetric-capacity OOM behavior
//! testable. See `docs/devices.md`.

use std::sync::{Arc, OnceLock};

use crate::driver::backend::Backend;
use crate::error::{Error, Result};

/// Which execution backend a device maps to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT CPU client executing AOT HLO artifacts (the "real hardware"
    /// path of this stack).
    Pjrt,
    /// VTX virtual-ISA interpreter (the GPU Ocelot emulator analog).
    VtxEmulator,
}

/// Static device attributes (a subset of `CUdevice_attribute`).
#[derive(Clone, Debug)]
pub struct DeviceAttributes {
    pub max_threads_per_block: u32,
    pub max_shared_mem_per_block: usize,
    pub warp_size: u32,
    pub total_memory: usize,
}

impl Default for DeviceAttributes {
    fn default() -> Self {
        DeviceAttributes {
            max_threads_per_block: 1024,
            max_shared_mem_per_block: 48 << 10,
            warp_size: 32,
            total_memory: crate::driver::memory::DEFAULT_CAPACITY,
        }
    }
}

/// A visible accelerator device.
#[derive(Clone)]
pub struct Device {
    pub ordinal: usize,
    pub name: String,
    pub kind: BackendKind,
    pub attributes: DeviceAttributes,
}

impl std::fmt::Debug for Device {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Device({}: {} [{:?}])", self.ordinal, self.name, self.kind)
    }
}

static DEVICES: OnceLock<Vec<Device>> = OnceLock::new();

/// Upper bound on `HLGPU_DEVICES` — a typo guard, not a real limit.
const MAX_EMULATOR_DEVICES: usize = 64;

/// Number of VTX emulator devices to expose: `HLGPU_DEVICES` clamped to
/// `1..=MAX_EMULATOR_DEVICES`; 1 when unset or unparseable.
pub(crate) fn emulator_count_from_env() -> usize {
    std::env::var("HLGPU_DEVICES")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .map(|n| n.clamp(1, MAX_EMULATOR_DEVICES))
        .unwrap_or(1)
}

/// Parse an `HLGPU_DEV_MEM` value: comma-separated per-ordinal sizes
/// (`k`/`m`/`g` suffixes, powers of 1024). Entry i applies to ordinal
/// i; empty or malformed entries keep that ordinal's default.
fn parse_device_memory(v: &str) -> Vec<Option<usize>> {
    v.split(',').map(crate::driver::memory::parse_mem_size).collect()
}

fn device_table() -> &'static [Device] {
    DEVICES.get_or_init(|| {
        let mem = std::env::var("HLGPU_DEV_MEM")
            .map(|v| parse_device_memory(&v))
            .unwrap_or_default();
        let attrs_for = |ordinal: usize| {
            let mut a = DeviceAttributes::default();
            if let Some(&Some(bytes)) = mem.get(ordinal) {
                a.total_memory = bytes;
            }
            a
        };
        let mut table = vec![Device {
            ordinal: 0,
            name: "PJRT CPU (simulated accelerator)".into(),
            kind: BackendKind::Pjrt,
            attributes: attrs_for(0),
        }];
        for i in 0..emulator_count_from_env() {
            table.push(Device {
                ordinal: 1 + i,
                name: if i == 0 {
                    "VTX emulator (Ocelot analog)".into()
                } else {
                    format!("VTX emulator {i} (Ocelot analog)")
                },
                kind: BackendKind::VtxEmulator,
                attributes: attrs_for(1 + i),
            });
        }
        table
    })
}

/// `cuDeviceGetCount`.
pub fn device_count() -> usize {
    device_table().len()
}

/// `cuDeviceGet`.
pub fn device(ordinal: usize) -> Result<Device> {
    device_table()
        .get(ordinal)
        .cloned()
        .ok_or(Error::InvalidDevice(ordinal))
}

/// All visible devices.
pub fn devices() -> Vec<Device> {
    device_table().to_vec()
}

/// The first device backed by the VTX emulator. Use this instead of a
/// hardcoded ordinal — the device table's layout is not part of the API
/// contract.
pub fn emulator_device() -> Result<Device> {
    device_table()
        .iter()
        .find(|d| d.kind == BackendKind::VtxEmulator)
        .cloned()
        .ok_or_else(|| Error::Other("no VTX emulator device visible".into()))
}

/// All visible VTX emulator devices, in ordinal order.
pub fn emulator_devices() -> Vec<Device> {
    device_table()
        .iter()
        .filter(|d| d.kind == BackendKind::VtxEmulator)
        .cloned()
        .collect()
}

/// The first PJRT-backed device (the simulated accelerator executing AOT
/// artifacts).
pub fn pjrt_device() -> Result<Device> {
    device_table()
        .iter()
        .find(|d| d.kind == BackendKind::Pjrt)
        .cloned()
        .ok_or_else(|| Error::Other("no PJRT device visible".into()))
}

impl Device {
    /// Instantiate the execution backend for this device. PJRT backends
    /// share a process-global client (PJRT clients are heavyweight);
    /// each emulator device gets a backend bound to that device's
    /// worker pool.
    pub fn backend(&self) -> Result<Arc<dyn Backend>> {
        match self.kind {
            BackendKind::Pjrt => Ok(crate::runtime::PjrtBackend::global()?),
            BackendKind::VtxEmulator => {
                Ok(Arc::new(crate::emulator::VtxBackend::for_device(self.ordinal)))
            }
        }
    }

    /// Synthesize a VTX emulator device descriptor outside the visible
    /// table — for `DeviceSet` members beyond `HLGPU_DEVICES`, or tests
    /// that need a device with asymmetric memory capacity. The ordinal
    /// should not collide with a visible device of a different kind
    /// (ordinal 0 is always PJRT).
    pub fn emulator_at(ordinal: usize, total_memory: Option<usize>) -> Device {
        let mut attributes = DeviceAttributes::default();
        if let Some(bytes) = total_memory {
            attributes.total_memory = bytes;
        }
        Device {
            ordinal,
            name: format!("VTX emulator {ordinal} (synthesized)"),
            kind: BackendKind::VtxEmulator,
            attributes,
        }
    }

    /// `cuDeviceReset` analog for device loss: clear this ordinal's
    /// sticky lost mark (see `crate::driver::faults` and
    /// `docs/faults.md`). Contexts over the device work again
    /// afterwards, but memory contents are not guaranteed — in-flight
    /// work at the moment of loss was abandoned. A `DeviceSet` member
    /// additionally needs `DeviceSet::probe` to return to placement.
    pub fn reset(&self) {
        crate::driver::faults::reset_device(self.ordinal);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumeration() {
        // The table layout depends on HLGPU_DEVICES, which CI varies.
        let emus = emulator_count_from_env();
        assert_eq!(device_count(), 1 + emus);
        assert_eq!(device(0).unwrap().kind, BackendKind::Pjrt);
        for i in 1..=emus {
            let d = device(i).unwrap();
            assert_eq!(d.kind, BackendKind::VtxEmulator);
            assert_eq!(d.ordinal, i);
        }
        let past = device_count() + 7;
        assert!(matches!(device(past), Err(Error::InvalidDevice(p)) if p == past));
        assert_eq!(emulator_devices().len(), emus);
    }

    #[test]
    fn named_device_lookups() {
        assert_eq!(emulator_device().unwrap().kind, BackendKind::VtxEmulator);
        assert_eq!(pjrt_device().unwrap().kind, BackendKind::Pjrt);
    }

    #[test]
    fn attributes_sane() {
        let d = device(0).unwrap();
        assert!(d.attributes.max_threads_per_block >= 256);
        assert!(d.attributes.max_shared_mem_per_block >= 16 << 10);
    }

    #[test]
    fn per_device_memory_parses() {
        assert_eq!(parse_device_memory("1g,2g"), vec![Some(1 << 30), Some(2 << 30)]);
        assert_eq!(parse_device_memory(",512m"), vec![None, Some(512 << 20)]);
        assert_eq!(parse_device_memory("nope,4096"), vec![None, Some(4096)]);
    }

    #[test]
    fn synthesized_emulator_device() {
        let d = Device::emulator_at(7, Some(1 << 20));
        assert_eq!(d.ordinal, 7);
        assert_eq!(d.kind, BackendKind::VtxEmulator);
        assert_eq!(d.attributes.total_memory, 1 << 20);
        let d2 = Device::emulator_at(3, None);
        assert_eq!(d2.attributes.total_memory, crate::driver::memory::DEFAULT_CAPACITY);
    }
}
