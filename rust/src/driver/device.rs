//! Device enumeration (`cuDeviceGet` / `cuDeviceGetAttribute` analog).

use std::sync::{Arc, OnceLock};

use crate::driver::backend::Backend;
use crate::error::{Error, Result};

/// Which execution backend a device maps to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT CPU client executing AOT HLO artifacts (the "real hardware"
    /// path of this stack).
    Pjrt,
    /// VTX virtual-ISA interpreter (the GPU Ocelot emulator analog).
    VtxEmulator,
}

/// Static device attributes (a subset of `CUdevice_attribute`).
#[derive(Clone, Debug)]
pub struct DeviceAttributes {
    pub max_threads_per_block: u32,
    pub max_shared_mem_per_block: usize,
    pub warp_size: u32,
    pub total_memory: usize,
}

impl Default for DeviceAttributes {
    fn default() -> Self {
        DeviceAttributes {
            max_threads_per_block: 1024,
            max_shared_mem_per_block: 48 << 10,
            warp_size: 32,
            total_memory: crate::driver::memory::DEFAULT_CAPACITY,
        }
    }
}

/// A visible accelerator device.
#[derive(Clone)]
pub struct Device {
    pub ordinal: usize,
    pub name: String,
    pub kind: BackendKind,
    pub attributes: DeviceAttributes,
}

impl std::fmt::Debug for Device {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Device({}: {} [{:?}])", self.ordinal, self.name, self.kind)
    }
}

static DEVICES: OnceLock<Vec<Device>> = OnceLock::new();

fn device_table() -> &'static [Device] {
    DEVICES.get_or_init(|| {
        vec![
            Device {
                ordinal: 0,
                name: "PJRT CPU (simulated accelerator)".into(),
                kind: BackendKind::Pjrt,
                attributes: DeviceAttributes::default(),
            },
            Device {
                ordinal: 1,
                name: "VTX emulator (Ocelot analog)".into(),
                kind: BackendKind::VtxEmulator,
                attributes: DeviceAttributes::default(),
            },
        ]
    })
}

/// `cuDeviceGetCount`.
pub fn device_count() -> usize {
    device_table().len()
}

/// `cuDeviceGet`.
pub fn device(ordinal: usize) -> Result<Device> {
    device_table()
        .get(ordinal)
        .cloned()
        .ok_or(Error::InvalidDevice(ordinal))
}

/// All visible devices.
pub fn devices() -> Vec<Device> {
    device_table().to_vec()
}

/// The first device backed by the VTX emulator. Use this instead of a
/// hardcoded ordinal — the device table's layout is not part of the API
/// contract.
pub fn emulator_device() -> Result<Device> {
    device_table()
        .iter()
        .find(|d| d.kind == BackendKind::VtxEmulator)
        .cloned()
        .ok_or_else(|| Error::Other("no VTX emulator device visible".into()))
}

/// The first PJRT-backed device (the simulated accelerator executing AOT
/// artifacts).
pub fn pjrt_device() -> Result<Device> {
    device_table()
        .iter()
        .find(|d| d.kind == BackendKind::Pjrt)
        .cloned()
        .ok_or_else(|| Error::Other("no PJRT device visible".into()))
}

impl Device {
    /// Instantiate the execution backend for this device. PJRT backends
    /// share a process-global client (PJRT clients are heavyweight).
    pub fn backend(&self) -> Result<Arc<dyn Backend>> {
        match self.kind {
            BackendKind::Pjrt => Ok(crate::runtime::PjrtBackend::global()?),
            BackendKind::VtxEmulator => Ok(Arc::new(crate::emulator::VtxBackend::new())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumeration() {
        assert_eq!(device_count(), 2);
        assert_eq!(device(0).unwrap().kind, BackendKind::Pjrt);
        assert_eq!(device(1).unwrap().kind, BackendKind::VtxEmulator);
        assert!(matches!(device(9), Err(Error::InvalidDevice(9))));
    }

    #[test]
    fn named_device_lookups() {
        assert_eq!(emulator_device().unwrap().kind, BackendKind::VtxEmulator);
        assert_eq!(pjrt_device().unwrap().kind, BackendKind::Pjrt);
    }

    #[test]
    fn attributes_sane() {
        let d = device(0).unwrap();
        assert!(d.attributes.max_threads_per_block >= 256);
        assert!(d.attributes.max_shared_mem_per_block >= 16 << 10);
    }
}
