//! Modules and functions (`cuModuleLoad` / `cuModuleGetFunction`).

use std::sync::Arc;

use crate::driver::backend::{DeviceFunction, LoadedModule};
use crate::driver::launch::{KernelArg, LaunchConfig};
use crate::driver::memory::MemoryPool;
use crate::error::Result;

/// A loaded code module, holding one or more launchable kernels.
#[derive(Clone)]
pub struct Module {
    name: String,
    inner: Arc<dyn LoadedModule>,
}

impl Module {
    pub(crate) fn new(name: String, inner: Arc<dyn LoadedModule>) -> Self {
        Module { name, inner }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// `cuModuleGetFunction`.
    pub fn function(&self, name: &str) -> Result<Function> {
        Ok(Function { inner: self.inner.function(name)? })
    }

    pub fn function_names(&self) -> Vec<String> {
        self.inner.function_names()
    }
}

/// A launchable kernel handle (`CUfunction`).
#[derive(Clone)]
pub struct Function {
    inner: Arc<dyn DeviceFunction>,
}

impl Function {
    /// `cuLaunchKernel` — synchronous. Use [`crate::driver::Stream`] for
    /// asynchronous launches.
    pub fn launch(
        &self,
        cfg: &LaunchConfig,
        args: &[KernelArg],
        mem: &MemoryPool,
    ) -> Result<()> {
        self.inner.launch(cfg, args, mem)
    }

    /// Launch and report execution statistics (blocks executed, worker
    /// utilization) for backends that track them.
    pub fn launch_report(
        &self,
        cfg: &LaunchConfig,
        args: &[KernelArg],
        mem: &MemoryPool,
    ) -> Result<crate::driver::launch::LaunchReport> {
        self.inner.launch_report(cfg, args, mem)
    }

    pub fn name(&self) -> String {
        self.inner.name()
    }

}
