//! Simulated device memory: a **disjoint address space** addressed by
//! opaque handles, exactly the restriction the paper builds on (§4.1:
//! "GPU and CPU memories are disjoint, pointers are not interchangeable").
//!
//! Buffers live in a pool owned by the context; host code can only move
//! data across with explicit `copy_h2d` / `copy_d2h` calls, which are
//! counted — the transfer-minimization claims of §6.3 are validated
//! against these counters.
//!
//! # Caching allocator
//!
//! The pool is a **caching allocator** in the CUDA.jl mold: `free` does
//! not return storage to the host allocator but parks the buffer in a
//! size-binned free list (power-of-two bins), and `alloc` serves
//! same-bin requests from that cache. This is the §6.2 rationale made
//! explicit — raw `cuMemAlloc`/`cuMemFree` round-trips dominate
//! small-kernel launches, so steady-state launch paths must not touch
//! the underlying allocator at all. Consequences:
//!
//! * handles are never recycled — a freed `DevicePtr` stays invalid
//!   forever, so use-after-free and double-free detection survive
//!   caching;
//! * recycled storage retains stale contents (as with `cuMemAlloc`):
//!   programs must not read device memory they have not written;
//! * cached blocks count against capacity; when an allocation would
//!   otherwise report `OutOfMemory` the pool trims its cache and
//!   retries ([`MemoryPool::trim`] is the explicit version);
//! * the `HLGPU_POOL` environment knob (`cached` | `none`) selects the
//!   policy for pools created with [`MemoryPool::new`], so benches can
//!   A/B the two (`benches/alloc_throughput.rs`);
//! * `HLGPU_POOL_CAP` bounds the **cached** (parked) bytes with LRU
//!   eviction — oldest parked blocks are released first when a free
//!   would push the cache over the bound — so long-lived processes stop
//!   holding peak-watermark memory until pressure. Plain bytes, with
//!   optional `k`/`m`/`g` (or `kb`/`mb`/`gb`) suffix; unset means
//!   unbounded, and an unparseable value warns on stderr instead of
//!   silently disabling the bound (the pressure-release path still
//!   empties the cache before reporting `OutOfMemory`). Evictions
//!   surface as `evicted_bytes` / `evicted_blocks` in [`MemStats`].
//!
//! # Per-stream allocation arenas
//!
//! The pool is sharded into **arenas** (`HLGPU_ARENAS`, default 4): each
//! arena owns its own lock, buffer map and free bins, and a handle
//! encodes the arena it belongs to, so allocations, frees and copies
//! against buffers in *different* arenas never contend on one mutex.
//! [`crate::driver::Stream`]s carry an arena id (round-robin at
//! creation) and stream-ordered pipelines allocate their double buffers
//! with [`MemoryPool::alloc_in`] — concurrent streams then stop
//! serializing on the allocator, the ROADMAP "per-stream allocation
//! arenas" item. Plain [`MemoryPool::alloc`] always uses arena 0, so
//! single-threaded workloads keep the exact single-lock semantics
//! (including LRU order) they had before sharding. Pool-wide gauges
//! (live/cached/peak bytes) are atomics shared by all arenas; capacity
//! and the `HLGPU_POOL_CAP` bound are enforced against those global
//! gauges, with LRU eviction draining the freeing arena first and
//! sweeping the others only if the bound is still exceeded.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};

/// Opaque device pointer (the `CUdeviceptr` analog). Never a host address.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DevicePtr(pub u64);

impl DevicePtr {
    pub fn null() -> Self {
        DevicePtr(0)
    }
    pub fn is_null(&self) -> bool {
        self.0 == 0
    }
}

/// Allocation policy of a [`MemoryPool`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolPolicy {
    /// Freed buffers are parked in power-of-two bins and recycled
    /// (the default; the CUDA.jl caching-pool design).
    Cached,
    /// Every `alloc` heap-allocates and every `free` releases to the
    /// host allocator (the seed behavior; `HLGPU_POOL=none`).
    Uncached,
}

impl PoolPolicy {
    /// Parse an `HLGPU_POOL` value; unknown values select the default.
    pub fn parse(v: &str) -> PoolPolicy {
        match v.to_ascii_lowercase().as_str() {
            "none" | "uncached" | "off" | "0" => PoolPolicy::Uncached,
            _ => PoolPolicy::Cached,
        }
    }

    /// Policy selected by the `HLGPU_POOL` environment variable
    /// (`cached` | `none`); `Cached` when unset.
    pub fn from_env() -> PoolPolicy {
        std::env::var("HLGPU_POOL")
            .map(|v| Self::parse(&v))
            .unwrap_or(PoolPolicy::Cached)
    }
}

/// Running transfer / allocation statistics for a pool, aggregated over
/// every arena.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemStats {
    pub alloc_count: u64,
    pub free_count: u64,
    pub h2d_count: u64,
    pub d2h_count: u64,
    pub d2d_count: u64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub d2d_bytes: u64,
    pub current_bytes: usize,
    pub peak_bytes: usize,
    /// Allocations served from the size-binned cache.
    pub reuse_count: u64,
    /// Requested bytes served from the cache.
    pub reuse_bytes: u64,
    /// Cache drops (explicit `trim` or pressure release).
    pub trim_count: u64,
    /// Bytes released back to the host allocator by trims.
    pub trimmed_bytes: u64,
    /// Bytes currently parked in the free bins (gauge).
    pub cached_bytes: usize,
    /// Blocks currently parked in the free bins (gauge).
    pub cached_blocks: usize,
    /// Bytes released by the LRU bound (`HLGPU_POOL_CAP`) evicting the
    /// oldest parked blocks (distinct from `trimmed_bytes`).
    pub evicted_bytes: u64,
    /// Blocks released by the LRU bound.
    pub evicted_blocks: u64,
    /// Bytes served by **cross-arena bin stealing**: an arena whose own
    /// bins were empty recycled a fitting block parked by a sibling
    /// arena instead of carving fresh capacity (counted at the bin
    /// size; also included in `reuse_*`).
    pub stolen_bytes: u64,
    /// Blocks served by cross-arena bin stealing.
    pub stolen_blocks: u64,
}

impl MemStats {
    /// Fraction of allocations served from the cache (0.0 when no
    /// allocations happened yet, or under the uncached policy).
    pub fn pool_hit_rate(&self) -> f64 {
        if self.alloc_count == 0 {
            0.0
        } else {
            self.reuse_count as f64 / self.alloc_count as f64
        }
    }
}

/// Smallest bin: sub-16-byte requests share one bin so tiny scalars do
/// not fragment the free lists.
const MIN_BIN: usize = 16;

/// Power-of-two bin a request falls into.
fn bin_size(bytes: usize) -> usize {
    bytes.checked_next_power_of_two().unwrap_or(bytes).max(MIN_BIN)
}

/// Per-arena event counters. Gauges (live/cached/peak bytes) and the
/// pressure counters (trim/evict) are pool-global atomics instead — they
/// feed capacity decisions that span arenas.
#[derive(Clone, Copy, Debug, Default)]
struct ArenaCounters {
    alloc_count: u64,
    free_count: u64,
    h2d_count: u64,
    d2h_count: u64,
    d2d_count: u64,
    h2d_bytes: u64,
    d2h_bytes: u64,
    d2d_bytes: u64,
    reuse_count: u64,
    reuse_bytes: u64,
    stolen_bytes: u64,
    stolen_blocks: u64,
}

/// One allocation arena: its own buffer map and free bins behind its own
/// lock.
struct ArenaInner {
    buffers: HashMap<u64, Vec<u8>>,
    /// bin size -> parked buffers (each with `len == capacity == bin`),
    /// FIFO-ordered and stamped with a park sequence number: reuse pops
    /// the warm back, LRU eviction pops the oldest front.
    free_bins: HashMap<usize, VecDeque<(u64, Vec<u8>)>>,
    counters: ArenaCounters,
    /// Local mirror of this arena's share of the global cached gauge.
    cached_bytes: usize,
    cached_blocks: usize,
}

impl ArenaInner {
    fn new() -> Self {
        ArenaInner {
            buffers: HashMap::new(),
            free_bins: HashMap::new(),
            counters: ArenaCounters::default(),
            cached_bytes: 0,
            cached_blocks: 0,
        }
    }
}

/// Pool-global gauges and pressure counters, shared by every arena.
struct GlobalGauges {
    current_bytes: AtomicUsize,
    peak_bytes: AtomicUsize,
    cached_bytes: AtomicUsize,
    cached_blocks: AtomicUsize,
    trim_count: AtomicU64,
    trimmed_bytes: AtomicU64,
    evicted_bytes: AtomicU64,
    evicted_blocks: AtomicU64,
    /// Monotonic park stamp for LRU ordering across bins (and arenas).
    park_seq: AtomicU64,
}

impl GlobalGauges {
    fn new() -> Self {
        GlobalGauges {
            current_bytes: AtomicUsize::new(0),
            peak_bytes: AtomicUsize::new(0),
            cached_bytes: AtomicUsize::new(0),
            cached_blocks: AtomicUsize::new(0),
            trim_count: AtomicU64::new(0),
            trimmed_bytes: AtomicU64::new(0),
            evicted_bytes: AtomicU64::new(0),
            evicted_blocks: AtomicU64::new(0),
            park_seq: AtomicU64::new(0),
        }
    }
}

/// Device memory pool. One per context (the CUDA context owns allocations
/// the same way). Thread-safe, and sharded into per-stream arenas:
/// streams that allocate/copy in different arenas never contend.
pub struct MemoryPool {
    capacity: usize,
    policy: PoolPolicy,
    /// LRU bound on parked (cached) bytes; `None` = unbounded.
    cache_cap: Option<usize>,
    /// Owning device ordinal, when the pool belongs to a context: routes
    /// alloc/copy operations through the fault plane
    /// (`crate::driver::faults`). Free-standing pools stay uninstrumented.
    ordinal: Option<usize>,
    next: AtomicU64,
    arenas: Vec<Mutex<ArenaInner>>,
    global: GlobalGauges,
}

/// Default simulated device memory: 4 GiB (GTX-Titan-class with headroom).
pub const DEFAULT_CAPACITY: usize = 4 << 30;

/// Default arena (shard) count when `HLGPU_ARENAS` is unset.
pub const DEFAULT_ARENAS: usize = 4;

/// Parse a byte-size value (`HLGPU_POOL_CAP`, `HLGPU_DEV_MEM` entries):
/// plain bytes with an optional `k`/`m`/`g` (or `kb`/`mb`/`gb`) suffix,
/// powers of 1024.
pub(crate) fn parse_mem_size(v: &str) -> Option<usize> {
    let mut s = v.trim().to_ascii_lowercase();
    if s.is_empty() {
        return None;
    }
    // accept the natural `kb`/`mb`/`gb` spellings too
    if s.len() >= 2 && s.ends_with('b') && matches!(s.as_bytes()[s.len() - 2], b'k' | b'm' | b'g')
    {
        s.pop();
    }
    let (digits, mult): (&str, usize) = match s.as_bytes()[s.len() - 1] {
        b'k' => (&s[..s.len() - 1], 1 << 10),
        b'm' => (&s[..s.len() - 1], 1 << 20),
        b'g' => (&s[..s.len() - 1], 1 << 30),
        _ => (&s[..], 1),
    };
    digits
        .trim()
        .parse::<usize>()
        .ok()
        .and_then(|n| n.checked_mul(mult))
}

fn cache_cap_from_env() -> Option<usize> {
    let v = std::env::var("HLGPU_POOL_CAP").ok()?;
    match parse_mem_size(&v) {
        Some(cap) => Some(cap),
        None => {
            // A resource bound that silently disables itself on a typo
            // would be a trap; warn (once) and leave the cache unbounded.
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                eprintln!(
                    "hlgpu: ignoring unparseable HLGPU_POOL_CAP={v:?} \
                     (expected bytes with optional k/m/g suffix, e.g. 256m); \
                     cached-memory bound is DISABLED"
                );
            });
            None
        }
    }
}

/// Arena count selected by `HLGPU_ARENAS` (>= 1); [`DEFAULT_ARENAS`]
/// when unset or unparseable.
fn arena_count_from_env() -> usize {
    std::env::var("HLGPU_ARENAS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_ARENAS)
}

impl MemoryPool {
    /// Pool with the policy selected by `HLGPU_POOL` (cached by default)
    /// and the arena count selected by `HLGPU_ARENAS`.
    pub fn new(capacity: usize) -> Self {
        Self::with_policy(capacity, PoolPolicy::from_env())
    }

    pub fn with_policy(capacity: usize, policy: PoolPolicy) -> Self {
        Self::with_policy_arenas(capacity, policy, arena_count_from_env())
    }

    /// Pool with an explicit arena (shard) count; `arenas` is floored
    /// at 1.
    pub fn with_policy_arenas(capacity: usize, policy: PoolPolicy, arenas: usize) -> Self {
        let n = arenas.max(1);
        MemoryPool {
            capacity,
            policy,
            cache_cap: cache_cap_from_env(),
            ordinal: None,
            next: AtomicU64::new(1),
            arenas: (0..n).map(|_| Mutex::new(ArenaInner::new())).collect(),
            global: GlobalGauges::new(),
        }
    }

    /// Override the LRU bound on cached bytes (`None` = unbounded),
    /// taking precedence over `HLGPU_POOL_CAP`.
    pub fn with_cache_cap(mut self, cap: Option<usize>) -> Self {
        self.cache_cap = cap;
        self
    }

    /// Attach the owning device's ordinal, routing this pool's alloc and
    /// copy operations through the fault-injection plane
    /// (`crate::driver::faults`). Contexts set this at creation.
    pub fn with_device_ordinal(mut self, ordinal: usize) -> Self {
        self.ordinal = Some(ordinal);
        self
    }

    /// The owning device's ordinal, when attached.
    pub fn device_ordinal(&self) -> Option<usize> {
        self.ordinal
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn policy(&self) -> PoolPolicy {
        self.policy
    }

    /// The LRU bound on cached bytes, if any.
    pub fn cache_cap(&self) -> Option<usize> {
        self.cache_cap
    }

    /// Number of allocation arenas (lock shards) in this pool.
    pub fn arena_count(&self) -> usize {
        self.arenas.len()
    }

    /// Arena a handle routes to (handles encode their arena).
    fn arena_of(&self, ptr: DevicePtr) -> usize {
        (ptr.0 % self.arenas.len() as u64) as usize
    }

    /// Map an `alloc_in` arena request to a shard index. Zero always
    /// means the default arena; nonzero requests (stream arena ids,
    /// which grow without bound) spread over shards `1..n` and **never**
    /// land on arena 0 — otherwise the 4th stream of a default 4-arena
    /// pool would silently share the synchronous path's lock again.
    fn arena_index(&self, arena: usize) -> usize {
        let n = self.arenas.len();
        if arena == 0 || n == 1 {
            0
        } else {
            1 + (arena - 1) % (n - 1)
        }
    }

    /// Reserve `bytes` of live capacity with a compare-exchange loop —
    /// two arenas allocating concurrently must not both pass a stale
    /// capacity check and overcommit the device. The cached-bytes term
    /// is a snapshot (parking is advisory and self-heals via pressure
    /// trims), but live bytes never exceed capacity.
    fn try_reserve(&self, bytes: usize) -> bool {
        let mut current = self.global.current_bytes.load(Ordering::Relaxed);
        loop {
            let cached = self.global.cached_bytes.load(Ordering::Relaxed);
            let fits = match current.checked_add(cached).and_then(|f| f.checked_add(bytes)) {
                Some(f) => f <= self.capacity,
                None => false,
            };
            if !fits {
                return false;
            }
            match self.global.current_bytes.compare_exchange_weak(
                current,
                current + bytes,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => current = now,
            }
        }
    }

    fn oom(&self, requested: usize) -> Error {
        Error::OutOfMemory {
            requested,
            available: self
                .capacity
                .saturating_sub(self.global.current_bytes.load(Ordering::Relaxed)),
        }
    }

    /// `cuMemAlloc`: allocate `bytes` of device memory in arena 0.
    /// Contents are unspecified (fresh blocks happen to be zeroed,
    /// recycled blocks keep stale data — as on real hardware).
    pub fn alloc(&self, bytes: usize) -> Result<DevicePtr> {
        self.alloc_in(0, bytes)
    }

    /// `cuMemAlloc` in a specific arena. `0` is the default arena;
    /// nonzero requests spread over the shards `1..n` and never land on
    /// arena 0 (stream ids grow without bound — wrapping them back onto
    /// the default arena would silently reintroduce the shared lock).
    /// Streams pass their [`crate::driver::Stream::arena_id`] here so
    /// concurrent pipelines allocate without lock contention.
    pub fn alloc_in(&self, arena: usize, bytes: usize) -> Result<DevicePtr> {
        if let Some(ord) = self.ordinal {
            crate::driver::faults::on_alloc(ord, bytes)?;
        }
        let arena = self.arena_index(arena);

        // Fast path: recycle from the matching bin of this arena. Never
        // increases the pool's footprint (bin >= bytes), so no capacity
        // check needed.
        if self.policy == PoolPolicy::Cached {
            let bin = bin_size(bytes);
            let mut inner = self.arenas[arena].lock().unwrap();
            // Pop the warm end (most recently parked); the LRU bound
            // evicts from the cold front.
            if let Some((_, mut buf)) = inner.free_bins.get_mut(&bin).and_then(|v| v.pop_back())
            {
                buf.truncate(bytes); // parked with len == bin >= bytes
                inner.cached_bytes -= bin;
                inner.cached_blocks -= 1;
                self.global.cached_bytes.fetch_sub(bin, Ordering::Relaxed);
                self.global.cached_blocks.fetch_sub(1, Ordering::Relaxed);
                inner.counters.reuse_count += 1;
                inner.counters.reuse_bytes += bytes as u64;
                return Ok(self.finish_alloc(&mut inner, arena, bytes, buf, false));
            }
        }

        // Cross-arena bin stealing: before carving fresh capacity, check
        // whether a *sibling* arena parks a block of the right bin. Under
        // imbalanced stream traffic (one arena frees what another
        // allocates) this turns a fresh carve into a recycle. Sibling
        // locks are taken one at a time and released before this arena's
        // lock, so the steal never nests locks; the stolen block is
        // re-registered under a handle that routes to the *thief's*
        // arena. Guarded by the global cached gauge so a cold pool (the
        // allocation-heavy startup phase, nothing parked anywhere) pays
        // one relaxed load instead of sweeping every sibling's mutex.
        if self.policy == PoolPolicy::Cached
            && self.arenas.len() > 1
            && self.global.cached_blocks.load(Ordering::Relaxed) > 0
        {
            let bin = bin_size(bytes);
            for (i, sibling) in self.arenas.iter().enumerate() {
                if i == arena {
                    continue;
                }
                let stolen = {
                    let mut other = sibling.lock().unwrap();
                    match other.free_bins.get_mut(&bin).and_then(|v| v.pop_back()) {
                        Some((_, buf)) => {
                            other.cached_bytes -= bin;
                            other.cached_blocks -= 1;
                            Some(buf)
                        }
                        None => None,
                    }
                };
                if let Some(mut buf) = stolen {
                    self.global.cached_bytes.fetch_sub(bin, Ordering::Relaxed);
                    self.global.cached_blocks.fetch_sub(1, Ordering::Relaxed);
                    buf.truncate(bytes); // parked with len == bin >= bytes
                    let mut inner = self.arenas[arena].lock().unwrap();
                    inner.counters.reuse_count += 1;
                    inner.counters.reuse_bytes += bytes as u64;
                    inner.counters.stolen_blocks += 1;
                    inner.counters.stolen_bytes += bin as u64;
                    return Ok(self.finish_alloc(&mut inner, arena, bytes, buf, false));
                }
            }
        }

        // Slow path: fresh allocation. Capacity is reserved up front
        // with a compare-exchange (overflow-safe inside `try_reserve`) —
        // two arenas racing here must not both pass a stale check.
        if !self.try_reserve(bytes) {
            // Unsatisfiable requests must not wipe the warm cache.
            if bytes > self.capacity {
                return Err(self.oom(bytes));
            }
            // Pressure release: drop cached blocks (all arenas) before
            // giving up.
            self.trim();
            if !self.try_reserve(bytes) {
                return Err(self.oom(bytes));
            }
        }
        let buf = match self.policy {
            PoolPolicy::Cached => {
                // Reserve the full bin so the block re-parks without a
                // reallocation when freed.
                let mut b = Vec::with_capacity(bin_size(bytes));
                b.resize(bytes, 0u8);
                b
            }
            PoolPolicy::Uncached => vec![0u8; bytes],
        };
        let mut inner = self.arenas[arena].lock().unwrap();
        Ok(self.finish_alloc(&mut inner, arena, bytes, buf, true))
    }

    fn finish_alloc(
        &self,
        inner: &mut ArenaInner,
        arena: usize,
        bytes: usize,
        buf: Vec<u8>,
        reserved: bool,
    ) -> DevicePtr {
        // Handles encode their arena: handle = seq * arenas + arena,
        // seq >= 1, so 0 stays the null pointer and `arena_of` is a
        // modulo.
        let seq = self.next.fetch_add(1, Ordering::Relaxed);
        let handle = seq * self.arenas.len() as u64 + arena as u64;
        inner.buffers.insert(handle, buf);
        inner.counters.alloc_count += 1;
        // The slow path already reserved its bytes in `try_reserve`; the
        // bin-reuse path (bin >= bytes, so it never grows the footprint)
        // accounts here.
        let cur = if reserved {
            self.global.current_bytes.load(Ordering::Relaxed)
        } else {
            self.global.current_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes
        };
        self.global.peak_bytes.fetch_max(cur, Ordering::Relaxed);
        DevicePtr(handle)
    }

    /// `cuMemFree`. Double frees and unknown handles are errors (the
    /// framework relies on this to catch lifetime bugs in transfer plans).
    /// Under the cached policy the storage is parked in its size bin; the
    /// handle is dead either way.
    pub fn free(&self, ptr: DevicePtr) -> Result<()> {
        let arena = self.arena_of(ptr);
        let mut inner = self.arenas[arena].lock().unwrap();
        match inner.buffers.remove(&ptr.0) {
            Some(mut buf) => {
                inner.counters.free_count += 1;
                self.global
                    .current_bytes
                    .fetch_sub(buf.len(), Ordering::Relaxed);
                if self.policy == PoolPolicy::Cached {
                    let bin = bin_size(buf.len());
                    // A block whose bin alone exceeds the LRU bound can
                    // never stay parked: release it directly instead of
                    // paying the park-then-evict round-trip, and keep
                    // the eviction counters meaningful (they measure
                    // real cap pressure, not ordinary frees).
                    let parkable = match self.cache_cap {
                        Some(cap) => bin <= cap,
                        None => true,
                    };
                    // Park only while live + cached stays within capacity
                    // (bin rounding could otherwise overcommit the
                    // device); blocks that do not fit are released.
                    let current = self.global.current_bytes.load(Ordering::Relaxed);
                    let cached = self.global.cached_bytes.load(Ordering::Relaxed);
                    let fits = match current.checked_add(cached).and_then(|f| f.checked_add(bin))
                    {
                        Some(f) => f <= self.capacity,
                        None => false,
                    };
                    if parkable && fits {
                        // Capacity was reserved at the bin size, so this
                        // never reallocates.
                        buf.resize(bin, 0u8);
                        inner.cached_bytes += bin;
                        inner.cached_blocks += 1;
                        self.global.cached_bytes.fetch_add(bin, Ordering::Relaxed);
                        self.global.cached_blocks.fetch_add(1, Ordering::Relaxed);
                        let seq = self.global.park_seq.fetch_add(1, Ordering::Relaxed);
                        inner.free_bins.entry(bin).or_default().push_back((seq, buf));
                        if let Some(cap) = self.cache_cap {
                            // Drain the freeing arena's oldest blocks
                            // first; sweep the other arenas only if the
                            // global bound is still exceeded.
                            self.evict_lru_local(&mut inner, cap);
                            if self.global.cached_bytes.load(Ordering::Relaxed) > cap {
                                drop(inner);
                                self.evict_lru_others(cap, arena);
                            }
                        }
                    }
                }
                Ok(())
            }
            None => Err(Error::DoubleFree(ptr.0)),
        }
    }

    /// Enforce the LRU bound within one arena: release its oldest parked
    /// blocks (smallest park stamp across all bin fronts) until the
    /// *global* cache fits within `cap` or this arena has nothing parked.
    fn evict_lru_local(&self, inner: &mut ArenaInner, cap: usize) {
        while self.global.cached_bytes.load(Ordering::Relaxed) > cap {
            let victim = inner
                .free_bins
                .iter()
                .filter_map(|(bin, q)| q.front().map(|(seq, _)| (*seq, *bin)))
                .min();
            match victim {
                Some((_, bin)) => {
                    inner
                        .free_bins
                        .get_mut(&bin)
                        .and_then(|q| q.pop_front())
                        .expect("victim bin has a front block");
                    inner.cached_bytes -= bin;
                    inner.cached_blocks -= 1;
                    self.global.cached_bytes.fetch_sub(bin, Ordering::Relaxed);
                    self.global.cached_blocks.fetch_sub(1, Ordering::Relaxed);
                    self.global.evicted_bytes.fetch_add(bin as u64, Ordering::Relaxed);
                    self.global.evicted_blocks.fetch_add(1, Ordering::Relaxed);
                }
                None => break, // this arena is drained; caller sweeps the rest
            }
        }
    }

    /// Sweep the remaining arenas (one lock at a time, never nested) when
    /// the freeing arena alone could not bring the cache under the bound.
    fn evict_lru_others(&self, cap: usize, skip: usize) {
        for (i, a) in self.arenas.iter().enumerate() {
            if i == skip {
                continue;
            }
            if self.global.cached_bytes.load(Ordering::Relaxed) <= cap {
                return;
            }
            let mut inner = a.lock().unwrap();
            self.evict_lru_local(&mut inner, cap);
        }
    }

    /// Release every cached block (all arenas) back to the host
    /// allocator; returns the bytes released. Live buffers are untouched.
    /// The allocator calls this itself when an allocation would otherwise
    /// hit `OutOfMemory`.
    pub fn trim(&self) -> usize {
        let mut released = 0usize;
        for a in &self.arenas {
            let mut inner = a.lock().unwrap();
            let r = inner.cached_bytes;
            if r > 0 {
                self.global.cached_bytes.fetch_sub(r, Ordering::Relaxed);
                self.global
                    .cached_blocks
                    .fetch_sub(inner.cached_blocks, Ordering::Relaxed);
                inner.cached_bytes = 0;
                inner.cached_blocks = 0;
                inner.free_bins.clear();
                released += r;
            }
        }
        if released > 0 {
            self.global.trim_count.fetch_add(1, Ordering::Relaxed);
            self.global
                .trimmed_bytes
                .fetch_add(released as u64, Ordering::Relaxed);
        }
        released
    }

    pub fn size_of(&self, ptr: DevicePtr) -> Result<usize> {
        let inner = self.arenas[self.arena_of(ptr)].lock().unwrap();
        inner
            .buffers
            .get(&ptr.0)
            .map(|b| b.len())
            .ok_or(Error::InvalidDevicePtr(ptr.0))
    }

    /// `cuMemcpyHtoD`.
    pub fn copy_h2d(&self, dst: DevicePtr, src: &[u8]) -> Result<()> {
        self.copy_h2d_at(dst, 0, src)
    }

    pub fn copy_h2d_at(&self, dst: DevicePtr, offset: usize, src: &[u8]) -> Result<()> {
        if let Some(ord) = self.ordinal {
            crate::driver::faults::on_h2d(ord)?;
        }
        let mut inner = self.arenas[self.arena_of(dst)].lock().unwrap();
        let buf = inner
            .buffers
            .get_mut(&dst.0)
            .ok_or(Error::InvalidDevicePtr(dst.0))?;
        if offset + src.len() > buf.len() {
            return Err(Error::OutOfBounds {
                ptr: dst.0,
                off: offset,
                len: src.len(),
                size: buf.len(),
            });
        }
        buf[offset..offset + src.len()].copy_from_slice(src);
        inner.counters.h2d_count += 1;
        inner.counters.h2d_bytes += src.len() as u64;
        Ok(())
    }

    /// `cuMemcpyDtoH`.
    pub fn copy_d2h(&self, src: DevicePtr, dst: &mut [u8]) -> Result<()> {
        self.copy_d2h_at(src, 0, dst)
    }

    pub fn copy_d2h_at(&self, src: DevicePtr, offset: usize, dst: &mut [u8]) -> Result<()> {
        if let Some(ord) = self.ordinal {
            crate::driver::faults::on_d2h(ord)?;
        }
        let mut inner = self.arenas[self.arena_of(src)].lock().unwrap();
        let buf = inner
            .buffers
            .get(&src.0)
            .ok_or(Error::InvalidDevicePtr(src.0))?;
        if offset + dst.len() > buf.len() {
            return Err(Error::OutOfBounds {
                ptr: src.0,
                off: offset,
                len: dst.len(),
                size: buf.len(),
            });
        }
        dst.copy_from_slice(&buf[offset..offset + dst.len()]);
        inner.counters.d2h_count += 1;
        inner.counters.d2h_bytes += dst.len() as u64;
        Ok(())
    }

    /// `cuMemcpyDtoD`. Source and destination may live in different
    /// arenas; the two locks are taken sequentially, never nested.
    pub fn copy_d2d(&self, dst: DevicePtr, src: DevicePtr) -> Result<()> {
        let data = {
            let inner = self.arenas[self.arena_of(src)].lock().unwrap();
            inner
                .buffers
                .get(&src.0)
                .ok_or(Error::InvalidDevicePtr(src.0))?
                .clone()
        };
        let mut inner = self.arenas[self.arena_of(dst)].lock().unwrap();
        let dbuf = inner
            .buffers
            .get_mut(&dst.0)
            .ok_or(Error::InvalidDevicePtr(dst.0))?;
        if data.len() != dbuf.len() {
            return Err(Error::OutOfBounds {
                ptr: dst.0,
                off: 0,
                len: data.len(),
                size: dbuf.len(),
            });
        }
        dbuf.copy_from_slice(&data);
        inner.counters.d2d_count += 1;
        inner.counters.d2d_bytes += data.len() as u64;
        Ok(())
    }

    /// Read an entire device buffer into a fresh host vector (not counted
    /// as a D2H *transfer*: used by backends, which access device memory
    /// directly — the kernel-side view).
    pub fn read_raw(&self, ptr: DevicePtr) -> Result<Vec<u8>> {
        let inner = self.arenas[self.arena_of(ptr)].lock().unwrap();
        inner
            .buffers
            .get(&ptr.0)
            .cloned()
            .ok_or(Error::InvalidDevicePtr(ptr.0))
    }

    /// Overwrite an entire device buffer (backend-side write; length must
    /// match exactly).
    pub fn write_raw(&self, ptr: DevicePtr, data: &[u8]) -> Result<()> {
        let mut inner = self.arenas[self.arena_of(ptr)].lock().unwrap();
        let buf = inner
            .buffers
            .get_mut(&ptr.0)
            .ok_or(Error::InvalidDevicePtr(ptr.0))?;
        if data.len() != buf.len() {
            return Err(Error::OutOfBounds { ptr: ptr.0, off: 0, len: data.len(), size: buf.len() });
        }
        buf.copy_from_slice(data);
        Ok(())
    }

    /// Run `f` with a borrowed view of the buffer (zero-copy backend read;
    /// avoids the clone of [`MemoryPool::read_raw`] on hot launch paths —
    /// §Perf iteration I4).
    pub fn with_raw<R>(&self, ptr: DevicePtr, f: impl FnOnce(&[u8]) -> R) -> Result<R> {
        let inner = self.arenas[self.arena_of(ptr)].lock().unwrap();
        let buf = inner
            .buffers
            .get(&ptr.0)
            .ok_or(Error::InvalidDevicePtr(ptr.0))?;
        Ok(f(buf))
    }

    /// Run `f` with a mutable view of the buffer (zero-copy backend access;
    /// used by the VTX interpreter for global memory).
    pub fn with_raw_mut<R>(
        &self,
        ptr: DevicePtr,
        f: impl FnOnce(&mut [u8]) -> R,
    ) -> Result<R> {
        let mut inner = self.arenas[self.arena_of(ptr)].lock().unwrap();
        let buf = inner
            .buffers
            .get_mut(&ptr.0)
            .ok_or(Error::InvalidDevicePtr(ptr.0))?;
        Ok(f(buf))
    }

    /// Take several buffers out of the pool, run `f`, and put them back.
    /// Allows a kernel to access multiple buffers mutably without holding
    /// any arena lock for the duration of the launch. Buffers may span
    /// arenas; locks are taken one at a time.
    pub fn with_buffers<R>(
        &self,
        ptrs: &[DevicePtr],
        f: impl FnOnce(&mut [Vec<u8>]) -> R,
    ) -> Result<R> {
        // Duplicate pointers are not supported (aliasing) — error out
        // before removing anything.
        for (i, p) in ptrs.iter().enumerate() {
            if ptrs[..i].contains(p) {
                return Err(Error::InvalidLaunch(format!(
                    "duplicate device pointer argument {:#x}",
                    p.0
                )));
            }
        }
        let mut taken: Vec<Vec<u8>> = Vec::with_capacity(ptrs.len());
        for (i, p) in ptrs.iter().enumerate() {
            let removed = {
                let mut inner = self.arenas[self.arena_of(*p)].lock().unwrap();
                inner.buffers.remove(&p.0)
            };
            match removed {
                Some(buf) => taken.push(buf),
                None => {
                    // Roll back the buffers already taken, then error.
                    for (q, buf) in ptrs[..i].iter().zip(taken) {
                        let mut inner = self.arenas[self.arena_of(*q)].lock().unwrap();
                        inner.buffers.insert(q.0, buf);
                    }
                    return Err(Error::InvalidDevicePtr(p.0));
                }
            }
        }
        let result = f(&mut taken);
        for (p, buf) in ptrs.iter().zip(taken) {
            let mut inner = self.arenas[self.arena_of(*p)].lock().unwrap();
            inner.buffers.insert(p.0, buf);
        }
        Ok(result)
    }

    pub fn stats(&self) -> MemStats {
        let mut st = MemStats::default();
        for a in &self.arenas {
            let c = a.lock().unwrap().counters;
            st.alloc_count += c.alloc_count;
            st.free_count += c.free_count;
            st.h2d_count += c.h2d_count;
            st.d2h_count += c.d2h_count;
            st.d2d_count += c.d2d_count;
            st.h2d_bytes += c.h2d_bytes;
            st.d2h_bytes += c.d2h_bytes;
            st.d2d_bytes += c.d2d_bytes;
            st.reuse_count += c.reuse_count;
            st.reuse_bytes += c.reuse_bytes;
            st.stolen_bytes += c.stolen_bytes;
            st.stolen_blocks += c.stolen_blocks;
        }
        st.current_bytes = self.global.current_bytes.load(Ordering::Relaxed);
        st.peak_bytes = self.global.peak_bytes.load(Ordering::Relaxed);
        st.cached_bytes = self.global.cached_bytes.load(Ordering::Relaxed);
        st.cached_blocks = self.global.cached_blocks.load(Ordering::Relaxed);
        st.trim_count = self.global.trim_count.load(Ordering::Relaxed);
        st.trimmed_bytes = self.global.trimmed_bytes.load(Ordering::Relaxed);
        st.evicted_bytes = self.global.evicted_bytes.load(Ordering::Relaxed);
        st.evicted_blocks = self.global.evicted_blocks.load(Ordering::Relaxed);
        st
    }

    /// Reset the counters; gauges (live bytes, peak, cached blocks)
    /// survive, as the storage they describe does.
    pub fn reset_stats(&self) {
        for a in &self.arenas {
            a.lock().unwrap().counters = ArenaCounters::default();
        }
        self.global.trim_count.store(0, Ordering::Relaxed);
        self.global.trimmed_bytes.store(0, Ordering::Relaxed);
        self.global.evicted_bytes.store(0, Ordering::Relaxed);
        self.global.evicted_blocks.store(0, Ordering::Relaxed);
    }

    pub fn live_buffers(&self) -> usize {
        self.arenas
            .iter()
            .map(|a| a.lock().unwrap().buffers.len())
            .sum()
    }
}

impl Default for MemoryPool {
    fn default() -> Self {
        MemoryPool::new(DEFAULT_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_copy_roundtrip() {
        let pool = MemoryPool::default();
        let ptr = pool.alloc(8).unwrap();
        pool.copy_h2d(ptr, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        let mut out = [0u8; 8];
        pool.copy_d2h(ptr, &mut out).unwrap();
        assert_eq!(out, [1, 2, 3, 4, 5, 6, 7, 8]);
        let st = pool.stats();
        assert_eq!((st.h2d_count, st.d2h_count), (1, 1));
        assert_eq!((st.h2d_bytes, st.d2h_bytes), (8, 8));
    }

    #[test]
    fn double_free_detected() {
        let pool = MemoryPool::default();
        let ptr = pool.alloc(4).unwrap();
        pool.free(ptr).unwrap();
        assert!(matches!(pool.free(ptr), Err(Error::DoubleFree(_))));
    }

    #[test]
    fn use_after_free_detected() {
        let pool = MemoryPool::default();
        let ptr = pool.alloc(4).unwrap();
        pool.free(ptr).unwrap();
        assert!(pool.copy_h2d(ptr, &[0; 4]).is_err());
        let mut buf = [0u8; 4];
        assert!(pool.copy_d2h(ptr, &mut buf).is_err());
    }

    #[test]
    fn out_of_bounds_detected() {
        let pool = MemoryPool::default();
        let ptr = pool.alloc(4).unwrap();
        assert!(matches!(
            pool.copy_h2d(ptr, &[0; 8]),
            Err(Error::OutOfBounds { .. })
        ));
    }

    #[test]
    fn oom_enforced() {
        let pool = MemoryPool::new(16);
        let _a = pool.alloc(12).unwrap();
        assert!(matches!(pool.alloc(8), Err(Error::OutOfMemory { .. })));
    }

    #[test]
    fn peak_tracking() {
        let pool = MemoryPool::new(100);
        let a = pool.alloc(60).unwrap();
        pool.free(a).unwrap();
        let _b = pool.alloc(10).unwrap();
        let st = pool.stats();
        assert_eq!(st.peak_bytes, 60);
        assert_eq!(st.current_bytes, 10);
    }

    #[test]
    fn with_buffers_rejects_duplicates() {
        let pool = MemoryPool::default();
        let a = pool.alloc(4).unwrap();
        assert!(pool.with_buffers(&[a, a], |_| ()).is_err());
        // buffer must still be live afterwards
        assert_eq!(pool.size_of(a).unwrap(), 4);
    }

    #[test]
    fn with_buffers_restores_on_completion() {
        let pool = MemoryPool::default();
        let a = pool.alloc(4).unwrap();
        let b = pool.alloc(2).unwrap();
        pool.with_buffers(&[a, b], |bufs| {
            bufs[0][0] = 42;
            bufs[1][1] = 7;
        })
        .unwrap();
        assert_eq!(pool.read_raw(a).unwrap()[0], 42);
        assert_eq!(pool.read_raw(b).unwrap()[1], 7);
        assert_eq!(pool.live_buffers(), 2);
    }

    #[test]
    fn with_buffers_rolls_back_on_dead_handle() {
        let pool = MemoryPool::default();
        let a = pool.alloc(4).unwrap();
        let dead = pool.alloc(4).unwrap();
        pool.free(dead).unwrap();
        assert!(pool.with_buffers(&[a, dead], |_| ()).is_err());
        // the live buffer was rolled back, not lost
        assert_eq!(pool.size_of(a).unwrap(), 4);
    }

    #[test]
    fn d2d_copy() {
        let pool = MemoryPool::default();
        let a = pool.alloc(4).unwrap();
        let b = pool.alloc(4).unwrap();
        pool.copy_h2d(a, &[9, 9, 9, 9]).unwrap();
        pool.copy_d2d(b, a).unwrap();
        assert_eq!(pool.read_raw(b).unwrap(), vec![9, 9, 9, 9]);
    }

    // ---- caching allocator -------------------------------------------

    #[test]
    fn policy_parsing() {
        assert_eq!(PoolPolicy::parse("none"), PoolPolicy::Uncached);
        assert_eq!(PoolPolicy::parse("NONE"), PoolPolicy::Uncached);
        assert_eq!(PoolPolicy::parse("off"), PoolPolicy::Uncached);
        assert_eq!(PoolPolicy::parse("cached"), PoolPolicy::Cached);
        assert_eq!(PoolPolicy::parse(""), PoolPolicy::Cached);
    }

    #[test]
    fn bin_sizes_are_powers_of_two() {
        assert_eq!(bin_size(0), MIN_BIN);
        assert_eq!(bin_size(1), MIN_BIN);
        assert_eq!(bin_size(16), 16);
        assert_eq!(bin_size(17), 32);
        assert_eq!(bin_size(60), 64);
        assert_eq!(bin_size(1 << 20), 1 << 20);
        assert_eq!(bin_size((1 << 20) + 1), 1 << 21);
    }

    #[test]
    fn reuse_hit_miss_accounting() {
        let pool = MemoryPool::with_policy(DEFAULT_CAPACITY, PoolPolicy::Cached);
        let a = pool.alloc(100).unwrap(); // miss (cold)
        pool.free(a).unwrap();
        let b = pool.alloc(100).unwrap(); // hit: bin 128 holds the block
        let c = pool.alloc(100).unwrap(); // miss: bin drained
        let st = pool.stats();
        assert_eq!(st.alloc_count, 3);
        assert_eq!(st.reuse_count, 1);
        assert_eq!(st.reuse_bytes, 100);
        assert!((st.pool_hit_rate() - 1.0 / 3.0).abs() < 1e-9);
        // a different size in the same power-of-two bin also hits
        pool.free(b).unwrap();
        let d = pool.alloc(120).unwrap();
        assert_eq!(pool.stats().reuse_count, 2);
        assert_eq!(pool.size_of(d).unwrap(), 120);
        pool.free(c).unwrap();
        pool.free(d).unwrap();
        assert_eq!(pool.stats().cached_blocks, 2);
    }

    #[test]
    fn uncached_policy_never_reuses() {
        let pool = MemoryPool::with_policy(1 << 20, PoolPolicy::Uncached);
        for _ in 0..5 {
            let p = pool.alloc(64).unwrap();
            pool.free(p).unwrap();
        }
        let st = pool.stats();
        assert_eq!(st.alloc_count, 5);
        assert_eq!(st.reuse_count, 0);
        assert_eq!(st.cached_bytes, 0);
        assert_eq!(st.pool_hit_rate(), 0.0);
    }

    #[test]
    fn recycled_buffer_gets_fresh_handle() {
        let pool = MemoryPool::with_policy(1 << 20, PoolPolicy::Cached);
        let a = pool.alloc(32).unwrap();
        pool.copy_h2d(a, &[7u8; 32]).unwrap();
        pool.free(a).unwrap();
        let b = pool.alloc(32).unwrap();
        assert_ne!(a, b, "handles are never recycled, only storage");
        assert!(pool.copy_h2d(a, &[0u8; 32]).is_err(), "stale handle stays dead");
        assert_eq!(pool.stats().reuse_count, 1);
        pool.free(b).unwrap();
    }

    #[test]
    fn trim_under_pressure_recovers_from_oom() {
        let pool = MemoryPool::with_policy(256, PoolPolicy::Cached);
        let a = pool.alloc(200).unwrap(); // bin 256
        pool.free(a).unwrap();
        assert_eq!(pool.stats().cached_bytes, 256);
        // A 100-byte request needs a fresh block (bin 128 is empty) and
        // cached + requested exceeds capacity: the pool must trim and
        // recover instead of reporting OutOfMemory.
        let b = pool.alloc(100).unwrap();
        let st = pool.stats();
        assert_eq!(st.current_bytes, 100);
        assert_eq!(st.cached_bytes, 0);
        assert_eq!(st.trim_count, 1);
        assert_eq!(st.trimmed_bytes, 256);
        pool.free(b).unwrap();
    }

    #[test]
    fn oversized_request_preserves_cache() {
        let pool = MemoryPool::with_policy(1024, PoolPolicy::Cached);
        let a = pool.alloc(100).unwrap();
        pool.free(a).unwrap();
        assert_eq!(pool.stats().cached_blocks, 1);
        // larger than the device itself: fail fast, keep the warm bins
        assert!(matches!(pool.alloc(4096), Err(Error::OutOfMemory { .. })));
        let st = pool.stats();
        assert_eq!(st.cached_blocks, 1, "unsatisfiable request must not trim");
        assert_eq!(st.trim_count, 0);
    }

    #[test]
    fn cache_never_overcommits_capacity() {
        let pool = MemoryPool::with_policy(100, PoolPolicy::Cached);
        let a = pool.alloc(60).unwrap(); // bin 64
        let b = pool.alloc(33).unwrap(); // bin 64
        pool.free(a).unwrap(); // live 33 + cached 64 = 97: parked
        pool.free(b).unwrap(); // a second 64-byte bin would overcommit: released
        let st = pool.stats();
        assert_eq!(st.cached_blocks, 1);
        assert!(st.current_bytes + st.cached_bytes <= pool.capacity());
    }

    #[test]
    fn explicit_trim_releases_cache() {
        let pool = MemoryPool::with_policy(1 << 20, PoolPolicy::Cached);
        let a = pool.alloc(100).unwrap();
        pool.free(a).unwrap();
        assert_eq!(pool.stats().cached_blocks, 1);
        assert_eq!(pool.trim(), 128);
        let st = pool.stats();
        assert_eq!((st.cached_bytes, st.cached_blocks), (0, 0));
        let b = pool.alloc(100).unwrap(); // cold again after the trim
        assert_eq!(pool.stats().reuse_count, 0);
        pool.free(b).unwrap();
    }

    #[test]
    fn peak_ignores_cached_blocks() {
        let pool = MemoryPool::with_policy(1 << 20, PoolPolicy::Cached);
        let a = pool.alloc(512).unwrap();
        pool.free(a).unwrap();
        let b = pool.alloc(512).unwrap(); // recycled
        let st = pool.stats();
        assert_eq!(st.peak_bytes, 512, "peak tracks live bytes only");
        assert_eq!(st.current_bytes, 512);
        pool.free(b).unwrap();
        let st = pool.stats();
        assert_eq!(st.current_bytes, 0);
        assert_eq!(st.peak_bytes, 512);
    }

    #[test]
    fn overflowing_request_reports_oom() {
        // current_bytes + usize::MAX wraps with unchecked arithmetic and
        // would sail past the capacity check into a host-killing
        // allocation; the checked path must report OutOfMemory.
        for policy in [PoolPolicy::Cached, PoolPolicy::Uncached] {
            let pool = MemoryPool::with_policy(1024, policy);
            let _a = pool.alloc(16).unwrap();
            assert!(matches!(
                pool.alloc(usize::MAX),
                Err(Error::OutOfMemory { .. })
            ));
            // the pool stays usable
            assert!(pool.alloc(16).is_ok());
        }
    }

    #[test]
    fn reset_stats_preserves_gauges() {
        let pool = MemoryPool::with_policy(1 << 20, PoolPolicy::Cached);
        let a = pool.alloc(64).unwrap();
        let b = pool.alloc(64).unwrap();
        pool.free(a).unwrap();
        pool.reset_stats();
        let st = pool.stats();
        assert_eq!(st.alloc_count, 0);
        assert_eq!(st.reuse_count, 0);
        assert_eq!(st.current_bytes, 64);
        assert_eq!(st.cached_blocks, 1);
        pool.free(b).unwrap();
    }

    // ---- LRU bound on cached bytes (HLGPU_POOL_CAP) ------------------

    #[test]
    fn cache_cap_parsing() {
        assert_eq!(parse_mem_size("4096"), Some(4096));
        assert_eq!(parse_mem_size(" 16k "), Some(16 << 10));
        assert_eq!(parse_mem_size("2M"), Some(2 << 20));
        assert_eq!(parse_mem_size("1g"), Some(1 << 30));
        // natural kb/mb/gb spellings are accepted too
        assert_eq!(parse_mem_size("16kb"), Some(16 << 10));
        assert_eq!(parse_mem_size("512MB"), Some(512 << 20));
        assert_eq!(parse_mem_size("1gb"), Some(1 << 30));
        assert_eq!(parse_mem_size("0"), Some(0));
        assert_eq!(parse_mem_size(""), None);
        assert_eq!(parse_mem_size("lots"), None);
        assert_eq!(parse_mem_size("-1"), None);
        assert_eq!(parse_mem_size("b"), None);
    }

    #[test]
    fn lru_cap_evicts_oldest_parked_blocks_first() {
        let pool = MemoryPool::with_policy(1 << 20, PoolPolicy::Cached)
            .with_cache_cap(Some(256)); // room for two 128-byte bins
        assert_eq!(pool.cache_cap(), Some(256));
        let a = pool.alloc(100).unwrap(); // bin 128
        let b = pool.alloc(100).unwrap();
        let c = pool.alloc(100).unwrap();
        pool.free(a).unwrap(); // oldest parked
        pool.free(b).unwrap();
        assert_eq!(pool.stats().cached_blocks, 2);
        assert_eq!(pool.stats().evicted_blocks, 0);
        pool.free(c).unwrap(); // would make 384 cached: evict a's block
        let st = pool.stats();
        assert_eq!(st.cached_blocks, 2);
        assert_eq!(st.cached_bytes, 256);
        assert_eq!(st.evicted_blocks, 1);
        assert_eq!(st.evicted_bytes, 128);
        // the survivors still serve allocations
        let d = pool.alloc(100).unwrap();
        let e = pool.alloc(100).unwrap();
        assert_eq!(pool.stats().reuse_count, 2);
        pool.free(d).unwrap();
        pool.free(e).unwrap();
    }

    #[test]
    fn lru_eviction_is_oldest_across_bins() {
        let pool = MemoryPool::with_policy(1 << 20, PoolPolicy::Cached)
            .with_cache_cap(Some(400));
        let a = pool.alloc(60).unwrap(); // bin 64 (parked first = oldest)
        let b = pool.alloc(100).unwrap(); // bin 128
        let c = pool.alloc(200).unwrap(); // bin 256
        pool.free(a).unwrap();
        pool.free(b).unwrap();
        assert_eq!(pool.stats().cached_bytes, 192);
        // parking c makes 448 cached: the oldest block (a's, in a
        // *different* bin than c's) must go, leaving 384 <= 400.
        pool.free(c).unwrap();
        let st = pool.stats();
        assert_eq!(st.cached_bytes, 384);
        assert_eq!(st.cached_blocks, 2);
        assert_eq!(st.evicted_blocks, 1);
        assert_eq!(st.evicted_bytes, 64);
        // the younger 128- and 256-bin blocks survived
        let d = pool.alloc(100).unwrap();
        let e = pool.alloc(200).unwrap();
        assert_eq!(pool.stats().reuse_count, 2);
        pool.free(d).unwrap();
        pool.free(e).unwrap();
    }

    #[test]
    fn zero_cache_cap_disables_parking() {
        let pool = MemoryPool::with_policy(1 << 20, PoolPolicy::Cached)
            .with_cache_cap(Some(0));
        for _ in 0..3 {
            let p = pool.alloc(64).unwrap();
            pool.free(p).unwrap();
        }
        let st = pool.stats();
        assert_eq!(st.cached_blocks, 0);
        assert_eq!(st.reuse_count, 0);
        // never-parkable blocks are released directly, not counted as
        // LRU evictions (those measure real cap pressure)
        assert_eq!(st.evicted_blocks, 0);
        assert_eq!(st.evicted_bytes, 0);
    }

    #[test]
    fn block_larger_than_cap_skips_parking_but_smaller_blocks_still_cache() {
        let pool = MemoryPool::with_policy(1 << 20, PoolPolicy::Cached)
            .with_cache_cap(Some(128));
        let big = pool.alloc(200).unwrap(); // bin 256 > cap: never parks
        let small = pool.alloc(100).unwrap(); // bin 128 == cap: parks
        pool.free(big).unwrap();
        pool.free(small).unwrap();
        let st = pool.stats();
        assert_eq!(st.cached_blocks, 1);
        assert_eq!(st.cached_bytes, 128);
        assert_eq!(st.evicted_blocks, 0);
        let again = pool.alloc(100).unwrap();
        assert_eq!(pool.stats().reuse_count, 1);
        pool.free(again).unwrap();
    }

    #[test]
    fn reuse_prefers_most_recently_parked_block() {
        // LIFO reuse keeps the warm end hot: write a sentinel into a
        // block, free it, free another block of the same bin, and check
        // the *second* (warmest) storage is handed back first.
        let pool = MemoryPool::with_policy(1 << 20, PoolPolicy::Cached);
        let a = pool.alloc(32).unwrap();
        let b = pool.alloc(32).unwrap();
        pool.copy_h2d(a, &[1u8; 32]).unwrap();
        pool.copy_h2d(b, &[2u8; 32]).unwrap();
        pool.free(a).unwrap();
        pool.free(b).unwrap();
        let c = pool.alloc(32).unwrap();
        // recycled storage keeps stale contents: must be b's
        assert_eq!(pool.read_raw(c).unwrap(), vec![2u8; 32]);
        pool.free(c).unwrap();
    }

    #[test]
    fn zero_sized_allocs_roundtrip() {
        for policy in [PoolPolicy::Cached, PoolPolicy::Uncached] {
            let pool = MemoryPool::with_policy(1024, policy);
            let p = pool.alloc(0).unwrap();
            assert_eq!(pool.size_of(p).unwrap(), 0);
            pool.free(p).unwrap();
            let q = pool.alloc(0).unwrap();
            pool.free(q).unwrap();
        }
    }

    // ---- per-stream allocation arenas --------------------------------

    #[test]
    fn arena_routing_roundtrips_across_arenas() {
        let pool = MemoryPool::with_policy_arenas(1 << 20, PoolPolicy::Cached, 4);
        assert_eq!(pool.arena_count(), 4);
        let ptrs: Vec<DevicePtr> = (0..8)
            .map(|i| pool.alloc_in(i, 16).unwrap())
            .collect();
        // 0 is the default arena; nonzero requests spread over 1..n and
        // never wrap back onto arena 0
        for (i, p) in ptrs.iter().enumerate() {
            let expect = if i == 0 { 0 } else { 1 + (i - 1) % 3 };
            assert_eq!(pool.arena_of(*p), expect, "request {i}");
            pool.copy_h2d(*p, &[i as u8; 16]).unwrap();
        }
        for (i, p) in ptrs.iter().enumerate() {
            let mut out = [0u8; 16];
            pool.copy_d2h(*p, &mut out).unwrap();
            assert_eq!(out, [i as u8; 16]);
        }
        assert_eq!(pool.live_buffers(), 8);
        assert_eq!(pool.stats().alloc_count, 8);
        for p in ptrs {
            pool.free(p).unwrap();
        }
        assert_eq!(pool.live_buffers(), 0);
        assert_eq!(pool.stats().free_count, 8);
    }

    #[test]
    fn cross_arena_bin_stealing_recycles_sibling_blocks() {
        let pool = MemoryPool::with_policy_arenas(1 << 20, PoolPolicy::Cached, 2);
        let a = pool.alloc_in(0, 100).unwrap(); // bin 128 parked in arena 0
        pool.copy_h2d(a, &[7u8; 100]).unwrap();
        pool.free(a).unwrap();
        assert_eq!(pool.stats().cached_blocks, 1);
        // a same-bin request in the *other* arena steals the parked
        // block instead of carving fresh capacity
        let b = pool.alloc_in(1, 100).unwrap();
        let st = pool.stats();
        assert_eq!(st.reuse_count, 1, "the steal is a cache hit");
        assert_eq!(st.stolen_blocks, 1);
        assert_eq!(st.stolen_bytes, 128, "counted at the bin size");
        assert_eq!((st.cached_bytes, st.cached_blocks), (0, 0));
        assert_eq!(st.current_bytes, 100, "no fresh capacity carved");
        // the stolen handle routes to the thief's arena
        assert_eq!(pool.arena_of(b), 1);
        // recycled storage keeps stale contents, as always
        assert_eq!(pool.read_raw(b).unwrap(), vec![7u8; 100]);
        pool.free(b).unwrap();
    }

    #[test]
    fn bin_stealing_requires_a_fitting_bin() {
        let pool = MemoryPool::with_policy_arenas(1 << 20, PoolPolicy::Cached, 2);
        let a = pool.alloc_in(0, 100).unwrap(); // bin 128
        pool.free(a).unwrap();
        // a different-bin request in the other arena must NOT steal
        let b = pool.alloc_in(1, 300).unwrap(); // bin 512
        let st = pool.stats();
        assert_eq!(st.stolen_blocks, 0);
        assert_eq!(st.reuse_count, 0);
        assert_eq!(st.cached_blocks, 1, "arena 0's block stays parked");
        pool.free(b).unwrap();
    }

    #[test]
    fn local_bins_beat_stealing() {
        // When the allocating arena has its own parked block, it is
        // preferred — stealing only covers the local-miss case.
        let pool = MemoryPool::with_policy_arenas(1 << 20, PoolPolicy::Cached, 3);
        let a = pool.alloc_in(1, 100).unwrap();
        let b = pool.alloc_in(2, 100).unwrap();
        pool.free(a).unwrap();
        pool.free(b).unwrap();
        let c = pool.alloc_in(1, 100).unwrap(); // local hit, not a steal
        let st = pool.stats();
        assert_eq!(st.reuse_count, 1);
        assert_eq!(st.stolen_blocks, 0);
        assert_eq!(pool.arena_of(c), pool.arena_index(1));
        pool.free(c).unwrap();
    }

    #[test]
    fn arena_capacity_is_global() {
        let pool = MemoryPool::with_policy_arenas(256, PoolPolicy::Cached, 2);
        let a = pool.alloc_in(0, 100).unwrap(); // bin 128 in arena 0
        pool.free(a).unwrap();
        // a *different-bin* request in the other arena cannot steal;
        // capacity counts the parked block globally: live 0 + cached 128
        // + fresh 192 (bin 256... request 192) exceeds 256, so the pool
        // must pressure-trim arena 0's cache to satisfy it
        let b = pool.alloc_in(1, 192).unwrap();
        let st = pool.stats();
        assert_eq!(st.trim_count, 1);
        assert_eq!(st.cached_bytes, 0);
        assert_eq!(st.stolen_blocks, 0);
        pool.free(b).unwrap();
    }

    #[test]
    fn lru_cap_enforced_globally_across_arenas() {
        let pool = MemoryPool::with_policy_arenas(1 << 20, PoolPolicy::Cached, 2)
            .with_cache_cap(Some(128));
        let a = pool.alloc_in(0, 100).unwrap(); // bin 128
        let b = pool.alloc_in(1, 100).unwrap(); // bin 128
        pool.free(a).unwrap(); // arena 0 parks: cached 128 == cap
        // arena 1 parks its block, pushing the global cache to 256 > cap;
        // the freeing arena drains itself first, so arena 1's block goes
        // and arena 0's parked block survives.
        pool.free(b).unwrap();
        let st = pool.stats();
        assert_eq!(st.cached_bytes, 128);
        assert_eq!(st.cached_blocks, 1);
        assert_eq!(st.evicted_blocks, 1);
        let again = pool.alloc_in(0, 100).unwrap();
        assert_eq!(pool.stats().reuse_count, 1, "arena 0's block survived");
        pool.free(again).unwrap();
    }

    #[test]
    fn d2d_copies_across_arenas() {
        let pool = MemoryPool::with_policy_arenas(1 << 20, PoolPolicy::Cached, 3);
        let a = pool.alloc_in(0, 8).unwrap();
        let b = pool.alloc_in(2, 8).unwrap();
        pool.copy_h2d(a, &[5u8; 8]).unwrap();
        pool.copy_d2d(b, a).unwrap();
        assert_eq!(pool.read_raw(b).unwrap(), vec![5u8; 8]);
        // with_buffers spans arenas too
        pool.with_buffers(&[a, b], |bufs| {
            bufs[0][0] = 1;
            bufs[1][0] = 2;
        })
        .unwrap();
        assert_eq!(pool.read_raw(a).unwrap()[0], 1);
        assert_eq!(pool.read_raw(b).unwrap()[0], 2);
    }

    #[test]
    fn concurrent_arena_traffic_is_consistent() {
        use std::sync::Arc;
        let pool = Arc::new(MemoryPool::with_policy_arenas(
            1 << 22,
            PoolPolicy::Cached,
            4,
        ));
        let mut handles = Vec::new();
        for arena in 0..4usize {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..200usize {
                    let ptr = p.alloc_in(arena, 64 + (i % 3) * 64).unwrap();
                    p.copy_h2d(ptr, &[arena as u8; 64]).unwrap();
                    let mut out = vec![0u8; 64];
                    p.copy_d2h(ptr, &mut out).unwrap();
                    assert_eq!(out, vec![arena as u8; 64]);
                    p.free(ptr).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let st = pool.stats();
        assert_eq!(st.alloc_count, 800);
        assert_eq!(st.free_count, 800);
        assert_eq!(st.current_bytes, 0);
        assert_eq!(pool.live_buffers(), 0);
    }
}
