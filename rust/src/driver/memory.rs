//! Simulated device memory: a **disjoint address space** addressed by
//! opaque handles, exactly the restriction the paper builds on (§4.1:
//! "GPU and CPU memories are disjoint, pointers are not interchangeable").
//!
//! Buffers live in a pool owned by the context; host code can only move
//! data across with explicit `copy_h2d` / `copy_d2h` calls, which are
//! counted — the transfer-minimization claims of §6.3 are validated
//! against these counters.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};

/// Opaque device pointer (the `CUdeviceptr` analog). Never a host address.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DevicePtr(pub u64);

impl DevicePtr {
    pub fn null() -> Self {
        DevicePtr(0)
    }
    pub fn is_null(&self) -> bool {
        self.0 == 0
    }
}

/// Running transfer / allocation statistics for a pool.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemStats {
    pub alloc_count: u64,
    pub free_count: u64,
    pub h2d_count: u64,
    pub d2h_count: u64,
    pub d2d_count: u64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub d2d_bytes: u64,
    pub current_bytes: usize,
    pub peak_bytes: usize,
}

struct PoolInner {
    buffers: HashMap<u64, Vec<u8>>,
    stats: MemStats,
}

/// Device memory pool. One per context (the CUDA context owns allocations
/// the same way). Thread-safe: streams copy concurrently.
pub struct MemoryPool {
    capacity: usize,
    next: AtomicU64,
    inner: Mutex<PoolInner>,
}

/// Default simulated device memory: 4 GiB (GTX-Titan-class with headroom).
pub const DEFAULT_CAPACITY: usize = 4 << 30;

impl MemoryPool {
    pub fn new(capacity: usize) -> Self {
        MemoryPool {
            capacity,
            next: AtomicU64::new(1),
            inner: Mutex::new(PoolInner { buffers: HashMap::new(), stats: MemStats::default() }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// `cuMemAlloc`: allocate `bytes` of device memory.
    pub fn alloc(&self, bytes: usize) -> Result<DevicePtr> {
        let mut inner = self.inner.lock().unwrap();
        if inner.stats.current_bytes + bytes > self.capacity {
            return Err(Error::OutOfMemory {
                requested: bytes,
                available: self.capacity - inner.stats.current_bytes,
            });
        }
        let handle = self.next.fetch_add(1, Ordering::Relaxed);
        inner.buffers.insert(handle, vec![0u8; bytes]);
        inner.stats.alloc_count += 1;
        inner.stats.current_bytes += bytes;
        inner.stats.peak_bytes = inner.stats.peak_bytes.max(inner.stats.current_bytes);
        Ok(DevicePtr(handle))
    }

    /// `cuMemFree`. Double frees and unknown handles are errors (the
    /// framework relies on this to catch lifetime bugs in transfer plans).
    pub fn free(&self, ptr: DevicePtr) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        match inner.buffers.remove(&ptr.0) {
            Some(buf) => {
                inner.stats.free_count += 1;
                inner.stats.current_bytes -= buf.len();
                Ok(())
            }
            None => Err(Error::DoubleFree(ptr.0)),
        }
    }

    pub fn size_of(&self, ptr: DevicePtr) -> Result<usize> {
        let inner = self.inner.lock().unwrap();
        inner
            .buffers
            .get(&ptr.0)
            .map(|b| b.len())
            .ok_or(Error::InvalidDevicePtr(ptr.0))
    }

    /// `cuMemcpyHtoD`.
    pub fn copy_h2d(&self, dst: DevicePtr, src: &[u8]) -> Result<()> {
        self.copy_h2d_at(dst, 0, src)
    }

    pub fn copy_h2d_at(&self, dst: DevicePtr, offset: usize, src: &[u8]) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let buf = inner
            .buffers
            .get_mut(&dst.0)
            .ok_or(Error::InvalidDevicePtr(dst.0))?;
        if offset + src.len() > buf.len() {
            return Err(Error::OutOfBounds {
                ptr: dst.0,
                off: offset,
                len: src.len(),
                size: buf.len(),
            });
        }
        buf[offset..offset + src.len()].copy_from_slice(src);
        inner.stats.h2d_count += 1;
        inner.stats.h2d_bytes += src.len() as u64;
        Ok(())
    }

    /// `cuMemcpyDtoH`.
    pub fn copy_d2h(&self, src: DevicePtr, dst: &mut [u8]) -> Result<()> {
        self.copy_d2h_at(src, 0, dst)
    }

    pub fn copy_d2h_at(&self, src: DevicePtr, offset: usize, dst: &mut [u8]) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let buf = inner
            .buffers
            .get(&src.0)
            .ok_or(Error::InvalidDevicePtr(src.0))?;
        if offset + dst.len() > buf.len() {
            return Err(Error::OutOfBounds {
                ptr: src.0,
                off: offset,
                len: dst.len(),
                size: buf.len(),
            });
        }
        dst.copy_from_slice(&buf[offset..offset + dst.len()]);
        inner.stats.d2h_count += 1;
        inner.stats.d2h_bytes += dst.len() as u64;
        Ok(())
    }

    /// `cuMemcpyDtoD`.
    pub fn copy_d2d(&self, dst: DevicePtr, src: DevicePtr) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let data = inner
            .buffers
            .get(&src.0)
            .ok_or(Error::InvalidDevicePtr(src.0))?
            .clone();
        let dbuf = inner
            .buffers
            .get_mut(&dst.0)
            .ok_or(Error::InvalidDevicePtr(dst.0))?;
        if data.len() != dbuf.len() {
            return Err(Error::OutOfBounds {
                ptr: dst.0,
                off: 0,
                len: data.len(),
                size: dbuf.len(),
            });
        }
        dbuf.copy_from_slice(&data);
        inner.stats.d2d_count += 1;
        inner.stats.d2d_bytes += data.len() as u64;
        Ok(())
    }

    /// Read an entire device buffer into a fresh host vector (not counted
    /// as a D2H *transfer*: used by backends, which access device memory
    /// directly — the kernel-side view).
    pub fn read_raw(&self, ptr: DevicePtr) -> Result<Vec<u8>> {
        let inner = self.inner.lock().unwrap();
        inner
            .buffers
            .get(&ptr.0)
            .cloned()
            .ok_or(Error::InvalidDevicePtr(ptr.0))
    }

    /// Overwrite an entire device buffer (backend-side write; length must
    /// match exactly).
    pub fn write_raw(&self, ptr: DevicePtr, data: &[u8]) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let buf = inner
            .buffers
            .get_mut(&ptr.0)
            .ok_or(Error::InvalidDevicePtr(ptr.0))?;
        if data.len() != buf.len() {
            return Err(Error::OutOfBounds { ptr: ptr.0, off: 0, len: data.len(), size: buf.len() });
        }
        buf.copy_from_slice(data);
        Ok(())
    }

    /// Run `f` with a borrowed view of the buffer (zero-copy backend read;
    /// avoids the clone of [`MemoryPool::read_raw`] on hot launch paths —
    /// §Perf iteration I4).
    pub fn with_raw<R>(&self, ptr: DevicePtr, f: impl FnOnce(&[u8]) -> R) -> Result<R> {
        let inner = self.inner.lock().unwrap();
        let buf = inner
            .buffers
            .get(&ptr.0)
            .ok_or(Error::InvalidDevicePtr(ptr.0))?;
        Ok(f(buf))
    }

    /// Run `f` with a mutable view of the buffer (zero-copy backend access;
    /// used by the VTX interpreter for global memory).
    pub fn with_raw_mut<R>(
        &self,
        ptr: DevicePtr,
        f: impl FnOnce(&mut [u8]) -> R,
    ) -> Result<R> {
        let mut inner = self.inner.lock().unwrap();
        let buf = inner
            .buffers
            .get_mut(&ptr.0)
            .ok_or(Error::InvalidDevicePtr(ptr.0))?;
        Ok(f(buf))
    }

    /// Take several buffers out of the pool, run `f`, and put them back.
    /// Allows a kernel to access multiple buffers mutably without holding
    /// the pool lock for the duration of the launch.
    pub fn with_buffers<R>(
        &self,
        ptrs: &[DevicePtr],
        f: impl FnOnce(&mut [Vec<u8>]) -> R,
    ) -> Result<R> {
        let mut taken = Vec::with_capacity(ptrs.len());
        {
            let mut inner = self.inner.lock().unwrap();
            // Validate all first so we never partially remove.
            for p in ptrs {
                if !inner.buffers.contains_key(&p.0) {
                    return Err(Error::InvalidDevicePtr(p.0));
                }
            }
            // Duplicate pointers are not supported (aliasing) — error out.
            for (i, p) in ptrs.iter().enumerate() {
                if ptrs[..i].contains(p) {
                    return Err(Error::InvalidLaunch(format!(
                        "duplicate device pointer argument {:#x}",
                        p.0
                    )));
                }
            }
            for p in ptrs {
                taken.push(inner.buffers.remove(&p.0).unwrap());
            }
        }
        let result = f(&mut taken);
        {
            let mut inner = self.inner.lock().unwrap();
            for (p, buf) in ptrs.iter().zip(taken) {
                inner.buffers.insert(p.0, buf);
            }
        }
        Ok(result)
    }

    pub fn stats(&self) -> MemStats {
        self.inner.lock().unwrap().stats
    }

    pub fn reset_stats(&self) {
        let mut inner = self.inner.lock().unwrap();
        let live = inner.stats.current_bytes;
        let peak = inner.stats.peak_bytes;
        inner.stats = MemStats { current_bytes: live, peak_bytes: peak, ..MemStats::default() };
    }

    pub fn live_buffers(&self) -> usize {
        self.inner.lock().unwrap().buffers.len()
    }
}

impl Default for MemoryPool {
    fn default() -> Self {
        MemoryPool::new(DEFAULT_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_copy_roundtrip() {
        let pool = MemoryPool::default();
        let ptr = pool.alloc(8).unwrap();
        pool.copy_h2d(ptr, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        let mut out = [0u8; 8];
        pool.copy_d2h(ptr, &mut out).unwrap();
        assert_eq!(out, [1, 2, 3, 4, 5, 6, 7, 8]);
        let st = pool.stats();
        assert_eq!((st.h2d_count, st.d2h_count), (1, 1));
        assert_eq!((st.h2d_bytes, st.d2h_bytes), (8, 8));
    }

    #[test]
    fn double_free_detected() {
        let pool = MemoryPool::default();
        let ptr = pool.alloc(4).unwrap();
        pool.free(ptr).unwrap();
        assert!(matches!(pool.free(ptr), Err(Error::DoubleFree(_))));
    }

    #[test]
    fn use_after_free_detected() {
        let pool = MemoryPool::default();
        let ptr = pool.alloc(4).unwrap();
        pool.free(ptr).unwrap();
        assert!(pool.copy_h2d(ptr, &[0; 4]).is_err());
        let mut buf = [0u8; 4];
        assert!(pool.copy_d2h(ptr, &mut buf).is_err());
    }

    #[test]
    fn out_of_bounds_detected() {
        let pool = MemoryPool::default();
        let ptr = pool.alloc(4).unwrap();
        assert!(matches!(
            pool.copy_h2d(ptr, &[0; 8]),
            Err(Error::OutOfBounds { .. })
        ));
    }

    #[test]
    fn oom_enforced() {
        let pool = MemoryPool::new(16);
        let _a = pool.alloc(12).unwrap();
        assert!(matches!(pool.alloc(8), Err(Error::OutOfMemory { .. })));
    }

    #[test]
    fn peak_tracking() {
        let pool = MemoryPool::new(100);
        let a = pool.alloc(60).unwrap();
        pool.free(a).unwrap();
        let _b = pool.alloc(10).unwrap();
        let st = pool.stats();
        assert_eq!(st.peak_bytes, 60);
        assert_eq!(st.current_bytes, 10);
    }

    #[test]
    fn with_buffers_rejects_duplicates() {
        let pool = MemoryPool::default();
        let a = pool.alloc(4).unwrap();
        assert!(pool.with_buffers(&[a, a], |_| ()).is_err());
        // buffer must still be live afterwards
        assert_eq!(pool.size_of(a).unwrap(), 4);
    }

    #[test]
    fn with_buffers_restores_on_completion() {
        let pool = MemoryPool::default();
        let a = pool.alloc(4).unwrap();
        let b = pool.alloc(2).unwrap();
        pool.with_buffers(&[a, b], |bufs| {
            bufs[0][0] = 42;
            bufs[1][1] = 7;
        })
        .unwrap();
        assert_eq!(pool.read_raw(a).unwrap()[0], 42);
        assert_eq!(pool.read_raw(b).unwrap()[1], 7);
        assert_eq!(pool.live_buffers(), 2);
    }

    #[test]
    fn d2d_copy() {
        let pool = MemoryPool::default();
        let a = pool.alloc(4).unwrap();
        let b = pool.alloc(4).unwrap();
        pool.copy_h2d(a, &[9, 9, 9, 9]).unwrap();
        pool.copy_d2d(b, a).unwrap();
        assert_eq!(pool.read_raw(b).unwrap(), vec![9, 9, 9, 9]);
    }
}
