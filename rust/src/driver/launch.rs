//! Launch configuration and kernel arguments (the `cuLaunchKernel` call
//! surface).

use crate::driver::memory::DevicePtr;
use crate::error::{Error, Result};

/// 3-component dimension (grid or block), the CUDA `dim3` analog.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dim3 {
    pub x: u32,
    pub y: u32,
    pub z: u32,
}

impl Dim3 {
    pub fn count(&self) -> u64 {
        self.x as u64 * self.y as u64 * self.z as u64
    }
}

impl From<u32> for Dim3 {
    fn from(x: u32) -> Self {
        Dim3 { x, y: 1, z: 1 }
    }
}

impl From<(u32, u32)> for Dim3 {
    fn from((x, y): (u32, u32)) -> Self {
        Dim3 { x, y, z: 1 }
    }
}

impl From<(u32, u32, u32)> for Dim3 {
    fn from((x, y, z): (u32, u32, u32)) -> Self {
        Dim3 { x, y, z }
    }
}

/// Kernel launch configuration: grid/block dimensions + dynamic shared
/// memory, mirroring the triple-angle-bracket syntax parameters.
#[derive(Clone, Copy, Debug)]
pub struct LaunchConfig {
    pub grid: Dim3,
    pub block: Dim3,
    pub shared_mem_bytes: usize,
}

impl LaunchConfig {
    pub fn new(grid: impl Into<Dim3>, block: impl Into<Dim3>) -> Self {
        LaunchConfig { grid: grid.into(), block: block.into(), shared_mem_bytes: 0 }
    }

    pub fn with_shared_mem(mut self, bytes: usize) -> Self {
        self.shared_mem_bytes = bytes;
        self
    }

    /// Validate against device limits (max threads per block etc.).
    pub fn validate(&self, max_threads_per_block: u32, max_shared_mem: usize) -> Result<()> {
        if self.grid.count() == 0 || self.block.count() == 0 {
            return Err(Error::InvalidLaunch("zero-sized grid or block".into()));
        }
        if self.block.count() > max_threads_per_block as u64 {
            return Err(Error::InvalidLaunch(format!(
                "block has {} threads, device limit is {max_threads_per_block}",
                self.block.count()
            )));
        }
        if self.shared_mem_bytes > max_shared_mem {
            return Err(Error::InvalidLaunch(format!(
                "requested {} bytes of shared memory, device limit is {max_shared_mem}",
                self.shared_mem_bytes
            )));
        }
        Ok(())
    }
}

/// Execution statistics of one kernel launch, reported by backends that
/// track them (the VTX emulator's block scheduler; PJRT launches report
/// zeros). Consumed by `coordinator::LaunchMetrics` and the benches.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LaunchReport {
    /// Thread blocks executed.
    pub blocks: u64,
    /// Worker threads the schedule dispatched blocks across (1 for the
    /// sequential schedule).
    pub workers: usize,
    /// Sum of per-worker busy time, in nanoseconds.
    pub busy_ns: u64,
    /// Wall-clock time of the grid execution, in nanoseconds.
    pub wall_ns: u64,
    /// Virtual-ISA instructions retired across all threads.
    pub instrs: u64,
    /// Instructions retired inside fused superinstructions (zero on the
    /// scalar tier, which interprets the unfused stream).
    pub fused_instrs: u64,
    /// Interpreter dispatch events. On the scalar tier this equals
    /// `instrs`; on the vector tier one dispatch covers every active
    /// lane of a block, so `instrs / dispatches` is the amortization
    /// factor.
    pub dispatches: u64,
    /// Σ active lanes over vector-tier dispatches (zero on the scalar
    /// tier).
    pub lane_ops: u64,
    /// Σ block width over vector-tier dispatches — the lane capacity
    /// `lane_ops` is measured against.
    pub lane_slots: u64,
    /// Instructions retired inside JIT-compiled block bodies (zero
    /// unless the compiled tier ran).
    pub compiled_instrs: u64,
    /// Compiled-block body executions (each replaces the per-op vector
    /// dispatch loop for one block entry).
    pub compiled_blocks: u64,
    /// Blocks promoted from the vector tier to compiled form during
    /// this launch (each block tiers up at most once per decoded
    /// kernel, so warm launches report zero).
    pub tier_ups: u64,
    /// Compiled-body guard failures that fell back to the vector tier
    /// mid-block (the vector tier then replays from the exact failing
    /// instruction, preserving trap coordinates bitwise).
    pub deopts: u64,
}

impl LaunchReport {
    /// Fraction of the worker pool's wall-clock capacity spent executing
    /// blocks (1.0 = every worker busy the whole launch).
    pub fn utilization(&self) -> f64 {
        if self.wall_ns == 0 || self.workers == 0 {
            return 0.0;
        }
        self.busy_ns as f64 / (self.wall_ns as f64 * self.workers as f64)
    }

    /// Fraction of retired instructions covered by fused
    /// superinstructions (0.0 on the scalar tier).
    pub fn fused_share(&self) -> f64 {
        if self.instrs == 0 {
            return 0.0;
        }
        self.fused_instrs as f64 / self.instrs as f64
    }

    /// Fraction of retired instructions executed by JIT-compiled block
    /// bodies (0.0 unless the compiled tier ran). Mirrors
    /// [`fused_share`](Self::fused_share).
    pub fn compiled_share(&self) -> f64 {
        if self.instrs == 0 {
            return 0.0;
        }
        self.compiled_instrs as f64 / self.instrs as f64
    }

    /// Mean fraction of a block's lanes active per vector dispatch
    /// (1.0 = no divergence; 0.0 when the vector tier did not run).
    pub fn lane_utilization(&self) -> f64 {
        if self.lane_slots == 0 {
            return 0.0;
        }
        self.lane_ops as f64 / self.lane_slots as f64
    }
}

/// One kernel argument, as passed to `cuLaunchKernel`'s argument array.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelArg {
    /// Device buffer (pointer in the disjoint device address space).
    Ptr(DevicePtr),
    F32(f32),
    I32(i32),
    U32(u32),
}

impl KernelArg {
    pub fn as_ptr(&self) -> Result<DevicePtr> {
        match self {
            KernelArg::Ptr(p) => Ok(*p),
            other => Err(Error::InvalidLaunch(format!(
                "expected device pointer argument, got {other:?}"
            ))),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        match self {
            KernelArg::F32(v) => Ok(*v),
            KernelArg::I32(v) => Ok(*v as f32),
            KernelArg::U32(v) => Ok(*v as f32),
            other => Err(Error::InvalidLaunch(format!(
                "expected scalar argument, got {other:?}"
            ))),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            KernelArg::I32(v) => Ok(*v as i64),
            KernelArg::U32(v) => Ok(*v as i64),
            other => Err(Error::InvalidLaunch(format!(
                "expected integer argument, got {other:?}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dim3_conversions() {
        assert_eq!(Dim3::from(8), Dim3 { x: 8, y: 1, z: 1 });
        assert_eq!(Dim3::from((2, 3)).count(), 6);
        assert_eq!(Dim3::from((2, 3, 4)).count(), 24);
    }

    #[test]
    fn validate_rejects_zero_and_oversize() {
        let cfg = LaunchConfig::new(0u32, 32u32);
        assert!(cfg.validate(1024, 48 << 10).is_err());
        let cfg = LaunchConfig::new(1u32, 2048u32);
        assert!(cfg.validate(1024, 48 << 10).is_err());
        let cfg = LaunchConfig::new(1u32, 128u32).with_shared_mem(1 << 20);
        assert!(cfg.validate(1024, 48 << 10).is_err());
        let cfg = LaunchConfig::new((4, 4), (16, 16));
        assert!(cfg.validate(1024, 48 << 10).is_ok());
    }

    #[test]
    fn report_ratios_guard_division_by_zero() {
        let r = LaunchReport::default();
        assert_eq!(r.utilization(), 0.0);
        assert_eq!(r.fused_share(), 0.0);
        assert_eq!(r.compiled_share(), 0.0);
        assert_eq!(r.lane_utilization(), 0.0);
        let r = LaunchReport {
            instrs: 100,
            fused_instrs: 25,
            dispatches: 10,
            lane_ops: 80,
            lane_slots: 100,
            compiled_instrs: 95,
            ..LaunchReport::default()
        };
        assert!((r.fused_share() - 0.25).abs() < 1e-12);
        assert!((r.compiled_share() - 0.95).abs() < 1e-12);
        assert!((r.lane_utilization() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn arg_accessors() {
        assert!(KernelArg::F32(1.5).as_f32().unwrap() == 1.5);
        assert!(KernelArg::I32(-3).as_i64().unwrap() == -3);
        assert!(KernelArg::F32(1.0).as_ptr().is_err());
        assert!(KernelArg::Ptr(DevicePtr(4)).as_f32().is_err());
    }
}
