//! Simulated accelerator **driver API** — the CUDA driver API analog the
//! rest of the framework is built on (paper §2.1, §5).
//!
//! The surface mirrors the driver-API lifecycle the paper describes:
//! device enumeration → context creation → module loading (JIT of a
//! virtual ISA) → function handles → memory management in a *disjoint*
//! address space → stream-ordered launches with events.
//!
//! Two backends implement the execution side (as in the paper, where the
//! same API drives both CUDA hardware and the GPU Ocelot emulator):
//! [`crate::runtime::PjrtBackend`] (AOT HLO artifacts on the XLA/PJRT CPU
//! client) and [`crate::emulator::VtxBackend`] (interpreted VTX kernels).

pub mod backend;
pub mod context;
pub mod device;
pub mod deviceset;
pub mod event;
pub mod faults;
pub mod launch;
pub mod memory;
pub mod module;
pub mod stream;
pub mod streampool;

pub use backend::{Backend, DeviceFunction, LoadedModule, ModuleSource, TensorSpec};
pub use context::Context;
pub use device::{
    device, device_count, devices, emulator_device, emulator_devices, pjrt_device, BackendKind,
    Device, DeviceAttributes,
};
pub use deviceset::{DeviceSet, DeviceSetStats, Health};
pub use event::Event;
pub use faults::{FaultPlan, FaultRule, FaultSite};
pub use launch::{Dim3, KernelArg, LaunchConfig, LaunchReport};
pub use memory::{DevicePtr, MemStats, MemoryPool, PoolPolicy, DEFAULT_CAPACITY};
pub use module::{Function, Module};
pub use stream::Stream;
pub use streampool::{StreamLease, StreamPool, StreamPoolStats};
