//! Streams (`cuStream*`): ordered asynchronous work queues.
//!
//! Each stream owns a worker thread consuming closures in FIFO order —
//! launches and copies enqueued on different streams overlap, matching the
//! CUDA semantics the paper's host code relies on between kernel launches.
//!
//! Combined with the VTX emulator's parallel block scheduler this gives
//! genuinely asynchronous launches: the host enqueues
//! ([`Stream::launch`]), the stream thread dispatches the grid across the
//! emulator's worker pool which drains the blocks concurrently, and
//! [`Stream::synchronize`] (or an [`Event`]) joins.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::driver::event::Event;
use crate::driver::launch::{KernelArg, LaunchConfig};
use crate::driver::memory::{DevicePtr, MemoryPool};
use crate::driver::module::Function;
use crate::error::{Error, Result};

type Op = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Op(Op),
    Quit,
}

struct Tracker {
    submitted: u64,
    completed: u64,
    failed: Option<String>,
}

/// An asynchronous, ordered work queue backed by a worker thread.
///
/// Every stream carries an **arena id** (round-robin over a global
/// counter, starting at 1 so arena 0 stays the default synchronous
/// arena): allocations a pipeline makes for this stream's work go
/// through [`MemoryPool::alloc_in`] with that id, landing in a dedicated
/// pool shard so concurrent streams do not contend on one allocator
/// lock (see `docs/memory.md`, per-stream arenas).
pub struct Stream {
    tx: Sender<Msg>,
    tracker: Arc<(Mutex<Tracker>, Condvar)>,
    worker: Option<JoinHandle<()>>,
    arena_id: usize,
}

/// Round-robin source of stream arena ids (0 is reserved for the
/// default arena used by plain `alloc`).
static NEXT_ARENA: AtomicUsize = AtomicUsize::new(1);

impl Stream {
    /// `cuStreamCreate`.
    pub fn new() -> Self {
        let (tx, rx) = channel::<Msg>();
        let tracker = Arc::new((
            Mutex::new(Tracker { submitted: 0, completed: 0, failed: None }),
            Condvar::new(),
        ));
        let t2 = tracker.clone();
        let worker = std::thread::Builder::new()
            .name("hlgpu-stream".into())
            .spawn(move || {
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Op(op) => {
                            op();
                            let (lock, cv) = &*t2;
                            lock.lock().unwrap().completed += 1;
                            cv.notify_all();
                        }
                        Msg::Quit => break,
                    }
                }
            })
            .expect("failed to spawn stream worker");
        Stream {
            tx,
            tracker,
            worker: Some(worker),
            arena_id: NEXT_ARENA.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// The allocation arena assigned to this stream (pass to
    /// [`MemoryPool::alloc_in`] / `Context::alloc_in` so this stream's
    /// buffers live in their own pool shard).
    pub fn arena_id(&self) -> usize {
        self.arena_id
    }

    /// Enqueue an operation. Errors inside the op are captured and
    /// surfaced by the next `synchronize` (CUDA's sticky-error model).
    pub fn enqueue<F>(&self, op: F) -> Result<()>
    where
        F: FnOnce() -> Result<()> + Send + 'static,
    {
        {
            let (lock, _) = &*self.tracker;
            lock.lock().unwrap().submitted += 1;
        }
        let tracker = self.tracker.clone();
        self.tx
            .send(Msg::Op(Box::new(move || {
                if let Err(e) = op() {
                    let (lock, _) = &*tracker;
                    let mut t = lock.lock().unwrap();
                    if t.failed.is_none() {
                        t.failed = Some(e.to_string());
                    }
                }
            })))
            .map_err(|_| Error::Stream("stream worker has exited".into()))
    }

    /// `cuLaunchKernel` on a stream: enqueue an asynchronous kernel
    /// launch. The call returns immediately; the launch executes on the
    /// stream's worker (and, on the VTX emulator, fans its blocks out
    /// across the block-scheduler pool). Errors surface at the next
    /// [`Stream::synchronize`], CUDA's sticky-error model.
    pub fn launch(
        &self,
        function: &Function,
        cfg: LaunchConfig,
        args: Vec<KernelArg>,
        mem: Arc<MemoryPool>,
    ) -> Result<()> {
        let f = function.clone();
        self.enqueue(move || f.launch(&cfg, &args, &mem))
    }

    /// `cuMemcpyHtoDAsync`: enqueue a host→device upload of an owned
    /// buffer. The copy executes on the stream's worker in FIFO order
    /// with the stream's other work, so a subsequent launch on the same
    /// stream observes the uploaded data while *other* streams keep
    /// computing — the double-buffered upload pattern.
    pub fn copy_h2d(
        &self,
        mem: Arc<MemoryPool>,
        dst: DevicePtr,
        data: Vec<u8>,
    ) -> Result<()> {
        self.enqueue(move || mem.copy_h2d(dst, &data))
    }

    /// `cuMemcpyDtoHAsync`: enqueue a device→host download. The bytes
    /// land in the shared `dst` slot when the stream reaches the op, so
    /// the download observes every kernel enqueued before it — the same
    /// FIFO staging discipline as [`Stream::copy_h2d`], mirrored. The
    /// slot must be pre-sized to the buffer's byte length; readers join
    /// via an [`Event`] recorded after this op (what
    /// `PendingDownload` does) before touching the bytes.
    pub fn copy_d2h(
        &self,
        mem: Arc<MemoryPool>,
        src: DevicePtr,
        dst: Arc<Mutex<Vec<u8>>>,
    ) -> Result<()> {
        self.enqueue(move || {
            let mut buf = dst.lock().unwrap();
            mem.copy_d2h(src, &mut buf)
        })
    }

    /// Enqueue an event record (`cuEventRecord`): the event fires when all
    /// previously enqueued work has completed.
    pub fn record_event(&self, event: &Event) -> Result<()> {
        let ev = event.clone();
        self.enqueue(move || {
            ev.record_now();
            Ok(())
        })
    }

    /// `cuStreamWaitEvent`: all work enqueued after this call waits until
    /// `event` is recorded (by another stream or the host). This is the
    /// cross-stream fence the double-buffered pipelines use: the compute
    /// stream waits on the upload stream's record without blocking the
    /// host.
    pub fn wait_event(&self, event: &Event) -> Result<()> {
        let ev = event.clone();
        self.enqueue(move || {
            ev.synchronize();
            Ok(())
        })
    }

    /// `cuStreamSynchronize`: block until all enqueued work is done, and
    /// surface the first asynchronous error if any.
    pub fn synchronize(&self) -> Result<()> {
        let (lock, cv) = &*self.tracker;
        let mut t = lock.lock().unwrap();
        while t.completed < t.submitted {
            t = cv.wait(t).unwrap();
        }
        match t.failed.take() {
            Some(msg) => Err(Error::Stream(msg)),
            None => Ok(()),
        }
    }

    /// `cuStreamQuery`: true if all work submitted so far has completed.
    pub fn is_idle(&self) -> bool {
        let (lock, _) = &*self.tracker;
        let t = lock.lock().unwrap();
        t.completed >= t.submitted
    }

    /// Non-consuming view of the sticky error, if any work enqueued so
    /// far has failed. Unlike [`Stream::synchronize`] this neither blocks
    /// nor clears the error — `PendingLaunch::wait` uses it to surface a
    /// failure without swallowing it for a later synchronize.
    pub fn peek_error(&self) -> Option<String> {
        let (lock, _) = &*self.tracker;
        lock.lock().unwrap().failed.clone()
    }

    /// Reclaim a stream after a sticky error: block until every op
    /// submitted so far has drained off the worker, then clear and
    /// return the sticky error, if any. After this call the stream is
    /// clean — no in-flight work, no latent error — so it can be leased
    /// to a new client without serving another request's stale failure
    /// (or, worse, results ordered behind a failed op). This is the
    /// quarantine-then-reclaim primitive [`StreamPool`] applies to
    /// streams returned in an errored state.
    ///
    /// [`StreamPool`]: crate::driver::StreamPool
    pub fn reset_error(&self) -> Option<String> {
        let (lock, cv) = &*self.tracker;
        let mut t = lock.lock().unwrap();
        while t.completed < t.submitted {
            t = cv.wait(t).unwrap();
        }
        t.failed.take()
    }
}

impl Default for Stream {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Stream {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Quit);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    #[test]
    fn fifo_ordering() {
        let s = Stream::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..16 {
            let log = log.clone();
            s.enqueue(move || {
                log.lock().unwrap().push(i);
                Ok(())
            })
            .unwrap();
        }
        s.synchronize().unwrap();
        assert_eq!(*log.lock().unwrap(), (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn errors_are_sticky_until_synchronize() {
        let s = Stream::new();
        s.enqueue(|| Err(Error::Stream("boom".into()))).unwrap();
        s.enqueue(|| Ok(())).unwrap();
        let err = s.synchronize().unwrap_err();
        assert!(err.to_string().contains("boom"));
        // error consumed; next synchronize is clean
        s.synchronize().unwrap();
    }

    #[test]
    fn two_streams_overlap() {
        let a = Stream::new();
        let b = Stream::new();
        let counter = Arc::new(AtomicU32::new(0));
        let (c1, c2) = (counter.clone(), counter.clone());
        a.enqueue(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            c1.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        b.enqueue(move || {
            c2.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        // b's op should finish while a is still sleeping
        b.synchronize().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 1);
        a.synchronize().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn event_records_after_preceding_work() {
        let s = Stream::new();
        let ev = Event::new();
        let flag = Arc::new(AtomicU32::new(0));
        let f2 = flag.clone();
        s.enqueue(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            f2.store(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        s.record_event(&ev).unwrap();
        ev.synchronize();
        assert_eq!(flag.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn async_kernel_launch_drains_through_stream() {
        use crate::driver::backend::{Backend, ModuleSource};
        use crate::driver::module::Module;
        use crate::emulator::{KernelBuilder, VtxBackend};

        // out[block*bdim + tid] = tid  — multi-block so the emulator's
        // parallel scheduler engages behind the stream.
        let mut b = KernelBuilder::new("write_tid");
        let pout = b.ptr_param();
        let tid = b.tid_x();
        let bid = b.ctaid_x();
        let bdim = b.ntid_x();
        let base = b.imul(bid, bdim);
        let gid = b.iadd(base, tid);
        let v = b.cvt_i2f(tid);
        b.stg(pout, gid, v);
        b.ret();
        let kernel = b.build().unwrap();

        let loaded = VtxBackend::new()
            .load_module(&ModuleSource::Vtx { kernels: vec![kernel] })
            .unwrap();
        let module = Module::new("write_tid".into(), loaded);
        let f = module.function("write_tid").unwrap();

        let mem = Arc::new(crate::driver::memory::MemoryPool::default());
        let n = 128usize;
        let out = mem.alloc(n * 4).unwrap();

        let s = Stream::new();
        s.launch(
            &f,
            crate::driver::launch::LaunchConfig::new((n / 16) as u32, 16u32),
            vec![crate::driver::launch::KernelArg::Ptr(out)],
            mem.clone(),
        )
        .unwrap();
        s.synchronize().unwrap();

        let mut bytes = vec![0u8; n * 4];
        mem.copy_d2h(out, &mut bytes).unwrap();
        let vals: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(*v, (i % 16) as f32, "element {i}");
        }
    }

    #[test]
    fn streams_get_distinct_arena_ids() {
        let a = Stream::new();
        let b = Stream::new();
        assert_ne!(a.arena_id(), b.arena_id());
        assert!(a.arena_id() >= 1, "arena 0 is reserved for the default path");
    }

    #[test]
    fn async_copy_h2d_is_stream_ordered() {
        let mem = Arc::new(crate::driver::memory::MemoryPool::default());
        let dst = mem.alloc(4).unwrap();
        let s = Stream::new();
        s.copy_h2d(mem.clone(), dst, vec![1, 2, 3, 4]).unwrap();
        s.synchronize().unwrap();
        assert_eq!(mem.read_raw(dst).unwrap(), vec![1, 2, 3, 4]);
        // an upload into a dead handle is a sticky stream error
        mem.free(dst).unwrap();
        s.copy_h2d(mem.clone(), dst, vec![9]).unwrap();
        assert!(s.synchronize().is_err());
    }

    #[test]
    fn async_copy_d2h_is_stream_ordered() {
        let mem = Arc::new(crate::driver::memory::MemoryPool::default());
        let src = mem.alloc(4).unwrap();
        let s = Stream::new();
        // the download must observe the upload enqueued before it
        s.copy_h2d(mem.clone(), src, vec![9, 8, 7, 6]).unwrap();
        let slot = Arc::new(Mutex::new(vec![0u8; 4]));
        s.copy_d2h(mem.clone(), src, slot.clone()).unwrap();
        s.synchronize().unwrap();
        assert_eq!(*slot.lock().unwrap(), vec![9, 8, 7, 6]);
        let st = mem.stats();
        assert_eq!((st.h2d_count, st.d2h_count), (1, 1));
        // a download from a dead handle is a sticky stream error
        mem.free(src).unwrap();
        s.copy_d2h(mem.clone(), src, slot).unwrap();
        assert!(s.synchronize().is_err());
    }

    #[test]
    fn wait_event_fences_across_streams() {
        let producer = Stream::new();
        let consumer = Stream::new();
        let flag = Arc::new(AtomicU32::new(0));
        let ev = Event::new();
        let f1 = flag.clone();
        producer
            .enqueue(move || {
                std::thread::sleep(std::time::Duration::from_millis(25));
                f1.store(1, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
        producer.record_event(&ev).unwrap();
        consumer.wait_event(&ev).unwrap();
        let f2 = flag.clone();
        let seen = Arc::new(AtomicU32::new(99));
        let s2 = seen.clone();
        consumer
            .enqueue(move || {
                s2.store(f2.load(Ordering::SeqCst), Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
        consumer.synchronize().unwrap();
        assert_eq!(seen.load(Ordering::SeqCst), 1, "consumer ran after the fence");
        producer.synchronize().unwrap();
    }

    #[test]
    fn peek_error_does_not_consume() {
        let s = Stream::new();
        s.enqueue(|| Err(Error::Stream("boom".into()))).unwrap();
        while s.peek_error().is_none() {
            std::thread::yield_now();
        }
        assert!(s.peek_error().unwrap().contains("boom"));
        assert!(s.peek_error().is_some(), "peek leaves the sticky error in place");
        // synchronize still surfaces (and consumes) it
        assert!(s.synchronize().is_err());
        assert!(s.peek_error().is_none());
        s.synchronize().unwrap();
    }

    #[test]
    fn reset_error_drains_and_reclaims() {
        let s = Stream::new();
        s.enqueue(|| Err(Error::Stream("poisoned".into()))).unwrap();
        s.enqueue(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
            Ok(())
        })
        .unwrap();
        // reclaim: waits for the queue to drain, returns the sticky error
        let taken = s.reset_error().unwrap();
        assert!(taken.contains("poisoned"));
        assert!(s.is_idle(), "reset_error drains every submitted op");
        // the stream is clean: no latent error for the next client
        assert!(s.peek_error().is_none());
        assert!(s.reset_error().is_none());
        s.enqueue(|| Ok(())).unwrap();
        s.synchronize().unwrap();
    }

    #[test]
    fn is_idle_reflects_queue_state() {
        let s = Stream::new();
        s.enqueue(|| {
            std::thread::sleep(std::time::Duration::from_millis(20));
            Ok(())
        })
        .unwrap();
        // may or may not be idle instantly, but must be idle after sync
        s.synchronize().unwrap();
        assert!(s.is_idle());
    }
}
