//! Source-lines-of-code counting for Table 2 (programmer productivity).
//!
//! The paper counts "lines of code of entire application and core
//! algorithm". We count non-blank, non-comment lines, and support
//! `// SLOC:core-begin` / `// SLOC:core-end` (or `# ...` for Python)
//! region markers so each benchmark implementation can tag its core
//! algorithm exactly as the paper separates "Program" from "Core
//! algorithm" columns.

use std::path::Path;

use crate::error::Result;

/// Count result for one source file or region set.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SlocCount {
    /// All significant lines (non-blank, non-comment).
    pub total: usize,
    /// Lines inside `SLOC:core` regions.
    pub core: usize,
}

/// Count significant lines in source text. `comment` is the line-comment
/// leader (`//` for rust, `#` for python).
pub fn count_text(text: &str, comment: &str) -> SlocCount {
    let begin_marker = format!("{comment} SLOC:core-begin");
    let end_marker = format!("{comment} SLOC:core-end");
    let mut total = 0usize;
    let mut core = 0usize;
    let mut in_core = false;
    let mut in_block_comment = false;
    for line in text.lines() {
        let trimmed = line.trim();
        if trimmed.starts_with(&begin_marker) {
            in_core = true;
            continue;
        }
        if trimmed.starts_with(&end_marker) {
            in_core = false;
            continue;
        }
        // rust block doc comments /* ... */ (rare in this repo, handled
        // conservatively: a line starting the block until a line ending it)
        if comment == "//" {
            if in_block_comment {
                if trimmed.contains("*/") {
                    in_block_comment = false;
                }
                continue;
            }
            if trimmed.starts_with("/*") {
                if !trimmed.contains("*/") {
                    in_block_comment = true;
                }
                continue;
            }
        }
        if trimmed.is_empty() || trimmed.starts_with(comment) {
            continue;
        }
        // python docstrings: count them as comments when a line is only a
        // triple-quote delimiter (approximation adequate for our sources)
        if comment == "#" && (trimmed == "\"\"\"" || trimmed == "'''") {
            continue;
        }
        total += 1;
        if in_core {
            core += 1;
        }
    }
    SlocCount { total, core }
}

/// Count a file, inferring the comment leader from the extension.
pub fn count_file(path: impl AsRef<Path>) -> Result<SlocCount> {
    let path = path.as_ref();
    let comment = match path.extension().and_then(|e| e.to_str()) {
        Some("py") => "#",
        _ => "//",
    };
    let text = std::fs::read_to_string(path)?;
    Ok(count_text(&text, comment))
}

/// Sum counts over several files.
pub fn count_files<P: AsRef<Path>>(paths: &[P]) -> Result<SlocCount> {
    let mut acc = SlocCount::default();
    for p in paths {
        let c = count_file(p)?;
        acc.total += c.total;
        acc.core += c.core;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_skip_blanks_and_comments() {
        let src = "\n// comment\nlet x = 1;\n\nlet y = 2; // trailing ok\n";
        let c = count_text(src, "//");
        assert_eq!(c.total, 2);
        assert_eq!(c.core, 0);
    }

    #[test]
    fn core_regions_tracked() {
        let src = "\
setup();
// SLOC:core-begin
hot1();
hot2();
// SLOC:core-end
teardown();
";
        let c = count_text(src, "//");
        assert_eq!(c.total, 4);
        assert_eq!(c.core, 2);
    }

    #[test]
    fn python_comment_leader() {
        let src = "# comment\nx = 1\n\n# SLOC:core-begin\ny = 2\n# SLOC:core-end\n";
        let c = count_text(src, "#");
        assert_eq!(c.total, 2);
        assert_eq!(c.core, 1);
    }

    #[test]
    fn block_comments_skipped() {
        let src = "/* a\nb\nc */\nreal();\n";
        let c = count_text(src, "//");
        assert_eq!(c.total, 1);
    }
}
