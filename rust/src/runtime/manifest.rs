//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! (the build-time code generator) and the rust request path.
//!
//! The manifest plays the role of the paper's method cache *key space*:
//! each artifact is one kernel already specialized for a concrete argument
//! signature by the JAX AOT pass; the coordinator matches call signatures
//! against these entries (§6.2).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::driver::backend::TensorSpec;
use crate::error::{Error, Result};
use crate::util::Json;

/// One AOT-lowered artifact.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// Unique artifact name, e.g. `sinogram_radon_f32_128x90`.
    pub name: String,
    /// Logical kernel name, e.g. `sinogram` — what `@cuda` calls.
    pub kernel: String,
    /// HLO text file, relative to the manifest directory.
    pub path: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (tfunc name, size, angles, ...).
    pub meta: BTreeMap<String, String>,
}

impl ArtifactEntry {
    /// Signature string of the inputs, the specialization cache key format:
    /// `f32[128,128];f32[90]`.
    pub fn input_signature(&self) -> String {
        self.inputs
            .iter()
            .map(|s| s.signature())
            .collect::<Vec<_>>()
            .join(";")
    }

    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).map(|s| s.as_str())
    }
}

/// Loaded artifact library: manifest + directory.
#[derive(Clone, Debug)]
pub struct ArtifactLibrary {
    dir: PathBuf,
    entries: Vec<ArtifactEntry>,
}

fn parse_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| Error::Manifest("inputs/outputs must be arrays".into()))?;
    arr.iter()
        .map(|io| {
            let dtype = io
                .req("dtype")?
                .as_str()
                .ok_or_else(|| Error::Manifest("dtype must be a string".into()))?
                .to_string();
            let shape = io
                .req("shape")?
                .as_arr()
                .ok_or_else(|| Error::Manifest("shape must be an array".into()))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| Error::Manifest("shape dims must be integers".into()))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { dtype, shape })
        })
        .collect()
}

fn meta_to_strings(j: Option<&Json>) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    if let Some(Json::Obj(m)) = j {
        for (k, v) in m {
            let s = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => {
                    if n.fract() == 0.0 {
                        format!("{}", *n as i64)
                    } else {
                        format!("{n}")
                    }
                }
                Json::Bool(b) => b.to_string(),
                other => format!("{other:?}"),
            };
            out.insert(k.clone(), s);
        }
    }
    out
}

impl ArtifactLibrary {
    /// Load `manifest.json` from a directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactLibrary> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        Self::from_json(&text, dir)
    }

    /// Load from the default repository artifact directory.
    pub fn load_default() -> Result<ArtifactLibrary> {
        Self::load(crate::artifacts_dir())
    }

    pub fn from_json(text: &str, dir: PathBuf) -> Result<ArtifactLibrary> {
        let j = Json::parse(text)?;
        let version = j
            .req("version")?
            .as_usize()
            .ok_or_else(|| Error::Manifest("version must be an integer".into()))?;
        if version != 1 {
            return Err(Error::Manifest(format!("unsupported manifest version {version}")));
        }
        let arts = j
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| Error::Manifest("artifacts must be an array".into()))?;
        let entries = arts
            .iter()
            .map(|a| {
                Ok(ArtifactEntry {
                    name: a
                        .req("name")?
                        .as_str()
                        .ok_or_else(|| Error::Manifest("name must be a string".into()))?
                        .to_string(),
                    kernel: a
                        .req("kernel")?
                        .as_str()
                        .ok_or_else(|| Error::Manifest("kernel must be a string".into()))?
                        .to_string(),
                    path: a
                        .req("path")?
                        .as_str()
                        .ok_or_else(|| Error::Manifest("path must be a string".into()))?
                        .to_string(),
                    inputs: parse_specs(a.req("inputs")?)?,
                    outputs: parse_specs(a.req("outputs")?)?,
                    meta: meta_to_strings(a.get("meta")),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactLibrary { dir, entries })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Find an artifact by its unique name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find the artifact for `kernel` matching an input signature — the
    /// specialization lookup (§6.2). The signature is a `;`-joined list of
    /// `dtype[dims]` strings.
    pub fn find(&self, kernel: &str, input_signature: &str) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kernel == kernel && e.input_signature() == input_signature)
            .ok_or_else(|| Error::NoArtifact {
                kernel: kernel.to_string(),
                signature: input_signature.to_string(),
            })
    }

    /// All artifacts implementing a logical kernel.
    pub fn for_kernel(&self, kernel: &str) -> Vec<&ArtifactEntry> {
        self.entries.iter().filter(|e| e.kernel == kernel).collect()
    }

    /// Absolute path of an entry's HLO file.
    pub fn artifact_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.path)
    }

    /// Build the driver [`crate::driver::ModuleSource`] for an entry.
    pub fn module_source(&self, entry: &ArtifactEntry) -> crate::driver::ModuleSource {
        crate::driver::ModuleSource::HloFile {
            name: entry.name.clone(),
            path: self.artifact_path(entry),
            inputs: entry.inputs.clone(),
            outputs: entry.outputs.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "generated_by": "test",
      "artifacts": [
        {"name": "vadd_f32_12", "kernel": "vadd", "path": "vadd_f32_12.hlo.txt",
         "inputs": [{"dtype": "f32", "shape": [12]}, {"dtype": "f32", "shape": [12]}],
         "outputs": [{"dtype": "f32", "shape": [12]}],
         "meta": {"n": 12}},
        {"name": "sino_radon_64", "kernel": "sinogram", "path": "s.hlo.txt",
         "inputs": [{"dtype": "f32", "shape": [64, 64]}, {"dtype": "f32", "shape": [90]}],
         "outputs": [{"dtype": "f32", "shape": [90, 64]}],
         "meta": {"tfunc": "radon", "size": 64}}
      ]
    }"#;

    fn lib() -> ArtifactLibrary {
        ArtifactLibrary::from_json(SAMPLE, PathBuf::from("/tmp/arts")).unwrap()
    }

    #[test]
    fn parses_entries() {
        let l = lib();
        assert_eq!(l.len(), 2);
        let e = l.by_name("vadd_f32_12").unwrap();
        assert_eq!(e.kernel, "vadd");
        assert_eq!(e.input_signature(), "f32[12];f32[12]");
        assert_eq!(e.meta_usize("n"), Some(12));
    }

    #[test]
    fn signature_lookup() {
        let l = lib();
        let e = l.find("sinogram", "f32[64,64];f32[90]").unwrap();
        assert_eq!(e.name, "sino_radon_64");
        assert_eq!(e.meta_str("tfunc"), Some("radon"));
        // mismatch -> NoArtifact
        let err = l.find("sinogram", "f32[65,65];f32[90]").unwrap_err();
        assert!(matches!(err, Error::NoArtifact { .. }));
        assert_eq!(err.status(), "ERROR_NO_BINARY_FOR_GPU");
    }

    #[test]
    fn kernel_filter_and_paths() {
        let l = lib();
        assert_eq!(l.for_kernel("vadd").len(), 1);
        let e = l.by_name("sino_radon_64").unwrap();
        assert_eq!(
            l.artifact_path(e),
            PathBuf::from("/tmp/arts").join("s.hlo.txt")
        );
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 99");
        assert!(ArtifactLibrary::from_json(&bad, PathBuf::from(".")).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = r#"{"version": 1, "artifacts": [{"name": "x"}]}"#;
        let err = ArtifactLibrary::from_json(bad, PathBuf::from(".")).unwrap_err();
        assert!(err.to_string().contains("kernel"));
    }
}
