//! Runtime layer: PJRT client wrapper and the AOT artifact library.
//!
//! `load_hlo`-style flow (see /opt/xla-example): HLO text →
//! `HloModuleProto` → `XlaComputation` → `PjRtClient::compile` →
//! `execute`. Wrapped behind the [`crate::driver`] backend traits so the
//! coordinator is backend-agnostic.

pub mod manifest;
pub mod pjrt;

pub use manifest::{ArtifactEntry, ArtifactLibrary};
pub use pjrt::PjrtBackend;
