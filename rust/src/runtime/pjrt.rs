//! PJRT execution backend: loads AOT HLO-text artifacts and runs them on
//! the `xla` crate's CPU client.
//!
//! This is the "real hardware" path of the stack: the analog of the CUDA
//! driver consuming PTX. The HLO text was produced once at build time by
//! `python/compile/aot.py` from the JAX/Pallas model — Python never runs
//! here.
//!
//! ## Thread-safety strategy
//!
//! The `xla` crate's types are `!Send`/`!Sync` (the client is an `Rc`
//! internally, and executables/buffers hold `Rc` clones of it). The
//! driver API above this layer is multi-threaded (streams), so we route
//! **every** XLA operation — compile, execute, and executable drop —
//! through one process-global mutex ([`xla_lock`]). With all refcount
//! mutations serialized behind that lock, sharing the wrapped handles
//! across threads is sound; the `unsafe impl Send/Sync` below encode
//! exactly that invariant.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::driver::backend::{Backend, DeviceFunction, LoadedModule, ModuleSource, TensorSpec};
use crate::driver::launch::{KernelArg, LaunchConfig};
use crate::driver::memory::MemoryPool;
use crate::error::{Error, Result};

/// Global serialization lock for all XLA object operations, plus the
/// lazily created CPU client living behind it.
struct XlaGlobal {
    client: xla::PjRtClient,
}

// Safety: all access to the client (and to every object holding an Rc
// clone of it) is serialized through XLA_LOCK; see module docs.
unsafe impl Send for XlaGlobal {}

static XLA_LOCK: OnceLock<Mutex<XlaGlobal>> = OnceLock::new();

fn xla_lock() -> Result<MutexGuard<'static, XlaGlobal>> {
    // std's OnceLock has no stable get_or_try_init, so serialize the
    // fallible first initialization behind a dedicated mutex
    // (double-checked): exactly one client is ever constructed — the
    // serialization invariant the unsafe Send impl above relies on —
    // and a creation failure leaves the cell empty so a later call can
    // retry (or observe a client another thread installed meanwhile).
    if XLA_LOCK.get().is_none() {
        static INIT: Mutex<()> = Mutex::new(());
        let _init = INIT.lock().unwrap();
        if XLA_LOCK.get().is_none() {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Xla(format!("failed to create PJRT CPU client: {e}")))?;
            let _ = XLA_LOCK.set(Mutex::new(XlaGlobal { client }));
        }
    }
    Ok(XLA_LOCK.get().expect("just initialized").lock().unwrap())
}

/// Platform name of the global client (diagnostics).
pub fn platform_name() -> Result<String> {
    Ok(xla_lock()?.client.platform_name())
}

/// An executable cell whose drop re-enters the global lock, so the
/// client-Rc decrement cannot race with compiles on other threads.
struct ExeCell {
    inner: Mutex<Option<xla::PjRtLoadedExecutable>>,
}

// Safety: the contained executable is only touched under both its own
// mutex and (for operations that move client refcounts: execute, drop)
// the global XLA lock.
unsafe impl Send for ExeCell {}
unsafe impl Sync for ExeCell {}

impl Drop for ExeCell {
    fn drop(&mut self) {
        if let Ok(guard) = xla_lock() {
            let _hold = guard; // serialize the Rc decrement
            *self.inner.lock().unwrap() = None;
        }
        // If the lock itself failed (client never created), inner is None
        // anyway — nothing to drop.
    }
}

/// The PJRT-backed [`Backend`].
pub struct PjrtBackend;

static BACKEND: OnceLock<Arc<PjrtBackend>> = OnceLock::new();

impl PjrtBackend {
    /// The shared process-global backend instance (forces client
    /// creation so failures surface here, not on first launch).
    pub fn global() -> Result<Arc<dyn Backend>> {
        let _probe = xla_lock()?;
        Ok(BACKEND.get_or_init(|| Arc::new(PjrtBackend)).clone())
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt-cpu"
    }

    fn load_module(&self, source: &ModuleSource) -> Result<Arc<dyn LoadedModule>> {
        let (name, text, inputs, outputs) = match source {
            ModuleSource::HloText { name, text, inputs, outputs } => {
                (name.clone(), text.clone(), inputs.clone(), outputs.clone())
            }
            ModuleSource::HloFile { name, path, inputs, outputs } => {
                let text = std::fs::read_to_string(path).map_err(|e| Error::ModuleLoad {
                    backend: "pjrt-cpu".into(),
                    reason: format!("cannot read {}: {e}", path.display()),
                })?;
                (name.clone(), text, inputs.clone(), outputs.clone())
            }
            ModuleSource::Vtx { .. } => {
                return Err(Error::ModuleLoad {
                    backend: "pjrt-cpu".into(),
                    reason: "VTX kernels cannot run on the PJRT backend".into(),
                })
            }
        };
        // HLO text -> proto (ids reassigned by the parser) -> compile.
        // Everything under the global lock: proto/computation hold no
        // client refs, but compile does.
        let exe = {
            let guard = xla_lock()?;
            let proto = xla::HloModuleProto::parse_and_return_unverified_module(
                text.as_bytes(),
            )
            .map_err(|e| Error::ModuleLoad {
                backend: "pjrt-cpu".into(),
                reason: format!("HLO parse failed for `{name}`: {e}"),
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            guard.client.compile(&comp).map_err(|e| Error::ModuleLoad {
                backend: "pjrt-cpu".into(),
                reason: format!("PJRT compile failed for `{name}`: {e}"),
            })?
        };
        Ok(Arc::new(PjrtModule {
            name,
            exe: Arc::new(ExeCell { inner: Mutex::new(Some(exe)) }),
            inputs,
            outputs,
        }))
    }
}

/// A compiled PJRT executable exposed as a single-function module (AOT
/// modules have exactly one entry computation; the function name is the
/// module name, with `"main"` accepted as an alias).
pub struct PjrtModule {
    name: String,
    exe: Arc<ExeCell>,
    inputs: Vec<TensorSpec>,
    outputs: Vec<TensorSpec>,
}

impl LoadedModule for PjrtModule {
    fn function(&self, name: &str) -> Result<Arc<dyn DeviceFunction>> {
        if name != self.name && name != "main" {
            return Err(Error::FunctionNotFound(format!(
                "`{name}` (module `{}` exposes only `main`)",
                self.name
            )));
        }
        Ok(Arc::new(PjrtFunction {
            name: self.name.clone(),
            exe: self.exe.clone(),
            inputs: self.inputs.clone(),
            outputs: self.outputs.clone(),
        }))
    }

    fn function_names(&self) -> Vec<String> {
        vec![self.name.clone()]
    }
}

pub struct PjrtFunction {
    name: String,
    exe: Arc<ExeCell>,
    inputs: Vec<TensorSpec>,
    outputs: Vec<TensorSpec>,
}

fn element_type(dtype: &str) -> Result<xla::ElementType> {
    match dtype {
        "f32" => Ok(xla::ElementType::F32),
        "f64" => Ok(xla::ElementType::F64),
        "i32" => Ok(xla::ElementType::S32),
        other => Err(Error::Type(format!("unsupported artifact dtype `{other}`"))),
    }
}

impl PjrtFunction {
    /// Build an input literal from the raw bytes of a device buffer.
    fn literal_from_bytes(spec: &TensorSpec, bytes: &[u8]) -> Result<xla::Literal> {
        if bytes.len() != spec.byte_len() {
            return Err(Error::InvalidLaunch(format!(
                "input buffer has {} bytes, artifact expects {} ({})",
                bytes.len(),
                spec.byte_len(),
                spec.signature()
            )));
        }
        xla::Literal::create_from_shape_and_untyped_data(
            element_type(&spec.dtype)?,
            &spec.shape,
            bytes,
        )
        .map_err(|e| Error::Xla(format!("literal creation failed: {e}")))
    }

    fn literal_to_bytes(spec: &TensorSpec, lit: &xla::Literal) -> Result<Vec<u8>> {
        // One typed copy out of the literal, then a plain byte view of the
        // typed vec (LE host; no per-element loop — §Perf I4).
        fn collect<T: xla::ArrayElement + Copy>(lit: &xla::Literal) -> Result<Vec<u8>> {
            let v: Vec<T> = lit.to_vec().map_err(|e| Error::Xla(e.to_string()))?;
            let byte_len = std::mem::size_of_val(v.as_slice());
            // Safety: reading a POD slice as bytes is always valid.
            let bytes = unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, byte_len)
            };
            Ok(bytes.to_vec())
        }
        match spec.dtype.as_str() {
            "f32" => collect::<f32>(lit),
            "f64" => collect::<f64>(lit),
            "i32" => collect::<i32>(lit),
            other => Err(Error::Type(format!("unsupported artifact dtype `{other}`"))),
        }
    }
}

impl DeviceFunction for PjrtFunction {
    /// Argument convention: `args = [in_0, ..., in_{N-1}, out_0, ..., out_{M-1}]`,
    /// all device pointers. Grid/block are accepted but ignored — a PJRT
    /// module is a whole-computation launch (its internal parallelism was
    /// fixed by the AOT grid in the Pallas BlockSpecs).
    fn launch(&self, _cfg: &LaunchConfig, args: &[KernelArg], mem: &MemoryPool) -> Result<()> {
        let want = self.inputs.len() + self.outputs.len();
        if args.len() != want {
            return Err(Error::InvalidLaunch(format!(
                "kernel `{}` takes {} arguments ({} in + {} out), got {}",
                self.name,
                want,
                self.inputs.len(),
                self.outputs.len(),
                args.len()
            )));
        }
        // Gather input literals from device memory (literals hold no
        // client refs; safe outside the global lock). Borrowed access —
        // the literal constructor copies once, no intermediate Vec (§Perf I4).
        let mut literals = Vec::with_capacity(self.inputs.len());
        for (i, spec) in self.inputs.iter().enumerate() {
            let ptr = args[i].as_ptr()?;
            let lit = mem.with_raw(ptr, |bytes| Self::literal_from_bytes(spec, bytes))??;
            literals.push(lit);
        }
        // Execute under the global lock (buffers created/dropped inside
        // hold client refs). return_tuple=True at lowering: unwrap tuple.
        let tuple = {
            let _guard = xla_lock()?;
            let cell = self.exe.inner.lock().unwrap();
            let exe = cell.as_ref().ok_or_else(|| Error::Xla("executable dropped".into()))?;
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Xla(format!("execute `{}` failed: {e}", self.name)))?;
            result[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Xla(e.to_string()))?
            // device buffers in `result` drop here, still under the lock
        };
        let outputs = tuple
            .to_tuple()
            .map_err(|e| Error::Xla(format!("expected tuple output: {e}")))?;
        if outputs.len() != self.outputs.len() {
            return Err(Error::Xla(format!(
                "kernel `{}` returned {} outputs, manifest says {}",
                self.name,
                outputs.len(),
                self.outputs.len()
            )));
        }
        // Scatter outputs into the destination device buffers.
        for (k, (spec, lit)) in self.outputs.iter().zip(outputs.iter()).enumerate() {
            let ptr = args[self.inputs.len() + k].as_ptr()?;
            let bytes = Self::literal_to_bytes(spec, lit)?;
            mem.write_raw(ptr, &bytes)?;
        }
        Ok(())
    }

    fn name(&self) -> String {
        self.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_type_mapping() {
        assert!(matches!(element_type("f32"), Ok(xla::ElementType::F32)));
        assert!(matches!(element_type("i32"), Ok(xla::ElementType::S32)));
        assert!(element_type("q7").is_err());
    }

    #[test]
    fn literal_byte_len_checked() {
        let spec = TensorSpec::f32(&[4]);
        assert!(PjrtFunction::literal_from_bytes(&spec, &[0u8; 8]).is_err());
        let lit = PjrtFunction::literal_from_bytes(&spec, &[0u8; 16]).unwrap();
        assert_eq!(lit.element_count(), 4);
    }
}
