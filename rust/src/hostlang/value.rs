//! Boxed dynamic values and checked arrays.

use std::cell::RefCell;
use std::rc::Rc;

use crate::error::{Error, Result};

/// A boxed dynamic value — the heap-allocated "box" the paper's §4.1
/// works so hard to avoid on the GPU. Numeric storage is f64, so every
/// f32 kernel interaction incurs a conversion (the §7.3 "argument
/// conversion" overhead).
#[derive(Clone, Debug)]
pub enum Value {
    Nothing,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(DynArray),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Nothing => "Nothing",
            Value::Bool(_) => "Bool",
            Value::Int(_) => "Int64",
            Value::Float(_) => "Float64",
            Value::Str(_) => "String",
            Value::Array(_) => "Array{Float64}",
        }
    }

    /// Dynamic numeric conversion with checking (Julia's `convert`).
    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            Value::Bool(b) => Ok(if *b { 1.0 } else { 0.0 }),
            other => Err(Error::HostLang(format!(
                "cannot convert {} to Float64",
                other.type_name()
            ))),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            Value::Float(f) => {
                // InexactError semantics: only exact conversions allowed.
                if f.fract() == 0.0 {
                    Ok(*f as i64)
                } else {
                    Err(Error::HostLang(format!("InexactError: Int64({f})")))
                }
            }
            other => Err(Error::HostLang(format!(
                "cannot convert {} to Int64",
                other.type_name()
            ))),
        }
    }

    pub fn as_array(&self) -> Result<&DynArray> {
        match self {
            Value::Array(a) => Ok(a),
            other => Err(Error::HostLang(format!(
                "expected Array, got {}",
                other.type_name()
            ))),
        }
    }

    /// Dynamic binary `+` with type dispatch.
    pub fn add(&self, other: &Value) -> Result<Value> {
        match (self, other) {
            (Value::Int(a), Value::Int(b)) => Ok(Value::Int(a + b)),
            (Value::Array(a), Value::Array(b)) => Ok(Value::Array(a.zip_with(b, |x, y| x + y)?)),
            _ => Ok(Value::Float(self.as_float()? + other.as_float()?)),
        }
    }

    pub fn mul(&self, other: &Value) -> Result<Value> {
        match (self, other) {
            (Value::Int(a), Value::Int(b)) => Ok(Value::Int(a * b)),
            (Value::Array(a), Value::Array(b)) => Ok(Value::Array(a.zip_with(b, |x, y| x * y)?)),
            _ => Ok(Value::Float(self.as_float()? * other.as_float()?)),
        }
    }
}

/// A dynamically typed, shape-checked, **1-indexed** array (row major,
/// boxed f64 elements, shared via refcount like Julia bindings).
#[derive(Clone, Debug)]
pub struct DynArray {
    inner: Rc<RefCell<ArrInner>>,
}

#[derive(Debug)]
struct ArrInner {
    shape: Vec<usize>,
    data: Vec<f64>,
}

impl DynArray {
    pub fn zeros(shape: &[usize]) -> DynArray {
        let n: usize = shape.iter().product();
        DynArray {
            inner: Rc::new(RefCell::new(ArrInner { shape: shape.to_vec(), data: vec![0.0; n] })),
        }
    }

    pub fn from_vec(data: Vec<f64>, shape: &[usize]) -> Result<DynArray> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            return Err(Error::HostLang(format!(
                "DimensionMismatch: {} elements for shape {shape:?}",
                data.len()
            )));
        }
        Ok(DynArray {
            inner: Rc::new(RefCell::new(ArrInner { shape: shape.to_vec(), data })),
        })
    }

    pub fn from_f32(data: &[f32], shape: &[usize]) -> Result<DynArray> {
        Self::from_vec(data.iter().map(|&x| x as f64).collect(), shape)
    }

    pub fn shape(&self) -> Vec<usize> {
        self.inner.borrow().shape.clone()
    }

    pub fn numel(&self) -> usize {
        self.inner.borrow().data.len()
    }

    fn offset(&self, idx: &[usize]) -> Result<usize> {
        let inner = self.inner.borrow();
        if idx.len() != inner.shape.len() {
            return Err(Error::HostLang(format!(
                "BoundsError: {}-d index into {}-d array",
                idx.len(),
                inner.shape.len()
            )));
        }
        // 1-indexed, row-major linearization with per-dimension checks.
        let mut off = 0usize;
        for (d, (&i, &s)) in idx.iter().zip(&inner.shape).enumerate() {
            if i < 1 || i > s {
                return Err(Error::HostLang(format!(
                    "BoundsError: index {i} out of 1:{s} in dimension {}",
                    d + 1
                )));
            }
            off = off * s + (i - 1);
        }
        Ok(off)
    }

    /// Bounds-checked 1-indexed element read, boxed result.
    pub fn get(&self, idx: &[usize]) -> Result<Value> {
        let off = self.offset(idx)?;
        Ok(Value::Float(self.inner.borrow().data[off]))
    }

    /// Bounds-checked 1-indexed element write with dynamic conversion.
    pub fn set(&self, idx: &[usize], v: &Value) -> Result<()> {
        let off = self.offset(idx)?;
        let f = v.as_float()?;
        self.inner.borrow_mut().data[off] = f;
        Ok(())
    }

    /// Fast-ish raw accessors used by the framework boundary (upload /
    /// download) — still one conversion per element.
    pub fn to_f32_vec(&self) -> Vec<f32> {
        self.inner.borrow().data.iter().map(|&x| x as f32).collect()
    }

    pub fn fill_from_f32(&self, src: &[f32]) -> Result<()> {
        let mut inner = self.inner.borrow_mut();
        if src.len() != inner.data.len() {
            return Err(Error::HostLang("DimensionMismatch in fill".into()));
        }
        for (d, s) in inner.data.iter_mut().zip(src) {
            *d = *s as f64;
        }
        Ok(())
    }

    pub fn zip_with(&self, other: &DynArray, f: impl Fn(f64, f64) -> f64) -> Result<DynArray> {
        let a = self.inner.borrow();
        let b = other.inner.borrow();
        if a.shape != b.shape {
            return Err(Error::HostLang(format!(
                "DimensionMismatch: {:?} vs {:?}",
                a.shape, b.shape
            )));
        }
        let data = a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect();
        Ok(DynArray {
            inner: Rc::new(RefCell::new(ArrInner { shape: a.shape.clone(), data })),
        })
    }

    /// Dynamic reduction over all elements.
    pub fn reduce(&self, init: f64, f: impl Fn(f64, f64) -> f64) -> f64 {
        self.inner.borrow().data.iter().fold(init, |acc, &x| f(acc, x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_indexed_access() {
        let a = DynArray::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(a.get(&[1, 1]).unwrap().as_float().unwrap(), 1.0);
        assert_eq!(a.get(&[2, 2]).unwrap().as_float().unwrap(), 4.0);
        // 0 is out of bounds in a 1-indexed world
        assert!(a.get(&[0, 1]).is_err());
        assert!(a.get(&[3, 1]).is_err());
    }

    #[test]
    fn bounds_error_messages_name_dimension() {
        let a = DynArray::zeros(&[3, 5]);
        let err = a.get(&[2, 6]).unwrap_err().to_string();
        assert!(err.contains("1:5") && err.contains("dimension 2"), "{err}");
    }

    #[test]
    fn dynamic_dispatch_add() {
        let a = Value::Int(3);
        let b = Value::Float(1.5);
        assert_eq!(a.add(&b).unwrap().as_float().unwrap(), 4.5);
        let arr1 = Value::Array(DynArray::from_vec(vec![1.0, 2.0], &[2]).unwrap());
        let arr2 = Value::Array(DynArray::from_vec(vec![10.0, 20.0], &[2]).unwrap());
        let sum = arr1.add(&arr2).unwrap();
        assert_eq!(sum.as_array().unwrap().to_f32_vec(), vec![11.0, 22.0]);
    }

    #[test]
    fn inexact_int_conversion_errors() {
        assert!(Value::Float(1.5).as_int().is_err());
        assert_eq!(Value::Float(2.0).as_int().unwrap(), 2);
    }

    #[test]
    fn type_errors_are_dynamic() {
        let s = Value::Str("hi".into());
        let err = s.as_float().unwrap_err().to_string();
        assert!(err.contains("String"), "{err}");
    }

    #[test]
    fn shape_mismatch_in_zip() {
        let a = DynArray::zeros(&[2, 2]);
        let b = DynArray::zeros(&[4]);
        assert!(a.zip_with(&b, |x, y| x + y).is_err());
    }

    #[test]
    fn shared_binding_semantics() {
        let a = DynArray::zeros(&[2]);
        let b = a.clone(); // Julia-style binding, not a copy
        a.set(&[1], &Value::Float(9.0)).unwrap();
        assert_eq!(b.get(&[1]).unwrap().as_float().unwrap(), 9.0);
    }
}
