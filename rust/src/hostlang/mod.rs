//! `hostlang`: a dynamic, boxed, bounds-checked array layer that plays
//! the role of the high-level host language (Julia) in the evaluation.
//!
//! The paper's Figure 3 shows the Julia CPU implementation trailing C++
//! because of "unnecessary checks on integer conversions and array
//! bounds" and boxed values. This layer reproduces those costs *by
//! construction*: every value is a tagged enum (the box), every element
//! access bounds-checks and converts (f64 storage, like a dynamically
//! typed numeric tower), arrays are 1-indexed (Julia convention), and all
//! dispatch is dynamic.
//!
//! The "Julia"-analog benchmark implementations are written against this
//! API; the "C++"-analog ones use plain `f32` slices.

pub mod value;

pub use value::{DynArray, Value};
