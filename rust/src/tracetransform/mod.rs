//! The paper's case study (§7): the **trace transform** — image
//! descriptors from projections along straight lines at many orientations
//! (Kadyrov & Petrou 2001), with T/P/F functional stacks and the five
//! benchmark implementations of Tables 1–2 / Figure 3.

pub mod functionals;
pub mod image;
pub mod impls;
pub mod rotate;

pub use functionals::{
    feature_order, FFunctional, PFunctional, TFunctional, FEATURE_COUNT, F_SET, P_SET, T_SET,
};
pub use image::{orientations, random_phantom, shepp_logan, Image};
pub use impls::{
    default_reduce, default_shard, set_default_reduce, set_default_shard, AutoMode, CpuDynamic,
    CpuNative, DeviceChoice, GpuAuto, GpuDynamic, GpuManual, ReduceMode, ShardMode, TraceImpl,
};
