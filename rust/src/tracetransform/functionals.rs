//! The T/P/F functional stack of the trace transform (Kadyrov & Petrou;
//! paper §7.1).
//!
//! * **T-functionals** map each line (column of the rotated image) to a
//!   scalar → one sinogram row per orientation.
//! * **P-functionals** (diametric) reduce each sinogram row over the
//!   offset axis → the circus function of the orientation.
//! * **F-functionals** (circus) reduce the circus function to a single
//!   scalar → one feature per (T, P, F) triple.
//!
//! Names and formulas match `python/compile/kernels/tfunctionals.py` and
//! `ref.py` exactly, so features cross-check across all five
//! implementations and both backends.

/// T-functionals over a line f(r), with c = (n-1)/2 the line centre.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TFunctional {
    /// Σ f(r) — the Radon transform.
    Radon,
    /// Σ |r − c|·f(r).
    T1,
    /// Σ (r − c)²·f(r).
    T2,
    /// max f(r).
    TMax,
}

/// P-functionals over a sinogram row g(p).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PFunctional {
    /// Σ g(p).
    Sum,
    /// max g(p).
    Max,
    /// Σ |g(p)|.
    L1,
}

/// F-functionals over the circus function h(θ).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FFunctional {
    /// mean h.
    Mean,
    /// max h.
    Max,
}

pub const T_SET: [TFunctional; 4] =
    [TFunctional::Radon, TFunctional::T1, TFunctional::T2, TFunctional::TMax];
pub const P_SET: [PFunctional; 3] = [PFunctional::Sum, PFunctional::Max, PFunctional::L1];
pub const F_SET: [FFunctional; 2] = [FFunctional::Mean, FFunctional::Max];

/// Total number of features produced by the full stack.
pub const FEATURE_COUNT: usize = T_SET.len() * P_SET.len() * F_SET.len();

impl TFunctional {
    /// Manifest/kernel name (shared with the Python side).
    pub fn name(self) -> &'static str {
        match self {
            TFunctional::Radon => "radon",
            TFunctional::T1 => "t1",
            TFunctional::T2 => "t2",
            TFunctional::TMax => "tmax",
        }
    }

    pub fn from_name(name: &str) -> Option<TFunctional> {
        T_SET.iter().copied().find(|t| t.name() == name)
    }

    /// Apply to a strided column: elements `col[i*stride]`, `n` of them.
    #[inline]
    pub fn apply_strided(self, col: &[f32], n: usize, stride: usize) -> f32 {
        let c = (n as f32 - 1.0) / 2.0;
        match self {
            TFunctional::Radon => {
                let mut acc = 0.0;
                for i in 0..n {
                    acc += col[i * stride];
                }
                acc
            }
            TFunctional::T1 => {
                let mut acc = 0.0;
                for i in 0..n {
                    acc += (i as f32 - c).abs() * col[i * stride];
                }
                acc
            }
            TFunctional::T2 => {
                let mut acc = 0.0;
                for i in 0..n {
                    let d = i as f32 - c;
                    acc += d * d * col[i * stride];
                }
                acc
            }
            TFunctional::TMax => {
                let mut acc = f32::NEG_INFINITY;
                for i in 0..n {
                    acc = acc.max(col[i * stride]);
                }
                acc
            }
        }
    }
}

impl PFunctional {
    pub fn name(self) -> &'static str {
        match self {
            PFunctional::Sum => "psum",
            PFunctional::Max => "pmax",
            PFunctional::L1 => "pl1",
        }
    }

    pub fn apply(self, row: &[f32]) -> f32 {
        match self {
            PFunctional::Sum => row.iter().sum(),
            PFunctional::Max => row.iter().copied().fold(f32::NEG_INFINITY, f32::max),
            PFunctional::L1 => row.iter().map(|v| v.abs()).sum(),
        }
    }
}

impl FFunctional {
    pub fn name(self) -> &'static str {
        match self {
            FFunctional::Mean => "fmean",
            FFunctional::Max => "fmax",
        }
    }

    pub fn apply(self, circus: &[f32]) -> f32 {
        match self {
            FFunctional::Mean => circus.iter().sum::<f32>() / circus.len() as f32,
            FFunctional::Max => circus.iter().copied().fold(f32::NEG_INFINITY, f32::max),
        }
    }
}

/// Feature index order: (t, p, f) lexicographic over the sets above —
/// identical to `python/compile/model.py::FEATURE_ORDER`.
pub fn feature_order() -> Vec<(TFunctional, PFunctional, FFunctional)> {
    let mut order = Vec::with_capacity(FEATURE_COUNT);
    for t in T_SET {
        for p in P_SET {
            for f in F_SET {
                order.push((t, p, f));
            }
        }
    }
    order
}

/// Reduce a sinogram (`angles` rows × `width` offsets, row-major) with
/// every (P, F) pair, in order. Returns `P_SET.len() * F_SET.len()`
/// features for the given T's sinogram.
///
/// Single sweep: each row is read **once**, producing all `|P|` circus
/// values simultaneously, and the `|F|` accumulators fold the circus
/// functions on the fly — no per-P `Vec`, no re-reading the row per
/// functional. Structurally this is the host twin of the device-side
/// `circus_all`/`features_all` kernel pair (`docs/emulator.md`), and
/// both F-functionals are streaming (running sum for the mean, running
/// max), so the output is bitwise-identical to the staged
/// circus-then-reduce formulation.
pub fn reduce_sinogram(sino: &[f32], angles: usize, width: usize) -> Vec<f32> {
    assert_eq!(sino.len(), angles * width);
    const NP: usize = P_SET.len();
    // Per-P circus folds over the angle axis: running sum (F = Mean) and
    // running max (F = Max).
    let mut csum = [0.0f32; NP];
    let mut cmax = [f32::NEG_INFINITY; NP];
    for row in sino.chunks_exact(width) {
        // One pass over the row computes every P value.
        let (mut sum, mut l1) = (0.0f32, 0.0f32);
        let mut max = f32::NEG_INFINITY;
        for &v in row {
            sum += v;
            max = max.max(v);
            l1 += v.abs();
        }
        for ((cs, cm), circus) in csum.iter_mut().zip(&mut cmax).zip([sum, max, l1]) {
            *cs += circus;
            *cm = cm.max(circus);
        }
    }
    let mut out = Vec::with_capacity(NP * F_SET.len());
    for (cs, cm) in csum.iter().zip(&cmax) {
        out.push(cs / angles as f32); // FFunctional::Mean
        out.push(*cm); // FFunctional::Max
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn radon_is_plain_sum() {
        let col = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(TFunctional::Radon.apply_strided(&col, 4, 1), 10.0);
    }

    #[test]
    fn t1_t2_weights_centered() {
        // n=3 -> c=1 -> weights |{-1,0,1}| = {1,0,1} and squares {1,0,1}
        let col = [2.0, 100.0, 4.0];
        assert_eq!(TFunctional::T1.apply_strided(&col, 3, 1), 6.0);
        assert_eq!(TFunctional::T2.apply_strided(&col, 3, 1), 6.0);
    }

    #[test]
    fn tmax_handles_negatives() {
        let col = [-5.0, -2.0, -9.0];
        assert_eq!(TFunctional::TMax.apply_strided(&col, 3, 1), -2.0);
    }

    #[test]
    fn strided_access_reads_columns() {
        // 2x3 row-major matrix; column 1 = [2, 5]
        let m = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(TFunctional::Radon.apply_strided(&m[1..], 2, 3), 7.0);
    }

    #[test]
    fn p_functionals() {
        let row = [1.0, -2.0, 3.0];
        assert_eq!(PFunctional::Sum.apply(&row), 2.0);
        assert_eq!(PFunctional::Max.apply(&row), 3.0);
        assert_eq!(PFunctional::L1.apply(&row), 6.0);
    }

    #[test]
    fn f_functionals() {
        let h = [1.0, 2.0, 3.0, 6.0];
        assert_eq!(FFunctional::Mean.apply(&h), 3.0);
        assert_eq!(FFunctional::Max.apply(&h), 6.0);
    }

    #[test]
    fn feature_order_matches_python_convention() {
        let order = feature_order();
        assert_eq!(order.len(), FEATURE_COUNT);
        assert_eq!(order[0], (TFunctional::Radon, PFunctional::Sum, FFunctional::Mean));
        assert_eq!(order[1], (TFunctional::Radon, PFunctional::Sum, FFunctional::Max));
        assert_eq!(
            order[FEATURE_COUNT - 1],
            (TFunctional::TMax, PFunctional::L1, FFunctional::Max)
        );
    }

    /// The single-sweep `reduce_sinogram` must stay bitwise-identical to
    /// the staged circus-then-reduce formulation it replaced (same fold
    /// orders everywhere), so the host reference for the device kernels
    /// is the function the pipeline actually runs.
    #[test]
    fn reduce_sinogram_matches_staged_formulation_bitwise() {
        let (angles, width) = (7usize, 11usize);
        let sino: Vec<f32> = (0..angles * width)
            .map(|i| ((i * 37) % 23) as f32 * 0.31 - 3.0)
            .collect();
        let got = reduce_sinogram(&sino, angles, width);
        let mut want = Vec::new();
        for p in P_SET {
            let circus: Vec<f32> = (0..angles)
                .map(|a| p.apply(&sino[a * width..(a + 1) * width]))
                .collect();
            for f in F_SET {
                want.push(f.apply(&circus));
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn names_roundtrip() {
        for t in T_SET {
            assert_eq!(TFunctional::from_name(t.name()), Some(t));
        }
        assert_eq!(TFunctional::from_name("bogus"), None);
    }
}
