//! Implementation 4 — "Julia (CPU) + CUDA (GPU)": dynamic `hostlang` host
//! code, manual driver API, precompiled kernels. The host data lives in
//! boxed f64 arrays, so every launch pays the "copying and converting
//! Julia datatypes before they are uploaded" cost the paper measures
//! (§7.3), and the between-kernel glue (P/F stacks) runs dynamically.

use std::collections::HashMap;

use crate::coordinator::{checked_cfg, checked_cfg2, DeviceArray};
use crate::driver::{Context, Function, KernelArg, ModuleSource};
use crate::error::Result;
use crate::hostlang::DynArray;
use crate::runtime::ArtifactLibrary;
use crate::tensor::{Dtype, Tensor};
use crate::tracetransform::functionals::{
    FFunctional, PFunctional, FEATURE_COUNT, F_SET, P_SET, T_SET,
};
use crate::tracetransform::image::Image;
use crate::tracetransform::impls::{
    alloc3, alloc_n, default_reduce, free3, free_n, DeviceChoice, ReduceMode, TraceImpl,
};

pub struct GpuDynamic {
    ctx: Context,
    device: DeviceChoice,
    library: Option<ArtifactLibrary>,
    functions: HashMap<(&'static str, usize, usize), Function>,
    /// Device-resident angle table for the batched path: the boxing tax
    /// and the upload are paid once per distinct angle set, not per
    /// batch (keyed by the raw bits).
    angles_dev: Option<(Vec<u32>, DeviceArray)>,
    /// Persistent batched-path device buffers (stacked images,
    /// sinograms, and the device-reduce chain's circus/feature blocks),
    /// keyed by (batch, size, angles) and reused across batches of the
    /// same shape.
    batch_bufs: Option<BatchBufs>,
}

/// The batched path's persistent device arrays. The reduce-chain
/// scratch exists only on the device-reduce path — host-reduce batches
/// must not hold device memory they never touch.
struct BatchBufs {
    key: (usize, usize, usize),
    imgs: DeviceArray,
    sinos: DeviceArray,
    circus: Option<DeviceArray>,
    feats: Option<DeviceArray>,
}

type DynFeats = Vec<f32>;

/// Box one T-functional's sinogram plane back into the dynamic world and
/// run the P/F stacks on it — the between-kernel glue that stays dynamic
/// (§7.3). Shared by the sequential and batched paths.
fn dyn_reduce_plane(plane: &[f32], a: usize, s: usize) -> Result<DynFeats> {
    let sino = DynArray::zeros(&[a, s]);
    sino.fill_from_f32(plane)?;
    let mut feats = Vec::with_capacity(P_SET.len() * F_SET.len());
    for p in P_SET {
        let mut circus = Vec::with_capacity(a);
        for ai in 1..=a {
            let mut acc = match p {
                PFunctional::Max => f64::NEG_INFINITY,
                _ => 0.0,
            };
            for x in 1..=s {
                let v = sino.get(&[ai, x])?.as_float()?;
                match p {
                    PFunctional::Sum => acc += v,
                    PFunctional::Max => acc = acc.max(v),
                    PFunctional::L1 => acc += v.abs(),
                }
            }
            circus.push(acc);
        }
        for f in F_SET {
            let v = match f {
                FFunctional::Mean => circus.iter().sum::<f64>() / a as f64,
                FFunctional::Max => {
                    circus.iter().copied().fold(f64::NEG_INFINITY, f64::max)
                }
            };
            feats.push(v as f32);
        }
    }
    Ok(feats)
}

impl GpuDynamic {
    pub fn new() -> Result<GpuDynamic> {
        Self::on_device(DeviceChoice::Pjrt)
    }

    pub fn on_device(device: DeviceChoice) -> Result<GpuDynamic> {
        let ctx = Context::create(&device.device()?)?;
        let library = match device {
            DeviceChoice::Pjrt => Some(ArtifactLibrary::load_default()?),
            DeviceChoice::Emulator => None,
        };
        Ok(GpuDynamic {
            ctx,
            device,
            library,
            functions: HashMap::new(),
            angles_dev: None,
            batch_bufs: None,
        })
    }

    fn function(&mut self, s: usize, a: usize) -> Result<Function> {
        let key = ("sinogram_all", s, a);
        if let Some(f) = self.functions.get(&key) {
            return Ok(f.clone());
        }
        let f = match self.device {
            DeviceChoice::Pjrt => {
                let lib = self.library.as_ref().expect("library loaded for pjrt");
                let sig = format!("f32[{s},{s}];f32[{a}]");
                let entry = lib.find("sinogram_all", &sig)?.clone();
                let module = self.ctx.load_module(&lib.module_source(&entry))?;
                module.function("main")?
            }
            DeviceChoice::Emulator => {
                let module = self.ctx.load_module(&ModuleSource::Vtx {
                    kernels: vec![crate::emulator::kernels::sinogram_all()?],
                })?;
                module.function("sinogram_all")?
            }
        };
        self.functions.insert(key, f.clone());
        Ok(f)
    }

    /// Handles for the device-side P/F stage (emulator only): the
    /// `circus_all` kernel is specialized per row width `s`, the
    /// `features_all` kernel per angle count `a` (their tree widths are
    /// the next powers of two).
    fn reduce_functions(&mut self, s: usize, a: usize) -> Result<(Function, Function)> {
        let ckey = ("circus_all", s, 0);
        let fkey = ("features_all", 0, a);
        if let (Some(c), Some(f)) = (self.functions.get(&ckey), self.functions.get(&fkey)) {
            return Ok((c.clone(), f.clone()));
        }
        // the generated kernels carry their tree width in their names —
        // resolve by that, so the driver's name-keyed module cache never
        // serves a mismatched width
        let ck = crate::emulator::kernels::circus_all(s.next_power_of_two())?;
        let cname = ck.name.clone();
        let cmod = self.ctx.load_module(&ModuleSource::Vtx { kernels: vec![ck] })?;
        let c = cmod.function(&cname)?;
        let fk = crate::emulator::kernels::features_all(a.next_power_of_two())?;
        let fname = fk.name.clone();
        let fmod = self.ctx.load_module(&ModuleSource::Vtx { kernels: vec![fk] })?;
        let f = fmod.function(&fname)?;
        self.functions.insert(ckey, c.clone());
        self.functions.insert(fkey, f.clone());
        Ok((c, f))
    }

    /// True when this call's P/F stage runs on the device.
    fn device_reduce(&self) -> bool {
        self.device == DeviceChoice::Emulator && default_reduce() == ReduceMode::Device
    }

    /// Launch the device-side `circus_all → features_all` chain over
    /// `rows = T` sinogram planes already resident at `sinos`, leaving
    /// the `rows/|T| * FEATURE_COUNT` feature block at `feats`.
    fn launch_reduce(
        &mut self,
        sinos: crate::driver::DevicePtr,
        circus: crate::driver::DevicePtr,
        feats: crate::driver::DevicePtr,
        rows: usize,
        s: usize,
        a: usize,
    ) -> Result<()> {
        let np = P_SET.len();
        let (cf, ff) = self.reduce_functions(s, a)?;
        cf.launch(
            &checked_cfg2("circus_all", (a, rows), s.next_power_of_two())?,
            &[
                KernelArg::Ptr(sinos),
                KernelArg::Ptr(circus),
                KernelArg::I32(s as i32),
            ],
            self.ctx.memory()?,
        )?;
        ff.launch(
            &checked_cfg2("features_all", (np, rows), a.next_power_of_two())?,
            &[
                KernelArg::Ptr(circus),
                KernelArg::Ptr(feats),
                KernelArg::I32(a as i32),
            ],
            self.ctx.memory()?,
        )?;
        Ok(())
    }

    /// Batched kernel handle (emulator only; the generated kernel is
    /// shape-generic so one cache entry serves every batch).
    fn batched_function(&mut self) -> Result<Function> {
        let key = ("batched_sinogram", 0usize, 0usize);
        if let Some(f) = self.functions.get(&key) {
            return Ok(f.clone());
        }
        let module = self.ctx.load_module(&ModuleSource::Vtx {
            kernels: vec![crate::emulator::kernels::batched_sinogram()?],
        })?;
        let f = module.function("batched_sinogram")?;
        self.functions.insert(key, f.clone());
        Ok(f)
    }
}

impl TraceImpl for GpuDynamic {
    fn name(&self) -> &'static str {
        "gpu-dynamic"
    }

    fn features(&mut self, img: &Image, thetas: &[f32]) -> Result<Vec<f32>> {
        // SLOC:core-begin
        let s = img.size();
        let a = thetas.len();

        // host world: boxed f64 arrays (the dynamic language's natural type)
        let dimg = DynArray::from_f32(img.pixels(), &[s, s])?;
        let dangles =
            DynArray::from_vec(thetas.iter().map(|&t| t as f64).collect(), &[a])?;

        // per-launch conversion: f64 boxes -> f32 tensors (the overhead
        // the paper attributes to argument conversion)
        let img_t = Tensor::from_f64_as_f32(
            &dimg.to_f32_vec().iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &[s, s],
        );
        let angles_t = Tensor::from_f64_as_f32(
            &dangles.to_f32_vec().iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &[a],
        );

        let nt = T_SET.len();
        let np = P_SET.len();

        if self.device_reduce() {
            // Device-resident P/F stage: sinograms and circus functions
            // never reach the boxed world; only the FEATURE_COUNT-float
            // block is downloaded (the dynamic tax stays on the inputs).
            let ptrs = alloc_n(
                &self.ctx,
                &[
                    img_t.byte_len(),
                    angles_t.byte_len(),
                    nt * a * s * 4,
                    nt * np * a * 4,
                    FEATURE_COUNT * 4,
                ],
            )?;
            let (ga, gb, gc, gd, ge) = (ptrs[0], ptrs[1], ptrs[2], ptrs[3], ptrs[4]);
            let body = (|| -> Result<Vec<f32>> {
                self.ctx.upload(ga, img_t.bytes())?;
                self.ctx.upload(gb, angles_t.bytes())?;
                let f = self.function(s, a)?;
                f.launch(
                    &checked_cfg("sinogram_all", a, s)?,
                    &[
                        KernelArg::Ptr(ga),
                        KernelArg::Ptr(gb),
                        KernelArg::Ptr(gc),
                        KernelArg::I32(s as i32),
                    ],
                    self.ctx.memory()?,
                )?;
                self.launch_reduce(gc, gd, ge, nt, s, a)?;
                let mut feats_host = Tensor::zeros_f32(&[FEATURE_COUNT]);
                self.ctx.download(ge, feats_host.bytes_mut())?;
                Ok(feats_host.to_vec_f32())
            })();
            return free_n(&self.ctx, &ptrs, body);
        }

        let (ga, gb, gc) =
            alloc3(&self.ctx, img_t.byte_len(), angles_t.byte_len(), nt * a * s * 4)?;

        // transfers + launch; the buffers must be freed on every path — a
        // mid-call error must not leak device memory
        let body = (|| -> Result<Tensor> {
            self.ctx.upload(ga, img_t.bytes())?;
            self.ctx.upload(gb, angles_t.bytes())?;
            // one fused launch computes every T-functional's sinogram
            let f = self.function(s, a)?;
            let args = match self.device {
                DeviceChoice::Pjrt => {
                    vec![KernelArg::Ptr(ga), KernelArg::Ptr(gb), KernelArg::Ptr(gc)]
                }
                DeviceChoice::Emulator => vec![
                    KernelArg::Ptr(ga),
                    KernelArg::Ptr(gb),
                    KernelArg::Ptr(gc),
                    KernelArg::I32(s as i32),
                ],
            };
            f.launch(&checked_cfg("sinogram_all", a, s)?, &args, self.ctx.memory()?)?;
            let mut sinos_host = Tensor::zeros_f32(&[nt, a, s]);
            self.ctx.download(gc, sinos_host.bytes_mut())?;
            Ok(sinos_host)
        })();
        let sinos_host = free3(&self.ctx, ga, gb, gc, body)?;

        let mut feats: DynFeats = Vec::with_capacity(nt * 6);
        for ti in 0..nt {
            // back into the boxed world before the dynamic P/F stacks
            feats.extend(dyn_reduce_plane(
                &sinos_host.as_f32()[ti * a * s..(ti + 1) * a * s],
                a,
                s,
            )?);
        }
        // SLOC:core-end
        Ok(feats)
    }

    /// Batched path (emulator): the dynamic host still pays its boxing
    /// tax per image, but the angle table is **device-resident** across
    /// batches (one conversion + upload per distinct angle set), the
    /// stacked-image and sinogram buffers persist between same-shaped
    /// batches, and one `batched_sinogram` launch covers the whole
    /// batch — the steady state moves only the stacked images in and the
    /// sinograms out, allocating nothing.
    fn features_batch(&mut self, imgs: &[Image], thetas: &[f32]) -> Result<Vec<Vec<f32>>> {
        if imgs.is_empty() {
            return Ok(Vec::new());
        }
        let same_size = imgs.iter().all(|i| i.size() == imgs[0].size());
        if self.device != DeviceChoice::Emulator || !same_size {
            return imgs.iter().map(|img| self.features(img, thetas)).collect();
        }
        let s = imgs[0].size();
        let a = thetas.len();
        let n = imgs.len();
        let nt = T_SET.len();

        // boxed-world conversion per image (the dynamic cost the paper
        // measures), stacked into a single upload
        let mut stacked = Vec::with_capacity(n * s * s);
        for img in imgs {
            let dimg = DynArray::from_f32(img.pixels(), &[s, s])?;
            stacked.extend(dimg.to_f32_vec());
        }
        let imgs_t = Tensor::from_f32(&stacked, &[n, s, s]);

        // device-resident angle table, refreshed only when the set changes
        let akey: Vec<u32> = thetas.iter().map(|t| t.to_bits()).collect();
        let stale = match &self.angles_dev {
            Some((k, _)) => *k != akey,
            None => true,
        };
        if stale {
            let dangles =
                DynArray::from_vec(thetas.iter().map(|&t| t as f64).collect(), &[a])?;
            let angles_t = Tensor::from_f32(&dangles.to_f32_vec(), &[a]);
            self.angles_dev = Some((akey, DeviceArray::from_tensor(&self.ctx, &angles_t)?));
        }

        // persistent device buffers, rebuilt only when the batch shape
        // changes (the old ones drop back into the pool's bins first) or
        // when a mode flip to device reduce finds no reduce scratch
        let np = P_SET.len();
        let dev = self.device_reduce();
        let bkey = (n, s, a);
        let rebuild = match &self.batch_bufs {
            Some(b) => b.key != bkey || (dev && b.circus.is_none()),
            None => true,
        };
        if rebuild {
            self.batch_bufs = None;
            let (circus, feats) = if dev {
                (
                    Some(DeviceArray::alloc(&self.ctx, Dtype::F32, &[n, nt, np, a])?),
                    Some(DeviceArray::alloc(&self.ctx, Dtype::F32, &[n, FEATURE_COUNT])?),
                )
            } else {
                (None, None)
            };
            self.batch_bufs = Some(BatchBufs {
                key: bkey,
                imgs: DeviceArray::alloc(&self.ctx, Dtype::F32, &[n, s, s])?,
                sinos: DeviceArray::alloc(&self.ctx, Dtype::F32, &[n, nt, a, s])?,
                circus,
                feats,
            });
        }

        let f = self.batched_function()?;
        let bufs = self.batch_bufs.as_ref().unwrap();
        let (_, angles_dev) = self.angles_dev.as_ref().unwrap();
        bufs.imgs.upload(&imgs_t)?;
        let args = vec![
            KernelArg::Ptr(bufs.imgs.ptr()),
            KernelArg::Ptr(angles_dev.ptr()),
            KernelArg::Ptr(bufs.sinos.ptr()),
            KernelArg::I32(s as i32),
        ];
        f.launch(
            &checked_cfg2("batched_sinogram", (a, n), s)?,
            &args,
            self.ctx.memory()?,
        )?;

        if dev {
            // One launch pair reduces the whole batch's sinograms on
            // device; the download is n * FEATURE_COUNT floats.
            let bufs = self.batch_bufs.as_ref().unwrap();
            let (sinos, circus, feats) = (
                bufs.sinos.ptr(),
                bufs.circus.as_ref().expect("device-reduce scratch built above").ptr(),
                bufs.feats.as_ref().expect("device-reduce scratch built above").ptr(),
            );
            self.launch_reduce(sinos, circus, feats, n * nt, s, a)?;
            let bufs = self.batch_bufs.as_ref().unwrap();
            let feats_host = bufs.feats.as_ref().expect("checked above").download()?;
            let all = feats_host.as_f32();
            return Ok((0..n)
                .map(|i| all[i * FEATURE_COUNT..(i + 1) * FEATURE_COUNT].to_vec())
                .collect());
        }

        let bufs = self.batch_bufs.as_ref().unwrap();
        let mut sinos_host = Tensor::zeros_f32(&[n, nt, a, s]);
        bufs.sinos.download_into(&mut sinos_host)?;

        let all = sinos_host.as_f32();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let mut feats: DynFeats = Vec::with_capacity(nt * 6);
            for ti in 0..nt {
                let off = (i * nt + ti) * a * s;
                feats.extend(dyn_reduce_plane(&all[off..off + a * s], a, s)?);
            }
            out.push(feats);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracetransform::functionals::FEATURE_COUNT;
    use crate::tracetransform::image::{orientations, shepp_logan};

    #[test]
    fn emulator_dynamic_batch_keeps_angles_and_buffers_device_resident() {
        use crate::tracetransform::image::random_phantom;
        let _g = crate::tracetransform::impls::REDUCE_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let imgs: Vec<Image> = (0..3).map(|i| random_phantom(10, 70 + i as u64)).collect();
        let thetas = orientations(5);
        let mut m = GpuDynamic::on_device(DeviceChoice::Emulator).unwrap();
        // device reduce allocates the circus/feature scratch per
        // sequential call (5 buffers); host reduce the Listing-2 three
        let per_call = if m.device_reduce() { 5 } else { 3 };
        m.features_batch(&imgs, &thetas).unwrap(); // cold: buffers + angle table
        m.ctx.memory().unwrap().reset_stats();
        m.features_batch(&imgs, &thetas).unwrap();
        let bat = m.ctx.mem_stats().unwrap();
        m.ctx.memory().unwrap().reset_stats();
        for img in &imgs {
            m.features(img, &thetas).unwrap();
        }
        let seq = m.ctx.mem_stats().unwrap();
        assert_eq!(bat.h2d_count, 1, "stacked images only; angles stay on device");
        assert_eq!(bat.d2h_count, 1, "one result download per batch");
        assert_eq!(bat.alloc_count, 0, "persistent buffers recycle across batches");
        assert_eq!(seq.h2d_count, 2 * imgs.len() as u64);
        assert_eq!(seq.alloc_count, (per_call * imgs.len()) as u64);
        // a different batch shape rebuilds the buffers, then goes warm again
        m.ctx.memory().unwrap().reset_stats();
        m.features_batch(&imgs[..2], &thetas).unwrap();
        assert!(m.ctx.mem_stats().unwrap().alloc_count > 0);
        m.ctx.memory().unwrap().reset_stats();
        m.features_batch(&imgs[..2], &thetas).unwrap();
        assert_eq!(m.ctx.mem_stats().unwrap().alloc_count, 0);
    }

    #[test]
    fn emulator_dynamic_produces_features() {
        let img = shepp_logan(12);
        let mut m = GpuDynamic::on_device(DeviceChoice::Emulator).unwrap();
        let feats = m.features(&img, &orientations(5)).unwrap();
        assert_eq!(feats.len(), FEATURE_COUNT);
        assert!(feats.iter().all(|f| f.is_finite()));
    }
}
