//! Implementation 4 — "Julia (CPU) + CUDA (GPU)": dynamic `hostlang` host
//! code, manual driver API, precompiled kernels. The host data lives in
//! boxed f64 arrays, so every launch pays the "copying and converting
//! Julia datatypes before they are uploaded" cost the paper measures
//! (§7.3), and the between-kernel glue (P/F stacks) runs dynamically.

use std::collections::HashMap;

use crate::driver::{Context, Function, KernelArg, LaunchConfig, ModuleSource};
use crate::error::Result;
use crate::hostlang::DynArray;
use crate::runtime::ArtifactLibrary;
use crate::tensor::Tensor;
use crate::tracetransform::functionals::{FFunctional, PFunctional, F_SET, P_SET, T_SET};
use crate::tracetransform::image::Image;
use crate::tracetransform::impls::{DeviceChoice, TraceImpl};

pub struct GpuDynamic {
    ctx: Context,
    device: DeviceChoice,
    library: Option<ArtifactLibrary>,
    functions: HashMap<(&'static str, usize, usize), Function>,
}

type DynFeats = Vec<f32>;

impl GpuDynamic {
    pub fn new() -> Result<GpuDynamic> {
        Self::on_device(DeviceChoice::Pjrt)
    }

    pub fn on_device(device: DeviceChoice) -> Result<GpuDynamic> {
        let ctx = Context::create(&crate::driver::device(device.ordinal())?)?;
        let library = match device {
            DeviceChoice::Pjrt => Some(ArtifactLibrary::load_default()?),
            DeviceChoice::Emulator => None,
        };
        Ok(GpuDynamic { ctx, device, library, functions: HashMap::new() })
    }

    fn function(&mut self, s: usize, a: usize) -> Result<Function> {
        let key = ("sinogram_all", s, a);
        if let Some(f) = self.functions.get(&key) {
            return Ok(f.clone());
        }
        let f = match self.device {
            DeviceChoice::Pjrt => {
                let lib = self.library.as_ref().expect("library loaded for pjrt");
                let sig = format!("f32[{s},{s}];f32[{a}]");
                let entry = lib.find("sinogram_all", &sig)?.clone();
                let module = self.ctx.load_module(&lib.module_source(&entry))?;
                module.function("main")?
            }
            DeviceChoice::Emulator => {
                let module = self.ctx.load_module(&ModuleSource::Vtx {
                    kernels: vec![crate::emulator::kernels::sinogram_all()?],
                })?;
                module.function("sinogram_all")?
            }
        };
        self.functions.insert(key, f.clone());
        Ok(f)
    }
}

impl TraceImpl for GpuDynamic {
    fn name(&self) -> &'static str {
        "gpu-dynamic"
    }

    fn features(&mut self, img: &Image, thetas: &[f32]) -> Result<Vec<f32>> {
        // SLOC:core-begin
        let s = img.size();
        let a = thetas.len();

        // host world: boxed f64 arrays (the dynamic language's natural type)
        let dimg = DynArray::from_f32(img.pixels(), &[s, s])?;
        let dangles =
            DynArray::from_vec(thetas.iter().map(|&t| t as f64).collect(), &[a])?;

        // per-launch conversion: f64 boxes -> f32 tensors (the overhead
        // the paper attributes to argument conversion)
        let img_t = Tensor::from_f64_as_f32(
            &dimg.to_f32_vec().iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &[s, s],
        );
        let angles_t = Tensor::from_f64_as_f32(
            &dangles.to_f32_vec().iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &[a],
        );

        let nt = T_SET.len();
        let ga = self.ctx.alloc(img_t.byte_len())?;
        let gb = self.ctx.alloc(angles_t.byte_len())?;
        let gc = self.ctx.alloc(nt * a * s * 4)?;
        self.ctx.upload(ga, img_t.bytes())?;
        self.ctx.upload(gb, angles_t.bytes())?;

        // one fused launch computes every T-functional's sinogram
        let f = self.function(s, a)?;
        let args = match self.device {
            DeviceChoice::Pjrt => {
                vec![KernelArg::Ptr(ga), KernelArg::Ptr(gb), KernelArg::Ptr(gc)]
            }
            DeviceChoice::Emulator => vec![
                KernelArg::Ptr(ga),
                KernelArg::Ptr(gb),
                KernelArg::Ptr(gc),
                KernelArg::I32(s as i32),
            ],
        };
        f.launch(&LaunchConfig::new(a as u32, s as u32), &args, self.ctx.memory()?)?;
        let mut sinos_host = Tensor::zeros_f32(&[nt, a, s]);
        self.ctx.download(gc, sinos_host.bytes_mut())?;

        let mut feats: DynFeats = Vec::with_capacity(nt * 6);
        for ti in 0..nt {
            // back into the boxed world before the dynamic P/F stacks
            let sino = DynArray::zeros(&[a, s]);
            sino.fill_from_f32(&sinos_host.as_f32()[ti * a * s..(ti + 1) * a * s])?;
            for p in P_SET {
                let mut circus = Vec::with_capacity(a);
                for ai in 1..=a {
                    let mut acc = match p {
                        PFunctional::Max => f64::NEG_INFINITY,
                        _ => 0.0,
                    };
                    for x in 1..=s {
                        let v = sino.get(&[ai, x])?.as_float()?;
                        match p {
                            PFunctional::Sum => acc += v,
                            PFunctional::Max => acc = acc.max(v),
                            PFunctional::L1 => acc += v.abs(),
                        }
                    }
                    circus.push(acc);
                }
                for f in F_SET {
                    let v = match f {
                        FFunctional::Mean => circus.iter().sum::<f64>() / a as f64,
                        FFunctional::Max => {
                            circus.iter().copied().fold(f64::NEG_INFINITY, f64::max)
                        }
                    };
                    feats.push(v as f32);
                }
            }
        }

        self.ctx.free(ga)?;
        self.ctx.free(gb)?;
        self.ctx.free(gc)?;
        // SLOC:core-end
        Ok(feats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracetransform::functionals::FEATURE_COUNT;
    use crate::tracetransform::image::{orientations, shepp_logan};

    #[test]
    fn emulator_dynamic_produces_features() {
        let img = shepp_logan(12);
        let mut m = GpuDynamic::on_device(DeviceChoice::Emulator).unwrap();
        let feats = m.features(&img, &orientations(5)).unwrap();
        assert_eq!(feats.len(), FEATURE_COUNT);
        assert!(feats.iter().all(|f| f.is_finite()));
    }
}
