//! Implementation 1 — "C++ (CPU)": optimized native rust, plain `f32`
//! slices, fused sampling (every rotated sample computed once and fed to
//! all four T-functionals simultaneously).

use crate::error::Result;
use crate::tracetransform::functionals::{reduce_sinogram, T_SET};
use crate::tracetransform::image::Image;
use crate::tracetransform::impls::TraceImpl;
use crate::tracetransform::rotate::sample_bilinear;

pub struct CpuNative {
    /// Scratch sinograms, one per T-functional (reused across calls).
    sinos: Vec<Vec<f32>>,
}

impl CpuNative {
    pub fn new() -> CpuNative {
        CpuNative { sinos: vec![Vec::new(); T_SET.len()] }
    }

    /// Core marching loop against a precomputed `(sin, cos)` table — the
    /// batched path shares one table across all images.
    fn features_with_trig(&mut self, img: &Image, trig: &[(f32, f32)]) -> Result<Vec<f32>> {
        // SLOC:core-begin
        let s = img.size();
        let a = trig.len();
        let src = img.pixels();
        let c = (s as f32 - 1.0) / 2.0;
        for sino in &mut self.sinos {
            sino.clear();
            sino.resize(a * s, 0.0);
        }
        for (ai, &(st, ct)) in trig.iter().enumerate() {
            for col in 0..s {
                let dx = col as f32 - c;
                let sx_base = ct * dx + c;
                let sy_base = c - st * dx;
                let (mut radon, mut t1, mut t2) = (0.0f32, 0.0f32, 0.0f32);
                let mut tmax = f32::NEG_INFINITY;
                for r in 0..s {
                    let dy = r as f32 - c;
                    let v = sample_bilinear(src, s, sy_base + ct * dy, sx_base + st * dy);
                    radon += v;
                    t1 += dy.abs() * v;
                    t2 += dy * dy * v;
                    tmax = tmax.max(v);
                }
                let o = ai * s + col;
                self.sinos[0][o] = radon;
                self.sinos[1][o] = t1;
                self.sinos[2][o] = t2;
                self.sinos[3][o] = tmax;
            }
        }
        let mut feats = Vec::with_capacity(T_SET.len() * 6);
        for sino in &self.sinos {
            feats.extend(reduce_sinogram(sino, a, s));
        }
        // SLOC:core-end
        Ok(feats)
    }
}

impl Default for CpuNative {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceImpl for CpuNative {
    fn name(&self) -> &'static str {
        "cpu-native"
    }

    fn features(&mut self, img: &Image, thetas: &[f32]) -> Result<Vec<f32>> {
        let trig: Vec<(f32, f32)> = thetas.iter().map(|t| t.sin_cos()).collect();
        self.features_with_trig(img, &trig)
    }

    /// Batched path: one trig table for the whole batch; the per-T
    /// scratch sinograms were already reused across calls.
    fn features_batch(&mut self, imgs: &[Image], thetas: &[f32]) -> Result<Vec<Vec<f32>>> {
        let trig: Vec<(f32, f32)> = thetas.iter().map(|t| t.sin_cos()).collect();
        imgs.iter().map(|img| self.features_with_trig(img, &trig)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracetransform::functionals::{FEATURE_COUNT, TFunctional};
    use crate::tracetransform::image::{orientations, shepp_logan};
    use crate::tracetransform::rotate;

    #[test]
    fn matches_unfused_reference() {
        let img = shepp_logan(20);
        let thetas = orientations(7);
        let feats = CpuNative::new().features(&img, &thetas).unwrap();
        assert_eq!(feats.len(), FEATURE_COUNT);
        // independently: per-T staged sinogram + reduction
        let mut want = Vec::new();
        for t in T_SET {
            let sino = rotate::sinogram(&img, &thetas, t);
            want.extend(reduce_sinogram(&sino, thetas.len(), img.size()));
        }
        for (i, (a, b)) in feats.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-2 * b.abs().max(1.0), "feature {i}: {a} vs {b}");
        }
    }

    #[test]
    fn features_depend_on_image_content() {
        let thetas = orientations(8);
        let a = CpuNative::new().features(&shepp_logan(16), &thetas).unwrap();
        let blank = Image::zeros(16);
        let b = CpuNative::new().features(&blank, &thetas).unwrap();
        assert_ne!(a, b);
        // radon-sum-mean of a blank image is 0
        let idx = 0; // (Radon, Sum, Mean)
        assert_eq!(b[idx], 0.0);
        assert!(a[idx] > 0.0);
        let _ = TFunctional::Radon;
    }
}
